"""EP shard_map path vs dense reference oracle — runs in a subprocess with
8 forced host devices (the main pytest process must keep 1 device)."""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import AxisType, make_mesh, set_mesh
    from repro.models.layers import ModelConfig
    from repro.models import moe as M

    mesh = make_mesh((2, 4), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
    set_mesh(mesh)
    cfg = ModelConfig(name="moe-test", family="moe", num_layers=1,
                      d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
                      d_ff=96, vocab_size=128, num_experts=6, top_k=2,
                      expert_pad_to=8, moe_capacity_factor=4.0,
                      dtype=jnp.float32)
    params = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64), jnp.float32)

    ref = M.apply_moe_reference(params, x, cfg)
    info = M.EPInfo(mesh=mesh, ep_axes=("data", "model"),
                    batch_axes=("data",), capacity_factor=4.0)
    ep_fn = jax.jit(lambda p, xx: M.apply_moe_ep(p, xx, cfg, info))
    out = ep_fn(params, x)
    err = float(jnp.abs(out - ref).max())
    rel = err / float(jnp.abs(ref).max())
    info_f = M.EPInfo(mesh=mesh, ep_axes=("data", "model"),
                      batch_axes=("data",), capacity_factor=4.0,
                      fused_a2a=True)
    f_fn = jax.jit(lambda p, xx: M.apply_moe_ep(p, xx, cfg, info_f))
    out_f = f_fn(params, x)
    rel_fused = float(jnp.abs(out_f - out).max()) / float(jnp.abs(ref).max())
    info_ag = M.EPInfo(mesh=mesh, ep_axes=("data", "model"),
                       batch_axes=("data",), ep_mode="allgather")
    ag_fn = jax.jit(lambda p, xx: M.apply_moe_ep(p, xx, cfg, info_ag))
    out_ag = ag_fn(params, x)
    err_ag = float(jnp.abs(out_ag - ref).max())
    rel_ag = err_ag / float(jnp.abs(ref).max())
    print(json.dumps({"err": err, "rel": rel, "rel_ag": rel_ag,
                      "rel_fused": rel_fused}))
""")


def test_ep_matches_reference():
    # the subprocess doesn't see pytest's pyproject pythonpath insertion
    env = dict(os.environ)
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=420, env=env,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    data = json.loads(res.stdout.strip().splitlines()[-1])
    # capacity_factor=4 on tiny batches still drops a little; the surviving
    # tokens must match closely
    assert data["rel"] < 5e-2, data
    # allgather mode has NO capacity drops: must match the oracle tightly
    assert data["rel_ag"] < 1e-4, data
    # fused all_to_all must be bit-identical routing vs per-axis composition
    assert data["rel_fused"] < 1e-5, data
