"""Vectorized scheduler hot path: batched-vs-scalar numerical parity.

The vectorized dispatch path exists purely as an optimization — every
array evaluation must be bit-for-bit identical to the scalar reference
(same IEEE-754 operations in the same association order), so the
fixed-seed decision streams of the two paths can never diverge. These
tests pin that contract at both layers:

* property-style grids over the ``predict_*_batch`` entry points against
  per-element scalar calls — across heterogeneous ``HardwareSpec``s,
  bucketed γ ``InterferenceTable``s, and warmed ``OnlinePredictor`` EWMA
  states;
* end-to-end fixed-seed runs (single-class, 2-class mixture, hetero +
  online calibration) asserting the recorded decision streams match
  exactly between ``build_cluster(..., vectorized=True)`` and the scalar
  reference.
"""
import dataclasses
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.common import MODEL, WORKER, clone_trace, cost_model, \
    make_trace
from repro.configs import get_config
from repro.core.predictor import (AnalyticalPredictor, BiasedPredictor,
                                  OnlinePredictor)
from repro.perf.hardware import InterferenceTable, V5E, WorkerSpec, \
    gamma_at, gamma_at_batch
from repro.perf.predictor import ClusterPredictor
from repro.serving.costmodel import CostModel
from repro.serving.simulator import build_cluster

GAMMA_TABLE = InterferenceTable(
    decode_edges=(0, 8, 32), chunk_edges=(0, 512, 2048),
    gamma=((0.0, 0.05, 0.12), (0.03, 0.10, 0.22), (0.08, 0.18, 0.35)))


@pytest.fixture(scope="module")
def cost():
    return cost_model()


@pytest.fixture(scope="module")
def gamma_cost():
    hw = dataclasses.replace(V5E, interference=GAMMA_TABLE)
    return CostModel(get_config(MODEL), WorkerSpec(tp=8, hw=hw))


def _grid(rng, n=64):
    """Mixed-phase argument grid with deliberate zeros/edge rows."""
    nd = rng.integers(0, 48, n)
    nd[:8] = 0                                    # pure-prefill rows
    sc = np.where(nd > 0, nd * rng.integers(64, 4096, n), 0.0).astype(float)
    pt = rng.integers(0, 4096, n)
    pt[8:16] = 0                                  # pure-decode rows
    pt[:4] = 0                                    # fully idle rows
    off = rng.integers(0, 2048, n).astype(float)
    return nd, sc, pt, off


# --------------------------------------------------- cost-model batch lanes

def test_iteration_time_batch_matches_scalar(gamma_cost):
    rng = np.random.default_rng(0)
    nd, sc, pt, off = _grid(rng)
    got = gamma_cost.iteration_time_batch(nd.astype(float), sc,
                                          pt.astype(float), off)
    for i in range(nd.size):
        want = gamma_cost.iteration_time(int(nd[i]), float(sc[i]),
                                         int(pt[i]), float(off[i]))
        assert got[i] == want, (i, nd[i], sc[i], pt[i], off[i])


def test_uniform_phase_fast_lanes_match_scalar(gamma_cost):
    """Scalar-zero ``n_decode`` / ``prefill_tokens`` take the dedicated
    fast lanes; their outputs must still be bit-identical."""
    rng = np.random.default_rng(1)
    toks = rng.integers(0, 8192, 48)
    offs = rng.integers(0, 4096, 48).astype(float)
    got = gamma_cost.iteration_time_batch(0, 0.0, toks.astype(float), offs)
    for i in range(toks.size):
        assert got[i] == gamma_cost.iteration_time(0, 0.0, int(toks[i]),
                                                   float(offs[i]))
    nd = rng.integers(0, 64, 48)
    sc = (nd * rng.integers(128, 4096, 48)).astype(float)
    got = gamma_cost.iteration_time_batch(nd.astype(float), sc)
    for i in range(nd.size):
        assert got[i] == gamma_cost.iteration_time(int(nd[i]), float(sc[i]))


def test_interference_penalty_batch_matches_scalar(gamma_cost):
    rng = np.random.default_rng(2)
    nd, sc, pt, off = _grid(rng)
    got = gamma_cost.interference_penalty_batch(nd.astype(float), sc,
                                               pt.astype(float), off)
    for i in range(nd.size):
        want = gamma_cost.interference_penalty(int(nd[i]), float(sc[i]),
                                               int(pt[i]), float(off[i]))
        assert got[i] == want, (i, nd[i], pt[i])


def test_gamma_at_batch_matches_scalar_on_bucket_edges():
    """γ lookups exactly on, below, and above every bucket edge resolve
    to the same cell as the scalar ``bisect`` path."""
    probes = [0, 1, 7, 8, 9, 31, 32, 33, 100]
    chunks = [0, 1, 511, 512, 513, 2047, 2048, 2049, 10000]
    n = np.array([float(p) for p in probes for _ in chunks])
    p = np.array([float(c) for _ in probes for c in chunks])
    got = gamma_at_batch(GAMMA_TABLE, n, p)
    for i in range(n.size):
        assert got[i] == gamma_at(GAMMA_TABLE, n[i], p[i]), (n[i], p[i])
    # scalar-γ (degenerate table) and plain-float specs resolve too
    assert np.all(gamma_at_batch(0.25, n, p) == 0.25)


# ------------------------------------------------------- predictor parity

def _assert_batch_matches_scalar(pred, wids, nd, sc, pt, off):
    toks = pt.astype(np.int64)
    got_p = pred.predict_prefill_batch(wids, toks, off.astype(np.int64))
    got_d = pred.predict_decode_iter_batch(wids, nd, sc)
    got_i = pred.predict_interference_batch(wids, nd, sc, toks, off)
    for i, w in enumerate(wids):
        assert got_p[i] == pred.predict_prefill(
            int(toks[i]), int(off[i]), wid=w)
        assert got_d[i] == pred.predict_decode_iter(
            int(nd[i]), float(sc[i]), wid=w)
        assert got_i[i] == pred.predict_interference(
            int(nd[i]), float(sc[i]), int(toks[i]), float(off[i]), wid=w)


def test_analytical_predictor_batch_parity(gamma_cost):
    rng = np.random.default_rng(3)
    nd, sc, pt, off = _grid(rng)
    pred = AnalyticalPredictor(gamma_cost, safety=1.1)
    _assert_batch_matches_scalar(pred, [None] * nd.size, nd, sc, pt, off)


def test_cluster_predictor_hetero_batch_parity(gamma_cost):
    """Heterogeneous hardware: each row prices on its own worker's spec,
    including a 1.7x straggler, a smaller TP slice, and a γ table."""
    cfg = get_config(MODEL)
    costs = {
        0: CostModel(cfg, WORKER),
        1: CostModel(cfg, WorkerSpec(tp=8, hw=V5E.slowed(1.7))),
        2: CostModel(cfg, WorkerSpec(tp=4)),
        3: gamma_cost,
    }
    pred = ClusterPredictor(costs, safety=1.1)
    rng = np.random.default_rng(4)
    nd, sc, pt, off = _grid(rng)
    wids = [int(w) if w >= 0 else None
            for w in rng.integers(-1, 4, nd.size)]
    _assert_batch_matches_scalar(pred, wids, nd, sc, pt, off)


def test_online_predictor_warmed_ewma_batch_parity(gamma_cost):
    """The EWMA-corrected scales must gather identically into the batch
    path after real observations have moved them off 1.0."""
    pred = OnlinePredictor(BiasedPredictor(gamma_cost, 1.6))
    truth = gamma_cost
    for k in range(25):
        pred.observe_prefill(1024 + 64 * k, 0,
                             truth.prefill_time(1024 + 64 * k))
        pred.observe_decode(8 + k, (8 + k) * 1500.0,
                            truth.decode_iter_time(8 + k, (8 + k) * 1500.0))
    assert pred.prefill_scale != 1.0 and pred.decode_scale != 1.0
    rng = np.random.default_rng(5)
    nd, sc, pt, off = _grid(rng)
    _assert_batch_matches_scalar(pred, [None] * nd.size, nd, sc, pt, off)


# ------------------------------------------- end-to-end decision parity

def _decisions(policy, trace, vectorized, n_workers, **kw):
    sim, _ = build_cluster(get_config(MODEL), policy, n_workers=n_workers,
                           worker_spec=WORKER, record_decisions=True,
                           vectorized=vectorized, **kw)
    sim.add_trace(clone_trace(trace))
    m = sim.run()
    return sim.decisions, m


def _assert_run_parity(policy, trace, n_workers=8, **kw):
    da, ma = _decisions(policy, trace, False, n_workers, **kw)
    db, mb = _decisions(policy, trace, True, n_workers, **kw)
    assert len(da) == len(db)
    for i, (x, y) in enumerate(zip(da, db)):
        assert x == y, f"decision {i} diverged: {x} vs {y}"
    assert ma.slo_attainment == mb.slo_attainment


def test_decision_parity_tropical(cost):
    trace = make_trace(2.5, 30.0, cost, seed=5)
    _assert_run_parity("tropical", trace)


def test_decision_parity_mixture_two_classes(cost):
    """2-class SLO mixture: class-aware queue ordering, per-class floors,
    and the multiplex admission gates all stay in lockstep."""
    from repro.launch.serve import _classes_scenario, parse_slo_classes
    classes = parse_slo_classes(
        "interactive:scale=3,weight=2,frac=0.6;batch:scale=9,frac=0.4")
    scenario = _classes_scenario(classes, cost)
    trace = scenario.generate(2.0, 30.0, cost, seed=7)
    _assert_run_parity("tropical", trace, n_workers=4)


def test_decision_parity_hetero_online(cost):
    """Heterogeneous specs + online EWMA calibration: per-worker batch
    grouping and the calibrated scale gathers stay bit-identical."""
    specs = [WORKER, WorkerSpec(tp=8, hw=V5E.slowed(1.7)),
             WORKER, WorkerSpec(tp=4)]
    trace = make_trace(2.0, 25.0, cost, seed=5)
    _assert_run_parity("tropical", trace, n_workers=4,
                       worker_specs=specs, online_predictor=True)


# ------------------------------------- closed-form slack chunking parity

def _chunk_toggle(pred, rng, n=24):
    """A slack_chunking toggle over n MULTIPLEX views spanning the grid:
    empty/small/large decode batches, short/long contexts, and slack
    budgets that land the answer at min_chunk, in the interior, and at
    chunk_tokens."""
    from repro.core.toggle import (MultiplexingToggle, Role, ToggleConfig,
                                   WorkerView)
    cfg_t = ToggleConfig(slack_chunking=True)
    views = []
    for i in range(n):
        b = int(rng.choice([0, 1, 4, 8, 32]))
        sc = float(b) * float(rng.choice([128, 2048, 8192]))
        v = WorkerView(wid=i, role=Role.MULTIPLEX, kv_capacity_tokens=1e9,
                       decode_batch=b, decode_sum_ctx=sc)
        ref = pred.predict_prefill(int(rng.integers(64, 4096)), int(sc),
                                   wid=i)
        v.min_tpot_slack = ref * cfg_t.slack_safety \
            * float(rng.choice([0.02, 0.6, 1.0, 1.7, 50.0]))
        views.append(v)
    return MultiplexingToggle(views, pred, cfg_t), views


def _count_prefill_batch_calls(pred):
    calls = []
    orig = pred.predict_prefill_batch

    def counting(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    pred.predict_prefill_batch = counting
    return calls


def _assert_chunk_parity(pred, seed, closed_form=True):
    rng = np.random.default_rng(seed)
    tog, views = _chunk_toggle(pred, rng)
    cols = tog._cols_sync()
    gidx = np.arange(len(views))
    calls = _count_prefill_batch_calls(pred)
    closed = tog._chunk_for_vec(cols, gidx, 10.0)
    if closed_form:
        # the whole point: ONE batched cost evaluation per arrival where
        # the lockstep bisection issued ~log2(chunk_tokens - min_chunk)
        assert len(calls) == 1
    bisected = tog._chunk_for_vec_bisect(cols, gidx, 10.0)
    np.testing.assert_array_equal(closed, bisected)
    scalar = np.array([tog.chunk_for(v, 10.0) for v in views])
    np.testing.assert_array_equal(scalar, closed)
    # answers must actually span the range or the grid proves nothing
    assert closed.min() == tog.cfg.min_chunk
    assert closed.max() == tog.cfg.chunk_tokens
    assert np.any((closed > tog.cfg.min_chunk)
                  & (closed < tog.cfg.chunk_tokens))


def _interference_model(name=MODEL, interference=GAMMA_TABLE, slow=1.0):
    hw = dataclasses.replace(V5E, interference=interference)
    if slow != 1.0:
        hw = hw.slowed(slow)
    return CostModel(get_config(name), WorkerSpec(tp=8, hw=hw))


def test_chunk_closed_form_matches_bisection_gamma_shapes():
    for seed, interf in [(11, 0.0), (12, 0.8), (13, GAMMA_TABLE)]:
        _assert_chunk_parity(
            AnalyticalPredictor(_interference_model(interference=interf)),
            seed)


def test_chunk_closed_form_matches_bisection_sliding_window():
    """gemma2's ctx_cap bends both rooflines mid-range: the closed form
    must cover the cap-crossing breakpoints, not just smooth roots."""
    _assert_chunk_parity(
        AnalyticalPredictor(_interference_model("gemma2-2b")), 17)


def test_chunk_closed_form_matches_bisection_biased_and_cluster():
    _assert_chunk_parity(
        BiasedPredictor(_interference_model(), bias=1.7), 19)
    costs = {i: _interference_model(slow=(1.0 if i % 2 == 0 else 2.0))
             for i in range(24)}
    _assert_chunk_parity(ClusterPredictor(costs), 23)


def test_chunk_closed_form_matches_bisection_online_warmed():
    """The EWMA prefill scale is piecewise constant over pow2 size
    buckets; the closed form folds the per-segment scale in and must
    still agree with bisection after observations move scales off 1.0."""
    base = AnalyticalPredictor(_interference_model())
    pred = OnlinePredictor(base, per_worker=True)
    rng = np.random.default_rng(29)
    for _ in range(200):
        tk, ct = int(rng.integers(64, 4096)), float(rng.integers(0, 8192))
        pred.observe_prefill(tk, int(ct),
                             base.predict_prefill(tk, int(ct)) / base.safety
                             * float(rng.uniform(0.6, 1.9)),
                             wid=int(rng.integers(0, 24)))
        b = int(rng.integers(1, 32))
        pred.observe_decode(b, b * 512.0,
                            base.predict_decode_iter(b, b * 512.0)
                            / base.safety * float(rng.uniform(0.6, 1.9)),
                            wid=int(rng.integers(0, 24)))
    assert pred.prefill_scale != 1.0
    _assert_chunk_parity(pred, 31)


def test_chunk_non_analytic_predictor_falls_back_to_bisection():
    from repro.perf.predictor import ProfiledPredictor
    pred = ProfiledPredictor([(128, 0.01), (2048, 0.1)],
                             [(1, 0.005, 512.0), (32, 0.02, 512.0)],
                             1e-8, 1e-9)
    assert pred.chunk_candidates([0], 256, 2048, np.array([0.05]),
                                 np.array([0.0]), np.array([0.0]),
                                 np.array([0.0])) is None
    _assert_chunk_parity(pred, 37, closed_form=False)
