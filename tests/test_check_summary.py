"""CI perf gate (benchmarks/check_summary.py): tolerance classification,
the demonstrated-failure path, and snapshot-layout mismatch handling."""
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.check_summary import (ATTAINMENT_DROP, LATENCY_REGRESS,
                                      RPS_DROP, check, classify, main)

SNAPSHOT = {
    "schema_version": 2,
    "ref_rate": 2.0,
    "generator": "benchmarks.run --quick",
    "n_requests": 80,
    "slo_attainment": 0.976,
    "weighted_attainment": 1.0,
    "ttft_p90_s": 0.9635,
    "mean_step_s": 0.01365,
    "sim_throughput_rps": 900.0,
}


def _fails(lines):
    return [ln for ln in lines if ln.startswith("FAIL")]


def test_classify_heuristics():
    assert classify("schema_version", 2) == "exact"
    assert classify("ttft_p90_s", 0.9) == "latency"
    assert classify("slo_attainment", 0.97) == "attainment"
    assert classify("goodput_ratio", 2.1) == "info"
    assert classify("sim_throughput_rps", 900.0) == "throughput"
    # the suffix wins even for sub-1.0 values that look like fractions:
    # gating a slow sim's rps as attainment would invert the tolerance
    assert classify("sim_throughput_rps", 0.4) == "throughput"


def test_throughput_drop_beyond_tolerance_fails():
    fresh = dict(SNAPSHOT)
    fresh["sim_throughput_rps"] = \
        SNAPSHOT["sim_throughput_rps"] * (1 - RPS_DROP) * 0.9
    fails = _fails(check(fresh, SNAPSHOT))
    assert len(fails) == 1 and "sim_throughput_rps" in fails[0]
    # a drop inside tolerance passes
    fresh["sim_throughput_rps"] = \
        SNAPSHOT["sim_throughput_rps"] * (1 - RPS_DROP) * 1.01
    assert _fails(check(fresh, SNAPSHOT)) == []
    # improvements always pass
    fresh["sim_throughput_rps"] = SNAPSHOT["sim_throughput_rps"] * 10
    assert _fails(check(fresh, SNAPSHOT)) == []


def test_identical_summaries_pass():
    assert _fails(check(dict(SNAPSHOT), SNAPSHOT)) == []


def test_attainment_drop_beyond_tolerance_fails():
    fresh = dict(SNAPSHOT)
    fresh["slo_attainment"] = SNAPSHOT["slo_attainment"] \
        - ATTAINMENT_DROP - 0.01
    fails = _fails(check(fresh, SNAPSHOT))
    assert len(fails) == 1 and "slo_attainment" in fails[0]
    # a drop inside tolerance (and any rise) passes
    fresh["slo_attainment"] = SNAPSHOT["slo_attainment"] - 0.01
    assert _fails(check(fresh, SNAPSHOT)) == []
    fresh["slo_attainment"] = 1.0
    assert _fails(check(fresh, SNAPSHOT)) == []


def test_latency_regression_beyond_tolerance_fails():
    fresh = dict(SNAPSHOT)
    fresh["ttft_p90_s"] = SNAPSHOT["ttft_p90_s"] * (1 + LATENCY_REGRESS) * 1.1
    fails = _fails(check(fresh, SNAPSHOT))
    assert len(fails) == 1 and "ttft_p90_s" in fails[0]
    # within tolerance / speedups pass
    fresh["ttft_p90_s"] = SNAPSHOT["ttft_p90_s"] * 1.2
    assert _fails(check(fresh, SNAPSHOT)) == []
    fresh["ttft_p90_s"] = SNAPSHOT["ttft_p90_s"] * 0.5
    assert _fails(check(fresh, SNAPSHOT)) == []


def test_schema_and_layout_mismatches_fail():
    fresh = dict(SNAPSHOT)
    fresh["schema_version"] = SNAPSHOT["schema_version"] + 1
    assert _fails(check(fresh, SNAPSHOT))
    fresh = dict(SNAPSHOT)
    del fresh["mean_step_s"]                      # key vanished
    assert _fails(check(fresh, SNAPSHOT))
    fresh = dict(SNAPSHOT)
    fresh["brand_new_key"] = 1.0                  # key appeared
    assert _fails(check(fresh, SNAPSHOT))


def test_cli_exit_codes(tmp_path, capsys):
    """The blocking CI job's contract: 0 within tolerance, 1 on
    regression, 2 on unreadable input — demonstrated end to end."""
    snap = tmp_path / "snap.json"
    snap.write_text(json.dumps(SNAPSHOT))
    good = tmp_path / "good.json"
    good.write_text(json.dumps(SNAPSHOT))
    assert main([str(good), str(snap)]) == 0

    bad = tmp_path / "bad.json"
    regressed = dict(SNAPSHOT, slo_attainment=0.90)   # -7.6 pts
    bad.write_text(json.dumps(regressed))
    assert main([str(bad), str(snap)]) == 1
    out = capsys.readouterr().out
    assert "FAIL slo_attainment" in out
    assert "regenerate" in out.lower()

    assert main([str(tmp_path / "missing.json"), str(snap)]) == 2
    unversioned = tmp_path / "unversioned.json"
    unversioned.write_text(json.dumps({"hello": 1}))
    assert main([str(unversioned), str(snap)]) == 2
    capsys.readouterr()
