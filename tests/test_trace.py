"""Trace synthesis + Mooncake-schema CSV round-trip + seed determinism."""
import numpy as np

from repro.configs import get_config
from repro.serving.costmodel import CostModel, WorkerSpec
from repro.serving.trace import (MOONCAKE, STEADY, generate_trace, load_csv,
                                 sample_arrivals, sample_lengths, save_csv)

COST = CostModel(get_config("internlm-20b"), WorkerSpec(tp=8))


def test_csv_round_trip(tmp_path):
    path = str(tmp_path / "trace.csv")
    orig = generate_trace(2.0, 30.0, COST, seed=13)
    assert orig, "need a non-empty trace to round-trip"
    save_csv(path, orig)
    back = load_csv(path, COST)
    assert len(back) == len(orig)
    for a, b in zip(orig, back):
        assert b.prompt_len == a.prompt_len
        assert b.output_len == a.output_len
        # timestamps quantise to the schema's integer milliseconds
        assert abs(b.arrival_time - a.arrival_time) <= 1e-3
        # SLOs re-derive from the cost model on load
        assert b.slo.ttft > 0 and b.slo.tpot > 0


def test_sample_lengths_deterministic_under_seed():
    a_in, a_out = sample_lengths(np.random.default_rng(42), 500, MOONCAKE)
    b_in, b_out = sample_lengths(np.random.default_rng(42), 500, MOONCAKE)
    np.testing.assert_array_equal(a_in, b_in)
    np.testing.assert_array_equal(a_out, b_out)
    c_in, _ = sample_lengths(np.random.default_rng(43), 500, MOONCAKE)
    assert not np.array_equal(a_in, c_in)


def test_sample_arrivals_deterministic_under_seed():
    a = sample_arrivals(np.random.default_rng(7), 3.0, 60.0, MOONCAKE)
    b = sample_arrivals(np.random.default_rng(7), 3.0, 60.0, MOONCAKE)
    np.testing.assert_array_equal(a, b)
    assert np.all(np.diff(a) >= 0), "arrivals must be sorted"
    assert np.all((a >= 0.0) & (a < 60.0))
    c = sample_arrivals(np.random.default_rng(8), 3.0, 60.0, STEADY)
    assert not np.array_equal(a, c)


def test_generate_trace_deterministic_under_seed():
    a = generate_trace(2.0, 40.0, COST, seed=21)
    b = generate_trace(2.0, 40.0, COST, seed=21)
    assert [(r.rid, r.arrival_time, r.prompt_len, r.output_len,
             r.slo.ttft, r.slo.tpot) for r in a] == \
           [(r.rid, r.arrival_time, r.prompt_len, r.output_len,
             r.slo.ttft, r.slo.tpot) for r in b]
    c = generate_trace(2.0, 40.0, COST, seed=22)
    assert [(r.arrival_time, r.prompt_len) for r in a] != \
           [(r.arrival_time, r.prompt_len) for r in c]
