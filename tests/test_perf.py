"""repro.perf: the unified interference-aware performance model.

Covers the shim contract (old import paths resolve to the same objects),
the §IV mixed-batch interference term (legacy bit-parity when disabled),
per-worker hardware pricing (ClusterPredictor, WorkerView.speed,
relative_speeds), the per-(worker, phase, bucket) online-calibration
hierarchy, the measured-MFU calibrated roofline over real Pallas kernels,
and the TraceReplayBackend streaming-arrival equivalence.
"""
import copy
import dataclasses

import pytest

from repro.configs import get_config, get_smoke
from repro.perf import (AnalyticalPredictor, CalibratedRooflineBackend,
                        ClusterPredictor, CostModel, HardwareSpec,
                        IterationCostModel, OnlinePredictor, V5E, WorkerSpec,
                        calibrate_hardware, relative_speeds)
from repro.serving.simulator import build_cluster


@pytest.fixture(scope="module")
def cfg():
    return get_config("internlm-20b")


@pytest.fixture(scope="module")
def cost(cfg):
    return CostModel(cfg, WorkerSpec(tp=8))


# ------------------------------------------------------------------- shims

def test_legacy_import_paths_resolve_to_the_same_objects():
    import repro.core.predictor as legacy_pred
    import repro.perf as perf
    import repro.serving.costmodel as legacy_cost

    assert legacy_cost.CostModel is perf.CostModel
    assert legacy_cost.HardwareSpec is perf.HardwareSpec
    assert legacy_cost.WorkerSpec is perf.WorkerSpec
    assert legacy_cost.V5E is perf.V5E
    assert legacy_cost.build_cost_spec is perf.build_cost_spec
    assert legacy_pred.AnalyticalPredictor is perf.AnalyticalPredictor
    assert legacy_pred.OnlinePredictor is perf.OnlinePredictor
    assert legacy_pred.ProfiledPredictor is perf.ProfiledPredictor


def test_cost_model_satisfies_the_iteration_cost_interface(cost):
    assert isinstance(cost, IterationCostModel)


# -------------------------------------------------------- interference term

def test_interference_disabled_is_bit_identical_to_legacy(cfg, cost):
    """γ = 0 (the default) must reproduce the pre-perf-package model
    exactly — the decision-parity guarantee every benchmark relies on."""
    explicit = CostModel(cfg, WorkerSpec(
        tp=8, hw=dataclasses.replace(V5E, interference=0.0)))
    for args in ((8, 8 * 2048.0, 2048, 0.0), (16, 16 * 512.0, 0, 0.0),
                 (0, 0.0, 8192, 0), (1, 131072.0, 256, 4096.0)):
        assert explicit.iteration_time(*args) == cost.iteration_time(*args)


def test_interference_penalises_only_mixed_batches(cfg, cost):
    gamma_hw = dataclasses.replace(V5E, interference=0.5)
    inter = CostModel(cfg, WorkerSpec(tp=8, hw=gamma_hw))
    # pure phases: identical to the additive model
    assert inter.prefill_time(4096) == cost.prefill_time(4096)
    assert inter.decode_iter_time(16, 16 * 2048.0) == \
        cost.decode_iter_time(16, 16 * 2048.0)
    # mixed batch: strictly super-additive, bounded by the serialised sum
    legacy = cost.iteration_time(8, 8 * 2048.0, 2048, 0.0)
    mixed = inter.iteration_time(8, 8 * 2048.0, 2048, 0.0)
    serialised = cost.prefill_time(2048) + cost.decode_iter_time(
        8, 8 * 2048.0)
    assert legacy < mixed < serialised


def test_interference_monotone_in_gamma(cfg):
    times = [
        CostModel(cfg, WorkerSpec(tp=8, hw=dataclasses.replace(
            V5E, interference=g))).iteration_time(8, 8 * 2048.0, 2048, 0.0)
        for g in (0.0, 0.25, 0.5, 1.0)]
    assert times == sorted(times) and len(set(times)) == 4


# ------------------------------------------------------ per-worker hardware

def test_slowed_spec_scales_compute_and_memory():
    hw = V5E.slowed(2.0)
    assert hw.peak_flops == V5E.peak_flops / 2.0
    assert hw.hbm_bw == V5E.hbm_bw / 2.0
    assert hw.hbm_bytes == V5E.hbm_bytes          # capacity is unchanged


def test_relative_speeds_homogeneous_is_exactly_one(cfg):
    c = CostModel(cfg, WorkerSpec(tp=8))
    speeds = relative_speeds({0: c, 1: c, 2: c})
    assert all(s == 1.0 for s in speeds.values())


def test_relative_speeds_orders_straggler(cfg):
    fast = CostModel(cfg, WorkerSpec(tp=8))
    slow = CostModel(cfg, WorkerSpec(tp=8, hw=V5E.slowed(2.0)))
    speeds = relative_speeds({0: fast, 1: slow})
    assert speeds[0] == 1.0
    assert 0.4 < speeds[1] < 0.6          # ~half the throughput


def test_cluster_predictor_prices_on_the_target_worker(cfg):
    fast = CostModel(cfg, WorkerSpec(tp=8))
    slow = CostModel(cfg, WorkerSpec(tp=8, hw=V5E.slowed(2.0)))
    pred = ClusterPredictor({0: fast, 1: slow})
    assert pred.predict_prefill(4096, wid=1) > \
        pred.predict_prefill(4096, wid=0)
    # wid=None prices on the reference (fastest) model
    assert pred.predict_prefill(4096) == pred.predict_prefill(4096, wid=0)
    assert pred.predict_decode_iter(8, 8 * 2048.0, wid=1) > \
        pred.predict_decode_iter(8, 8 * 2048.0, wid=0)


def test_build_cluster_heterogeneous_wires_speeds_and_predictor(cfg):
    fast = WorkerSpec(tp=8)
    slow = WorkerSpec(tp=8, hw=V5E.slowed(2.0))
    sim, _ = build_cluster(cfg, "tropical", n_workers=3,
                           worker_spec=fast, worker_specs=[fast, fast, slow])
    views = {w.wid: w.view for w in sim.workers.values()}
    assert views[0].speed == views[1].speed == 1.0
    assert 0.4 < views[2].speed < 0.6
    assert isinstance(sim.policy.predictor, ClusterPredictor)
    assert sim.workers[2].cost.worker.hw.peak_flops == V5E.peak_flops / 2.0
    # homogeneous default: speeds exactly 1.0, plain analytic predictor
    sim2, _ = build_cluster(cfg, "tropical", n_workers=2, worker_spec=fast)
    assert all(w.view.speed == 1.0 for w in sim2.workers.values())
    assert isinstance(sim2.policy.predictor, AnalyticalPredictor)


def test_build_cluster_rejects_mismatched_worker_specs(cfg):
    with pytest.raises(ValueError, match="worker_specs"):
        build_cluster(cfg, "tropical", n_workers=4,
                      worker_specs=[WorkerSpec(tp=8)] * 3)


def test_dispatch_prefers_fast_worker_under_slack_discipline(cfg):
    """Straggler routing: with empty queues everywhere, per-worker pricing
    sends the next prefill to a fast worker — the slow worker's predicted
    TTFT is strictly worse. The global predictor cannot tell them apart
    (ties break by iteration order)."""
    from repro.core.request import Request, SLOSpec

    fast = WorkerSpec(tp=8)
    slow = WorkerSpec(tp=8, hw=V5E.slowed(2.0))
    # worker 0 = slow PREFILL, worker 1 = fast PREFILL (n_prefill=2)
    sim, cost = build_cluster(cfg, "tropical", n_workers=4,
                              worker_spec=fast,
                              worker_specs=[slow, fast, fast, fast],
                              n_prefill=2)
    req = Request(rid=0, arrival_time=0.0, prompt_len=8192, output_len=64,
                  slo=SLOSpec(ttft=10.0, tpot=1.0))
    toggle = sim.policy.toggle
    views = {w.wid: w.view for w in sim.workers.values()}
    # the straggler's predicted TTFT is strictly worse at equal (empty) load
    assert toggle._predict_ttft_on_prefill(views[0], req) > \
        toggle._predict_ttft_on_prefill(views[1], req)
    wid = sim.policy.dispatch_prefill(req, 0.0)
    assert wid != 0, "per-worker pricing must avoid the straggler"


# --------------------------------------------------- per-worker calibration

def test_online_predictor_per_worker_converges_independently(cost):
    """Worker 1 runs 2x slower than the (shared, nominal) base profile;
    worker 0 matches it. Per-worker EWMAs converge to each worker's own
    bias instead of a blend."""
    pred = OnlinePredictor(AnalyticalPredictor(cost), per_worker=True)
    t = cost.prefill_time(2048)
    for _ in range(60):
        pred.observe_prefill(2048, 0, t, wid=0)
        pred.observe_prefill(2048, 0, 2.0 * t, wid=1)
    assert pred.predict_prefill(2048, wid=0) == \
        pytest.approx(t * 1.1, rel=0.1)
    assert pred.predict_prefill(2048, wid=1) == \
        pytest.approx(2.0 * t * 1.1, rel=0.1)
    # the global scale blends the two and fits neither
    assert pred.prefill_scale == pytest.approx(1.5, rel=0.2)
    # an unknown worker falls back to the blended global correction
    assert pred.predict_prefill(2048, wid=99) == \
        pytest.approx(pred.base.predict_prefill(2048) * pred.prefill_scale)


def test_online_predictor_per_worker_fallback_hierarchy(cost):
    """Below the evidence floors a worker borrows coarser scales:
    (wid, phase, bucket) -> (wid, phase) -> the global per-phase scale."""
    pred = OnlinePredictor(AnalyticalPredictor(cost), per_worker=True,
                           bucket_floor=8, worker_floor=8)
    t = cost.prefill_time(2048)
    for _ in range(20):
        pred.observe_prefill(2048, 0, 2.0 * t, wid=1)
    # warm (wid, phase, bucket): the worker's own bucket scale rules
    assert pred.predict_prefill(2048, wid=1) == \
        pytest.approx(2.0 * t * 1.1, rel=0.1)
    # same worker, never-seen size bucket: falls to the (wid, phase) scale
    small = pred.predict_prefill(64, wid=1)
    assert small == pytest.approx(
        pred.base.predict_prefill(64) * pred.worker_scales[(1, "prefill")])
    # cold worker (few observations): global per-phase scale governs
    pred.observe_prefill(2048, 0, 0.5 * t, wid=2)
    assert pred.worker_observations[(2, "prefill")] < pred.worker_floor
    assert pred.predict_prefill(2048, wid=2) == \
        pytest.approx(pred.base.predict_prefill(2048)
                      * pred._bucket_scale("prefill", 2048,
                                           pred.prefill_scale))


def test_online_predictor_per_worker_off_ignores_wid(cost):
    pred = OnlinePredictor(AnalyticalPredictor(cost), per_worker=False)
    for _ in range(20):
        pred.observe_prefill(2048, 0, 2.0 * cost.prefill_time(2048), wid=3)
    assert not pred.worker_scales and not pred.worker_bucket_scales
    assert pred.predict_prefill(2048, wid=3) == pred.predict_prefill(2048)


def test_scheduler_feeds_per_worker_scales_on_hetero_cluster(cfg):
    """End-to-end: a straggler cluster under the cost-model backend
    converges per-worker scales near each worker's true bias."""
    from repro.serving.trace import generate_trace

    fast = WorkerSpec(tp=8)
    slow = WorkerSpec(tp=8, hw=V5E.slowed(2.0))
    nominal = CostModel(cfg, fast)
    pred = OnlinePredictor(AnalyticalPredictor(nominal), per_worker=True)
    sim, _ = build_cluster(cfg, "tropical", n_workers=4, worker_spec=fast,
                           worker_specs=[fast, fast, fast, slow],
                           predictor=pred)
    sim.add_trace(generate_trace(2.0, 60.0, nominal, seed=7))
    m = sim.run(until=4000.0)
    assert m.n_finished == m.n_total
    slow_scales = [v for (wid, _ph), v in pred.worker_scales.items()
                   if wid == 3]
    fast_scales = [v for (wid, _ph), v in pred.worker_scales.items()
                   if wid != 3]
    assert slow_scales and fast_scales
    # the straggler learned its slowdown (mixed-iteration attribution keeps
    # the phases from landing exactly on 2.0; the dominant phase does)
    assert max(slow_scales) > 1.4
    assert max(fast_scales) < 1.25         # fast workers stay ~unbiased
    assert max(slow_scales) > max(fast_scales) + 0.3


# ------------------------------------------------- measured-MFU calibration

def test_calibrate_hardware_measures_sane_fractions():
    hw, cal = calibrate_hardware(V5E, seq=128, heads=2, head_dim=64,
                                 batch=2, page_size=16, pages_per_seq=2,
                                 repeats=1)
    for frac in (hw.mfu_prefill, hw.mfu_decode, hw.bw_eff):
        assert 0.0 < frac <= 1.0
    assert cal.prefill_seconds > 0.0 and cal.decode_seconds > 0.0
    assert hw.name.endswith("-measured")
    # capacity/links come from the spec, not the measurement
    assert hw.hbm_bytes == V5E.hbm_bytes and hw.ici_bw == V5E.ici_bw


def test_calibrated_roofline_backend_prices_iterations():
    cfg = get_smoke("deepseek-7b")
    backend = CalibratedRooflineBackend(
        cfg, WorkerSpec(tp=1), seq=128, heads=2, head_dim=64, batch=2,
        page_size=16, pages_per_seq=2, repeats=1)
    from repro.serving.engine import IterationPlan, Worker

    w = Worker(0, CostModel(cfg, WorkerSpec(tp=1)))
    plan = IterationPlan(decode_reqs=[], prefill_parts=[], n_decode=4,
                         sum_ctx=4 * 64.0, prefill_tokens=32,
                         prefill_ctx_offset=0.0, exclusive_prefill=False)
    dur = backend.run_iteration(w, plan)
    assert dur > 0.0
    cal = backend.calibration
    assert 0.0 < cal.mfu_prefill <= 1.0


# ------------------------------------------------------ trace-replay backend

def test_trace_replay_backend_matches_materialised_trace(cfg):
    """Streaming arrivals through TraceReplayBackend must reproduce the
    materialised add_trace run decision-for-decision."""
    from repro.sched import TraceReplayBackend
    from repro.serving.trace import generate_trace

    spec = WorkerSpec(tp=8)
    cost = CostModel(cfg, spec)
    trace = generate_trace(2.0, 40.0, cost, seed=9)

    sim_a, _ = build_cluster(cfg, "tropical", n_workers=2, worker_spec=spec,
                             record_decisions=True)
    sim_a.add_trace(copy.deepcopy(trace))
    m_a = sim_a.run(until=4000.0)

    sim_b, _ = build_cluster(cfg, "tropical", n_workers=2, worker_spec=spec,
                             record_decisions=True)
    replay = TraceReplayBackend(
        (r.arrival_time, r) for r in copy.deepcopy(trace))
    sim_b.add_replay(replay)
    m_b = sim_b.run(until=4000.0)

    assert replay.replayed == len(trace)
    assert m_a.n_finished == m_b.n_finished == len(trace)
    assert sim_a.decisions == sim_b.decisions
    assert m_a.slo_attainment == m_b.slo_attainment
    assert m_a.ttft_p90 == m_b.ttft_p90


def test_trace_replay_rejects_unsorted_feed(cfg):
    """Streaming keeps one pending arrival: an out-of-order item would
    move the driver clock backwards. The backend refuses loudly."""
    from repro.core.request import Request, SLOSpec
    from repro.sched import TraceReplayBackend

    slo = SLOSpec(ttft=10.0, tpot=1.0)
    reqs = [Request(rid=i, arrival_time=t, prompt_len=8, output_len=2,
                    slo=slo) for i, t in enumerate((1.0, 3.0, 2.0))]
    sim, _ = build_cluster(cfg, "tropical", n_workers=2,
                           worker_spec=WorkerSpec(tp=8))
    sim.add_replay(TraceReplayBackend((r.arrival_time, r) for r in reqs))
    with pytest.raises(ValueError, match="not sorted"):
        sim.run(until=100.0)


def test_add_replay_adopts_configured_clock(cfg):
    """A bare TraceReplayBackend(feed) must not silently swap a custom
    duration_fn for the default analytic clock — both call forms adopt
    the simulator's configured backend as the inner clock."""
    from repro.sched import CallableBackend, TraceReplayBackend
    from repro.serving.trace import generate_trace

    spec = WorkerSpec(tp=8)
    cost = CostModel(cfg, spec)
    trace = generate_trace(1.0, 10.0, cost, seed=2)
    calls = []

    def spy(worker, plan):
        calls.append(worker.wid)
        return worker.plan_duration(plan)

    sim, _ = build_cluster(cfg, "tropical", n_workers=2, worker_spec=spec,
                           backend=CallableBackend(spy))
    replay = TraceReplayBackend((r.arrival_time, r) for r in trace)
    sim.add_replay(replay)
    assert replay.inner is not None and isinstance(
        replay.inner, CallableBackend)
    m = sim.run(until=1000.0)
    assert m.n_finished == len(trace)
    assert calls, "the custom clock must keep supplying durations"


def test_serve_cli_trace_replay_backend_equivalent(capsys):
    import json

    from repro.launch import serve

    base = ["--mode", "sim", "--rate", "1.0", "--duration", "15",
            "--seed", "3", "--json"]
    row_a = serve.main(base)
    capsys.readouterr()
    row_b = serve.main(base + ["--backend", "trace-replay"])
    out = capsys.readouterr().out
    data = json.loads(out)
    assert data["backend"] == "trace-replay"
    assert row_b["n_total"] == row_a["n_total"] > 0
    for key in ("slo_attainment", "ttft_p90", "tpot_p90", "n_finished"):
        assert row_b[key] == row_a[key], key


def test_serve_cli_trace_replay_rejects_real_mode():
    from repro.launch import serve

    with pytest.raises(SystemExit):
        serve.main(["--mode", "real", "--backend", "trace-replay",
                    "--rate", "1.0", "--duration", "5"])


# ------------------------------------------------------- rebalancer decay

def test_rebalancer_window_ttl_expires_silent_class():
    from repro.core.request import Request, SLOSpec
    from repro.sched import RebalanceConfig, RoleRebalancer
    from repro.core.toggle import Role, WorkerView

    cfg = RebalanceConfig(min_samples=8, window_ttl=30.0, cooldown=0.0)
    rb = RoleRebalancer(cfg)
    views = {i: WorkerView(wid=i, role=r, kv_capacity_tokens=1e5)
             for i, r in enumerate(
                 [Role.PREFILL, Role.MULTIPLEX, Role.MULTIPLEX])}
    tight = SLOSpec(ttft=1.0, tpot=0.1, name="interactive")

    def _outcome(t, ok):
        r = Request(rid=0, arrival_time=0.0, prompt_len=8, output_len=4,
                    slo=tight)
        r.first_token_time = t if ok else t + 10.0 * tight.ttft
        r.arrival_time = t - (0.5 if ok else 2.0) * tight.ttft
        return r

    # the tenant breaches TTFT, then goes silent
    for i in range(12):
        rb.record_first_token(_outcome(10.0 + 0.1 * i, ok=False))
    for _ in range(12):
        rb.tpot_window.append(True)
    # inside the TTL the stale window still drives a role move
    assert rb._worst_attainment(rb.ttft_windows) == 0.0
    assert rb.step(views, now=20.0) is not None
    # well past the TTL the silent tenant's evidence expires: no review
    # keeps chasing a tenant that no longer sends traffic
    views2 = {i: WorkerView(wid=i, role=r, kv_capacity_tokens=1e5)
              for i, r in enumerate(
                  [Role.PREFILL, Role.MULTIPLEX, Role.MULTIPLEX])}
    assert rb.step(views2, now=100.0) is None
    assert len(rb.ttft_windows["interactive"]) == 0


def test_rebalancer_default_never_expires():
    from repro.sched import RebalanceConfig, RoleRebalancer

    rb = RoleRebalancer(RebalanceConfig(min_samples=8))
    assert rb.cfg.window_ttl is None
    rb.ttft_window.extend([False] * 12)
    rb.tpot_window.extend([True] * 12)
    rb._expire_stale_windows(now=1e9)
    assert len(rb.ttft_window) == 12       # legacy windows never decay


# --------------------------------------------------------- bench summary

def test_bench_summary_schema():
    from benchmarks.run import REF_RATE, SUMMARY_SCHEMA_VERSION, build_summary

    results = {
        "fig8": [{"policy": "tropical", "rate": REF_RATE,
                  "slo_attainment": 0.97}],
        "fig_multitenant": [{"policy": "tropical", "rate": REF_RATE,
                             "weighted_attainment": 0.95}],
        "fig_hetero": [{"config": "summary", "mean_hetero_global": 0.69,
                        "mean_hetero_pw": 0.76}],
        "fig_interference": [{"config": "summary", "mean_gamma_blind": 0.98,
                              "mean_gamma_aware": 0.99,
                              "mean_gamma_drift": 0.98,
                              "mean_gamma_abs_err": 0.01}],
        "fig_tiered": [{"config": "summary", "evict_ttft_attainment": 0.957,
                        "tiered_prefix_ttft_attainment": 0.996,
                        "prefix_hit_rate": 0.958}],
        "scale": [{"tier": "throughput", "mode": "vectorized",
                   "workers": 256, "sim_throughput_rps": 410.0,
                   "speedup_x": 4.1},
                  {"tier": "throughput", "mode": "vectorized",
                   "workers": 1024, "sim_throughput_rps": 1000.0,
                   "speedup_x": 13.8},
                  {"tier": "throughput", "mode": "scalar",
                   "workers": 1024, "sim_throughput_rps": 72.0},
                  {"tier": "engine", "mode": "vectorized",
                   "workers": 1024, "sim_throughput_rps": 155.0,
                   "speedup_x": 3.2},
                  {"tier": "engine", "mode": "scalar",
                   "workers": 1024, "sim_throughput_rps": 49.0},
                  {"tier": "real_exec", "mode": "seed",
                   "iters": 17, "step_ms": 375.8},
                  {"tier": "real_exec", "mode": "fast",
                   "iters": 17, "step_ms": 2.5, "speedup_x": 150.3}],
    }
    s = build_summary(results)
    assert s["schema_version"] == SUMMARY_SCHEMA_VERSION == 5
    assert s["slo_attainment"] == 0.97
    assert s["weighted_attainment"] == 0.95
    assert s["hetero_per_worker_attainment"] == 0.76
    assert s["interference_aware_attainment"] == 0.99
    assert s["interference_blind_attainment"] == 0.98
    assert s["interference_gamma_abs_err"] == 0.01
    assert s["tiered_evict_ttft_attainment"] == 0.957
    assert s["tiered_prefix_ttft_attainment"] == 0.996
    assert s["tiered_prefix_hit_rate"] == 0.958
    # throughput tier: largest-scale vectorized row wins
    assert s["sim_throughput_rps"] == 1000.0
    assert s["sim_throughput_workers"] == 1024
    assert s["sim_throughput_speedup"] == 13.8
    # engine tier: same rule, its own keys
    assert s["sim_engine_rps"] == 155.0
    assert s["sim_engine_workers"] == 1024
    assert s["sim_engine_speedup"] == 3.2
    # real-compute executor tier: the fast row's wall clock + speedup
    assert s["real_step_ms"] == 2.5
    assert s["real_exec_speedup"] == 150.3
    assert s["ttft_p90_s"] > 0 and s["tpot_p90_s"] > 0
    assert s["mean_step_s"] > 0 and s["n_requests"] > 0
