"""Training substrate: loss decreases, checkpoint save/restore/resume is
bit-exact, elastic resharding works."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import api as model_api
from repro.train import checkpoint, optimizer
from repro.train.data import DataConfig, SyntheticLM


def _setup(arch="qwen2-1.5b", batch=4, seq=32):
    cfg = get_smoke(arch)
    api = model_api.build(cfg)
    data = SyntheticLM(cfg, DataConfig(batch=batch, seq=seq))
    step = jax.jit(optimizer.make_train_step(
        lambda p, b: api.loss(p, b),
        optimizer.AdamWConfig(lr=3e-3, warmup_steps=5)))
    return cfg, api, data, step


def test_loss_decreases():
    cfg, api, data, step = _setup()
    params = api.init(jax.random.PRNGKey(0))
    state = optimizer.init_state(params)
    first = None
    for i in range(30):
        params, state, loss = step(params, state, data.batch_at(i))
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.9, (first, float(loss))


def test_grad_clipping_keeps_norm_bounded():
    cfg, api, data, step = _setup()
    params = api.init(jax.random.PRNGKey(0))
    loss, grads = jax.value_and_grad(lambda p: api.loss(p, data.batch_at(0))
                                     )(params)
    gnorm = optimizer.global_norm(grads)
    assert jnp.isfinite(gnorm)


def test_checkpoint_roundtrip_bitexact(tmp_path):
    cfg, api, data, step = _setup()
    params = api.init(jax.random.PRNGKey(0))
    state = optimizer.init_state(params)
    for i in range(3):
        params, state, _ = step(params, state, data.batch_at(i))
    checkpoint.save(tmp_path, 3, {"params": params, "state": state})

    tree = checkpoint.restore(tmp_path, 3, {"params": params, "state": state})
    for a, b in zip(jax.tree.leaves(tree["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # resume path: continuing from restore == continuing without it
    p1, s1, l1 = step(params, state, data.batch_at(3))
    p2, s2, l2 = step(tree["params"], tree["state"], data.batch_at(3))
    assert float(l1) == pytest.approx(float(l2), rel=1e-6)


def test_checkpoint_restart_resumes_same_trajectory(tmp_path):
    """Kill-and-restart determinism: steps 0..5 with a crash+resume at 3
    produce the same weights as an uninterrupted run."""
    cfg, api, data, step = _setup()

    def fresh():
        p = api.init(jax.random.PRNGKey(0))
        return p, optimizer.init_state(p)

    # uninterrupted
    p, s = fresh()
    for i in range(6):
        p, s, _ = step(p, s, data.batch_at(i))

    # interrupted at 3
    p2, s2 = fresh()
    for i in range(3):
        p2, s2, _ = step(p2, s2, data.batch_at(i))
    checkpoint.save(tmp_path, 3, {"params": p2, "state": s2})
    tree = checkpoint.restore(tmp_path, checkpoint.latest_step(tmp_path),
                              {"params": p2, "state": s2})
    p2, s2 = tree["params"], tree["state"]
    for i in range(3, 6):
        p2, s2, _ = step(p2, s2, data.batch_at(i))

    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_checkpoint_retention(tmp_path):
    cfg, api, data, step = _setup()
    params = api.init(jax.random.PRNGKey(0))
    state = optimizer.init_state(params)
    for s in (1, 2, 3, 4, 5):
        checkpoint.save(tmp_path, s, {"params": params, "state": state},
                        keep=2)
    assert checkpoint.latest_step(tmp_path) == 5
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir())
    assert steps == [4, 5]


def test_zero_state_specs_divisible_only():
    from jax.sharding import PartitionSpec as P
    specs = {"w": P(None, "model"), "s": P(None)}
    shapes = {"w": jax.ShapeDtypeStruct((18, 64), jnp.float32),
              "s": jax.ShapeDtypeStruct((7,), jnp.float32)}
    out = optimizer.state_specs(specs, shapes, zero_size=16)
    assert out["m"]["w"] == P(None, "model")     # 18 % 16 != 0: unchanged
    assert out["m"]["s"] == P(None)              # 7 % 16 != 0: unchanged
    shapes2 = {"w": jax.ShapeDtypeStruct((32, 64), jnp.float32),
               "s": jax.ShapeDtypeStruct((16,), jnp.float32)}
    out2 = optimizer.state_specs(specs, shapes2, zero_size=16)
    assert out2["m"]["w"] == P("data", "model")  # ZeRO widened
    assert out2["m"]["s"] == P("data")
