"""Paged KV store + allocator: indirection correctness feeding the Pallas
paged_attention kernel, watermark accounting used by the toggle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.paged_attention import paged_attention
from repro.serving.kvcache import BlockAllocator, PagedKVStore


def test_allocator_watermark_and_release():
    a = BlockAllocator(n_blocks=10, block_size=16)
    assert a.can_fit(160) and not a.can_fit(161)
    a.allocate(rid=1, tokens=100)          # 7 blocks
    assert a.used_blocks == 7
    assert a.utilization == pytest.approx(0.7)
    assert a.allocate(rid=2, tokens=100) is None   # only 3 left
    a.extend(1, 112)                        # same block count
    assert a.used_blocks == 7
    a.release(1)
    assert a.used_blocks == 0
    assert a.allocate(rid=2, tokens=160) is not None


def test_allocator_table_padding():
    a = BlockAllocator(8, 16)
    a.allocate(3, 40)
    t = a.table(3, max_pages=6)
    assert (t[:3] >= 0).all() and (t[3:] == -1).all()


def test_paged_store_roundtrip_and_kernel():
    """Write tokens through the paged store, run the Pallas kernel over the
    resulting block tables, compare with dense-attention oracle."""
    L, n_pages, ps, hkv, d = 2, 12, 16, 2, 64
    store = PagedKVStore.create(L, n_pages, ps, hkv, d, dtype=jnp.float32)
    rng = np.random.default_rng(0)
    lengths = [37, 21]
    ks, vs = {}, {}
    for rid, ln in enumerate(lengths):
        k = jnp.asarray(rng.normal(size=(L, ln, hkv, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(L, ln, hkv, d)), jnp.float32)
        store.write_tokens(rid, 0, k, v)
        ks[rid], vs[rid] = k, v
        # dense gather matches what was written
        gk, gv = store.gather_dense(rid, ln)
        np.testing.assert_array_equal(np.asarray(gk), np.asarray(k))

    # run the kernel for layer 0 over both requests
    max_pages = 4
    bt = np.stack([store.allocator.table(r, max_pages) for r in (0, 1)])
    q = jnp.asarray(rng.normal(size=(2, 4, d)), jnp.float32)  # Hq=4, G=2
    out = paged_attention(q, store.k_pages[0], store.v_pages[0],
                          jnp.asarray(bt), jnp.asarray(lengths, jnp.int32),
                          interpret=True)
    want = ref.paged_attention_ref(q, store.k_pages[0], store.v_pages[0],
                                   jnp.asarray(np.maximum(bt, 0)),
                                   jnp.asarray(lengths, jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


def test_pool_exhaustion_raises():
    store = PagedKVStore.create(1, n_pages=2, page_size=8, num_kv_heads=1,
                                head_dim=8, dtype=jnp.float32)
    k = jnp.zeros((1, 17, 1, 8), jnp.float32)
    with pytest.raises(MemoryError):
        store.write_tokens(0, 0, k, k)
