"""Paged KV store + allocator: indirection correctness feeding the Pallas
paged_attention kernel, watermark accounting used by the toggle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.paged_attention import paged_attention
from repro.serving.kvcache import BlockAllocator, PageAccountant, PagedKVStore


def test_allocator_watermark_and_release():
    a = BlockAllocator(n_blocks=10, block_size=16)
    assert a.can_fit(160) and not a.can_fit(161)
    a.allocate(rid=1, tokens=100)          # 7 blocks
    assert a.used_blocks == 7
    assert a.utilization == pytest.approx(0.7)
    assert a.allocate(rid=2, tokens=100) is None   # only 3 left
    a.extend(1, 112)                        # same block count
    assert a.used_blocks == 7
    a.release(1)
    assert a.used_blocks == 0
    assert a.allocate(rid=2, tokens=160) is not None


def test_allocator_table_padding():
    a = BlockAllocator(8, 16)
    a.allocate(3, 40)
    t = a.table(3, max_pages=6)
    assert (t[:3] >= 0).all() and (t[3:] == -1).all()


def test_paged_store_roundtrip_and_kernel():
    """Write tokens through the paged store, run the Pallas kernel over the
    resulting block tables, compare with dense-attention oracle."""
    L, n_pages, ps, hkv, d = 2, 12, 16, 2, 64
    store = PagedKVStore.create(L, n_pages, ps, hkv, d, dtype=jnp.float32)
    rng = np.random.default_rng(0)
    lengths = [37, 21]
    ks, vs = {}, {}
    for rid, ln in enumerate(lengths):
        k = jnp.asarray(rng.normal(size=(L, ln, hkv, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(L, ln, hkv, d)), jnp.float32)
        store.write_tokens(rid, 0, k, v)
        ks[rid], vs[rid] = k, v
        # dense gather matches what was written
        gk, gv = store.gather_dense(rid, ln)
        np.testing.assert_array_equal(np.asarray(gk), np.asarray(k))

    # run the kernel for layer 0 over both requests
    max_pages = 4
    bt = np.stack([store.allocator.table(r, max_pages) for r in (0, 1)])
    q = jnp.asarray(rng.normal(size=(2, 4, d)), jnp.float32)  # Hq=4, G=2
    out = paged_attention(q, store.k_pages[0], store.v_pages[0],
                          jnp.asarray(bt), jnp.asarray(lengths, jnp.int32),
                          interpret=True)
    want = ref.paged_attention_ref(q, store.k_pages[0], store.v_pages[0],
                                   jnp.asarray(np.maximum(bt, 0)),
                                   jnp.asarray(lengths, jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


def test_pool_exhaustion_raises():
    store = PagedKVStore.create(1, n_pages=2, page_size=8, num_kv_heads=1,
                                head_dim=8, dtype=jnp.float32)
    k = jnp.zeros((1, 17, 1, 8), jnp.float32)
    with pytest.raises(MemoryError):
        store.write_tokens(0, 0, k, k)


# ----------------------------------------------- scheduler page accounting

def test_page_accountant_never_overallocates():
    a = PageAccountant(total_pages=10, page_size=16)
    assert a.reserve(1, 100)            # 7 pages
    assert a.used_pages == 7 and a.free_pages == 3
    assert not a.reserve(2, 100)        # needs 7, only 3 left
    assert a.used_pages == 7            # failed reserve left no residue
    assert a.reserve(2, 48)             # exactly the last 3 pages
    assert a.free_pages == 0
    assert not a.reserve(3, 1)


def test_page_accountant_growth_is_incremental():
    a = PageAccountant(total_pages=10, page_size=16)
    a.reserve(1, 10)
    assert a.used_pages == 1
    a.reserve(1, 16)                    # same page covers it
    assert a.used_pages == 1
    a.reserve(1, 17)
    assert a.used_pages == 2
    a.reserve(1, 5)                     # shrinking request: no-op
    assert a.used_pages == 2


def test_page_accountant_release_restores_free_pages():
    a = PageAccountant(total_pages=10, page_size=16)
    a.reserve(1, 100)                   # 7 pages
    a.reserve(2, 20)                    # 2 pages
    assert a.free_pages == 10 - 7 - 2
    assert a.release(1) == 7
    assert a.free_pages == 8
    a.release(2)
    assert a.free_pages == 10 and a.used_pages == 0
    assert a.fragmentation == 0.0


def test_page_accountant_fragmentation():
    a = PageAccountant(total_pages=8, page_size=16)
    a.reserve(1, 17)                    # 2 pages, 15 tail tokens unwritten
    assert a.fragmentation == pytest.approx(15 / 32)
    a.reserve(1, 32)                    # tail fills in
    assert a.fragmentation == 0.0


def test_page_accountant_can_fit_counts_held_pages():
    a = PageAccountant(total_pages=4, page_size=16)
    a.reserve(1, 48)                    # 3 pages
    assert a.can_fit(64, rid=1)         # growth of 1 page fits
    assert not a.can_fit(64)            # a fresh request would need 4
    assert a.can_fit(16)
