"""Hypothesis, with a deterministic fallback when it isn't installed.

CI installs hypothesis via requirements-dev.txt and runs the real
property-based engine (shrinking, example database, coverage-guided
generation). A bare container without it still exercises every property
test: the fallback replays each ``@given`` body over ``max_examples``
seeded pseudo-random draws — no shrinking, but the invariants themselves
are checked rather than silently skipped.

Only the strategy surface this repo uses is implemented: ``integers``,
``floats``, ``lists``, ``sampled_from``, ``data``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st   # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import random

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def draw(self, rng: random.Random):
            return self._draw_fn(rng)

    class _DataObject:
        """Interactive draws: ``data.draw(strategy)``."""

        def __init__(self, rng: random.Random):
            self._rng = rng

        def draw(self, strategy: _Strategy):
            return strategy.draw(self._rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: elements[
                rng.randrange(len(elements))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def data():
            return _Strategy(_DataObject)

    st = _Strategies()

    def settings(max_examples: int = 10, **_ignored):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            def runner(*args, **kwargs):
                n = (getattr(runner, "_compat_max_examples", None)
                     or getattr(fn, "_compat_max_examples", None) or 10)
                for i in range(n):
                    rng = random.Random(0x5EED + 7919 * i)
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)
            runner.__name__ = fn.__name__
            runner.__qualname__ = fn.__qualname__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner
        return deco
