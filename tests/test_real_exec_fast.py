"""Batched real-executor fast path: token-stream bit-parity vs the scalar
reference, compile-count regression over the bucket grid, and the
SlotExhausted refusal contract (executor raise -> scheduler requeue)."""
import copy

import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.request import Request, SLOSpec
from repro.sched.backend import SlotExhausted
from repro.serving.costmodel import WorkerSpec
from repro.serving.engine import IterationPlan
from repro.serving.executor import ClusterRealExecutors
from repro.serving.simulator import build_cluster

SLO = SLOSpec(ttft=30.0, tpot=5.0)


def _req(rid, prompt_len, output_len=8):
    return Request(rid=rid, arrival_time=0.0, prompt_len=prompt_len,
                   output_len=output_len, slo=SLO)


def _plan(prefill=(), decode=()):
    pp = [(r, int(t)) for r, t in prefill]
    dr = list(decode)
    return IterationPlan(
        decode_reqs=dr, prefill_parts=pp, n_decode=len(dr),
        sum_ctx=float(sum(r.prompt_len for r in dr)),
        prefill_tokens=int(sum(t for _, t in pp)),
        prefill_ctx_offset=0.0, exclusive_prefill=not dr)


def _drive(cfg_name, batched):
    """One fixed mixed workload on a 2-worker cluster: multi-chunk prefill
    (including a left-padded partial chunk and a same-bucket 2-row batch),
    mixed prefill+decode iterations, a mid-decode migration, and further
    decode on both workers. Returns the final per-request token streams."""
    cfg = get_smoke(cfg_name)
    execs = ClusterRealExecutors(cfg, 2, max_slots=4, max_len=64,
                                 batched=batched, warmup=False)
    e0, e1 = execs.execs[0], execs.execs[1]
    a, b, c, d = _req(0, 24), _req(1, 40), _req(2, 16), _req(3, 33)

    def step(ex, plan):
        ex.run_plan(plan)
        for r, t in plan.prefill_parts:       # the engine's bookkeeping
            r.prefilled_tokens += t

    step(e0, _plan(prefill=[(a, 16)]))
    step(e0, _plan(prefill=[(a, 8), (b, 24)]))       # same bucket, 2 rows
    step(e0, _plan(prefill=[(b, 16)], decode=[a]))   # left-pad + mixed iter
    step(e1, _plan(prefill=[(d, 33)]))               # bucket == max_len
    step(e0, _plan(decode=[a, b]))
    execs.migrate(b, 0, 1)                           # mid-decode migration
    step(e0, _plan(prefill=[(c, 16)], decode=[a]))
    step(e1, _plan(decode=[b, d]))
    step(e1, _plan(decode=[d, b]))
    step(e0, _plan(decode=[c, a]))
    streams = {0: list(e0.generated[0]), 1: list(e1.generated[1]),
               2: list(e0.generated[2]), 3: list(e1.generated[3])}
    return execs, streams


# ---------------------------------------------------------------- bit parity

def test_fast_path_token_parity_transformer():
    """batched=True must produce bit-identical token streams to the scalar
    per-request reference on a KV-cache transformer, across chunked
    prefill, fused mixed iterations and a device-to-device migration."""
    fast, s_fast = _drive("qwen2-1.5b", batched=True)
    ref, s_ref = _drive("qwen2-1.5b", batched=False)
    assert fast.execs[0].fast and fast.execs[1].fast
    assert not ref.execs[0].fast
    assert fast.kernels is not None and ref.kernels is None
    assert s_fast == s_ref
    for rid, toks in s_fast.items():
        assert len(toks) >= 2, f"rid {rid} produced too few tokens"


def test_fast_path_token_parity_stateful_fallback():
    """Stateful families (rwkv6: no positional chunk entry point) must fall
    back to the scalar reference even under batched=True — and still match
    it bit-for-bit through the same mixed workload."""
    fast, s_fast = _drive("rwkv6-7b", batched=True)
    ref, s_ref = _drive("rwkv6-7b", batched=False)
    assert not fast.execs[0].fast          # fallback engaged
    assert fast.kernels is None            # no bucketed kernels built
    assert s_fast == s_ref


# ------------------------------------------------------------- compile count

def test_compile_count_bounded_by_bucket_grid():
    """Warmup pre-traces every (bucket, rows=1) prefill entry; afterwards,
    >= 6 distinct chunk lengths must hit the jit cache (misses bounded by
    the bucket count), and decode must stay on its single trace."""
    cfg = get_smoke("qwen2-1.5b")
    execs = ClusterRealExecutors(cfg, 1, max_slots=8, max_len=128,
                                 batched=True, warmup=True)
    k = execs.kernels
    assert k is not None
    assert k.prefill_traces == len(k.buckets)
    assert k.decode_traces == 1
    e = execs.execs[0]
    takes = [3, 5, 9, 17, 33, 65]          # 6 distinct lengths, 3 buckets
    for i, t in enumerate(takes):
        r = _req(rid=100 + i, prompt_len=t)
        e.run_plan(_plan(prefill=[(r, t)]))
        r.prefilled_tokens = t
        execs.on_finish(r)                  # free the slot for the next
    assert k.prefill_traces == len(k.buckets), \
        "distinct chunk lengths must not add jit traces beyond the buckets"
    e.run_plan(_plan(decode=[]))            # empty plan: no tracing at all
    assert k.decode_traces == 1


# ------------------------------------------------------------- slot accounting

def test_slot_exhausted_is_typed_and_side_effect_free():
    cfg = get_smoke("qwen2-1.5b")
    execs = ClusterRealExecutors(cfg, 1, max_slots=2, max_len=64,
                                 warmup=False)
    e = execs.execs[0]
    for rid in (0, 1):
        e._slot(rid)
    with pytest.raises(SlotExhausted) as ei:
        e._slot(2)
    assert ei.value.wid == 0
    assert ei.value.rid == 2
    assert ei.value.max_slots == 2
    assert set(e.slot_of) == {0, 1}         # existing tenants untouched
    assert execs._owner == {0: 0, 1: 0}     # refused rid never registered


def test_run_plan_reserves_slots_before_any_compute():
    """A plan needing more slots than remain must refuse before running any
    prefill part — otherwise a re-run would double-append sampled tokens."""
    cfg = get_smoke("qwen2-1.5b")
    execs = ClusterRealExecutors(cfg, 1, max_slots=2, max_len=64,
                                 warmup=False)
    e = execs.execs[0]
    reqs = [_req(i, 16) for i in range(3)]
    with pytest.raises(SlotExhausted):
        e.run_plan(_plan(prefill=[(r, 16) for r in reqs]))
    assert all(not e.generated.get(r.rid) for r in reqs), \
        "no tokens may be sampled when the plan is refused"


def test_migrate_to_full_worker_raises_and_preserves_source():
    cfg = get_smoke("qwen2-1.5b")
    execs = ClusterRealExecutors(cfg, 2, max_slots=1, max_len=64,
                                 warmup=False)
    e0, e1 = execs.execs[0], execs.execs[1]
    a = _req(0, 16)
    e0.run_plan(_plan(prefill=[(a, 16)]))
    a.prefilled_tokens = 16
    e1._slot(99)                            # destination is full
    with pytest.raises(SlotExhausted):
        execs.migrate(a, 0, 1)
    assert a.rid in e0.slot_of              # source slot intact
    assert execs._owner[a.rid] == 0


def test_on_finish_releases_only_on_owning_executor():
    """Regression: on_finish used to call release() on EVERY executor.
    Only the owner may release — other executors' free lists must not be
    touched (a foreign release would corrupt their slot accounting)."""
    cfg = get_smoke("qwen2-1.5b")
    execs = ClusterRealExecutors(cfg, 3, max_slots=2, max_len=64,
                                 warmup=False)
    e0, e1, e2 = (execs.execs[i] for i in range(3))
    a = _req(0, 16)
    e0.run_plan(_plan(prefill=[(a, 16)]))
    e1._slot(7)                             # unrelated tenant elsewhere
    free1 = list(e1.free_slots)
    free2 = list(e2.free_slots)
    calls = []
    orig1, orig2 = e1.release, e2.release
    e1.release = lambda rid: (calls.append((1, rid)), orig1(rid))
    e2.release = lambda rid: (calls.append((2, rid)), orig2(rid))
    execs.on_finish(a)
    assert calls == [], "release must only run on the owning executor"
    assert a.rid not in e0.slot_of and len(e0.free_slots) == 2
    assert list(e1.free_slots) == free1
    assert list(e2.free_slots) == free2
    execs.on_finish(a)                      # idempotent for unknown rids


def test_scheduler_turns_slot_exhaustion_into_refusal():
    """End to end: a slot-starved real backend under the model clock must
    surface SlotExhausted as dispatch refusals (requests requeue and retry)
    rather than crashing — and every request still finishes."""
    cfg = get_smoke("deepseek-7b")
    trace = [_req(i, 24, output_len=5) for i in range(10)]
    execs = ClusterRealExecutors(cfg, 2, max_slots=2, max_len=64)
    sim, _ = build_cluster(cfg, "tropical", n_workers=2,
                           worker_spec=WorkerSpec(tp=1),
                           record_decisions=True,
                           backend=execs.as_backend(clock="model"))
    sim.add_trace(copy.deepcopy(trace))
    m = sim.run(until=10000.0)
    assert m.n_finished == len(trace)
    refusals = [d for d in sim.decisions if d[0] == "refuse"]
    assert refusals, "slot starvation must show up as dispatch refusals"
    for _, wid, rid in refusals:
        assert wid in (0, 1) and 0 <= rid < len(trace)
