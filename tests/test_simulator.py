"""Discrete-event simulator integration tests: conservation, policy
behaviours, fault tolerance, elastic scaling, KV accounting."""
import copy

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.metrics import derive_slos
from repro.core.request import Phase, Request
from repro.serving.costmodel import CostModel, WorkerSpec
from repro.serving.simulator import build_cluster
from repro.serving.trace import MOONCAKE, generate_trace, sample_lengths


CFG = get_config("internlm-20b")
SPEC = WorkerSpec(tp=8)


def _trace(rate=1.0, duration=60.0, seed=0):
    cost = CostModel(CFG, SPEC)
    return generate_trace(rate, duration, cost, seed=seed)


@pytest.mark.parametrize("policy", ["vllm", "sarathi", "distserve",
                                    "tropical", "tropical++"])
def test_all_requests_finish(policy):
    sim, _ = build_cluster(CFG, policy, n_workers=4, worker_spec=SPEC)
    sim.add_trace(_trace())
    m = sim.run(until=4000.0)
    assert m.n_finished == m.n_total, (policy, m.n_finished, m.n_total)
    # every finished request generated exactly its output_len
    for r in sim.requests:
        assert r.phase == Phase.FINISHED
        assert r.streamed_tokens == r.output_len
        assert r.prefilled_tokens == r.prompt_len


def test_kv_accounting_returns_to_zero():
    sim, _ = build_cluster(CFG, "tropical", n_workers=4, worker_spec=SPEC)
    sim.add_trace(_trace(rate=0.5))
    sim.run(until=4000.0)
    for w in sim.workers.values():
        assert w.view.kv_used_tokens == pytest.approx(0.0, abs=1.0), w.wid
        assert not w.decode_running and not w.prefill_queue


def test_distserve_never_decodes_on_prefill_worker():
    sim, _ = build_cluster(CFG, "distserve", n_workers=4, worker_spec=SPEC)
    sim.add_trace(_trace())
    sim.run(until=4000.0)
    from repro.core.toggle import Role
    for w in sim.workers.values():
        if w.view.role == Role.PREFILL:
            assert w.blocked_time == {} or all(
                v == 0 for v in w.blocked_time.values())
    # migrations happened for every request (P -> D handoff)
    assert sum(r.migrations for r in sim.requests) >= len(sim.requests) * 0.9


def test_vllm_decode_blocked_by_prefill():
    """The interference mechanism: colocated exclusive prefill stalls
    decodes (Fig 1b)."""
    sim, _ = build_cluster(CFG, "vllm", n_workers=2, worker_spec=SPEC)
    sim.add_trace(_trace(rate=2.0, duration=60.0))
    sim.run(until=4000.0)
    blocked = {}
    for w in sim.workers.values():
        blocked.update(w.blocked_time)
    assert blocked and max(blocked.values()) > 0.0


def test_worker_failure_requests_recover():
    sim, _ = build_cluster(CFG, "tropical", n_workers=4, worker_spec=SPEC)
    trace = _trace(rate=1.0, duration=60.0)
    sim.add_trace(trace)
    sim.inject_failure(20.0, wid=3, recover_after=30.0)
    m = sim.run(until=6000.0)
    assert m.n_finished == m.n_total
    assert m.restarts > 0          # someone was on worker 3
    for r in sim.requests:
        assert r.streamed_tokens == r.output_len


def test_elastic_add_worker_improves_queueing():
    results = {}
    for scale in (False, True):
        sim, cost = build_cluster(CFG, "tropical", n_workers=2,
                                  worker_spec=SPEC)
        sim.add_trace(copy.deepcopy(_trace(rate=2.0, duration=80.0)))
        if scale:
            from repro.serving.engine import Worker
            sim.add_worker_at(10.0, Worker(10, cost))
            sim.add_worker_at(10.0, Worker(11, cost))
        m = sim.run(until=6000.0)
        results[scale] = m
        assert m.n_finished == m.n_total
    assert results[True].queue_p90 <= results[False].queue_p90


def test_page_pressure_preempts_and_recovers():
    """Shrunken page pools force watermark evictions; every evicted decode
    re-prefills and still finishes, and the pools drain back to empty."""
    from repro.serving.kvcache import PageAccountant
    sim, cost = build_cluster(CFG, "tropical", n_workers=2, worker_spec=SPEC)
    trace = _trace(rate=2.0, duration=60.0, seed=2)
    for r in trace:
        r.prompt_len = min(max(r.prompt_len, 1024), 2048)
        r.output_len = min(max(r.output_len, 128), 512)
    for w in sim.workers.values():
        w.pages = PageAccountant(total_pages=500, page_size=16)  # 8k tokens
        w.kv_preempt_watermark = 0.9
        w._refresh_view()
    sim.add_trace(trace)
    m = sim.run(until=200000.0)
    assert m.n_finished == m.n_total
    assert m.preemptions > 0
    for w in sim.workers.values():
        assert w.pages.used_pages == 0
        assert w.view.free_pages == w.view.total_pages


def test_migration_cost_charged():
    cost = CostModel(CFG, SPEC)
    t = cost.migration_time(8192)
    assert t > cost.worker.hw.migration_latency
    # monotone in context
    assert cost.migration_time(16384) > t


@given(rate=st.floats(0.2, 1.5), seed=st.integers(0, 100))
@settings(max_examples=8, deadline=None)
def test_property_conservation_under_random_load(rate, seed):
    """No request is lost or duplicated under any load/policy mix."""
    sim, _ = build_cluster(CFG, "tropical", n_workers=3, worker_spec=SPEC)
    trace = _trace(rate=rate, duration=30.0, seed=seed)
    sim.add_trace(trace)
    m = sim.run(until=9000.0)
    assert m.n_total == len(trace)
    assert m.n_finished == m.n_total
    rids = sorted(r.rid for r in sim.requests)
    assert rids == sorted(r.rid for r in trace)


def test_trace_statistics_longtail():
    """Fig 3 reproduction: inputs must be long-tailed and far more dynamic
    than outputs."""
    rng = np.random.default_rng(0)
    inp, out = sample_lengths(rng, 20000, MOONCAKE)
    assert np.percentile(inp, 99) / np.median(inp) > 8    # long tail
    in_cv = inp.std() / inp.mean()
    out_cv = out.std() / out.mean()
    assert in_cv > 1.5 * out_cv                           # input more dynamic
