"""Per-architecture smoke tests: reduced configs, one forward/train step +
prefill/decode on CPU; assert output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke, list_archs
from repro.models import api as model_api

ARCHS = list_archs()


def _inputs_for(api, rng, batch=2, seq=16):
    cfg = api.cfg
    toks = jax.random.randint(rng, (batch, seq), 0, cfg.vocab_size)
    if cfg.family == "encdec":
        frames = jax.random.normal(rng, (batch, 8, cfg.d_model),
                                   dtype=cfg.dtype)
        return {"frames": frames, "tokens": toks}
    if cfg.family == "vlm":
        pe = jax.random.normal(rng, (batch, cfg.num_patches,
                                     cfg.vision_feature_dim), dtype=cfg.dtype)
        return {"tokens": toks, "prefix_embeds": pe}
    return toks


def _train_batch(api, rng, batch=2, seq=16):
    cfg = api.cfg
    toks = jax.random.randint(rng, (batch, seq), 0, cfg.vocab_size)
    b = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(rng, (batch, 8, cfg.d_model),
                                        dtype=cfg.dtype)
    if cfg.family == "vlm":
        b["prefix_embeds"] = jax.random.normal(
            rng, (batch, cfg.num_patches, cfg.vision_feature_dim),
            dtype=cfg.dtype)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = get_smoke(arch)
    api = model_api.build(cfg)
    rng = jax.random.PRNGKey(0)
    params = api.init(rng)
    batch = _train_batch(api, jax.random.PRNGKey(1))
    loss = api.loss(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: loss not finite"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_grads(arch):
    cfg = get_smoke(arch)
    api = model_api.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = _train_batch(api, jax.random.PRNGKey(1))
    loss, grads = jax.value_and_grad(api.loss)(params, batch)
    assert jnp.isfinite(loss)
    flat = jax.tree.leaves(grads)
    assert flat, "no grads"
    assert all(jnp.all(jnp.isfinite(g)) for g in flat), f"{arch}: NaN grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode(arch):
    cfg = get_smoke(arch)
    api = model_api.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch, seq, max_len = 2, 16, 32
    inputs = _inputs_for(api, jax.random.PRNGKey(1), batch, seq)
    cache = api.init_cache(batch, max_len)
    lengths = jnp.full((batch,), seq, jnp.int32)
    last, cache = api.prefill(params, cache, inputs, lengths)
    assert last.shape == (batch, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(last)), f"{arch}: NaN prefill logits"
    nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
    logits, cache = api.decode(params, cache, nxt, lengths)
    assert logits.shape == (batch, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits)), f"{arch}: NaN decode logits"


@pytest.mark.parametrize("arch", ["gemma2-2b", "deepseek-7b", "kimi-k2-1t",
                                  "rwkv6-7b", "zamba2-2.7b", "whisper-medium"])
def test_decode_matches_forward(arch):
    """Greedy decode continuation must equal teacher-forced forward."""
    cfg = get_smoke(arch)
    api = model_api.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch, seq = 2, 12
    inputs = _inputs_for(api, jax.random.PRNGKey(1), batch, seq)
    cache = api.init_cache(batch, 24)
    lengths = jnp.full((batch,), seq, jnp.int32)
    last, cache = api.prefill(params, cache, inputs, lengths)
    nxt = jnp.argmax(last, -1).astype(jnp.int32)
    dl, _ = api.decode(params, cache, nxt, lengths)

    # oracle: teacher-forced forward over the extended sequence
    from repro.models import transformer, rwkv6, zamba2, whisper
    toks = inputs["tokens"] if isinstance(inputs, dict) else inputs
    ext = jnp.concatenate([toks, nxt[:, None]], axis=1)
    if cfg.family in ("dense", "moe"):
        ref = transformer.forward_train(params, ext, cfg)[:, -1]
    elif cfg.family == "vlm":
        ref = transformer.forward_train(
            params, ext, cfg, prefix_embeds=inputs["prefix_embeds"])[:, -1]
    elif cfg.family == "rwkv":
        ref = rwkv6.forward_train(params, ext, cfg)[:, -1]
    elif cfg.family == "hybrid":
        ref = zamba2.forward_train(params, ext, cfg)[:, -1]
    else:
        ref = whisper.forward_train(params, inputs["frames"], ext, cfg)[:, -1]
    assert jnp.allclose(dl, ref, atol=2e-4), (
        f"{arch}: decode/forward mismatch {jnp.abs(dl - ref).max()}")
