"""Workload subsystem: scenario determinism, CSV replay equivalence,
hardened Mooncake-schema parsing, per-class metrics arithmetic."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.metrics import compute_metrics
from repro.core.request import Phase, Request, SLOClass, SLOSpec
from repro.serving.costmodel import CostModel, WorkerSpec
from repro.workload import (AGENTIC, Diurnal, GammaPoisson, LONGCTX,
                            MOONCAKE, OnOffBursts, SCENARIOS, Scenario,
                            ScenarioComponent, get_scenario, load_csv,
                            replay_csv, sample_lengths, save_csv)

COST = CostModel(get_config("internlm-20b"), WorkerSpec(tp=8))


def _sig(reqs):
    return [(r.rid, r.arrival_time, r.prompt_len, r.output_len,
             r.slo.name, r.slo.ttft, r.slo.tpot, r.slo.weight)
            for r in reqs]


# ---------------------------------------------------------------- scenarios

@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_every_scenario_is_seed_deterministic(name):
    sc = get_scenario(name)
    a = sc.generate(2.0, 40.0, COST, seed=3)
    b = sc.generate(2.0, 40.0, COST, seed=3)
    assert a, f"scenario {name} generated an empty trace"
    assert _sig(a) == _sig(b)
    c = sc.generate(2.0, 40.0, COST, seed=4)
    assert _sig(a) != _sig(c)
    # merged stream invariants: sorted arrivals, dense rids
    assert all(x.arrival_time <= y.arrival_time for x, y in zip(a, a[1:]))
    assert [r.rid for r in a] == list(range(len(a)))


def test_mixture_scenario_carries_two_classes():
    reqs = get_scenario("mixture").generate(3.0, 60.0, COST, seed=0)
    names = {r.slo.name for r in reqs}
    assert names == {"interactive", "batch"}
    by = {n: [r for r in reqs if r.slo.name == n] for n in names}
    # the interactive tenant is short-prompt/long-output vs batch long-ctx
    med = lambda rs, attr: float(np.median([getattr(r, attr) for r in rs]))
    assert med(by["interactive"], "prompt_len") \
        < med(by["batch"], "prompt_len")
    assert by["interactive"][0].slo.weight == 2.0
    assert by["batch"][0].slo.ttft > by["interactive"][0].slo.ttft


def test_component_substreams_are_independent():
    """Removing ANY component (leading or trailing — substreams are keyed
    by name, not position) must not perturb the survivors' traffic; the
    solo-reference construction in fig_multitenant relies on this."""
    comps = get_scenario("mixture").components
    both = Scenario("m", comps).generate(2.0, 40.0, COST, seed=9)
    for keep_idx in range(len(comps)):
        solo = Scenario("s", comps[keep_idx:keep_idx + 1]).generate(
            2.0, 40.0, COST, seed=9)
        keep = [(r.arrival_time, r.prompt_len, r.output_len) for r in both
                if r.slo.name == comps[keep_idx].name]
        assert keep == [(r.arrival_time, r.prompt_len, r.output_len)
                        for r in solo], comps[keep_idx].name


def test_scenario_rejects_duplicate_component_names():
    comp = get_scenario("mixture").components[0]
    with pytest.raises(ValueError, match="duplicate component names"):
        Scenario("dup", (comp, comp))


def test_replay_iterator_contract():
    sc = get_scenario("bursty")
    pairs = list(sc.replay(2.0, 30.0, COST, seed=1))
    assert pairs
    assert all(t == r.arrival_time for t, r in pairs)
    assert all(a[0] <= b[0] for a, b in zip(pairs, pairs[1:]))


def test_get_scenario_unknown_name_errors():
    with pytest.raises(KeyError, match="unknown scenario"):
        get_scenario("nope")


# ----------------------------------------------------------------- profiles

def test_agentic_profile_inverts_prompt_output_balance():
    rng = np.random.default_rng(0)
    a_in, a_out = sample_lengths(rng, 8000, AGENTIC)
    m_in, m_out = sample_lengths(np.random.default_rng(0), 8000, MOONCAKE)
    assert np.median(a_out) > np.median(a_in)          # inversion
    assert np.median(m_out) < np.median(m_in)          # mooncake baseline
    assert np.median(a_out) > np.median(m_out)


def test_longctx_profile_is_tail_heavy():
    rng = np.random.default_rng(0)
    l_in, _ = sample_lengths(rng, 8000, LONGCTX)
    m_in, _ = sample_lengths(np.random.default_rng(0), 8000, MOONCAKE)
    assert np.median(l_in) > np.median(m_in)
    assert np.percentile(l_in, 90) > np.percentile(m_in, 90)


# ----------------------------------------------------------------- arrivals

def test_onoff_bursts_keep_average_rate():
    proc = OnOffBursts(on_mean=5.0, off_mean=15.0)
    rng = np.random.default_rng(2)
    n = np.mean([len(proc.sample(rng, 4.0, 400.0)) for _ in range(5)])
    assert n / 400.0 == pytest.approx(4.0, rel=0.25)
    # burstier than its average: the max 5s window far exceeds the mean
    times = proc.sample(np.random.default_rng(3), 4.0, 400.0)
    per_win = np.histogram(times, bins=int(400 / 5))[0]
    assert per_win.max() > 3 * per_win.mean()


def test_diurnal_rate_modulates_sinusoidally():
    proc = Diurnal(period=100.0, amplitude=0.8)
    times = proc.sample(np.random.default_rng(5), 8.0, 1000.0)
    assert len(times) / 1000.0 == pytest.approx(8.0, rel=0.2)
    phase = (times % 100.0)
    peak = np.sum((phase > 10) & (phase < 40))     # sin>0 half (rising)
    trough = np.sum((phase > 60) & (phase < 90))   # sin<0 half
    assert peak > 1.5 * trough


# ---------------------------------------------------------------- CSV round

def _two_class_scenario():
    tight = SLOClass(ttft=1.0, tpot=0.05, name="interactive", weight=2.0)
    loose = SLOClass(ttft=15.0, tpot=0.5, name="batch", weight=1.0)
    return Scenario("2c", (
        ScenarioComponent(name="interactive", profile=AGENTIC,
                          arrivals=GammaPoisson(), rate_frac=0.5, slo=tight),
        ScenarioComponent(name="batch", profile=LONGCTX,
                          arrivals=GammaPoisson(), rate_frac=0.5, slo=loose),
    ))


def test_csv_round_trip_multiclass_identical_streams(tmp_path):
    sc = _two_class_scenario()
    orig = sc.generate(2.0, 40.0, COST, seed=7)
    assert {r.slo.name for r in orig} == {"interactive", "batch"}
    path = str(tmp_path / "trace.csv")
    save_csv(path, orig)
    back = load_csv(path, COST, classes=sc.classes)
    assert len(back) == len(orig)
    for a, b in zip(orig, back):
        assert (b.prompt_len, b.output_len) == (a.prompt_len, a.output_len)
        assert abs(b.arrival_time - a.arrival_time) <= 1e-3   # ms schema
        assert b.slo == a.slo          # identical class objects round-trip
    # replay_csv serves the same stream through the iterator contract
    pairs = list(replay_csv(path, COST, classes=sc.classes))
    assert [(r.prompt_len, r.slo.name) for _, r in pairs] == \
        [(r.prompt_len, r.slo.name) for r in orig]


def test_csv_single_class_keeps_legacy_3_column_schema(tmp_path):
    reqs = [Request(rid=0, arrival_time=0.5, prompt_len=100, output_len=10,
                    slo=SLOSpec(ttft=1.0, tpot=0.1))]
    path = str(tmp_path / "legacy.csv")
    save_csv(path, reqs)
    with open(path) as f:
        assert f.readline().strip() == "timestamp_ms,input_length,output_length"
    assert load_csv(path, COST)[0].prompt_len == 100


def test_load_csv_tolerates_header_variants_and_blank_lines(tmp_path):
    path = str(tmp_path / "messy.csv")
    with open(path, "w") as f:
        f.write("﻿ Timestamp , Input_Tokens ,OUTPUT_LENGTH, class \n"
                "1000,64,8,gold\n"
                "\n"
                "2500,128,16,\n"
                ",,,\n")
    reqs = load_csv(path, COST,
                    classes={"gold": SLOClass(1.0, 0.1, name="gold")})
    assert len(reqs) == 2
    assert reqs[0].slo.name == "gold" and reqs[0].prompt_len == 64
    assert reqs[1].slo.name == "default"       # blank class cell
    assert reqs[1].arrival_time == pytest.approx(2.5)
    assert [r.rid for r in reqs] == [0, 1]     # blank rows don't burn rids


def test_load_csv_clear_errors_on_bad_data(tmp_path):
    bad_neg = tmp_path / "neg.csv"
    bad_neg.write_text("timestamp_ms,input_length,output_length\n"
                       "100,-5,10\n")
    with pytest.raises(ValueError, match=r"neg.csv:2.*input_length.*-5"):
        load_csv(str(bad_neg), COST)
    bad_nan = tmp_path / "nan.csv"
    bad_nan.write_text("timestamp_ms,input_length,output_length\n"
                       "100,abc,10\n")
    with pytest.raises(ValueError, match="must be a number"):
        load_csv(str(bad_nan), COST)
    bad_hdr = tmp_path / "hdr.csv"
    bad_hdr.write_text("when,how_big\n1,2\n")
    with pytest.raises(ValueError, match="missing required column"):
        load_csv(str(bad_hdr), COST)
    zero_out = tmp_path / "zero.csv"
    zero_out.write_text("timestamp_ms,input_length,output_length\n"
                        "100,10,0\n")
    with pytest.raises(ValueError, match="output_length"):
        load_csv(str(zero_out), COST)


# ------------------------------------------------------- per-class metrics

def _finished(rid, slo, ttft, tpot, n_out=10):
    r = Request(rid=rid, arrival_time=0.0, prompt_len=8, output_len=n_out,
                slo=slo)
    r.record_first_token(ttft)
    for _ in range(n_out - 1):
        r.record_decode_iteration(tpot)
    r.finish_time = ttft + tpot * (n_out - 1)
    r.phase = Phase.FINISHED
    return r


def test_per_class_metrics_hand_computed():
    gold = SLOClass(ttft=1.0, tpot=0.10, name="gold", weight=2.0)
    bulk = SLOClass(ttft=5.0, tpot=0.50, name="bulk", weight=1.0)
    reqs = [
        _finished(0, gold, ttft=0.5, tpot=0.05),    # ok
        _finished(1, gold, ttft=0.5, tpot=0.05),    # ok
        _finished(2, gold, ttft=0.5, tpot=0.05),    # ok
        _finished(3, gold, ttft=2.0, tpot=0.05),    # ttft miss
        _finished(4, bulk, ttft=1.0, tpot=0.20),    # ok
        _finished(5, bulk, ttft=1.0, tpot=0.90),    # tpot miss
    ]
    m = compute_metrics(reqs)
    assert set(m.per_class) == {"gold", "bulk"}
    g, b = m.per_class["gold"], m.per_class["bulk"]
    assert (g.n_total, g.n_finished) == (4, 4)
    assert g.slo_attainment == pytest.approx(0.75)
    assert g.ttft_attainment == pytest.approx(0.75)
    assert g.tpot_attainment == pytest.approx(1.0)
    assert b.slo_attainment == pytest.approx(0.5)
    assert b.tpot_attainment == pytest.approx(0.5)
    # weighted: (2*0.75 + 1*0.5) / 3
    assert m.weighted_attainment == pytest.approx(2.0 / 3.0)
    # aggregate view unchanged: 4 of 6 meet both
    assert m.slo_attainment == pytest.approx(4.0 / 6.0)
    assert g.ttft_avg == pytest.approx((0.5 * 3 + 2.0) / 4)
    assert b.tpot_avg == pytest.approx((0.2 + 0.9) / 2)


def test_single_class_weighted_equals_aggregate():
    slo = SLOSpec(ttft=1.0, tpot=0.1)
    reqs = [_finished(i, slo, ttft=0.5 if i % 2 else 2.0, tpot=0.05)
            for i in range(8)]
    m = compute_metrics(reqs)
    assert set(m.per_class) == {"default"}
    assert m.weighted_attainment == pytest.approx(m.slo_attainment)
