"""Cross-request prefix reuse: index semantics, borrow/evict safety,
workload tagging determinism, end-to-end hit behaviour."""
import copy
import dataclasses

from repro.configs import get_config
from repro.perf import CostModel, WorkerSpec
from repro.serving.kvcache import PrefixIndex
from repro.serving.simulator import build_cluster
from repro.workload import get_scenario
from repro.workload.profiles import AGENTIC, MOONCAKE


# ------------------------------------------------------------ PrefixIndex
def test_index_lookup_counts_and_lru():
    idx = PrefixIndex(max_pages=100)
    idx.insert(11, tokens=256, pages=16)
    idx.insert(22, tokens=128, pages=8)
    assert idx.lookup(11) is not None
    assert idx.lookup(99) is None
    assert idx.lookups == 2 and idx.hits == 1
    assert idx.hit_rate == 0.5
    # peek never counts
    assert idx.peek(22) == 128 and idx.lookups == 2
    # 22 is now LRU (11 was touched by the counted lookup)
    evicted = idx.evict_lru()
    assert evicted.key == 22
    assert idx.peek(22) == 0


def test_index_never_evicts_borrowed_entry():
    """Evicting a prefix some decode still borrows would dangle its pages
    under a live request — refs > 0 entries must survive LRU pressure."""
    idx = PrefixIndex(max_pages=100)
    e = idx.insert(11, tokens=256, pages=16)
    e.refs += 1                         # a borrower is mid-decode
    idx.insert(22, tokens=128, pages=8)
    idx.lookup(22)                      # 11 is strictly older AND colder
    evicted = idx.evict_lru()
    assert evicted is not None and evicted.key == 22    # skipped the borrowed
    assert idx.evict_lru() is None      # only the borrowed entry remains
    assert idx.peek(11) == 256
    e.refs -= 1
    assert idx.evict_lru().key == 11    # released -> evictable again


def test_index_pseudo_rids_unique_and_negative():
    idx = PrefixIndex(max_pages=100)
    a = idx.insert(1, 64, 4)
    b = idx.insert(2, 64, 4)
    assert a.rid < 0 and b.rid < 0 and a.rid != b.rid


def test_index_clear_resets_entries_not_counters():
    idx = PrefixIndex(max_pages=100)
    idx.insert(1, 64, 4)
    idx.lookup(1)
    idx.clear()
    assert idx.peek(1) == 0 and idx.used_pages == 0
    assert idx.lookups == 1             # lifetime stats survive HBM loss


# ------------------------------------------------------- workload tagging
def test_scenario_prefix_tagging_deterministic():
    cm = CostModel(get_config("internlm-20b"), WorkerSpec(tp=8))
    sc = get_scenario("agentic")
    a = sc.generate(4.0, 30.0, cm, seed=7)
    b = sc.generate(4.0, 30.0, cm, seed=7)
    assert [(r.prefix_key, r.prefix_len) for r in a] \
        == [(r.prefix_key, r.prefix_len) for r in b]
    c = sc.generate(4.0, 30.0, cm, seed=8)
    assert [r.prefix_key for r in a] != [r.prefix_key for r in c]
    tagged = [r for r in a if r.prefix_key is not None]
    assert tagged and all(r.prompt_len > r.prefix_len > 0 for r in tagged)
    assert len({r.prefix_key for r in tagged}) <= AGENTIC.shared_prefixes


def test_prefix_tagging_never_perturbs_length_streams():
    """Arming shared prefixes must not shift arrival/length RNG draws:
    the identity stream is a separate substream."""
    cm = CostModel(get_config("internlm-20b"), WorkerSpec(tp=8))
    sc = get_scenario("agentic")
    untagged_prof = dataclasses.replace(AGENTIC, shared_prefixes=0,
                                        prefix_tokens=0)
    sc_off = dataclasses.replace(sc, components=tuple(
        dataclasses.replace(comp, profile=untagged_prof)
        for comp in sc.components))
    a = sc.generate(4.0, 30.0, cm, seed=7)
    b = sc_off.generate(4.0, 30.0, cm, seed=7)
    assert [(r.arrival_time, r.prompt_len, r.output_len) for r in a] \
        == [(r.arrival_time, r.prompt_len, r.output_len) for r in b]
    assert all(r.prefix_key is None for r in b)


def test_mooncake_and_agentic_profiles_carry_shared_prefixes():
    assert MOONCAKE.shared_prefixes > 0 and MOONCAKE.prefix_tokens > 0
    assert AGENTIC.shared_prefixes > 0 and AGENTIC.prefix_tokens > 0


# ------------------------------------------------------------ end-to-end
def _run(prefix_cache, seed=23, rate=6.0, duration=60.0):
    spec = dataclasses.replace(WorkerSpec(tp=8), hw=dataclasses.replace(
        WorkerSpec(tp=8).hw, hbm_bytes=WorkerSpec(tp=8).hw.hbm_bytes / 2))
    cfg = get_config("internlm-20b")
    cm = CostModel(cfg, spec)
    trace = get_scenario("agentic").generate(rate, duration, cm, seed=seed)
    sim, _ = build_cluster(cfg, "tropical", n_workers=2, worker_spec=spec,
                           host_kv_gb=16.0, prefix_cache=prefix_cache,
                           record_decisions=True)
    sim.add_trace(copy.deepcopy(trace))
    m = sim.run(until=duration * 10)
    return m, sim


def test_sim_prefix_hits_deterministic_and_positive():
    m1, sim1 = _run(prefix_cache=True)
    m2, sim2 = _run(prefix_cache=True)
    assert m1.prefix_lookups > 0 and m1.prefix_hits > 0
    assert 0.0 < m1.prefix_hit_rate <= 1.0
    # same seed + scenario => identical hit sequence and decision trace
    assert (m1.prefix_lookups, m1.prefix_hits) \
        == (m2.prefix_lookups, m2.prefix_hits)
    assert sim1.decisions == sim2.decisions
    assert m1.n_finished == m1.n_total
    # hits shorten real work: requests record their borrowed spans
    assert sum(r.prefix_hits for r in sim1.requests) == m1.prefix_hits


def test_sim_prefix_cache_off_is_inert():
    m, sim = _run(prefix_cache=False)
    assert m.prefix_lookups == 0 and m.prefix_hits == 0
    assert m.prefix_hit_rate == 0.0
    assert all(r.cached_prefix == 0 for r in sim.requests)
