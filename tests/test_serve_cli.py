"""launch/serve.py end-to-end smoke (sim mode) + stable JSON schema."""
import json

import pytest

from repro.launch import serve

REQUIRED_KEYS = {
    "schema_version", "policy", "arch", "mode", "rate", "workers", "seed",
    "n_total", "n_finished", "slo_attainment", "ttft_attainment",
    "tpot_attainment", "ttft_avg", "ttft_p90", "tpot_avg", "tpot_p90",
    "queue_avg", "queue_p90", "blocked_time_avg", "migrations", "restarts",
    "preemptions", "migration_wait_avg", "weighted_attainment",
    "per_class", "scenario",
    # v3: tiered-KV + prefix-reuse counters
    "kv_offloads", "kv_restores", "pages_offloaded", "pages_restored",
    "pages_reprefilled", "prefix_lookups", "prefix_hits", "prefix_hit_rate",
}


def _run(extra=()):
    return serve.main(["--mode", "sim", "--rate", "1.0",
                       "--duration", "15", "--json", *extra])


def test_serve_sim_json_schema(capsys):
    row = _run(["--seed", "1"])
    out = capsys.readouterr().out
    data = json.loads(out)          # stdout is exactly one JSON object
    # v3: tiered-KV + prefix-reuse counters (additive over the v2
    # per_class/weighted_attainment layout)
    assert data["schema_version"] == serve.METRICS_SCHEMA_VERSION == 3
    assert REQUIRED_KEYS <= set(data)
    assert data["mode"] == "sim" and data["seed"] == 1
    # both features default OFF: counters exist but must read zero
    assert data["kv_offloads"] == 0 and data["prefix_lookups"] == 0
    assert data["prefix_hit_rate"] == 0.0
    assert data["n_total"] > 0
    assert data["n_finished"] == data["n_total"]
    assert row["n_total"] == data["n_total"]
    # transfer engine on by default -> migration accounting present
    assert "kv_bytes_migrated" in data and "transfer_seconds" in data
    # single-class default run: one 'default' class, weighted == aggregate
    assert set(data["per_class"]) == {"default"}
    assert data["weighted_attainment"] == pytest.approx(
        data["slo_attainment"])


def test_serve_seed_reproducible(capsys):
    a = _run(["--seed", "5"])
    b = _run(["--seed", "5"])
    c = _run(["--seed", "6"])
    capsys.readouterr()
    assert a == b
    strip = lambda row: {k: v for k, v in row.items() if k != "seed"}
    assert strip(a) != strip(c)


def test_serve_online_predictor_flag(capsys):
    row = _run(["--online-predictor"])
    capsys.readouterr()
    assert "predictor_prefill_scale" in row
    assert "predictor_decode_scale" in row
    assert "role_transitions" in row      # windowed rebalancer active


def test_serve_rejects_bad_link_flags(capsys):
    with pytest.raises(SystemExit):
        serve.main(["--ici-bw", "0"])
    with pytest.raises(SystemExit):
        serve.main(["--ici-links", "-1"])
    with pytest.raises(SystemExit):
        serve.main(["--page-size", "0"])
    capsys.readouterr()


def test_serve_slo_classes_per_class_metrics(capsys):
    row = _run(["--slo-classes",
                "interactive:ttft=1.0,tpot=0.05,weight=2,frac=0.6;"
                "batch:ttft=12,tpot=0.6,frac=0.4"])
    capsys.readouterr()
    assert row["scenario"] == "slo-classes"
    assert set(row["per_class"]) == {"interactive", "batch"}
    assert row["per_class"]["interactive"]["weight"] == 2.0
    n = sum(c["n_total"] for c in row["per_class"].values())
    assert n == row["n_total"] > 0
    # weighted attainment is the weight-normalised per-class combination
    want = sum(c["weight"] * c["slo_attainment"]
               for c in row["per_class"].values()) \
        / sum(c["weight"] for c in row["per_class"].values())
    assert row["weighted_attainment"] == pytest.approx(want)


def test_serve_named_scenario(capsys):
    row = _run(["--scenario", "mixture", "--duration", "10"])
    capsys.readouterr()
    assert row["scenario"] == "mixture"
    assert set(row["per_class"]) == {"interactive", "batch"}


def test_serve_trace_csv_replay(tmp_path, capsys):
    path = tmp_path / "trace.csv"
    path.write_text("timestamp_ms,input_length,output_length,slo_class\n"
                    "0,512,16,interactive\n"
                    "500,2048,32,batch\n"
                    "900,256,8,interactive\n")
    row = _run(["--trace-csv", str(path), "--slo-classes",
                "interactive:ttft=2.0,tpot=0.1;batch:ttft=20,tpot=1.0"])
    capsys.readouterr()
    assert row["n_total"] == 3
    assert row["per_class"]["interactive"]["n_total"] == 2
    assert row["per_class"]["batch"]["n_total"] == 1


def test_serve_rejects_bad_scenario_and_classes(capsys):
    with pytest.raises(SystemExit):
        serve.main(["--scenario", "nope"])
    with pytest.raises(SystemExit):
        serve.main(["--slo-classes", "broken"])
    with pytest.raises(SystemExit):
        serve.main(["--slo-classes", "a:ttft=1"])       # missing tpot
    with pytest.raises(SystemExit):
        serve.main(["--slo-classes", "a:ttft=1,tpot=-2"])
    with pytest.raises(SystemExit):     # fracs oversubscribe the rate
        serve.main(["--slo-classes",
                    "a:ttft=1,tpot=0.1,frac=0.8;b:ttft=2,tpot=0.2,frac=0.8"])
    with pytest.raises(SystemExit):     # unassigned class left zero traffic
        serve.main(["--slo-classes",
                    "a:ttft=1,tpot=0.1,frac=1.0;b:ttft=2,tpot=0.2"])
    with pytest.raises(SystemExit):     # --slo-classes owns the workload
        serve.main(["--scenario", "agentic",
                    "--slo-classes", "a:ttft=1,tpot=0.1"])
    with pytest.raises(SystemExit):     # duplicate class names
        serve.main(["--slo-classes", "a:ttft=1,tpot=0.1;a:ttft=2,tpot=0.2"])
    with pytest.raises(SystemExit):     # absolute + scale conflict
        serve.main(["--slo-classes", "a:ttft=1,scale=5"])
    capsys.readouterr()
