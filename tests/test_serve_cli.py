"""launch/serve.py end-to-end smoke (sim mode) + stable JSON schema."""
import json

import pytest

from repro.launch import serve

REQUIRED_KEYS = {
    "schema_version", "policy", "arch", "mode", "rate", "workers", "seed",
    "n_total", "n_finished", "slo_attainment", "ttft_attainment",
    "tpot_attainment", "ttft_avg", "ttft_p90", "tpot_avg", "tpot_p90",
    "queue_avg", "queue_p90", "blocked_time_avg", "migrations", "restarts",
    "preemptions", "migration_wait_avg",
}


def _run(extra=()):
    return serve.main(["--mode", "sim", "--rate", "1.0",
                       "--duration", "15", "--json", *extra])


def test_serve_sim_json_schema(capsys):
    row = _run(["--seed", "1"])
    out = capsys.readouterr().out
    data = json.loads(out)          # stdout is exactly one JSON object
    assert data["schema_version"] == serve.METRICS_SCHEMA_VERSION == 1
    assert REQUIRED_KEYS <= set(data)
    assert data["mode"] == "sim" and data["seed"] == 1
    assert data["n_total"] > 0
    assert data["n_finished"] == data["n_total"]
    assert row["n_total"] == data["n_total"]
    # transfer engine on by default -> migration accounting present
    assert "kv_bytes_migrated" in data and "transfer_seconds" in data


def test_serve_seed_reproducible(capsys):
    a = _run(["--seed", "5"])
    b = _run(["--seed", "5"])
    c = _run(["--seed", "6"])
    capsys.readouterr()
    assert a == b
    strip = lambda row: {k: v for k, v in row.items() if k != "seed"}
    assert strip(a) != strip(c)


def test_serve_online_predictor_flag(capsys):
    row = _run(["--online-predictor"])
    capsys.readouterr()
    assert "predictor_prefill_scale" in row
    assert "predictor_decode_scale" in row
    assert "role_transitions" in row      # windowed rebalancer active


def test_serve_rejects_bad_link_flags(capsys):
    with pytest.raises(SystemExit):
        serve.main(["--ici-bw", "0"])
    with pytest.raises(SystemExit):
        serve.main(["--ici-links", "-1"])
    with pytest.raises(SystemExit):
        serve.main(["--page-size", "0"])
    capsys.readouterr()
