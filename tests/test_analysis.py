"""repro-lint: per-pass good/bad fixture pairs + repo self-check.

Every pass is driven through ``Project.from_sources`` — the same code
path the CLI uses on the real tree — with a minimal bad fixture that
must fire and its minimally-fixed twin that must stay silent. The final
tests pin the shipped ``LINT_baseline.json`` to an actual fresh run, so
the committed baseline can never drift from what the tool reports.
"""
import json
from pathlib import Path

from repro.analysis import BASELINE_NAME, run_all
from repro.analysis.base import Project, load_baseline
from repro.analysis.determinism import DeterminismPass
from repro.analysis.metrics_schema import MetricsSchemaPass
from repro.analysis.parity import ParityPass
from repro.analysis.refusals import RefusalsPass
from repro.analysis.soa import SoaCoherencePass
from repro.analysis.syncdonate import SyncDonationPass

REPO_ROOT = Path(__file__).resolve().parent.parent


def _rules(pass_cls, sources, data=None):
    findings = pass_cls().run(Project.from_sources(sources, data))
    return sorted(f.rule for f in findings)


# ------------------------------------------------------------ determinism

def test_determinism_flags_wallclock_rng_and_set_iter():
    bad = """
import time, random
import numpy as np

def decide(jobs):
    t = time.time()
    r = np.random.rand()
    g = np.random.default_rng()
    u = random.random()
    for j in {1, 2, 3}:
        t += j
    order = list({4, 5})
    return t, r, g, u, order
"""
    rules = _rules(DeterminismPass, {"src/repro/sched/policy.py": bad})
    assert rules.count("wallclock") == 1
    assert rules.count("unseeded-rng") == 3
    assert rules.count("set-iter") == 2


def test_determinism_good_twin_is_silent():
    good = """
import time
import numpy as np

def decide(jobs, seed):
    t = time.time()  # lint: allow-wallclock(measured harness)
    g = np.random.default_rng(seed)
    for j in sorted({1, 2, 3}):
        t += j
    order = sorted({4, 5})
    return t, g.random(), order
"""
    assert _rules(DeterminismPass, {"src/repro/sched/policy.py": good}) == []


def test_determinism_ignores_out_of_scope_files():
    bad = "import time\nT = time.time()\n"
    assert _rules(DeterminismPass, {"src/repro/models/layers.py": bad}) == []


# -------------------------------------------------------------------- soa

SOA_BAD = """
class Refresher:
    def sneak(self, view):
        object.__setattr__(view, "free_pages", 3)

    def evict(self, rid):
        self.decode_running.pop(rid)
"""

SOA_GOOD = """
class Refresher:
    def refresh(self, view, cols):
        object.__setattr__(view, "free_pages", 3)
        cols.dirty.add(view._row)

    def plumbing(self, view):
        object.__setattr__(view, "_row", 7)   # not a mirrored field

    def _decode_discard(self, rid):
        self.decode_running.pop(rid)

    def fail(self, rid):
        self.decode_running.clear()
        self._batch_version += 1
        self._cols.dirty = True
"""


def test_soa_flags_bypass_write_and_unversioned_mutation():
    rules = _rules(SoaCoherencePass, {"src/repro/serving/engine.py": SOA_BAD})
    assert rules == ["bypass-setattr", "decode-batch-version"]


def test_soa_good_twin_is_silent():
    assert _rules(SoaCoherencePass,
                  {"src/repro/serving/engine.py": SOA_GOOD}) == []


def test_soa_mirrored_fields_derived_from_viewcolumns():
    # a project that mirrors ONLY `speed` must not flag free_pages
    sources = {
        "src/repro/core/toggle.py": """
class ViewColumns:
    def _pull(self, views):
        for i, v in enumerate(views):
            self.speed[i] = v.speed
""",
        "src/repro/serving/engine.py": """
def poke(view):
    object.__setattr__(view, "free_pages", 3)

def tweak(view):
    object.__setattr__(view, "speed", 2.0)
""",
    }
    findings = SoaCoherencePass().run(Project.from_sources(sources))
    assert [f.scope for f in findings] == ["tweak"]


# ------------------------------------------------------------------- sync

SYNC_SCAFFOLD = """
import jax
import numpy as np

class Kernels:
    def prefill_fn(self, bucket, rows):
        fn = jax.jit(step, donate_argnums=1)
        return fn

    def warmup(self, params):
        cache = init()
        _, cache = self.prefill_fn(8, 1)(params, cache)
        jax.block_until_ready(cache)

class Executor:
    def _run_plan_fast(self, plan):
        {body}
"""


def _sync_rules(body):
    src = SYNC_SCAFFOLD.replace("{body}", body)
    return _rules(SyncDonationPass, {"src/repro/serving/executor.py": src})


def test_sync_budget_flags_loop_sync_and_extra_transfer():
    body = """for part in plan:
            jax.block_until_ready(part)
            n = int(part.tokens.item())
        host = np.asarray(plan.out)"""
    rules = _sync_rules(body)
    assert rules.count("sync-budget") == 2       # block in loop, host x2


def test_sync_budget_good_twin_is_silent():
    body = """jax.block_until_ready(plan.cache)
        host = np.asarray(plan.a) if plan.one else np.asarray(plan.b)"""
    assert _sync_rules(body) == []


def test_sync_missing_fast_path_scope_is_reported():
    src = "def unrelated():\n    pass\n"
    rules = _rules(SyncDonationPass, {"src/repro/serving/executor.py": src})
    assert rules == ["missing-fast-path", "missing-fast-path"]


def test_use_after_donate_flags_read_of_dead_buffer():
    body = """toks = self.kernels.prefill_fn(8, 4)(self.params, self.cache)
        return self.cache"""
    rules = _sync_rules(body)
    assert "use-after-donate" in rules


def test_use_after_donate_rebind_idiom_is_silent():
    body = """toks, self.cache = self.kernels.prefill_fn(8, 4)(
            self.params, self.cache)
        jax.block_until_ready(self.cache)
        host = np.asarray(toks)"""
    assert _sync_rules(body) == []


# ----------------------------------------------------------------- parity

def test_parity_flags_missing_scalar_ref_and_missing_test():
    sources = {
        "src/repro/core/dispatch.py": """
def choose(xs):
    return min(xs)

def choose_vec(xs):
    return xs.min()

def orphan_vec(xs):
    return xs
""",
        "tests/test_dispatch.py": "from repro.core.dispatch import choose_vec\n",
    }
    findings = ParityPass().run(Project.from_sources(sources))
    by_rule = {f.rule: f.scope for f in findings}
    assert by_rule == {"no-scalar-ref": "orphan_vec",
                       "no-parity-test": "orphan_vec"}


def test_parity_transitive_caller_coverage_and_pragmas():
    sources = {
        "src/repro/core/dispatch.py": """
def handle(xs):
    return inner_vec(xs)

def inner_vec(xs):  # lint: parity-ref(choose)
    return xs.min()

def choose(xs):
    return min(xs)

def helper_batch(xs):  # lint: not-parity(shape utility, no scalar twin)
    return xs
""",
        "tests/test_dispatch.py": "import handle  # drives the vec path\n",
    }
    assert ParityPass().run(Project.from_sources(sources)) == []


def test_parity_ref_to_nonexistent_def_is_flagged():
    sources = {"src/repro/core/dispatch.py": """
def lost_vec(xs):  # lint: parity-ref(ghost)
    return xs
""",
               "tests/test_dispatch.py": "lost_vec\n"}
    rules = _rules(ParityPass, sources)
    assert rules == ["parity-ref-missing"]


# ---------------------------------------------------------------- metrics

CHECKER_FIXTURE = '''
EXACT_KEYS = {"schema_version", "n_requests"}
'''


def test_metrics_flags_info_key_and_unclassified_emit():
    sources = {
        "benchmarks/check_summary.py": CHECKER_FIXTURE,
        "benchmarks/run.py": """
summary = {"schema_version": 5}
summary["weird_blob"] = 17
summary["ttft_p90_s"] = 0.5
""",
    }
    data = {"BENCH_summary.json": json.dumps(
        {"schema_version": 5, "weird_blob": 17, "ttft_p90_s": 0.5})}
    findings = MetricsSchemaPass().run(Project.from_sources(sources, data))
    rules = sorted(f.rule for f in findings)
    assert rules == ["unclassified-emit", "unclassified-key"]
    assert all(f.scope == "weird_blob" for f in findings)


def test_metrics_emitted_key_missing_from_snapshot():
    sources = {
        "benchmarks/check_summary.py": CHECKER_FIXTURE,
        "benchmarks/run.py": 'summary = {}\nsummary["new_thing_s"] = 1.0\n',
    }
    data = {"BENCH_summary.json": json.dumps({"schema_version": 5})}
    rules = _rules(MetricsSchemaPass, sources, data)
    assert rules == ["emitted-not-in-snapshot"]


def test_metrics_allow_key_pragma_and_update_kwargs():
    sources = {
        "benchmarks/check_summary.py": CHECKER_FIXTURE,
        "benchmarks/run.py": """
summary = {}
summary["blob"] = 17  # lint: allow-key(blob: debug payload, not gated)
summary.update(tpot_p90_s=0.1)
""",
    }
    data = {"BENCH_summary.json": json.dumps(
        {"schema_version": 5, "blob": 17, "tpot_p90_s": 0.1})}
    assert _rules(MetricsSchemaPass, sources, data) == []


# --------------------------------------------------------------- refusals

def test_refusals_flags_short_context_and_bare_raises():
    bad = """
def admit(self, wid, rid):
    if self.full:
        raise SlotExhausted(wid)
    if rid < 0:
        raise ValueError()
"""
    rules = _rules(RefusalsPass, {"src/repro/sched/backend.py": bad})
    assert rules == ["bare-raise", "refusal-context"]


def test_refusals_good_twin_is_silent():
    good = """
def admit(self, wid, rid, limit):
    if self.full:
        raise SlotExhausted(wid, rid, limit)
    if rid < 0:
        raise ValueError(f"rid {rid} negative (wid={wid})")
    try:
        pass
    except KeyError:
        raise
"""
    assert _rules(RefusalsPass, {"src/repro/sched/backend.py": good}) == []


# ----------------------------------------------------------------- pragmas

def test_unknown_and_reasonless_pragmas_are_findings():
    src = """
X = 1  # lint: allow-wallclok(typo'd name)
Y = 2  # lint: allow-wallclock()
"""
    project = Project.from_sources({"src/repro/core/x.py": src})
    rules = sorted(f.rule for f in project.pragma_findings())
    assert rules == ["pragma-reason", "unknown-pragma"]


# --------------------------------------------------------- repo self-check

def test_repo_is_clean_and_baseline_matches_fresh_run():
    """The shipped baseline must equal a fresh run EXACTLY — and the goal
    state is an empty baseline (violations get fixed, not baselined)."""
    project = Project.from_dir(REPO_ROOT)
    findings = run_all(project)
    findings.extend(project.pragma_findings())
    fresh = sorted(f.fingerprint for f in findings)
    shipped = sorted(load_baseline(REPO_ROOT / BASELINE_NAME))
    assert fresh == shipped, (
        "LINT_baseline.json is stale vs a fresh run; regenerate with "
        "`PYTHONPATH=src python -m repro.analysis --write-baseline` "
        "(after fixing, not baselining, new findings)")
    assert shipped == [], "baseline must stay empty: fix findings instead"


def test_cli_check_exits_clean_at_head():
    from repro.analysis.__main__ import main
    assert main(["--check", "--root", str(REPO_ROOT)]) == 0


def test_cli_exit_contract_on_bad_input(tmp_path):
    from repro.analysis.__main__ import main
    assert main(["--root", str(tmp_path / "missing")]) == 2   # no such dir
    (tmp_path / "src").mkdir()
    assert main(["--root", str(tmp_path)]) == 2               # no sources
    # malformed baseline -> exit 2
    repo = tmp_path / "repo"
    (repo / "src" / "repro").mkdir(parents=True)
    (repo / "src" / "repro" / "ok.py").write_text("X = 1\n")
    (repo / BASELINE_NAME).write_text('{"wrong": true}')
    assert main(["--check", "--root", str(repo)]) == 2


def test_cli_check_fails_on_new_finding_and_stale_entry(tmp_path, capsys):
    from repro.analysis.__main__ import main
    repo = tmp_path / "repo"
    (repo / "src" / "repro" / "sched").mkdir(parents=True)
    bad = repo / "src" / "repro" / "sched" / "p.py"
    bad.write_text("import time\nT = time.time()\n")
    # no baseline: the finding is NEW -> exit 1
    assert main(["--check", "--root", str(repo)]) == 1
    assert "NEW" in capsys.readouterr().out
    # accept it, then fix it: the baseline entry goes STALE -> exit 1
    assert main(["--write-baseline", "--root", str(repo)]) == 0
    assert main(["--check", "--root", str(repo)]) == 0
    bad.write_text("T = 0\n")
    assert main(["--check", "--root", str(repo)]) == 1
    assert "STALE" in capsys.readouterr().out
