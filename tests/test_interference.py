"""PR 5: measured mixed-batch interference + online recalibration.

Covers the bucketed ``InterferenceTable`` (scalar ↔ 1×1 equivalence,
piecewise-constant lookup), the γ-aware cost/predictor/toggle plumbing,
``calibrate_interference`` over the real Pallas kernels, the
``DriftMonitor`` online re-fit, the constant-state (rwkv/mamba) HBM
footprint bugfix with its page-preemption regression, the calibration
timer's median fix, and the per-iteration interference accounting.
"""
import copy
import dataclasses

import pytest

from repro.configs import get_config
from repro.core.request import Phase, Request, SLOSpec
from repro.perf import (AnalyticalPredictor, ClusterPredictor, CostModel,
                        DriftMonitor, InterferenceTable, OnlinePredictor,
                        Predictor, STATE_TOKEN_EQUIV, V5E, WorkerSpec,
                        calibrate_interference, gamma_at)
from repro.serving.engine import IterationPlan, Worker
from repro.serving.simulator import build_cluster


@pytest.fixture(scope="module")
def cfg():
    return get_config("internlm-20b")


@pytest.fixture(scope="module")
def blind(cfg):
    return CostModel(cfg, WorkerSpec(tp=8))


def _gamma_model(cfg, interference):
    return CostModel(cfg, WorkerSpec(tp=8, hw=dataclasses.replace(
        V5E, interference=interference)))


MIXED = (8, 8 * 2048.0, 2048, 0.0)


# --------------------------------------------------------------- the table

def test_table_validation():
    with pytest.raises(ValueError, match="bucket"):
        InterferenceTable(decode_edges=(), chunk_edges=(0,), gamma=())
    with pytest.raises(ValueError, match="ascend"):
        InterferenceTable(decode_edges=(4, 1), chunk_edges=(0,),
                          gamma=((0.1,), (0.2,)))
    with pytest.raises(ValueError, match="grid"):
        InterferenceTable(decode_edges=(1, 4), chunk_edges=(0,),
                          gamma=((0.1,),))
    with pytest.raises(ValueError, match="finite"):
        InterferenceTable(decode_edges=(1,), chunk_edges=(0,),
                          gamma=((-0.5,),))
    with pytest.raises(ValueError, match="finite"):
        InterferenceTable(decode_edges=(1,), chunk_edges=(0,),
                          gamma=((float("nan"),),))
    # list input is normalised to (hashable) tuples
    t = InterferenceTable(decode_edges=[1, 4], chunk_edges=[128],
                          gamma=[[0.1], [0.2]])
    assert hash(t) == hash(copy.deepcopy(t))


def test_table_lookup_piecewise_constant_and_monotone():
    t = InterferenceTable(decode_edges=(1, 4, 16), chunk_edges=(256, 1024),
                          gamma=((0.1, 0.2), (0.3, 0.4), (0.5, 0.6)))
    # within one cell the coefficient is constant wherever you probe it
    assert t.lookup(4, 256) == t.lookup(7, 500) == t.lookup(15, 1023) == 0.3
    # below the first edge clamps into the first bucket
    assert t.lookup(0, 0) == 0.1
    # a monotone grid yields monotone lookups across bucket boundaries
    for chunk in (0, 300, 2048):
        gs = [t.lookup(n, chunk) for n in (1, 4, 16, 64)]
        assert gs == sorted(gs)
    for n in (1, 8, 32):
        gs = [t.lookup(n, c) for c in (64, 512, 4096)]
        assert gs == sorted(gs)
    assert t.max_gamma == 0.6


def test_scalar_and_1x1_table_bit_exact(cfg):
    scalar = _gamma_model(cfg, 0.7)
    table = _gamma_model(cfg, InterferenceTable.from_scalar(0.7))
    for args in (MIXED, (1, 4096.0, 256, 1024.0), (32, 32 * 512.0, 8192, 0.0),
                 (4, 1024.0, 0, 0.0), (0, 0.0, 2048, 0.0)):
        assert scalar.iteration_time(*args) == table.iteration_time(*args)


def test_zero_table_bit_exact_with_legacy(cfg, blind):
    zeros = _gamma_model(cfg, InterferenceTable(
        decode_edges=(1, 8), chunk_edges=(256,), gamma=((0.0,), (0.0,))))
    for args in (MIXED, (16, 16 * 512.0, 512, 0.0), (1, 2048.0, 128, 64.0)):
        assert zeros.iteration_time(*args) == blind.iteration_time(*args)


def test_gamma_looked_up_by_actual_batch_and_chunk(cfg, blind):
    t = InterferenceTable(decode_edges=(1, 8), chunk_edges=(256,),
                          gamma=((0.0,), (0.9,)))
    m = _gamma_model(cfg, t)
    # small decode batch lands in the γ=0 cell: additive exactly
    assert m.iteration_time(4, 4 * 2048.0, 2048, 0.0) == \
        blind.iteration_time(4, 4 * 2048.0, 2048, 0.0)
    # large batch pays the hot cell's penalty
    assert m.iteration_time(8, 8 * 2048.0, 2048, 0.0) > \
        blind.iteration_time(8, 8 * 2048.0, 2048, 0.0)


def test_interference_penalty_decomposition(cfg, blind):
    m = _gamma_model(cfg, 0.5)
    assert m.interference_penalty(8, 8 * 2048.0, 0) == 0.0
    assert m.interference_penalty(0, 0.0, 2048) == 0.0
    assert blind.interference_penalty(*MIXED) == 0.0
    # penalty is exactly the mixed-iteration excess over the γ=0 model
    assert m.iteration_time(*MIXED) == \
        blind.iteration_time(*MIXED) + m.interference_penalty(*MIXED)


def test_gamma_at_resolves_scalar_and_table():
    assert gamma_at(0.25, 8, 2048) == 0.25
    t = InterferenceTable(decode_edges=(1, 8), chunk_edges=(0,),
                          gamma=((0.1,), (0.4,)))
    assert gamma_at(t, 2, 512) == 0.1
    assert gamma_at(t, 8, 512) == 0.4


# ---------------------------------------------------------- predictor layer

def test_predictor_interference_plumbing(cfg, blind):
    class Bare(Predictor):
        pass

    assert Bare().predict_interference(8, 8 * 2048.0, 2048) == 0.0
    m = _gamma_model(cfg, 0.5)
    pred = AnalyticalPredictor(m)
    expect = m.interference_penalty(*MIXED) * pred.safety
    assert pred.predict_interference(*MIXED) == expect > 0.0
    assert AnalyticalPredictor(blind).predict_interference(*MIXED) == 0.0
    # OnlinePredictor passes the penalty through untouched
    online = OnlinePredictor(pred)
    assert online.predict_interference(*MIXED) == expect
    # ClusterPredictor prices on the target worker's own γ
    cp = ClusterPredictor({0: blind, 1: m})
    assert cp.predict_interference(*MIXED, wid=0) == 0.0
    assert cp.predict_interference(*MIXED, wid=1) == expect


def test_toggle_admission_prices_the_penalty(cfg, blind):
    from repro.core.toggle import (MultiplexingToggle, Role, ToggleConfig,
                                   WorkerView)

    m = _gamma_model(cfg, 0.8)
    req = Request(rid=0, arrival_time=0.0, prompt_len=4096, output_len=64,
                  slo=SLOSpec(ttft=30.0, tpot=10.0))

    def view():
        return WorkerView(wid=0, role=Role.MULTIPLEX,
                          kv_capacity_tokens=1e9, decode_batch=8,
                          decode_sum_ctx=8 * 2048.0)

    cfg_t = ToggleConfig()
    chunk = cfg_t.chunk_tokens
    pred_aware = AnalyticalPredictor(m)
    t_chunk = pred_aware.predict_prefill(chunk, int(8 * 2048.0))
    penalty = pred_aware.predict_interference(8, 8 * 2048.0, chunk,
                                              int(8 * 2048.0))
    assert penalty > 0.0
    # slack absorbs the additive chunk cost but not the contention on top
    slack = (t_chunk + 0.5 * penalty) * cfg_t.slack_safety
    v_blind, v_aware = view(), view()
    v_blind.min_tpot_slack = v_aware.min_tpot_slack = slack
    tog_blind = MultiplexingToggle([v_blind], AnalyticalPredictor(blind),
                                   cfg_t)
    tog_aware = MultiplexingToggle([v_aware], pred_aware, cfg_t)
    assert tog_blind._multiplex_ok(v_blind, req)
    assert not tog_aware._multiplex_ok(v_aware, req)


def test_batch_rule_chunk_gate_prices_the_penalty(cfg, blind):
    """The per-iteration chunk-insertion gate (batch_rule) must price what
    dispatch admission prices: a chunk whose additive cost fits the slack
    but whose contention does not stays out of the batch."""
    from repro.core.policies import make_policy
    from repro.core.toggle import Role, WorkerView

    m = _gamma_model(cfg, 0.8)
    head = Request(rid=0, arrival_time=0.0, prompt_len=4096, output_len=64,
                   slo=SLOSpec(ttft=30.0, tpot=10.0))

    def policy_and_view(cost_model):
        views = [WorkerView(wid=0, role=Role.MULTIPLEX,
                            kv_capacity_tokens=1e9, decode_batch=8,
                            decode_sum_ctx=8 * 2048.0)]
        return make_policy("tropical", views,
                           AnalyticalPredictor(cost_model)), views[0]

    pol_aware, v_aware = policy_and_view(m)
    chunk = pol_aware.toggle.cfg.chunk_tokens
    t_add = AnalyticalPredictor(m).predict_prefill(chunk, int(8 * 2048.0))
    penalty = AnalyticalPredictor(m).predict_interference(
        8, 8 * 2048.0, chunk, int(8 * 2048.0))
    slack = (t_add + 0.5 * penalty) * pol_aware.toggle.cfg.slack_safety
    v_aware.min_tpot_slack = slack
    pol_blind, v_blind = policy_and_view(blind)
    v_blind.min_tpot_slack = slack
    assert pol_blind.batch_rule(v_blind, 0.0, head).prefill_budget > 0
    assert pol_aware.batch_rule(v_aware, 0.0, head).prefill_budget == 0


def test_slack_chunking_shrinks_chunk_instead_of_rejecting(cfg, blind):
    """tropical++'s slack-sized chunking must fold the penalty into the
    binary search: with γ on, the same slack budget buys a smaller chunk
    — not a full-size chunk the admission gate then refuses."""
    from repro.core.toggle import (MultiplexingToggle, Role, ToggleConfig,
                                   WorkerView)

    m = _gamma_model(cfg, 0.8)
    cfg_t = ToggleConfig(slack_chunking=True)

    def view():
        v = WorkerView(wid=0, role=Role.MULTIPLEX, kv_capacity_tokens=1e9,
                       decode_batch=8, decode_sum_ctx=8 * 2048.0)
        # slack that fits a mid-size additive chunk comfortably
        v.min_tpot_slack = AnalyticalPredictor(blind).predict_prefill(
            1024, int(8 * 2048.0)) * cfg_t.slack_safety
        return v

    tog_blind = MultiplexingToggle([view()], AnalyticalPredictor(blind),
                                   cfg_t)
    tog_aware = MultiplexingToggle([view()], AnalyticalPredictor(m), cfg_t)
    c_blind = tog_blind.chunk_for(view(), 10.0)
    c_aware = tog_aware.chunk_for(view(), 10.0)
    assert cfg_t.min_chunk <= c_aware < c_blind


# ---------------------------------------------------- kernel-grid calibration

def test_time_fn_median_and_repeats_guard(monkeypatch):
    import types

    import repro.perf.calibrate as cal

    with pytest.raises(ValueError, match="repeats"):
        cal._time_fn(lambda: None, 0)
    with pytest.raises(ValueError, match="repeats"):
        cal._time_fn(lambda: None, -3)

    def fake_clock(deltas):
        ticks = []
        t = 0.0
        for d in deltas:
            ticks += [t, t + d]
            t += d + 100.0
        it = iter(ticks)
        return types.SimpleNamespace(perf_counter=lambda: next(it))

    # even repeats: the mean of the two middle samples, NOT the
    # upper-middle sample times[len//2] (the old biased pick -> 5.0)
    monkeypatch.setattr(cal, "time", fake_clock([1.0, 5.0, 2.0, 100.0]))
    assert cal._time_fn(lambda: None, 4) == 3.5
    # odd repeats: the true middle
    monkeypatch.setattr(cal, "time", fake_clock([9.0, 1.0, 5.0]))
    assert cal._time_fn(lambda: None, 3) == 5.0


def test_calibrate_interference_measures_a_bounded_grid():
    table, cal = calibrate_interference(
        V5E, decode_batches=(2, 1), chunk_sizes=(64,), heads=2, head_dim=64,
        page_size=16, pages_per_seq=2, repeats=2)
    assert table.decode_edges == (1, 2)       # grid values sorted into edges
    assert table.chunk_edges == (64,)
    assert all(0.0 <= g <= 1.0 for row in table.gamma for g in row)
    assert all(t > 0.0 for t in cal.pure_prefill_s + cal.pure_decode_s)
    assert all(t > 0.0 for row in cal.mixed_s for t in row)
    assert cal.table is table
    with pytest.raises(ValueError, match="grid"):
        calibrate_interference(V5E, decode_batches=(), chunk_sizes=(64,))


def test_calibrated_backend_solves_gamma_against_measured_spec(monkeypatch):
    """measure_interference=True must solve γ with the MEASURED constants
    — the β's the model recomputes when applying the penalty — not the
    assumed spec's."""
    import repro.perf.calibrate as cal
    from repro.configs import get_smoke

    captured = {}
    real = cal.calibrate_interference

    def spy(hw, **kw):
        captured["hw"] = hw
        return real(hw, **kw)

    monkeypatch.setattr(cal, "calibrate_interference", spy)
    backend = cal.CalibratedRooflineBackend(
        get_smoke("deepseek-7b"), WorkerSpec(tp=1), seq=128, heads=2,
        head_dim=64, batch=2, page_size=16, pages_per_seq=2, repeats=1,
        measure_interference=True,
        interference_kw=dict(decode_batches=(1,), chunk_sizes=(64,),
                             heads=2, head_dim=64, page_size=16,
                             pages_per_seq=2, repeats=1))
    assert captured["hw"].name.endswith("-measured")
    assert isinstance(backend.cost.worker.hw.interference, InterferenceTable)
    assert backend.interference_calibration is not None


def test_online_predictor_does_not_absorb_the_gamma_penalty(cfg):
    """Observed mixed durations include the γ penalty; the phase-scale
    EWMAs must strip it before apportioning, or admission prices the
    contention twice (once in the inflated scales, once via
    predict_interference)."""
    m = _gamma_model(cfg, 0.8)
    pred = OnlinePredictor(AnalyticalPredictor(m))
    t_mixed = m.iteration_time(*MIXED)       # truth = the model's own γ
    for _ in range(60):
        pred.observe_iteration(8, 8 * 2048.0, 2048, 0.0, t_mixed)
    # an unbiased model converges to scale ~1.0 — no phantom inflation
    assert pred.prefill_scale == pytest.approx(1.0, abs=0.1)
    assert pred.decode_scale == pytest.approx(1.0, abs=0.1)


def test_calibrated_table_drops_into_a_cost_model(cfg, blind):
    table, _ = calibrate_interference(
        V5E, decode_batches=(1,), chunk_sizes=(64,), heads=2, head_dim=64,
        page_size=16, pages_per_seq=2, repeats=1)
    m = _gamma_model(cfg, table)
    assert m.iteration_time(*MIXED) >= blind.iteration_time(*MIXED)


# ------------------------------------------------------- online recalibration

def test_drift_monitor_converges_to_injected_gamma(cfg, blind):
    cost = CostModel(cfg, WorkerSpec(tp=8))            # starts γ-blind
    truth = _gamma_model(cfg, 0.6)
    dm = DriftMonitor({0: cost}, every=16, floor=8)
    plan = IterationPlan(decode_reqs=[], prefill_parts=[], n_decode=8,
                         sum_ctx=8 * 2048.0, prefill_tokens=2048,
                         prefill_ctx_offset=0.0, exclusive_prefill=False)
    for _ in range(40):
        predicted = cost.iteration_time(*MIXED)
        dm.observe(0, plan, predicted, truth.iteration_time(*MIXED))
    assert dm.recalibrations >= 2
    assert gamma_at(cost.worker.hw.interference, 8, 2048) == \
        pytest.approx(0.6, abs=0.05)
    # the corrected model now prices the truth
    assert cost.iteration_time(*MIXED) == \
        pytest.approx(truth.iteration_time(*MIXED), rel=0.05)


def test_drift_monitor_nudges_efficiency_from_pure_residuals(cfg):
    cost = CostModel(cfg, WorkerSpec(tp=8))
    dm = DriftMonitor({0: cost}, every=16, floor=8)
    plan = IterationPlan(decode_reqs=[], prefill_parts=[], n_decode=0,
                         sum_ctx=0.0, prefill_tokens=4096,
                         prefill_ctx_offset=0.0, exclusive_prefill=False)
    target = 2.0 * cost.prefill_time(4096)   # hardware runs 2x slower
    for _ in range(64):
        dm.observe(0, plan, cost.prefill_time(4096), target)
    assert cost.worker.hw.mfu_prefill < V5E.mfu_prefill
    assert cost.prefill_time(4096) == pytest.approx(target, rel=0.2)


def test_drift_monitor_is_a_noop_without_drift(cfg):
    cost = CostModel(cfg, WorkerSpec(tp=8))
    dm = DriftMonitor({0: cost}, every=8, floor=2)
    mixed = IterationPlan(decode_reqs=[], prefill_parts=[], n_decode=8,
                          sum_ctx=8 * 2048.0, prefill_tokens=2048,
                          prefill_ctx_offset=0.0, exclusive_prefill=False)
    pure = IterationPlan(decode_reqs=[], prefill_parts=[], n_decode=16,
                         sum_ctx=16 * 512.0, prefill_tokens=0,
                         prefill_ctx_offset=0.0, exclusive_prefill=False)
    before = [cost.iteration_time(*MIXED),
              cost.iteration_time(16, 16 * 512.0),
              cost.prefill_time(8192)]
    for _ in range(24):                       # observed == predicted
        dm.observe(0, mixed, cost.iteration_time(*MIXED),
                   cost.iteration_time(*MIXED))
        dm.observe(0, pure, cost.iteration_time(16, 16 * 512.0),
                   cost.iteration_time(16, 16 * 512.0))
    assert dm.recalibrations >= 1
    after = [cost.iteration_time(*MIXED),
             cost.iteration_time(16, 16 * 512.0),
             cost.prefill_time(8192)]
    assert before == after                    # bit-exact


def test_drift_monitor_preserves_startup_calibrated_cells(cfg):
    """Re-fitting from traffic that only warms one bucket must not forget
    the startup calibration's other cells — the new table is the union of
    warm cells and the existing grid."""
    startup = InterferenceTable(decode_edges=(1, 8), chunk_edges=(256,),
                                gamma=((0.2,), (0.9,)))
    cost = _gamma_model(cfg, startup)
    truth = _gamma_model(cfg, InterferenceTable(
        decode_edges=(1, 8), chunk_edges=(256,), gamma=((0.5,), (0.9,))))
    dm = DriftMonitor({0: cost}, every=16, floor=8)
    plan = IterationPlan(decode_reqs=[], prefill_parts=[], n_decode=2,
                         sum_ctx=2 * 2048.0, prefill_tokens=2048,
                         prefill_ctx_offset=0.0, exclusive_prefill=False)
    for _ in range(32):                      # warms only the (2, 2048) cell
        dm.observe(0, plan, cost.iteration_time(2, 2 * 2048.0, 2048),
                   truth.iteration_time(2, 2 * 2048.0, 2048))
    table = cost.worker.hw.interference
    assert gamma_at(table, 2, 2048) == pytest.approx(0.5, abs=0.05)
    # cells outside the traffic's hull keep their startup-measured γ
    assert gamma_at(table, 8, 256) == 0.9
    assert gamma_at(table, 1, 256) == 0.2
    assert 8 in table.decode_edges and 256 in table.chunk_edges


def test_drift_monitor_keeps_per_model_evidence_separate(cfg):
    """One throttling worker must not corrupt a healthy peer's constants
    (heterogeneous clusters carry one CostModel per worker)."""
    sick = CostModel(cfg, WorkerSpec(tp=8))
    healthy = CostModel(cfg, WorkerSpec(tp=8))
    dm = DriftMonitor({0: sick, 1: healthy}, every=16, floor=8)
    plan = IterationPlan(decode_reqs=[], prefill_parts=[], n_decode=16,
                         sum_ctx=16 * 512.0, prefill_tokens=0,
                         prefill_ctx_offset=0.0, exclusive_prefill=False)
    for _ in range(64):
        t_sick = sick.iteration_time(16, 16 * 512.0)
        t_ok = healthy.iteration_time(16, 16 * 512.0)
        dm.observe(0, plan, t_sick, 2.0 * t_sick)   # worker 0 runs 2x slow
        dm.observe(1, plan, t_ok, t_ok)             # worker 1 is fine
    assert sick.worker.hw.mfu_decode < V5E.mfu_decode
    assert healthy.worker.hw.mfu_decode == V5E.mfu_decode
    assert healthy.worker.hw.bw_eff == V5E.bw_eff


def test_drift_monitor_unbiased_under_symmetric_noise(cfg):
    """Zero-mean noise around the additive prediction must not teach a
    phantom γ: negative residuals pull the EWMA down (only the folded
    table value clamps at 0)."""
    cost = CostModel(cfg, WorkerSpec(tp=8))
    dm = DriftMonitor({0: cost}, every=16, floor=8)
    plan = IterationPlan(decode_reqs=[], prefill_parts=[], n_decode=8,
                         sum_ctx=8 * 2048.0, prefill_tokens=2048,
                         prefill_ctx_offset=0.0, exclusive_prefill=False)
    truth = CostModel(cfg, WorkerSpec(tp=8))  # frozen γ=0 ground truth
    unit = truth._interference(1.0, *MIXED)
    for i in range(64):                       # observed = truth ± 0.3·unit
        noise = 0.3 * unit * (1 if i % 2 else -1)
        dm.observe(0, plan, cost.iteration_time(*MIXED),
                   truth.iteration_time(*MIXED) + noise)
    assert gamma_at(cost.worker.hw.interference, 8, 2048) == \
        pytest.approx(0.0, abs=0.1)


def test_drift_monitor_accumulates_subfloor_evidence_across_windows(cfg):
    """A phase too rare to reach the evidence floor inside one window must
    keep its evidence across applies — only a folded phase resets."""
    cost = CostModel(cfg, WorkerSpec(tp=8))
    dm = DriftMonitor({0: cost}, every=4, floor=8)   # window < floor
    plan = IterationPlan(decode_reqs=[], prefill_parts=[], n_decode=0,
                         sum_ctx=0.0, prefill_tokens=4096,
                         prefill_ctx_offset=0.0, exclusive_prefill=False)
    target = 2.0 * cost.prefill_time(4096)   # frozen: hardware is 2x slow
    for _ in range(40):
        dm.observe(0, plan, cost.prefill_time(4096), target)
    assert dm.recalibrations >= 8
    # evidence survived the sub-floor windows and eventually folded
    assert cost.worker.hw.mfu_prefill < V5E.mfu_prefill
    assert cost.prefill_time(4096) == pytest.approx(target, rel=0.2)


def test_drift_monitor_scalar_start_keeps_floor_below_warm_hull(cfg, blind):
    """Starting γ-blind (scalar 0.0), evidence at a big-batch cell must
    not leak to small batches: the folded table anchors the lowest bucket
    at the current scalar."""
    cost = CostModel(cfg, WorkerSpec(tp=8))
    truth = _gamma_model(cfg, 0.8)
    dm = DriftMonitor({0: cost}, every=16, floor=8)
    plan = IterationPlan(decode_reqs=[], prefill_parts=[], n_decode=8,
                         sum_ctx=8 * 2048.0, prefill_tokens=2048,
                         prefill_ctx_offset=0.0, exclusive_prefill=False)
    for _ in range(32):
        dm.observe(0, plan, cost.iteration_time(*MIXED),
                   truth.iteration_time(*MIXED))
    table = cost.worker.hw.interference
    assert gamma_at(table, 8, 2048) == pytest.approx(0.8, abs=0.05)
    # no evidence at batch 1 / tiny chunks: stays at the scalar (0.0), so
    # small mixed batches remain priced additively — bit-exact
    assert gamma_at(table, 1, 64) == 0.0
    assert cost.iteration_time(1, 2048.0, 64, 0.0) == \
        blind.iteration_time(1, 2048.0, 64, 0.0)


def test_build_cluster_gates_efficiency_fold_under_online_predictor(cfg):
    """Both loops armed: the OnlinePredictor owns efficiency drift, the
    DriftMonitor re-fits γ only — never the same correction twice."""
    sim, _ = build_cluster(cfg, "tropical", n_workers=2,
                           online_predictor=True, recalibrate_every=32)
    assert sim.sched.drift_monitor is not None
    assert sim.sched.drift_monitor.adjust_efficiency is False
    sim2, _ = build_cluster(cfg, "tropical", n_workers=2,
                            recalibrate_every=32)
    assert sim2.sched.drift_monitor.adjust_efficiency is True


def test_drift_monitor_does_not_misread_uniform_drift_as_gamma(cfg):
    """A uniformly 1.5x-slow backend with NO contention (the thermal-
    drift case) must not teach γ, even when efficiency folding is off
    (the OnlinePredictor pairing): the implied-γ solve discounts the
    pure-phase drift ratio first."""
    cost = CostModel(cfg, WorkerSpec(tp=8))
    dm = DriftMonitor({0: cost}, every=16, floor=8,
                      adjust_efficiency=False)
    pre = IterationPlan(decode_reqs=[], prefill_parts=[], n_decode=0,
                        sum_ctx=0.0, prefill_tokens=2048,
                        prefill_ctx_offset=0.0, exclusive_prefill=False)
    dec = IterationPlan(decode_reqs=[], prefill_parts=[], n_decode=8,
                        sum_ctx=8 * 2048.0, prefill_tokens=0,
                        prefill_ctx_offset=0.0, exclusive_prefill=False)
    mix = IterationPlan(decode_reqs=[], prefill_parts=[], n_decode=8,
                        sum_ctx=8 * 2048.0, prefill_tokens=2048,
                        prefill_ctx_offset=0.0, exclusive_prefill=False)
    for _ in range(60):                       # evidence of uniform drift
        dm.observe(0, pre, cost.prefill_time(2048),
                   1.5 * cost.prefill_time(2048))
        dm.observe(0, dec, cost.iteration_time(8, 8 * 2048.0),
                   1.5 * cost.iteration_time(8, 8 * 2048.0))
    for _ in range(16):                       # mixed: slow but additive
        dm.observe(0, mix, cost.iteration_time(*MIXED),
                   1.5 * cost.iteration_time(*MIXED))
    assert cost.worker.hw.mfu_prefill == V5E.mfu_prefill   # fold stayed off
    assert gamma_at(cost.worker.hw.interference, 8, 2048) == \
        pytest.approx(0.0, abs=0.1)


def test_drift_monitor_registers_elastic_workers(cfg):
    """A worker added after construction (elastic clusters) must observe
    and recalibrate like a founding one."""
    cost0 = CostModel(cfg, WorkerSpec(tp=8))
    dm = DriftMonitor({0: cost0}, every=16, floor=8)
    late = CostModel(cfg, WorkerSpec(tp=8))
    dm.register(10, late)
    truth = _gamma_model(cfg, 0.6)
    plan = IterationPlan(decode_reqs=[], prefill_parts=[], n_decode=8,
                         sum_ctx=8 * 2048.0, prefill_tokens=2048,
                         prefill_ctx_offset=0.0, exclusive_prefill=False)
    for _ in range(32):
        dm.observe(10, plan, late.iteration_time(*MIXED),
                   truth.iteration_time(*MIXED))
    assert gamma_at(late.worker.hw.interference, 8, 2048) == \
        pytest.approx(0.6, abs=0.05)
    # the scheduler's elastic-add path wires the registration
    sim, _ = build_cluster(cfg, "tropical", n_workers=2,
                           recalibrate_every=32)
    w = Worker(5, CostModel(cfg, WorkerSpec(tp=8)))
    sim.add_worker_at(0.0, w)
    sim.run(until=1.0)
    assert sim.sched.drift_monitor.costs.get(5) is w.cost


def test_drift_monitor_rejects_bad_cadence(cfg, blind):
    with pytest.raises(ValueError, match="cadence"):
        DriftMonitor({0: blind}, every=0)


def test_serve_cli_round_trips_recalibrate_every():
    from repro.launch import serve

    row = serve.main(["--rate", "0.5", "--duration", "10", "--seed", "3",
                      "--recalibrate-every", "64"])
    assert row["recalibrate_every"] == 64
    assert row["recalibrations"] >= 0
    assert "drift_gamma_max" in row
    # off by default: no drift keys in the legacy row
    row_off = serve.main(["--rate", "0.5", "--duration", "10", "--seed", "3"])
    assert "recalibrate_every" not in row_off
    with pytest.raises(SystemExit):
        serve.main(["--rate", "0.5", "--duration", "10",
                    "--recalibrate-every", "0"])


# ------------------------------------- constant-state HBM footprint bugfix

def test_state_tokens_nonzero_for_constant_state_families():
    cm = CostModel(get_config("rwkv6-7b"), WorkerSpec(tp=4))
    assert cm.spec.kv_bytes_per_token == 0.0
    assert cm.spec.state_bytes > 0.0
    # context-independent, but NOT zero: the state pins real HBM
    assert cm.state_tokens(1) == cm.state_tokens(100_000) \
        == float(STATE_TOKEN_EQUIV)
    # the pool grants exactly (#states that fit) x the per-state unit,
    # so admission gates at the true state count
    states = cm.kv_capacity_tokens() / cm.state_tokens(0)
    assert states == int(states) and states >= 1


def test_dense_state_tokens_unchanged(blind):
    assert blind.state_tokens(4096) == 4096.0


def test_dense_kv_counter_balances_over_lifecycle(blind):
    """Full engine flow (prefill start -> first token -> decode ->
    finish) must return the token counter to exactly zero: the first
    generated token's footprint is charged at prefill completion, every
    decode step adds its delta, release frees the final context."""
    from repro.core.policies import BatchRule

    w = Worker(0, blind)
    slo = SLOSpec(ttft=60.0, tpot=10.0)
    r = Request(rid=0, arrival_time=0.0, prompt_len=64, output_len=4,
                slo=slo)
    w.admit_prefill(r, 0.0)
    plan = w.compose_iteration(
        BatchRule(run_decode=True, prefill_budget=10_000,
                  prefill_exclusive=True), 0.0)
    assert plan.prefill_tokens == 64
    now = 1.0
    assert w.complete_iteration(plan, now, 1.0) == [r]
    # prompt + the first generated token are both on the books
    assert w.view.kv_used_tokens == blind.state_tokens(r.context_len) == 65.0
    w.admit_decode(r, now)
    while r.phase == Phase.DECODING:
        plan = w.compose_iteration(
            BatchRule(run_decode=True, prefill_budget=0,
                      prefill_exclusive=False), now)
        dur = w.plan_duration(plan)
        now += dur
        w.complete_iteration(plan, now, dur)
    assert r.phase == Phase.FINISHED
    assert w.view.kv_used_tokens == 0.0
    assert w.pages.used_pages == 0


def test_sliding_window_kv_counter_balances_over_lifecycle():
    """Past the window cap a decode step only pins 0.5 token-equivalents
    (half the layers hold window-bounded KV); growing the counter by a
    flat 1 leaked the other half on every finished long request."""
    cfg = get_config("gemma2-2b")
    cm = CostModel(cfg, WorkerSpec(tp=1))
    assert cm.spec.ctx_cap is not None
    w = Worker(0, cm)
    slo = SLOSpec(ttft=60.0, tpot=10.0)
    prompt = cm.spec.ctx_cap + 64            # already past the window
    r = Request(rid=0, arrival_time=0.0, prompt_len=prompt, output_len=3,
                slo=slo)
    r.generated_tokens = 1
    # charge admission for the live context, as admit_migrated does
    assert w.pages.reserve(r.rid, w._page_need(r.context_len))
    w.view.kv_used_tokens += cm.state_tokens(r.context_len)
    w.admit_decode(r, 0.0)
    plan = IterationPlan(decode_reqs=[r], prefill_parts=[], n_decode=1,
                         sum_ctx=float(r.context_len), prefill_tokens=0,
                         prefill_ctx_offset=0.0, exclusive_prefill=False)
    dur = cm.decode_iter_time(1, plan.sum_ctx)
    w.complete_iteration(plan, now=dur, duration=dur)
    # one token past the cap pins exactly 0.5 token-equivalents
    assert w.view.kv_used_tokens == cm.state_tokens(r.context_len)
    w.complete_iteration(plan, now=2 * dur, duration=dur)
    assert r.phase.name == "FINISHED"
    assert w.view.kv_used_tokens == 0.0       # fully released, no leak


def test_constant_state_kv_counter_balances_over_lifecycle():
    """Admission pins the constant state; decode steps must NOT grow the
    token counter (nothing new is written), or release() — which frees
    the constant footprint — would leak output_len tokens per finished
    request and eventually wedge admission on an empty worker."""
    cfg = get_config("rwkv6-7b")
    w = Worker(0, CostModel(cfg, WorkerSpec(tp=4)))
    slo = SLOSpec(ttft=60.0, tpot=10.0)
    r = Request(rid=0, arrival_time=0.0, prompt_len=32, output_len=3,
                slo=slo)
    r.generated_tokens = 1
    assert w.pages.reserve(r.rid, w._page_need(r.prompt_len))
    w.view.kv_used_tokens += w.cost.state_tokens(r.prompt_len)
    w.admit_decode(r, 0.0)
    plan = IterationPlan(decode_reqs=[r], prefill_parts=[], n_decode=1,
                         sum_ctx=float(r.context_len), prefill_tokens=0,
                         prefill_ctx_offset=0.0, exclusive_prefill=False)
    dur = w.cost.decode_iter_time(1, plan.sum_ctx)
    for step in range(2):
        w.complete_iteration(plan, now=(step + 1) * dur, duration=dur)
    assert r.phase.name == "FINISHED"
    assert w.view.kv_used_tokens == 0.0       # fully released, no leak
    assert w.pages.used_pages == 0


def test_rwkv_pool_exhaustion_triggers_preemption():
    """A pool of rwkv6 decodes must exhaust the page pool and preempt —
    with the old zero-footprint ternary the accountant saw nothing, so
    admission never gated and the watermark never fired."""
    cfg = get_config("rwkv6-7b")
    from repro.perf.model import build_cost_spec
    spec = build_cost_spec(cfg)
    # HBM sized so ~2.5 states fit beside the weights -> 2 concurrent
    hbm = (spec.n_params * spec.bytes_per_weight
           + 2.5 * spec.state_bytes) / 0.9
    wspec = WorkerSpec(tp=1, hw=dataclasses.replace(V5E, hbm_bytes=hbm))
    sim, cm = build_cluster(cfg, "tropical", n_workers=2, worker_spec=wspec)
    assert cm.kv_capacity_tokens() == 2 * STATE_TOKEN_EQUIV
    slo = SLOSpec(ttft=120.0, tpot=10.0)
    trace = [Request(rid=i, arrival_time=0.01 * i, prompt_len=32,
                     output_len=12, slo=slo) for i in range(6)]
    sim.add_trace(trace)
    m = sim.run(until=4000.0)
    assert m.n_finished == 6                  # preempted work still completes
    assert m.preemptions > 0, \
        "six concurrent states in a 2-state pool must preempt"
    assert sum(w.preemption_count for w in sim.workers.values()) > 0


# -------------------------------------------- per-iteration interference

def test_interference_charged_once_per_iteration(cfg):
    m = _gamma_model(cfg, 0.5)
    w = Worker(0, m)
    slo = SLOSpec(ttft=60.0, tpot=10.0)
    decodes = [Request(rid=i, arrival_time=0.0, prompt_len=2048,
                       output_len=64, slo=slo) for i in range(3)]
    for r in decodes:
        r.generated_tokens = 1
        w.admit_decode(r, 0.0)
    rp = Request(rid=9, arrival_time=0.0, prompt_len=256, output_len=8,
                 slo=slo)
    rp.prefill_start = 0.0
    plan = IterationPlan(
        decode_reqs=list(decodes), prefill_parts=[(rp, 256)],
        n_decode=3, sum_ctx=float(sum(r.context_len for r in decodes)),
        prefill_tokens=256, prefill_ctx_offset=0.0, exclusive_prefill=False)
    pure = m.decode_iter_time(plan.n_decode, plan.sum_ctx)
    dur = pure + 0.25
    w.complete_iteration(plan, now=dur, duration=dur)
    # each blocked request's stream stalled the full interval (wall
    # blocking is concurrent) ...
    for r in decodes:
        assert w.blocked_time[r.rid] == pytest.approx(0.25)
    # ... but the worker-level machine-time counter sees it exactly ONCE
    assert w.interference_time == pytest.approx(0.25)
    w.complete_iteration(plan, now=2 * dur, duration=dur)
    assert w.interference_time == pytest.approx(0.5)
    for r in decodes:
        assert w.blocked_time[r.rid] == pytest.approx(0.5)


def test_pure_iterations_charge_no_interference(cfg, blind):
    w = Worker(0, blind)
    slo = SLOSpec(ttft=60.0, tpot=10.0)
    r = Request(rid=0, arrival_time=0.0, prompt_len=2048, output_len=64,
                slo=slo)
    r.generated_tokens = 1
    w.admit_decode(r, 0.0)
    plan = IterationPlan(decode_reqs=[r], prefill_parts=[], n_decode=1,
                         sum_ctx=float(r.context_len), prefill_tokens=0,
                         prefill_ctx_offset=0.0, exclusive_prefill=False)
    w.complete_iteration(plan, now=1.0,
                         duration=blind.decode_iter_time(1, plan.sum_ctx))
    assert w.interference_time == 0.0
    assert r.rid not in w.blocked_time
