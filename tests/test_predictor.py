"""§IV-C predictors: profiled interpolation bounds, safety-margin
consistency, and OnlinePredictor convergence under injected bias."""
import pytest

from repro.configs import get_config
from repro.core.predictor import (AnalyticalPredictor, BiasedPredictor,
                                  OnlinePredictor, ProfiledPredictor,
                                  profile_worker)
from repro.serving.costmodel import CostModel, WorkerSpec


@pytest.fixture(scope="module")
def cost():
    return CostModel(get_config("internlm-20b"), WorkerSpec(tp=8))


@pytest.fixture(scope="module")
def profiled(cost):
    return profile_worker(lambda nd, ctx, pt: cost.iteration_time(nd, ctx, pt))


# ----------------------------------------------------------------- profiled

def test_profiled_interpolation_stays_within_point_bounds(profiled):
    """Piecewise-linear interpolation between profiled points can never
    leave the bracketing points' value range (no overshoot)."""
    pts = profiled.prefill_points
    for (x0, y0), (x1, y1) in zip(pts, pts[1:]):
        for frac in (0.0, 0.25, 0.5, 0.75, 1.0):
            x = int(x0 + frac * (x1 - x0))
            got = profiled.predict_prefill(x) / profiled.safety
            assert min(y0, y1) - 1e-12 <= got <= max(y0, y1) + 1e-12, x
    dec = [(b, t) for b, t, _ in profiled.decode_points]
    for (b0, y0), (b1, y1) in zip(dec, dec[1:]):
        mid = (b0 + b1) // 2
        got = profiled.predict_decode_iter(mid, 0.0) / profiled.safety
        assert min(y0, y1) - 1e-12 <= got <= max(y0, y1) + 1e-12, mid


def test_profiled_predictions_monotone_in_tokens(profiled):
    xs = [128, 300, 512, 1200, 2048, 5000, 8192]
    ys = [profiled.predict_prefill(x) for x in xs]
    assert all(b >= a for a, b in zip(ys, ys[1:]))


def test_safety_margin_consistent_across_predictors(cost, profiled):
    """Both predictor families apply ``safety`` as the same multiplicative
    factor on every phase."""
    for s in (1.0, 1.3):
        ana = AnalyticalPredictor(cost, safety=s)
        assert ana.predict_prefill(2048) == \
            pytest.approx(cost.prefill_time(2048) * s)
        assert ana.predict_decode_iter(8, 4096.0) == \
            pytest.approx(cost.decode_iter_time(8, 4096.0) * s)
        assert ana.predict_migration(2048) == \
            pytest.approx(cost.migration_time(2048) * s)
    base = profiled.predict_prefill(512) / profiled.safety
    prof13 = ProfiledPredictor(profiled.prefill_points,
                               profiled.decode_points, profiled.ctx_coeff,
                               profiled.migration_coeff, safety=1.3)
    assert prof13.predict_prefill(512) == pytest.approx(base * 1.3)


# ------------------------------------------------------------------- online

@pytest.mark.parametrize("bias", [2.0, 0.5])
def test_online_predictor_converges_under_bias(cost, bias):
    pred = OnlinePredictor(BiasedPredictor(cost, bias))
    for _ in range(60):
        pred.observe_prefill(2048, 0, cost.prefill_time(2048))
        pred.observe_decode(16, 16 * 2048.0,
                            cost.decode_iter_time(16, 16 * 2048.0))
    # converged prediction == safety * truth (margin restored, bias gone)
    want_p = cost.prefill_time(2048) * 1.1
    want_d = cost.decode_iter_time(16, 16 * 2048.0) * 1.1
    assert pred.predict_prefill(2048) == pytest.approx(want_p, rel=0.1)
    assert pred.predict_decode_iter(16, 16 * 2048.0) == \
        pytest.approx(want_d, rel=0.1)
    assert pred.prefill_scale == pytest.approx(1.0 / bias, rel=0.1)


def test_online_predictor_unbiased_base_is_fixed_point(cost):
    pred = OnlinePredictor(AnalyticalPredictor(cost))
    for _ in range(40):
        pred.observe_prefill(1024, 0, cost.prefill_time(1024))
    assert pred.prefill_scale == pytest.approx(1.0, abs=1e-6)


def test_online_predictor_mixed_iteration_split(cost):
    """Hybrid decode+chunk iterations still feed both phases."""
    pred = OnlinePredictor(BiasedPredictor(cost, 2.0))
    n, ctx, toks = 8, 8 * 2048.0, 512
    true_iter = cost.iteration_time(n, ctx, toks)
    for _ in range(80):
        pred.observe_iteration(n, ctx, toks, 0.0, true_iter)
    assert pred.prefill_observations == pred.decode_observations == 80
    # corrected composite prediction lands near safety * truth
    got = pred.predict_prefill(toks) + pred.predict_decode_iter(n, ctx)
    assert got == pytest.approx(true_iter * 1.1, rel=0.25)


def test_online_predictor_clips_outliers(cost):
    pred = OnlinePredictor(AnalyticalPredictor(cost), alpha=1.0)
    pred.observe_prefill(1024, 0, cost.prefill_time(1024) * 1e6)
    assert pred.prefill_scale <= pred.clip[1]
    pred.observe_prefill(1024, 0, cost.prefill_time(1024) * 1e-6)
    assert pred.prefill_scale >= pred.clip[0]


def test_online_predictor_bucketed_corrects_size_dependent_bias(cost):
    """Heterogeneity: when the base's error differs by batch size (real
    profiles miss differently at batch 1 than 64), per-(phase, bucket)
    EWMAs converge to each bucket's own bias while the single global
    scale can only average them."""
    pred = OnlinePredictor(AnalyticalPredictor(cost), bucket_floor=8)
    # executor runs 2x slower than the cost model at batch 2, 2x faster
    # at batch 64; converged predictions land at safety x each truth
    for _ in range(60):
        pred.observe_decode(2, 2 * 512.0,
                            cost.decode_iter_time(2, 2 * 512.0) * 2.0)
        pred.observe_decode(64, 64 * 512.0,
                            cost.decode_iter_time(64, 64 * 512.0) * 0.5)
    want_small = cost.decode_iter_time(2, 2 * 512.0) * 2.0 * 1.1
    want_big = cost.decode_iter_time(64, 64 * 512.0) * 0.5 * 1.1
    assert pred.predict_decode_iter(2, 2 * 512.0) == \
        pytest.approx(want_small, rel=0.1)
    assert pred.predict_decode_iter(64, 64 * 512.0) == \
        pytest.approx(want_big, rel=0.1)
    # the global scale averaged the two regimes and fits neither
    assert pred.decode_scale == pytest.approx(1.25, rel=0.3)


def test_online_predictor_cold_bucket_falls_back_to_global(cost):
    """Below the sample floor a bucket borrows the global per-phase scale
    instead of acting on thin evidence."""
    pred = OnlinePredictor(BiasedPredictor(cost, 2.0), bucket_floor=10)
    for _ in range(40):
        pred.observe_prefill(2048, 0, cost.prefill_time(2048))
    # bucket for 2048 tokens is warm (40 >= 10): uses its own scale
    assert ("prefill", pred._bucket(2048)) in pred.bucket_scales
    # a different, never-observed bucket uses the global corrected scale
    cold = pred.predict_prefill(64)
    assert cold == pytest.approx(
        pred.base.predict_prefill(64) * pred.prefill_scale)
    assert pred.prefill_scale == pytest.approx(0.5, rel=0.1)
    # 9 observations in a fresh bucket still fall back; the 10th flips it
    pred2 = OnlinePredictor(BiasedPredictor(cost, 2.0), bucket_floor=10)
    for _ in range(9):
        pred2.observe_prefill(64, 0, cost.prefill_time(64))
    key = ("prefill", pred2._bucket(64))
    assert pred2.bucket_observations[key] == 9
    assert pred2.predict_prefill(64) == pytest.approx(
        pred2.base.predict_prefill(64) * pred2.prefill_scale)
    pred2.observe_prefill(64, 0, cost.prefill_time(64))
    assert pred2.predict_prefill(64) == pytest.approx(
        pred2.base.predict_prefill(64) * pred2.bucket_scales[key])


def test_online_predictor_unbucketed_opt_out(cost):
    pred = OnlinePredictor(AnalyticalPredictor(cost), bucketed=False)
    for _ in range(20):
        pred.observe_prefill(1024, 0, cost.prefill_time(1024))
    assert not pred.bucket_scales and not pred.bucket_observations


def test_online_predictor_ignores_degenerate_observations(cost):
    pred = OnlinePredictor(AnalyticalPredictor(cost))
    pred.observe_prefill(0, 0, 0.5)        # zero-token prediction
    pred.observe_decode(4, 4096.0, 0.0)    # zero observed time
    assert pred.prefill_observations == 0
    assert pred.decode_observations == 0
    assert pred.prefill_scale == 1.0 and pred.decode_scale == 1.0
