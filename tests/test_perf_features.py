"""Beyond-paper perf features: ring caches, fp8 KV, EP modes — correctness
guarantees behind the §Perf wins."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import transformer as T


def _decode_seq(cfg, params, toks, max_len=40):
    cache = T.init_cache(cfg, toks.shape[0], max_len)
    lengths = jnp.zeros((toks.shape[0],), jnp.int32)
    out = None
    for t in range(toks.shape[1]):
        out, cache = T.decode(params, cache, toks[:, t], lengths, cfg)
        lengths = lengths + 1
    return out


def test_window_ring_cache_exact():
    """Ring caches on local layers reproduce full-cache decode exactly,
    including after the ring wraps (seq 20 >> window 8)."""
    cfg = dataclasses.replace(get_smoke("gemma2-2b"), scan_layers=False)
    cfg_ring = dataclasses.replace(cfg, window_sized_cache=True)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 20), 0,
                              cfg.vocab_size)
    full = _decode_seq(cfg, params, toks)
    ring = _decode_seq(cfg_ring, params, toks)
    np.testing.assert_allclose(np.asarray(full), np.asarray(ring),
                               rtol=2e-5, atol=2e-5)
    # the ring actually IS smaller
    rc = T.init_cache(cfg_ring, 2, 40)
    assert any(c.shape[1] < 40 for c in rc["k"])


def test_fp8_kv_cache_close():
    """fp8 KV storage: decode stays close to the bf16/f32 reference (it is
    a capacity lever; tolerance is the e4m3 quantisation error)."""
    cfg = get_smoke("deepseek-7b")
    cfg8 = dataclasses.replace(cfg, kv_cache_quant=True)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    full = _decode_seq(cfg, params, toks)
    q = _decode_seq(cfg8, params, toks)
    # logits correlation must survive quantisation
    a = np.asarray(full, np.float32).ravel()
    b = np.asarray(q, np.float32).ravel()
    corr = np.corrcoef(a, b)[0, 1]
    assert corr > 0.98, corr
    cache = T.init_cache(cfg8, 2, 16)
    assert cache["k"].dtype == jnp.float8_e4m3fn


def test_ep_capacity_floor_semantics():
    from repro.models.moe import EPInfo
    info = EPInfo(mesh=None, ep_axes=(), batch_axes=(), capacity_floor=1)
    assert info.capacity_floor == 1
    info4 = EPInfo(mesh=None, ep_axes=(), batch_axes=())
    assert info4.capacity_floor == 4 and info4.ep_mode == "alltoall"
