"""Array-native engine bookkeeping: fast-vs-reference parity.

The incremental worker view (running aggregates + ``RequestColumns``
SoA reductions) and the batched completion effects exist purely as
optimisations — every derived value must be bit-for-bit identical to
the scalar reference after **every** event, or fixed-seed decision
streams diverge. These tests pin that contract at three layers:

* **checked runs** — wrap ``ClusterScheduler.handle_batch`` so that
  after every coalesced event batch, every worker's maintained view is
  compared field-for-field against ``Worker.view_reference()`` (the
  from-scratch recompute), across scenarios that exercise each event
  kind: plain multiplexing, watermark preemption, host-tier
  offload/restore, prefix-cache eviction, and worker ``fail()``;
* **end-to-end metrics equality** — fixed-seed runs asserting the full
  ``ServeMetrics`` row (and per-class rows, and the raw latency lists)
  match exactly between ``vectorized=True`` and the scalar reference,
  over single-class, 2-class-mixture, and hetero+online clusters, plus
  ``serve.py`` JSON rows with and without ``--reference``;
* **unit** — ``state_token_delta_sum`` against the scalar
  ``state_tokens`` recurrence for dense / windowed / constant-state
  families, and ``RequestColumns.rebuild`` ordering against live
  ``decode_running`` insertion order.

A decode-heavy scenario additionally asserts the vector completion path
(``_decode_effects_fast``) actually ran — guarding against the
``_VEC_MIN_BATCH`` shortcut silently turning the numpy paths into dead
code under test workloads.
"""
import copy
import dataclasses
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.common import (MODEL, WORKER, clone_trace, cost_model,
                               fixed_slo, make_trace)
from benchmarks.scale import ENGINE_HEAVY
from repro.configs import get_config
from repro.perf.hardware import V5E, WorkerSpec
from repro.serving.costmodel import CostModel
from repro.serving.engine import RequestColumns, _VEC_MIN_BATCH
from repro.serving.simulator import build_cluster
from repro.workload import get_scenario
from repro.workload.scenario import generate_trace


@pytest.fixture(scope="module")
def cost():
    return cost_model()


# ------------------------------------------------- checked-run view parity

def _checked_run(sim, until=None):
    """Run ``sim`` with the scheduler's ``handle_batch`` wrapped so every
    worker's maintained view is checked against ``view_reference()``
    after every event batch. Returns (metrics, max decode batch seen)."""
    sched = sim.sched
    inner = sched.handle_batch
    peak = [0]

    def checked(now, events):
        inner(now, events)
        for w in sim.workers.values():
            if not w.view.alive:
                continue
            # the view is refreshed lazily (page reservations inside an
            # iteration kick publish at the next refresh, identically in
            # both modes) — force one, then demand reference-exact values
            w._refresh_view()
            ref = w.view_reference()
            got = {k: getattr(w.view, k) for k in ref}
            assert got == ref, (
                f"worker {w.wid} view diverged at t={now} after "
                f"{[e[2] for e in events]}: "
                f"{ {k: (got[k], ref[k]) for k in ref if got[k] != ref[k]} }")
            peak[0] = max(peak[0], ref["decode_batch"])

    sched.handle_batch = checked
    try:
        m = sim.run(until=until)
    finally:
        sched.handle_batch = inner
    return m, peak[0]


def test_checked_run_baseline(cost):
    trace = make_trace(3.0, 20.0, cost, seed=5)
    sim, _ = build_cluster(get_config(MODEL), "tropical", n_workers=4,
                           worker_spec=WORKER, vectorized=True)
    sim.add_trace(clone_trace(trace))
    m, _ = _checked_run(sim)
    assert m.n_finished > 0


def _pressure_cluster(host_kv_gb, rate=6.0, **kw):
    """Halved-HBM cluster under agentic load: watermark preemption (and,
    with a host tier, offload/restore) fires within the run."""
    spec = dataclasses.replace(WorkerSpec(tp=8), hw=dataclasses.replace(
        WorkerSpec(tp=8).hw, hbm_bytes=WorkerSpec(tp=8).hw.hbm_bytes / 2))
    cfg = get_config("internlm-20b")
    cm = CostModel(cfg, spec)
    trace = get_scenario("agentic").generate(rate, 60.0, cm, seed=23)
    sim, _ = build_cluster(cfg, "tropical", n_workers=2, worker_spec=spec,
                           host_kv_gb=host_kv_gb, vectorized=True, **kw)
    sim.add_trace(copy.deepcopy(trace))
    return sim


def test_checked_run_watermark_preemption():
    sim = _pressure_cluster(host_kv_gb=0.0)
    m, _ = _checked_run(sim, until=400.0)
    assert m.preemptions > 0      # the event kind under test actually fired


def test_checked_run_offload_restore_prefix_and_fail():
    """Tiered KV + prefix cache + a mid-run worker failure: the view stays
    reference-exact through offload/restore effects, prefix insert/evict,
    and ``fail()``'s bulk teardown + recovery."""
    # prefix hits shed most of the KV pressure — push the rate up so the
    # host tier still has to absorb spills
    sim = _pressure_cluster(host_kv_gb=16.0, rate=14.0, prefix_cache=True)
    sim.inject_failure(20.0, 0, recover_after=10.0)
    m, _ = _checked_run(sim, until=800.0)
    assert m.kv_offloads > 0 and m.kv_restores > 0
    assert m.prefix_lookups > 0
    assert m.n_finished == m.n_total


def test_checked_run_decode_heavy_exercises_vector_paths(cost):
    """Long-output workload: decode batches exceed ``_VEC_MIN_BATCH`` so
    the numpy completion path and the SoA refresh branch genuinely run
    (otherwise the small-batch scalar shortcut would make every other
    parity test vacuous for the vector code)."""
    from repro.serving import engine as eng_mod

    trace = generate_trace(rate=24.0, duration=10.0, cost_model=cost,
                           seed=5, profile=ENGINE_HEAVY,
                           fixed_slo=fixed_slo(cost))
    sim, _ = build_cluster(get_config(MODEL), "tropical", n_workers=2,
                           worker_spec=WORKER, vectorized=True)
    sim.add_trace(copy.deepcopy(trace))

    calls = [0]
    inner_fast = eng_mod.Worker._decode_effects_fast

    def counting(self, *a, **kw):
        calls[0] += 1
        return inner_fast(self, *a, **kw)

    eng_mod.Worker._decode_effects_fast = counting
    try:
        m, peak = _checked_run(sim)
    finally:
        eng_mod.Worker._decode_effects_fast = inner_fast
    assert peak >= _VEC_MIN_BATCH, peak
    assert calls[0] > 0
    assert m.n_finished > 0


# -------------------------------------------- end-to-end metrics equality

def _metrics(policy, trace, vectorized, n_workers, **kw):
    sim, _ = build_cluster(get_config(MODEL), policy, n_workers=n_workers,
                           worker_spec=WORKER, vectorized=vectorized, **kw)
    sim.add_trace(clone_trace(trace))
    return sim.run()


def _assert_metrics_equal(policy, trace, n_workers=8, **kw):
    ma = _metrics(policy, trace, False, n_workers, **kw)
    mb = _metrics(policy, trace, True, n_workers, **kw)
    assert ma.row() == mb.row()
    assert ma.per_class_rows() == mb.per_class_rows()
    # the raw latency lists too: same finish order, same bits
    assert ma.ttfts == mb.ttfts
    assert ma.tpots == mb.tpots
    assert ma.queues == mb.queues


def test_metrics_equality_single_class(cost):
    _assert_metrics_equal("tropical", make_trace(2.5, 30.0, cost, seed=5))


def test_metrics_equality_mixture(cost):
    from repro.launch.serve import _classes_scenario, parse_slo_classes
    classes = parse_slo_classes(
        "interactive:scale=3,weight=2,frac=0.6;batch:scale=9,frac=0.4")
    trace = _classes_scenario(classes, cost).generate(2.0, 30.0, cost,
                                                      seed=7)
    _assert_metrics_equal("tropical", trace, n_workers=4)


def test_metrics_equality_hetero_online(cost):
    specs = [WORKER, WorkerSpec(tp=8, hw=V5E.slowed(1.7)),
             WORKER, WorkerSpec(tp=4)]
    trace = make_trace(2.0, 25.0, cost, seed=5)
    _assert_metrics_equal("tropical", trace, n_workers=4,
                          worker_specs=specs, online_predictor=True)


def test_serve_json_reference_flag_is_bit_identical():
    """The CLI contract: ``serve.py --json`` emits the same row with and
    without ``--reference`` (sim mode carries no wall-clock keys)."""
    from repro.launch import serve
    base = ["--duration", "15", "--rate", "4", "--workers", "2",
            "--seed", "3", "--prefix-cache", "--host-kv-gb", "8"]
    fast = serve.main(base)
    slow = serve.main(base + ["--reference"])
    assert fast == slow


# ------------------------------------------------------------------- unit

def _ctx_grid():
    return np.array([1, 2, 3, 100, 4095, 4096, 4097, 8192, 20000],
                    dtype=np.int64)


def _scalar_delta_sum(cm, ctx_new):
    return sum(cm.state_tokens(int(c)) - cm.state_tokens(int(c) - 1)
               for c in ctx_new)


def test_state_token_delta_sum_dense():
    cm = CostModel(get_config(MODEL), WorkerSpec(tp=8))
    ctx = _ctx_grid()
    assert cm.state_token_delta_sum(ctx) == _scalar_delta_sum(cm, ctx)
    assert cm.state_token_delta_sum(ctx) == float(ctx.size)


def test_state_token_delta_sum_windowed():
    cm = CostModel(get_config("gemma2-2b"), WorkerSpec(tp=8))
    assert cm.spec.ctx_cap is not None
    cap = cm.spec.ctx_cap
    ctx = np.array([1, cap - 1, cap, cap + 1, cap * 2], dtype=np.int64)
    got = cm.state_token_delta_sum(ctx)
    assert got == _scalar_delta_sum(cm, ctx)
    assert got == 3 * 1.0 + 2 * 0.5   # past the cap only half the layers grow


def test_state_token_delta_sum_constant_state():
    cm = CostModel(get_config("rwkv6-7b"), WorkerSpec(tp=8))
    assert cm.state_token_delta_sum(_ctx_grid()) == 0.0


def test_request_columns_rebuild_order(cost):
    """Rebuilt columns mirror ``decode_running``'s insertion order and the
    live request fields exactly — the property the vector completion path
    relies on to map masked rows back to requests."""
    trace = make_trace(4.0, 12.0, cost, seed=5)
    sim, _ = build_cluster(get_config(MODEL), "tropical", n_workers=2,
                           worker_spec=WORKER, vectorized=True)
    sim.add_trace(clone_trace(trace))
    sched = sim.sched
    inner = sched.handle_batch
    checked = [False]

    def probe(now, events):
        inner(now, events)
        for w in sim.workers.values():
            running = w.decode_running
            if len(running) < 3:
                continue
            cols = RequestColumns()     # scratch — never touches w._cols
            cols.rebuild(running, w.pages)
            assert cols.rids == list(running.keys())
            assert cols.reqs == list(running.values())
            for i, r in enumerate(running.values()):
                assert cols.ctx[i] == r.context_len
                assert cols.gen[i] == r.generated_tokens
                assert cols.rem_out[i] == r.remaining_output
                assert cols.decode_time[i] == r.decode_time
                assert cols.tpot_slack[i] == r.tpot_slack
                assert cols.tpot_slo[i] == r.slo.tpot
                assert cols.cached_prefix[i] == r.cached_prefix
                assert cols.pages_held[i] == w.pages.held_pages(r.rid)
            assert not cols.dirty
            checked[0] = True

    sched.handle_batch = probe
    try:
        sim.run()
    finally:
        sched.handle_batch = inner
    assert checked[0]


# --------------------------------------------- dirty-marking bypass hazard

def test_bypassed_view_write_is_caught_by_reference_divergence(cost):
    """The hazard repro-lint's ``soa`` pass forbids, demonstrated live: a
    write that bypasses ``WorkerView.__setattr__`` (no dirty mark) leaves
    the ViewColumns mirror stale, and ``view_reference()`` flags the
    divergence — the same check ``_checked_run`` applies after every
    event batch. A dirty-marked write through the view propagates."""
    trace = make_trace(3.0, 20.0, cost, seed=5)
    sim, _ = build_cluster(get_config(MODEL), "tropical", n_workers=4,
                           worker_spec=WORKER, vectorized=True)
    sim.add_trace(clone_trace(trace))
    sim.run(until=10.0)
    w = next(w for w in sim.workers.values() if w.view.alive)
    w._refresh_view()
    view, colstore = w.view, w.view._cols
    assert colstore is not None
    colstore.sync()
    row = view._row
    assert colstore.free_pages[row] == view.free_pages  # coherent at rest

    # the forbidden bypass: no dirty mark, mirror goes stale silently
    object.__setattr__(view, "free_pages", view.free_pages + 7)
    assert row not in colstore.dirty
    assert colstore.free_pages[row] != view.free_pages  # mirror is stale
    got = {k: getattr(view, k) for k in w.view_reference()}
    assert got != w.view_reference()    # the parity harness catches it

    # the sanctioned path: plain attribute write marks the row dirty and
    # sync() restores mirror coherence
    view.free_pages = view.free_pages - 7
    assert row in colstore.dirty
    colstore.sync()
    assert colstore.free_pages[row] == view.free_pages
    assert {k: getattr(view, k)
            for k in w.view_reference()} == w.view_reference()
