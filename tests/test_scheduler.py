"""Scheduler unit + property tests: slack accounting, toggle admission,
policy invariants."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.metrics import compute_metrics, derive_slos
from repro.core.predictor import AnalyticalPredictor, profile_worker
from repro.core.request import Phase, Request, SLOSpec
from repro.core.toggle import (MultiplexingToggle, Role, ToggleConfig,
                               WorkerView)
from repro.serving.costmodel import CostModel, WorkerSpec


@pytest.fixture(scope="module")
def cost():
    return CostModel(get_config("internlm-20b"), WorkerSpec(tp=8))


def _req(rid=0, arrival=0.0, prompt=4096, out=128,
         slo=SLOSpec(ttft=2.0, tpot=0.05)):
    return Request(rid=rid, arrival_time=arrival, prompt_len=prompt,
                   output_len=out, slo=slo)


# ------------------------------------------------------------------ slack

def test_slack_accumulates_and_burns():
    r = _req()
    r.record_first_token(1.0)
    assert r.tpot_slack == pytest.approx(r.slo.tpot)   # initial credit
    r.record_decode_iteration(0.01)                     # fast: banks slack
    assert r.tpot_slack == pytest.approx(r.slo.tpot + 0.04)
    r.record_decode_iteration(0.30)                     # chunk insertion
    assert r.tpot_slack == pytest.approx(r.slo.tpot + 0.04 - 0.25)


def test_effective_slack_forward_credit_bounded():
    r = _req(out=1000)
    r.record_first_token(0.0)
    e4 = r.effective_slack(base_iter=0.01, horizon=4)
    e8 = r.effective_slack(base_iter=0.01, horizon=8)
    assert e8 > e4 > r.tpot_slack
    # nearly-finished request gets little forward credit
    r.generated_tokens = 999
    assert r.effective_slack(0.01, horizon=8) <= r.tpot_slack + 0.04 + 1e-9


@given(
    iters=st.lists(st.floats(0.001, 0.2), min_size=2, max_size=60),
    slo_tpot=st.floats(0.02, 0.2),
)
@settings(max_examples=60, deadline=None)
def test_property_tpot_slo_iff_nonnegative_terminal_slack(iters, slo_tpot):
    """Invariant: final TPOT <= SLO  <=>  banked slack stayed >= 0 at the
    end (slack is exactly the integrated SLO margin)."""
    r = _req(out=len(iters) + 1, slo=SLOSpec(ttft=1.0, tpot=slo_tpot))
    r.record_first_token(0.0)
    t = 0.0
    for d in iters:
        t += d
        r.record_decode_iteration(d)
    r.finish_time = t
    r.phase = Phase.FINISHED
    # terminal banked slack (minus the initial credit) == (SLO - tpot)*n
    n = r.generated_tokens - 1
    assert r.tpot_slack - slo_tpot == pytest.approx(
        (slo_tpot - r.tpot()) * n, rel=1e-6, abs=1e-7)
    # equivalence holds away from the knife edge (at tpot == SLO exactly,
    # float summation order decides the two accountings independently)
    if abs(r.tpot() - slo_tpot) > 1e-9:
        assert r.tpot_ok() == (r.tpot_slack - slo_tpot >= 0.0)


# ------------------------------------------------------------------ toggle

def _views(n_p=1, n_m=1, cap=100000.0):
    views = []
    for i in range(n_p + n_m):
        views.append(WorkerView(
            wid=i, role=Role.PREFILL if i < n_p else Role.MULTIPLEX,
            kv_capacity_tokens=cap))
    return views


def test_toggle_path2_requires_slack(cost):
    views = _views()
    toggle = MultiplexingToggle(views, AnalyticalPredictor(cost),
                                ToggleConfig(role_transitions=False))
    m = views[1]
    m.decode_batch = 8
    m.decode_sum_ctx = 8 * 4096.0
    req = _req(prompt=2048)
    m.min_tpot_slack = 0.0
    assert not toggle._multiplex_ok(m, req)
    m.min_tpot_slack = 10.0
    assert toggle._multiplex_ok(m, req)


def test_toggle_hbm_watermark_blocks_path2(cost):
    views = _views()
    toggle = MultiplexingToggle(views, AnalyticalPredictor(cost))
    m = views[1]
    m.min_tpot_slack = 100.0
    m.kv_used_tokens = 0.95 * m.kv_capacity_tokens
    assert not toggle._multiplex_ok(m, _req())


def test_toggle_role_transition_on_hbm_pressure(cost):
    views = _views(n_p=2, n_m=2)
    toggle = MultiplexingToggle(views, AnalyticalPredictor(cost))
    for v in views[2:]:
        v.kv_used_tokens = 0.95 * v.kv_capacity_tokens
    toggle.review_roles(now=0.0)
    roles = [v.role for v in views]
    assert roles.count(Role.MULTIPLEX) == 3   # one P converted


def test_toggle_dispatch_prefers_lower_predicted_ttft(cost):
    views = _views(n_p=2, n_m=1)
    views[0].queued_prefill_tokens = 200_000   # deep queue
    toggle = MultiplexingToggle(views, AnalyticalPredictor(cost),
                                ToggleConfig(role_transitions=False))
    req = _req(prompt=4096, slo=derive_slos(cost, 8192))
    wid = toggle.dispatch_prefill(req, now=0.0)
    assert wid == 1   # empty P worker beats queued one


def test_toggle_worker_failure_excluded(cost):
    views = _views(n_p=1, n_m=1)
    toggle = MultiplexingToggle(views, AnalyticalPredictor(cost),
                                ToggleConfig(role_transitions=False))
    toggle.on_worker_failure(0)
    wid = toggle.dispatch_prefill(_req(slo=derive_slos(cost, 8192)), 0.0)
    assert wid == 1


# --------------------------------------------------------------- predictor

def test_profiled_predictor_tracks_cost_model(cost):
    pred = profile_worker(
        lambda nd, ctx, pt: cost.iteration_time(nd, ctx, pt))
    for tokens in (256, 1024, 4096):
        got = pred.predict_prefill(tokens)
        want = cost.prefill_time(tokens)
        assert got == pytest.approx(want * pred.safety, rel=0.35), tokens


def test_metrics_attainment_definition():
    reqs = []
    for i in range(10):
        r = _req(rid=i, slo=SLOSpec(ttft=1.0, tpot=0.05))
        r.record_first_token(0.5 if i < 7 else 2.0)   # 3 TTFT violations
        for _ in range(9):
            r.record_decode_iteration(0.04 if i % 2 == 0 else 0.06)
        r.finish_time = 5.0
        r.phase = Phase.FINISHED
        reqs.append(r)
    m = compute_metrics(reqs)
    assert m.ttft_attainment == pytest.approx(0.7)
    assert m.tpot_attainment == pytest.approx(0.5)
    # Eq. 3: intersection
    assert m.slo_attainment == pytest.approx(
        sum(1 for r in reqs if r.ttft_ok() and r.tpot_ok()) / 10)
