"""WKV6 chunked Pallas kernel vs the sequential-scan oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.wkv6 import wkv6_chunked
from repro.models.rwkv6 import wkv_scan


def _mk(rng, b, t, h, d, dtype=jnp.float32, state_scale=0.1):
    r = jnp.asarray(rng.normal(size=(b, t, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, t, h, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, t, h, d)), dtype)
    dlog = rng.normal(size=(b, t, h, d)) * 2 - 4
    w = jnp.exp(-jnp.exp(jnp.clip(jnp.asarray(dlog, jnp.float32),
                                  -20.0, 0.5))).astype(dtype)
    u = jnp.asarray(rng.normal(size=(h, d)) * 0.5, jnp.float32)
    s0 = jnp.asarray(rng.normal(size=(b, h, d, d)) * state_scale,
                     jnp.float32)
    return r, k, v, w, u, s0


SWEEP = [
    (1, 64, 1, 64, 64, jnp.float32),
    (2, 256, 3, 64, 64, jnp.float32),
    (2, 128, 2, 64, 32, jnp.float32),    # chunk 32
    (1, 128, 2, 64, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("b,t,h,d,chunk,dtype", SWEEP)
def test_wkv6_kernel_sweep(b, t, h, d, chunk, dtype):
    rng = np.random.default_rng(7)
    r, k, v, w, u, s0 = _mk(rng, b, t, h, d, dtype)
    o_ref, s_ref = wkv_scan(r, k, v, w, u, s0)
    o, sT = wkv6_chunked(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(s_ref),
                               rtol=3e-4, atol=3e-3)


@settings(max_examples=8, deadline=None)
@given(b=st.integers(1, 2), h=st.integers(1, 2),
       n_chunks=st.integers(1, 4), data=st.data())
def test_wkv6_kernel_property(b, h, n_chunks, data):
    """Property: chunked kernel == sequential scan for random decay
    trajectories, any chunk count (state carried correctly across chunks)."""
    d, chunk = 64, 64
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    r, k, v, w, u, s0 = _mk(rng, b, n_chunks * chunk, h, d)
    o_ref, s_ref = wkv_scan(r, k, v, w, u, s0)
    o, sT = wkv6_chunked(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(s_ref),
                               rtol=3e-4, atol=3e-3)


def test_wkv6_zero_state_first_token_is_bonus_only():
    """t=0 output must be r·(u ⊙ k v^T) when s0 = 0 (recurrence base case)."""
    rng = np.random.default_rng(1)
    r, k, v, w, u, _ = _mk(rng, 1, 64, 1, 64)
    s0 = jnp.zeros((1, 1, 64, 64), jnp.float32)
    o, _ = wkv6_chunked(r, k, v, w, u, s0, interpret=True)
    want = (jnp.sum(r[0, 0, 0] * u[0] * k[0, 0, 0])) * v[0, 0, 0]
    np.testing.assert_allclose(np.asarray(o[0, 0, 0]), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
