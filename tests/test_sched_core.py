"""Unified scheduling core: backend parity (simulator vs real JAX through
one ClusterScheduler), online predictor feedback, role rebalancing."""
import copy

import pytest

from repro.configs import get_config, get_smoke
from repro.core.predictor import (AnalyticalPredictor, BiasedPredictor,
                                  OnlinePredictor)
from repro.core.request import Phase, Request, SLOSpec
from repro.core.toggle import Role, WorkerView
from repro.sched import (ClusterScheduler, CostModelBackend, RebalanceConfig,
                         RoleRebalancer)
from repro.serving.costmodel import CostModel, WorkerSpec
from repro.serving.simulator import Simulator, build_cluster
from repro.serving.trace import generate_trace


def _smoke_trace(n=6, prompt=24, out=5):
    slo = SLOSpec(ttft=30.0, tpot=5.0)
    return [Request(rid=i, arrival_time=0.05 * i, prompt_len=prompt,
                    output_len=out, slo=slo) for i in range(n)]


def _smoke_trace_2class(n=8, prompt=24, out=5):
    """Alternating tight/loose SLO classes — exercises the class-aware
    (tightest-relative-slack-first) dispatch ordering."""
    tight = SLOSpec(ttft=3.0, tpot=1.0, name="interactive", weight=2.0)
    loose = SLOSpec(ttft=60.0, tpot=10.0, name="batch")
    return [Request(rid=i, arrival_time=0.05 * i, prompt_len=prompt,
                    output_len=out, slo=loose if i % 2 else tight)
            for i in range(n)]


# ------------------------------------------------------------ backend parity

@pytest.mark.parametrize("policy", ["tropical", "distserve"])
def test_sim_and_real_backend_make_identical_decisions(policy):
    """The acceptance guarantee of the sched/ refactor: the discrete-event
    simulator and the real-JAX executor drive the *same* ClusterScheduler
    code path. With the real backend running under the cost-model clock
    (identical durations), every dispatch target, batch composition and
    decode route must be bit-identical."""
    from repro.serving.executor import ClusterRealExecutors

    cfg = get_smoke("deepseek-7b")
    spec = WorkerSpec(tp=1)
    trace = _smoke_trace()

    sim_a, _ = build_cluster(cfg, policy, n_workers=2, worker_spec=spec,
                             record_decisions=True)
    sim_a.add_trace(copy.deepcopy(trace))
    m_a = sim_a.run(until=3000.0)

    execs = ClusterRealExecutors(cfg, 2, max_slots=8, max_len=64)
    sim_b, _ = build_cluster(cfg, policy, n_workers=2, worker_spec=spec,
                             record_decisions=True,
                             backend=execs.as_backend(clock="model"))
    sim_b.add_trace(copy.deepcopy(trace))
    m_b = sim_b.run(until=3000.0)

    assert m_a.n_finished == m_b.n_finished == len(trace)
    assert sim_a.decisions, "decision log must be non-trivial"
    assert sim_a.decisions == sim_b.decisions
    kinds = {d[0] for d in sim_a.decisions}
    assert {"dispatch", "iter", "route"} <= kinds
    # the real backend actually generated tokens while agreeing on decisions
    for r in trace:
        gen = [e.generated[r.rid] for e in execs.execs.values()
               if r.rid in e.generated]
        assert gen and max(len(g) for g in gen) >= r.output_len


def test_sim_and_real_backend_parity_with_two_slo_classes():
    """Multi-tenant decision parity: the class-aware ordering (tightest
    relative slack first across heterogeneous classes) is itself part of
    the one scheduling code path — sim and real backends must agree on it
    too, and per-class metrics must match."""
    from repro.serving.executor import ClusterRealExecutors

    cfg = get_smoke("deepseek-7b")
    spec = WorkerSpec(tp=1)
    trace = _smoke_trace_2class()

    sim_a, _ = build_cluster(cfg, "tropical", n_workers=2, worker_spec=spec,
                             record_decisions=True)
    sim_a.add_trace(copy.deepcopy(trace))
    m_a = sim_a.run(until=3000.0)

    execs = ClusterRealExecutors(cfg, 2, max_slots=8, max_len=64)
    sim_b, _ = build_cluster(cfg, "tropical", n_workers=2, worker_spec=spec,
                             record_decisions=True,
                             backend=execs.as_backend(clock="model"))
    sim_b.add_trace(copy.deepcopy(trace))
    m_b = sim_b.run(until=3000.0)

    assert m_a.n_finished == m_b.n_finished == len(trace)
    assert sim_a.decisions == sim_b.decisions
    assert set(m_a.per_class) == set(m_b.per_class) \
        == {"interactive", "batch"}
    for name in m_a.per_class:
        assert m_a.per_class[name].slo_attainment == \
            m_b.per_class[name].slo_attainment


def test_sim_and_real_backend_parity_on_heterogeneous_cluster():
    """Per-worker hardware is part of the one scheduling code path too:
    with a 2x-slow straggler (mixed HardwareSpecs, per-worker analytic
    predictor, speed-normalised load) the simulator and the real-JAX
    executor under the cost-model clock must still agree on every
    dispatch, batch composition and route."""
    from repro.perf import V5E
    from repro.serving.executor import ClusterRealExecutors

    cfg = get_smoke("deepseek-7b")
    fast = WorkerSpec(tp=1)
    slow = WorkerSpec(tp=1, hw=V5E.slowed(2.0))
    specs = [fast, slow]
    trace = _smoke_trace()

    sim_a, _ = build_cluster(cfg, "tropical", n_workers=2, worker_spec=fast,
                             worker_specs=specs, record_decisions=True)
    sim_a.add_trace(copy.deepcopy(trace))
    m_a = sim_a.run(until=3000.0)

    execs = ClusterRealExecutors(cfg, 2, max_slots=8, max_len=64)
    sim_b, _ = build_cluster(cfg, "tropical", n_workers=2, worker_spec=fast,
                             worker_specs=specs, record_decisions=True,
                             backend=execs.as_backend(clock="model"))
    sim_b.add_trace(copy.deepcopy(trace))
    m_b = sim_b.run(until=3000.0)

    assert m_a.n_finished == m_b.n_finished == len(trace)
    assert sim_a.decisions == sim_b.decisions
    # both stacks really saw the straggler: its speed is threaded through
    for sim in (sim_a, sim_b):
        assert sim.workers[1].view.speed < 1.0
        assert sim.workers[0].view.speed == 1.0


def test_slack_discipline_orders_multiclass_tightest_first():
    """Unit view of the class-aware queue: heterogeneous classes order by
    relative TTFT slack; a homogeneous queue keeps exact FCFS admission
    order (single-class decision parity with the paper's discipline)."""
    from repro.serving.engine import Worker

    cfg = get_config("internlm-20b")
    cost = CostModel(cfg, WorkerSpec(tp=8))
    w = Worker(0, cost, queue_discipline="slack")
    tight = SLOSpec(ttft=2.0, tpot=0.1, name="interactive")
    loose = SLOSpec(ttft=40.0, tpot=1.0, name="batch")
    a = Request(rid=0, arrival_time=0.0, prompt_len=64, output_len=4,
                slo=loose)
    b = Request(rid=1, arrival_time=0.5, prompt_len=64, output_len=4,
                slo=tight)
    w.admit_prefill(a, 0.0)
    w.admit_prefill(b, 0.5)
    # at t=1.0 the tight request has burnt 25% of budget, the loose 2.5%:
    # the tight one overtakes the earlier loose arrival
    assert [r.rid for r in w._prefill_order(1.0)] == [1, 0]
    assert w.peek_prefill(1.0).rid == 1
    # homogeneous queue (same class): exact admission order
    w2 = Worker(1, cost, queue_discipline="slack")
    for i, arr in enumerate((0.0, 0.5)):
        w2.admit_prefill(Request(rid=i, arrival_time=arr, prompt_len=64,
                                 output_len=4, slo=loose), arr)
    assert [r.rid for r in w2._prefill_order(1.0)] == [0, 1]


def test_simulator_is_a_thin_driver():
    """No scheduling logic may live in the Simulator: it owns the heap and
    the clock, the ClusterScheduler owns every decision."""
    for fossil in ("_kick", "_route_decode", "_try_dispatch", "_on_iter_done",
                   "_on_migration_done", "_on_fail"):
        assert not hasattr(Simulator, fossil), fossil
    cfg = get_config("internlm-20b")
    sim, _ = build_cluster(cfg, "tropical", n_workers=2,
                           worker_spec=WorkerSpec(tp=8))
    assert isinstance(sim.sched, ClusterScheduler)
    assert isinstance(sim.sched.backend, CostModelBackend)


def test_legacy_simulator_ctor_and_duration_fn_shims():
    """Pre-refactor entry points keep working: positional (workers, policy)
    construction and the settable ``duration_fn`` hook."""
    from repro.core.policies import make_policy
    from repro.serving.engine import Worker

    cfg = get_config("internlm-20b")
    cost = CostModel(cfg, WorkerSpec(tp=8))
    workers = [Worker(i, cost) for i in range(2)]
    policy = make_policy("sarathi", [w.view for w in workers],
                         AnalyticalPredictor(cost))
    sim = Simulator(workers, policy)
    calls = []

    def spy_fn(worker, plan):
        calls.append(worker.wid)
        return worker.plan_duration(plan)

    sim.duration_fn = spy_fn
    trace = generate_trace(1.0, 20.0, cost, seed=4)
    sim.add_trace(trace)
    m = sim.run(until=2000.0)
    assert m.n_finished == m.n_total == len(trace)
    assert calls, "custom duration_fn must supply the clock"


# ----------------------------------------------------- online predictor loop

def test_scheduler_feeds_online_predictor_and_corrects_bias():
    cfg = get_config("internlm-20b")
    cost = CostModel(cfg, WorkerSpec(tp=8))
    pred = OnlinePredictor(BiasedPredictor(cost, 2.0))
    sim, _ = build_cluster(cfg, "tropical", n_workers=2,
                           worker_spec=WorkerSpec(tp=8), predictor=pred)
    sim.add_trace(generate_trace(1.0, 60.0, cost, seed=7))
    m = sim.run(until=4000.0)
    assert m.n_finished == m.n_total
    assert pred.prefill_observations > 0 and pred.decode_observations > 0
    # the 2x overestimate must be substantially corrected toward 0.5
    assert pred.prefill_scale < 0.7
    assert pred.decode_scale < 0.7


def test_online_predictor_unbiased_base_keeps_margin():
    cfg = get_config("internlm-20b")
    cost = CostModel(cfg, WorkerSpec(tp=8))
    pred = OnlinePredictor(AnalyticalPredictor(cost))
    sim, _ = build_cluster(cfg, "tropical", n_workers=2,
                           worker_spec=WorkerSpec(tp=8), predictor=pred)
    sim.add_trace(generate_trace(1.0, 60.0, cost, seed=7))
    sim.run(until=4000.0)
    # exact executor => scales hover at 1.0 (safety margin preserved)
    assert pred.prefill_scale == pytest.approx(1.0, abs=0.15)
    assert pred.decode_scale == pytest.approx(1.0, abs=0.15)


# --------------------------------------------------------- role rebalancing

def _views(roles):
    return {i: WorkerView(wid=i, role=r, kv_capacity_tokens=100000.0)
            for i, r in enumerate(roles)}


def test_rebalancer_promotes_multiplexer_on_ttft_window():
    rb = RoleRebalancer(RebalanceConfig(min_samples=8))
    views = _views([Role.PREFILL, Role.MULTIPLEX, Role.MULTIPLEX])
    views[1].decode_batch = 4
    views[2].decode_batch = 1           # least decode-committed -> flips
    for ok in [False] * 12:
        rb.ttft_window.append(ok)
    for ok in [True] * 12:
        rb.tpot_window.append(ok)
    action = rb.step(views, now=100.0)
    assert action is not None and "ttft-window" in action
    assert views[2].role == Role.PREFILL
    assert views[1].role == Role.MULTIPLEX


def test_rebalancer_demotes_prefill_on_tpot_window():
    rb = RoleRebalancer(RebalanceConfig(min_samples=8))
    views = _views([Role.PREFILL, Role.PREFILL, Role.MULTIPLEX])
    views[0].queued_prefill_tokens = 10
    views[1].queued_prefill_tokens = 5000
    for ok in [True] * 12:
        rb.ttft_window.append(ok)
    for ok in [False] * 12:
        rb.tpot_window.append(ok)
    action = rb.step(views, now=100.0)
    assert action is not None and "tpot-window" in action
    assert views[0].role == Role.MULTIPLEX       # least-queued P converts


def test_rebalancer_hbm_pressure_rule_and_cooldown():
    rb = RoleRebalancer(RebalanceConfig(min_samples=8, cooldown=50.0))
    views = _views([Role.PREFILL, Role.MULTIPLEX])
    views[1].kv_used_tokens = 0.95 * views[1].kv_capacity_tokens
    action = rb.step(views, now=0.0)
    assert action is not None and "hbm-pressure" in action
    assert views[0].role == Role.MULTIPLEX
    # windowed actions respect the cooldown that change started
    views2 = _views([Role.PREFILL, Role.MULTIPLEX, Role.MULTIPLEX])
    for ok in [False] * 12:
        rb.ttft_window.append(ok)
    for ok in [True] * 12:
        rb.tpot_window.append(ok)
    assert rb.step(views2, now=10.0) is None      # inside cooldown
    assert rb.step(views2, now=100.0) is not None  # after cooldown


def test_rebalancer_needs_evidence():
    rb = RoleRebalancer(RebalanceConfig(min_samples=8))
    views = _views([Role.PREFILL, Role.MULTIPLEX, Role.MULTIPLEX])
    rb.ttft_window.extend([False] * 3)            # too thin
    assert rb.step(views, now=100.0) is None
    assert views[0].role == Role.PREFILL


def test_rebalancer_worst_class_governs_not_aggregate():
    """A starving tight class must trigger a role move even when the
    aggregate (dominated by an over-served batch class) looks healthy."""
    rb = RoleRebalancer(RebalanceConfig(min_samples=8))
    views = _views([Role.PREFILL, Role.MULTIPLEX, Role.MULTIPLEX])
    tight = SLOSpec(ttft=1.0, tpot=0.1, name="interactive")
    loose = SLOSpec(ttft=100.0, tpot=10.0, name="batch")

    def _outcome(slo, ttft_ok):
        r = Request(rid=0, arrival_time=0.0, prompt_len=8, output_len=4,
                    slo=slo)
        r.first_token_time = (0.5 if ttft_ok else 2.0) * slo.ttft
        r.finish_time = r.first_token_time      # 1-token finish: tpot 0.0
        return r

    # 30 batch successes drown 10 interactive failures in the aggregate
    # (75% overall > 0.9 target would still fail, so use 90+%): 60 batch
    # OK + 10 interactive KO -> aggregate 86% but per-class worst = 0%
    for _ in range(60):
        rb.record_first_token(_outcome(loose, True))
    for _ in range(10):
        rb.record_first_token(_outcome(tight, False))
    for _ in range(20):
        rb.record_finish(_outcome(loose, True))     # tpot healthy
    assert rb._worst_attainment(rb.ttft_windows) == 0.0
    action = rb.step(views, now=100.0)
    assert action is not None and "ttft-window" in action


def test_rebalancer_proportional_moves_with_cap():
    """max_move_frac > 0: ceil(deficit x convertible) workers flip in one
    review, capped at ceil(frac x alive) — the 100+-worker scaling mode."""
    rb = RoleRebalancer(RebalanceConfig(
        min_samples=8, max_move_frac=0.25, confirm_windows=1))
    # 2 P + 10 M, decode healthy, TTFT at 45% of the 90% target
    views = _views([Role.PREFILL] * 2 + [Role.MULTIPLEX] * 10)
    for ok in ([False] * 11 + [True] * 9):      # attainment 0.45
        rb.ttft_window.append(ok)
    for ok in [True] * 12:
        rb.tpot_window.append(ok)
    action = rb.step(views, now=100.0)
    assert action is not None and "ttft-window" in action
    moved = sum(1 for v in views.values() if v.role == Role.PREFILL) - 2
    # deficit = (0.9-0.45)/0.9 = 0.5 -> want ceil(0.5*10)=5, but the
    # per-review cap is ceil(0.25*12)=3
    assert moved == 3
    assert len(rb.transitions) == 3


def test_rebalancer_hysteresis_needs_consecutive_breaches():
    """confirm_windows=2: one bad window never reconfigures; two
    consecutive do; a healthy review in between resets the streak."""
    cfg = RebalanceConfig(min_samples=8, confirm_windows=2, cooldown=0.0)
    rb = RoleRebalancer(cfg)
    views = _views([Role.PREFILL, Role.MULTIPLEX, Role.MULTIPLEX])

    def _set(window, oks):
        window.clear()
        window.extend(oks)

    _set(rb.ttft_window, [False] * 12)
    _set(rb.tpot_window, [True] * 12)
    assert rb.step(views, now=10.0) is None        # first breach: wait
    _set(rb.ttft_window, [True] * 12)              # recovery resets streak
    assert rb.step(views, now=20.0) is None
    _set(rb.ttft_window, [False] * 12)
    assert rb.step(views, now=30.0) is None        # breach #1 again
    assert rb.step(views, now=40.0) is not None    # breach #2: act


def test_cluster_run_drives_windowed_rebalancer():
    """End-to-end: build_cluster('tropical') wires the rebalancer, the
    scheduler feeds it outcome windows, and the toggle's dispatch-count
    review is retired."""
    cfg = get_config("internlm-20b")
    sim, cost = build_cluster(cfg, "tropical", n_workers=4,
                              worker_spec=WorkerSpec(tp=8))
    assert sim.sched.rebalancer is not None
    assert sim.policy.toggle.cfg.role_transitions is False
    sim.add_trace(generate_trace(2.0, 60.0, cost, seed=5))
    m = sim.run(until=4000.0)
    assert m.n_finished == m.n_total
    rb = sim.sched.rebalancer
    assert len(rb.ttft_window) > 0 and len(rb.tpot_window) > 0

    # opting out restores the legacy dispatch-time review
    sim2, _ = build_cluster(cfg, "tropical", n_workers=4,
                            worker_spec=WorkerSpec(tp=8),
                            role_rebalance=False)
    assert sim2.sched.rebalancer is None
    assert sim2.policy.toggle.cfg.role_transitions is True


def test_force_rebalance_without_role_lifecycle_is_an_error():
    cfg = get_config("internlm-20b")
    with pytest.raises(ValueError, match="role_rebalance"):
        build_cluster(cfg, "distserve", n_workers=2,
                      worker_spec=WorkerSpec(tp=8), role_rebalance=True)


def test_unbounded_run_terminates_when_no_progress_is_possible():
    """Regression: the rebalance review must not re-arm itself forever
    over queued-but-stuck work — ``run()`` without ``until`` has to drain
    the heap and return, exactly like the pre-sched/ simulator."""
    cfg = get_config("internlm-20b")
    sim, cost = build_cluster(cfg, "tropical", n_workers=2,
                              worker_spec=WorkerSpec(tp=8))
    sim.inject_failure(0.0, 0)
    sim.inject_failure(0.0, 1)          # whole cluster dead, no recovery
    trace = generate_trace(1.0, 10.0, cost, seed=3)
    sim.add_trace(trace)
    m = sim.run()                       # unbounded: must still terminate
    assert m.n_finished == 0
    assert len(sim.global_queue) == len(trace)
