"""Unified scheduling core: backend parity (simulator vs real JAX through
one ClusterScheduler), online predictor feedback, role rebalancing."""
import copy

import pytest

from repro.configs import get_config, get_smoke
from repro.core.predictor import (AnalyticalPredictor, BiasedPredictor,
                                  OnlinePredictor)
from repro.core.request import Phase, Request, SLOSpec
from repro.core.toggle import Role, WorkerView
from repro.sched import (ClusterScheduler, CostModelBackend, RebalanceConfig,
                         RoleRebalancer)
from repro.serving.costmodel import CostModel, WorkerSpec
from repro.serving.simulator import Simulator, build_cluster
from repro.serving.trace import generate_trace


def _smoke_trace(n=6, prompt=24, out=5):
    slo = SLOSpec(ttft=30.0, tpot=5.0)
    return [Request(rid=i, arrival_time=0.05 * i, prompt_len=prompt,
                    output_len=out, slo=slo) for i in range(n)]


# ------------------------------------------------------------ backend parity

@pytest.mark.parametrize("policy", ["tropical", "distserve"])
def test_sim_and_real_backend_make_identical_decisions(policy):
    """The acceptance guarantee of the sched/ refactor: the discrete-event
    simulator and the real-JAX executor drive the *same* ClusterScheduler
    code path. With the real backend running under the cost-model clock
    (identical durations), every dispatch target, batch composition and
    decode route must be bit-identical."""
    from repro.serving.executor import ClusterRealExecutors

    cfg = get_smoke("deepseek-7b")
    spec = WorkerSpec(tp=1)
    trace = _smoke_trace()

    sim_a, _ = build_cluster(cfg, policy, n_workers=2, worker_spec=spec,
                             record_decisions=True)
    sim_a.add_trace(copy.deepcopy(trace))
    m_a = sim_a.run(until=3000.0)

    execs = ClusterRealExecutors(cfg, 2, max_slots=8, max_len=64)
    sim_b, _ = build_cluster(cfg, policy, n_workers=2, worker_spec=spec,
                             record_decisions=True,
                             backend=execs.as_backend(clock="model"))
    sim_b.add_trace(copy.deepcopy(trace))
    m_b = sim_b.run(until=3000.0)

    assert m_a.n_finished == m_b.n_finished == len(trace)
    assert sim_a.decisions, "decision log must be non-trivial"
    assert sim_a.decisions == sim_b.decisions
    kinds = {d[0] for d in sim_a.decisions}
    assert {"dispatch", "iter", "route"} <= kinds
    # the real backend actually generated tokens while agreeing on decisions
    for r in trace:
        gen = [e.generated[r.rid] for e in execs.execs.values()
               if r.rid in e.generated]
        assert gen and max(len(g) for g in gen) >= r.output_len


def test_simulator_is_a_thin_driver():
    """No scheduling logic may live in the Simulator: it owns the heap and
    the clock, the ClusterScheduler owns every decision."""
    for fossil in ("_kick", "_route_decode", "_try_dispatch", "_on_iter_done",
                   "_on_migration_done", "_on_fail"):
        assert not hasattr(Simulator, fossil), fossil
    cfg = get_config("internlm-20b")
    sim, _ = build_cluster(cfg, "tropical", n_workers=2,
                           worker_spec=WorkerSpec(tp=8))
    assert isinstance(sim.sched, ClusterScheduler)
    assert isinstance(sim.sched.backend, CostModelBackend)


def test_legacy_simulator_ctor_and_duration_fn_shims():
    """Pre-refactor entry points keep working: positional (workers, policy)
    construction and the settable ``duration_fn`` hook."""
    from repro.core.policies import make_policy
    from repro.serving.engine import Worker

    cfg = get_config("internlm-20b")
    cost = CostModel(cfg, WorkerSpec(tp=8))
    workers = [Worker(i, cost) for i in range(2)]
    policy = make_policy("sarathi", [w.view for w in workers],
                         AnalyticalPredictor(cost))
    sim = Simulator(workers, policy)
    calls = []

    def spy_fn(worker, plan):
        calls.append(worker.wid)
        return worker.plan_duration(plan)

    sim.duration_fn = spy_fn
    trace = generate_trace(1.0, 20.0, cost, seed=4)
    sim.add_trace(trace)
    m = sim.run(until=2000.0)
    assert m.n_finished == m.n_total == len(trace)
    assert calls, "custom duration_fn must supply the clock"


# ----------------------------------------------------- online predictor loop

def test_scheduler_feeds_online_predictor_and_corrects_bias():
    cfg = get_config("internlm-20b")
    cost = CostModel(cfg, WorkerSpec(tp=8))
    pred = OnlinePredictor(BiasedPredictor(cost, 2.0))
    sim, _ = build_cluster(cfg, "tropical", n_workers=2,
                           worker_spec=WorkerSpec(tp=8), predictor=pred)
    sim.add_trace(generate_trace(1.0, 60.0, cost, seed=7))
    m = sim.run(until=4000.0)
    assert m.n_finished == m.n_total
    assert pred.prefill_observations > 0 and pred.decode_observations > 0
    # the 2x overestimate must be substantially corrected toward 0.5
    assert pred.prefill_scale < 0.7
    assert pred.decode_scale < 0.7


def test_online_predictor_unbiased_base_keeps_margin():
    cfg = get_config("internlm-20b")
    cost = CostModel(cfg, WorkerSpec(tp=8))
    pred = OnlinePredictor(AnalyticalPredictor(cost))
    sim, _ = build_cluster(cfg, "tropical", n_workers=2,
                           worker_spec=WorkerSpec(tp=8), predictor=pred)
    sim.add_trace(generate_trace(1.0, 60.0, cost, seed=7))
    sim.run(until=4000.0)
    # exact executor => scales hover at 1.0 (safety margin preserved)
    assert pred.prefill_scale == pytest.approx(1.0, abs=0.15)
    assert pred.decode_scale == pytest.approx(1.0, abs=0.15)


# --------------------------------------------------------- role rebalancing

def _views(roles):
    return {i: WorkerView(wid=i, role=r, kv_capacity_tokens=100000.0)
            for i, r in enumerate(roles)}


def test_rebalancer_promotes_multiplexer_on_ttft_window():
    rb = RoleRebalancer(RebalanceConfig(min_samples=8))
    views = _views([Role.PREFILL, Role.MULTIPLEX, Role.MULTIPLEX])
    views[1].decode_batch = 4
    views[2].decode_batch = 1           # least decode-committed -> flips
    for ok in [False] * 12:
        rb.ttft_window.append(ok)
    for ok in [True] * 12:
        rb.tpot_window.append(ok)
    action = rb.step(views, now=100.0)
    assert action is not None and "ttft-window" in action
    assert views[2].role == Role.PREFILL
    assert views[1].role == Role.MULTIPLEX


def test_rebalancer_demotes_prefill_on_tpot_window():
    rb = RoleRebalancer(RebalanceConfig(min_samples=8))
    views = _views([Role.PREFILL, Role.PREFILL, Role.MULTIPLEX])
    views[0].queued_prefill_tokens = 10
    views[1].queued_prefill_tokens = 5000
    for ok in [True] * 12:
        rb.ttft_window.append(ok)
    for ok in [False] * 12:
        rb.tpot_window.append(ok)
    action = rb.step(views, now=100.0)
    assert action is not None and "tpot-window" in action
    assert views[0].role == Role.MULTIPLEX       # least-queued P converts


def test_rebalancer_hbm_pressure_rule_and_cooldown():
    rb = RoleRebalancer(RebalanceConfig(min_samples=8, cooldown=50.0))
    views = _views([Role.PREFILL, Role.MULTIPLEX])
    views[1].kv_used_tokens = 0.95 * views[1].kv_capacity_tokens
    action = rb.step(views, now=0.0)
    assert action is not None and "hbm-pressure" in action
    assert views[0].role == Role.MULTIPLEX
    # windowed actions respect the cooldown that change started
    views2 = _views([Role.PREFILL, Role.MULTIPLEX, Role.MULTIPLEX])
    for ok in [False] * 12:
        rb.ttft_window.append(ok)
    for ok in [True] * 12:
        rb.tpot_window.append(ok)
    assert rb.step(views2, now=10.0) is None      # inside cooldown
    assert rb.step(views2, now=100.0) is not None  # after cooldown


def test_rebalancer_needs_evidence():
    rb = RoleRebalancer(RebalanceConfig(min_samples=8))
    views = _views([Role.PREFILL, Role.MULTIPLEX, Role.MULTIPLEX])
    rb.ttft_window.extend([False] * 3)            # too thin
    assert rb.step(views, now=100.0) is None
    assert views[0].role == Role.PREFILL


def test_cluster_run_drives_windowed_rebalancer():
    """End-to-end: build_cluster('tropical') wires the rebalancer, the
    scheduler feeds it outcome windows, and the toggle's dispatch-count
    review is retired."""
    cfg = get_config("internlm-20b")
    sim, cost = build_cluster(cfg, "tropical", n_workers=4,
                              worker_spec=WorkerSpec(tp=8))
    assert sim.sched.rebalancer is not None
    assert sim.policy.toggle.cfg.role_transitions is False
    sim.add_trace(generate_trace(2.0, 60.0, cost, seed=5))
    m = sim.run(until=4000.0)
    assert m.n_finished == m.n_total
    rb = sim.sched.rebalancer
    assert len(rb.ttft_window) > 0 and len(rb.tpot_window) > 0

    # opting out restores the legacy dispatch-time review
    sim2, _ = build_cluster(cfg, "tropical", n_workers=4,
                            worker_spec=WorkerSpec(tp=8),
                            role_rebalance=False)
    assert sim2.sched.rebalancer is None
    assert sim2.policy.toggle.cfg.role_transitions is True


def test_force_rebalance_without_role_lifecycle_is_an_error():
    cfg = get_config("internlm-20b")
    with pytest.raises(ValueError, match="role_rebalance"):
        build_cluster(cfg, "distserve", n_workers=2,
                      worker_spec=WorkerSpec(tp=8), role_rebalance=True)


def test_unbounded_run_terminates_when_no_progress_is_possible():
    """Regression: the rebalance review must not re-arm itself forever
    over queued-but-stuck work — ``run()`` without ``until`` has to drain
    the heap and return, exactly like the pre-sched/ simulator."""
    cfg = get_config("internlm-20b")
    sim, cost = build_cluster(cfg, "tropical", n_workers=2,
                              worker_spec=WorkerSpec(tp=8))
    sim.inject_failure(0.0, 0)
    sim.inject_failure(0.0, 1)          # whole cluster dead, no recovery
    trace = generate_trace(1.0, 10.0, cost, seed=3)
    sim.add_trace(trace)
    m = sim.run()                       # unbounded: must still terminate
    assert m.n_finished == 0
    assert len(sim.global_queue) == len(trace)
