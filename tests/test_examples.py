"""Examples must keep running against the refactored API (importable
``main(argv)`` smoke at reduced scale)."""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                       / "examples"))

import serve_cluster          # noqa: E402
import slack_multiplexing     # noqa: E402


def test_serve_cluster_example_smoke(capsys):
    serve_cluster.main(["--rate", "1.0", "--duration", "15"])
    out = capsys.readouterr().out
    for pol in ("vllm", "sarathi", "distserve", "tropical", "tropical++"):
        assert pol in out
    assert "fault tolerance" in out
    assert "tropical+failure" in out


def test_slack_multiplexing_example_smoke(capsys):
    slack_multiplexing.main([])
    out = capsys.readouterr().out
    assert "attainment=" in out
    assert "multiplexing worker" in out
