"""Real-JAX executor end-to-end: the same Tropical scheduler drives actual
model execution (smoke config) — wall-clock durations, real KV caches."""
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.request import Phase, Request, SLOSpec
from repro.serving.costmodel import CostModel, WorkerSpec
from repro.serving.executor import ClusterRealExecutors, RealExecutor
from repro.serving.simulator import build_cluster


def _mk_trace(n=6, prompt=24, out=6):
    slo = SLOSpec(ttft=30.0, tpot=5.0)   # generous: wall-clock CPU
    return [Request(rid=i, arrival_time=0.05 * i, prompt_len=prompt,
                    output_len=out, slo=slo) for i in range(n)]


@pytest.mark.parametrize("policy", ["sarathi", "tropical"])
def test_real_executor_end_to_end(policy):
    cfg = get_smoke("deepseek-7b")
    sim, _ = build_cluster(cfg, policy, n_workers=2,
                           worker_spec=WorkerSpec(tp=1))
    execs = ClusterRealExecutors(cfg, 2, max_slots=8, max_len=64)
    sim.duration_fn = execs.duration_fn()
    trace = _mk_trace()
    sim.add_trace(trace)
    m = sim.run(until=3000.0)
    assert m.n_finished == m.n_total == len(trace)
    # every request actually generated tokens through the real model
    for r in trace:
        wid = r.worker
        gen = None
        for e in execs.execs.values():
            if r.rid in e.generated:
                gen = e.generated[r.rid]
        assert gen is not None and len(gen) >= r.output_len


def test_real_executor_chunked_prefill_matches_full():
    """Chunked prefill through the slot cache == one-shot prefill."""
    import jax
    import jax.numpy as jnp
    from repro.models import api as model_api

    cfg = get_smoke("qwen2-1.5b")
    api = model_api.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 24), 0,
                              cfg.vocab_size)
    # one-shot
    cache = api.init_cache(1, 48)
    lengths = jnp.asarray([24], jnp.int32)
    full_logits, _ = api.prefill(params, cache, toks, lengths)
    # chunked: 3 chunks of 8
    cache2 = api.init_cache(1, 48)
    logits = None
    for i in range(3):
        chunk = toks[:, i * 8:(i + 1) * 8]
        starts = jnp.asarray([i * 8], jnp.int32)
        logits, cache2 = api.prefill_chunk(params, cache2, chunk, starts)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full_logits),
                               rtol=2e-4, atol=2e-4)


def test_real_executor_migration_preserves_generation():
    cfg = get_smoke("deepseek-7b")
    execs = ClusterRealExecutors(cfg, 2, max_slots=4, max_len=64)
    req = Request(rid=0, arrival_time=0.0, prompt_len=16, output_len=8,
                  slo=SLOSpec(30.0, 5.0))
    src = execs.execs[0]
    src.register(req)
    src.run_prefill_chunk(req, 16)
    req.prefilled_tokens = 16
    src.run_decode_batch([req])
    tokens_before = list(src.generated[0])
    execs.migrate(req, 0, 1)
    dst = execs.execs[1]
    assert dst.generated[0] == tokens_before
    dst.run_decode_batch([req])
    assert len(dst.generated[0]) == len(tokens_before) + 1
