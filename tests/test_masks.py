"""MaskSpec properties: the lazy per-chunk masks must agree with their
dense definitions and with each other at the seams the engine relies on."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.models.layers import MaskSpec


@given(sq=st.integers(1, 8), sk=st.integers(1, 16),
       off=st.integers(0, 8))
@settings(max_examples=25, deadline=None)
def test_causal_mask_definition(sq, sk, off):
    m = np.asarray(MaskSpec("causal").block(sq, sk, off))[0, 0]
    for i in range(sq):
        for j in range(sk):
            assert m[i, j] == (j <= i + off)


@given(sq=st.integers(1, 8), sk=st.integers(4, 16),
       w=st.integers(1, 8))
@settings(max_examples=25, deadline=None)
def test_window_mask_band(sq, sk, w):
    m = np.asarray(MaskSpec("causal", window=w).block(sq, sk, 0))[0, 0]
    for i in range(sq):
        for j in range(sk):
            assert m[i, j] == (j <= i and j > i - w)


@given(starts=st.lists(st.integers(0, 12), min_size=1, max_size=3),
       sq=st.integers(1, 4))
@settings(max_examples=25, deadline=None)
def test_chunk_mask_equals_shifted_causal(starts, sq):
    """chunk mask with per-request start == causal mask with that offset."""
    sk = 24
    lengths = jnp.asarray(starts, jnp.int32)
    chunk = np.asarray(MaskSpec("chunk").block(sq, sk, 0, lengths))
    for b, s in enumerate(starts):
        causal = np.asarray(MaskSpec("causal", q_offset=s).block(sq, sk, 0))
        np.testing.assert_array_equal(chunk[b, 0], causal[0, 0])


@given(lengths=st.lists(st.integers(0, 15), min_size=1, max_size=4))
@settings(max_examples=25, deadline=None)
def test_ring_mask_matches_lengths_before_wrap(lengths):
    """Until the ring wraps (len+1 < size), ring == lengths mask."""
    sk = 16
    l = jnp.asarray(lengths, jnp.int32)
    ring = np.asarray(MaskSpec("ring").block(1, sk, 0, l))
    dense = np.asarray(MaskSpec("lengths").block(1, sk, 0, l))
    for b, ln in enumerate(lengths):
        if ln + 1 < sk:
            np.testing.assert_array_equal(ring[b], dense[b])
        else:
            assert ring[b].all()   # wrapped: every slot valid
