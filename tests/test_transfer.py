"""KV transfer engine: link-contention arithmetic, max-min fairness,
prediction, and simulator-level bandwidth monotonicity."""
import copy

import pytest

from repro.configs import get_config
from repro.serving.costmodel import CostModel, WorkerSpec
from repro.serving.simulator import build_cluster
from repro.serving.trace import generate_trace
from repro.serving.transfer import LinkSpec, TransferEngine


GB = 1e9


def _engine(n=4, bw=10 * GB, latency=0.0):
    spec = LinkSpec(egress_bw=bw, ingress_bw=bw, latency=latency)
    return TransferEngine({i: spec for i in range(n)})


# ------------------------------------------------------------- contention

def test_single_flow_runs_at_line_rate():
    e = _engine()
    e.start(src=0, dst=1, nbytes=10 * GB, now=0.0)
    assert e.next_completion() == pytest.approx(1.0)


def test_two_concurrent_migrations_take_twice_as_long():
    """Two flows out of one worker split its egress: each takes ~2x the
    solo transfer time."""
    e = _engine()
    e.start(0, 1, 10 * GB, now=0.0)
    e.start(0, 2, 10 * GB, now=0.0)          # same source, distinct dsts
    assert e.next_completion() == pytest.approx(2.0)
    done = e.pop_completed(2.0)
    assert len(done) == 2


def test_disjoint_flows_do_not_contend():
    e = _engine()
    e.start(0, 1, 10 * GB, now=0.0)
    e.start(2, 3, 10 * GB, now=0.0)
    assert e.next_completion() == pytest.approx(1.0)


def test_ingress_contention_shares_destination():
    """Two sources into one destination split its ingress capacity."""
    e = _engine()
    e.start(0, 2, 10 * GB, now=0.0)
    e.start(1, 2, 10 * GB, now=0.0)
    assert e.next_completion() == pytest.approx(2.0)


def test_maxmin_releases_bandwidth_of_bottlenecked_sibling():
    """Flow A (0->2) shares dst-2 ingress with B (1->2); B is alone on its
    source. A's sibling C (0->3) must pick up the egress A cannot use:
    max-min gives A and B 5 GB/s on the shared ingress, and C the
    remaining 5 GB/s of worker 0's egress... then A finishing frees C up
    to line rate. Waterfilling, not naive equal split."""
    e = _engine()
    a = e.start(0, 2, 5 * GB, now=0.0)
    b = e.start(1, 2, 5 * GB, now=0.0)
    c = e.start(0, 3, 10 * GB, now=0.0)
    # ingress of 2 is the bottleneck for a,b: 5 GB/s each; c gets the
    # remaining 5 GB/s of 0's egress
    assert a.rate == pytest.approx(5 * GB)
    assert b.rate == pytest.approx(5 * GB)
    assert c.rate == pytest.approx(5 * GB)
    done = e.pop_completed(1.0)              # a and b drain together
    assert {f.fid for f in done} == {a.fid, b.fid}
    assert c.rate == pytest.approx(10 * GB)  # c inherits the freed egress
    assert e.next_completion() == pytest.approx(1.5)


def test_late_joiner_reshapes_rates():
    e = _engine()
    a = e.start(0, 1, 10 * GB, now=0.0)
    e.advance(0.5)                           # a drained 5 GB so far
    b = e.start(0, 2, 10 * GB, now=0.5)
    assert a.rate == b.rate == pytest.approx(5 * GB)
    # a has 5 GB left at 5 GB/s -> finishes at 1.5
    assert e.next_completion() == pytest.approx(1.5)


def test_infinite_bandwidth_completes_immediately():
    e = TransferEngine({0: LinkSpec(float("inf"), float("inf")),
                        1: LinkSpec(float("inf"), float("inf"))})
    e.start(0, 1, 100 * GB, now=3.0)
    assert e.next_completion() == pytest.approx(3.0)
    assert len(e.pop_completed(3.0)) == 1


def test_predict_transfer_time_monotone_in_queue_depth():
    e = _engine(latency=0.001)
    t0 = e.predict_transfer_time(0, 1, GB)
    e.start(0, 2, 10 * GB, now=0.0)          # backlog on 0's egress
    t1 = e.predict_transfer_time(0, 1, GB)
    e.start(0, 3, 10 * GB, now=0.0)
    t2 = e.predict_transfer_time(0, 1, GB)
    assert t0 < t1 < t2


def test_predict_transfer_time_batch_matches_scalar():
    """The batched predictor must be bit-identical to the scalar one per
    destination — including a dead-link dst (inf) and contended dsts."""
    e = _engine(n=5, latency=0.001)
    e.links[4] = LinkSpec(egress_bw=10 * GB, ingress_bw=0.0, latency=0.001)
    e.start(0, 2, 10 * GB, now=0.0)          # egress backlog on 0
    e.start(3, 1, 4 * GB, now=0.0)           # ingress backlog on 1
    dsts = [1, 2, 3, 4]
    batch = e.predict_transfer_time_batch(0, dsts, GB, now=0.25)
    scalar = [e.predict_transfer_time(0, d, GB, now=0.25) for d in dsts]
    assert batch == scalar                   # exact, not approx
    assert batch[-1] == float("inf")         # dead ingress link


def test_drop_flows_touching_dead_worker():
    e = _engine()
    e.start(0, 1, 10 * GB, now=0.0)
    e.start(0, 2, 10 * GB, now=0.0)
    dead = e.drop_flows_touching(1, now=0.5)
    assert len(dead) == 1
    # survivor drained 2.5 GB at its pre-failure 5 GB/s share, then
    # reclaims the full 10 GB/s egress: 7.5 GB left -> done at 1.25
    assert e.next_completion() == pytest.approx(1.25)
    # flows OUT of a dead worker are lost too (its HBM held the KV)
    e2 = _engine()
    e2.start(0, 1, 10 * GB, now=0.0)
    assert len(e2.drop_flows_touching(0, now=0.0)) == 1
    assert e2.active_flows == 0


# ------------------------------------------------- simulator-level checks

CFG = get_config("internlm-20b")
SPEC = WorkerSpec(tp=8)


def _run(policy, bw_per_link, rate=1.5, duration=40.0, seed=3):
    sim, cost = build_cluster(CFG, policy, n_workers=4, worker_spec=SPEC,
                              ici_bw=bw_per_link)
    trace = generate_trace(rate, duration, cost, seed=seed)
    sim.add_trace(copy.deepcopy(trace))
    return sim.run(until=100000.0)


def test_migration_burst_wait_monotone_with_bandwidth():
    """distserve migrates every request; shrinking the per-link bandwidth
    must monotonically raise the time migrated KV sits on the wire and
    the inter-token latency right after migration (TPOT component)."""
    waits, tpots = [], []
    for bw in (0.25 * GB, 2 * GB, 50 * GB):
        m = _run("distserve", bw)
        assert m.n_finished == m.n_total
        waits.append(m.migration_wait_avg)
        tpots.append(m.tpot_avg)
    assert waits[0] > waits[1] > waits[2]
    assert tpots[0] > tpots[1] >= tpots[2]


def test_infinite_bandwidth_matches_legacy_fixed_model():
    """Regression guard on the cost model: with effectively infinite link
    bandwidth the contended engine must reproduce the seed's fixed-delay
    migration metrics for every policy."""
    for policy in ("distserve", "tropical"):
        rows = {}
        for engine_on in (True, False):
            sim, cost = build_cluster(CFG, policy, n_workers=4,
                                      worker_spec=SPEC, ici_bw=1e21,
                                      use_transfer_engine=engine_on)
            trace = generate_trace(1.0, 30.0, cost, seed=0)
            sim.add_trace(copy.deepcopy(trace))
            rows[engine_on] = sim.run(until=50000.0)
        a, b = rows[True], rows[False]
        assert a.n_finished == b.n_finished == a.n_total
        assert a.migrations == b.migrations
        assert a.ttft_avg == pytest.approx(b.ttft_avg, rel=1e-6)
        assert a.tpot_avg == pytest.approx(b.tpot_avg, rel=1e-6)
        assert a.slo_attainment == pytest.approx(b.slo_attainment)
