"""Two-tier KV hierarchy: accountant, restore pricing, offload lifecycle,
and the fail-mid-offload exactly-once guarantee."""
import copy
import dataclasses
import math

import pytest

from repro.configs import get_config
from repro.core.request import Phase, Request, SLOSpec
from repro.perf import AnalyticalPredictor, CostModel, Predictor, WorkerSpec
from repro.serving.engine import Worker
from repro.serving.kvcache import PageAccountant
from repro.serving.simulator import build_cluster
from repro.serving.transfer import LinkSpec, TransferEngine, host_node
from repro.workload import get_scenario

GB = 1e9


def _cost(tp=8, hbm_frac=1.0):
    spec = WorkerSpec(tp=tp)
    if hbm_frac != 1.0:
        spec = dataclasses.replace(spec, hw=dataclasses.replace(
            spec.hw, hbm_bytes=spec.hw.hbm_bytes * hbm_frac))
    return CostModel(get_config("internlm-20b"), spec)


def _req(rid=1, prompt=1024, out=64):
    return Request(rid=rid, arrival_time=0.0, prompt_len=prompt,
                   output_len=out, slo=SLOSpec(ttft=5.0, tpot=0.5))


# --------------------------------------------------------- PageAccountant
def test_accountant_two_tier_roundtrip():
    pa = PageAccountant(total_pages=10, page_size=16, host_pages=6)
    assert pa.reserve(1, 64)             # 4 pages
    assert pa.used_pages == 4 and pa.host_used_pages == 0
    assert pa.can_offload(1)
    assert pa.offload(1) == 4
    assert pa.used_pages == 0 and pa.host_used_pages == 4
    assert pa.host_free_pages == 2
    assert pa.host_held_pages(1) == 4
    assert pa.can_restore(1)
    assert pa.restore(1) == 4
    assert pa.used_pages == 4 and pa.host_used_pages == 0
    # release clears whichever tier holds the pages
    assert pa.offload(1) == 4
    pa.release(1)
    assert pa.used_pages == 0 and pa.host_used_pages == 0


def test_accountant_offload_requires_host_room():
    pa = PageAccountant(total_pages=10, page_size=16, host_pages=2)
    assert pa.reserve(1, 64)             # 4 pages > 2 host pages
    assert not pa.can_offload(1)
    assert pa.offload(1) == 0            # refused, nothing moved
    assert pa.used_pages == 4 and pa.host_used_pages == 0
    # zero host tier: never offloadable
    pa0 = PageAccountant(total_pages=10, page_size=16)
    assert pa0.reserve(1, 32)
    assert not pa0.can_offload(1)


def test_accountant_restore_requires_hbm_room():
    pa = PageAccountant(total_pages=4, page_size=16, host_pages=8)
    assert pa.reserve(1, 64)
    assert pa.offload(1) == 4
    assert pa.reserve(2, 48)             # 3 of 4 HBM pages now taken
    assert not pa.can_restore(1)
    assert pa.restore(1) == 0
    pa.release(2)
    assert pa.can_restore(1) and pa.restore(1) == 4


def test_accountant_reset_clears_both_tiers():
    pa = PageAccountant(total_pages=10, page_size=16, host_pages=6)
    pa.reserve(1, 64)
    pa.offload(1)
    pa.reserve(2, 32)
    pa.reset()
    assert pa.used_pages == 0 and pa.host_used_pages == 0
    assert pa.held_pages(1) == 0 and pa.host_held_pages(1) == 0


# --------------------------------------------------- restore-cost pricing
def test_host_capacity_and_restore_time():
    cm = _cost()
    assert cm.host_capacity_pages(0.0) == 0
    assert cm.host_capacity_pages(-1.0) == 0
    pages = cm.host_capacity_pages(16 * GB)
    assert pages > 0
    # restore = host link latency + wire time; strictly cheaper than a
    # full re-prefill for a long context (the reason the tier exists)
    t = cm.restore_time(4096)
    assert 0 < t < cm.prefill_time(4096)
    # residue tokens append a suffix prefill at the restored offset
    assert cm.restore_time(4096, residue_tokens=256) > t
    # a zero-bandwidth host link can never restore
    dead = CostModel(get_config("internlm-20b"), dataclasses.replace(
        cm.worker, hw=dataclasses.replace(cm.worker.hw, host_bw=0.0)))
    assert math.isinf(dead.restore_time(4096))


def test_predictor_restore_hierarchy():
    cm = _cost()
    base = Predictor()
    assert math.isinf(base.predict_restore(4096))   # no tier knowledge
    ana = AnalyticalPredictor(cm, safety=1.2)
    assert ana.predict_restore(4096) == pytest.approx(
        cm.restore_time(4096) * 1.2)
    assert ana.predict_restore(4096) < ana.predict_prefill(4096)


# ------------------------------------------------- worker offload lifecycle
def test_worker_offload_restore_lifecycle():
    cm = _cost()
    w = Worker(0, cm, host_pages=cm.host_capacity_pages(16 * GB),
               offload_gate=lambda r: True)
    req = _req(prompt=2048)
    req.phase = Phase.DECODING
    req.generated_tokens = 4
    assert w.pages.reserve(req.rid, req.context_len)
    w.decode_running[req.rid] = req
    w.view.kv_used_tokens = float(req.context_len)
    held = w.pages.held_pages(req.rid)

    assert w._try_offload(req, now=1.0)
    assert req.phase == Phase.OFFLOADED and req.offloads == 1
    assert req.stall_start == 1.0
    assert req.rid not in w.decode_running
    assert w.pages.used_pages == 0 and w.pages.host_used_pages == held
    assert w.drain_offload_started() == [req]
    assert w.drain_offload_started() == []      # drained exactly once

    w.offload_landed(req)
    assert req.rid in w.offloaded and req.rid not in w.offloading
    assert w.next_restorable() is req
    assert w.begin_restore(req, now=2.0)
    assert req.rid in w.restoring
    assert w.pages.used_pages == held and w.pages.host_used_pages == 0
    assert w.finish_restore(req, now=3.0)
    assert req.rid in w.decode_running and req.restores == 1
    # the whole parked interval charged as inter-token latency
    assert req.decode_time == pytest.approx(2.0)
    assert req.stall_start is None


def test_worker_fail_mid_offload_counts_pages_exactly_once():
    """A worker dying with one request offload-in-flight and one landed
    must hand each back for restart exactly once and zero both tiers."""
    cm = _cost()
    w = Worker(0, cm, host_pages=cm.host_capacity_pages(16 * GB),
               offload_gate=lambda r: True)
    a, b = _req(rid=1, prompt=2048), _req(rid=2, prompt=1024)
    for r in (a, b):
        r.phase = Phase.DECODING
        r.generated_tokens = 2
        assert w.pages.reserve(r.rid, r.context_len)
        w.decode_running[r.rid] = r
    w.view.kv_used_tokens = float(a.context_len + b.context_len)
    assert w._try_offload(a, 1.0) and w._try_offload(b, 1.0)
    w.drain_offload_started()
    w.offload_landed(a)                  # a landed; b still in flight
    assert set(w.offloaded) == {1} and set(w.offloading) == {2}

    lost = w.fail(2.0)
    assert sorted(r.rid for r in lost) == [1, 2]        # each exactly once
    assert len(lost) == len({id(r) for r in lost})
    assert w.pages.used_pages == 0 and w.pages.host_used_pages == 0
    assert w.offloading == {} and w.offloaded == {} and w.restoring == {}
    assert w.drain_offload_started() == []
    for r in lost:
        assert r.phase == Phase.QUEUED_PREFILL          # reset for re-prefill


def test_stale_restore_completion_after_fail_is_ignored():
    cm = _cost()
    w = Worker(0, cm, host_pages=cm.host_capacity_pages(16 * GB),
               offload_gate=lambda r: True)
    req = _req(prompt=2048)
    req.phase = Phase.DECODING
    assert w.pages.reserve(req.rid, req.context_len)
    w.decode_running[req.rid] = req
    w.view.kv_used_tokens = float(req.context_len)
    assert w._try_offload(req, 1.0)
    w.drain_offload_started()
    w.offload_landed(req)
    assert w.begin_restore(req, 2.0)
    w.fail(3.0)
    w.view.alive = True
    assert not w.finish_restore(req, 4.0)   # stale: failure already reset
    assert w.restore_count == 0 and w.pages.used_pages == 0


# ---------------------------------------------- transfer-engine host nodes
def test_host_node_flows_drop_with_worker():
    eng = TransferEngine()
    eng.add_worker(0, LinkSpec(egress_bw=10 * GB, ingress_bw=10 * GB))
    eng.add_worker(1, LinkSpec(egress_bw=10 * GB, ingress_bw=10 * GB))
    hn = eng.add_host(0, LinkSpec(egress_bw=32 * GB, ingress_bw=32 * GB))
    assert hn == host_node(0) == -1
    eng.start(0, hn, 1 * GB, 0.0, payload=("offload", 0, "a"))
    eng.start(hn, 0, 1 * GB, 0.0, payload=("restore", 0, "b"))
    eng.start(0, 1, 1 * GB, 0.0, payload=("mig", "r", 0.0, 0))
    # dropping the worker catches flows touching it AND its host node
    dropped = eng.drop_flows_touching(0, 1e-3)
    dropped += eng.drop_flows_touching(hn, 1e-3)
    assert len(dropped) == 3
    assert eng.next_completion() is None


# -------------------------------------------------- end-to-end (scheduler)
def _tiered_sim(host_kv_gb, duration=60.0, rate=6.0, **kw):
    spec = dataclasses.replace(WorkerSpec(tp=8), hw=dataclasses.replace(
        WorkerSpec(tp=8).hw, hbm_bytes=WorkerSpec(tp=8).hw.hbm_bytes / 2))
    cfg = get_config("internlm-20b")
    cm = CostModel(cfg, spec)
    trace = get_scenario("agentic").generate(rate, duration, cm, seed=23)
    sim, _ = build_cluster(cfg, "tropical", n_workers=2, worker_spec=spec,
                           host_kv_gb=host_kv_gb, **kw)
    sim.add_trace(copy.deepcopy(trace))
    return sim, duration


def test_sim_offloads_replace_evictions_under_pressure():
    sim0, dur = _tiered_sim(host_kv_gb=0.0)
    m0 = sim0.run(until=dur * 10)
    sim1, dur = _tiered_sim(host_kv_gb=16.0)
    m1 = sim1.run(until=dur * 10)
    assert m0.preemptions > 0 and m0.kv_offloads == 0
    assert m1.kv_offloads > 0 and m1.kv_restores == m1.kv_offloads
    assert m1.preemptions < m0.preemptions
    assert m1.n_finished == m1.n_total
    # nothing left parked in either tier at the end of the run
    for w in sim1.workers.values():
        assert not w.offloading and not w.offloaded and not w.restoring
        assert w.pages.host_used_pages == 0


def test_sim_fail_during_tiered_run_accounts_once():
    sim, dur = _tiered_sim(host_kv_gb=16.0)
    sim.inject_failure(20.0, 0, recover_after=10.0)
    m = sim.run(until=dur * 20)
    assert m.n_finished == m.n_total
    for w in sim.workers.values():
        assert not w.offloading and not w.offloaded and not w.restoring
        assert w.pages.host_used_pages == 0
        # only prefix pseudo-rids (negative) may outlive the run
        assert all(rid < 0 for rid in w.pages._pages)
