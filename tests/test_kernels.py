"""Pallas kernel validation: shape/dtype sweeps + hypothesis properties,
interpret mode vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.chunked_prefill import chunked_prefill_attention
from repro.kernels.paged_attention import paged_attention


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


def _mk_paged(rng, b, hq, hkv, d, ps, mp, dtype):
    n_pages = b * mp + 3
    q = jnp.asarray(rng.normal(size=(b, hq, d)), dtype)
    kp = jnp.asarray(rng.normal(size=(n_pages, ps, hkv, d)), dtype)
    vp = jnp.asarray(rng.normal(size=(n_pages, ps, hkv, d)), dtype)
    bt = jnp.asarray(
        rng.permutation(n_pages)[: b * mp].reshape(b, mp), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, mp * ps + 1, size=(b,)), jnp.int32)
    return q, kp, vp, bt, lengths


# ------------------------------------------------------------- paged decode

PAGED_SWEEP = [
    # (b, hq, hkv, d, page, max_pages, dtype)
    (1, 4, 4, 64, 16, 4, jnp.float32),      # MHA
    (3, 8, 2, 64, 16, 8, jnp.float32),      # GQA
    (2, 8, 1, 128, 32, 4, jnp.float32),     # MQA
    (2, 16, 4, 128, 64, 4, jnp.float32),    # serving-like tiles
    (3, 8, 2, 64, 16, 8, jnp.bfloat16),
    (2, 8, 8, 128, 64, 2, jnp.bfloat16),
]


@pytest.mark.parametrize("b,hq,hkv,d,ps,mp,dtype", PAGED_SWEEP)
def test_paged_attention_sweep(b, hq, hkv, d, ps, mp, dtype):
    rng = np.random.default_rng(42)
    q, kp, vp, bt, lengths = _mk_paged(rng, b, hq, hkv, d, ps, mp, dtype)
    out = paged_attention(q, kp, vp, bt, lengths, interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        **_tol(dtype))


def test_paged_attention_softcap():
    rng = np.random.default_rng(7)
    q, kp, vp, bt, lengths = _mk_paged(rng, 2, 8, 4, 64, 16, 4, jnp.float32)
    out = paged_attention(q, kp, vp, bt, lengths, softcap=30.0,
                          interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, bt, lengths, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


def test_paged_attention_single_token_cache():
    """length=1 edge: only the first token of the first page attends."""
    rng = np.random.default_rng(3)
    q, kp, vp, bt, _ = _mk_paged(rng, 2, 4, 2, 64, 16, 4, jnp.float32)
    lengths = jnp.asarray([1, 1], jnp.int32)
    out = paged_attention(q, kp, vp, bt, lengths, interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 4),
    group=st.sampled_from([1, 2, 4]),
    hkv=st.sampled_from([1, 2, 4]),
    ps=st.sampled_from([8, 16]),
    mp=st.integers(1, 6),
    data=st.data(),
)
def test_paged_attention_property(b, group, hkv, ps, mp, data):
    """Property: kernel == oracle for random ragged lengths and shuffled
    page tables (indirection correctness)."""
    d = 64
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    q, kp, vp, bt, lengths = _mk_paged(rng, b, group * hkv, hkv, d, ps, mp,
                                       jnp.float32)
    out = paged_attention(q, kp, vp, bt, lengths, interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=3e-5,
                               atol=3e-5)


# -------------------------------------------------------- chunked prefill

CHUNK_SWEEP = [
    # (b, sq, hq, hkv, d, smax, bq, bk, window, dtype)
    (2, 64, 4, 4, 64, 256, 32, 64, None, jnp.float32),
    (1, 128, 8, 2, 64, 512, 64, 128, None, jnp.float32),
    (2, 32, 8, 1, 128, 128, 32, 64, None, jnp.float32),
    (2, 64, 4, 2, 64, 256, 32, 64, 48, jnp.float32),     # sliding window
    (2, 64, 4, 4, 64, 256, 32, 64, None, jnp.bfloat16),
    (1, 256, 16, 16, 64, 512, 128, 256, None, jnp.bfloat16),
]


@pytest.mark.parametrize("b,sq,hq,hkv,d,smax,bq,bk,window,dtype", CHUNK_SWEEP)
def test_chunked_prefill_sweep(b, sq, hq, hkv, d, smax, bq, bk, window, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, sq, hq, d)), dtype)
    kc = jnp.asarray(rng.normal(size=(b, smax, hkv, d)), dtype)
    vc = jnp.asarray(rng.normal(size=(b, smax, hkv, d)), dtype)
    starts = jnp.asarray(rng.integers(0, smax - sq + 1, size=(b,)), jnp.int32)
    out = chunked_prefill_attention(q, kc, vc, starts, window=window,
                                    bq=bq, bk=bk, interpret=True)
    want = ref.chunked_prefill_attention_ref(q, kc, vc, starts, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        **_tol(dtype))


def test_chunked_prefill_zero_start_is_causal_attention():
    """start=0, Smax=Sq: reduces to plain causal self-attention."""
    from repro.models.layers import MaskSpec, attention_scores
    rng = np.random.default_rng(5)
    b, sq, h, d = 2, 64, 4, 64
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
    starts = jnp.zeros((b,), jnp.int32)
    out = chunked_prefill_attention(q, k, v, starts, bq=32, bk=32,
                                    interpret=True)
    want = attention_scores(q, k, v, MaskSpec("causal"))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=3e-5,
                               atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 3),
    group=st.sampled_from([1, 2]),
    hkv=st.sampled_from([1, 2]),
    nq=st.sampled_from([1, 2]),       # sq = nq * bq
    nk=st.sampled_from([2, 4]),       # smax = nk * bk
    window=st.sampled_from([None, 40]),
    data=st.data(),
)
def test_chunked_prefill_property(b, group, hkv, nq, nk, window, data):
    bq, bk, d = 32, 64, 64
    sq, smax = nq * bq, nk * bk
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    q = jnp.asarray(rng.normal(size=(b, sq, group * hkv, d)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(b, smax, hkv, d)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, smax, hkv, d)), jnp.float32)
    starts = jnp.asarray(rng.integers(0, smax - sq + 1, size=(b,)), jnp.int32)
    out = chunked_prefill_attention(q, kc, vc, starts, window=window,
                                    bq=bq, bk=bk, interpret=True)
    want = ref.chunked_prefill_attention_ref(q, kc, vc, starts, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=3e-5,
                               atol=3e-5)
