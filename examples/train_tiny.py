"""Train a ~100M-param dense LM for a few hundred steps with the full
substrate (AdamW, synthetic pipeline, checkpoints) — CPU-sized.

    PYTHONPATH=src python examples/train_tiny.py [steps]
"""
import dataclasses
import sys
import time

import jax

from repro.models import api as model_api
from repro.models.layers import ModelConfig
from repro.train import checkpoint, optimizer
from repro.train.data import DataConfig, SyntheticLM
import jax.numpy as jnp

# ~100M params: 12L x d512 x ff2048, 32k vocab
CFG = ModelConfig(
    name="tiny-100m", family="dense", num_layers=12, d_model=512,
    num_heads=8, num_kv_heads=8, head_dim=64, d_ff=2048, vocab_size=32768,
    dtype=jnp.float32,
)


def main(steps: int = 200) -> None:
    api = model_api.build(CFG)
    params = api.init(jax.random.PRNGKey(0))
    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"{CFG.name}: {n/1e6:.1f}M params, {steps} steps")
    data = SyntheticLM(CFG, DataConfig(batch=4, seq=128))
    step = jax.jit(optimizer.make_train_step(
        lambda p, b: api.loss(p, b),
        optimizer.AdamWConfig(lr=1e-3, warmup_steps=20)))
    state = optimizer.init_state(params)
    t0 = time.perf_counter()  # lint: allow-wallclock(measured step time for progress display)
    for i in range(steps):
        params, state, loss = step(params, state, data.batch_at(i))
        if i % 20 == 0 or i == steps - 1:
            print(f"step {i:4d} loss {float(loss):.4f} "
                  f"({(time.perf_counter()-t0)/(i+1):.2f}s/step)")  # lint: allow-wallclock(measured step time for progress display)
    checkpoint.save("/tmp/tiny100m_ckpt", steps,
                    {"params": params, "state": state})
    print("checkpoint saved to /tmp/tiny100m_ckpt")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 200)
