"""Quickstart: build a model from the registry, prefill + decode a few
tokens, run one training step.

    PYTHONPATH=src python examples/quickstart.py [arch]
"""
import sys

import jax
import jax.numpy as jnp

from repro.configs import get_smoke, list_archs
from repro.models import api as model_api
from repro.train import optimizer
from repro.train.data import DataConfig, SyntheticLM


def main(arch: str = "gemma2-2b") -> None:
    cfg = get_smoke(arch)     # reduced same-family config for CPU
    print(f"arch={cfg.name} family={cfg.family} layers={cfg.num_layers} "
          f"d_model={cfg.d_model}")
    api = model_api.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.2f}M")

    # ---- serve: prefill a prompt, decode 8 tokens -----------------------
    rng = jax.random.PRNGKey(1)
    prompt = jax.random.randint(rng, (2, 12), 0, cfg.vocab_size)
    inputs = prompt
    if cfg.family == "encdec":
        inputs = {"frames": jax.random.normal(rng, (2, 8, cfg.d_model),
                                              dtype=cfg.dtype),
                  "tokens": prompt}
    elif cfg.family == "vlm":
        inputs = {"tokens": prompt,
                  "prefix_embeds": jax.random.normal(
                      rng, (2, cfg.num_patches, cfg.vision_feature_dim),
                      dtype=cfg.dtype)}
    cache = api.init_cache(2, 32)
    lengths = jnp.full((2,), 12, jnp.int32)
    logits, cache = api.prefill(params, cache, inputs, lengths)
    out = [int(t) for t in jnp.argmax(logits, -1)]
    seqs = [[t] for t in out]
    for _ in range(8):
        tok = jnp.asarray([s[-1] for s in seqs], jnp.int32)
        logits, cache = api.decode(params, cache, tok, lengths)
        lengths = lengths + 1
        for s, t in zip(seqs, jnp.argmax(logits, -1)):
            s.append(int(t))
    print("generated:", seqs)

    # ---- train: a couple of optimizer steps ------------------------------
    data = SyntheticLM(cfg, DataConfig(batch=2, seq=16))
    step = jax.jit(optimizer.make_train_step(lambda p, b: api.loss(p, b)))
    state = optimizer.init_state(params)
    for i in range(3):
        params, state, loss = step(params, state, data.batch_at(i))
        print(f"train step {i}: loss {float(loss):.4f}")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "gemma2-2b")
