"""End-to-end serving driver (the paper's experiment in miniature).

Serves a Mooncake-like trace on a 4-worker InternLM-20B cluster under all
four schedulers and prints the SLO-attainment comparison — then re-runs
Tropical with a worker failure injected mid-run to show fault tolerance.

    PYTHONPATH=src python examples/serve_cluster.py [--rate 4] [--duration 240]
"""
import argparse
import copy
from typing import Optional, Sequence

from repro.configs import get_config
from repro.serving.costmodel import CostModel, WorkerSpec
from repro.serving.simulator import build_cluster
from repro.serving.trace import generate_trace
from repro.core.request import SLOSpec


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--duration", type=float, default=240.0)
    ap.add_argument("--seed", type=int, default=3)
    args = ap.parse_args(argv)

    cfg = get_config("internlm-20b")
    spec = WorkerSpec(tp=8)
    cost = CostModel(cfg, spec)
    slo = SLOSpec(ttft=5.0 * cost.prefill_time(8192),
                  tpot=5.0 * cost.decode_iter_time(1, 8192.0))
    trace = generate_trace(args.rate, args.duration, cost, seed=args.seed,
                           fixed_slo=slo)
    until = args.duration * 10
    print(f"model={cfg.name} workers=4xTP8-v5e rate={args.rate}/s "
          f"requests={len(trace)} SLO(ttft={slo.ttft:.2f}s "
          f"tpot={slo.tpot*1000:.0f}ms)")
    print(f"{'policy':<11} {'SLO-A':>6} {'TTFT-A':>7} {'TPOT-A':>7} "
          f"{'q90(s)':>7} {'tpot90':>7} {'migr':>5}")
    for pol in ("vllm", "sarathi", "distserve", "tropical", "tropical++"):
        sim, _ = build_cluster(cfg, pol, n_workers=4, worker_spec=spec)
        sim.add_trace(copy.deepcopy(trace))
        m = sim.run(until=until)
        print(f"{pol:<11} {m.slo_attainment:>6.3f} {m.ttft_attainment:>7.3f} "
              f"{m.tpot_attainment:>7.3f} {m.queue_p90:>7.2f} "
              f"{m.tpot_p90:>7.3f} {m.migrations:>5}")

    print(f"\n--- fault tolerance: worker 3 dies at t="
          f"{args.duration / 4:.0f}s, recovers {args.duration / 4:.0f}s later")
    sim, _ = build_cluster(cfg, "tropical", n_workers=4, worker_spec=spec)
    sim.add_trace(copy.deepcopy(trace))
    sim.inject_failure(args.duration / 4, wid=3,
                       recover_after=args.duration / 4)
    m = sim.run(until=until)
    print(f"tropical+failure: SLO-A={m.slo_attainment:.3f} "
          f"finished={m.n_finished}/{m.n_total} restarts={m.restarts}")


if __name__ == "__main__":
    main()
