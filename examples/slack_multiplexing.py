"""Fig. 7 in miniature: watch the TPOT-slack mechanism admit a prefill onto
a multiplexing worker without breaking the decode SLO.

    PYTHONPATH=src python examples/slack_multiplexing.py
"""
from typing import Optional, Sequence

from repro.configs import get_config
from repro.core.predictor import AnalyticalPredictor
from repro.core.request import Request, SLOSpec
from repro.core.toggle import Role
from repro.serving.costmodel import CostModel, WorkerSpec
from repro.serving.engine import Worker
from repro.core.policies import TropicalPolicy
from repro.serving.simulator import Simulator


def main(argv: Optional[Sequence[str]] = None) -> None:
    cfg = get_config("internlm-20b")
    cost = CostModel(cfg, WorkerSpec(tp=8))
    slo = SLOSpec(ttft=5.0, tpot=0.05)

    workers = [Worker(0, cost, role=Role.PREFILL),
               Worker(1, cost, role=Role.MULTIPLEX)]
    policy = TropicalPolicy([w.view for w in workers],
                            AnalyticalPredictor(cost), n_prefill=1)
    sim = Simulator(workers, policy)

    # R0: a decode-phase request on the multiplexing worker
    r0 = Request(rid=0, arrival_time=0.0, prompt_len=4096, output_len=120,
                 slo=slo)
    # R1 arrives while the prefill worker is busy with a monster prompt
    monster = Request(rid=1, arrival_time=0.05, prompt_len=32768,
                      output_len=8, slo=slo)
    short = Request(rid=2, arrival_time=0.30, prompt_len=2048, output_len=8,
                    slo=slo)
    sim.add_trace([r0, monster, short])
    m = sim.run(until=120.0)

    print(f"R0 (decode on multiplexing worker): tpot={r0.tpot()*1000:.1f}ms "
          f"(SLO {slo.tpot*1000:.0f}ms) ok={r0.tpot_ok()}")
    print(f"R2 (short prefill, arrived behind a 32k prompt): "
          f"ttft={short.ttft():.2f}s (SLO {slo.ttft:.0f}s) "
          f"served_on_worker={short.worker} ok={short.ttft_ok()}")
    print(f"R1 (32k prompt on prefill worker): ttft={monster.ttft():.2f}s")
    print(f"attainment={m.slo_attainment:.2f} — the short prefill was "
          f"absorbed by R0's banked TPOT slack on the multiplexing worker")


if __name__ == "__main__":
    main()
