"""Fig. 8 — SLO attainment vs arrival rate (the paper's headline result).

(a) combined SLO attainment A = |R_TTFT ∩ R_TPOT| / |R| per policy per rate;
(b) the TTFT/TPOT attainment split (Pareto view).

Headline metric: max sustained rate at A >= 0.9 — the paper reports
Tropical serving 2.02-2.09x more than the best baseline.
"""
from __future__ import annotations

from benchmarks.common import POLICIES, cost_model, emit, make_trace, run_policy

RATES = (1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 8.0)
DURATION = 300.0


def main(rates=RATES, duration=DURATION) -> list[dict]:
    cm = cost_model()
    rows = []
    best_rate = {p: 0.0 for p in POLICIES}
    for rate in rates:
        trace = make_trace(rate, duration, cm, seed=11)
        for pol in POLICIES:
            m = run_policy(pol, trace, until=duration * 6)
            rows.append({
                "policy": pol, "rate": rate,
                "slo_attainment": round(m.slo_attainment, 3),
                "ttft_attainment": round(m.ttft_attainment, 3),
                "tpot_attainment": round(m.tpot_attainment, 3),
                "finished": m.n_finished, "total": m.n_total,
            })
            if m.slo_attainment >= 0.9:
                best_rate[pol] = max(best_rate[pol], rate)
    base = max(best_rate[p] for p in ("vllm", "sarathi", "distserve"))
    rows.append({
        "policy": "summary",
        "tropical_rate_at_90": best_rate["tropical"],
        "best_baseline_rate_at_90": base,
        "goodput_ratio": round(best_rate["tropical"] / max(base, 1e-9), 2),
    })
    emit("fig8_slo_attainment", rows)
    return rows


if __name__ == "__main__":
    main()
