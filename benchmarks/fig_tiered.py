"""Tiered KV + prefix reuse under memory pressure (beyond-paper figure).

The agentic scenario (short shared prompts, long generations) on
half-HBM workers drives decode KV through the preemption watermark. Three
tropical configurations on the identical trace:

    evict           seed behaviour — watermark victims lose their KV and
                    pay a full re-prefill on readmission
    tiered          a host-DRAM tier absorbs victims over the host DMA
                    link; restore (wire + residue) is priced against
                    re-prefill by the predictor, so spills happen only
                    when they win
    tiered+prefix   tiered + the per-worker cross-request prefix cache:
                    requests sharing an agentic system prompt skip the
                    cached span of prefill

Guard (the PR's acceptance assertion): tiered+prefix must beat evict-only
on TTFT attainment (and not regress P90 TTFT) with a non-zero prefix hit
rate, and the evict config must report exactly zero tier traffic — the
zero-DRAM path is the seed path.

Usage: PYTHONPATH=src python -m benchmarks.fig_tiered [--quick]
"""
from __future__ import annotations

import argparse
import copy
import dataclasses

from benchmarks.common import MODEL, WORKER, emit
from repro.configs import get_config
from repro.perf import CostModel
from repro.serving.simulator import build_cluster
from repro.workload import get_scenario

RATE = 6.0
DURATION = 240.0
N_WORKERS = 2
HOST_KV_GB = 16.0
SEED = 23

# half the v5e HBM per chip: same compute, ~97k KV tokens per worker
# instead of ~390k — the watermark becomes the binding constraint for
# agentic decode growth (the regime the host tier exists for)
SMALL_WORKER = dataclasses.replace(
    WORKER, hw=dataclasses.replace(WORKER.hw,
                                   hbm_bytes=WORKER.hw.hbm_bytes / 2))

CONFIGS = (
    ("evict", 0.0, False),
    ("tiered", HOST_KV_GB, False),
    ("tiered+prefix", HOST_KV_GB, True),
)


def run_config(trace, host_kv_gb: float, prefix_cache: bool,
               duration: float):
    sim, _ = build_cluster(
        get_config(MODEL), "tropical", n_workers=N_WORKERS,
        worker_spec=SMALL_WORKER, host_kv_gb=host_kv_gb,
        prefix_cache=prefix_cache)
    sim.add_trace(copy.deepcopy(trace))
    return sim.run(until=duration * 10)


def main(rate=RATE, duration=DURATION) -> list[dict]:
    cm = CostModel(get_config(MODEL), SMALL_WORKER)
    trace = get_scenario("agentic").generate(rate, duration, cm, seed=SEED)
    rows, by_name = [], {}
    for name, host_gb, prefix in CONFIGS:
        m = run_config(trace, host_gb, prefix, duration)
        by_name[name] = m
        rows.append({
            "config": name, "rate": rate,
            "slo_attainment": round(m.slo_attainment, 3),
            "ttft_attainment": round(m.ttft_attainment, 3),
            "tpot_attainment": round(m.tpot_attainment, 3),
            "ttft_p90": round(m.ttft_p90, 4),
            "tpot_p90": round(m.tpot_p90, 5),
            "preemptions": m.preemptions,
            "kv_offloads": m.kv_offloads,
            "kv_restores": m.kv_restores,
            "pages_reprefilled": m.pages_reprefilled,
            "prefix_hit_rate": round(m.prefix_hit_rate, 4),
            "finished": m.n_finished, "total": m.n_total,
        })

    evict, best = by_name["evict"], by_name["tiered+prefix"]
    rows.append({
        "config": "summary",
        "evict_ttft_attainment": round(evict.ttft_attainment, 4),
        "tiered_prefix_ttft_attainment": round(best.ttft_attainment, 4),
        "evict_ttft_p90": round(evict.ttft_p90, 4),
        "tiered_prefix_ttft_p90": round(best.ttft_p90, 4),
        "prefix_hit_rate": round(best.prefix_hit_rate, 4),
        "kv_offloads": best.kv_offloads,
    })
    # the evict config IS the seed path: zero tier traffic, zero lookups
    assert evict.kv_offloads == 0 and evict.kv_restores == 0
    assert evict.prefix_lookups == 0
    # memory pressure actually bites (otherwise this figure tests nothing)
    assert evict.preemptions > 0, "no watermark pressure at this rate"
    # the PR's headline guard: offload-instead-of-evict + prefix reuse
    # must not lose TTFT attainment, and must actually exercise the tier
    assert best.ttft_attainment >= evict.ttft_attainment, \
        (best.ttft_attainment, evict.ttft_attainment)
    assert best.ttft_p90 <= evict.ttft_p90 * 1.05, \
        (best.ttft_p90, evict.ttft_p90)
    assert best.prefix_hit_rate > 0.0
    emit("fig_tiered", rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    if a.quick:
        main(rate=RATE, duration=60.0)
    else:
        main()
