"""Fig. 3 — workload characterisation: token arrivals over time (burstiness)
and the (prefill, decode) length distribution of the Mooncake-like trace."""
from __future__ import annotations

import numpy as np

from benchmarks.common import cost_model, emit
from repro.serving.trace import MOONCAKE, generate_trace


def main() -> list[dict]:
    cm = cost_model()
    trace = generate_trace(rate=2.0, duration=600.0, cost_model=cm, seed=7)
    inputs = np.array([r.prompt_len for r in trace])
    outputs = np.array([r.output_len for r in trace])
    t = np.array([r.arrival_time for r in trace])

    # (a) tokens arrived per 10s window — short-term dynamism
    bins = np.arange(0, 601, 10.0)
    per_window, _ = np.histogram(t, bins=bins, weights=inputs)
    cv = per_window.std() / max(per_window.mean(), 1e-9)

    rows = [{
        "n_requests": len(trace),
        "input_mean": int(inputs.mean()), "input_p50": int(np.median(inputs)),
        "input_p90": int(np.percentile(inputs, 90)),
        "input_p99": int(np.percentile(inputs, 99)),
        "input_max": int(inputs.max()),
        "output_mean": int(outputs.mean()),
        "output_p90": int(np.percentile(outputs, 90)),
        "window_tokens_cv": round(float(cv), 3),
        "input_over_output_dynamic_range": round(
            float(np.percentile(inputs, 99) / np.percentile(outputs, 99)), 1),
    }]
    emit("fig3_workload", rows)
    return rows


if __name__ == "__main__":
    main()
