"""Fig. 5 — P90 latency under different prefill:decode worker splits for
three (input, output) configurations: static allocation cannot match both
phases (Characterization III / leaky-bucket)."""
from __future__ import annotations

import copy

from benchmarks.common import MODEL, WORKER, cost_model, emit
from repro.configs import get_config
from repro.core.request import Request
from repro.serving.simulator import build_cluster
from repro.core.metrics import derive_slos
import numpy as np


CONFIGS = [(8192, 64), (8192, 256), (16384, 256)]
SPLITS = [(1, 3), (2, 2), (3, 1)]
RATE = 1.2
DURATION = 300.0


def fixed_trace(cm, inp, out, rate, seed=0):
    rng = np.random.default_rng(seed)
    n = rng.poisson(rate * DURATION)
    times = np.sort(rng.uniform(0, DURATION, n))
    slo = derive_slos(cm, inp)
    return [Request(rid=i, arrival_time=float(t), prompt_len=inp,
                    output_len=out, slo=slo) for i, t in enumerate(times)]


def main() -> list[dict]:
    cm = cost_model()
    rows = []
    for inp, out in CONFIGS:
        trace = fixed_trace(cm, inp, out, RATE)
        for n_p, n_d in SPLITS:
            sim, _ = build_cluster(get_config(MODEL), "distserve",
                                   n_workers=n_p + n_d, worker_spec=WORKER,
                                   n_prefill=n_p)
            sim.add_trace(copy.deepcopy(trace))
            m = sim.run(until=1500.0)
            rows.append({
                "input": inp, "output": out, "split": f"{n_p}p{n_d}d",
                "ttft_p90_s": round(m.ttft_p90, 3),
                "tpot_p90_s": round(m.tpot_p90, 4),
                "slo_attainment": round(m.slo_attainment, 3),
                "finished": m.n_finished,
            })
    emit("fig5_worker_allocation", rows)
    return rows


if __name__ == "__main__":
    main()
