"""Fig. 9 — average and P90 TTFT / TPOT / total latency per policy.

Paper claims to validate: Tropical ~9x better P90 TTFT than DistServe at
~15% P90 TPOT cost; >=2.33x better P90 TPOT than vLLM(+chunked) at equal
TTFT."""
from __future__ import annotations

from benchmarks.common import POLICIES, cost_model, emit, make_trace, run_policy

RATE = 5.0
DURATION = 300.0


def main(rate=RATE) -> list[dict]:
    cm = cost_model()
    trace = make_trace(rate, DURATION, cm, seed=23)
    rows = []
    res = {}
    for pol in POLICIES:
        m = run_policy(pol, trace, until=DURATION * 6)
        res[pol] = m
        rows.append({
            "policy": pol, "rate": rate,
            "ttft_avg_s": round(m.ttft_avg, 3),
            "ttft_p90_s": round(m.ttft_p90, 3),
            "tpot_avg_s": round(m.tpot_avg, 4),
            "tpot_p90_s": round(m.tpot_p90, 4),
            "blocked_avg_s": round(m.blocked_time_avg, 3),
            "migrations": m.migrations,
        })
    t, d, v = res["tropical"], res["distserve"], res["vllm"]
    rows.append({
        "policy": "ratios",
        "ttft_p90_vs_distserve": round(d.ttft_p90 / max(t.ttft_p90, 1e-9), 2),
        "tpot_p90_cost_vs_distserve": round(
            (t.tpot_p90 - d.tpot_p90) / max(d.tpot_p90, 1e-9), 3),
        "tpot_p90_vs_vllm": round(v.tpot_p90 / max(t.tpot_p90, 1e-9), 2),
        "ttft_p90_vs_vllm": round(t.ttft_p90 / max(v.ttft_p90, 1e-9), 2),
    })
    emit("fig9_latency", rows)
    return rows


if __name__ == "__main__":
    main()
