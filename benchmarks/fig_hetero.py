"""Heterogeneous cluster: per-worker calibration vs the global predictor.

A realistic failure mode the ROADMAP left open: one worker in the cluster
is a 2x-slow straggler (older chip generation, thermal throttling,
degraded HBM) and the offline profile does not know — every worker is
priced with the nominal fast spec. The pre-perf-package stack can only
EWMA-correct a single global scale per phase, which converges to a
traffic-weighted blend of the workers' biases: it under-prices the
straggler (TTFT misses on everything dispatched there) while over-pricing
the fast workers (refused multiplexing, wasted capacity).

Configurations compared at the reference rate, mean over fixed seeds:

  homogeneous   4 fast workers (what the cluster was supposed to be)
  hetero-oracle 3 fast + 1 slow, exact per-worker analytic pricing +
                true speed-normalised load (``ClusterPredictor`` — the
                operator knew the hardware)
  hetero-global 3 fast + 1 slow, nominal profile + global-scale
                ``OnlinePredictor`` and no speed knowledge (legacy stack)
  hetero-pw     3 fast + 1 slow, nominal profile + per-(worker, phase,
                bucket) ``OnlinePredictor``, and — like hetero-global —
                NO speed oracle: the straggler is entirely *learned* from
                observed durations, so the comparison isolates the
                calibration mechanism

Asserts (1) per-worker calibration strictly beats the global-scale
predictor on mean SLO attainment, and (2) the measured-MFU calibrated
roofline (``repro.perf.calibrate``) produces efficiency fractions in
(0, 1] from real Pallas kernel runs.

Usage: PYTHONPATH=src python -m benchmarks.fig_hetero [--quick]
"""
from __future__ import annotations

import argparse
import copy
import dataclasses

from benchmarks.common import MODEL, WORKER, cost_model, emit, make_trace
from repro.configs import get_config
from repro.perf import (AnalyticalPredictor, ClusterPredictor, CostModel,
                        OnlinePredictor)
from repro.serving.simulator import build_cluster

RATE = 4.0               # the knee where straggler mispricing binds
DURATION = 120.0
SEEDS = (5, 7, 11, 13)
SLOW_FACTOR = 2.0


def _run(cfg, trace, specs, predictor, know_speed: bool):
    sim, _ = build_cluster(cfg, "tropical", n_workers=len(specs),
                           worker_spec=specs[0], worker_specs=specs,
                           predictor=predictor)
    if not know_speed:
        # the legacy stack has no notion of per-worker speed: every load
        # comparison treats the straggler as a full-speed peer
        for w in sim.workers.values():
            w.view.speed = 1.0
    sim.add_trace(copy.deepcopy(trace))
    return sim.run(until=DURATION * 10)


def main(rate=RATE, duration=DURATION, seeds=SEEDS,
         slow_factor=SLOW_FACTOR) -> list[dict]:
    cm = cost_model()
    cfg = get_config(MODEL)
    fast = WORKER
    slow = dataclasses.replace(fast, hw=fast.hw.slowed(slow_factor))
    hetero = [fast, fast, fast, slow]
    homog = [fast, fast, fast, fast]

    def nominal():
        """The miscalibrated offline profile: fast hardware everywhere."""
        return AnalyticalPredictor(CostModel(cfg, fast))

    def oracle_pred():
        costs = {i: CostModel(cfg, s) for i, s in enumerate(hetero)}
        return ClusterPredictor(costs)

    configs = {
        "homogeneous": (homog, nominal, True),
        "hetero-oracle": (hetero, oracle_pred, True),
        "hetero-global": (
            hetero, lambda: OnlinePredictor(nominal(), per_worker=False),
            False),
        "hetero-pw": (
            hetero, lambda: OnlinePredictor(nominal(), per_worker=True),
            False),
    }
    # one trace per seed, shared by every config: the comparison is
    # always over identical arrival streams
    traces = {seed: make_trace(rate, duration, cm, seed=seed)
              for seed in seeds}
    rows, means = [], {}
    for tag, (specs, mk_pred, know_speed) in configs.items():
        atts = []
        for seed in seeds:
            m = _run(cfg, traces[seed], specs, mk_pred(), know_speed)
            atts.append(m.slo_attainment)
            rows.append({
                "config": tag, "rate": rate, "seed": seed,
                "slow_factor": slow_factor if "hetero" in tag else 1.0,
                "slo_attainment": round(m.slo_attainment, 3),
                "ttft_attainment": round(m.ttft_attainment, 3),
                "tpot_attainment": round(m.tpot_attainment, 3),
                "finished": m.n_finished, "total": m.n_total,
            })
        means[tag] = sum(atts) / len(atts)
    rows.append({"config": "summary", "rate": rate,
                 **{f"mean_{k.replace('-', '_')}": round(v, 4)
                    for k, v in means.items()}})

    # measured-MFU roofline: real Pallas kernels, sane efficiency fractions
    from repro.perf import calibrate_hardware
    hw, cal = calibrate_hardware(fast.hw)
    assert 0.0 < hw.mfu_prefill <= 1.0, hw.mfu_prefill
    assert 0.0 < hw.mfu_decode <= 1.0, hw.mfu_decode
    assert 0.0 < hw.bw_eff <= 1.0, hw.bw_eff
    rows.append({"config": "calibrated-roofline", "device": cal.device,
                 "mfu_prefill": f"{hw.mfu_prefill:.3g}",
                 "mfu_decode": f"{hw.mfu_decode:.3g}",
                 "bw_eff": f"{hw.bw_eff:.3g}"})

    emit("fig_hetero", rows)
    # the acceptance claim: learning the straggler recovers attainment the
    # blended global scale cannot
    assert means["hetero-pw"] > means["hetero-global"], means
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    if a.quick:
        main(seeds=(7, 11))
    else:
        main()
