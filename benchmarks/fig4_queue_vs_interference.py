"""Fig. 4 — queuing vs interference by context length.

(a) prefill-time breakdown (execution vs queuing) per context bucket;
(b) decode blocked-time (interference) per context bucket;
non-disaggregated (vllm) vs disaggregated (distserve).

Expected reproduction of Characterization II: short contexts are
queue-dominated (disaggregated ~10x worse queuing), long contexts are
interference-dominated (non-disaggregated blocked-time grows with length).
"""
from __future__ import annotations

import copy

import numpy as np

from benchmarks.common import MODEL, N_WORKERS, WORKER, cost_model, emit, make_trace
from repro.configs import get_config
from repro.serving.simulator import build_cluster

BUCKETS = [(0, 2048), (2048, 8192), (8192, 32768), (32768, 1 << 20)]


def main() -> list[dict]:
    cm = cost_model()
    trace = make_trace(5.0, 400.0, cm, seed=1)
    rows = []
    for pol in ("vllm", "distserve"):
        sim, _ = build_cluster(get_config(MODEL), pol, n_workers=N_WORKERS,
                               worker_spec=WORKER)
        sim.add_trace(copy.deepcopy(trace))
        sim.run(until=2000.0)
        queue_by_rid, blocked_by_rid = {}, {}
        for w in sim.workers.values():
            queue_by_rid.update(w.queue_times)
            blocked_by_rid.update(w.blocked_time)
        for lo, hi in BUCKETS:
            reqs = [r for r in sim.requests if lo <= r.prompt_len < hi
                    and r.first_token_time is not None]
            if not reqs:
                continue
            queues = [queue_by_rid.get(r.rid, 0.0) for r in reqs]
            execs = [r.first_token_time - r.arrival_time
                     - queue_by_rid.get(r.rid, 0.0) for r in reqs]
            blocked = [blocked_by_rid.get(r.rid, 0.0)
                       / max(r.generated_tokens, 1) for r in reqs]
            rows.append({
                "policy": pol, "ctx_lo": lo, "ctx_hi": hi, "n": len(reqs),
                "queue_p90_s": round(float(np.percentile(queues, 90)), 3),
                "exec_p90_s": round(float(np.percentile(execs, 90)), 3),
                "queue_over_exec": round(
                    float(np.mean(queues) / max(np.mean(execs), 1e-9)), 2),
                "blocked_per_token_s": round(float(np.mean(blocked)), 4),
            })
    emit("fig4_queue_vs_interference", rows)
    return rows


if __name__ == "__main__":
    main()
