"""Fig. 11 — TTFT and TPOT CDFs per policy (tail-latency view)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import POLICIES, cost_model, emit, make_trace, run_policy

RATE = 5.0
DURATION = 300.0
QUANTILES = (0.5, 0.9, 0.95, 0.99)


def main() -> list[dict]:
    cm = cost_model()
    trace = make_trace(RATE, DURATION, cm, seed=41)
    rows = []
    for pol in POLICIES:
        m = run_policy(pol, trace, until=DURATION * 6)
        for q in QUANTILES:
            rows.append({
                "policy": pol, "quantile": q,
                "ttft_s": round(float(np.percentile(m.ttfts, q * 100)), 3)
                if m.ttfts else None,
                "tpot_s": round(float(np.percentile(m.tpots, q * 100)), 4)
                if m.tpots else None,
            })
    emit("fig11_cdf", rows)
    return rows


if __name__ == "__main__":
    main()
