"""Shared benchmark plumbing: cluster construction + CSV emission."""
from __future__ import annotations

import sys
import time

from repro.configs import get_config
from repro.core.metrics import ServeMetrics
from repro.serving.costmodel import CostModel, WorkerSpec
from repro.serving.simulator import build_cluster
from repro.serving.trace import MOONCAKE, generate_trace

MODEL = "internlm-20b"          # the paper's evaluation model
# Worker = 8 v5e chips (128 GB HBM) ~ the paper's 2xA100-80GB worker class:
# comparable KV headroom (~90 GB after weights), so the experiments sit in
# the paper's interference-vs-queueing regime rather than a KV-admission-
# limited one (DESIGN.md §7 hardware adaptation).
WORKER = WorkerSpec(tp=8)
N_WORKERS = 4                   # paper: 8 GPUs -> 4 workers
POLICIES = ("vllm", "sarathi", "distserve", "tropical")


def cost_model() -> CostModel:
    return CostModel(get_config(MODEL), WORKER)


def fixed_slo(cm: CostModel, mean_prompt: int = 8192):
    """Paper §V-A: one SLO pair per experiment — 5x the light-load latency
    of each phase (prefill of the mean prompt; single-request decode)."""
    from repro.core.request import SLOSpec
    return SLOSpec(ttft=5.0 * cm.prefill_time(mean_prompt),
                   tpot=5.0 * cm.decode_iter_time(1, float(mean_prompt)))


def make_trace(rate: float, duration: float, cm: CostModel, seed: int):
    return generate_trace(rate=rate, duration=duration, cost_model=cm,
                          seed=seed, fixed_slo=fixed_slo(cm))


def clone_trace(trace) -> list:
    """Cheap replay copy of a *pristine* trace: fresh ``Request`` objects
    carrying only the generation-time fields (runtime state starts at the
    dataclass defaults), sharing the frozen ``SLOClass`` instances.

    Equivalent to ``copy.deepcopy`` on a never-run trace at a fraction of
    the cost — deepcopy walks all ~25 fields plus the SLO objects per
    request, which dominates setup time for 100k-request scale sweeps.
    The master trace must never be handed to a simulator directly (runs
    mutate requests in place); always feed clones."""
    from repro.core.request import Request
    return [Request(rid=r.rid, arrival_time=r.arrival_time,
                    prompt_len=r.prompt_len, output_len=r.output_len,
                    slo=r.slo, prefix_key=r.prefix_key,
                    prefix_len=r.prefix_len)
            for r in trace]


def run_policy(policy: str, trace, until: float = 3600.0,
               n_workers: int = N_WORKERS, **kw) -> ServeMetrics:
    cfg = get_config(MODEL)
    sim, _ = build_cluster(cfg, policy, n_workers=n_workers,
                           worker_spec=WORKER, **kw)
    sim.add_trace(clone_trace(trace))
    return sim.run(until=until)


def emit(name: str, rows: list[dict]) -> None:
    """CSV rows to stdout: name,key=value,... one line per row (the
    ``name,us_per_call,derived`` convention extended with labelled cols)."""
    for r in rows:
        cols = ",".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{cols}")
    sys.stdout.flush()


def timed(fn):
    t0 = time.perf_counter()  # lint: allow-wallclock(measured benchmark wall time)
    out = fn()
    return out, time.perf_counter() - t0  # lint: allow-wallclock(measured benchmark wall time)
