"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json and prints the per-(arch x shape x mesh)
three-term roofline: compute / memory / collective seconds, dominant term,
MODEL_FLOPS/HLO_FLOPS useful ratio, roofline fraction."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

DRYRUN = Path("experiments/dryrun")


def main() -> list[dict]:
    rows = []
    for f in sorted(DRYRUN.glob("*.json")):
        r = json.loads(f.read_text())
        if r["status"] != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "status": r["status"]})
            continue
        rl = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok",
            "compute_us": round(rl["compute_s"] * 1e6, 1),
            "memory_us": round(rl["memory_s"] * 1e6, 1),
            "collective_us": round(rl["collective_s"] * 1e6, 1),
            "dominant": rl["dominant"],
            "useful_ratio": round(rl["useful_ratio"], 4),
            "roofline_fraction": round(rl["roofline_fraction"], 4),
            "mem_gb_per_dev": round(r["bytes_per_device"] / 1e9, 2),
        })
    emit("roofline", rows)
    return rows


if __name__ == "__main__":
    main()
