"""Predictor-robustness ablation (beyond-paper): Tropical's admission
hinges on the §IV-C execution-time predictor. How much predictor error
before SLO-aware multiplexing stops paying?

We inject multiplicative lognormal noise into the predictor (the executor
stays exact) and sweep sigma; also sweep the safety margin.
"""
from __future__ import annotations

import copy

import numpy as np

from benchmarks.common import MODEL, WORKER, cost_model, emit, make_trace
from repro.configs import get_config
from repro.core.predictor import AnalyticalPredictor
from repro.serving.costmodel import CostModel
from repro.serving.simulator import build_cluster

RATE = 5.0
DURATION = 180.0


class NoisyPredictor(AnalyticalPredictor):
    def __init__(self, cost, sigma: float, safety: float = 1.1, seed: int = 0):
        super().__init__(cost, safety=safety)
        self.rng = np.random.default_rng(seed)
        self.sigma = sigma

    def _noise(self) -> float:
        return float(self.rng.lognormal(0.0, self.sigma)) if self.sigma else 1.0

    def predict_prefill(self, tokens, ctx_offset=0):
        return super().predict_prefill(tokens, ctx_offset) * self._noise()

    def predict_decode_iter(self, n, ctx):
        return super().predict_decode_iter(n, ctx) * self._noise()


def main() -> list[dict]:
    cm = cost_model()
    trace = make_trace(RATE, DURATION, cm, seed=9)
    rows = []
    for sigma in (0.0, 0.2, 0.5, 1.0):
        cost = CostModel(get_config(MODEL), WORKER)
        pred = NoisyPredictor(cost, sigma)
        sim, _ = build_cluster(get_config(MODEL), "tropical", n_workers=4,
                               worker_spec=WORKER, predictor=pred)
        sim.add_trace(copy.deepcopy(trace))
        m = sim.run(until=DURATION * 6)
        rows.append({
            "sigma": sigma,
            "slo_attainment": round(m.slo_attainment, 3),
            "ttft_attainment": round(m.ttft_attainment, 3),
            "tpot_attainment": round(m.tpot_attainment, 3),
        })
    emit("predictor_noise", rows)
    return rows


if __name__ == "__main__":
    main()
