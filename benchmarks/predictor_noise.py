"""Predictor-robustness ablation (beyond-paper): Tropical's admission
hinges on the §IV-C execution-time predictor. How much predictor error
before SLO-aware multiplexing stops paying?

Two experiments:

* noise sweep — multiplicative lognormal noise injected into the predictor
  (the executor stays exact), sigma swept;
* bias + online recovery — a systematically 2x-overestimating predictor
  makes the toggle too conservative (Path-② admissions refused, prefill
  queues grow, TTFT attainment collapses). ``OnlinePredictor`` closes the
  §IV-C loop: the scheduler feeds observed iteration durations back and
  the EWMA correction converges on the true scale. The run asserts the
  online wrapper recovers at least half of the bias-induced attainment
  gap — the PR-2 acceptance guard.
"""
from __future__ import annotations

import copy

import numpy as np

from benchmarks.common import MODEL, WORKER, cost_model, emit, make_trace
from repro.configs import get_config
from repro.core.predictor import (AnalyticalPredictor, BiasedPredictor,
                                  OnlinePredictor)
from repro.serving.costmodel import CostModel
from repro.serving.simulator import build_cluster

RATE = 5.0
DURATION = 180.0
BIAS = 2.0


class NoisyPredictor(AnalyticalPredictor):
    def __init__(self, cost, sigma: float, safety: float = 1.1, seed: int = 0):
        super().__init__(cost, safety=safety)
        self.rng = np.random.default_rng(seed)
        self.sigma = sigma

    def _noise(self) -> float:
        return float(self.rng.lognormal(0.0, self.sigma)) if self.sigma else 1.0

    def predict_prefill(self, tokens, ctx_offset=0, wid=None):
        return super().predict_prefill(tokens, ctx_offset, wid) * self._noise()

    def predict_decode_iter(self, n, ctx, wid=None):
        return super().predict_decode_iter(n, ctx, wid) * self._noise()


def _run(predictor, trace, duration):
    sim, _ = build_cluster(get_config(MODEL), "tropical", n_workers=4,
                           worker_spec=WORKER, predictor=predictor)
    sim.add_trace(copy.deepcopy(trace))
    m = sim.run(until=duration * 6)
    return m, sim.policy.predictor


def main(quick: bool = False) -> list[dict]:
    duration = 60.0 if quick else DURATION
    sigmas = (0.0, 0.5) if quick else (0.0, 0.2, 0.5, 1.0)
    cm = cost_model()
    trace = make_trace(RATE, duration, cm, seed=9)
    rows = []
    for sigma in sigmas:
        cost = CostModel(get_config(MODEL), WORKER)
        m, _ = _run(NoisyPredictor(cost, sigma), trace, duration)
        rows.append({
            "sigma": sigma,
            "slo_attainment": round(m.slo_attainment, 3),
            "ttft_attainment": round(m.ttft_attainment, 3),
            "tpot_attainment": round(m.tpot_attainment, 3),
        })

    # --- bias + online recovery -------------------------------------------
    atts = {}
    for variant in ("exact", "biased", "biased_online"):
        cost = CostModel(get_config(MODEL), WORKER)
        if variant == "exact":
            pred = AnalyticalPredictor(cost)
        elif variant == "biased":
            pred = BiasedPredictor(cost, BIAS)
        else:
            pred = OnlinePredictor(BiasedPredictor(cost, BIAS))
        m, pred_after = _run(pred, trace, duration)
        atts[variant] = m.slo_attainment
        row = {
            "variant": variant, "bias": BIAS,
            "slo_attainment": round(m.slo_attainment, 3),
            "ttft_attainment": round(m.ttft_attainment, 3),
            "tpot_attainment": round(m.tpot_attainment, 3),
        }
        if isinstance(pred_after, OnlinePredictor):
            row.update(
                prefill_scale=round(pred_after.prefill_scale, 3),
                decode_scale=round(pred_after.decode_scale, 3),
                observations=(pred_after.prefill_observations
                              + pred_after.decode_observations))
        rows.append(row)

    gap = atts["exact"] - atts["biased"]
    recovered = atts["biased_online"] - atts["biased"]
    rows.append({
        "variant": "recovery_summary", "bias": BIAS,
        "gap": round(gap, 3), "recovered": round(recovered, 3),
        "recovered_frac": round(recovered / gap, 2) if gap > 1e-9 else 1.0,
    })
    # emit BEFORE the guard: a failing assertion must not discard the very
    # rows (scales, observation counts) needed to debug it
    emit("predictor_noise", rows)
    # acceptance guard: the online loop must win back >= half the gap the
    # biased predictor opened (when bias costs anything at this load)
    if gap > 0.01 and recovered < 0.5 * gap:
        raise AssertionError(
            f"OnlinePredictor recovered {recovered:.3f} of a {gap:.3f} "
            f"attainment gap (< half)")
    return rows


if __name__ == "__main__":
    main()
