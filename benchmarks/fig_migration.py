"""SLO attainment vs KV migration bandwidth (beyond-paper figure).

The contended transfer engine (serving/transfer.py) makes the
disaggregation penalty explicit: DistServe migrates *every* request P->D,
so as per-link ICI bandwidth shrinks its post-migration inter-token
latency blows through the TPOT SLO, while Tropical (decode-in-place for
Path-②, transfer-aware dispatch for Path-①) and the non-disaggregated
baselines (sarathi/vllm — zero migrations) stay comparatively flat.

Also the regression guard on the cost model: with bandwidth effectively
infinite, the contended engine must reproduce the legacy fixed-delay
metrics for every policy (rows tagged ``check=infbw``).

Usage: PYTHONPATH=src python -m benchmarks.fig_migration [--quick]
"""
from __future__ import annotations

import argparse
import copy

from benchmarks.common import (MODEL, N_WORKERS, POLICIES, WORKER,
                               cost_model, emit, make_trace)
from repro.configs import get_config
from repro.serving.simulator import build_cluster

GB = 1e9
# per-link bandwidth sweep; hardware default is 50 GB/s x 2 links
BANDWIDTHS = (0.05 * GB, 0.2 * GB, 1 * GB, 5 * GB, 50 * GB)
RATE = 3.0
DURATION = 300.0


def run_policy_bw(policy: str, trace, bw: float | None,
                  use_engine: bool = True, until: float = 36000.0):
    sim, _ = build_cluster(get_config(MODEL), policy, n_workers=N_WORKERS,
                           worker_spec=WORKER, ici_bw=bw,
                           use_transfer_engine=use_engine)
    sim.add_trace(copy.deepcopy(trace))
    m = sim.run(until=until)
    return m, sim


def main(bandwidths=BANDWIDTHS, rate=RATE, duration=DURATION) -> list[dict]:
    cm = cost_model()
    trace = make_trace(rate, duration, cm, seed=17)
    rows = []
    for bw in bandwidths:
        for pol in POLICIES:
            m, sim = run_policy_bw(pol, trace, bw)
            rows.append({
                "policy": pol, "ici_bw_gbps": round(bw / GB, 3),
                "slo_attainment": round(m.slo_attainment, 3),
                "ttft_attainment": round(m.ttft_attainment, 3),
                "tpot_attainment": round(m.tpot_attainment, 3),
                "migrations": m.migrations,
                "migration_wait_avg": round(m.migration_wait_avg, 4),
                "preemptions": m.preemptions,
                "finished": m.n_finished, "total": m.n_total,
            })

    # regression guard: infinite bandwidth == legacy fixed-delay model
    for pol in POLICIES:
        m_new, _ = run_policy_bw(pol, trace, bw=1e21, use_engine=True)
        m_old, _ = run_policy_bw(pol, trace, bw=1e21, use_engine=False)
        drift = abs(m_new.slo_attainment - m_old.slo_attainment)
        rows.append({
            "policy": pol, "check": "infbw",
            "engine_slo": round(m_new.slo_attainment, 4),
            "legacy_slo": round(m_old.slo_attainment, 4),
            "engine_ttft_avg": round(m_new.ttft_avg, 5),
            "legacy_ttft_avg": round(m_old.ttft_avg, 5),
            "drift": round(drift, 5),
            "ok": drift < 1e-3 and m_new.migrations == m_old.migrations,
        })
        assert drift < 1e-3, (pol, m_new.slo_attainment, m_old.slo_attainment)
        assert m_new.migrations == m_old.migrations, \
            (pol, m_new.migrations, m_old.migrations)

    emit("fig_migration", rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    if a.quick:
        main(bandwidths=(0.05 * GB, 1 * GB, 50 * GB), rate=2.0,
             duration=60.0)
    else:
        main()
