"""CI perf-regression gate over BENCH_summary.json.

Compares a freshly generated ``benchmarks.run --quick`` summary against
the committed snapshot and fails (exit 1) on regressions beyond per-key
tolerances:

  * attainment-like keys (fractions in [0, 1]) may not DROP by more than
    ``ATTAINMENT_DROP`` (2 points) — rises are always fine;
  * latency/step-time keys (``*_s``/``*_ms`` suffixes) may not REGRESS
    (grow) by more than ``LATENCY_REGRESS`` (25%) — speedups are always
    fine;
  * throughput-like keys (``*_rps``/``*_speedup`` suffixes) may not DROP
    by more than ``RPS_DROP`` (20%) — improvements always pass;
  * counters/config keys (``n_requests``, ``ref_rate``, ``schema_version``)
    must match exactly: a changed request count means the quick sweep
    itself changed, which is a snapshot refresh, not noise.

A key present in the snapshot but missing from the fresh run (or vice
versa) is an error — the snapshot must be regenerated in the same PR that
changes the summary layout (ROADMAP "CI perf gate" documents the
legitimate-refresh workflow).

Usage:
    python benchmarks/check_summary.py BENCH_fresh.json [BENCH_summary.json]

Exit 0 = within tolerances (per-key report on stdout), 1 = regression or
schema mismatch, 2 = unreadable/invalid input.
"""
from __future__ import annotations

import json
import sys

ATTAINMENT_DROP = 0.02       # absolute points a fraction may fall
LATENCY_REGRESS = 0.25       # relative growth a *_s latency may show
RPS_DROP = 0.20              # relative fall a *_rps throughput may show

# keys outside both heuristics: identity must hold exactly. The
# *_workers keys are the scale-tier size the gated rps/speedup numbers
# were measured at — a silent size change would make those comparisons
# meaningless, so the size itself must match.
EXACT_KEYS = {"schema_version", "ref_rate", "n_requests", "generator",
              "sim_throughput_workers", "sim_engine_workers"}


def classify(key: str, value) -> str:
    """'exact' | 'latency' | 'throughput' | 'attainment' | 'info'."""
    if key in EXACT_KEYS:
        return "exact"
    # *_ms/*_s must classify before the [0, 1] heuristic: a fast enough
    # real-executor step lands below 1.0 ms, and gating that as
    # attainment would invert the direction of the tolerance
    if key.endswith("_s") or key.endswith("_ms"):
        return "latency"
    # *_rps likewise: a slow enough sim could report a sub-1.0
    # requests-per-second figure
    if key.endswith("_rps") or key.endswith("_speedup"):
        return "throughput"
    if isinstance(value, (int, float)) and 0.0 <= float(value) <= 1.0:
        return "attainment"
    return "info"


def check(fresh: dict, snapshot: dict) -> list[str]:
    """Per-key verdict lines; lines starting with 'FAIL' gate the build."""
    lines = []
    missing = sorted(set(snapshot) - set(fresh))
    extra = sorted(set(fresh) - set(snapshot))
    for k in missing:
        lines.append(f"FAIL {k}: in snapshot but missing from fresh run "
                     "(regenerate the committed BENCH_summary.json)")
    for k in extra:
        lines.append(f"FAIL {k}: new key absent from snapshot "
                     "(regenerate the committed BENCH_summary.json)")
    for k in sorted(set(snapshot) & set(fresh)):
        old, new = snapshot[k], fresh[k]
        kind = classify(k, old)
        if kind == "exact":
            verdict = "ok" if old == new else "FAIL"
            lines.append(f"{verdict} {k}: {old!r} -> {new!r} (must match)")
        elif kind == "latency":
            unit = "ms" if k.endswith("_ms") else "s"
            limit = old * (1.0 + LATENCY_REGRESS)
            verdict = "ok" if new <= limit else "FAIL"
            lines.append(f"{verdict} {k}: {old:g}{unit} -> {new:g}{unit} "
                         f"(limit {limit:g}{unit}, +{LATENCY_REGRESS:.0%})")
        elif kind == "throughput":
            unit = "x" if k.endswith("_speedup") else "rps"
            limit = old * (1.0 - RPS_DROP)
            verdict = "ok" if new >= limit else "FAIL"
            lines.append(f"{verdict} {k}: {old:g} -> {new:g} {unit} "
                         f"(floor {limit:g}, -{RPS_DROP:.0%})")
        elif kind == "attainment":
            limit = old - ATTAINMENT_DROP
            verdict = "ok" if new >= limit else "FAIL"
            lines.append(f"{verdict} {k}: {old:g} -> {new:g} "
                         f"(floor {limit:g}, -{ATTAINMENT_DROP:g} pts)")
        else:
            lines.append(f"ok {k}: {old!r} -> {new!r} (informational)")
    return lines


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    if not 1 <= len(argv) <= 2:
        print(__doc__, file=sys.stderr)
        return 2
    fresh_path = argv[0]
    snap_path = argv[1] if len(argv) == 2 else "BENCH_summary.json"
    loaded = {}
    for label, path in (("fresh", fresh_path), ("snapshot", snap_path)):
        try:
            with open(path) as f:
                loaded[label] = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"error: cannot read {label} summary {path}: {e}",
                  file=sys.stderr)
            return 2
    for label, d in loaded.items():
        if not isinstance(d, dict) or "schema_version" not in d:
            print(f"error: {label} summary carries no schema_version "
                  f"(not a benchmarks.run summary?)", file=sys.stderr)
            return 2
    lines = check(loaded["fresh"], loaded["snapshot"])
    for line in lines:
        print(line)
    failures = [ln for ln in lines if ln.startswith("FAIL")]
    if failures:
        print(f"\n{len(failures)} regression(s) vs {snap_path}. If this "
              "change intentionally moves the headline numbers, regenerate "
              "the snapshot (PYTHONPATH=src python -m benchmarks.run "
              "--quick) and commit it in the same PR.")
        return 1
    print(f"\nall {len(lines)} keys within tolerance vs {snap_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
