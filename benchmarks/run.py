"""Benchmark driver: one function per paper table/figure + the roofline
table. Prints ``name,key=value,...`` CSV rows.

``--quick`` additionally writes ``BENCH_summary.json`` — a small,
schema-versioned record of the headline numbers (weighted attainment at
the reference rate, P90 TTFT/TPOT, mean step time) that the bench-smoke
CI job uploads on every push, seeding the perf-trajectory history.

``--profile`` wraps the whole sweep in ``cProfile`` and prints the top-25
cumulative-time entries to stderr — the first stop when a bench tier gets
slower.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig8]
                                               [--summary PATH] [--profile]
"""
from __future__ import annotations

import argparse
import contextlib
import json
import sys
import time


@contextlib.contextmanager
def maybe_profile(enabled: bool, top: int = 25):
    """Optionally run the body under cProfile, reporting the ``top``
    cumulative entries to stderr on exit (shared by run.py and serve.py)."""
    if not enabled:
        yield
        return
    import cProfile
    import pstats
    pr = cProfile.Profile()
    pr.enable()
    try:
        yield
    finally:
        pr.disable()
        stats = pstats.Stats(pr, stream=sys.stderr)
        stats.sort_stats("cumulative")
        print(f"# --profile: top {top} by cumulative time", file=sys.stderr)
        stats.print_stats(top)

SUMMARY_SCHEMA_VERSION = 5   # v5: real_step_ms + real_exec_speedup (batched
                             # real-executor fast path, scale real_exec
                             # tier); additive over v4 (sim_engine_rps)
REF_RATE = 2.0


def _canonical_run(ref_rate: float = REF_RATE, duration: float = 60.0):
    """One reference serving run for the summary's latency/step columns:
    tropical, 4 workers, the paper's §V-A trace at the reference rate."""
    import copy

    from benchmarks.common import MODEL, WORKER, cost_model, make_trace
    from repro.configs import get_config
    from repro.serving.simulator import build_cluster

    cm = cost_model()
    trace = make_trace(ref_rate, duration, cm, seed=11)
    sim, _ = build_cluster(get_config(MODEL), "tropical", n_workers=4,
                           worker_spec=WORKER, record_decisions=True)
    sim.add_trace(copy.deepcopy(trace))
    m = sim.run(until=duration * 10)
    n_iters = sum(1 for d in sim.decisions if d[0] == "iter")
    busy = sum(w.busy_time for w in sim.workers.values())
    return m, busy / max(n_iters, 1)


def build_summary(results: dict[str, list[dict]],
                  ref_rate: float = REF_RATE) -> dict:
    """Distil the quick sweep into the schema-versioned BENCH record."""
    summary = {
        "schema_version": SUMMARY_SCHEMA_VERSION,
        "ref_rate": ref_rate,
        "generator": "benchmarks.run --quick",
    }
    for row in results.get("fig8", []):
        if row.get("policy") == "tropical" and row.get("rate") == ref_rate:
            summary["slo_attainment"] = row["slo_attainment"]
    for row in results.get("fig_multitenant", []):
        if row.get("policy") == "tropical" and row.get("rate") == ref_rate:
            summary["weighted_attainment"] = row["weighted_attainment"]
    for row in results.get("fig_hetero", []):
        if row.get("config") == "summary":
            summary["hetero_global_attainment"] = row["mean_hetero_global"]
            summary["hetero_per_worker_attainment"] = row["mean_hetero_pw"]
    for row in results.get("fig_interference", []):
        if row.get("config") == "summary":
            summary["interference_blind_attainment"] = row["mean_gamma_blind"]
            summary["interference_aware_attainment"] = row["mean_gamma_aware"]
            summary["interference_gamma_abs_err"] = row["mean_gamma_abs_err"]
    for row in results.get("fig_tiered", []):
        if row.get("config") == "summary":
            summary["tiered_evict_ttft_attainment"] = \
                row["evict_ttft_attainment"]
            summary["tiered_prefix_ttft_attainment"] = \
                row["tiered_prefix_ttft_attainment"]
            summary["tiered_prefix_hit_rate"] = row["prefix_hit_rate"]
    # vectorized-scheduler throughput at the largest scale-tier size: the
    # *_rps key class in check_summary.py gates drops > 20%
    tp_rows = [r for r in results.get("scale", [])
               if r.get("tier") == "throughput"
               and r.get("mode") == "vectorized"]
    if tp_rows:
        best = max(tp_rows, key=lambda r: r["workers"])
        summary["sim_throughput_rps"] = best["sim_throughput_rps"]
        summary["sim_throughput_workers"] = best["workers"]
        summary["sim_throughput_speedup"] = best["speedup_x"]
    # engine-bound tier (decode-heavy long-output): the fast engine
    # bookkeeping path's gated number, same *_rps key class
    eng_rows = [r for r in results.get("scale", [])
                if r.get("tier") == "engine"
                and r.get("mode") == "vectorized"]
    if eng_rows:
        best = max(eng_rows, key=lambda r: r["workers"])
        summary["sim_engine_rps"] = best["sim_throughput_rps"]
        summary["sim_engine_workers"] = best["workers"]
        summary["sim_engine_speedup"] = best["speedup_x"]
    # real-compute executor tier: per-iteration wall clock of the batched
    # fast path (``*_ms`` latency class: check_summary.py fails growth
    # beyond 25%) and its measured speedup over the scalar seed reference
    # (``*_speedup`` throughput class: fails drops beyond 20%)
    re_row = next((r for r in results.get("scale", [])
                   if r.get("tier") == "real_exec"
                   and r.get("mode") == "fast"), None)
    if re_row:
        summary["real_step_ms"] = re_row["step_ms"]
        summary["real_exec_speedup"] = re_row["speedup_x"]
    m, mean_step = _canonical_run(ref_rate)
    summary.update(
        ttft_p90_s=round(m.ttft_p90, 4),
        tpot_p90_s=round(m.tpot_p90, 5),
        mean_step_s=round(mean_step, 5),
        n_requests=m.n_total,
    )
    return summary


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--summary", default=None, metavar="PATH",
                    help="write the BENCH_summary.json record here "
                         "(default: BENCH_summary.json when --quick)")
    ap.add_argument("--profile", action="store_true",
                    help="run under cProfile; print the top-25 "
                         "cumulative-time entries to stderr")
    args = ap.parse_args(argv)

    from benchmarks import (fig3_workload, fig4_queue_vs_interference,
                            fig5_worker_allocation, fig8_slo_attainment,
                            fig9_latency, fig10_queueing, fig11_cdf,
                            fig_hetero, fig_interference, fig_migration,
                            fig_multitenant, fig_tiered, predictor_noise,
                            roofline, scale)
    benches = {
        "fig3": fig3_workload.main,
        "fig4": fig4_queue_vs_interference.main,
        "fig5": fig5_worker_allocation.main,
        "fig8": (lambda: fig8_slo_attainment.main(rates=(1.0, 2.0, 3.0)))
        if args.quick else fig8_slo_attainment.main,
        "fig9": fig9_latency.main,
        "fig10": fig10_queueing.main,
        "fig11": fig11_cdf.main,
        "fig_migration": (lambda: fig_migration.main(
            bandwidths=(0.05e9, 1e9, 50e9), rate=2.0, duration=60.0))
        if args.quick else fig_migration.main,
        "fig_multitenant": (lambda: fig_multitenant.main(
            rates=(2.0,), duration=60.0, ref_rate=2.0))
        if args.quick else fig_multitenant.main,
        "fig_tiered": (lambda: fig_tiered.main(duration=60.0))
        if args.quick else fig_tiered.main,
        "fig_hetero": (lambda: fig_hetero.main(seeds=(7, 11)))
        if args.quick else fig_hetero.main,
        "fig_interference": (lambda: fig_interference.main(
            rates=(2.0,), seeds=(11, 13)))
        if args.quick else fig_interference.main,
        "scale": (lambda: scale.main(
            scales=[(4, 4.0), (16, 16.0)], duration=60.0,
            throughput_scales=scale.THROUGHPUT_SCALES_QUICK,
            engine_scales=scale.ENGINE_SCALES))
        if args.quick else scale.main,
        "predictor_noise": (lambda: predictor_noise.main(quick=True))
        if args.quick else predictor_noise.main,
        "roofline": roofline.main,
    }
    results: dict[str, list[dict]] = {}
    with maybe_profile(args.profile):
        for name, fn in benches.items():
            if args.only and name != args.only:
                continue
            t0 = time.perf_counter()  # lint: allow-wallclock(suite progress timing, never enters results)
            try:
                results[name] = fn() or []
                print(f"# {name}: done in {time.perf_counter() - t0:.1f}s",  # lint: allow-wallclock(suite progress timing, never enters results)
                      file=sys.stderr)
            except Exception as e:  # noqa: BLE001 — keep the suite running
                print(f"# {name}: FAILED {type(e).__name__}: {e}",
                      file=sys.stderr)
                raise

    # an explicit --summary is always honoured (with --only the record
    # carries whatever that one bench produced plus the canonical-run
    # columns); the implicit --quick default skips partial sweeps
    summary_path = args.summary or (
        "BENCH_summary.json" if args.quick and not args.only else None)
    if summary_path:
        summary = build_summary(results)
        with open(summary_path, "w") as f:
            json.dump(summary, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# summary -> {summary_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
