"""Benchmark driver: one function per paper table/figure + the roofline
table. Prints ``name,key=value,...`` CSV rows.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig8]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    from benchmarks import (fig3_workload, fig4_queue_vs_interference,
                            fig5_worker_allocation, fig8_slo_attainment,
                            fig9_latency, fig10_queueing, fig11_cdf,
                            fig_migration, fig_multitenant, predictor_noise,
                            roofline, scale)
    benches = {
        "fig3": fig3_workload.main,
        "fig4": fig4_queue_vs_interference.main,
        "fig5": fig5_worker_allocation.main,
        "fig8": (lambda: fig8_slo_attainment.main(rates=(1.0, 2.0, 3.0)))
        if args.quick else fig8_slo_attainment.main,
        "fig9": fig9_latency.main,
        "fig10": fig10_queueing.main,
        "fig11": fig11_cdf.main,
        "fig_migration": (lambda: fig_migration.main(
            bandwidths=(0.05e9, 1e9, 50e9), rate=2.0, duration=60.0))
        if args.quick else fig_migration.main,
        "fig_multitenant": (lambda: fig_multitenant.main(
            rates=(2.0,), duration=60.0, ref_rate=2.0))
        if args.quick else fig_multitenant.main,
        "scale": (lambda: scale.main(scales=[(4, 4.0), (16, 16.0)],
                                     duration=60.0))
        if args.quick else scale.main,
        "predictor_noise": (lambda: predictor_noise.main(quick=True))
        if args.quick else predictor_noise.main,
        "roofline": roofline.main,
    }
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        t0 = time.perf_counter()
        try:
            fn()
            print(f"# {name}: done in {time.perf_counter() - t0:.1f}s",
                  file=sys.stderr)
        except Exception as e:  # noqa: BLE001 — keep the suite running
            print(f"# {name}: FAILED {type(e).__name__}: {e}",
                  file=sys.stderr)
            raise


if __name__ == "__main__":
    main()
