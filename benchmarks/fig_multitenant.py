"""Multi-tenant SLO classes: weighted attainment under a 2-class mixture.

Two tenants share the cluster (the setting DistServe §5 evaluates with
per-application SLOs and "Taming Request Imbalance" schedules per-request):

* ``interactive`` — short agentic prompts, tight SLOs (3x light-load),
  2x weight: the latency-sensitive product surface;
* ``batch``       — long-context prompts in on/off bursts, loose SLOs
  (12x light-load): background summarisation/extraction traffic.

Reported per policy per rate: weighted attainment Σ w_c·A_c / Σ w_c plus
the per-class split. Two claims are asserted at the reference rate:

1. tropical's weighted attainment >= both the disaggregated (distserve)
   and non-disaggregated (sarathi, vllm) baselines — SLO-aware
   multiplexing wins precisely when the SLOs are heterogeneous;
2. the interactive class is not sacrificed to batch traffic: its TTFT
   attainment in the mixture stays within 2 points of a tropical run
   serving the interactive stream alone (same seed => identical
   interactive arrivals, the batch component simply removed).

Usage: PYTHONPATH=src python -m benchmarks.fig_multitenant [--quick]
"""
from __future__ import annotations

import argparse
import copy

import dataclasses

from benchmarks.common import cost_model, emit, run_policy
from repro.core.request import SLOClass
from repro.workload import (AGENTIC, GammaPoisson, LONGCTX, OnOffBursts,
                            Scenario, ScenarioComponent)

RATES = (1.0, 2.0, 3.0, 4.0)
REF_RATE = 3.0
DURATION = 180.0
SEED = 23
POLICIES = ("vllm", "sarathi", "distserve", "tropical")

# the interactive tenant's prompts cap at 4k: a fixed class-level TTFT SLO
# must be attainable by construction (an 8k+ agentic-tail prompt whose own
# light-load prefill exceeds the class SLO would be unattainable under any
# scheduler and only add noise to the comparison)
INTERACTIVE_PROFILE = dataclasses.replace(
    AGENTIC, name="interactive", max_input=4096, tail_median=2048.0)


def slo_classes(cm) -> tuple[SLOClass, SLOClass]:
    interactive = SLOClass(
        ttft=3.0 * cm.prefill_time(2048),
        tpot=3.0 * cm.decode_iter_time(1, 2048.0),
        name="interactive", weight=2.0)
    batch = SLOClass(
        ttft=12.0 * cm.prefill_time(16384),
        tpot=12.0 * cm.decode_iter_time(1, 16384.0),
        name="batch", weight=1.0)
    return interactive, batch


def components(cm) -> tuple[ScenarioComponent, ScenarioComponent]:
    interactive, batch = slo_classes(cm)
    return (
        ScenarioComponent(
            name="interactive", profile=INTERACTIVE_PROFILE,
            arrivals=GammaPoisson(window=5.0, shape=4.0),
            rate_frac=0.6, slo=interactive, weight=interactive.weight),
        ScenarioComponent(
            name="batch", profile=LONGCTX,
            arrivals=OnOffBursts(on_mean=8.0, off_mean=12.0),
            rate_frac=0.4, slo=batch, weight=batch.weight),
    )


def make_traces(cm, rate: float, duration: float):
    """(mixture trace, interactive-only trace). Component RNG substreams
    are keyed by component NAME, so the interactive arrivals are identical
    in both — the solo run isolates exactly the batch tenant's
    influence."""
    comps = components(cm)
    mixed = Scenario("multitenant", comps).generate(rate, duration, cm,
                                                    seed=SEED)
    solo = Scenario("interactive-only", comps[:1]).generate(
        rate, duration, cm, seed=SEED)
    return mixed, solo


def main(rates=RATES, duration=DURATION, ref_rate=REF_RATE) -> list[dict]:
    cm = cost_model()
    rows = []
    ref = {}
    for rate in rates:
        mixed, solo = make_traces(cm, rate, duration)
        for pol in POLICIES:
            m = run_policy(pol, copy.deepcopy(mixed), until=duration * 10)
            cls = {name: c for name, c in m.per_class.items()}
            row = {
                "policy": pol, "rate": rate,
                "weighted_attainment": round(m.weighted_attainment, 3),
                "slo_attainment": round(m.slo_attainment, 3),
                "finished": m.n_finished, "total": m.n_total,
            }
            for name, c in sorted(cls.items()):
                row[f"{name}_slo"] = round(c.slo_attainment, 3)
                row[f"{name}_ttft"] = round(c.ttft_attainment, 3)
                row[f"{name}_tpot"] = round(c.tpot_attainment, 3)
            rows.append(row)
            if rate == ref_rate:
                ref[pol] = m
        if rate == ref_rate:
            m_solo = run_policy("tropical", copy.deepcopy(solo),
                                until=duration * 10)
            ref["tropical-solo"] = m_solo
            rows.append({
                "policy": "tropical-interactive-only", "rate": rate,
                "weighted_attainment": round(m_solo.weighted_attainment, 3),
                "interactive_ttft": round(
                    m_solo.per_class["interactive"].ttft_attainment, 3),
                "finished": m_solo.n_finished, "total": m_solo.n_total,
            })

    # claim 1: heterogeneous SLOs are where SLO-aware multiplexing pays
    trop = ref["tropical"].weighted_attainment
    for base in ("distserve", "sarathi", "vllm"):
        got = ref[base].weighted_attainment
        assert trop >= got - 1e-9, (
            f"tropical weighted attainment {trop:.3f} < {base} {got:.3f} "
            f"at rate {ref_rate}")
    # claim 2: the tight class is not sacrificed to the batch class
    tight_mixed = ref["tropical"].per_class["interactive"].ttft_attainment
    tight_solo = ref["tropical-solo"].per_class["interactive"].ttft_attainment
    assert tight_mixed >= tight_solo - 0.02, (
        f"interactive TTFT attainment dropped from {tight_solo:.3f} (solo) "
        f"to {tight_mixed:.3f} (mixed) at rate {ref_rate}")
    rows.append({
        "policy": "summary", "ref_rate": ref_rate,
        "tropical_weighted": round(trop, 3),
        "best_baseline_weighted": round(
            max(ref[b].weighted_attainment
                for b in ("distserve", "sarathi", "vllm")), 3),
        "interactive_ttft_mixed": round(tight_mixed, 3),
        "interactive_ttft_solo": round(tight_solo, 3),
    })
    emit("fig_multitenant", rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    if a.quick:
        main(rates=(2.0,), duration=60.0, ref_rate=2.0)
    else:
        main()
