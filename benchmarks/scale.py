"""Cluster-scale sweep (beyond-paper): the paper stops at 4 workers; the
scheduler must hold SLO attainment as workers and load scale together
(64 workers x TP8 = 512 chips — one dry-run pod-pair worth of serving).

Checks (a) attainment stays flat under proportional scaling (no
centralised-scheduler collapse), (b) simulated-cluster throughput, (c)
scheduler decision cost per request stays O(workers).
"""
from __future__ import annotations

import copy
import time

from benchmarks.common import MODEL, WORKER, cost_model, emit, make_trace
from repro.configs import get_config
from repro.serving.simulator import build_cluster

SCALES = [(4, 4.0), (16, 16.0), (64, 64.0)]
DURATION = 120.0


def main() -> list[dict]:
    cm = cost_model()
    rows = []
    for n_workers, rate in SCALES:
        trace = make_trace(rate, DURATION, cm, seed=5)
        for pol in ("tropical", "tropical++"):
            sim, _ = build_cluster(get_config(MODEL), pol,
                                   n_workers=n_workers, worker_spec=WORKER)
            sim.add_trace(copy.deepcopy(trace))
            t0 = time.perf_counter()
            m = sim.run(until=DURATION * 6)
            wall = time.perf_counter() - t0
            rows.append({
                "policy": pol, "workers": n_workers, "rate": rate,
                "chips": n_workers * WORKER.tp,
                "requests": m.n_total,
                "slo_attainment": round(m.slo_attainment, 3),
                "ttft_p90_s": round(m.ttft_p90, 2),
                "tpot_p90_s": round(m.tpot_p90, 4),
                "sim_wall_s": round(wall, 2),
                "req_per_sim_sec": round(m.n_total / max(wall, 1e-9), 0),
            })
    emit("scale", rows)
    return rows


if __name__ == "__main__":
    main()
