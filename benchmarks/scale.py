"""Cluster-scale sweep (beyond-paper): the paper stops at 4 workers; the
scheduler must hold SLO attainment as workers and load scale together
(64 workers x TP8 = 512 chips — one dry-run pod-pair worth of serving).

Checks (a) attainment stays flat under proportional scaling (no
centralised-scheduler collapse), (b) simulated-cluster throughput, (c)
scheduler decision cost per request stays O(workers), and (d) the
proportional role-rebalancer (ceil(deficit x workers) moves per review
with two-window hysteresis, ``rebalance=proportional`` rows) keeps pace
with breaches the legacy one-worker-per-review controller chases at
100+-worker scale; its attainment must stay >= flat-minus-noise of the
legacy rows.

Usage: PYTHONPATH=src python -m benchmarks.scale [--quick]
"""
from __future__ import annotations

import argparse
import copy
import time

from benchmarks.common import MODEL, WORKER, cost_model, emit, make_trace
from repro.configs import get_config
from repro.sched.rebalance import RebalanceConfig
from repro.serving.simulator import build_cluster

SCALES = [(4, 4.0), (16, 16.0), (64, 64.0)]
DURATION = 120.0


def _run(cm, pol, n_workers, rate, duration, rebalance_config=None):
    trace = make_trace(rate, duration, cm, seed=5)
    sim, _ = build_cluster(get_config(MODEL), pol, n_workers=n_workers,
                           worker_spec=WORKER,
                           rebalance_config=rebalance_config)
    sim.add_trace(copy.deepcopy(trace))
    t0 = time.perf_counter()
    m = sim.run(until=duration * 6)
    wall = time.perf_counter() - t0
    transitions = len(sim.sched.rebalancer.transitions) \
        if sim.sched.rebalancer is not None else 0
    return m, wall, transitions


def main(scales=SCALES, duration=DURATION) -> list[dict]:
    cm = cost_model()
    rows = []
    proportional = RebalanceConfig(confirm_windows=2, max_move_frac=0.25)
    for n_workers, rate in scales:
        for pol, rb_cfg, tag in (
                ("tropical", None, "legacy"),
                ("tropical++", None, "legacy"),
                ("tropical", proportional, "proportional")):
            m, wall, transitions = _run(cm, pol, n_workers, rate, duration,
                                        rebalance_config=rb_cfg)
            rows.append({
                "policy": pol, "rebalance": tag,
                "workers": n_workers, "rate": rate,
                "chips": n_workers * WORKER.tp,
                "requests": m.n_total,
                "slo_attainment": round(m.slo_attainment, 3),
                "ttft_p90_s": round(m.ttft_p90, 2),
                "tpot_p90_s": round(m.tpot_p90, 4),
                "role_transitions": transitions,
                "sim_wall_s": round(wall, 2),
                "req_per_sim_sec": round(m.n_total / max(wall, 1e-9), 0),
            })
    # hysteresis must not cost attainment at any scale: proportional rows
    # stay within noise of the matching legacy tropical rows
    by = {(r["rebalance"], r["workers"]): r for r in rows
          if r["policy"] == "tropical"}
    for n_workers, _ in scales:
        legacy = by[("legacy", n_workers)]["slo_attainment"]
        prop = by[("proportional", n_workers)]["slo_attainment"]
        assert prop >= legacy - 0.02, \
            (n_workers, prop, legacy)
    emit("scale", rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    if a.quick:
        main(scales=[(4, 4.0), (16, 16.0)], duration=60.0)
    else:
        main()
