"""Cluster-scale sweep (beyond-paper): the paper stops at 4 workers; the
scheduler must hold SLO attainment as workers and load scale together
(64 workers x TP8 = 512 chips — one dry-run pod-pair worth of serving).

Two tiers:

* **attainment** — checks (a) attainment stays flat under proportional
  scaling (no centralised-scheduler collapse), (b) simulated-cluster
  throughput, (c) scheduler decision cost per request stays O(workers),
  and (d) the proportional role-rebalancer (``rebalance=proportional``
  rows) keeps pace with the legacy one-worker-per-review controller; its
  attainment must stay >= flat-minus-noise of the legacy rows.
* **throughput** — simulated-requests-per-second of the vectorized
  scheduler hot path against the scalar reference at 256+ workers, on a
  dispatch-heavy workload (short outputs, so the O(workers) placement
  decision dominates each request's cost — the regime the batched cost
  evaluation exists for). The vectorized rows carry ``speedup_x`` vs the
  scalar row at the same scale; the largest scale's vectorized
  ``sim_throughput_rps`` is the number ``benchmarks.run --quick`` records
  in ``BENCH_summary.json`` for the CI perf gate.
* **real_exec** — real JAX compute, not simulation: wall clock per
  composed iteration of the batched donation-aware executor fast path vs
  the scalar seed reference on a smoke model (CPU jit), with token
  streams asserted bit-identical. Lands ``real_step_ms`` /
  ``real_exec_speedup`` in the summary (``--real-exec-only`` runs just
  this tier — the bench-weekly cProfile target).

The master trace for each (rate, duration, seed) is generated once and
every run receives a cheap replay clone (``common.clone_trace``) — the
per-policy regenerate + ``copy.deepcopy`` the original version of this
sweep paid dominated its own wall clock at scale.

Usage: PYTHONPATH=src python -m benchmarks.scale [--quick]
                                                 [--throughput-only]
"""
from __future__ import annotations

import argparse
import time

from benchmarks.common import (MODEL, WORKER, clone_trace, cost_model, emit,
                               fixed_slo, make_trace)
from repro.configs import get_config
from repro.sched.rebalance import RebalanceConfig
from repro.serving.simulator import build_cluster
from repro.workload.profiles import TraceProfile
from repro.workload.scenario import generate_trace

SCALES = [(4, 4.0), (16, 16.0), (64, 64.0)]
DURATION = 120.0

# throughput tier: (workers, rate, duration). The workload keeps outputs
# short so dispatch — not decode iterations — dominates per-request cost.
THROUGHPUT_SCALES = [(256, 256.0, 6.0), (1024, 1024.0, 4.0),
                     (2048, 2048.0, 3.0)]
THROUGHPUT_SCALES_QUICK = [(256, 256.0, 6.0), (1024, 1024.0, 4.0)]
DISPATCH_HEAVY = TraceProfile(
    name="dispatch-heavy", body_median=1024.0, body_sigma=0.8,
    tail_frac=0.02, out_median=4.0, out_sigma=0.3,
    min_output=2, max_output=8)

# engine tier: decode-heavy long-output workload, so per-iteration engine
# bookkeeping (completion effects + view refresh over large decode
# batches) — not dispatch — dominates each request's simulation cost.
# This is the regime the SoA fast path exists for.
ENGINE_SCALES = [(1024, 512.0, 4.0)]
ENGINE_HEAVY = TraceProfile(
    name="engine-heavy", body_median=96.0, body_sigma=0.5,
    tail_frac=0.0, out_median=192.0, out_sigma=0.3,
    min_output=96, max_output=384)


def _attainment_run(cm, pol, n_workers, trace, duration,
                    rebalance_config=None):
    sim, _ = build_cluster(get_config(MODEL), pol, n_workers=n_workers,
                           worker_spec=WORKER,
                           rebalance_config=rebalance_config)
    sim.add_trace(clone_trace(trace))
    t0 = time.perf_counter()  # lint: allow-wallclock(measured sim wall time for speedup rows)
    m = sim.run(until=duration * 6)
    wall = time.perf_counter() - t0  # lint: allow-wallclock(measured sim wall time for speedup rows)
    transitions = len(sim.sched.rebalancer.transitions) \
        if sim.sched.rebalancer is not None else 0
    return m, wall, transitions


def attainment_tier(scales=SCALES, duration=DURATION) -> list[dict]:
    cm = cost_model()
    rows = []
    proportional = RebalanceConfig(confirm_windows=2, max_move_frac=0.25)
    for n_workers, rate in scales:
        # one master trace per scale; every policy run replays a clone
        trace = make_trace(rate, duration, cm, seed=5)
        for pol, rb_cfg, tag in (
                ("tropical", None, "legacy"),
                ("tropical++", None, "legacy"),
                ("tropical", proportional, "proportional")):
            m, wall, transitions = _attainment_run(
                cm, pol, n_workers, trace, duration, rebalance_config=rb_cfg)
            rows.append({
                "tier": "attainment",
                "policy": pol, "rebalance": tag,
                "workers": n_workers, "rate": rate,
                "chips": n_workers * WORKER.tp,
                "requests": m.n_total,
                "slo_attainment": round(m.slo_attainment, 3),
                "ttft_p90_s": round(m.ttft_p90, 2),
                "tpot_p90_s": round(m.tpot_p90, 4),
                "role_transitions": transitions,
                "sim_wall_s": round(wall, 2),
                "req_per_sim_sec": round(m.n_total / max(wall, 1e-9), 0),
            })
    # hysteresis must not cost attainment at any scale: proportional rows
    # stay within noise of the matching legacy tropical rows
    by = {(r["rebalance"], r["workers"]): r for r in rows
          if r["policy"] == "tropical"}
    for n_workers, _ in scales:
        legacy = by[("legacy", n_workers)]["slo_attainment"]
        prop = by[("proportional", n_workers)]["slo_attainment"]
        assert prop >= legacy - 0.02, \
            (n_workers, prop, legacy)
    return rows


def _throughput_run(trace, n_workers, vectorized):
    sim, _ = build_cluster(get_config(MODEL), "tropical",
                           n_workers=n_workers, worker_spec=WORKER,
                           vectorized=vectorized)
    sim.add_trace(clone_trace(trace))
    t0 = time.perf_counter()  # lint: allow-wallclock(measured sim wall time for speedup rows)
    m = sim.run()
    return m, time.perf_counter() - t0  # lint: allow-wallclock(measured sim wall time for speedup rows)


def throughput_tier(scales=THROUGHPUT_SCALES, repeats=2, *,
                    tier="throughput",
                    profile=DISPATCH_HEAVY) -> list[dict]:
    """Vectorized-vs-scalar sim throughput on ``profile``. The vectorized
    measurement is best-of-``repeats`` (it is the gated number and short
    enough to repeat; the scalar baseline runs once). Both modes replay
    clones of one master trace, so the decision streams — and therefore
    the attainment columns — are identical by construction."""
    cm = cost_model()
    rows = []
    for n_workers, rate, duration in scales:
        trace = generate_trace(rate=rate, duration=duration, cost_model=cm,
                               seed=5, profile=profile,
                               fixed_slo=fixed_slo(cm))
        walls = {}
        for mode, vec in (("scalar", False), ("vectorized", True)):
            n_runs = repeats if vec else 1
            best = None
            for _ in range(n_runs):
                m, wall = _throughput_run(trace, n_workers, vec)
                best = wall if best is None else min(best, wall)
            walls[mode] = best
            row = {
                "tier": tier, "mode": mode,
                "workers": n_workers, "rate": rate,
                "requests": m.n_total,
                "slo_attainment": round(m.slo_attainment, 3),
                "sim_wall_s": round(best, 3),
                "sim_throughput_rps": round(m.n_total / max(best, 1e-9), 1),
            }
            if mode == "vectorized":
                row["speedup_x"] = round(walls["scalar"] / max(best, 1e-9),
                                         2)
            rows.append(row)
    return rows


def engine_tier(scales=ENGINE_SCALES, repeats=2) -> list[dict]:
    """Engine-bound tier: same harness, decode-heavy workload. The
    largest scale's vectorized ``sim_throughput_rps`` is what
    ``benchmarks.run --quick`` records as ``sim_engine_rps``."""
    return throughput_tier(scales, repeats, tier="engine",
                           profile=ENGINE_HEAVY)


def _real_exec_drive(execs, rid_base: int, n_reqs=6, prompt=96, out=12,
                     chunk=48):
    """Deterministic smoke workload against one RealExecutor: admit up to
    two chunked prefills per iteration while decoding every completed
    request — the composed mixed-iteration regime the batched fast path
    fuses. The admission logic never looks at token values, so seed and
    fast runs execute identical plan sequences."""
    from repro.core.request import Request, SLOSpec
    from repro.serving.engine import IterationPlan

    e = execs.execs[0]
    slo = SLOSpec(ttft=30.0, tpot=5.0)
    queue = [Request(rid=rid_base + i, arrival_time=0.0, prompt_len=prompt,
                     output_len=out, slo=slo) for i in range(n_reqs)]
    rids = [r.rid for r in queue]
    admitted: list = []
    iters = 0
    while queue or admitted:
        while queue and len(admitted) < e.max_slots and \
                sum(1 for r in admitted
                    if r.prefilled_tokens < prompt) < 2:
            admitted.append(queue.pop(0))
        prefill = []
        for r in admitted:
            if r.prefilled_tokens < prompt and len(prefill) < 2:
                prefill.append((r, min(chunk, prompt - r.prefilled_tokens)))
        decode = [r for r in admitted if r.prefilled_tokens >= prompt
                  and len(e.generated[r.rid]) < out]
        e.run_plan(IterationPlan(
            decode_reqs=decode, prefill_parts=prefill, n_decode=len(decode),
            sum_ctx=float(sum(r.prompt_len for r in decode)),
            prefill_tokens=sum(t for _, t in prefill),
            prefill_ctx_offset=0.0, exclusive_prefill=not decode))
        for r, t in prefill:
            r.prefilled_tokens += t
        iters += 1
        for r in [r for r in admitted if r.prefilled_tokens >= prompt
                  and len(e.generated[r.rid]) >= out]:
            admitted.remove(r)
            execs.on_finish(r)
    return iters, {rid: list(e.generated[rid]) for rid in rids}


def real_exec_tier(cfg_name: str = "qwen2-1.5b") -> list[dict]:
    """Seed vs fast real-compute wall clock per iteration at smoke scale
    (CPU jit). Both modes share one cluster per mode (jit caches stay
    warm), run the drive twice, and time the second pass; the fast row
    carries ``speedup_x`` vs seed and is what ``benchmarks.run --quick``
    records as ``real_step_ms`` / ``real_exec_speedup``. Token streams
    are asserted bit-identical across modes — the fast path may not buy
    its speed with different math."""
    from repro.configs import get_smoke
    from repro.serving.executor import ClusterRealExecutors

    cfg = get_smoke(cfg_name)
    rows, walls, streams = [], {}, {}
    for mode, batched in (("seed", False), ("fast", True)):
        execs = ClusterRealExecutors(cfg, 1, max_slots=8, max_len=128,
                                     batched=batched)
        _real_exec_drive(execs, rid_base=0)          # warm every jit entry
        t0 = time.perf_counter()  # lint: allow-wallclock(measured executor wall time for step_ms)
        iters, toks = _real_exec_drive(execs, rid_base=100)
        wall = time.perf_counter() - t0  # lint: allow-wallclock(measured executor wall time for step_ms)
        walls[mode] = wall / iters
        streams[mode] = toks
        row = {
            "tier": "real_exec", "mode": mode, "model": cfg_name,
            "iters": iters, "wall_s": round(wall, 3),
            "step_ms": round(1000.0 * wall / iters, 3),
        }
        if mode == "fast":
            row["speedup_x"] = round(walls["seed"] / max(walls["fast"],
                                                         1e-12), 2)
        rows.append(row)
    assert streams["seed"] == streams["fast"], \
        "fast path token streams diverged from the seed reference"
    return rows


def main(scales=SCALES, duration=DURATION,
         throughput_scales=THROUGHPUT_SCALES,
         engine_scales=ENGINE_SCALES,
         throughput_only=False, real_exec_only=False) -> list[dict]:
    if real_exec_only:
        rows = real_exec_tier()
        emit("scale", rows)
        return rows
    rows = [] if throughput_only else attainment_tier(scales, duration)
    rows += throughput_tier(throughput_scales)
    rows += engine_tier(engine_scales)
    rows += real_exec_tier()
    emit("scale", rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--throughput-only", action="store_true",
                    help="skip the attainment sweep (CI scale-throughput "
                         "tier)")
    ap.add_argument("--real-exec-only", action="store_true",
                    help="run only the real-compute seed-vs-fast tier "
                         "(the bench-weekly cProfile target)")
    a = ap.parse_args()
    if a.quick:
        main(scales=[(4, 4.0), (16, 16.0)], duration=60.0,
             throughput_scales=THROUGHPUT_SCALES_QUICK,
             throughput_only=a.throughput_only,
             real_exec_only=a.real_exec_only)
    else:
        main(throughput_only=a.throughput_only,
             real_exec_only=a.real_exec_only)
