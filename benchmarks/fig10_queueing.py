"""Fig. 10 — queuing time per policy (avg + P90): Tropical's TTFT advantage
over DistServe comes from queuing (claimed ~9x better P90 queueing)."""
from __future__ import annotations

from benchmarks.common import POLICIES, cost_model, emit, make_trace, run_policy

RATES = (2.0, 4.0, 6.0)
DURATION = 300.0


def main() -> list[dict]:
    cm = cost_model()
    rows = []
    for rate in RATES:
        trace = make_trace(rate, DURATION, cm, seed=31)
        per = {}
        for pol in POLICIES:
            m = run_policy(pol, trace, until=DURATION * 6)
            per[pol] = m
            rows.append({
                "policy": pol, "rate": rate,
                "queue_avg_s": round(m.queue_avg, 3),
                "queue_p90_s": round(m.queue_p90, 3),
            })
        rows.append({
            "policy": "ratio", "rate": rate,
            "distserve_over_tropical_q90": round(
                per["distserve"].queue_p90
                / max(per["tropical"].queue_p90, 1e-9), 2),
        })
    emit("fig10_queueing", rows)
    return rows


if __name__ == "__main__":
    main()
