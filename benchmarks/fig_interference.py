"""γ-aware vs γ-blind tropical under interference-bearing ground truth.

Tropical's multiplexing decision (§IV) prices the slowdown a co-batched
prefill chunk inflicts on decode. The legacy model prices it additively
(γ = 0), but DistServe (arXiv:2401.09670) and prefill-decode multiplexing
(arXiv:2504.14489) both measure a *super-additive* mixed-batch excess
that grows with decode batch size and chunk length. This figure makes the
simulated ground truth interference-bearing — every iteration is priced
by a cost model carrying a bucketed ``InterferenceTable`` — and compares
three tropical configurations whose *planning* models differ:

  gamma-blind   legacy γ=0 planning: the toggle believes mixed batches
                are free of contention, over-promises Path-② TTFT and
                admits chunks whose true cost drains decode slack
  gamma-aware   planning model carries the true γ table (what a
                ``calibrate_interference`` run at deploy time provides):
                chunk admission and TTFT prediction price the penalty
  gamma-drift   γ-blind planning plus a ``DriftMonitor``
                (``--recalibrate-every``-style online recalibration):
                per-bucket γ is *learned* from observed mixed-iteration
                residuals during the run

Workload: the chunk-heavy ``mixture`` scenario (its batch tenant is the
long-context profile, so multiplexing workers see a steady stream of
large chunks co-batched with running decodes).

Asserts (1) γ-aware mean attainment >= γ-blind under the interference-
bearing truth, and (2) the drift monitor's learned γ lands within
tolerance of the injected ground truth in every bucket the run's traffic
warmed. Also reports a
kernel-measured table from ``calibrate_interference`` (tiny shapes; real
Pallas kernels, mixed vs pure) so the calibration path is exercised
end-to-end.

Usage: PYTHONPATH=src python -m benchmarks.fig_interference [--quick]
"""
from __future__ import annotations

import argparse
import copy
import dataclasses

from benchmarks.common import MODEL, WORKER, cost_model, emit
from repro.configs import get_config
from repro.perf import CostModel, InterferenceTable
from repro.sched.backend import CallableBackend
from repro.serving.simulator import build_cluster
from repro.workload import get_scenario

RATES = (2.0, 2.5)
SEEDS = (7, 11, 13)
DURATION = 60.0
RECALIBRATE_EVERY = 64
# Injected ground truth: contention grows with decode batch and chunk
# size (the shape both measurement papers report); the hot serving bucket
# (batch >= 4, chunk >= 1024) pays γ = 0.8 of the overlapped minimum.
TRUE_TABLE = InterferenceTable(
    decode_edges=(1, 4, 16), chunk_edges=(256, 1024),
    gamma=((0.3, 0.5), (0.5, 0.8), (0.8, 1.0)))


def _truth_backend(truth: CostModel) -> CallableBackend:
    return CallableBackend(lambda w, plan: truth.iteration_time(
        plan.n_decode, plan.sum_ctx, plan.prefill_tokens,
        plan.prefill_ctx_offset))


def main(rates=RATES, seeds=SEEDS, duration=DURATION) -> list[dict]:
    cfg = get_config(MODEL)
    cm = cost_model()
    truth_spec = dataclasses.replace(
        WORKER, hw=dataclasses.replace(WORKER.hw, interference=TRUE_TABLE))
    truth = CostModel(cfg, truth_spec)

    configs = {
        "gamma-blind": dict(worker_spec=WORKER),
        "gamma-aware": dict(worker_spec=truth_spec),
        "gamma-drift": dict(worker_spec=WORKER,
                            recalibrate_every=RECALIBRATE_EVERY),
    }
    rows, atts = [], {tag: [] for tag in configs}
    learned = []
    for rate in rates:
        traces = {seed: get_scenario("mixture").generate(
            rate, duration, cm, seed=seed) for seed in seeds}
        for tag, kw in configs.items():
            for seed in seeds:
                sim, _ = build_cluster(cfg, "tropical", n_workers=4,
                                       backend=_truth_backend(truth), **kw)
                sim.add_trace(copy.deepcopy(traces[seed]))
                m = sim.run(until=duration * 10)
                atts[tag].append(m.slo_attainment)
                row = {
                    "config": tag, "rate": rate, "seed": seed,
                    "slo_attainment": round(m.slo_attainment, 3),
                    "weighted_attainment": round(m.weighted_attainment, 3),
                    "ttft_attainment": round(m.ttft_attainment, 3),
                    "tpot_attainment": round(m.tpot_attainment, 3),
                    "finished": m.n_finished, "total": m.n_total,
                }
                dm = sim.sched.drift_monitor
                if dm is not None:
                    # per warm cell: |learned - truth at that cell| (the
                    # run's traffic decides which buckets get evidence)
                    errs = [abs(dm.gamma_ewma[k] - TRUE_TABLE.lookup(*k))
                            for k, n in dm.gamma_obs.items()
                            if n >= dm.floor]
                    learned.extend(errs)
                    row.update(recalibrations=dm.recalibrations,
                               warm_cells=len(errs),
                               gamma_err=round(max(errs), 3) if errs
                               else float("nan"))
                rows.append(row)
    means = {tag: sum(a) / len(a) for tag, a in atts.items()}
    mean_err = sum(learned) / max(len(learned), 1)
    rows.append({
        "config": "summary",
        **{f"mean_{t.replace('-', '_')}": round(v, 4)
           for t, v in means.items()},
        "warm_cells": len(learned),
        "mean_gamma_abs_err": round(mean_err, 4),
    })

    # kernel-measured γ grid: real mixed-vs-pure Pallas runs (tiny shapes
    # so interpret-mode CI finishes fast; serving shapes on a real TPU)
    from repro.perf import calibrate_interference
    table, cal = calibrate_interference(
        WORKER.hw, decode_batches=(1, 2), chunk_sizes=(64,), heads=2,
        head_dim=64, page_size=16, pages_per_seq=2, repeats=1)
    assert all(0.0 <= g <= 1.0 for r in table.gamma for g in r), table
    rows.append({"config": "measured-table", "device": cal.device,
                 "grid": "x".join(map(str, (len(table.decode_edges),
                                            len(table.chunk_edges)))),
                 "gamma_min": f"{min(min(r) for r in table.gamma):.3g}",
                 "gamma_max": f"{table.max_gamma:.3g}"})

    emit("fig_interference", rows)
    # the acceptance claims: pricing the contention can only help when the
    # world actually contends, and the online monitor recovers the injected
    # coefficient without being told
    assert means["gamma-aware"] >= means["gamma-blind"], means
    assert learned, "drift runs must warm at least one γ cell"
    assert mean_err < 0.15, (mean_err, sorted(learned))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    if a.quick:
        main(rates=(2.0,), seeds=(11, 13))
    else:
        main()
