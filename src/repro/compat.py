"""Version-compatibility shims for the installed jax.

The repo targets the modern jax API surface; older point releases moved a
few symbols around. Every version-sensitive import goes through here so a
jax upgrade/downgrade is a one-file audit:

* ``shard_map`` — top-level ``jax.shard_map`` (jax >= 0.6) vs
  ``jax.experimental.shard_map.shard_map`` (<= 0.5.x). The experimental
  version also spells the replication-check kwarg ``check_rep`` instead of
  ``check_vma``; the wrapper translates.
* ``tree_map`` — ``jax.tree.map`` (>= 0.4.25) vs ``jax.tree_util.tree_map``.
* ``make_mesh``/``set_mesh``/``AxisType`` — the explicit-sharding mesh API
  (jax >= 0.5/0.6). Older jax has ``jax.make_mesh`` without ``axis_types``
  and no ambient-mesh setter; ``Auto`` axis semantics are the only
  behaviour those versions have, so dropping the kwarg is faithful.
"""
from __future__ import annotations

import contextlib
import enum
import inspect

import jax

try:                                    # jax >= 0.6
    from jax import shard_map as _shard_map
    _NEEDS_KWARG_TRANSLATION = False
except ImportError:                     # jax <= 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _NEEDS_KWARG_TRANSLATION = True


def shard_map(f=None, /, **kwargs):
    """``jax.shard_map`` with the modern kwarg spelling on any jax."""
    if _NEEDS_KWARG_TRANSLATION and "check_vma" in kwargs:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    if f is None:                       # used as shard_map(mesh=...)(f)
        return lambda g: _shard_map(g, **kwargs)
    return _shard_map(f, **kwargs)


if hasattr(jax, "tree") and hasattr(jax.tree, "map"):   # jax >= 0.4.25
    tree_map = jax.tree.map
    tree_leaves = jax.tree.leaves
else:                                   # pragma: no cover - older jax
    tree_map = jax.tree_util.tree_map
    tree_leaves = jax.tree_util.tree_leaves


if hasattr(jax.sharding, "AxisType"):
    AxisType = jax.sharding.AxisType
else:
    class AxisType(enum.Enum):          # placeholder matching >=0.5 names
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


_MAKE_MESH_HAS_AXIS_TYPES = "axis_types" in inspect.signature(
    jax.make_mesh).parameters


def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kwargs):
    """``jax.make_mesh`` accepting ``axis_types`` on any jax version."""
    if axis_types is not None and _MAKE_MESH_HAS_AXIS_TYPES:
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


if hasattr(jax, "set_mesh"):
    set_mesh = jax.set_mesh
elif hasattr(jax.sharding, "use_mesh"):     # pragma: no cover - 0.5.x
    def set_mesh(mesh):
        """0.5.x only has the context-manager form; enter it for the
        process lifetime to match ``jax.set_mesh`` statement semantics
        (call sites use it as a bare statement, never exiting)."""
        cm = jax.sharding.use_mesh(mesh)
        cm.__enter__()
        return cm
else:
    def set_mesh(mesh):
        """No ambient-mesh API on this jax: the repo always passes the mesh
        explicitly (shard_map(mesh=...), in_shardings), so an inert context
        is sufficient."""
        return contextlib.nullcontext(mesh)
