"""Unified workload subsystem: length profiles x arrival processes x SLO
classes, composed into named ``Scenario`` objects, with Mooncake-schema
CSV round-tripping and a TraceReplayBackend-ready ``replay`` iterator.

Grew out of ``serving/trace.py`` (which remains as an import shim); the
legacy single-class ``generate_trace`` keeps its exact RNG stream.
"""
from repro.workload.arrivals import (ArrivalProcess, Diurnal, GammaPoisson,
                                     OnOffBursts, sample_arrivals)
from repro.workload.csvio import load_csv, save_csv
from repro.workload.profiles import (AGENTIC, LONGCTX, MOONCAKE, STEADY,
                                     TraceProfile, sample_lengths)
from repro.workload.scenario import (SCENARIOS, Scenario, ScenarioComponent,
                                     generate_trace, get_scenario,
                                     replay_csv)

__all__ = [
    "AGENTIC",
    "ArrivalProcess",
    "Diurnal",
    "GammaPoisson",
    "LONGCTX",
    "MOONCAKE",
    "OnOffBursts",
    "SCENARIOS",
    "STEADY",
    "Scenario",
    "ScenarioComponent",
    "TraceProfile",
    "generate_trace",
    "get_scenario",
    "load_csv",
    "replay_csv",
    "sample_arrivals",
    "sample_lengths",
    "save_csv",
]
