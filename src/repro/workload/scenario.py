"""Scenarios: named, composable workload definitions.

A ``Scenario`` is a list of components, each owning a length profile, an
arrival process, a traffic share and an SLO class. ``generate`` yields the
merged Request stream (fixed-seed deterministic, per-component independent
RNG streams); ``replay`` yields ``(arrival_time, Request)`` pairs in
arrival order — the iterator contract a ``TraceReplayBackend`` consumes —
and every scenario round-trips through the Mooncake CSV schema
(``repro.workload.csvio``), so a real Mooncake/ShareGPT dump drops in by
loading it instead of generating.

The registry::

    mooncake   the paper's §V-A synthetic trace (long-tail prefills)
    steady     damped tail + near-Poisson arrivals (calibration runs)
    bursty     mooncake lengths, on/off Gamma bursts (flash crowds)
    diurnal    mooncake lengths, sinusoidal rate (day/night cycle)
    longctx    tail-heavy prefills (RAG/document QA, HOL-blocking regime)
    agentic    short-prompt/long-output inversion (decode-bound agents)
    mixture    two tenants: interactive (tight SLO, 2x weight) + batch
               (loose SLO) with distinct profiles and arrival processes

``generate_trace`` is the legacy single-profile entry point, RNG-stream
identical to the pre-package ``serving/trace.py`` — the compatibility shim
every existing benchmark and test reproduces its numbers through.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, Iterator, Optional

import numpy as np

from repro.core.metrics import derive_slos
from repro.core.request import Request, SLOClass
from repro.workload.arrivals import (ArrivalProcess, Diurnal, GammaPoisson,
                                     OnOffBursts, sample_arrivals)
from repro.workload.profiles import (AGENTIC, LONGCTX, MOONCAKE, STEADY,
                                     TraceProfile, sample_lengths)


@dataclasses.dataclass(frozen=True)
class ScenarioComponent:
    """One traffic stream: who arrives when, with what shape, under which
    SLO class. ``slo=None`` derives per-request SLOs from the cost model
    (paper §V-A: scale x the light-load latency of the request's own
    phases), tagged with this component's class name and weight."""
    name: str
    profile: TraceProfile
    arrivals: ArrivalProcess
    rate_frac: float = 1.0          # share of the scenario-level rate
    slo: Optional[SLOClass] = None
    slo_scale: tuple[float, float] = (5.0, 5.0)
    weight: float = 1.0


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    components: tuple[ScenarioComponent, ...]

    def __post_init__(self):
        names = [c.name for c in self.components]
        if len(set(names)) != len(names):
            raise ValueError(
                f"scenario {self.name!r}: duplicate component names "
                f"{names} — substreams are keyed by name")

    def generate(self, rate: float, duration: float, cost_model,
                 seed: int = 0) -> list[Request]:
        """Merged Request stream over [0, duration); ``rate`` is the total
        average arrival rate, split across components by ``rate_frac``.
        Each component draws from a substream keyed by its NAME (not its
        position), so adding/removing/reordering components never perturbs
        the survivors' traffic. Profiles with ``shared_prefixes`` > 0 tag
        eligible requests (prompt > ``prefix_tokens``) with a shared-prompt
        identity from a *separate* RNG substream — arrival/length streams
        are bit-identical with tagging on or off, and the tags themselves
        are inert unless a worker-side prefix cache is armed."""
        rows: list[tuple[float, int, int, SLOClass, Optional[int], int]] = []
        for comp in self.components:
            rng = np.random.default_rng(
                [seed, zlib.crc32(comp.name.encode())])
            times = comp.arrivals.sample(rng, rate * comp.rate_frac,
                                         duration)
            inputs, outputs = sample_lengths(rng, len(times), comp.profile)
            prof = comp.profile
            pkeys: list[Optional[int]] = [None] * len(times)
            if prof.shared_prefixes > 0 and prof.prefix_tokens > 0:
                prng = np.random.default_rng(
                    [seed, zlib.crc32(comp.name.encode()),
                     zlib.crc32(b"prefix")])
                draws = prng.integers(prof.shared_prefixes, size=len(times))
                # identities are globally unique per (component, slot):
                # two components can never alias each other's prompts
                pkeys = [
                    zlib.crc32(f"{comp.name}:{int(k)}".encode())
                    if int(pl) > prof.prefix_tokens else None
                    for k, pl in zip(draws, inputs)]
            for t, pl, ol, pkey in zip(times, inputs, outputs, pkeys):
                if comp.slo is not None:
                    slo = comp.slo
                else:
                    slo = dataclasses.replace(
                        derive_slos(cost_model, int(pl), *comp.slo_scale),
                        name=comp.name, weight=comp.weight)
                rows.append((float(t), int(pl), int(ol), slo, pkey,
                             prof.prefix_tokens if pkey is not None else 0))
        rows.sort(key=lambda x: x[0])
        return [Request(rid=i, arrival_time=t, prompt_len=pl, output_len=ol,
                        slo=slo, prefix_key=pkey, prefix_len=plen)
                for i, (t, pl, ol, slo, pkey, plen) in enumerate(rows)]

    def replay(self, rate: float, duration: float, cost_model,
               seed: int = 0) -> Iterator[tuple[float, Request]]:
        """TraceReplayBackend-ready iterator: ``(arrival_time, Request)``
        in arrival order. A backend replaying a recorded CSV gets the same
        contract from ``replay_csv``."""
        for r in self.generate(rate, duration, cost_model, seed):
            yield r.arrival_time, r

    @property
    def classes(self) -> dict[str, SLOClass]:
        """Fixed SLO classes declared by components (derived-SLO
        components are per-request and absent)."""
        return {c.slo.name: c.slo for c in self.components
                if c.slo is not None}


def replay_csv(path: str, cost_model, slo_scale=(5.0, 5.0),
               classes=None) -> Iterator[tuple[float, Request]]:
    """Replay a recorded Mooncake-schema CSV with the same iterator
    contract as ``Scenario.replay`` — how a real trace drops in."""
    from repro.workload.csvio import load_csv
    for r in load_csv(path, cost_model, slo_scale=slo_scale,
                      classes=classes):
        yield r.arrival_time, r


# ------------------------------------------------------------------ registry

def _single(name: str, profile: TraceProfile,
            arrivals: ArrivalProcess) -> Scenario:
    return Scenario(name, (ScenarioComponent(
        name="default", profile=profile, arrivals=arrivals),))


def _mixture() -> Scenario:
    """Two tenants at a 60/40 traffic split: an interactive class (short
    prompts, tight 3x-light-load SLOs, double weight) sharing the cluster
    with a batch class (long-context prompts, loose 12x SLOs, bursty
    arrivals)."""
    return Scenario("mixture", (
        ScenarioComponent(
            name="interactive", profile=AGENTIC,
            arrivals=GammaPoisson(window=5.0, shape=4.0),
            rate_frac=0.6, slo_scale=(3.0, 3.0), weight=2.0),
        ScenarioComponent(
            name="batch", profile=LONGCTX,
            arrivals=OnOffBursts(on_mean=8.0, off_mean=12.0),
            rate_frac=0.4, slo_scale=(12.0, 12.0), weight=1.0),
    ))


SCENARIOS: dict[str, Callable[[], Scenario]] = {
    "mooncake": lambda: _single("mooncake", MOONCAKE, GammaPoisson()),
    "steady": lambda: _single("steady", STEADY,
                              GammaPoisson(shape=STEADY.burst_shape)),
    "bursty": lambda: _single("bursty", MOONCAKE, OnOffBursts()),
    "diurnal": lambda: _single("diurnal", MOONCAKE, Diurnal()),
    "longctx": lambda: _single("longctx", LONGCTX, GammaPoisson()),
    "agentic": lambda: _single("agentic", AGENTIC, GammaPoisson()),
    "mixture": _mixture,
}


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]()
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; available: "
            f"{sorted(SCENARIOS)}") from None


# ------------------------------------------------------------- legacy shim

def generate_trace(rate: float, duration: float, cost_model,
                   seed: int = 0, profile: TraceProfile = MOONCAKE,
                   slo_scale: tuple[float, float] = (5.0, 5.0),
                   fixed_slo: Optional[SLOClass] = None) -> list[Request]:
    """Paper §V-A SLO setting: TTFT SLO = 5x the light-load prefill latency
    of the request's own prompt; TPOT SLO = 5x the light-load decode
    latency (per-request, as in DistServe). RNG-stream identical to the
    pre-``repro.workload`` implementation: single-class benchmark numbers
    reproduce exactly."""
    rng = np.random.default_rng(seed)
    times = sample_arrivals(rng, rate, duration, profile)
    inputs, outputs = sample_lengths(rng, len(times), profile)
    reqs = []
    for i, (t, pl, ol) in enumerate(zip(times, inputs, outputs)):
        if fixed_slo is not None:
            slo = fixed_slo
        else:
            slo = derive_slos(cost_model, int(pl), slo_scale[0], slo_scale[1])
        reqs.append(Request(rid=i, arrival_time=float(t), prompt_len=int(pl),
                            output_len=int(ol), slo=slo))
    return reqs
