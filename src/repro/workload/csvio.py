"""Mooncake-schema CSV round-tripping.

Base schema (what the public Mooncake trace ships): ``timestamp_ms,
input_length,output_length``. Multi-tenant traces append an optional
``slo_class`` column; single-class traces keep the exact legacy 3-column
layout so files written before the workload package load byte-identically.

``load_csv`` is deliberately forgiving about the things real trace dumps
get wrong — header case/whitespace/BOM, alias column names from other
serving repos (``input_tokens``/``prompt_len``/…), blank trailing lines —
and deliberately strict about the things that silently corrupt an
experiment: missing columns and negative/non-numeric lengths raise
``ValueError`` naming the file, row and field.
"""
from __future__ import annotations

import csv
import dataclasses
from typing import Optional, Sequence

from repro.core.metrics import derive_slos
from repro.core.request import Request, SLOClass

# canonical column -> accepted header aliases (lower-cased, stripped)
_ALIASES = {
    "timestamp_ms": ("timestamp_ms", "timestamp", "arrival_ms", "time_ms",
                     "arrival_time_ms"),
    "input_length": ("input_length", "input_tokens", "prompt_len",
                     "prompt_tokens", "input"),
    "output_length": ("output_length", "output_tokens", "output_len",
                      "generation_tokens", "output"),
    "slo_class": ("slo_class", "class", "tenant", "priority"),
}


def _resolve_header(fieldnames: Sequence[str], path: str) -> dict:
    norm = {}
    for raw in fieldnames or ():
        key = (raw or "").strip().lstrip("\ufeff").strip().lower()
        norm.setdefault(key, raw)
    colmap = {}
    for canon, aliases in _ALIASES.items():
        for a in aliases:
            if a in norm:
                colmap[canon] = norm[a]
                break
    missing = [c for c in ("timestamp_ms", "input_length", "output_length")
               if c not in colmap]
    if missing:
        raise ValueError(
            f"{path}: trace CSV is missing required column(s) {missing}; "
            f"got header {list(fieldnames or ())!r} (accepted aliases: "
            + ", ".join(f"{c}={list(_ALIASES[c])}" for c in missing) + ")")
    return colmap


def _field(row: dict, colmap: dict, canon: str, rownum: int, path: str,
           minimum: int = 0) -> int:
    raw = (row.get(colmap[canon]) or "").strip()
    try:
        val = int(float(raw))
    except ValueError:
        raise ValueError(
            f"{path}:{rownum}: column {canon!r} must be a number, "
            f"got {raw!r}") from None
    if val < minimum:
        raise ValueError(
            f"{path}:{rownum}: column {canon!r} must be >= {minimum}, "
            f"got {val}")
    return val


def save_csv(path: str, requests: Sequence[Request]) -> None:
    """Write the Mooncake schema; the ``slo_class`` column appears only
    when some request carries a non-default class (legacy files stay
    byte-identical)."""
    with_class = any(r.slo.name != "default" for r in requests)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        header = ["timestamp_ms", "input_length", "output_length"]
        if with_class:
            header.append("slo_class")
        w.writerow(header)
        for r in requests:
            row = [int(r.arrival_time * 1000), r.prompt_len, r.output_len]
            if with_class:
                row.append(r.slo.name)
            w.writerow(row)


def load_csv(path: str, cost_model, slo_scale=(5.0, 5.0),
             classes: Optional[dict[str, SLOClass]] = None) -> list[Request]:
    """Load a Mooncake-schema trace into Request objects.

    ``classes`` maps ``slo_class`` column values to SLOClass objects
    (unknown/absent names fall back to per-request derived SLOs carrying
    the class name, so a real multi-tenant dump still splits in the
    per-class metrics even before its SLO tiers are configured)."""
    reqs: list[Request] = []
    with open(path, newline="") as f:
        reader = csv.DictReader(f)
        colmap = _resolve_header(reader.fieldnames, path)
        rid = 0
        for rownum, row in enumerate(reader, start=2):
            if not any((v or "").strip() for v in row.values()):
                continue                      # blank / trailing line
            ts = _field(row, colmap, "timestamp_ms", rownum, path)
            pl = _field(row, colmap, "input_length", rownum, path, minimum=1)
            ol = _field(row, colmap, "output_length", rownum, path, minimum=1)
            cname = "default"
            if "slo_class" in colmap:
                cname = (row.get(colmap["slo_class"]) or "").strip() \
                    or "default"
            if classes is not None and cname in classes:
                slo = classes[cname]
            else:
                slo = derive_slos(cost_model, pl, *slo_scale)
                if cname != "default":
                    slo = dataclasses.replace(slo, name=cname)
            reqs.append(Request(rid=rid, arrival_time=ts / 1000.0,
                                prompt_len=pl, output_len=ol, slo=slo))
            rid += 1
    return reqs
