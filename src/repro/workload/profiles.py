"""Request-length profiles.

The Mooncake trace (paper §V-A) is not packaged offline, so we synthesise
length marginals matching the paper's characterisation (Fig. 3) and add
profiles for the workload families the paper's single trace cannot cover:

* ``MOONCAKE``  — long-tail prefills (lognormal body + heavy lognormal
  tail), short low-variance outputs;
* ``STEADY``    — the same shape with the tail and burstiness damped;
* ``LONGCTX``   — tail-heavy prefills: half the traffic is long-context
  (RAG / document QA), the regime where prefill head-of-line blocking
  dominates;
* ``AGENTIC``   — the inversion: short prompts, long generations (agents,
  chain-of-thought, code synthesis) — decode-capacity bound.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceProfile:
    name: str = "mooncake-like"
    # input-length mixture (lognormal body + tail)
    body_median: float = 2048.0
    body_sigma: float = 1.1
    tail_median: float = 16384.0
    tail_sigma: float = 0.7
    tail_frac: float = 0.15
    min_input: int = 16
    max_input: int = 32768      # Mooncake-like long-context cap: the tail
                                # service time stays within ~1x of the TTFT
                                # SLO (as in the paper's A100 setup), so
                                # head-of-line effects degrade rather than
                                # structurally break attainment
    # output lengths
    out_median: float = 256.0
    out_sigma: float = 0.7
    min_output: int = 2
    max_output: int = 2048
    # burstiness: per-window Gamma(shape k) rate modulation; k->inf = Poisson
    burst_window: float = 10.0      # seconds
    burst_shape: float = 2.0
    # shared system prompts: requests whose prompt exceeds ``prefix_tokens``
    # carry one of ``shared_prefixes`` prefix identities (uniformly drawn
    # from a dedicated RNG substream, so tagging never perturbs the
    # length/arrival streams). 0 = no sharing; the tags are inert unless a
    # worker-side prefix cache is armed.
    shared_prefixes: int = 0
    prefix_tokens: int = 0


MOONCAKE = TraceProfile(shared_prefixes=8, prefix_tokens=512)
STEADY = TraceProfile(name="steady", tail_frac=0.05, burst_shape=50.0)
LONGCTX = TraceProfile(
    name="longctx", tail_frac=0.45, tail_median=24576.0, tail_sigma=0.5,
    body_median=4096.0, out_median=192.0)
AGENTIC = TraceProfile(
    name="agentic", body_median=512.0, body_sigma=0.8, tail_frac=0.02,
    tail_median=4096.0, out_median=1024.0, out_sigma=0.9,
    min_output=64, max_output=4096,
    # agents re-enter with the same system prompt + tool schema: few
    # identities, high re-use — the prefix-cache sweet spot
    shared_prefixes=4, prefix_tokens=256)


def sample_lengths(rng: np.random.Generator, n: int,
                   prof: TraceProfile) -> tuple[np.ndarray, np.ndarray]:
    tail = rng.random(n) < prof.tail_frac
    body = rng.lognormal(math.log(prof.body_median), prof.body_sigma, n)
    tl = rng.lognormal(math.log(prof.tail_median), prof.tail_sigma, n)
    inputs = np.where(tail, tl, body)
    inputs = np.clip(inputs, prof.min_input, prof.max_input).astype(int)
    outputs = rng.lognormal(math.log(prof.out_median), prof.out_sigma, n)
    outputs = np.clip(outputs, prof.min_output, prof.max_output).astype(int)
    return inputs, outputs
