"""Arrival processes.

Every process is a frozen spec with ``sample(rng, rate, duration) ->
sorted arrival times in [0, duration)`` where ``rate`` is the *average*
request rate — processes shape the fluctuation around it, never the mean,
so scenarios stay comparable at equal offered load.

* ``GammaPoisson`` — doubly-stochastic Poisson: per-window Gamma rate
  modulation (the short-term burstiness of Mooncake Fig. 3a; shape→inf
  degenerates to plain Poisson);
* ``OnOffBursts``  — on/off source with Gamma-distributed burst and gap
  durations; all traffic arrives inside bursts at ``rate / duty``;
* ``Diurnal``      — sinusoidal rate λ(t) = rate·(1 + amp·sin 2πt/period),
  sampled exactly by thinning.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.workload.profiles import TraceProfile


class ArrivalProcess:
    def sample(self, rng: np.random.Generator, rate: float,
               duration: float) -> np.ndarray:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class GammaPoisson(ArrivalProcess):
    window: float = 10.0        # seconds per modulation window
    shape: float = 2.0          # Gamma shape; ->inf = plain Poisson

    def sample(self, rng, rate, duration):
        times: list[float] = []
        t = 0.0
        while t < duration:
            window_rate = rate * rng.gamma(self.shape, 1.0 / self.shape)
            end = min(t + self.window, duration)
            n = rng.poisson(window_rate * (end - t))
            times.extend(rng.uniform(t, end, n))
            t = end
        return np.sort(np.asarray(times))


@dataclasses.dataclass(frozen=True)
class OnOffBursts(ArrivalProcess):
    on_mean: float = 5.0        # mean burst length, seconds
    off_mean: float = 15.0      # mean silence between bursts
    shape: float = 2.0          # Gamma shape of both period lengths

    def sample(self, rng, rate, duration):
        # all load arrives during ON periods; scale the in-burst rate by
        # the duty cycle so the long-run average stays ``rate``
        duty = self.on_mean / (self.on_mean + self.off_mean)
        rate_on = rate / max(duty, 1e-9)
        times: list[float] = []
        t = 0.0
        while t < duration:
            on = rng.gamma(self.shape, self.on_mean / self.shape)
            end = min(t + on, duration)
            n = rng.poisson(rate_on * (end - t))
            times.extend(rng.uniform(t, end, n))
            t = end + rng.gamma(self.shape, self.off_mean / self.shape)
        return np.sort(np.asarray(times))


@dataclasses.dataclass(frozen=True)
class Diurnal(ArrivalProcess):
    period: float = 120.0       # one "day" (compressed to sim scale)
    amplitude: float = 0.6      # peak-to-mean rate swing, in [0, 1)
    phase: float = 0.0          # radians; 0 starts at mean load rising

    def sample(self, rng, rate, duration):
        lam_max = rate * (1.0 + self.amplitude)
        n = rng.poisson(lam_max * duration)
        cand = np.sort(rng.uniform(0.0, duration, n))
        lam = rate * (1.0 + self.amplitude * np.sin(
            2.0 * np.pi * cand / self.period + self.phase))
        keep = rng.random(len(cand)) < lam / lam_max   # exact thinning
        return cand[keep]


def sample_arrivals(rng: np.random.Generator, rate: float, duration: float,
                    prof: TraceProfile) -> np.ndarray:
    """Legacy entry point: Gamma-modulated Poisson arrivals driven by the
    profile's ``burst_window``/``burst_shape`` fields (byte-identical RNG
    consumption to the pre-workload-package ``serving/trace.py``)."""
    return GammaPoisson(window=prof.burst_window,
                        shape=prof.burst_shape).sample(rng, rate, duration)
