"""Checkpointing with cross-mesh resharding (elastic restart).

Fault-tolerance contract:
  * ``save`` writes params + optimizer state + step to a directory
    (msgpack-framed raw buffers + a JSON manifest), atomically
    (tmp + rename) so a mid-write crash never corrupts the latest.
  * ``restore`` reads into ANY mesh/sharding — arrays are written as
    full (unsharded) host buffers and re-placed with jax.device_put under
    the new sharding, so a job can restart on a different topology
    (elastic scale up/down).
  * ``latest_step`` + retention rotation for restart loops.

On a real multi-host cluster the full-gather save would be replaced by
per-shard writes (tensorstore); the manifest/restore/resharding logic is
the part under test here and is host-count independent.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str | Path, step: int, tree: Any, keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f".tmp_step_{step}"
    final = ckpt_dir / f"step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    flat = _flatten(tree)
    manifest = {}
    with open(tmp / "arrays.bin", "wb") as f:
        for key, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            raw = arr.tobytes()
            manifest[key] = {
                "dtype": str(arr.dtype), "shape": list(arr.shape),
                "offset": f.tell(), "nbytes": len(raw),
            }
            f.write(raw)
    (tmp / "manifest.json").write_text(json.dumps(
        {"step": step, "arrays": manifest}))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)

    # retention
    ckpts = sorted(p for p in ckpt_dir.iterdir()
                   if p.name.startswith("step_"))
    for old in ckpts[:-keep]:
        shutil.rmtree(old)
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.iterdir()
             if p.name.startswith("step_")]
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, step: int, target_tree: Any,
            shardings: Any = None) -> Any:
    """Read ``step`` into the structure of ``target_tree``; each leaf is
    device_put under the matching ``shardings`` leaf (None = default
    placement). Works across mesh shapes (full buffers on host)."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    meta = json.loads((d / "manifest.json").read_text())["arrays"]
    data = (d / "arrays.bin").read_bytes()

    flat_target = _flatten(target_tree)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    out = {}
    for key, leaf in flat_target.items():
        info = meta[key]
        arr = np.frombuffer(
            data, dtype=np.dtype(info["dtype"]), count=-1,
            offset=info["offset"],
        )[: int(np.prod(info["shape"])) if info["shape"] else 1]
        arr = arr.reshape(info["shape"])
        sh = flat_shard.get(key)
        out[key] = jax.device_put(arr, sh) if sh is not None \
            else jnp.asarray(arr)

    # unflatten back into the target structure
    leaves_paths = jax.tree_util.tree_flatten_with_path(target_tree)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path) for path, _ in leaves_paths[0]]
    new_leaves = [out[k] for k in keys]
    return jax.tree_util.tree_unflatten(leaves_paths[1], new_leaves)
