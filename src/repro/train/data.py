"""Deterministic synthetic token pipeline, shard-aware.

Produces the train batches the dry-run lowers against: {tokens, labels}
(+ frames / prefix_embeds for the encdec / vlm families). Deterministic in
(seed, step) so a restarted job resumes mid-epoch without drift — the
checkpoint stores only the step counter.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int
    seq: int
    seed: int = 0
    dec_len: int = 64          # decoder tokens (encdec)


class SyntheticLM:
    """Zipf-ish token stream with local structure (repeated n-grams) so the
    loss actually decreases during the example training runs."""

    def __init__(self, cfg: ModelConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.data.seed << 20) ^ step)
        b, s, v = self.data.batch, self.data.seq, self.cfg.vocab_size
        # zipfian marginal
        ranks = rng.zipf(1.3, size=(b, s + 1)).astype(np.int64)
        toks = np.minimum(ranks, v - 1).astype(np.int32)
        # inject copyable bigram structure: x[t] = x[t-2] with prob .3
        mask = rng.random((b, s + 1)) < 0.3
        toks[:, 2:] = np.where(mask[:, 2:], toks[:, :-2], toks[:, 2:])
        out = {"tokens": jnp.asarray(toks[:, :-1]),
               "labels": jnp.asarray(toks[:, 1:])}
        if self.cfg.family == "encdec":
            frames = rng.normal(size=(b, s, self.cfg.d_model)) * 0.1
            d = self.data.dec_len
            out = {"tokens": jnp.asarray(toks[:, :d]),
                   "labels": jnp.asarray(toks[:, 1:d + 1]),
                   "frames": jnp.asarray(frames, jnp.float32).astype(
                       self.cfg.dtype)}
        if self.cfg.family == "vlm":
            pe = rng.normal(size=(b, self.cfg.num_patches,
                                  self.cfg.vision_feature_dim)) * 0.1
            out["prefix_embeds"] = jnp.asarray(pe, jnp.float32).astype(
                self.cfg.dtype)
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
