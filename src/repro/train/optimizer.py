"""AdamW in pure JAX with ZeRO-1-style sharded optimizer state.

States (m, v, and the f32 master copy) inherit the parameter's
PartitionSpec and additionally shard their largest replicated dimension
over the data axis when divisible — the pjit formulation of optimizer-state
sharding (ZeRO-1): each data-parallel rank owns a slice of the states, XLA
inserts the reduce-scatter/all-gather pair around the update.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_state(params):
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
    }


def state_specs(param_specs, params_shape=None, zero_axis: str = "data",
                zero_size: int = 16):
    """Optimizer-state PartitionSpecs (ZeRO-1): inherit the param spec and
    additionally shard the first replicated *divisible* dim over
    ``zero_axis`` — each data-parallel rank then owns a slice of m/v/master
    and XLA places the corresponding reduce-scatter/all-gather around the
    update. ``params_shape`` (matching pytree of shaped leaves) enables the
    divisibility check; without it no widening happens."""

    def _axes_used(spec):
        used = set()
        for p in spec:
            if p is None:
                continue
            if isinstance(p, (tuple, list)):
                used.update(p)
            else:
                used.add(p)
        return used

    def widen(spec, leaf=None):
        if zero_axis is None or leaf is None or zero_axis in _axes_used(spec):
            return spec
        parts = list(spec)
        for i, p in enumerate(parts):
            if p is None and leaf.shape[i] % zero_size == 0 \
                    and leaf.shape[i] > 0:
                parts[i] = zero_axis
                return P(*parts)
        return spec

    if params_shape is None:
        wide = param_specs
    else:
        wide = jax.tree.map(widen, param_specs, params_shape,
                            is_leaf=lambda x: isinstance(x, P))
    return {
        "step": P(),
        "m": wide,
        "v": wide,
        "master": wide,
    }


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    return cfg.lr * warm


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, state, cfg: AdamWConfig = AdamWConfig()):
    """Returns (new_params, new_state). Grads may be bf16; math is f32."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = _schedule(cfg, state["step"])
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master):
        gf = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(gf)
        mh = m / b1c
        vh = v / b2c
        new_master = master - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                                    + cfg.weight_decay * master)
        return m, v, new_master

    out = jax.tree.map(upd, grads, state["m"], state["v"], state["master"])
    m = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda mp, p: mp.astype(p.dtype), master, params)
    return new_params, {"step": step, "m": m, "v": v, "master": master}


def make_train_step(loss_fn, cfg: AdamWConfig = AdamWConfig()):
    """loss_fn(params, batch) -> scalar. Returns step(params, state, batch)
    -> (params, state, loss)."""

    def step(params, state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, state = apply_updates(params, grads, state, cfg)
        return params, state, loss

    return step
