import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Perf hillclimb harness (§Perf): recompile one dry-run cell with a
named variant and report the roofline-term delta vs baseline.

  python -m repro.launch.hillclimb --arch kimi-k2-1t --shape decode_32k \
      --variant ep_floor1

Variants are (cfg, EPInfo, spec) transformations — each encodes one
hypothesis from the §Perf log. Results: experiments/perf/<cell>__<v>.json
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs import get_config
from repro.launch import hloanalysis
from repro.launch.dryrun import (SHAPES, WHISPER_DEC_PREFILL,
                                 WHISPER_DEC_TRAIN, _cache_for, _dryrun_cfg,
                                 _ep_for, build_step, input_specs)
from repro.launch.mesh import make_production_mesh
from repro.models import api as model_api
from repro.models import sharding
from repro.models.moe import EPInfo
from repro.train import optimizer


# --------------------------------------------------------------- variants

def v_baseline(cfg, ep):
    return cfg, ep


def v_kv_fp8(cfg, ep):
    """Hypothesis: decode is memory-bound on KV reads; fp8 storage halves
    cache bytes -> memory term ~/2 where KV >> weights."""
    return dataclasses.replace(cfg, kv_cache_quant=True), ep


def v_window_cache(cfg, ep):
    """Hypothesis (gemma2): local layers only ever attend within the
    window; a window-sized ring cache removes (S-W)/S of their KV reads
    and memory — halves cache footprint at 32k, ~2x more at 500k.
    (requires unrolled layers: per-layer cache shapes)"""
    return dataclasses.replace(cfg, window_sized_cache=True,
                               scan_layers=False), ep


def v_kv_fp8_window(cfg, ep):
    cfg, ep = v_kv_fp8(cfg, ep)
    return v_window_cache(cfg, ep)


def v_ep_floor1(cfg, ep):
    """Hypothesis (MoE decode): with T_loc*k << E_pad, the capacity floor
    of 4 pads the all_to_all buffers and expert GEMMs 4x; floor 1 cuts EP
    compute and collective bytes ~4x at identical routing semantics."""
    return cfg, dataclasses.replace(ep, capacity_floor=1)


def v_ep_cf1(cfg, ep):
    """Capacity factor 2 -> 1.25: less padding at slightly higher drop
    risk (train-side lever)."""
    return cfg, dataclasses.replace(
        ep, capacity_factor=1.25,
    )


def v_ep_fused_a2a(cfg, ep):
    """Hypothesis: the per-axis all_to_all composition moves the dispatch
    buffer once per mesh axis (2x on a 2-axis EP group); a single fused
    all_to_all halves EP wire bytes."""
    return cfg, dataclasses.replace(ep, fused_a2a=True)


def v_ep_cf1_fused(cfg, ep):
    cfg, ep = v_ep_cf1(cfg, ep)
    return v_ep_fused_a2a(cfg, ep)


def v_ep_train_best(cfg, ep):
    """Stacked winners for MoE train: cf 1.25 + fused a2a + no remat."""
    cfg, ep = v_ep_cf1_fused(cfg, ep)
    return v_remat_none(cfg, ep)


def v_ep_allgather(cfg, ep):
    """Hypothesis (MoE decode, beyond-paper): with T_global tokens << N*C
    padded slots, all_to_all routing is the wrong algorithm — broadcast all
    tokens (O(T*d)), compute local experts masked, psum the contributions
    (O(T*d)). Predicted ~15-20x lower collective volume for kimi decode."""
    return cfg, dataclasses.replace(ep, ep_mode="allgather")


def v_remat_none(cfg, ep):
    """Hypothesis (train): dots-saveable remat re-runs every block matmul
    in bwd (+~30% dot flops); disabling remat trades memory for compute."""
    return dataclasses.replace(cfg, remat_policy="none"), ep


VARIANTS = {
    "baseline": v_baseline,
    "kv_fp8": v_kv_fp8,
    "window_cache": v_window_cache,
    "kv_fp8_window": v_kv_fp8_window,
    "ep_floor1": v_ep_floor1,
    "ep_cf1": v_ep_cf1,
    "ep_fused_a2a": v_ep_fused_a2a,
    "ep_cf1_fused": v_ep_cf1_fused,
    "ep_train_best": v_ep_train_best,
    "ep_allgather": v_ep_allgather,
    "remat_none": v_remat_none,
}


def run_variant(arch: str, shape_name: str, variant: str,
                outdir: Path = Path("experiments/perf"),
                mesh_kind: str = "single") -> dict:
    outdir.mkdir(parents=True, exist_ok=True)
    out_path = outdir / f"{arch}__{shape_name}__{variant}.json"

    info = SHAPES[shape_name]
    kind, seq, batch = info["kind"], info["seq"], info["batch"]
    cfg = _dryrun_cfg(get_config(arch), kind)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    compat.set_mesh(mesh)
    rules = sharding.make_rules(mesh)
    ep = _ep_for(cfg, mesh, rules)
    cfg, ep = VARIANTS[variant](cfg, ep)

    rec = {"arch": arch, "shape": shape_name, "variant": variant,
           "status": "error"}
    try:
        api = model_api.build(cfg)
        t0 = time.time()
        params_shape = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
        pspecs = sharding.param_specs(cfg, params_shape, rules)
        ins, ispecs = input_specs(cfg, shape_name, rules)

        # step with the (possibly modified) ep
        if kind == "train":
            loss_fn = lambda p, b: api.loss(p, b, ep=ep)
            step = optimizer.make_train_step(loss_fn)
            opt_shape = jax.eval_shape(optimizer.init_state, params_shape)
            ospecs = optimizer.state_specs(
                pspecs, params_shape, zero_size=int(mesh.shape["data"]))
            jitted = jax.jit(step, in_shardings=(pspecs, ospecs, ispecs),
                             out_shardings=(pspecs, ospecs, P()),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_shape, opt_shape, ins)
        else:
            cache_shape, cspecs = _cache_for(cfg, api, shape_name, rules)
            if kind == "prefill":
                def step(params, cache, tokens, lengths):
                    return api.prefill(params, cache, tokens, lengths, ep=ep)
            else:
                def step(params, cache, tokens, lengths):
                    return api.decode(params, cache, tokens, lengths, ep=ep)
            order = list(ins.keys())
            jitted = jax.jit(
                step,
                in_shardings=(pspecs, cspecs) + tuple(ispecs[k] for k in order),
                out_shardings=(P(sharding.batch_spec(rules, batch), None),
                               cspecs),
                donate_argnums=(1,))
            lowered = jitted.lower(params_shape, cache_shape,
                                   *[ins[k] for k in order])
        compiled = lowered.compile()
        t1 = time.time()

        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        mc = hloanalysis.module_cost(hlo)
        coll = mc["collectives"]
        dot = {"flops": mc["flops"], "bytes": mc["bytes"]}
        resident = float(mem.argument_size_in_bytes
                         + mem.output_size_in_bytes
                         - mem.alias_size_in_bytes)
        dec_len = WHISPER_DEC_TRAIN if kind == "train" else WHISPER_DEC_PREFILL
        mflops = hloanalysis.model_flops(cfg, kind, batch, seq, dec_len)
        rl = hloanalysis.roofline(dot, resident, coll, mflops,
                                  mesh.devices.size)
        rec.update(status="ok", compile_s=round(t1 - t0, 2),
                   roofline=rl.row(),
                   bytes_per_device=resident,
                   collectives={"wire_total": coll["wire_total"],
                                "total": coll["total"]})
        print(f"[hillclimb] {arch} {shape_name} {variant}: "
              f"step={rl.step_s*1e3:.2f}ms dom={rl.dominant} "
              f"c/m/coll={rl.compute_s*1e3:.2f}/{rl.memory_s*1e3:.2f}/"
              f"{rl.collective_s*1e3:.2f}ms rf={rl.roofline_fraction:.3f} "
              f"mem={resident/1e9:.2f}GB")
    except Exception as e:  # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-3000:]
        print(f"[hillclimb] {arch} {shape_name} {variant}: FAIL "
              f"{rec['error'][:200]}")
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True,
                    help=",".join(VARIANTS) + " (comma-separated ok)")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    for v in args.variant.split(","):
        run_variant(args.arch, args.shape, v, mesh_kind=args.mesh)


if __name__ == "__main__":
    main()
