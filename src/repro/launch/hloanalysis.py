"""Compiled-HLO analysis: collective bytes + roofline terms.

``cost_analysis()`` gives per-device FLOPs / bytes-accessed but no
collective traffic, so we parse the post-SPMD HLO text and sum operand
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.

Every layer/chunk loop is unrolled in dry-run configs (see
ModelConfig.scan_layers / attn_unroll_chunks) so no while-trip-count
multipliers are needed; the only remaining scans are the rwkv/mamba time
recurrences, which contain no collectives and contribute only a few
percent of FLOPs (documented in EXPERIMENTS.md §Methodology).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return max(1, int(m.group(2)))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return max(1, len(m.group(1).split(",")))
    return 1


def collective_bytes(hlo_text: str) -> dict:
    """Collective traffic from compiled (post-SPMD) HLO.

    Two accountings per kind:
      * operand bytes — the assignment's formula (sum of operand sizes);
      * wire bytes — per-device link traffic under ring algorithms
        (all-gather / reduce-scatter ~ (N-1)/N x full buffer; all-reduce ~
        2x that; permute = operand). Wire bytes feed the collective
        roofline term.
    """
    out = {k: 0 for k in _COLLECTIVES}
    wire = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        kind = None
        for k in _COLLECTIVES:
            if re.search(rf"\s{k}(-start)?\(", stripped):
                kind = k
                break
        if kind is None:
            continue
        # result shape sits between '=' and the op name in compiled HLO:
        #   %all-reduce.5 = f32[8,1,4096]{2,1,0} all-reduce(%fusion)
        rhs = stripped.split("=", 1)[1] if "=" in stripped else stripped
        head = rhs.split(kind)[0]
        result_b = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head))
        n = _group_size(stripped)
        ring = (n - 1) / n if n > 1 else 0.0
        if kind == "all-gather":
            operand_b = result_b // max(n, 1)
            wire_b = int(result_b * ring)
        elif kind == "reduce-scatter":
            operand_b = result_b * n          # operand is the full buffer
            wire_b = int(operand_b * ring)
        elif kind == "all-reduce":
            operand_b = result_b
            wire_b = int(2 * result_b * ring)
        elif kind == "all-to-all":
            operand_b = result_b
            wire_b = int(result_b * ring)
        else:  # collective-permute
            operand_b = result_b
            wire_b = result_b
        out[kind] += operand_b
        wire[kind] += wire_b
        counts[kind] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["wire"] = wire
    out["wire_total"] = sum(wire[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


# ------------------------------------------------- while-body flop correction

_BLOCK_HEAD = re.compile(r"^(%[\w.\-]+|ENTRY [%\w.\-]+) \((.*?)\) -> .* \{")
_DEF_RE = re.compile(r"^\s*(%[\w.\-]+) = ([a-z0-9]+)\[([\d,]*)\]")
_PARAM_RE = re.compile(r"(%?[\w.\-]+): ([a-z0-9]+)\[([\d,]*)\]")
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=(%[\w.\-]+), body=(%[\w.\-]+)")
_CONST_RE = re.compile(r"(%[\w.\-]+) = s32\[\] constant\((\d+)\)")
_CMP_RE = re.compile(
    r"compare\((?:s32\[\] )?(%[\w.\-]+), (?:s32\[\] )?(%[\w.\-]+)\)"
    r".*direction=LT")
_DOT_RE = re.compile(
    r"(%[\w.\-]+) = ([a-z0-9]+)\[([\d,]*)\][^=]*? dot\((%[\w.\-]+), "
    r"(%[\w.\-]+)\)(.*)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _split_blocks(hlo_text: str) -> dict[str, list[str]]:
    blocks: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = _BLOCK_HEAD.match(line.strip())
        if m:
            name = m.group(1).replace("ENTRY ", "")
            cur = name
            blocks[cur] = [line]
        elif cur is not None:
            blocks[cur].append(line)
            if line.strip() == "}":
                cur = None
    return blocks


def _shape_map(block_lines: list[str]) -> dict[str, tuple[str, list[int]]]:
    shapes = {}
    header = block_lines[0]
    for name, dt, dims in _PARAM_RE.findall(header):
        key = name if name.startswith("%") else "%" + name
        shapes[key] = (dt, [int(d) for d in dims.split(",") if d])
    for line in block_lines[1:]:
        m = _DEF_RE.match(line)
        if m:
            shapes[m.group(1)] = (
                m.group(2), [int(d) for d in m.group(3).split(",") if d])
    return shapes


def _trip_count(cond_lines: list[str]) -> int:
    consts = dict(_CONST_RE.findall("\n".join(cond_lines)))
    m = _CMP_RE.search("\n".join(cond_lines))
    if m:
        for side in (m.group(2), m.group(1)):
            if side in consts:
                return int(consts[side])
    # fall back: the largest s32 constant in the condition
    vals = [int(v) for v in consts.values()]
    return max(vals) if vals else 1


def _body_dot_flops(body_lines: list[str]) -> tuple[float, float]:
    """(dot flops, dot operand+result bytes) for one body iteration."""
    shapes = _shape_map(body_lines)
    flops = 0.0
    bytes_ = 0.0
    for line in body_lines:
        m = _DOT_RE.search(line)
        if not m:
            continue
        _, rdt, rdims, lhs, rhs, tail = m.groups()
        rshape = [int(d) for d in rdims.split(",") if d]
        out = 1
        for d in rshape:
            out *= d
        contract = 1
        mc = _LHS_C_RE.search(tail)
        if mc and lhs in shapes:
            ldims = shapes[lhs][1]
            for ci in (int(c) for c in mc.group(1).split(",") if c):
                if ci < len(ldims):
                    contract *= ldims[ci]
        flops += 2.0 * out * contract
        bytes_ += out * _DTYPE_BYTES.get(rdt, 4)
        for op in (lhs, rhs):
            if op in shapes:
                dt, dims = shapes[op]
                n = 1
                for d in dims:
                    n *= d
                bytes_ += n * _DTYPE_BYTES.get(dt, 4)
    return flops, bytes_


def scan_correction(hlo_text: str) -> dict:
    """Extra (trip-1) x body cost for every while loop: XLA's static cost
    model counts loop bodies once, so scanned attention chunks / time
    recurrences are under-counted by the trip count. Returns per-device
    {flops, bytes, loops:[(trip, body_flops)]}."""
    blocks = _split_blocks(hlo_text)
    extra_f = 0.0
    extra_b = 0.0
    loops = []
    for name, lines in blocks.items():
        for line in lines:
            m = _WHILE_RE.search(line)
            if not m:
                continue
            cond, body = m.group(1), m.group(2)
            if cond not in blocks or body not in blocks:
                continue
            trip = _trip_count(blocks[cond])
            bf, bb = _body_dot_flops(blocks[body])
            if trip > 1:
                extra_f += (trip - 1) * bf
                extra_b += (trip - 1) * bb
                loops.append({"trip": trip, "body_dot_flops": bf})
    return {"flops": extra_f, "bytes": extra_b, "loops": loops}


_CALL_RE = re.compile(
    r"(?:calls=|to_apply=|body=|condition=)(%[\w.\-]+)")


def _block_multipliers(blocks: dict) -> dict[str, float]:
    """Execution-count multiplier per computation via the call graph:
    while bodies execute trip times (from the paired condition), other
    callees inherit their caller's multiplier. Handles nested scans
    (layer-scan x chunk-scan) by composition."""
    # edges: callee -> (caller, factor)
    edges: dict[str, tuple[str, float]] = {}
    for caller, lines in blocks.items():
        text = "\n".join(lines)
        for m in _WHILE_RE.finditer(text):
            cond, body = m.group(1), m.group(2)
            trip = _trip_count(blocks.get(cond, [])) if cond in blocks else 1
            edges[body] = (caller, float(max(trip, 1)))
            edges[cond] = (caller, float(max(trip, 1)))
        for name in _CALL_RE.findall(text):
            if name not in edges:
                edges[name] = (caller, 1.0)

    mult: dict[str, float] = {}

    def resolve(b: str, depth=0) -> float:
        if b in mult:
            return mult[b]
        if depth > 50 or b not in edges:
            mult[b] = 1.0
            return 1.0
        caller, factor = edges[b]
        mult[b] = factor * resolve(caller, depth + 1)
        return mult[b]

    for b in blocks:
        resolve(b)
    return mult


def module_cost(hlo_text: str) -> dict:
    """Per-device MXU work + collective traffic with execution-count
    multipliers (scan bodies count trip x, nested loops compose).

    The dot-flop measure is the roofline-relevant compute term — verified
    to match MODEL_FLOPS/chip exactly on a hand-checked decode cell,
    whereas XLA-CPU cost_analysis()['flops'] also counts VPU/elementwise
    emulation noise (converts, masks, scatters) and overstates 10-100x."""
    blocks = _split_blocks(hlo_text)
    mult = _block_multipliers(blocks)
    f_total = 0.0
    b_total = 0.0
    coll = {k: 0.0 for k in _COLLECTIVES}
    wire = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    n_loops = 0
    for name, lines in blocks.items():
        m = mult.get(name, 1.0)
        if m > 1:
            n_loops += 1
        f, b = _body_dot_flops(lines)
        f_total += m * f
        b_total += m * b
        c = collective_bytes("\n".join(lines))
        for k in _COLLECTIVES:
            coll[k] += m * c[k]
            wire[k] += m * c["wire"][k]
            counts[k] += c["counts"][k]
    out = dict(coll)
    out["total"] = sum(coll.values())
    out["wire"] = wire
    out["wire_total"] = sum(wire.values())
    out["counts"] = counts
    return {"flops": f_total, "bytes": b_total, "collectives": out,
            "n_multiplied_blocks": n_loops}


def dot_cost(hlo_text: str) -> dict:
    """Back-compat wrapper over module_cost."""
    mc = module_cost(hlo_text)
    return {"flops": mc["flops"], "bytes": mc["bytes"],
            "loops": [None] * mc["n_multiplied_blocks"]}


# ------------------------------------------------------------------ roofline

PEAK_FLOPS = 197e12       # bf16 / chip (v5e)
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float              # per device (MXU dot flops, loop-corrected)
    hlo_bytes: float              # per device (see memory accounting note)
    coll_bytes: float             # per device
    model_flops_per_device: float
    useful_ratio: float           # model_flops / hlo_flops

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful compute time / modeled step time."""
        useful = self.model_flops_per_device / PEAK_FLOPS
        return useful / self.step_s if self.step_s > 0 else 0.0

    def row(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "model_flops_per_device": self.model_flops_per_device,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline(dot: dict, resident_bytes: float, coll: dict,
             model_flops_total: float, n_devices: int) -> RooflineTerms:
    """Three-term roofline per device.

    compute    = parsed MXU dot flops (loop-corrected) / peak
    memory     = max(resident-state bytes touched once per step
                     [weights+caches+opt — the decode/train floor],
                     dot operand+result traffic) / HBM bw
    collective = ring wire bytes / link bw
    (XLA-CPU cost_analysis is recorded raw in the JSON but not used: its
    flops/bytes include f32-emulation artifacts that do not exist on TPU.)
    """
    flops = float(dot["flops"])
    bytes_ = max(float(resident_bytes), float(dot["bytes"]))
    cb = float(coll.get("wire_total", coll.get("total", 0.0)))
    mf = model_flops_total / n_devices
    return RooflineTerms(
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_ / HBM_BW,
        collective_s=cb / ICI_BW,
        hlo_flops=flops, hlo_bytes=bytes_, coll_bytes=cb,
        model_flops_per_device=mf,
        useful_ratio=mf / flops if flops > 0 else 0.0,
    )


def model_flops(cfg, kind: str, batch: int, seq: int,
                dec_len: Optional[int] = None) -> float:
    """MODEL_FLOPS: 6·N_active·D for train, 2·N_active·D (+attn) for
    inference — the classical useful-work estimate."""
    from repro.perf import build_cost_spec
    spec = build_cost_spec(cfg)
    if kind == "train":
        d = dec_len if cfg.family == "encdec" and dec_len else seq
        tokens = batch * d
        base = 6.0 * spec.n_active * tokens
        if cfg.family == "encdec":
            # encoder fwd+bwd over seq frames
            enc_active = spec.n_params - spec.n_active
            base += 6.0 * enc_active * batch * seq
        attn = 3.0 * spec.attn_flops_per_ctx_token * (seq / 2) * tokens
        return base + attn
    if kind == "prefill":
        tokens = batch * seq
        base = 2.0 * spec.n_active * tokens
        if cfg.family == "encdec":
            enc_active = spec.n_params - spec.n_active
            base = 2.0 * enc_active * tokens + 2.0 * spec.n_active * batch * (dec_len or 64)
        attn = spec.attn_flops_per_ctx_token * (seq / 2) * tokens
        return base + attn
    # decode: one token against a seq-long context
    base = 2.0 * spec.n_active * batch
    attn = spec.attn_flops_per_ctx_token * seq * batch
    return base + attn
