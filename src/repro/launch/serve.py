"""Serving launcher.

Two modes:
  * ``--mode sim`` (default): cluster-scale discrete-event run with the
    analytical v5e executor — the configuration used for the paper-figure
    benchmarks; scales to hundreds of workers.
  * ``--mode real``: drives the same ``ClusterScheduler`` against REAL JAX
    model execution on this host (reduced config) through the
    ``RealJaxBackend``, proving the scheduler is executor-agnostic end to
    end.

``--json`` prints one stable, versioned metrics object on stdout
(``schema_version`` bumps on breaking changes; keys are sorted) so scripts
can parse runs without scraping the human-readable table. ``--seed``
drives trace synthesis AND real-executor weight init, making whole runs
reproducible.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch internlm-20b \
      --policy tropical --rate 2.0 --duration 120
  PYTHONPATH=src python -m repro.launch.serve --mode real --policy tropical \
      --rate 2.0 --duration 20 --workers 2
"""
from __future__ import annotations

import argparse
import json
from typing import Optional, Sequence

METRICS_SCHEMA_VERSION = 1


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm-20b")
    ap.add_argument("--policy", default="tropical",
                    choices=["vllm", "sarathi", "distserve", "tropical",
                             "tropical++"])
    ap.add_argument("--mode", default="sim", choices=["sim", "real"])
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--tp", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0,
                    help="trace synthesis + real-executor init seed")
    ap.add_argument("--fail-worker", type=int, default=None,
                    help="inject a worker failure at duration/2")
    ap.add_argument("--ici-bw", type=float, default=None, metavar="GBPS",
                    help="per-link KV migration bandwidth in GB/s "
                         "(default: hardware spec, 50 GB/s on v5e)")
    ap.add_argument("--ici-links", type=int, default=None,
                    help="usable P2P links per worker (default 2)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV block granularity in tokens")
    ap.add_argument("--no-transfer-engine", action="store_true",
                    help="legacy fixed-delay migrations (no link contention)")
    ap.add_argument("--online-predictor", action="store_true",
                    help="EWMA-correct the §IV-C predictor from observed "
                         "iteration durations (wall-clock in --mode real)")
    ap.add_argument("--no-rebalance", action="store_true",
                    help="keep the legacy dispatch-count role review "
                         "instead of windowed-attainment rebalancing")
    ap.add_argument("--json", action="store_true")
    return ap


def main(argv: Optional[Sequence[str]] = None) -> dict:
    ap = build_parser()
    args = ap.parse_args(argv)
    if args.ici_bw is not None and args.ici_bw <= 0:
        ap.error("--ici-bw must be > 0 (migrated KV can never arrive "
                 "over a zero-bandwidth link)")
    if args.ici_links is not None and args.ici_links <= 0:
        ap.error("--ici-links must be > 0 (zero links stall every "
                 "migration forever)")
    if args.page_size <= 0:
        ap.error("--page-size must be a positive token count")

    from repro.configs import get_config, get_smoke
    from repro.serving.costmodel import WorkerSpec
    from repro.serving.simulator import build_cluster
    from repro.serving.trace import generate_trace

    if args.mode == "real":
        cfg = get_smoke(args.arch)
        spec = WorkerSpec(tp=1)
    else:
        cfg = get_config(args.arch)
        spec = WorkerSpec(tp=args.tp)

    sim, cost = build_cluster(
        cfg, args.policy, n_workers=args.workers, worker_spec=spec,
        use_transfer_engine=not args.no_transfer_engine,
        ici_bw=args.ici_bw * 1e9 if args.ici_bw is not None else None,
        ici_links=args.ici_links, page_size=args.page_size,
        online_predictor=args.online_predictor,
        role_rebalance=False if args.no_rebalance else "auto")
    trace = generate_trace(args.rate, args.duration, cost, seed=args.seed)
    if args.mode == "real":
        import jax
        from repro.serving.executor import ClusterRealExecutors
        for r in trace:   # shrink to smoke scale
            r.prompt_len = min(r.prompt_len, 48)
            r.output_len = min(r.output_len, 16)
        execs = ClusterRealExecutors(cfg, args.workers, max_slots=8,
                                     max_len=128,
                                     rng=jax.random.PRNGKey(args.seed))
        sim.sched.backend = execs.as_backend(clock="wall")
    sim.add_trace(trace)
    if args.fail_worker is not None:
        sim.inject_failure(args.duration / 2, args.fail_worker,
                           recover_after=args.duration / 4)
    m = sim.run(until=args.duration * 10)

    row = m.row()
    row.update(policy=args.policy, arch=cfg.name, mode=args.mode,
               rate=args.rate, workers=args.workers, seed=args.seed,
               schema_version=METRICS_SCHEMA_VERSION)
    if sim.transfer is not None:
        row.update(kv_bytes_migrated=sim.transfer.bytes_moved,
                   transfer_seconds=sim.transfer.total_transfer_seconds)
    pred = sim.policy.predictor
    if hasattr(pred, "prefill_scale"):
        row.update(predictor_prefill_scale=round(pred.prefill_scale, 4),
                   predictor_decode_scale=round(pred.decode_scale, 4))
    if sim.sched.rebalancer is not None:
        row.update(role_transitions=len(sim.sched.rebalancer.transitions))
    if args.json:
        print(json.dumps(row, indent=1, sort_keys=True, default=float))
    else:
        for k, v in row.items():
            print(f"{k:>22}: {v}")
    return row


if __name__ == "__main__":
    main()
