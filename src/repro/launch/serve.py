"""Serving launcher.

Two modes:
  * ``--mode sim`` (default): cluster-scale discrete-event run with the
    analytical v5e executor — the configuration used for the paper-figure
    benchmarks; scales to hundreds of workers.
  * ``--mode real``: drives the same ``ClusterScheduler`` against REAL JAX
    model execution on this host (reduced config) through the
    ``RealJaxBackend``, proving the scheduler is executor-agnostic end to
    end.

``--json`` prints one stable, versioned metrics object on stdout
(``schema_version`` bumps on breaking changes; keys are sorted) so scripts
can parse runs without scraping the human-readable table. ``--seed``
drives trace synthesis AND real-executor weight init, making whole runs
reproducible.

Multi-tenant runs: ``--scenario`` picks a named workload from
``repro.workload`` (bursty / diurnal / longctx / agentic / mixture / …)
and ``--slo-classes`` defines explicit SLO tiers, e.g.::

    --slo-classes "interactive:ttft=1.0,tpot=0.05,weight=2,frac=0.6;\
batch:ttft=10,tpot=0.5,frac=0.4"

(``ttft``/``tpot`` in seconds, or ``scale=K`` for K x the light-load
latency per §V-A; ``frac`` splits the arrival rate, default equal;
``weight`` enters the weighted attainment). The JSON object then carries a
``per_class`` block and ``weighted_attainment``.

``schema_version`` history: 2 added the per_class block +
weighted_attainment (breaking the v1 aggregate-only layout); 3 added the
tiered-KV / prefix-reuse counters (kv_offloads, kv_restores,
pages_offloaded, pages_restored, pages_reprefilled, prefix_lookups,
prefix_hits, prefix_hit_rate — all zero unless ``--host-kv-gb`` /
``--prefix-cache`` arm the features). v3 is additive over v2: every v2
key keeps its meaning.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch internlm-20b \
      --policy tropical --rate 2.0 --duration 120
  PYTHONPATH=src python -m repro.launch.serve --mode real --policy tropical \
      --rate 2.0 --duration 20 --workers 2
  PYTHONPATH=src python -m repro.launch.serve --scenario mixture --json
"""
from __future__ import annotations

import argparse
import json
from typing import Optional, Sequence

METRICS_SCHEMA_VERSION = 3     # v3: tiered-KV + prefix-reuse counters


def parse_slo_classes(spec: str) -> list[dict]:
    """Parse ``name:key=val,...;name:key=val,...`` into class descriptors.

    Keys: ``ttft``/``tpot`` (seconds), ``scale`` (K x the light-load phase
    latency, resolved against the cost model later), ``weight`` (default
    1), ``frac`` (rate share, default equal split). Raises ValueError with
    the offending fragment on malformed input."""
    classes = []
    for part in filter(None, (p.strip() for p in spec.split(";"))):
        name, _, body = part.partition(":")
        name = name.strip()
        if not name or not body.strip():
            raise ValueError(f"malformed class spec {part!r} "
                             "(want name:key=val,...)")
        cls = {"name": name, "weight": 1.0, "frac": None,
               "ttft": None, "tpot": None, "scale": None}
        for kv in filter(None, (s.strip() for s in body.split(","))):
            key, eq, val = kv.partition("=")
            key = key.strip()
            if not eq or key not in ("ttft", "tpot", "scale", "weight",
                                     "frac"):
                raise ValueError(f"unknown key in class {name!r}: {kv!r}")
            try:
                cls[key] = float(val)
            except ValueError:
                raise ValueError(
                    f"class {name!r}: {key} must be a number, "
                    f"got {val!r}") from None
        has_any_abs = cls["ttft"] is not None or cls["tpot"] is not None
        has_abs = cls["ttft"] is not None and cls["tpot"] is not None
        if cls["scale"] is not None and has_any_abs:
            raise ValueError(
                f"class {name!r}: give ttft=+tpot= (seconds) OR scale=, "
                "not both")
        if not has_abs and cls["scale"] is None:
            raise ValueError(
                f"class {name!r} needs ttft=+tpot= (seconds) or scale=")
        for key in ("ttft", "tpot", "scale", "weight"):
            if cls[key] is not None and cls[key] <= 0:
                raise ValueError(f"class {name!r}: {key} must be > 0")
        if cls["frac"] is not None and not 0.0 < cls["frac"] <= 1.0:
            raise ValueError(f"class {name!r}: frac must be in (0, 1]")
        classes.append(cls)
    if not classes:
        raise ValueError("empty --slo-classes spec")
    names = [c["name"] for c in classes]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate class names in spec: {names}")
    assigned = sum(c["frac"] for c in classes if c["frac"] is not None)
    if assigned > 1.0 + 1e-9:
        raise ValueError(
            f"class rate fracs sum to {assigned:g} > 1 (they split --rate)")
    unassigned = [c for c in classes if c["frac"] is None]
    if unassigned:
        left = 1.0 - assigned
        if left <= 1e-9:
            raise ValueError(
                "explicit fracs consume the whole rate but "
                + ", ".join(c["name"] for c in unassigned)
                + " carries no frac= — it would get zero traffic")
        for c in unassigned:
            c["frac"] = left / len(unassigned)
    return classes


def _classes_scenario(classes: list[dict], cost) -> "object":
    """Build a mixture Scenario from parsed --slo-classes descriptors:
    every class shares the mooncake-like profile and arrival process, but
    carries its own SLO tier and rate share."""
    from repro.core.request import SLOClass
    from repro.workload import (GammaPoisson, MOONCAKE, Scenario,
                                ScenarioComponent)
    comps = []
    for c in classes:
        if c["ttft"] is not None and c["tpot"] is not None:
            ttft, tpot = c["ttft"], c["tpot"]
        else:
            k = c["scale"]
            ttft = k * cost.prefill_time(int(MOONCAKE.body_median * 4))
            tpot = k * cost.decode_iter_time(
                1, float(MOONCAKE.body_median * 4))
        slo = SLOClass(ttft=ttft, tpot=tpot, name=c["name"],
                       weight=c["weight"])
        comps.append(ScenarioComponent(
            name=c["name"], profile=MOONCAKE, arrivals=GammaPoisson(),
            rate_frac=c["frac"], slo=slo, weight=c["weight"]))
    return Scenario("slo-classes", tuple(comps))


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm-20b")
    ap.add_argument("--policy", default="tropical",
                    choices=["vllm", "sarathi", "distserve", "tropical",
                             "tropical++"])
    ap.add_argument("--mode", default="sim", choices=["sim", "real"])
    ap.add_argument("--backend", default="cost-model",
                    choices=["cost-model", "trace-replay"],
                    help="sim-mode execution backend: 'cost-model' "
                         "materialises the trace up front; 'trace-replay' "
                         "streams arrivals lazily through a "
                         "TraceReplayBackend (constant-memory replay of "
                         "recorded/synthesised traces; identical "
                         "decisions)")
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--tp", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0,
                    help="trace synthesis + real-executor init seed")
    ap.add_argument("--scenario", default="mooncake",
                    help="named workload scenario (repro.workload.SCENARIOS"
                         "; 'mooncake' keeps the legacy §V-A trace)")
    ap.add_argument("--slo-classes", default=None, metavar="SPEC",
                    help="multi-tenant SLO tiers: 'name:ttft=S,tpot=S,"
                         "weight=W,frac=F;...' (or scale=K per §V-A); "
                         "defines its own mixture workload (mutually "
                         "exclusive with a non-default --scenario) or "
                         "maps a --trace-csv slo_class column")
    ap.add_argument("--trace-csv", default=None, metavar="PATH",
                    help="replay a recorded Mooncake-schema CSV instead of "
                         "synthesising (--rate/--duration/--scenario are "
                         "ignored for arrivals)")
    ap.add_argument("--fail-worker", type=int, default=None,
                    help="inject a worker failure at duration/2")
    ap.add_argument("--ici-bw", type=float, default=None, metavar="GBPS",
                    help="per-link KV migration bandwidth in GB/s "
                         "(default: hardware spec, 50 GB/s on v5e)")
    ap.add_argument("--ici-links", type=int, default=None,
                    help="usable P2P links per worker (default 2)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV block granularity in tokens")
    ap.add_argument("--no-transfer-engine", action="store_true",
                    help="legacy fixed-delay migrations (no link contention)")
    ap.add_argument("--host-kv-gb", type=float, default=0.0, metavar="GB",
                    help="per-worker host-DRAM KV tier: watermark victims "
                         "offload over the host DMA link instead of evict + "
                         "full re-prefill when the predictor prices restore "
                         "cheaper (default 0 = seed behaviour)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="per-worker cross-request prefix cache: requests "
                         "sharing a workload-tagged system prompt skip the "
                         "cached span of prefill")
    ap.add_argument("--online-predictor", action="store_true",
                    help="EWMA-correct the §IV-C predictor from observed "
                         "iteration durations (wall-clock in --mode real)")
    ap.add_argument("--recalibrate-every", type=int, default=None,
                    metavar="N",
                    help="online drift recalibration: every N observed "
                         "iterations re-fit the per-bucket interference "
                         "gamma and nudge the measured MFU/bandwidth "
                         "constants from residuals (default: off = "
                         "calibrate once at startup)")
    ap.add_argument("--no-rebalance", action="store_true",
                    help="keep the legacy dispatch-count role review "
                         "instead of windowed-attainment rebalancing")
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--reference", action="store_true",
                    help="run the scalar reference scheduler/engine "
                         "instead of the vectorized fast paths (decisions "
                         "and metrics are bit-identical; this is the "
                         "parity baseline, ~2-10x slower)")
    ap.add_argument("--profile", action="store_true",
                    help="run the simulation under cProfile; print the "
                         "top-25 cumulative-time entries to stderr")
    return ap


def main(argv: Optional[Sequence[str]] = None) -> dict:
    ap = build_parser()
    args = ap.parse_args(argv)
    if args.ici_bw is not None and args.ici_bw <= 0:
        ap.error("--ici-bw must be > 0 (migrated KV can never arrive "
                 "over a zero-bandwidth link)")
    if args.ici_links is not None and args.ici_links <= 0:
        ap.error("--ici-links must be > 0 (zero links stall every "
                 "migration forever)")
    if args.page_size <= 0:
        ap.error("--page-size must be a positive token count")
    if args.recalibrate_every is not None and args.recalibrate_every < 1:
        ap.error("--recalibrate-every must be >= 1 iteration "
                 "(omit the flag to disable online recalibration)")
    if args.host_kv_gb < 0:
        ap.error("--host-kv-gb must be >= 0 (0 disables the host tier)")

    from repro.configs import get_config, get_smoke
    from repro.serving.costmodel import WorkerSpec
    from repro.serving.simulator import build_cluster
    from repro.workload import SCENARIOS, generate_trace, get_scenario, \
        load_csv, replay_csv

    if args.backend == "trace-replay" and args.mode == "real":
        ap.error("--backend trace-replay streams the simulated clock; "
                 "--mode real owns its own backend (drop one of the flags)")

    if args.scenario not in SCENARIOS:
        ap.error(f"--scenario must be one of {sorted(SCENARIOS)}")
    classes = None
    if args.slo_classes is not None:
        try:
            classes = parse_slo_classes(args.slo_classes)
        except ValueError as e:
            ap.error(f"--slo-classes: {e}")
        if args.scenario != "mooncake" and not args.trace_csv:
            # --slo-classes builds its own mixture workload (one mooncake
            # component per class); silently discarding the named
            # scenario's profiles would measure a different workload than
            # requested
            ap.error("--slo-classes defines its own workload and cannot "
                     "be combined with --scenario (use --trace-csv to "
                     "replay recorded traffic under these tiers, or drop "
                     "one of the flags)")

    if args.mode == "real":
        cfg = get_smoke(args.arch)
        spec = WorkerSpec(tp=1)
    else:
        cfg = get_config(args.arch)
        spec = WorkerSpec(tp=args.tp)

    sim, cost = build_cluster(
        cfg, args.policy, n_workers=args.workers, worker_spec=spec,
        use_transfer_engine=not args.no_transfer_engine,
        ici_bw=args.ici_bw * 1e9 if args.ici_bw is not None else None,
        ici_links=args.ici_links, page_size=args.page_size,
        online_predictor=args.online_predictor,
        recalibrate_every=args.recalibrate_every,
        role_rebalance=False if args.no_rebalance else "auto",
        host_kv_gb=args.host_kv_gb, prefix_cache=args.prefix_cache,
        vectorized=not args.reference)
    # one workload-source selection for both feeds: each leaf names the
    # (materialised, streaming) pair so --backend trace-replay can never
    # diverge from the default path on *which* workload runs
    streaming = args.backend == "trace-replay"
    if classes is not None:
        scenario = _classes_scenario(classes, cost)
        if args.trace_csv:
            feed = replay_csv(args.trace_csv, cost,
                              classes=scenario.classes) if streaming \
                else load_csv(args.trace_csv, cost, classes=scenario.classes)
        else:
            feed = (scenario.replay if streaming else scenario.generate)(
                args.rate, args.duration, cost, seed=args.seed)
    elif args.trace_csv:
        feed = replay_csv(args.trace_csv, cost) if streaming \
            else load_csv(args.trace_csv, cost)
    elif args.scenario != "mooncake":
        scenario = get_scenario(args.scenario)
        feed = (scenario.replay if streaming else scenario.generate)(
            args.rate, args.duration, cost, seed=args.seed)
    else:
        # legacy single-class path: RNG-stream identical to pre-workload
        # releases, so seeded runs reproduce bit-exactly
        trace = generate_trace(args.rate, args.duration, cost,
                               seed=args.seed)
        feed = ((r.arrival_time, r) for r in trace) if streaming else trace

    if streaming:
        sim.add_replay(feed)
    else:
        if args.mode == "real":
            import jax
            from repro.serving.executor import ClusterRealExecutors
            for r in feed:   # shrink to smoke scale
                r.prompt_len = min(r.prompt_len, 48)
                r.output_len = min(r.output_len, 16)
            execs = ClusterRealExecutors(cfg, args.workers, max_slots=8,
                                         max_len=128,
                                         rng=jax.random.PRNGKey(args.seed))
            sim.sched.backend = execs.as_backend(clock="wall")
        sim.add_trace(feed)
    if args.fail_worker is not None:
        sim.inject_failure(args.duration / 2, args.fail_worker,
                           recover_after=args.duration / 4)
    if args.profile:
        import cProfile
        import pstats
        import sys as _sys
        pr = cProfile.Profile()
        pr.enable()
        try:
            m = sim.run(until=args.duration * 10)
        finally:
            pr.disable()
            stats = pstats.Stats(pr, stream=_sys.stderr)
            stats.sort_stats("cumulative")
            print("# --profile: top 25 by cumulative time",
                  file=_sys.stderr)
            stats.print_stats(25)
    else:
        m = sim.run(until=args.duration * 10)

    # label the workload that actually ran: CSV replay and --slo-classes
    # both bypass the named generator, and the JSON is the machine-read
    # contract downstream consumers group runs by
    if args.trace_csv:
        scenario_label = "trace-csv"
    elif classes is not None:
        scenario_label = "slo-classes"
    else:
        scenario_label = args.scenario
    row = m.row()
    row.update(policy=args.policy, arch=cfg.name, mode=args.mode,
               backend=args.backend if args.mode == "sim" else "real-jax",
               rate=args.rate, workers=args.workers, seed=args.seed,
               scenario=scenario_label,
               schema_version=METRICS_SCHEMA_VERSION,
               per_class=m.per_class_rows())
    if sim.transfer is not None:
        row.update(kv_bytes_migrated=sim.transfer.bytes_moved,
                   transfer_seconds=sim.transfer.total_transfer_seconds)
    pred = sim.policy.predictor
    if hasattr(pred, "prefill_scale"):
        row.update(predictor_prefill_scale=round(pred.prefill_scale, 4),
                   predictor_decode_scale=round(pred.decode_scale, 4))
    if sim.sched.rebalancer is not None:
        row.update(role_transitions=len(sim.sched.rebalancer.transitions))
    if sim.sched.drift_monitor is not None:
        lo, hi = sim.sched.drift_monitor.gamma_range()
        row.update(recalibrate_every=sim.sched.drift_monitor.every,
                   recalibrations=sim.sched.drift_monitor.recalibrations,
                   drift_gamma_min=round(lo, 4), drift_gamma_max=round(hi, 4))
    if args.json:
        print(json.dumps(row, indent=1, sort_keys=True, default=float))
    else:
        for k, v in row.items():
            if k == "per_class":
                continue
            print(f"{k:>22}: {v}")
        for name, cm in row["per_class"].items():
            cols = " ".join(f"{ck}={cv:.4g}" if isinstance(cv, float)
                            else f"{ck}={cv}" for ck, cv in cm.items())
            print(f"{'class ' + name:>22}: {cols}")
    return row


if __name__ == "__main__":
    main()
