"""Production mesh construction.

A *function*, not a module-level constant — importing this module never
touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain placeholder devices.
"""
from __future__ import annotations

from repro.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(
        shape, axes,
        axis_types=(AxisType.Auto,) * len(axes),
    )


def make_worker_mesh(tp: int = 4):
    """Mesh for one serving worker (TP-only sub-slice)."""
    return make_mesh((1, tp), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)


def make_host_mesh():
    """Single-device mesh for CPU tests/examples."""
    return make_mesh((1, 1), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
