"""Training launcher: smoke-scale end-to-end training on this host with the
full production substrate (AdamW+ZeRO specs, synthetic pipeline, atomic
checkpoints, restart-resume).

  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b \
      --steps 200 --batch 8 --seq 64 --ckpt /tmp/ckpt

Restarting the same command resumes from the latest checkpoint (the
fault-tolerance loop exercised by tests/test_train_e2e.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    from repro.configs import get_smoke
    from repro.models import api as model_api
    from repro.train import checkpoint, optimizer
    from repro.train.data import DataConfig, SyntheticLM

    cfg = get_smoke(args.arch)
    api = model_api.build(cfg)
    data = SyntheticLM(cfg, DataConfig(batch=args.batch, seq=args.seq))
    opt_cfg = optimizer.AdamWConfig(lr=args.lr, warmup_steps=20)
    step_fn = jax.jit(optimizer.make_train_step(
        lambda p, b: api.loss(p, b), opt_cfg))

    start = 0
    params = api.init(jax.random.PRNGKey(0))
    state = optimizer.init_state(params)
    if args.ckpt:
        latest = checkpoint.latest_step(args.ckpt)
        if latest is not None:
            tree = checkpoint.restore(args.ckpt, latest,
                                      {"params": params, "state": state})
            params, state = tree["params"], tree["state"]
            start = latest
            print(f"resumed from step {latest}")

    t0 = time.perf_counter()
    for step in range(start, args.steps):
        batch = data.batch_at(step)
        params, state, loss = step_fn(params, state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.perf_counter() - t0
            print(f"step {step:5d} loss {float(loss):.4f} "
                  f"({dt / max(step - start + 1, 1):.3f}s/step)")
        if args.ckpt and (step + 1) % args.ckpt_every == 0:
            checkpoint.save(args.ckpt, step + 1,
                            {"params": params, "state": state})
    if args.ckpt:
        checkpoint.save(args.ckpt, args.steps,
                        {"params": params, "state": state})
    print(f"final loss {float(loss):.4f}")


if __name__ == "__main__":
    main()
