import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above must run before ANY other import — jax locks the
# device count on first init (see MULTI-POD DRY-RUN requirements).

DOC = """Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and record memory / cost / collective analysis.

This proves the distribution config is coherent without hardware: sharding
mismatches, OOM-scale buffers and unsupported collectives all surface as
compile failures here.

Usage:
  python -m repro.launch.dryrun --arch deepseek-7b --shape decode_32k --mesh single
  python -m repro.launch.dryrun --all --mesh both        # full sweep
Results: experiments/dryrun/<arch>__<shape>__<mesh>.json
"""

import argparse
import dataclasses
import gc
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs import get_config, list_archs
from repro.launch import hloanalysis
from repro.launch.mesh import make_production_mesh
from repro.models import api as model_api
from repro.models import sharding
from repro.models.moe import EPInfo
from repro.train import optimizer

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# long_500k needs sub-quadratic context handling (DESIGN.md §5)
LONG_OK = {"rwkv6-7b", "zamba2-2.7b", "gemma2-2b",
           "rwkv6_7b", "zamba2_2_7b", "gemma2_2b"}
ASSIGNED = [a for a in list_archs() if a != "internlm20b"]

WHISPER_DEC_TRAIN = 512     # decoder tokens for encdec train cells
WHISPER_DEC_PREFILL = 64
WHISPER_ENC_DECODE = 1504   # encoder frames backing a decode-cell cross-KV


def _dryrun_cfg(cfg, kind: str):
    """Dry-run variant: layers are UNROLLED so per-layer GEMMs and
    collectives are exact in XLA's static cost model; long-sequence
    attention keeps its Q-chunk *scan* (unrolling 26-61 layers x 32 chunks
    is compile-time prohibitive) and the under-count is repaired by
    hloanalysis.scan_correction ((trip-1) x body dot cost, parsed from the
    compiled HLO). MoE expert tables are padded to divide the 512-chip EP
    group (padded experts get -inf router logits)."""
    return dataclasses.replace(
        cfg, scan_layers=True, attn_unroll_chunks=False, attn_q_chunk=1024,
        expert_pad_to=512 if cfg.is_moe else 0)


def input_specs(cfg, shape_name: str, rules):
    """ShapeDtypeStruct stand-ins + PartitionSpecs for every model input."""
    info = SHAPES[shape_name]
    kind, seq, batch = info["kind"], info["seq"], info["batch"]
    b = sharding.batch_spec(rules, batch)
    i32, bf16 = jnp.int32, cfg.dtype
    sds = jax.ShapeDtypeStruct

    if kind == "train":
        if cfg.family == "encdec":
            d = WHISPER_DEC_TRAIN
            batch_tree = {
                "frames": sds((batch, seq, cfg.d_model), bf16),
                "tokens": sds((batch, d), i32),
                "labels": sds((batch, d), i32),
            }
            spec_tree = {
                "frames": P(b, None, None),
                "tokens": P(b, None), "labels": P(b, None),
            }
        elif cfg.family == "vlm":
            txt = seq - cfg.num_patches
            batch_tree = {
                "tokens": sds((batch, txt), i32),
                "labels": sds((batch, txt), i32),
                "prefix_embeds": sds((batch, cfg.num_patches,
                                      cfg.vision_feature_dim), bf16),
            }
            spec_tree = {
                "tokens": P(b, None), "labels": P(b, None),
                "prefix_embeds": P(b, None, None),
            }
        else:
            batch_tree = {
                "tokens": sds((batch, seq), i32),
                "labels": sds((batch, seq), i32),
            }
            spec_tree = {"tokens": P(b, None), "labels": P(b, None)}
        return batch_tree, spec_tree

    lengths = sds((batch,), i32)
    lspec = P(b)
    if kind == "prefill":
        if cfg.family == "encdec":
            ins = {
                "frames": sds((batch, seq, cfg.d_model), bf16),
                "tokens": sds((batch, WHISPER_DEC_PREFILL), i32),
                "lengths": lengths,
            }
            specs = {"frames": P(b, None, None), "tokens": P(b, None),
                     "lengths": lspec}
        elif cfg.family == "vlm":
            ins = {
                "tokens": sds((batch, seq - cfg.num_patches), i32),
                "prefix_embeds": sds((batch, cfg.num_patches,
                                      cfg.vision_feature_dim), bf16),
                "lengths": lengths,
            }
            specs = {"tokens": P(b, None), "prefix_embeds": P(b, None, None),
                     "lengths": lspec}
        else:
            ins = {"tokens": sds((batch, seq), i32), "lengths": lengths}
            specs = {"tokens": P(b, None), "lengths": lspec}
        return ins, specs

    # decode
    ins = {"tokens": sds((batch,), i32), "lengths": lengths}
    specs = {"tokens": P(b), "lengths": lspec}
    return ins, specs


def _cache_for(cfg, api, shape_name: str, rules):
    info = SHAPES[shape_name]
    kind, seq, batch = info["kind"], info["seq"], info["batch"]
    if kind == "train":
        return None, None
    if cfg.family == "encdec":
        if kind == "prefill":
            tree = api.cache_spec(batch, 2 * WHISPER_DEC_PREFILL, enc_len=seq)
        else:
            tree = api.cache_spec(batch, seq, enc_len=WHISPER_ENC_DECODE)
    else:
        max_len = seq if kind == "decode" else seq
        tree = api.cache_spec(batch, max_len)
    specs = sharding.cache_specs(cfg, rules, batch, tree)
    return tree, specs


def _ep_for(cfg, mesh, rules):
    if not cfg.is_moe:
        return None
    return EPInfo(mesh=mesh, ep_axes=tuple(mesh.axis_names),
                  batch_axes=rules.batch_axes,
                  capacity_factor=cfg.moe_capacity_factor)


def build_step(cfg, api, kind: str, mesh, rules):
    ep = _ep_for(cfg, mesh, rules)
    if kind == "train":
        loss_fn = lambda p, b: api.loss(p, b, ep=ep)
        return optimizer.make_train_step(loss_fn)
    if kind == "prefill":
        if cfg.family == "encdec":
            def step(params, cache, frames, tokens, lengths):
                return api.prefill(params, cache,
                                   {"frames": frames, "tokens": tokens},
                                   lengths, ep=ep)
        elif cfg.family == "vlm":
            def step(params, cache, tokens, prefix_embeds, lengths):
                return api.prefill(params, cache,
                                   {"tokens": tokens,
                                    "prefix_embeds": prefix_embeds},
                                   lengths, ep=ep)
        else:
            def step(params, cache, tokens, lengths):
                return api.prefill(params, cache, tokens, lengths, ep=ep)
        return step

    def step(params, cache, tokens, lengths):
        return api.decode(params, cache, tokens, lengths, ep=ep)
    return step


def run_cell(arch: str, shape_name: str, mesh_kind: str, outdir: Path,
             force: bool = False) -> dict:
    out_path = outdir / f"{arch}__{shape_name}__{mesh_kind}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "status": "error"}
    if shape_name == "long_500k" and arch not in LONG_OK:
        rec["status"] = "skipped"
        rec["reason"] = ("pure full-attention arch: 512k KV on every layer; "
                         "sub-quadratic archs only (DESIGN.md §5)")
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    info = SHAPES[shape_name]
    kind, seq, batch = info["kind"], info["seq"], info["batch"]
    cfg = _dryrun_cfg(get_config(arch), kind)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    compat.set_mesh(mesh)
    n_dev = mesh.devices.size
    rules = sharding.make_rules(mesh)
    api = model_api.build(cfg)

    try:
        t0 = time.time()
        params_shape = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
        pspecs = sharding.param_specs(cfg, params_shape, rules)
        ins, ispecs = input_specs(cfg, shape_name, rules)
        step = build_step(cfg, api, kind, mesh, rules)

        if kind == "train":
            opt_shape = jax.eval_shape(optimizer.init_state, params_shape)
            ospecs = optimizer.state_specs(
                pspecs, params_shape, zero_size=int(mesh.shape["data"]))
            jitted = jax.jit(
                step,
                in_shardings=(pspecs, ospecs, ispecs),
                out_shardings=(pspecs, ospecs, P()),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_shape, opt_shape, ins)
        else:
            cache_shape, cspecs = _cache_for(cfg, api, shape_name, rules)
            arg_order = list(ins.keys())
            in_sh = (pspecs, cspecs) + tuple(ispecs[k] for k in arg_order)
            logits_spec = P(sharding.batch_spec(rules, batch), None)
            jitted = jax.jit(
                step,
                in_shardings=in_sh,
                out_shardings=(logits_spec, cspecs),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_shape, cache_shape,
                                   *[ins[k] for k in arg_order])
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()

        mem = compiled.memory_analysis()
        cost = dict(compiled.cost_analysis())
        hlo = compiled.as_text()
        mc = hloanalysis.module_cost(hlo)
        coll = mc["collectives"]
        dot = {"flops": mc["flops"], "bytes": mc["bytes"],
               "loops": [None] * mc["n_multiplied_blocks"]}
        resident = float((getattr(mem, "argument_size_in_bytes", 0) or 0)
                         + (getattr(mem, "output_size_in_bytes", 0) or 0)
                         - (getattr(mem, "alias_size_in_bytes", 0) or 0))
        dec_len = WHISPER_DEC_TRAIN if kind == "train" else WHISPER_DEC_PREFILL
        mflops = hloanalysis.model_flops(cfg, kind, batch, seq, dec_len)
        rl = hloanalysis.roofline(dot, resident, coll, mflops, n_dev)

        rec.update(
            status="ok", lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2), n_devices=n_dev,
            memory={
                k: getattr(mem, k, None) for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "alias_size_in_bytes",
                    "generated_code_size_in_bytes")
            },
            cost={"xla_cpu_flops_raw": cost.get("flops", 0.0),
                  "xla_cpu_bytes_raw": cost.get("bytes accessed", 0.0),
                  "dot_flops": dot["flops"], "dot_bytes": dot["bytes"],
                  "n_corrected_loops": len(dot["loops"])},
            collectives={k: v for k, v in coll.items() if k != "counts"},
            collective_counts=coll["counts"],
            model_flops_total=mflops,
            roofline=rl.row(),
        )
        # memory_analysis() reports PER-DEVICE sizes (verified: the donated
        # cache slice == alias bytes). Caveat (EXPERIMENTS.md §Methodology):
        # XLA-CPU upcasts every bf16 dot operand to f32, so temp bytes
        # include converts that do not exist on TPU (native bf16 MXU) —
        # steady-state (args+out-alias: weights, caches, optimizer) is the
        # capacity-critical number and is exact.
        args = rec["memory"]["argument_size_in_bytes"] or 0
        temps = rec["memory"]["temp_size_in_bytes"] or 0
        outs = rec["memory"]["output_size_in_bytes"] or 0
        alias = rec["memory"]["alias_size_in_bytes"] or 0
        rec["bytes_per_device"] = float(args + outs - alias)
        rec["bytes_per_device_incl_cpu_temps"] = float(
            args + temps + outs - alias)
        print(f"[dryrun] {arch} {shape_name} {mesh_kind}: OK "
              f"compile={rec['compile_s']}s "
              f"dotflops/dev={rec['cost']['dot_flops']:.3e} "
              f"coll={rec['collectives']['wire_total']:.3e}B "
              f"dom={rec['roofline']['dominant']} "
              f"rf={rec['roofline']['roofline_fraction']:.3f} "
              f"mem/dev={rec['bytes_per_device']/1e9:.2f}GB")
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {arch} {shape_name} {mesh_kind}: "
              f"FAIL {rec['error'][:200]}")
    out_path.write_text(json.dumps(rec, indent=1))
    del api
    gc.collect()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                rec = run_cell(arch, shape, mk, outdir, force=args.force)
                n_ok += rec["status"] == "ok"
                n_fail += rec["status"] == "error"
                n_skip += rec["status"] == "skipped"
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed, {n_skip} skipped")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
