"""Online calibration layer: per-(worker, phase, size-bucket) EWMA.

``OnlinePredictor`` wraps any base ``Predictor`` and closes the §IV-C
loop: the scheduler feeds every observed iteration duration back in, and
multiplicative EWMA correction factors pull a biased/stale offline
profile toward what the executor actually delivers (wall-clock on the
real backend, injected noise in robustness sims) while preserving the
base safety margin.

Correction hierarchy (most to least specific, each level falling back to
the next until it has enough evidence):

    (worker, phase, size-bucket)   per_worker=True only
    (worker, phase)                per_worker=True only
    (phase, size-bucket)           bucketed=True (default)
    phase                          always

The per-worker levels close the ROADMAP straggler item: on a
heterogeneous cluster a single global scale per phase converges to a
traffic-weighted blend of the workers' biases — systematically
under-predicting the slow worker and over-predicting the fast ones. With
``per_worker=True`` each worker's scale converges to its own bias, so a
2x-slow straggler is priced at 2x and admission/dispatch route around it
(``benchmarks/fig_hetero.py`` measures the attainment this recovers).
``per_worker=False`` (default) is bit-identical to the pre-perf-package
global correction.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.perf.predictor import Predictor, _seq


class OnlinePredictor(Predictor):
    """Online feedback wrapper: multiplicative EWMA correction.

    Let ``raw`` be the base predictor's estimate (which already includes
    its conservative ``safety`` margin). After each observed iteration the
    matching scale moves toward ``observed * margin / raw`` — so an
    unbiased base converges to scale 1.0 (the safety margin is *kept*, not
    regressed away), and a k×-biased base converges to scale 1/k, restoring
    calibrated-but-conservative predictions. Mixed decode+prefill
    iterations split the observed time proportionally to the current
    corrected per-phase estimates.

    Heterogeneity has two axes. *Size*: real profiles miss differently at
    batch 1 than at batch 128 (kernel occupancy, attention-vs-MLP
    balance), so each observation feeds a per-(phase, size-bucket) EWMA —
    buckets are powers of two over prefill tokens / decode batch size —
    used once it has ``bucket_floor`` observations (cold buckets borrow
    the global per-phase scale instead of guessing from one sample);
    ``bucketed=False`` restores pure global correction. *Hardware*: with
    ``per_worker=True`` every observation additionally feeds
    per-(worker, phase, bucket) and per-(worker, phase) EWMAs keyed on the
    worker id the scheduler reports, consulted first when predicting for a
    specific ``wid`` — the heterogeneous-cluster mode."""

    def __init__(self, base: Predictor, alpha: float = 0.2,
                 clip: tuple[float, float] = (0.125, 8.0),
                 bucketed: bool = True, bucket_floor: int = 8,
                 per_worker: bool = False, worker_floor: int = 8):
        self.base = base
        self.alpha = alpha
        self.clip = clip
        self.bucketed = bucketed
        self.bucket_floor = bucket_floor
        self.per_worker = per_worker
        self.worker_floor = worker_floor
        # preserve the base's deliberate conservatism as the convergence
        # target; a margin-free base converges to exact calibration
        self.margin = float(getattr(base, "safety", 1.0))
        self.prefill_scale = 1.0
        self.decode_scale = 1.0
        self.prefill_observations = 0
        self.decode_observations = 0
        self.bucket_scales: dict[tuple[str, int], float] = {}
        self.bucket_observations: dict[tuple[str, int], int] = {}
        # per-worker levels (per_worker=True): (wid, phase[, bucket]) keys
        self.worker_scales: dict[tuple[int, str], float] = {}
        self.worker_observations: dict[tuple[int, str], int] = {}
        self.worker_bucket_scales: dict[tuple[int, str, int], float] = {}
        self.worker_bucket_observations: dict[tuple[int, str, int], int] = {}

    # ------------------------------------------------------------- buckets
    @staticmethod
    def _bucket(size: float) -> int:
        """Power-of-two size bucket: 1, 2, 3… for sizes 1, 2-3, 4-7, …"""
        return max(int(size), 1).bit_length()

    def _bucket_scale(self, phase: str, size: float,
                      global_scale: float) -> float:
        if not self.bucketed:
            return global_scale
        key = (phase, self._bucket(size))
        if self.bucket_observations.get(key, 0) < self.bucket_floor:
            return global_scale
        return self.bucket_scales[key]

    def _observe_bucket(self, phase: str, size: float, ratio: float,
                        global_scale: float) -> None:
        if not self.bucketed:
            return
        key = (phase, self._bucket(size))
        # seed a cold bucket from the converged global scale, not 1.0:
        # crossing bucket_floor must refine the prediction, never snap it
        # back toward the uncorrected base
        self.bucket_scales[key] = self._ewma(
            self.bucket_scales.get(key, global_scale), ratio)
        self.bucket_observations[key] = \
            self.bucket_observations.get(key, 0) + 1

    # --------------------------------------------------------- worker level
    def _scale_for(self, phase: str, size: float, global_scale: float,
                   wid: Optional[int]) -> float:
        """Most-specific trusted correction: (wid, phase, bucket) ->
        (wid, phase) -> (phase, bucket) -> phase."""
        if self.per_worker and wid is not None:
            wkey = (wid, phase, self._bucket(size))
            if self.worker_bucket_observations.get(wkey, 0) \
                    >= self.bucket_floor:
                return self.worker_bucket_scales[wkey]
            pkey = (wid, phase)
            if self.worker_observations.get(pkey, 0) >= self.worker_floor:
                return self.worker_scales[pkey]
        return self._bucket_scale(phase, size, global_scale)

    def _observe_worker(self, phase: str, size: float, ratio: float,
                        global_scale: float, wid: Optional[int]) -> None:
        if not self.per_worker or wid is None:
            return
        pkey = (wid, phase)
        # cold per-worker levels seed from the converged coarser scale so
        # crossing the floor refines rather than resets
        self.worker_scales[pkey] = self._ewma(
            self.worker_scales.get(pkey, global_scale), ratio)
        self.worker_observations[pkey] = \
            self.worker_observations.get(pkey, 0) + 1
        wkey = (wid, phase, self._bucket(size))
        self.worker_bucket_scales[wkey] = self._ewma(
            self.worker_bucket_scales.get(
                wkey, self.worker_scales[pkey]), ratio)
        self.worker_bucket_observations[wkey] = \
            self.worker_bucket_observations.get(wkey, 0) + 1

    # ----------------------------------------------------------- predictions
    def predict_prefill(self, tokens: int, ctx_offset: int = 0,
                        wid: Optional[int] = None) -> float:
        return self.base.predict_prefill(tokens, ctx_offset, wid=wid) \
            * self._scale_for("prefill", tokens, self.prefill_scale, wid)

    def predict_decode_iter(self, n_decode: int, sum_ctx: float,
                            wid: Optional[int] = None) -> float:
        return self.base.predict_decode_iter(n_decode, sum_ctx, wid=wid) \
            * self._scale_for("decode", n_decode, self.decode_scale, wid)

    def predict_migration(self, ctx_tokens: int,
                          wid: Optional[int] = None) -> float:
        return self.base.predict_migration(ctx_tokens, wid=wid)

    def predict_restore(self, ctx_tokens: int, residue_tokens: int = 0,
                        wid: Optional[int] = None) -> float:
        # wire-dominated like migration: no EWMA correction layer (yet)
        return self.base.predict_restore(ctx_tokens, residue_tokens,
                                         wid=wid)

    def predict_interference(self, n_decode: int, sum_ctx: float,
                             prefill_tokens: int, ctx_offset: float = 0.0,
                             wid: Optional[int] = None) -> float:
        # the penalty rides on the base model's γ (kept current by the
        # DriftMonitor, the component that owns γ's online re-fit); the
        # per-phase EWMA scales correct the *additive* estimates only
        return self.base.predict_interference(
            n_decode, sum_ctx, prefill_tokens, ctx_offset, wid=wid)

    # ------------------------------------------------- batched entry points
    # base batch estimate × gathered per-element EWMA scales: the scale
    # lookup hierarchy is dict-bound Python either way, so gathering it
    # into a vector is exactly the scalar sequence of lookups.
    def predict_prefill_batch(self, wids: Sequence[Optional[int]], tokens,
                              ctx_offset=0) -> np.ndarray:
        base = self.base.predict_prefill_batch(wids, tokens, ctx_offset)
        toks = _seq(tokens, len(wids))
        scales = np.array(
            [self._scale_for("prefill", t, self.prefill_scale, w)
             for w, t in zip(wids, toks)], dtype=np.float64)
        return base * scales

    def predict_decode_iter_batch(self, wids: Sequence[Optional[int]],
                                  n_decode, sum_ctx) -> np.ndarray:
        base = self.base.predict_decode_iter_batch(wids, n_decode, sum_ctx)
        nds = _seq(n_decode, len(wids))
        scales = np.array(
            [self._scale_for("decode", b, self.decode_scale, w)
             for w, b in zip(wids, nds)], dtype=np.float64)
        return base * scales

    def predict_interference_batch(self, wids: Sequence[Optional[int]],
                                   n_decode, sum_ctx, prefill_tokens,
                                   ctx_offset=0.0) -> np.ndarray:
        return self.base.predict_interference_batch(
            wids, n_decode, sum_ctx, prefill_tokens, ctx_offset)

    def chunk_candidates(self, wids: Sequence[Optional[int]], lo: int,
                         hi: int, budget, n_decode, sum_ctx, ctx_offset,
                         s_mul=None) -> Optional[np.ndarray]:
        """The EWMA scale on predict_prefill is piecewise constant over
        the power-of-two size buckets, so the closed-form chunk inversion
        stays exact: delegate to the base once per bucket segment of
        [lo, hi] with that segment's per-row scale folded in via
        ``s_mul``. Segment edges are structural breakpoints and each call
        includes its own endpoints, so flips at a bucket boundary are
        covered. (Candidate generation is pure arithmetic — the single
        batched cost evaluation still happens in the caller.)"""
        parts = []
        a = int(lo)
        while a <= int(hi):
            b = min(int(hi), (1 << max(a, 1).bit_length()) - 1)
            scales = np.array(
                [self._scale_for("prefill", a, self.prefill_scale, w)
                 for w in wids], dtype=np.float64)
            mul = scales if s_mul is None \
                else scales * np.asarray(s_mul, dtype=np.float64)
            cand = self.base.chunk_candidates(
                wids, a, b, budget, n_decode, sum_ctx, ctx_offset,
                s_mul=mul)
            if cand is None:
                return None
            parts.append(cand)
            a = b + 1
        return np.concatenate(parts, axis=1)

    # ------------------------------------------------------------- feedback
    def _ewma(self, scale: float, ratio: float) -> float:
        lo, hi = self.clip
        ratio = min(max(ratio, lo), hi)
        return (1.0 - self.alpha) * scale + self.alpha * ratio

    def observe_prefill(self, tokens: int, ctx_offset: int,
                        observed: float, wid: Optional[int] = None) -> None:
        if tokens <= 0:
            return
        raw = self.base.predict_prefill(tokens, ctx_offset, wid=wid)
        if raw > 0.0 and observed > 0.0:
            ratio = observed * self.margin / raw
            self._observe_worker("prefill", tokens, ratio,
                                 self.prefill_scale, wid)
            self._observe_bucket("prefill", tokens, ratio,
                                 self.prefill_scale)
            self.prefill_scale = self._ewma(self.prefill_scale, ratio)
            self.prefill_observations += 1

    def observe_decode(self, n_decode: int, sum_ctx: float,
                       observed: float, wid: Optional[int] = None) -> None:
        if n_decode <= 0:
            return
        raw = self.base.predict_decode_iter(n_decode, sum_ctx, wid=wid)
        if raw > 0.0 and observed > 0.0:
            ratio = observed * self.margin / raw
            self._observe_worker("decode", n_decode, ratio,
                                 self.decode_scale, wid)
            self._observe_bucket("decode", n_decode, ratio,
                                 self.decode_scale)
            self.decode_scale = self._ewma(self.decode_scale, ratio)
            self.decode_observations += 1

    def observe_iteration(self, n_decode: int, sum_ctx: float,
                          prefill_tokens: int, ctx_offset: float,
                          observed: float,
                          wid: Optional[int] = None) -> None:
        """ClusterScheduler hook: one finished iteration's composition and
        its observed duration (simulated or wall-clock), tagged with the
        worker that ran it so per-worker scales converge independently."""
        has_p = prefill_tokens > 0
        has_d = n_decode > 0
        if has_p and has_d:
            # the phase scales correct the ADDITIVE estimates only: strip
            # the model's own γ penalty from the observed mixed duration
            # before apportioning, or the penalty would be absorbed into
            # the scales AND re-added by predict_interference — pricing
            # the contention twice in admission (base penalty carries the
            # base's safety margin; divide it back out to get the model's
            # raw expectation, mirroring DriftMonitor's base0 handling)
            penalty = self.base.predict_interference(
                n_decode, sum_ctx, prefill_tokens, ctx_offset,
                wid=wid) / self.margin
            observed = max(observed - penalty, 0.0)
            cp = self.predict_prefill(prefill_tokens, int(ctx_offset),
                                      wid=wid)
            cd = self.predict_decode_iter(n_decode, sum_ctx, wid=wid)
            if cp + cd <= 0.0:
                return
            share = cp / (cp + cd)
            self.observe_prefill(prefill_tokens, int(ctx_offset),
                                 observed * share, wid=wid)
            self.observe_decode(n_decode, sum_ctx, observed * (1.0 - share),
                                wid=wid)
        elif has_p:
            self.observe_prefill(prefill_tokens, int(ctx_offset), observed,
                                 wid=wid)
        elif has_d:
            self.observe_decode(n_decode, sum_ctx, observed, wid=wid)
