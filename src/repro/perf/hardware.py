"""Hardware descriptions for the unified performance model.

``HardwareSpec`` is **per-worker**: a cluster may mix fast and slow
workers (different chip generations, degraded-MFU stragglers, thermally
throttled hosts), and every layer that prices work — dispatch, toggle
admission, decode routing, role rebalancing — must price it on the
*target* worker's hardware, not a global spec. ``WorkerSpec`` scales one
``HardwareSpec`` by the tensor-parallel degree of a model replica.

Constants follow the assignment hardware: TPU v5e, 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI per chip.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16 per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    hbm_bytes: float = 16e9           # per chip
    ici_bw: float = 50e9              # bytes/s per link
    ici_links: int = 2                # usable links for P2P KV migration
    mfu_prefill: float = 0.55         # achievable fraction of peak, big GEMMs
    mfu_decode: float = 0.6           # decode GEMMs are memory bound anyway
    bw_eff: float = 0.8
    t_fixed: float = 0.003            # per-iteration dispatch overhead (s)
    migration_latency: float = 0.001  # per-migration fixed cost (s)
    # §IV interference: decode tokens co-batched with prefill chunks pay a
    # contention penalty (the mixed iteration is NOT the sum of its parts —
    # it is worse). 0.0 = the legacy purely-additive roofline, which every
    # pre-existing benchmark reproduces bit-exactly; CalibratedRooflineBackend
    # or an explicit spec override turns it on.
    interference: float = 0.0

    def slowed(self, factor: float) -> "HardwareSpec":
        """A ``factor``x-slower variant of this spec (straggler modelling):
        compute and memory throughput both divide by ``factor``."""
        return dataclasses.replace(
            self, name=f"{self.name}-x{factor:g}slow",
            peak_flops=self.peak_flops / factor,
            hbm_bw=self.hbm_bw / factor)


V5E = HardwareSpec()


@dataclasses.dataclass(frozen=True)
class WorkerSpec:
    """One serving worker = ``tp`` chips running one model replica."""
    tp: int = 4
    hw: HardwareSpec = V5E

    @property
    def peak_flops(self) -> float:
        return self.tp * self.hw.peak_flops

    @property
    def hbm_bw(self) -> float:
        return self.tp * self.hw.hbm_bw

    @property
    def hbm_bytes(self) -> float:
        return self.tp * self.hw.hbm_bytes
