"""Hardware descriptions for the unified performance model.

``HardwareSpec`` is **per-worker**: a cluster may mix fast and slow
workers (different chip generations, degraded-MFU stragglers, thermally
throttled hosts), and every layer that prices work — dispatch, toggle
admission, decode routing, role rebalancing — must price it on the
*target* worker's hardware, not a global spec. ``WorkerSpec`` scales one
``HardwareSpec`` by the tensor-parallel degree of a model replica.

Constants follow the assignment hardware: TPU v5e, 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI per chip.
"""
from __future__ import annotations

import bisect
import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class InterferenceTable:
    """Bucketed §IV interference coefficient γ(n_decode, prefill_tokens).

    The super-additive mixed-batch slowdown is not one number: DistServe
    (arXiv:2401.09670) and prefill-decode multiplexing (arXiv:2504.14489)
    both measure it varying strongly with the decode batch size and the
    co-batched chunk length. ``decode_edges`` / ``chunk_edges`` are
    ascending bucket *lower bounds* (the first bucket also absorbs
    anything below it); ``gamma[i][j]`` applies to decode bucket ``i`` ×
    chunk bucket ``j`` and lookups are piecewise-constant within a cell.

    ``HardwareSpec.interference`` accepts a plain scalar (uniform γ, the
    legacy form — ``from_scalar`` is the degenerate 1×1 table and prices
    every mixed batch identically) or a table; ``gamma_at`` resolves
    both, so every consumer of the model is shape-agnostic."""
    decode_edges: tuple
    chunk_edges: tuple
    gamma: tuple                      # one row-tuple per decode bucket

    def __post_init__(self):
        # normalise to tuples so the (frozen) table stays hashable inside
        # HardwareSpec — build_cluster deduplicates specs via set()
        object.__setattr__(self, "decode_edges", tuple(self.decode_edges))
        object.__setattr__(self, "chunk_edges", tuple(self.chunk_edges))
        object.__setattr__(self, "gamma",
                           tuple(tuple(float(g) for g in row)
                                 for row in self.gamma))
        if not self.decode_edges or not self.chunk_edges:
            raise ValueError("InterferenceTable needs >= 1 bucket per axis")
        for edges in (self.decode_edges, self.chunk_edges):
            if list(edges) != sorted(edges) or len(set(edges)) != len(edges):
                raise ValueError(f"bucket edges must strictly ascend: {edges}")
        if len(self.gamma) != len(self.decode_edges) or any(
                len(row) != len(self.chunk_edges) for row in self.gamma):
            raise ValueError(
                f"gamma grid must be {len(self.decode_edges)}x"
                f"{len(self.chunk_edges)}, got "
                f"{[len(r) for r in self.gamma]}")
        for row in self.gamma:
            for g in row:
                # NaN fails both comparisons; negative γ would price mixed
                # iterations BELOW the additive roofline
                if not (math.isfinite(g) and g >= 0.0):
                    raise ValueError(f"gamma must be finite and >= 0, "
                                     f"got {g!r}")

    @classmethod
    def from_scalar(cls, gamma: float) -> "InterferenceTable":
        """The degenerate 1×1 table: one γ for every mixed batch —
        bit-equivalent to the legacy scalar ``HardwareSpec.interference``."""
        return cls(decode_edges=(0,), chunk_edges=(0,),
                   gamma=((float(gamma),),))

    @staticmethod
    def _cell(edges: tuple, x: float) -> int:
        return max(bisect.bisect_right(edges, x) - 1, 0)

    def lookup(self, n_decode: float, prefill_tokens: float) -> float:
        return self.gamma[self._cell(self.decode_edges, n_decode)][
            self._cell(self.chunk_edges, prefill_tokens)]

    @property
    def max_gamma(self) -> float:
        return max(max(row) for row in self.gamma)


def gamma_at(interference, n_decode: float, prefill_tokens: float) -> float:
    """Resolve a scalar-or-table ``HardwareSpec.interference`` to the γ
    governing one concrete mixed batch. A scalar (incl. the 0.0 default)
    is returned unchanged, so the legacy additive path stays bit-exact."""
    if isinstance(interference, InterferenceTable):
        return interference.lookup(n_decode, prefill_tokens)
    return float(interference)


def gamma_at_batch(interference, n_decode, prefill_tokens) -> np.ndarray:
    """Vectorized ``gamma_at``: resolve γ for many mixed batches at once.

    ``np.searchsorted(edges, x, side="right") - 1`` clipped at 0 is
    bit-identical to ``InterferenceTable._cell``'s
    ``bisect.bisect_right`` (bucket lower bounds and batch sizes are
    small integers, exactly representable in float64), so every element
    equals the scalar lookup."""
    n = np.asarray(n_decode, dtype=np.float64)
    p = np.asarray(prefill_tokens, dtype=np.float64)
    n, p = np.broadcast_arrays(n, p)
    if isinstance(interference, InterferenceTable):
        de = np.asarray(interference.decode_edges, dtype=np.float64)
        ce = np.asarray(interference.chunk_edges, dtype=np.float64)
        grid = np.asarray(interference.gamma, dtype=np.float64)
        i = np.maximum(np.searchsorted(de, n, side="right") - 1, 0)
        j = np.maximum(np.searchsorted(ce, p, side="right") - 1, 0)
        return grid[i, j]
    return np.full(n.shape, float(interference))


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16 per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    hbm_bytes: float = 16e9           # per chip
    ici_bw: float = 50e9              # bytes/s per link
    ici_links: int = 2                # usable links for P2P KV migration
    mfu_prefill: float = 0.55         # achievable fraction of peak, big GEMMs
    mfu_decode: float = 0.6           # decode GEMMs are memory bound anyway
    bw_eff: float = 0.8
    t_fixed: float = 0.003            # per-iteration dispatch overhead (s)
    migration_latency: float = 0.001  # per-migration fixed cost (s)
    # Host-DRAM tier link (tiered KV offload/restore): aggregate DMA
    # bandwidth between one worker's HBM and host memory, PCIe-class —
    # far below ICI, which is exactly why restore cost must be priced
    # before choosing offload over re-prefill.
    host_bw: float = 32e9             # bytes/s per worker, each direction
    host_latency: float = 0.0005      # per-offload/restore fixed cost (s)
    # §IV interference: decode tokens co-batched with prefill chunks pay a
    # contention penalty (the mixed iteration is NOT the sum of its parts —
    # it is worse). A scalar γ (0.0 = the legacy purely-additive roofline,
    # which every pre-existing benchmark reproduces bit-exactly) or an
    # ``InterferenceTable`` calibrated per (decode-batch, chunk-size)
    # bucket by ``repro.perf.calibrate.calibrate_interference`` and kept
    # current online by ``repro.perf.recalibrate.DriftMonitor``.
    interference: "float | InterferenceTable" = 0.0

    def slowed(self, factor: float) -> "HardwareSpec":
        """A ``factor``x-slower variant of this spec (straggler modelling):
        compute and memory throughput both divide by ``factor``."""
        return dataclasses.replace(
            self, name=f"{self.name}-x{factor:g}slow",
            peak_flops=self.peak_flops / factor,
            hbm_bw=self.hbm_bw / factor)


V5E = HardwareSpec()


@dataclasses.dataclass(frozen=True)
class WorkerSpec:
    """One serving worker = ``tp`` chips running one model replica."""
    tp: int = 4
    hw: HardwareSpec = V5E

    @property
    def peak_flops(self) -> float:
        return self.tp * self.hw.peak_flops

    @property
    def hbm_bw(self) -> float:
        return self.tp * self.hw.hbm_bw

    @property
    def hbm_bytes(self) -> float:
        return self.tp * self.hw.hbm_bytes
