"""Measured-MFU roofline: instantiate the analytic model from real kernels.

The analytic ``CostModel`` ships with assumed efficiency constants
(``mfu_prefill``/``mfu_decode``/``bw_eff``). A real deployment should not
trust them: achieved MFU depends on head dims, page sizes, XLA version
and the exact kernels in the serving path. ``calibrate_hardware`` runs
the repo's own Pallas kernels — ``kernels/chunked_prefill.py`` for the
prefill side, ``kernels/paged_attention.py`` for the decode side — once
at startup, times them, and returns a ``HardwareSpec`` whose efficiency
constants are *measurements*:

    mfu    = achieved_flops / (elapsed · peak_flops)
    bw_eff = achieved_bytes / (elapsed · hbm_bw)

``CalibratedRooflineBackend`` is the ``ExecutionBackend`` over the
resulting model: the ROADMAP's "batched roofline with measured MFU"
backend. Off-TPU (CPU CI, interpret-mode Pallas) the measured fractions
are tiny but still well-defined — they are clamped into ``(0, 1]`` and
the backend remains exercisable end-to-end; on a real TPU the same code
path yields deployment-grade constants.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

from repro.perf.hardware import HardwareSpec, V5E, WorkerSpec
from repro.perf.model import CostModel

_MFU_FLOOR = 1e-6        # interpret-mode measurements stay valid fractions


@dataclasses.dataclass(frozen=True)
class KernelCalibration:
    """What the calibration run measured (seconds + derived fractions)."""
    mfu_prefill: float
    mfu_decode: float
    bw_eff: float
    prefill_seconds: float
    decode_seconds: float
    prefill_flops: float
    decode_flops: float
    decode_bytes: float
    device: str


def _clamp_frac(x: float) -> float:
    return min(max(x, _MFU_FLOOR), 1.0)


def _time_fn(fn, repeats: int) -> float:
    """Median-of-``repeats`` wall time, after one warmup compile call."""
    import jax
    jax.block_until_ready(fn())          # compile + warm caches
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def calibrate_hardware(hw: HardwareSpec = V5E, *,
                       seq: int = 256, heads: int = 4, head_dim: int = 64,
                       batch: int = 4, page_size: int = 16,
                       pages_per_seq: int = 8, repeats: int = 3,
                       interpret: Optional[bool] = None,
                       ) -> tuple[HardwareSpec, KernelCalibration]:
    """Measure achieved MFU / bandwidth-efficiency of the real serving
    kernels and return ``hw`` with the measured constants substituted.

    Shapes default small enough that interpret-mode (non-TPU) calibration
    finishes in seconds; on a TPU pass serving-sized shapes
    (seq=2048, head_dim=128, page_size=64) for representative numbers."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.chunked_prefill import chunked_prefill_attention
    from repro.kernels.paged_attention import paged_attention

    device = jax.default_backend()
    if interpret is None:
        interpret = device != "tpu"
    rng = np.random.default_rng(0)
    dtype = jnp.float32 if interpret else jnp.bfloat16

    # --- prefill side: one full-chunk causal attention over the cache ----
    q = jnp.asarray(rng.normal(size=(1, seq, heads, head_dim)), dtype)
    kc = jnp.asarray(rng.normal(size=(1, seq, heads, head_dim)), dtype)
    vc = jnp.asarray(rng.normal(size=(1, seq, heads, head_dim)), dtype)
    starts = jnp.zeros((1,), jnp.int32)
    t_p = _time_fn(
        lambda: chunked_prefill_attention(q, kc, vc, starts,
                                          interpret=interpret),
        repeats)
    # causal QK^T + PV: 4 · Hq · D · Sq · Skv / 2 useful flops
    p_flops = 4.0 * heads * head_dim * seq * seq / 2.0
    mfu_p = _clamp_frac(p_flops / (t_p * hw.peak_flops))

    # --- decode side: paged attention over a block-table-indirected pool -
    n_pages = batch * pages_per_seq + 1
    qd = jnp.asarray(rng.normal(size=(batch, heads, head_dim)), dtype)
    kp = jnp.asarray(
        rng.normal(size=(n_pages, page_size, heads, head_dim)), dtype)
    vp = jnp.asarray(
        rng.normal(size=(n_pages, page_size, heads, head_dim)), dtype)
    bt = jnp.asarray(rng.permutation(n_pages)[: batch * pages_per_seq]
                     .reshape(batch, pages_per_seq), jnp.int32)
    lengths = jnp.full((batch,), page_size * pages_per_seq, jnp.int32)
    t_d = _time_fn(
        lambda: paged_attention(qd, kp, vp, bt, lengths, interpret=interpret),
        repeats)
    ctx = page_size * pages_per_seq
    d_flops = 4.0 * batch * heads * head_dim * ctx
    # decode streams every attended K/V byte once: the memory roofline side
    d_bytes = 2.0 * batch * ctx * heads * head_dim * jnp.dtype(dtype).itemsize
    mfu_d = _clamp_frac(d_flops / (t_d * hw.peak_flops))
    bw_eff = _clamp_frac(d_bytes / (t_d * hw.hbm_bw))

    cal = KernelCalibration(
        mfu_prefill=mfu_p, mfu_decode=mfu_d, bw_eff=bw_eff,
        prefill_seconds=t_p, decode_seconds=t_d,
        prefill_flops=p_flops, decode_flops=d_flops, decode_bytes=d_bytes,
        device=device)
    measured = dataclasses.replace(
        hw, name=f"{hw.name}-measured",
        mfu_prefill=mfu_p, mfu_decode=mfu_d, bw_eff=bw_eff)
    return measured, cal


class CalibratedRooflineBackend:
    """ExecutionBackend whose clock is a roofline instantiated from
    measured kernel efficiency instead of the assumed constants (the
    ROADMAP's "batched roofline with measured MFU" backend).

    Runs the calibration once at construction; ``run_iteration`` then
    prices every composed iteration with the measured model. The
    per-worker cost models the engine carries (admission, capacity) are
    untouched — only the *clock* comes from measurements, which is the
    honest split: capacity is a spec property, speed is an empirical one."""

    def __init__(self, cfg, worker: WorkerSpec = WorkerSpec(),
                 page_size: int = 16, interpret: Optional[bool] = None,
                 **calibrate_kw):
        hw, self.calibration = calibrate_hardware(
            worker.hw, interpret=interpret, **calibrate_kw)
        self.cost = CostModel(cfg, dataclasses.replace(worker, hw=hw),
                              page_size=page_size)

    def run_iteration(self, worker, plan) -> float:
        return self.cost.iteration_time(
            plan.n_decode, plan.sum_ctx, plan.prefill_tokens,
            plan.prefill_ctx_offset)

    def on_finish(self, req) -> None:
        pass

    def on_migrate(self, req, src_wid: int, dst_wid: int) -> None:
        pass
