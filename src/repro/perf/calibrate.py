"""Measured-MFU roofline: instantiate the analytic model from real kernels.

The analytic ``CostModel`` ships with assumed efficiency constants
(``mfu_prefill``/``mfu_decode``/``bw_eff``). A real deployment should not
trust them: achieved MFU depends on head dims, page sizes, XLA version
and the exact kernels in the serving path. ``calibrate_hardware`` runs
the repo's own Pallas kernels — ``kernels/chunked_prefill.py`` for the
prefill side, ``kernels/paged_attention.py`` for the decode side — once
at startup, times them, and returns a ``HardwareSpec`` whose efficiency
constants are *measurements*:

    mfu    = achieved_flops / (elapsed · peak_flops)
    bw_eff = achieved_bytes / (elapsed · hbm_bw)

``calibrate_interference`` (v2) extends the same measured-constants idea
to the §IV mixed-batch contention coefficient: it runs the two kernels
*mixed* vs *pure* across a (decode-batch × chunk-size) grid and solves
each cell's measured excess for γ, returning a bucketed
``InterferenceTable`` that drops into ``HardwareSpec.interference``
(the scalar stays accepted as the degenerate 1×1 table).

``CalibratedRooflineBackend`` is the ``ExecutionBackend`` over the
resulting model: the ROADMAP's "batched roofline with measured MFU"
backend. Off-TPU (CPU CI, interpret-mode Pallas) the measured fractions
are tiny but still well-defined — they are clamped into ``(0, 1]`` and
the backend remains exercisable end-to-end; on a real TPU the same code
path yields deployment-grade constants.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Optional

from repro.perf.hardware import (HardwareSpec, InterferenceTable, V5E,
                                 WorkerSpec)
from repro.perf.model import CostModel

_MFU_FLOOR = 1e-6        # interpret-mode measurements stay valid fractions


@dataclasses.dataclass(frozen=True)
class KernelCalibration:
    """What the calibration run measured (seconds + derived fractions).

    The ``gemm_*`` fields (0.0 when the GEMM pass is disabled) time the
    full-layer dense forward — projections, MLP, unembed — so the blended
    ``mfu_prefill``/``mfu_decode`` cover the MLP-dominated regime the
    attention microkernels alone cannot see."""
    mfu_prefill: float
    mfu_decode: float
    bw_eff: float
    prefill_seconds: float
    decode_seconds: float
    prefill_flops: float
    decode_flops: float
    decode_bytes: float
    device: str
    gemm_prefill_seconds: float = 0.0
    gemm_decode_seconds: float = 0.0
    gemm_prefill_flops: float = 0.0
    gemm_decode_flops: float = 0.0
    mfu_gemm_prefill: float = 0.0
    mfu_gemm_decode: float = 0.0


def _clamp_frac(x: float) -> float:
    return min(max(x, _MFU_FLOOR), 1.0)


def _time_fn(fn, repeats: int) -> float:
    """True-median-of-``repeats`` wall time, after one warmup compile call
    (``times[len//2]`` alone is the *upper* middle for even counts — a
    biased pick; ``statistics.median`` averages the two middles)."""
    if repeats < 1:
        raise ValueError(
            f"repeats must be >= 1 to measure anything, got {repeats}")
    import jax
    jax.block_until_ready(fn())          # compile + warm caches
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return statistics.median(times)


def _prefill_case(rng, dtype, seq: int, heads: int, head_dim: int,
                  interpret: bool):
    """Pure chunked-prefill workload over the real Pallas kernel:
    (timed fn, useful flops, hot bytes). One full-chunk causal attention
    over the cache; flops = causal QK^T + PV = 4 · Hq · D · Sq · Skv / 2,
    bytes = q/k/v read + output write."""
    import jax.numpy as jnp

    from repro.kernels.chunked_prefill import chunked_prefill_attention

    q = jnp.asarray(rng.normal(size=(1, seq, heads, head_dim)), dtype)
    kc = jnp.asarray(rng.normal(size=(1, seq, heads, head_dim)), dtype)
    vc = jnp.asarray(rng.normal(size=(1, seq, heads, head_dim)), dtype)
    starts = jnp.zeros((1,), jnp.int32)
    flops = 4.0 * heads * head_dim * seq * seq / 2.0
    nbytes = 4.0 * seq * heads * head_dim * jnp.dtype(dtype).itemsize
    return (lambda: chunked_prefill_attention(q, kc, vc, starts,
                                              interpret=interpret),
            flops, nbytes)


def _decode_case(rng, dtype, batch: int, heads: int, head_dim: int,
                 page_size: int, pages_per_seq: int, interpret: bool):
    """Pure paged-decode workload over a block-table-indirected pool:
    (timed fn, useful flops, hot bytes). Decode streams every attended
    K/V byte once — the memory roofline side."""
    import jax.numpy as jnp

    from repro.kernels.paged_attention import paged_attention

    n_pages = batch * pages_per_seq + 1
    ctx = page_size * pages_per_seq
    qd = jnp.asarray(rng.normal(size=(batch, heads, head_dim)), dtype)
    kp = jnp.asarray(
        rng.normal(size=(n_pages, page_size, heads, head_dim)), dtype)
    vp = jnp.asarray(
        rng.normal(size=(n_pages, page_size, heads, head_dim)), dtype)
    bt = jnp.asarray(rng.permutation(n_pages)[: batch * pages_per_seq]
                     .reshape(batch, pages_per_seq), jnp.int32)
    lengths = jnp.full((batch,), ctx, jnp.int32)
    flops = 4.0 * batch * heads * head_dim * ctx
    nbytes = 2.0 * batch * ctx * heads * head_dim * jnp.dtype(dtype).itemsize
    return (lambda: paged_attention(qd, kp, vp, bt, lengths,
                                    interpret=interpret),
            flops, nbytes)


def _gemm_case(rng, dtype, seq: int, batch: int):
    """Full-layer GEMM workload over the serving executor's own batched,
    donation-aware entry points (``ExecutorKernels.prefill_fn`` /
    ``decode_fn``): a tiny 2-layer dense model driven through the exact
    slot-indexed jitted functions ``RealExecutor`` runs, so the measured
    fraction prices the serving path — slot gather/scatter, bucket
    padding and on-device sampling included — not a bespoke harness.
    Returns ``(prefill_fn, prefill_flops, decode_fn, decode_flops)`` with
    the canonical 2 · n_active flops/token accounting the cost model
    uses, so the measured fraction is an apples-to-apples MFU."""
    import jax
    import jax.numpy as jnp

    from repro.models.api import build
    from repro.models.layers import ModelConfig
    from repro.perf.model import build_cost_spec
    from repro.serving.executor import ExecutorKernels

    cfg = ModelConfig(name="calib-gemm", family="dense", num_layers=2,
                      d_model=128, num_heads=2, num_kv_heads=2, head_dim=64,
                      d_ff=512, vocab_size=512)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    if dtype is not None:
        params = jax.tree.map(
            lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating)
            else a, params)
    n_active = build_cost_spec(cfg).n_active
    # +1 cache row so the decode write at position ``seq`` stays in bounds
    kernels = ExecutorKernels(api, max_slots=batch, max_len=seq + 1)
    state = {"cache": api.init_cache(batch, seq + 1)}
    bucket = kernels.bucket_for(seq)
    tokens = rng.integers(0, cfg.vocab_size, size=(batch, seq))
    chunk = jnp.zeros((batch, bucket), jnp.int32).at[:, :seq].set(
        jnp.asarray(tokens, jnp.int32))
    slots = jnp.arange(batch, dtype=jnp.int32)
    starts = jnp.zeros((batch,), jnp.int32)
    takes = jnp.full((batch,), seq, jnp.int32)
    pfn = kernels.prefill_fn(bucket, batch)

    def prefill_call():
        # thread the cache: donate_argnums consumes the argument buffer
        toks, state["cache"] = pfn(params, state["cache"], chunk, slots,
                                   starts, takes)
        return toks

    lengths = jnp.full((batch,), seq, jnp.int32)
    step = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(batch,)),
                       jnp.int32)

    def decode_call():
        toks, state["cache"] = kernels.decode_fn(params, state["cache"],
                                                 step, lengths)
        return toks

    jax.block_until_ready(prefill_call())    # decode times a filled cache
    return (prefill_call, 2.0 * n_active * batch * seq,
            decode_call, 2.0 * n_active * batch)


def calibrate_hardware(hw: HardwareSpec = V5E, *,
                       seq: int = 256, heads: int = 4, head_dim: int = 64,
                       batch: int = 4, page_size: int = 16,
                       pages_per_seq: int = 8, repeats: int = 3,
                       interpret: Optional[bool] = None,
                       gemm: bool = True,
                       ) -> tuple[HardwareSpec, KernelCalibration]:
    """Measure achieved MFU / bandwidth-efficiency of the real serving
    kernels and return ``hw`` with the measured constants substituted.

    With ``gemm=True`` (default) the attention microkernel timings are
    blended with a full-layer dense-forward GEMM pass, so the returned
    MFU reflects the MLP-dominated regime a serving iteration actually
    spends most of its flops in:

        mfu = (attn_flops + gemm_flops) / ((t_attn + t_gemm) · peak)

    Shapes default small enough that interpret-mode (non-TPU) calibration
    finishes in seconds; on a TPU pass serving-sized shapes
    (seq=2048, head_dim=128, page_size=64) for representative numbers."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    device = jax.default_backend()
    if interpret is None:
        interpret = device != "tpu"
    rng = np.random.default_rng(0)
    dtype = jnp.float32 if interpret else jnp.bfloat16

    prefill_fn, p_flops, _ = _prefill_case(rng, dtype, seq, heads, head_dim,
                                           interpret)
    t_p = _time_fn(prefill_fn, repeats)
    mfu_p = _clamp_frac(p_flops / (t_p * hw.peak_flops))

    decode_fn, d_flops, d_bytes = _decode_case(
        rng, dtype, batch, heads, head_dim, page_size, pages_per_seq,
        interpret)
    t_d = _time_fn(decode_fn, repeats)
    mfu_d = _clamp_frac(d_flops / (t_d * hw.peak_flops))
    bw_eff = _clamp_frac(d_bytes / (t_d * hw.hbm_bw))

    gp_t = gd_t = gp_f = gd_f = mfu_gp = mfu_gd = 0.0
    if gemm:
        gp_fn, gp_f, gd_fn, gd_f = _gemm_case(rng, dtype, seq, batch)
        gp_t = _time_fn(gp_fn, repeats)
        gd_t = _time_fn(gd_fn, repeats)
        mfu_gp = _clamp_frac(gp_f / (gp_t * hw.peak_flops))
        mfu_gd = _clamp_frac(gd_f / (gd_t * hw.peak_flops))
        # blended phase MFU: one combined workload, one combined clock
        mfu_p = _clamp_frac((p_flops + gp_f) / ((t_p + gp_t) * hw.peak_flops))
        mfu_d = _clamp_frac((d_flops + gd_f) / ((t_d + gd_t) * hw.peak_flops))

    cal = KernelCalibration(
        mfu_prefill=mfu_p, mfu_decode=mfu_d, bw_eff=bw_eff,
        prefill_seconds=t_p, decode_seconds=t_d,
        prefill_flops=p_flops, decode_flops=d_flops, decode_bytes=d_bytes,
        device=device,
        gemm_prefill_seconds=gp_t, gemm_decode_seconds=gd_t,
        gemm_prefill_flops=gp_f, gemm_decode_flops=gd_f,
        mfu_gemm_prefill=mfu_gp, mfu_gemm_decode=mfu_gd)
    measured = dataclasses.replace(
        hw, name=f"{hw.name}-measured",
        mfu_prefill=mfu_p, mfu_decode=mfu_d, bw_eff=bw_eff)
    return measured, cal


@dataclasses.dataclass(frozen=True)
class InterferenceCalibration:
    """What the mixed-vs-pure grid sweep measured, per cell."""
    table: InterferenceTable
    decode_batches: tuple           # grid axis values (= table edges)
    chunk_sizes: tuple
    pure_prefill_s: tuple           # per chunk size
    pure_decode_s: tuple            # per decode batch
    mixed_s: tuple                  # row-per-batch grid of mixed times
    device: str


def calibrate_interference(hw: HardwareSpec = V5E, *,
                           decode_batches: tuple = (1, 4, 8),
                           chunk_sizes: tuple = (128, 256),
                           heads: int = 4, head_dim: int = 64,
                           page_size: int = 16, pages_per_seq: int = 8,
                           repeats: int = 3,
                           interpret: Optional[bool] = None,
                           gamma_max: float = 1.0,
                           ) -> tuple[InterferenceTable,
                                      InterferenceCalibration]:
    """Measure the §IV mixed-batch contention coefficient γ per
    (decode-batch, chunk-size) bucket from the repo's own serving kernels.

    For every grid cell the real Pallas kernels run *pure* (the
    chunked-prefill attention alone, the paged decode attention alone)
    and *mixed* (both in one composed call — how a multiplexing worker's
    iteration actually executes), and the cell's measured excess over the
    perfect-overlap floor ``max(t_prefill, t_decode)`` solves the cost
    model's penalty form for γ::

        t_mixed = max(t_p, t_d) + γ · β_p · β_d · min(t_p, t_d)

    with β from the kernels' flop/byte rooflines — the same *functional
    form* as ``CostModel._interference``, evaluated over the attention
    kernels' own operands. γ is therefore a dimensionless contention
    coefficient measured on the attention path; the model applies it to
    its full-phase unit (GEMMs + weight streaming included), treating
    attention-path contention as representative of the whole phase's —
    the approximation the ROADMAP's on-TPU validation item exists to
    check. γ is clamped into [0, ``gamma_max``] — ``gamma_max=1`` keeps
    the model's guarantee that a mixed iteration never exceeds the
    fully-serialised sum. Off-TPU
    (interpret-mode Pallas) the two kernels cannot overlap at all, so γ
    rails toward that serialised ceiling — still well-defined, and the
    same harness on a real TPU lands wherever the hardware actually sits
    between perfect overlap and serialisation.

    Returns the bucketed table (edges = the swept grid values as bucket
    lower bounds) plus the raw per-cell measurements."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    if not decode_batches or not chunk_sizes:
        raise ValueError("calibrate_interference needs a non-empty grid")
    decode_batches = tuple(sorted(decode_batches))
    chunk_sizes = tuple(sorted(chunk_sizes))
    device = jax.default_backend()
    if interpret is None:
        interpret = device != "tpu"
    rng = np.random.default_rng(0)
    dtype = jnp.float32 if interpret else jnp.bfloat16
    peak_c = hw.peak_flops
    mem = hw.hbm_bw * hw.bw_eff

    # one workload per axis value, shared by the pure timing and every
    # mixed cell it appears in — the mixed run times the SAME operands
    # its pure baseline did. Alone-times are per-axis (a chunk's does not
    # depend on which decode batch it will be mixed with); mixed per cell.
    pre = {c: _prefill_case(rng, dtype, c, heads, head_dim, interpret)
           for c in chunk_sizes}
    dec = {b: _decode_case(rng, dtype, b, heads, head_dim, page_size,
                           pages_per_seq, interpret)
           for b in decode_batches}
    t_p = {c: _time_fn(pre[c][0], repeats) for c in chunk_sizes}
    t_d = {b: _time_fn(dec[b][0], repeats) for b in decode_batches}
    mixed_rows, gamma_rows = [], []
    for b in decode_batches:
        mixed_row, gamma_row = [], []
        for c in chunk_sizes:
            pf, p_flops, p_bytes = pre[c]
            df, d_flops, d_bytes = dec[b]
            t_mix = _time_fn(lambda: (pf(), df()), repeats)
            # kernel-level flop/byte accounting -> phase boundedness
            t_cp = p_flops / (peak_c * hw.mfu_prefill)
            t_mp = p_bytes / mem
            t_cd = d_flops / (peak_c * hw.mfu_decode)
            t_md = d_bytes / mem
            beta_p = t_cp / max(t_cp, t_mp)
            beta_d = t_md / max(t_cd, t_md)
            unit = beta_p * beta_d * min(t_p[c], t_d[b])
            excess = t_mix - max(t_p[c], t_d[b])
            gamma = min(max(excess / unit, 0.0), gamma_max) \
                if unit > 1e-12 else 0.0
            mixed_row.append(t_mix)
            gamma_row.append(gamma)
        mixed_rows.append(tuple(mixed_row))
        gamma_rows.append(tuple(gamma_row))

    table = InterferenceTable(decode_edges=decode_batches,
                              chunk_edges=chunk_sizes,
                              gamma=tuple(gamma_rows))
    cal = InterferenceCalibration(
        table=table, decode_batches=decode_batches, chunk_sizes=chunk_sizes,
        pure_prefill_s=tuple(t_p[c] for c in chunk_sizes),
        pure_decode_s=tuple(t_d[b] for b in decode_batches),
        mixed_s=tuple(mixed_rows), device=device)
    return table, cal


class CalibratedRooflineBackend:
    """ExecutionBackend whose clock is a roofline instantiated from
    measured kernel efficiency instead of the assumed constants (the
    ROADMAP's "batched roofline with measured MFU" backend).

    Runs the calibration once at construction; ``run_iteration`` then
    prices every composed iteration with the measured model. The
    per-worker cost models the engine carries (admission, capacity) are
    untouched — only the *clock* comes from measurements, which is the
    honest split: capacity is a spec property, speed is an empirical one."""

    def __init__(self, cfg, worker: WorkerSpec = WorkerSpec(),
                 page_size: int = 16, interpret: Optional[bool] = None,
                 measure_interference: bool = False,
                 interference_kw: Optional[dict] = None,
                 **calibrate_kw):
        hw, self.calibration = calibrate_hardware(
            worker.hw, interpret=interpret, **calibrate_kw)
        self.interference_calibration = None
        if measure_interference:
            # solve γ against the MEASURED spec — the same constants the
            # model will recompute β with when applying the penalty
            table, self.interference_calibration = calibrate_interference(
                hw, interpret=interpret, **(interference_kw or {}))
            hw = dataclasses.replace(hw, interference=table)
        self.cost = CostModel(cfg, dataclasses.replace(worker, hw=hw),
                              page_size=page_size)

    def run_iteration(self, worker, plan) -> float:
        return self.cost.iteration_time(
            plan.n_decode, plan.sum_ctx, plan.prefill_tokens,
            plan.prefill_ctx_offset)

    def on_finish(self, req) -> None:
        pass

    def on_migrate(self, req, src_wid: int, dst_wid: int) -> None:
        pass
