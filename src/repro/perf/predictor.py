"""Execution-time predictors (paper §IV-C), per-worker aware.

The toggle "leverages offline profiling tools to estimate both the
execution time of a prefill request and the queuing time when scheduling
to the local worker". Every predict method takes an optional ``wid`` so
callers can price work on the *target* worker's hardware — heterogeneous
clusters answer differently per worker, homogeneous ones ignore it (and
stay decision-identical to the pre-``repro.perf`` scheduler).

* ``AnalyticalPredictor`` — wraps one roofline ``CostModel`` (what the
  simulator itself uses, optionally with a safety margin; predictor error
  can be injected for robustness experiments). Worker-agnostic.
* ``ClusterPredictor`` — one ``IterationCostModel`` per worker: the
  heterogeneous-cluster analytic predictor. ``wid=None`` prices on the
  reference (fastest) worker.
* ``ProfiledPredictor`` — piecewise-linear interpolation over an offline
  profile table {(tokens, ctx) -> seconds}, the way a real deployment
  profiles its worker; built by ``profile_worker`` from any executor.

The online-calibration wrapper (``OnlinePredictor``) lives in
``repro.perf.calibration``.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Callable, Optional, Sequence

from repro.perf.model import (CostModel, IterationCostModel,
                              canonical_iteration_time)


class Predictor:
    def predict_prefill(self, tokens: int, ctx_offset: int = 0,
                        wid: Optional[int] = None) -> float:
        raise NotImplementedError

    def predict_decode_iter(self, n_decode: int, sum_ctx: float,
                            wid: Optional[int] = None) -> float:
        raise NotImplementedError

    def predict_migration(self, ctx_tokens: int,
                          wid: Optional[int] = None) -> float:
        raise NotImplementedError

    def predict_interference(self, n_decode: int, sum_ctx: float,
                             prefill_tokens: int, ctx_offset: float = 0.0,
                             wid: Optional[int] = None) -> float:
        """§IV contention penalty a prefill chunk adds *on top of* the
        additive prefill + decode estimates when co-batched with
        ``n_decode`` running decodes. Admission paths add this to their
        chunk cost; the default (and any γ=0 model) returns exactly 0.0,
        so interference-blind predictors keep legacy decision parity."""
        return 0.0

    def predict_restore(self, ctx_tokens: int, residue_tokens: int = 0,
                        wid: Optional[int] = None) -> float:
        """Tiered-KV restore cost: wire time to pull an offloaded request's
        KV back over the host link plus any re-prefill residue. The engine
        offloads instead of evicting only when this beats re-prefilling the
        whole context; the default inf means 'no tier knowledge — never
        prefer offload', keeping tier-blind predictors safe."""
        return float("inf")


@dataclasses.dataclass
class AnalyticalPredictor(Predictor):
    cost: CostModel
    safety: float = 1.1          # conservative over-estimate (paper: the
                                 # toggle "conservatively sends requests")
    def predict_prefill(self, tokens: int, ctx_offset: int = 0,
                        wid: Optional[int] = None) -> float:
        return self.cost.prefill_time(tokens, ctx_offset) * self.safety

    def predict_decode_iter(self, n_decode: int, sum_ctx: float,
                            wid: Optional[int] = None) -> float:
        return self.cost.decode_iter_time(n_decode, sum_ctx) * self.safety

    def predict_migration(self, ctx_tokens: int,
                          wid: Optional[int] = None) -> float:
        return self.cost.migration_time(ctx_tokens) * self.safety

    def predict_interference(self, n_decode: int, sum_ctx: float,
                             prefill_tokens: int, ctx_offset: float = 0.0,
                             wid: Optional[int] = None) -> float:
        return self.cost.interference_penalty(
            n_decode, sum_ctx, prefill_tokens, ctx_offset) * self.safety

    def predict_restore(self, ctx_tokens: int, residue_tokens: int = 0,
                        wid: Optional[int] = None) -> float:
        return self.cost.restore_time(ctx_tokens, residue_tokens) \
            * self.safety


class BiasedPredictor(AnalyticalPredictor):
    """Systematically ``bias``×-miscalibrated analytical predictor — a
    stale or wrong-hardware offline profile. Robustness benchmarks and the
    OnlinePredictor convergence tests inject known error through this."""

    def __init__(self, cost: CostModel, bias: float, safety: float = 1.1):
        super().__init__(cost, safety=safety)
        self.bias = bias

    def predict_prefill(self, tokens: int, ctx_offset: int = 0,
                        wid: Optional[int] = None) -> float:
        return super().predict_prefill(tokens, ctx_offset, wid) * self.bias

    def predict_decode_iter(self, n_decode: int, sum_ctx: float,
                            wid: Optional[int] = None) -> float:
        return super().predict_decode_iter(n_decode, sum_ctx, wid) * self.bias


class ClusterPredictor(Predictor):
    """Per-worker analytic pricing over heterogeneous hardware.

    One ``IterationCostModel`` per worker id; predictions for ``wid``
    price on that worker's spec, so a 2x-slow straggler's prefill chunk
    really predicts 2x longer. ``wid=None`` (worker-agnostic call sites:
    SLO derivation, global-queue sizing) uses the reference model — by
    convention the fastest worker's, matching the optimistic light-load
    latencies SLOs are derived from."""

    def __init__(self, costs: dict[int, IterationCostModel],
                 reference: Optional[IterationCostModel] = None,
                 safety: float = 1.1):
        if not costs:
            raise ValueError("ClusterPredictor needs at least one worker")
        self.costs = dict(costs)
        self.safety = safety
        self.reference = reference if reference is not None else min(
            self.costs.values(), key=canonical_iteration_time)

    def _cost(self, wid: Optional[int]) -> IterationCostModel:
        if wid is None:
            return self.reference
        return self.costs.get(wid, self.reference)

    def predict_prefill(self, tokens: int, ctx_offset: int = 0,
                        wid: Optional[int] = None) -> float:
        return self._cost(wid).prefill_time(tokens, ctx_offset) * self.safety

    def predict_decode_iter(self, n_decode: int, sum_ctx: float,
                            wid: Optional[int] = None) -> float:
        return self._cost(wid).decode_iter_time(n_decode, sum_ctx) \
            * self.safety

    def predict_migration(self, ctx_tokens: int,
                          wid: Optional[int] = None) -> float:
        return self._cost(wid).migration_time(ctx_tokens) * self.safety

    def predict_interference(self, n_decode: int, sum_ctx: float,
                             prefill_tokens: int, ctx_offset: float = 0.0,
                             wid: Optional[int] = None) -> float:
        # IterationCostModel does not require the penalty decomposition;
        # models without one price 0 (interference-blind), like the base
        penalty = getattr(self._cost(wid), "interference_penalty", None)
        if penalty is None:
            return 0.0
        return penalty(n_decode, sum_ctx, prefill_tokens, ctx_offset) \
            * self.safety

    def predict_restore(self, ctx_tokens: int, residue_tokens: int = 0,
                        wid: Optional[int] = None) -> float:
        # IterationCostModel does not require restore_time; tier-blind
        # models keep the base's 'never prefer offload' answer
        restore = getattr(self._cost(wid), "restore_time", None)
        if restore is None:
            return float("inf")
        return restore(ctx_tokens, residue_tokens) * self.safety


class ProfiledPredictor(Predictor):
    """Interpolates a profiled (tokens -> seconds) table; ctx contributions
    enter linearly with a profiled per-ctx-token coefficient."""

    def __init__(self, prefill_points: Sequence[tuple[int, float]],
                 decode_points: Sequence[tuple[int, float, float]],
                 ctx_coeff: float, migration_coeff: float,
                 safety: float = 1.1):
        self.prefill_points = sorted(prefill_points)
        self.decode_points = sorted(decode_points)
        self.ctx_coeff = ctx_coeff
        self.migration_coeff = migration_coeff
        self.safety = safety

    @staticmethod
    def _interp(points, x):
        xs = [p[0] for p in points]
        i = bisect.bisect_left(xs, x)
        if i == 0:
            lo, hi = points[0], points[min(1, len(points) - 1)]
        elif i >= len(points):
            lo, hi = points[-2] if len(points) > 1 else points[-1], points[-1]
        else:
            lo, hi = points[i - 1], points[i]
        if hi[0] == lo[0]:
            return lo[1]
        t = (x - lo[0]) / (hi[0] - lo[0])
        return lo[1] + t * (hi[1] - lo[1])

    def predict_prefill(self, tokens: int, ctx_offset: int = 0,
                        wid: Optional[int] = None) -> float:
        base = self._interp(self.prefill_points, tokens)
        return (base + self.ctx_coeff * ctx_offset * tokens) * self.safety

    def predict_decode_iter(self, n_decode: int, sum_ctx: float,
                            wid: Optional[int] = None) -> float:
        base = self._interp([(b, t) for b, t, _ in self.decode_points], n_decode)
        return (base + self.ctx_coeff * sum_ctx) * self.safety

    def predict_migration(self, ctx_tokens: int,
                          wid: Optional[int] = None) -> float:
        return self.migration_coeff * ctx_tokens * self.safety


def profile_worker(step_fn: Callable[[int, float, int], float],
                   token_grid: Sequence[int] = (128, 512, 2048, 8192),
                   batch_grid: Sequence[int] = (1, 8, 32, 128),
                   ctx_probe: int = 8192) -> ProfiledPredictor:
    """Build a ProfiledPredictor by measuring ``step_fn(n_decode, sum_ctx,
    prefill_tokens) -> seconds`` — works against the real executor or the
    simulator alike (offline profiling per §IV-C)."""
    prefill_points = [(t, step_fn(0, 0.0, t)) for t in token_grid]
    decode_points = [(b, step_fn(b, float(b * 512), 0), 512.0)
                     for b in batch_grid]
    t0 = step_fn(1, 0.0, 0)
    t1 = step_fn(1, float(ctx_probe), 0)
    ctx_coeff = max(0.0, (t1 - t0) / ctx_probe)
    return ProfiledPredictor(prefill_points, decode_points, ctx_coeff,
                             migration_coeff=1e-9)
