"""Execution-time predictors (paper §IV-C), per-worker aware.

The toggle "leverages offline profiling tools to estimate both the
execution time of a prefill request and the queuing time when scheduling
to the local worker". Every predict method takes an optional ``wid`` so
callers can price work on the *target* worker's hardware — heterogeneous
clusters answer differently per worker, homogeneous ones ignore it (and
stay decision-identical to the pre-``repro.perf`` scheduler).

* ``AnalyticalPredictor`` — wraps one roofline ``CostModel`` (what the
  simulator itself uses, optionally with a safety margin; predictor error
  can be injected for robustness experiments). Worker-agnostic.
* ``ClusterPredictor`` — one ``IterationCostModel`` per worker: the
  heterogeneous-cluster analytic predictor. ``wid=None`` prices on the
  reference (fastest) worker.
* ``ProfiledPredictor`` — piecewise-linear interpolation over an offline
  profile table {(tokens, ctx) -> seconds}, the way a real deployment
  profiles its worker; built by ``profile_worker`` from any executor.

The online-calibration wrapper (``OnlinePredictor``) lives in
``repro.perf.calibration``.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from repro.perf.model import (CostModel, IterationCostModel,
                              canonical_iteration_time)


def _col(x, n: int) -> np.ndarray:
    """Broadcast a scalar-or-sequence argument to a length-``n`` float64
    column (a read-only broadcast view for scalars — callers never write)."""
    a = np.asarray(x, dtype=np.float64)
    if a.ndim == 0:
        return np.broadcast_to(a, (n,))
    return a


def _seq(x, n: int) -> Sequence:
    """Per-element view of a scalar-or-sequence argument, preserving the
    original Python scalar types for exact scalar-fallback loops."""
    if isinstance(x, np.ndarray):
        return x.tolist()
    if isinstance(x, (list, tuple)):
        return x
    return [x] * n


class Predictor:
    def predict_prefill(self, tokens: int, ctx_offset: int = 0,
                        wid: Optional[int] = None) -> float:
        raise NotImplementedError

    def predict_decode_iter(self, n_decode: int, sum_ctx: float,
                            wid: Optional[int] = None) -> float:
        raise NotImplementedError

    def predict_migration(self, ctx_tokens: int,
                          wid: Optional[int] = None) -> float:
        raise NotImplementedError

    def predict_interference(self, n_decode: int, sum_ctx: float,
                             prefill_tokens: int, ctx_offset: float = 0.0,
                             wid: Optional[int] = None) -> float:
        """§IV contention penalty a prefill chunk adds *on top of* the
        additive prefill + decode estimates when co-batched with
        ``n_decode`` running decodes. Admission paths add this to their
        chunk cost; the default (and any γ=0 model) returns exactly 0.0,
        so interference-blind predictors keep legacy decision parity."""
        return 0.0

    def predict_restore(self, ctx_tokens: int, residue_tokens: int = 0,
                        wid: Optional[int] = None) -> float:
        """Tiered-KV restore cost: wire time to pull an offloaded request's
        KV back over the host link plus any re-prefill residue. The engine
        offloads instead of evicting only when this beats re-prefilling the
        whole context; the default inf means 'no tier knowledge — never
        prefer offload', keeping tier-blind predictors safe."""
        return float("inf")

    # ------------------------------------------------- batched entry points
    # Price one candidate against many workers in a single call: ``wids``
    # is a length-n sequence of worker ids (None allowed, same meaning as
    # the scalar calls); the other arguments broadcast scalar-or-length-n.
    # The base implementations are scalar loops — bit-identical by
    # construction, so any Predictor subclass is batch-callable; the
    # analytic subclasses override with one-shot numpy evaluations that
    # tests/test_vectorized.py pins against the loops element-for-element.

    def predict_prefill_batch(self, wids: Sequence[Optional[int]], tokens,
                              ctx_offset=0) -> np.ndarray:
        n = len(wids)
        toks, offs = _seq(tokens, n), _seq(ctx_offset, n)
        return np.array([self.predict_prefill(t, o, wid=w)
                         for w, t, o in zip(wids, toks, offs)],
                        dtype=np.float64)

    def predict_decode_iter_batch(self, wids: Sequence[Optional[int]],
                                  n_decode, sum_ctx) -> np.ndarray:
        n = len(wids)
        nds, scs = _seq(n_decode, n), _seq(sum_ctx, n)
        return np.array([self.predict_decode_iter(b, s, wid=w)
                         for w, b, s in zip(wids, nds, scs)],
                        dtype=np.float64)

    def predict_interference_batch(self, wids: Sequence[Optional[int]],
                                   n_decode, sum_ctx, prefill_tokens,
                                   ctx_offset=0.0) -> np.ndarray:
        n = len(wids)
        nds, scs = _seq(n_decode, n), _seq(sum_ctx, n)
        pts, offs = _seq(prefill_tokens, n), _seq(ctx_offset, n)
        return np.array([self.predict_interference(b, s, p, o, wid=w)
                         for w, b, s, p, o in zip(wids, nds, scs, pts, offs)],
                        dtype=np.float64)

    # ------------------------------------------- slack-chunk inversion
    def chunk_candidates(self, wids: Sequence[Optional[int]], lo: int,
                         hi: int, budget, n_decode, sum_ctx, ctx_offset,
                         s_mul=None) -> Optional[np.ndarray]:
        """Closed-form slack-chunking support: per-row candidate chunk
        sizes guaranteed to contain every integer on [lo, hi] where this
        predictor's chunk cost (prefill + interference) can cross the
        per-row ``budget`` — so the toggle verifies them with ONE batched
        cost evaluation instead of a bisection loop. ``s_mul`` stacks an
        extra per-row multiplier on the prefill estimate (the
        OnlinePredictor's EWMA scale). None = no closed form available
        (profiled/custom predictors); callers fall back to bisection."""
        return None


@dataclasses.dataclass
class AnalyticalPredictor(Predictor):
    cost: CostModel
    safety: float = 1.1          # conservative over-estimate (paper: the
                                 # toggle "conservatively sends requests")
    def predict_prefill(self, tokens: int, ctx_offset: int = 0,
                        wid: Optional[int] = None) -> float:
        return self.cost.prefill_time(tokens, ctx_offset) * self.safety

    def predict_decode_iter(self, n_decode: int, sum_ctx: float,
                            wid: Optional[int] = None) -> float:
        return self.cost.decode_iter_time(n_decode, sum_ctx) * self.safety

    def predict_migration(self, ctx_tokens: int,
                          wid: Optional[int] = None) -> float:
        return self.cost.migration_time(ctx_tokens) * self.safety

    def predict_interference(self, n_decode: int, sum_ctx: float,
                             prefill_tokens: int, ctx_offset: float = 0.0,
                             wid: Optional[int] = None) -> float:
        return self.cost.interference_penalty(
            n_decode, sum_ctx, prefill_tokens, ctx_offset) * self.safety

    def predict_restore(self, ctx_tokens: int, residue_tokens: int = 0,
                        wid: Optional[int] = None) -> float:
        return self.cost.restore_time(ctx_tokens, residue_tokens) \
            * self.safety

    def predict_prefill_batch(self, wids: Sequence[Optional[int]], tokens,
                              ctx_offset=0) -> np.ndarray:
        n = len(wids)
        return self.cost.prefill_time_batch(
            _col(tokens, n), _col(ctx_offset, n)) * self.safety

    def predict_decode_iter_batch(self, wids: Sequence[Optional[int]],
                                  n_decode, sum_ctx) -> np.ndarray:
        n = len(wids)
        return self.cost.decode_iter_time_batch(
            _col(n_decode, n), _col(sum_ctx, n)) * self.safety

    def predict_interference_batch(self, wids: Sequence[Optional[int]],
                                   n_decode, sum_ctx, prefill_tokens,
                                   ctx_offset=0.0) -> np.ndarray:
        n = len(wids)
        return self.cost.interference_penalty_batch(
            _col(n_decode, n), _col(sum_ctx, n), _col(prefill_tokens, n),
            _col(ctx_offset, n)) * self.safety

    def _chunk_scales(self) -> tuple[float, float]:
        """(prefill multiplier, penalty multiplier) this predictor applies
        on top of the raw CostModel estimates — what the closed-form
        chunk inversion must fold into its coefficients."""
        return self.safety, self.safety

    def chunk_candidates(self, wids: Sequence[Optional[int]], lo: int,
                         hi: int, budget, n_decode, sum_ctx, ctx_offset,
                         s_mul=None) -> Optional[np.ndarray]:
        n = len(wids)
        S, Q = self._chunk_scales()
        s = S if s_mul is None else S * _col(s_mul, n)
        return self.cost.chunk_candidates(
            lo, hi, _col(budget, n), _col(n_decode, n), _col(sum_ctx, n),
            _col(ctx_offset, n), s, Q)


class BiasedPredictor(AnalyticalPredictor):
    """Systematically ``bias``×-miscalibrated analytical predictor — a
    stale or wrong-hardware offline profile. Robustness benchmarks and the
    OnlinePredictor convergence tests inject known error through this."""

    def __init__(self, cost: CostModel, bias: float, safety: float = 1.1):
        super().__init__(cost, safety=safety)
        self.bias = bias

    def predict_prefill(self, tokens: int, ctx_offset: int = 0,
                        wid: Optional[int] = None) -> float:
        return super().predict_prefill(tokens, ctx_offset, wid) * self.bias

    def predict_decode_iter(self, n_decode: int, sum_ctx: float,
                            wid: Optional[int] = None) -> float:
        return super().predict_decode_iter(n_decode, sum_ctx, wid) * self.bias

    def predict_prefill_batch(self, wids: Sequence[Optional[int]], tokens,
                              ctx_offset=0) -> np.ndarray:
        return super().predict_prefill_batch(wids, tokens, ctx_offset) \
            * self.bias

    def predict_decode_iter_batch(self, wids: Sequence[Optional[int]],
                                  n_decode, sum_ctx) -> np.ndarray:
        return super().predict_decode_iter_batch(wids, n_decode, sum_ctx) \
            * self.bias

    def _chunk_scales(self) -> tuple[float, float]:
        # the bias hits the additive prefill estimate only — interference
        # is not overridden and keeps the base safety margin
        return self.safety * self.bias, self.safety


class ClusterPredictor(Predictor):
    """Per-worker analytic pricing over heterogeneous hardware.

    One ``IterationCostModel`` per worker id; predictions for ``wid``
    price on that worker's spec, so a 2x-slow straggler's prefill chunk
    really predicts 2x longer. ``wid=None`` (worker-agnostic call sites:
    SLO derivation, global-queue sizing) uses the reference model — by
    convention the fastest worker's, matching the optimistic light-load
    latencies SLOs are derived from."""

    def __init__(self, costs: dict[int, IterationCostModel],
                 reference: Optional[IterationCostModel] = None,
                 safety: float = 1.1):
        if not costs:
            raise ValueError("ClusterPredictor needs at least one worker")
        self.costs = dict(costs)
        self.safety = safety
        self.reference = reference if reference is not None else min(
            self.costs.values(), key=canonical_iteration_time)

    def _cost(self, wid: Optional[int]) -> IterationCostModel:
        if wid is None:
            return self.reference
        return self.costs.get(wid, self.reference)

    def predict_prefill(self, tokens: int, ctx_offset: int = 0,
                        wid: Optional[int] = None) -> float:
        return self._cost(wid).prefill_time(tokens, ctx_offset) * self.safety

    def predict_decode_iter(self, n_decode: int, sum_ctx: float,
                            wid: Optional[int] = None) -> float:
        return self._cost(wid).decode_iter_time(n_decode, sum_ctx) \
            * self.safety

    def predict_migration(self, ctx_tokens: int,
                          wid: Optional[int] = None) -> float:
        return self._cost(wid).migration_time(ctx_tokens) * self.safety

    def predict_interference(self, n_decode: int, sum_ctx: float,
                             prefill_tokens: int, ctx_offset: float = 0.0,
                             wid: Optional[int] = None) -> float:
        # IterationCostModel does not require the penalty decomposition;
        # models without one price 0 (interference-blind), like the base
        penalty = getattr(self._cost(wid), "interference_penalty", None)
        if penalty is None:
            return 0.0
        return penalty(n_decode, sum_ctx, prefill_tokens, ctx_offset) \
            * self.safety

    def predict_restore(self, ctx_tokens: int, residue_tokens: int = 0,
                        wid: Optional[int] = None) -> float:
        # IterationCostModel does not require restore_time; tier-blind
        # models keep the base's 'never prefer offload' answer
        restore = getattr(self._cost(wid), "restore_time", None)
        if restore is None:
            return float("inf")
        return restore(ctx_tokens, residue_tokens) * self.safety

    def _groups(self, wids: Sequence[Optional[int]]):
        """(cost_model, row_indices) groups — workers sharing one CostModel
        instance (the homogeneous common case: a single group) price in one
        batched evaluation each."""
        groups: dict[int, tuple[IterationCostModel, list[int]]] = {}
        for i, w in enumerate(wids):
            c = self._cost(w)
            g = groups.get(id(c))
            if g is None:
                groups[id(c)] = (c, [i])
            else:
                g[1].append(i)
        return groups.values()

    def predict_prefill_batch(self, wids: Sequence[Optional[int]], tokens,
                              ctx_offset=0) -> np.ndarray:
        n = len(wids)
        toks, offs = _col(tokens, n), _col(ctx_offset, n)
        out = np.empty(n, dtype=np.float64)
        for cost, idxs in self._groups(wids):
            if isinstance(cost, CostModel):
                ii = np.asarray(idxs)
                out[ii] = cost.prefill_time_batch(toks[ii], offs[ii]) \
                    * self.safety
            else:
                for i in idxs:
                    out[i] = cost.prefill_time(toks[i], offs[i]) * self.safety
        return out

    def predict_decode_iter_batch(self, wids: Sequence[Optional[int]],
                                  n_decode, sum_ctx) -> np.ndarray:
        n = len(wids)
        nds, scs = _col(n_decode, n), _col(sum_ctx, n)
        out = np.empty(n, dtype=np.float64)
        for cost, idxs in self._groups(wids):
            if isinstance(cost, CostModel):
                ii = np.asarray(idxs)
                out[ii] = cost.decode_iter_time_batch(nds[ii], scs[ii]) \
                    * self.safety
            else:
                for i in idxs:
                    out[i] = cost.decode_iter_time(nds[i], scs[i]) \
                        * self.safety
        return out

    def predict_interference_batch(self, wids: Sequence[Optional[int]],
                                   n_decode, sum_ctx, prefill_tokens,
                                   ctx_offset=0.0) -> np.ndarray:
        n = len(wids)
        nds, scs = _col(n_decode, n), _col(sum_ctx, n)
        pts, offs = _col(prefill_tokens, n), _col(ctx_offset, n)
        out = np.empty(n, dtype=np.float64)
        for cost, idxs in self._groups(wids):
            if isinstance(cost, CostModel):
                ii = np.asarray(idxs)
                out[ii] = cost.interference_penalty_batch(
                    nds[ii], scs[ii], pts[ii], offs[ii]) * self.safety
            else:
                penalty = getattr(cost, "interference_penalty", None)
                for i in idxs:
                    out[i] = 0.0 if penalty is None else \
                        penalty(nds[i], scs[i], pts[i], offs[i]) * self.safety
        return out

    def chunk_candidates(self, wids: Sequence[Optional[int]], lo: int,
                         hi: int, budget, n_decode, sum_ctx, ctx_offset,
                         s_mul=None) -> Optional[np.ndarray]:
        n = len(wids)
        bud, nd = _col(budget, n), _col(n_decode, n)
        sc, off = _col(sum_ctx, n), _col(ctx_offset, n)
        mul = None if s_mul is None else _col(s_mul, n)
        got = []
        for cost, idxs in self._groups(wids):
            # any worker priced by a non-roofline model sinks the whole
            # batch to bisection: mixed closed-form/bisected rows would
            # split one arrival's pricing into several evaluations
            if not isinstance(cost, CostModel):
                return None
            ii = np.asarray(idxs)
            s = self.safety if mul is None else self.safety * mul[ii]
            got.append((ii, cost.chunk_candidates(
                lo, hi, bud[ii], nd[ii], sc[ii], off[ii], s, self.safety)))
        width = max(cand.shape[1] for _, cand in got)
        out = np.full((n, width), int(lo), dtype=np.int64)
        for ii, cand in got:
            out[ii, :cand.shape[1]] = cand
        return out


class ProfiledPredictor(Predictor):
    """Interpolates a profiled (tokens -> seconds) table; ctx contributions
    enter linearly with a profiled per-ctx-token coefficient."""

    def __init__(self, prefill_points: Sequence[tuple[int, float]],
                 decode_points: Sequence[tuple[int, float, float]],
                 ctx_coeff: float, migration_coeff: float,
                 safety: float = 1.1):
        self.prefill_points = sorted(prefill_points)
        self.decode_points = sorted(decode_points)
        self.ctx_coeff = ctx_coeff
        self.migration_coeff = migration_coeff
        self.safety = safety

    @staticmethod
    def _interp(points, x):
        xs = [p[0] for p in points]
        i = bisect.bisect_left(xs, x)
        if i == 0:
            lo, hi = points[0], points[min(1, len(points) - 1)]
        elif i >= len(points):
            lo, hi = points[-2] if len(points) > 1 else points[-1], points[-1]
        else:
            lo, hi = points[i - 1], points[i]
        if hi[0] == lo[0]:
            return lo[1]
        t = (x - lo[0]) / (hi[0] - lo[0])
        return lo[1] + t * (hi[1] - lo[1])

    def predict_prefill(self, tokens: int, ctx_offset: int = 0,
                        wid: Optional[int] = None) -> float:
        base = self._interp(self.prefill_points, tokens)
        return (base + self.ctx_coeff * ctx_offset * tokens) * self.safety

    def predict_decode_iter(self, n_decode: int, sum_ctx: float,
                            wid: Optional[int] = None) -> float:
        base = self._interp([(b, t) for b, t, _ in self.decode_points], n_decode)
        return (base + self.ctx_coeff * sum_ctx) * self.safety

    def predict_migration(self, ctx_tokens: int,
                          wid: Optional[int] = None) -> float:
        return self.migration_coeff * ctx_tokens * self.safety


def profile_worker(step_fn: Callable[[int, float, int], float],
                   token_grid: Sequence[int] = (128, 512, 2048, 8192),
                   batch_grid: Sequence[int] = (1, 8, 32, 128),
                   ctx_probe: int = 8192) -> ProfiledPredictor:
    """Build a ProfiledPredictor by measuring ``step_fn(n_decode, sum_ctx,
    prefill_tokens) -> seconds`` — works against the real executor or the
    simulator alike (offline profiling per §IV-C)."""
    prefill_points = [(t, step_fn(0, 0.0, t)) for t in token_grid]
    decode_points = [(b, step_fn(b, float(b * 512), 0), 512.0)
                     for b in batch_grid]
    t0 = step_fn(1, 0.0, 0)
    t1 = step_fn(1, float(ctx_probe), 0)
    ctx_coeff = max(0.0, (t1 - t0) / ctx_probe)
    return ProfiledPredictor(prefill_points, decode_points, ctx_coeff,
                             migration_coeff=1e-9)
