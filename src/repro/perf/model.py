"""Analytical worker step-time model — the one iteration-cost oracle.

Used by (a) the SimExecutor / ``CostModelBackend`` as the simulation
clock, (b) the scheduler's execution-time predictor (§IV-C: "we leverage
offline profiling tools to estimate the execution time of a prefill
request"), and (c) the toggle's admission maths. Before this package the
same quantity was computed three different ways in three layers; every
consumer now shares the ``IterationCostModel`` interface.

The model is a two-term roofline per iteration:

    t = max(FLOPs / (chips·peak·mfu),  bytes / (chips·bw·eff)) + t_fixed

with per-family FLOP/byte accounting (dense / MoE active params / rwkv &
mamba constant-state / enc-dec), plus an optional §IV **interference
term** for mixed batches: co-batched prefill chunks contend with decode's
memory streaming, so the mixed iteration exceeds the combined roofline by

    γ · β_p · β_d · min(t_prefill_alone, t_decode_alone)

where β_p is the prefill side's compute-boundedness, β_d the decode
side's memory-boundedness and γ the calibrated contention coefficient —
``HardwareSpec.interference`` as a uniform scalar, or an
``InterferenceTable`` looked up by the iteration's actual
``(n_decode, prefill_tokens)`` bucket (``perf.calibrate`` measures the
grid from mixed-vs-pure kernel runs; ``perf.recalibrate`` re-fits it
online). Contention is worst when each phase
saturates a *different* resource (overlap beyond the max is impossible and
the iteration drifts toward the additive sum); when both phases are bound
on the same resource the combined roofline already charges the serialised
cost and the penalty vanishes with 1-β. γ = 0 reproduces the legacy
purely-additive model bit-exactly — the default, so every pre-existing
benchmark and decision-parity test is unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Protocol, runtime_checkable

import numpy as np

from repro.models.layers import ModelConfig
from repro.perf.hardware import (V5E, HardwareSpec, InterferenceTable,
                                 WorkerSpec, gamma_at, gamma_at_batch)

# One constant-state request (rwkv/mamba/hybrid) is granted this many
# token-equivalents of HBM budget: ``kv_capacity_tokens`` sizes the pool
# as (#states that fit) × this, and ``state_tokens`` pins the same amount
# per admitted request, so page accounting gates at exactly the number of
# states the free HBM holds. One unit therefore equals
# ``state_bytes / STATE_TOKEN_EQUIV`` bytes.
STATE_TOKEN_EQUIV = 10_000


@runtime_checkable
class IterationCostModel(Protocol):
    """What every layer consuming step-time estimates depends on: the
    simulator clock, the §IV-C predictors, toggle admission, decode
    routing, and KV migration pricing all speak this interface."""

    def iteration_time(self, n_decode: int, sum_ctx: float,
                       prefill_tokens: int = 0,
                       prefill_ctx_offset: float = 0.0) -> float: ...

    def prefill_time(self, prompt_tokens: int, ctx_offset: int = 0) -> float: ...

    def decode_iter_time(self, n_decode: int, sum_ctx: float) -> float: ...

    def migration_time(self, ctx_tokens: int) -> float: ...

    def kv_transfer_bytes(self, ctx_tokens: int) -> float: ...


@dataclasses.dataclass(frozen=True)
class ModelCostSpec:
    """Closed-form per-token cost coefficients for one architecture."""
    name: str
    n_params: float                 # total parameters
    n_active: float                 # matmul-active params per token
    kv_bytes_per_token: float       # bytes of KV/state written per token
    attn_flops_per_ctx_token: float  # 4·Hq·Dh summed over ctx-attending layers
    ctx_cap: Optional[int]          # sliding-window cap (gemma2 local layers)
    state_bytes: float              # constant per-request state (rwkv/mamba)
    bytes_per_weight: float = 2.0   # bf16


def _transformer_attn_params(cfg: ModelConfig) -> float:
    p = (cfg.d_model * cfg.num_heads * cfg.head_dim          # wq
         + 2 * cfg.d_model * cfg.num_kv_heads * cfg.head_dim  # wk, wv
         + cfg.num_heads * cfg.head_dim * cfg.d_model)        # wo
    if cfg.qkv_bias:
        p += (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.head_dim
    return p


def build_cost_spec(cfg: ModelConfig) -> ModelCostSpec:
    d, f, L, v = cfg.d_model, cfg.d_ff, cfg.num_layers, cfg.vocab_size
    embed = v * d * (1 if cfg.tie_embeddings else 2)
    mlp = (3 if cfg.mlp_gated else 2) * d * f

    if cfg.family in ("dense", "vlm"):
        per_layer = _transformer_attn_params(cfg) + mlp
        total = embed + L * per_layer
        active = L * per_layer + v * d      # unembed matmul counts as active
        kv = 2 * L * cfg.num_kv_heads * cfg.head_dim * 2.0
        attn_c = 4.0 * cfg.num_heads * cfg.head_dim * L
        ctx_cap = cfg.sliding_window if cfg.local_global_alternating else None
        state = 0.0
    elif cfg.family == "moe":
        experts = cfg.num_experts * 3 * d * f
        shared = cfg.num_shared_experts * 3 * d * f
        dense_res = (3 * d * cfg.moe_dense_residual_ff
                     if cfg.moe_dense_residual_ff else 0)
        router = d * cfg.num_experts
        per_layer = _transformer_attn_params(cfg) + experts + shared \
            + dense_res + router
        per_layer_active = _transformer_attn_params(cfg) \
            + cfg.top_k * 3 * d * f + shared + dense_res + router
        total = embed + L * per_layer
        active = L * per_layer_active + v * d
        kv = 2 * L * cfg.num_kv_heads * cfg.head_dim * 2.0
        attn_c = 4.0 * cfg.num_heads * cfg.head_dim * L
        ctx_cap, state = None, 0.0
    elif cfg.family == "rwkv":
        # tm: 5 square proj + lora; cm: 2 d·f + d·d
        per_layer = 5 * d * d + d * (5 * 32) + d * 64 + 64 * d \
            + 2 * d * f + d * d
        total = embed + L * per_layer
        active = L * per_layer + v * d
        kv = 0.0
        attn_c = 0.0
        ctx_cap = None
        state = L * (d / 64) * 64 * 64 * 4.0 + 2 * L * d * 2.0  # wkv f32
    elif cfg.family == "hybrid":
        d_inner = cfg.ssm_expand * d
        n_heads = d_inner // 64
        mamba = 2 * d * d_inner + 2 * d * cfg.ssm_state + d * n_heads \
            + d_inner * d
        shared = _transformer_attn_params(cfg) + mlp + 2 * d * d + d * d
        ninv = (L + cfg.attn_every - 1) // cfg.attn_every
        total = embed + L * mamba + shared
        active = L * mamba + ninv * shared + v * d
        kv = 2 * ninv * cfg.num_kv_heads * cfg.head_dim * 2.0
        attn_c = 4.0 * cfg.num_heads * cfg.head_dim * ninv
        ctx_cap = None
        state = L * (n_heads * 64 * cfg.ssm_state * 4.0
                     + (cfg.ssm_conv - 1) * (d_inner + 2 * cfg.ssm_state) * 2.0)
    elif cfg.family == "encdec":
        n_enc = cfg.encoder_layers or L
        enc_layer = _transformer_attn_params(cfg) + mlp
        dec_layer = 2 * _transformer_attn_params(cfg) + mlp
        total = embed + n_enc * enc_layer + L * dec_layer
        active = L * dec_layer + v * d          # decode-side active
        kv = 2 * L * cfg.num_kv_heads * cfg.head_dim * 2.0
        attn_c = 4.0 * cfg.num_heads * cfg.head_dim * L * 2  # self + cross
        ctx_cap = None
        state = 0.0
    else:
        raise ValueError(cfg.family)

    return ModelCostSpec(
        name=cfg.name, n_params=float(total), n_active=float(active),
        kv_bytes_per_token=float(kv), attn_flops_per_ctx_token=float(attn_c),
        ctx_cap=ctx_cap, state_bytes=float(state),
    )


class CostModel:
    """Iteration-time + capacity model for one (model, worker) pair.

    Implements ``IterationCostModel``. Heterogeneous clusters instantiate
    one per worker (each with its own ``WorkerSpec``/``HardwareSpec``); a
    homogeneous cluster may share a single instance across workers."""

    def __init__(self, cfg: ModelConfig, worker: WorkerSpec = WorkerSpec(),
                 page_size: int = 16):
        self.cfg = cfg
        self.spec = build_cost_spec(cfg)
        self.worker = worker
        self.page_size = page_size          # KV block granularity (tokens)
        self.params_bytes = self.spec.n_params * self.spec.bytes_per_weight
        # opt-in iteration-time memo (build_cluster(vectorized=True) arms
        # it): the scheduler re-prices identical (n, ctx, chunk) shapes many
        # times per event. Keyed on args; invalidated when ``self.worker``
        # is replaced (DriftMonitor recalibration swaps the WorkerSpec).
        self.cached = False
        self._memo: dict = {}
        self._memo_worker: Optional[WorkerSpec] = None

    # ------------------------------------------------------------ capacity
    def kv_capacity_pages(self, reserve_frac: float = 0.1) -> int:
        """Allocatable KV pages per worker (page = ``page_size`` tokens)."""
        return max(1, self.kv_capacity_tokens(reserve_frac) // self.page_size)

    def kv_capacity_tokens(self, reserve_frac: float = 0.1) -> int:
        free = self.worker.hbm_bytes * (1 - reserve_frac) - self.params_bytes
        if self.spec.kv_bytes_per_token <= 0:
            # constant-state family: capacity = #states that fit
            per = max(self.spec.state_bytes, 1.0)
            return int(free / per) * STATE_TOKEN_EQUIV
        return max(0, int(free / self.spec.kv_bytes_per_token))

    def state_tokens(self, ctx: int) -> float:
        """HBM tokens-equivalent held by a request with context ctx.

        Constant-state families (rwkv/mamba/hybrid) hold one fixed-size
        state regardless of context; it pins ``STATE_TOKEN_EQUIV`` units —
        the per-state grant ``kv_capacity_tokens`` sizes the pool in — so
        the ``PageAccountant`` sees the true footprint and admission /
        watermark preemption gate at exactly the number of states the HBM
        fits. (A prior ternary returned 0.0 here, which made every
        constant-state request invisible to page accounting: admission
        never gated and the watermark never preempted.)"""
        if self.spec.kv_bytes_per_token <= 0:
            return float(STATE_TOKEN_EQUIV) if self.spec.state_bytes > 0 \
                else 0.0
        cap = self.spec.ctx_cap
        if cap is not None:
            # gemma2: half the layers hold only window-sized KV
            return ctx * 0.5 + min(ctx, cap) * 0.5
        return float(ctx)

    def state_token_delta_sum(self, ctx_new) -> float:
        """Exact sum of ``state_tokens(c) - state_tokens(c - 1)`` over an
        int64 array of post-step contexts — the engine's batched KV-growth
        charge for one decode token per request. Every per-element delta
        is 0.0 (constant-state), 1.0 (dense KV), or 0.5 (past a sliding
        window's cap): dyadic values whose float64 accumulation is exact
        at any magnitude this simulator reaches, so the batched sum lands
        on the same bits as the scalar per-request loop regardless of
        association order."""
        spec = self.spec
        if spec.kv_bytes_per_token <= 0:
            return 0.0
        cap = spec.ctx_cap
        if cap is None:
            return float(ctx_new.size)
        inside = int(np.count_nonzero(ctx_new <= cap))
        return inside * 1.0 + (ctx_new.size - inside) * 0.5

    # --------------------------------------------------------------- steps
    def _roofline(self, flops: float, bytes_: float, mfu: float) -> float:
        hw = self.worker.hw
        t_c = flops / (self.worker.peak_flops * mfu)
        t_m = bytes_ / (self.worker.hbm_bw * hw.bw_eff)
        return max(t_c, t_m) + hw.t_fixed

    def _attn_ctx(self, ctx: float) -> float:
        cap = self.spec.ctx_cap
        if cap is None:
            return ctx
        return 0.5 * ctx + 0.5 * min(ctx, cap)

    def _decode_terms(self, n_decode: int, sum_ctx: float
                      ) -> tuple[float, float, float, float]:
        """Decode-side accounting terms, kept individual so both the
        combined iteration roofline and the interference penalty sum them
        in their own (bit-pinned) order from one source of truth:
        (gemm_flops, attn_flops, kv_bytes, state_bytes)."""
        s = self.spec
        return (2.0 * s.n_active * n_decode,
                s.attn_flops_per_ctx_token * self._attn_ctx(sum_ctx),
                s.kv_bytes_per_token * self._attn_ctx(sum_ctx),
                s.state_bytes * n_decode * 2)   # rwkv/mamba state rw

    def _prefill_terms(self, prefill_tokens: int, ctx_offset: float
                       ) -> tuple[float, float, float]:
        """Prefill-chunk accounting terms: (gemm_flops, attn_flops,
        kv_bytes)."""
        s = self.spec
        p, c = float(prefill_tokens), float(ctx_offset)
        return (2.0 * s.n_active * p,
                s.attn_flops_per_ctx_token * self._attn_ctx(c + p / 2) * p,
                s.kv_bytes_per_token * (self._attn_ctx(c + p) + p))

    def iteration_time(self, n_decode: int, sum_ctx: float,
                       prefill_tokens: int = 0,
                       prefill_ctx_offset: float = 0.0) -> float:
        """One engine iteration: a decode batch (n_decode requests whose
        contexts sum to sum_ctx) plus an optional piggybacked prefill chunk
        of ``prefill_tokens`` starting at context ``prefill_ctx_offset``."""
        if not self.cached:
            return self._iteration_time(n_decode, sum_ctx, prefill_tokens,
                                        prefill_ctx_offset)
        if self._memo_worker is not self.worker:
            self._memo_worker = self.worker
            self._memo.clear()
        key = (n_decode, sum_ctx, prefill_tokens, prefill_ctx_offset)
        t = self._memo.get(key)
        if t is None:
            if len(self._memo) >= 4096:
                self._memo.clear()
            t = self._iteration_time(n_decode, sum_ctx, prefill_tokens,
                                     prefill_ctx_offset)
            self._memo[key] = t
        return t

    def _iteration_time(self, n_decode: int, sum_ctx: float,
                        prefill_tokens: int = 0,
                        prefill_ctx_offset: float = 0.0) -> float:
        flops = 0.0
        bytes_ = 0.0
        if n_decode > 0:
            df_gemm, df_attn, db_kv, db_state = \
                self._decode_terms(n_decode, sum_ctx)
            flops += df_gemm
            flops += df_attn
            bytes_ += db_kv
            bytes_ += db_state
        if prefill_tokens > 0:
            pf_gemm, pf_attn, pb_kv = \
                self._prefill_terms(prefill_tokens, prefill_ctx_offset)
            flops += pf_gemm
            flops += pf_attn
            bytes_ += pb_kv
        if flops == 0.0 and bytes_ == 0.0:
            return 0.0
        bytes_ += self.params_bytes  # weights stream once per iteration
        mfu = (self.worker.hw.mfu_prefill if prefill_tokens > 0
               else self.worker.hw.mfu_decode)
        t = self._roofline(flops, bytes_, mfu)
        if n_decode > 0 and prefill_tokens > 0:
            gamma = gamma_at(self.worker.hw.interference, n_decode,
                             prefill_tokens)
            if gamma != 0.0:
                t += self._interference(gamma, n_decode, sum_ctx,
                                        prefill_tokens, prefill_ctx_offset)
        return t

    def interference_penalty(self, n_decode: int, sum_ctx: float,
                             prefill_tokens: int,
                             prefill_ctx_offset: float = 0.0) -> float:
        """The §IV contention penalty alone — what a mixed iteration costs
        beyond the additive combined roofline. Exactly 0.0 for pure
        batches and whenever the governing γ is 0, so admission paths that
        *add* it to their additive estimates stay bit-identical to the
        legacy model until a calibration turns γ on."""
        if n_decode <= 0 or prefill_tokens <= 0:
            return 0.0
        gamma = gamma_at(self.worker.hw.interference, n_decode,
                         prefill_tokens)
        if gamma == 0.0:
            return 0.0
        return self._interference(gamma, n_decode, sum_ctx,
                                  prefill_tokens, prefill_ctx_offset)

    def _interference(self, gamma: float, n_decode: int, sum_ctx: float,
                      prefill_tokens: int, prefill_ctx_offset: float) -> float:
        """§IV contention penalty for a mixed prefill+decode batch.

        Phase-alone roofline terms (no ``t_fixed``; each phase streams the
        weights once when run alone):

            β_p = prefill compute-boundedness = t_cᵖ / max(t_cᵖ, t_mᵖ)
            β_d = decode  memory-boundedness  = t_mᵈ / max(t_cᵈ, t_mᵈ)

        penalty = γ · β_p · β_d · min(t_prefill_alone, t_decode_alone):
        zero whenever either phase is absent, largest when a compute-bound
        prefill is inserted into a memory-bound decode batch (the paper's
        observed super-additive slowdown; DistServe §3 measures the same
        asymmetry), bounded by the smaller phase's standalone time so the
        mixed iteration never exceeds the fully-serialised sum."""
        hw = self.worker.hw
        comp = self.worker.peak_flops
        mem = self.worker.hbm_bw * hw.bw_eff

        df_gemm, df_attn, db_kv, db_state = \
            self._decode_terms(n_decode, sum_ctx)
        d_flops = df_gemm + df_attn
        d_bytes = db_kv + db_state + self.params_bytes
        pf_gemm, pf_attn, pb_kv = \
            self._prefill_terms(prefill_tokens, prefill_ctx_offset)
        p_flops = pf_gemm + pf_attn
        p_bytes = pb_kv + self.params_bytes

        t_cp = p_flops / (comp * hw.mfu_prefill)
        t_mp = p_bytes / mem
        t_cd = d_flops / (comp * hw.mfu_decode)
        t_md = d_bytes / mem
        t_p = max(t_cp, t_mp)
        t_d = max(t_cd, t_md)
        if t_p <= 0.0 or t_d <= 0.0:
            return 0.0
        beta_p = t_cp / t_p
        beta_d = t_md / t_d
        return gamma * beta_p * beta_d * min(t_p, t_d)

    def prefill_time(self, prompt_tokens: int, ctx_offset: int = 0) -> float:
        return self.iteration_time(0, 0.0, prompt_tokens, ctx_offset)

    def decode_iter_time(self, n_decode: int, sum_ctx: float) -> float:
        return self.iteration_time(n_decode, sum_ctx)

    # ------------------------------------------------- batched entry points
    # One candidate priced against many workers (or many candidates against
    # one worker) in a single numpy evaluation. Every elementwise operation
    # mirrors the scalar path's exact association order, masked terms enter
    # through ``np.where(mask, term, 0.0)`` and ``x + 0.0`` is exact in
    # IEEE-754, so each element is bit-identical to the scalar call —
    # tests/test_vectorized.py pins that.

    def _attn_ctx_batch(self, ctx: np.ndarray) -> np.ndarray:
        cap = self.spec.ctx_cap
        if cap is None:
            return ctx
        return 0.5 * ctx + 0.5 * np.minimum(ctx, float(cap))

    def _batch_terms(self, n, sc, p, c):
        """Unmasked decode/prefill accounting terms, elementwise mirrors of
        ``_decode_terms``/``_prefill_terms``."""
        s = self.spec
        df_gemm = 2.0 * s.n_active * n
        df_attn = s.attn_flops_per_ctx_token * self._attn_ctx_batch(sc)
        db_kv = s.kv_bytes_per_token * self._attn_ctx_batch(sc)
        db_state = s.state_bytes * n * 2
        pf_gemm = 2.0 * s.n_active * p
        pf_attn = s.attn_flops_per_ctx_token \
            * self._attn_ctx_batch(c + p / 2) * p
        pb_kv = s.kv_bytes_per_token * (self._attn_ctx_batch(c + p) + p)
        return df_gemm, df_attn, db_kv, db_state, pf_gemm, pf_attn, pb_kv

    def _interference_batch(self, gamma: np.ndarray, terms) -> np.ndarray:
        hw = self.worker.hw
        comp = self.worker.peak_flops
        mem = self.worker.hbm_bw * hw.bw_eff
        df_gemm, df_attn, db_kv, db_state, pf_gemm, pf_attn, pb_kv = terms
        d_flops = df_gemm + df_attn
        d_bytes = db_kv + db_state + self.params_bytes
        p_flops = pf_gemm + pf_attn
        p_bytes = pb_kv + self.params_bytes
        t_cp = p_flops / (comp * hw.mfu_prefill)
        t_mp = p_bytes / mem
        t_cd = d_flops / (comp * hw.mfu_decode)
        t_md = d_bytes / mem
        t_p = np.maximum(t_cp, t_mp)
        t_d = np.maximum(t_cd, t_md)
        live = (t_p > 0.0) & (t_d > 0.0)
        beta_p = t_cp / np.where(live, t_p, 1.0)
        beta_d = t_md / np.where(live, t_d, 1.0)
        pen = gamma * beta_p * beta_d * np.minimum(t_p, t_d)
        return np.where(live, pen, 0.0)

    def _prefill_only_batch(self, prefill_tokens, prefill_ctx_offset  # lint: parity-ref(_iteration_time)
                            ) -> np.ndarray:
        """``iteration_time_batch`` lane for pure prefill rows (scalar
        n_decode == 0): only the prefill terms are evaluated. Bit-identical
        to the general path — its masked sums associate as
        ``((0.0+0.0)+a)+b`` and IEEE-754 ``0.0+x == x``."""
        p = np.asarray(prefill_tokens, dtype=np.float64)
        c = np.asarray(prefill_ctx_offset, dtype=np.float64)
        p, c = np.broadcast_arrays(p, c)
        s = self.spec
        hw = self.worker.hw
        pf_gemm = 2.0 * s.n_active * p
        pf_attn = s.attn_flops_per_ctx_token \
            * self._attn_ctx_batch(c + p / 2) * p
        pb_kv = s.kv_bytes_per_token * (self._attn_ctx_batch(c + p) + p)
        has_p = p > 0
        flops = np.where(has_p, pf_gemm, 0.0) + np.where(has_p, pf_attn, 0.0)
        bytes_ = np.where(has_p, pb_kv, 0.0)
        zero = (flops == 0.0) & (bytes_ == 0.0)
        bytes_ = bytes_ + self.params_bytes
        mfu = np.where(has_p, hw.mfu_prefill, hw.mfu_decode)
        t_c = flops / (self.worker.peak_flops * mfu)
        t_m = bytes_ / (self.worker.hbm_bw * hw.bw_eff)
        t = np.maximum(t_c, t_m) + hw.t_fixed
        return np.where(zero, 0.0, t)

    def _decode_only_batch(self, n_decode, sum_ctx) -> np.ndarray:  # lint: parity-ref(_iteration_time)
        """``iteration_time_batch`` lane for pure decode rows (scalar
        prefill_tokens == 0): only the decode terms are evaluated. The
        general path's masked sums associate as ``((a+b)+0.0)+0.0`` and its
        mfu select resolves to the scalar ``mfu_decode``, so this is
        bit-identical."""
        n = np.asarray(n_decode, dtype=np.float64)
        sc = np.asarray(sum_ctx, dtype=np.float64)
        n, sc = np.broadcast_arrays(n, sc)
        s = self.spec
        hw = self.worker.hw
        df_gemm = 2.0 * s.n_active * n
        df_attn = s.attn_flops_per_ctx_token * self._attn_ctx_batch(sc)
        db_kv = s.kv_bytes_per_token * self._attn_ctx_batch(sc)
        db_state = s.state_bytes * n * 2
        has_d = n > 0
        flops = np.where(has_d, df_gemm, 0.0) + np.where(has_d, df_attn, 0.0)
        bytes_ = np.where(has_d, db_kv, 0.0) + np.where(has_d, db_state, 0.0)
        zero = (flops == 0.0) & (bytes_ == 0.0)
        bytes_ = bytes_ + self.params_bytes
        t_c = flops / (self.worker.peak_flops * hw.mfu_decode)
        t_m = bytes_ / (self.worker.hbm_bw * hw.bw_eff)
        t = np.maximum(t_c, t_m) + hw.t_fixed
        return np.where(zero, 0.0, t)

    def iteration_time_batch(self, n_decode, sum_ctx, prefill_tokens=0,
                             prefill_ctx_offset=0.0) -> np.ndarray:
        """Elementwise ``iteration_time`` over broadcast scalar-or-array
        arguments; returns float64 with the broadcast shape."""
        # Uniform-phase fast lanes: dispatch prices pure prefill chunks and
        # pure decode batches far more often than mixed iterations, and a
        # scalar 0 for the absent phase proves every row skips it — so only
        # the present phase's terms are evaluated. (sum_ctx is ignored when
        # n_decode == 0, exactly as the general path masks it out.)
        if isinstance(n_decode, (int, float)) and n_decode == 0:
            return self._prefill_only_batch(prefill_tokens,
                                            prefill_ctx_offset)
        if isinstance(prefill_tokens, (int, float)) and prefill_tokens == 0:
            return self._decode_only_batch(n_decode, sum_ctx)
        n = np.asarray(n_decode, dtype=np.float64)
        sc = np.asarray(sum_ctx, dtype=np.float64)
        p = np.asarray(prefill_tokens, dtype=np.float64)
        c = np.asarray(prefill_ctx_offset, dtype=np.float64)
        n, sc, p, c = np.broadcast_arrays(n, sc, p, c)
        hw = self.worker.hw
        terms = self._batch_terms(n, sc, p, c)
        df_gemm, df_attn, db_kv, db_state, pf_gemm, pf_attn, pb_kv = terms
        has_d = n > 0
        has_p = p > 0
        flops = np.where(has_d, df_gemm, 0.0) \
            + np.where(has_d, df_attn, 0.0) \
            + np.where(has_p, pf_gemm, 0.0) \
            + np.where(has_p, pf_attn, 0.0)
        bytes_ = np.where(has_d, db_kv, 0.0) \
            + np.where(has_d, db_state, 0.0) \
            + np.where(has_p, pb_kv, 0.0)
        zero = (flops == 0.0) & (bytes_ == 0.0)
        bytes_ = bytes_ + self.params_bytes
        mfu = np.where(has_p, hw.mfu_prefill, hw.mfu_decode)
        t_c = flops / (self.worker.peak_flops * mfu)
        t_m = bytes_ / (self.worker.hbm_bw * hw.bw_eff)
        t = np.maximum(t_c, t_m) + hw.t_fixed
        mixed = has_d & has_p
        if np.any(mixed):
            gamma = gamma_at_batch(hw.interference, n, p)
            if gamma.any():     # all-zero gamma adds exact 0.0 everywhere
                pen = self._interference_batch(gamma, terms)
                t = t + np.where(mixed & (gamma != 0.0), pen, 0.0)
        return np.where(zero, 0.0, t)

    def interference_penalty_batch(self, n_decode, sum_ctx, prefill_tokens,
                                   prefill_ctx_offset=0.0) -> np.ndarray:
        """Elementwise ``interference_penalty`` over broadcast args."""
        n = np.asarray(n_decode, dtype=np.float64)
        sc = np.asarray(sum_ctx, dtype=np.float64)
        p = np.asarray(prefill_tokens, dtype=np.float64)
        c = np.asarray(prefill_ctx_offset, dtype=np.float64)
        n, sc, p, c = np.broadcast_arrays(n, sc, p, c)
        mixed = (n > 0) & (p > 0)
        if not np.any(mixed):
            return np.zeros(n.shape)
        gamma = gamma_at_batch(self.worker.hw.interference, n, p)
        if not gamma.any():     # γ=0 table: the masked result is all 0.0
            return np.zeros(n.shape)
        pen = self._interference_batch(gamma, self._batch_terms(n, sc, p, c))
        return np.where(mixed & (gamma != 0.0), pen, 0.0)

    def prefill_time_batch(self, prompt_tokens, ctx_offset=0) -> np.ndarray:
        return self.iteration_time_batch(0, 0.0, prompt_tokens, ctx_offset)

    def decode_iter_time_batch(self, n_decode, sum_ctx) -> np.ndarray:
        return self.iteration_time_batch(n_decode, sum_ctx)

    # ------------------------------------------- slack-chunk inversion
    def chunk_candidates(self, lo: int, hi: int, budget, n_decode, sum_ctx,
                         ctx_offset, s_scale=1.0, q_scale=1.0) -> np.ndarray:
        """Closed-form support for slack-sized prefill chunking: candidate
        chunk sizes containing every integer where the admission cost

            S·prefill_time(p, c) + Q·interference_penalty(n, sc, p, c)

        can cross ``budget`` on ``[lo, hi]``. The cost is piecewise
        quadratic in p: ``t_cp = a2·p² + a1·p`` (compute roofline),
        ``t_mp = m1·p + m0`` (memory roofline incl. weights), and the §IV
        penalty collapses per region — ``P·t_cp`` while the prefill-alone
        time is the iteration minimum, ``P·t_d`` (constant) or
        ``P·t_d·t_cp/t_mp`` (quadratic after clearing the linear
        denominator) once it dominates — with P = Q·γ·β_d piecewise
        constant over the γ table's chunk buckets. So every feasibility
        flip sits at a quadratic root or at a structural breakpoint (γ
        bucket edge, sliding-window cap crossing), all solved here in
        closed form. Callers verify the candidates with ONE batched cost
        evaluation and keep the largest feasible — replacing the lockstep
        bisection loop (~12 batched evaluations) while returning the same
        chunk wherever the cost is monotone in p (everywhere the model's
        increasing rooflines make it so).

        ``budget``/``n_decode``/``sum_ctx``/``ctx_offset``/``s_scale``
        broadcast per row; ``q_scale`` is scalar. Returns (rows, K) int64
        clipped to [lo, hi]; ``lo`` and ``hi`` are always included."""
        bud0 = np.atleast_1d(np.asarray(budget, dtype=np.float64))
        nd = np.asarray(n_decode, dtype=np.float64)
        sc = np.asarray(sum_ctx, dtype=np.float64)
        c = np.asarray(ctx_offset, dtype=np.float64)
        S = np.asarray(s_scale, dtype=np.float64)
        bud0, nd, sc, c, S = np.broadcast_arrays(bud0, nd, sc, c, S)
        Q = float(q_scale)
        s_ = self.spec
        hw = self.worker.hw
        comp = self.worker.peak_flops
        mem = self.worker.hbm_bw * hw.bw_eff
        F = comp * hw.mfu_prefill
        # decode-alone constants (mirroring _interference): t_d and the
        # memory-boundedness β_d do not depend on the chunk size
        df_gemm = 2.0 * s_.n_active * nd
        df_attn = s_.attn_flops_per_ctx_token * self._attn_ctx_batch(sc)
        db_kv = s_.kv_bytes_per_token * self._attn_ctx_batch(sc)
        db_state = s_.state_bytes * nd * 2
        t_cd = (df_gemm + df_attn) / (comp * hw.mfu_decode)
        t_md = (db_kv + db_state + self.params_bytes) / mem
        t_d = np.maximum(t_cd, t_md)
        live = (nd > 0) & (t_d > 0.0)
        beta_d = np.where(live, t_md / np.where(t_d > 0.0, t_d, 1.0), 0.0)
        t_d = np.where(live, t_d, 0.0)
        # γ is piecewise constant over the table's chunk buckets: one
        # penalty coefficient P per (row, chunk-cell)
        interf = hw.interference
        if isinstance(interf, InterferenceTable):
            de = np.asarray(interf.decode_edges, dtype=np.float64)
            row = np.maximum(np.searchsorted(de, nd, side="right") - 1, 0)
            gam = np.asarray(interf.gamma, dtype=np.float64)[row]
            edges = [float(e) for e in interf.chunk_edges]
        else:
            gam = np.full(nd.shape + (1,), float(interf))
            edges = []
        pen = Q * gam * beta_d[..., None]
        # prefill rooflines per sliding-window regime:
        #   t_cp = a2·p² + a1·p,  t_mp = m1·p + m0
        attn = s_.attn_flops_per_ctx_token
        kv = s_.kv_bytes_per_token
        gemm = 2.0 * s_.n_active
        cap = s_.ctx_cap
        a_regimes = [(attn / 2.0 / F, (gemm + attn * c) / F)]
        m_regimes = [(2.0 * kv / mem, (kv * c + self.params_bytes) / mem)]
        if cap is not None:
            a_regimes.append((attn / 4.0 / F,
                              (gemm + attn * (c + cap) / 2.0) / F))
            m_regimes.append((1.5 * kv / mem,
                              (kv * (c + cap) / 2.0 + self.params_bytes)
                              / mem))
        bud = bud0 - S * hw.t_fixed
        roots = []
        with np.errstate(divide="ignore", invalid="ignore"):
            for a2, a1 in a_regimes:
                for j in range(pen.shape[-1]):
                    P = pen[..., j]
                    sp = S + P
                    # compute-bound, penalty tracks t_cp: (S+P)·t_cp = bud
                    roots.append(_quad_roots(
                        a2, a1, -bud / np.where(sp > 0.0, sp, np.nan)))
                    # compute-bound past t_d: S·t_cp + P·t_d = bud
                    roots.append(_quad_roots(
                        a2, a1,
                        -(bud - P * t_d) / np.where(S > 0.0, S, np.nan)))
                # region boundary t_cp = t_d
                roots.append(_quad_roots(a2, a1, -t_d))
                for m1, m0 in m_regimes:
                    # region boundary t_cp = t_mp
                    roots.append(_quad_roots(a2, a1 - m1, -m0))
                    for j in range(pen.shape[-1]):
                        P = pen[..., j]
                        # memory-bound, penalty P·t_cp: S·t_mp + P·t_cp
                        roots.append(_quad_roots(
                            P * a2, S * m1 + P * a1, S * m0 - bud))
                        # memory-bound past t_d: S·t_mp² + P·t_d·t_cp
                        # − bud·t_mp = 0 (×t_mp clears the denominator)
                        roots.append(_quad_roots(
                            S * m1 * m1 + P * t_d * a2,
                            2.0 * S * m1 * m0 + P * t_d * a1 - bud * m1,
                            S * m0 * m0 - bud * m0))
            for m1, m0 in m_regimes:
                # region boundary t_mp = t_d (linear)
                r = (t_d - m0) / (m1 if m1 != 0.0 else np.nan)
                roots.append(np.stack([r, np.full_like(r, np.nan)],
                                      axis=-1))
        fl = np.floor(np.concatenate(roots, axis=-1))
        cols = [fl - 1.0, fl, fl + 1.0, fl + 2.0]
        # structural breakpoints: interval ends, γ bucket edges, and the
        # per-row sliding-window crossings (KV at cap−c, attention midpoint
        # at 2(cap−c))
        fixed = [float(lo), float(hi)]
        for e in edges:
            fixed += [e - 1.0, e, e + 1.0]
        cols.append(np.broadcast_to(np.asarray(fixed),
                                    nd.shape + (len(fixed),)))
        if cap is not None:
            for bp in (cap - c, 2.0 * (cap - c)):
                f = np.floor(bp)[..., None]
                cols.append(np.concatenate([f - 1.0, f, f + 1.0, f + 2.0],
                                           axis=-1))
        cand = np.concatenate(cols, axis=-1)
        cand = np.where(np.isfinite(cand), cand, float(lo))
        return np.clip(cand, float(lo), float(hi)).astype(np.int64)

    # ----------------------------------------------------------- migration
    def kv_transfer_bytes(self, ctx_tokens: int) -> float:
        """Bytes of KV/state that must cross the ICI links to migrate a
        request with context ``ctx_tokens``."""
        return self.spec.kv_bytes_per_token * self.state_tokens(ctx_tokens) \
            + self.spec.state_bytes

    def migration_time(self, ctx_tokens: int) -> float:
        """Uncontended lower bound (the seed's fixed-delay model); the
        contended path lives in serving/transfer.py."""
        hw = self.worker.hw
        bw = hw.ici_bw * hw.ici_links
        return hw.migration_latency + self.kv_transfer_bytes(ctx_tokens) / bw

    # ---------------------------------------------------------- tiered KV
    def host_capacity_pages(self, host_bytes: float) -> int:
        """Pages of KV a ``host_bytes``-sized host-DRAM tier holds for this
        model (same page arithmetic as the HBM pool; constant-state
        families count states via their token-equivalent grant)."""
        if host_bytes <= 0:
            return 0
        if self.spec.kv_bytes_per_token <= 0:
            per = max(self.spec.state_bytes, 1.0)
            tokens = int(host_bytes / per) * STATE_TOKEN_EQUIV
        else:
            tokens = int(host_bytes / self.spec.kv_bytes_per_token)
        return max(0, tokens // self.page_size)

    def restore_time(self, ctx_tokens: int, residue_tokens: int = 0) -> float:
        """Uncontended lower bound on pulling an offloaded request's KV
        back from the host tier: host-link wire time plus the prefill cost
        of any ``residue_tokens`` not captured by the offload (tokens
        generated after the snapshot that must be re-prefilled). The
        contended wire path lives in serving/transfer.py; the offload
        direction costs the same (symmetric host link)."""
        hw = self.worker.hw
        if hw.host_bw <= 0:
            return float("inf")
        t = hw.host_latency + self.kv_transfer_bytes(ctx_tokens) / hw.host_bw
        if residue_tokens > 0:
            t += self.prefill_time(residue_tokens, ctx_offset=ctx_tokens)
        return t


def _quad_roots(a, b, c) -> np.ndarray:
    """Real roots of ``a·x² + b·x + c = 0``, elementwise over broadcast
    arrays. Degenerate rows (a == 0) fall back to the linear root −c/b;
    rows with no real root (or 0·x² + 0·x + c) yield NaN. Returns the
    inputs' broadcast shape with a trailing axis of 2."""
    a, b, c = np.broadcast_arrays(np.asarray(a, dtype=np.float64),
                                  np.asarray(b, dtype=np.float64),
                                  np.asarray(c, dtype=np.float64))
    with np.errstate(divide="ignore", invalid="ignore"):
        disc = b * b - 4.0 * a * c
        sq = np.sqrt(np.where(disc >= 0.0, disc, np.nan))
        quad = a != 0.0
        den = np.where(quad, 2.0 * a, 1.0)
        r1 = np.where(quad, (-b - sq) / den, -c / np.where(b != 0.0, b, np.nan))
        r2 = np.where(quad, (-b + sq) / den, np.nan)
    return np.stack([r1, r2], axis=-1)


def canonical_iteration_time(cost: IterationCostModel) -> float:
    """One canonical mixed iteration (decode batch of 8 at ctx 2048 each,
    plus a 2048-token prefill chunk): THE probe that ranks heterogeneous
    hardware. Both the relative-speed normalisation and
    ``ClusterPredictor``'s reference-worker choice use it, so the two
    notions of 'fastest worker' can never drift apart."""
    return cost.iteration_time(8, 8 * 2048.0, 2048, 0.0)


def relative_speeds(costs: dict[int, CostModel]) -> dict[int, float]:
    """Per-worker relative throughput (fastest worker = 1.0), from each
    worker's predicted time on the canonical mixed iteration. Load metrics
    divide by this so 'least loaded' means 'finishes soonest', not 'fewest
    tokens' — on a homogeneous cluster every speed is exactly 1.0 and all
    orderings are unchanged."""
    ref = {wid: canonical_iteration_time(c) for wid, c in costs.items()}
    fastest = min(ref.values())
    return {wid: fastest / t if t > 0 else 1.0 for wid, t in ref.items()}
