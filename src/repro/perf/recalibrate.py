"""Online recalibration: re-fit γ (and nudge MFU/bandwidth) from residuals.

``calibrate_interference``/``calibrate_hardware`` measure once at startup,
but achieved efficiency drifts (thermal throttling, XLA recompiles,
noisy neighbours) and the startup γ grid was measured under synthetic
shapes. The scheduler's observation path already sees every iteration as
(plan, predicted, observed); ``DriftMonitor`` closes the loop the ROADMAP
left open — *re-calibrate periodically online instead of once at
startup*:

* **mixed iterations** — the observed excess over the worker model's
  γ=0 prediction, divided by the model's own unit penalty (γ=1 term),
  is that iteration's *implied* γ. The base is first scaled by the
  blended pure-phase drift ratio, so uniform slowdown (which the pure
  observations evidence) is never misread as contention. Per-(decode-bucket, chunk-bucket)
  EWMAs accumulate it, and every ``every`` observations the warm cells
  are folded into the worker models' ``InterferenceTable`` — whose grid
  is the *union* of the existing edges and the warm cells, so a startup
  calibration's cells outside the traffic's hull keep their measured γ.
* **pure iterations** — the observed/predicted ratio per phase nudges
  the measured efficiency constants: prefill residuals re-fit
  ``mfu_prefill``; decode residuals re-fit ``mfu_decode`` and ``bw_eff``
  together (scaling both moves the decode roofline's max by exactly the
  ratio, whichever side binds). This assumes the usual serving regime —
  prefill compute-bound, decode memory-bound: ``bw_eff`` is shared with
  the prefill memory roofline, so a decode-only slowdown also raises a
  *memory-bound* prefill's prediction, and ``mfu_prefill`` (the
  compute knob) cannot pull it back down. Splitting per-phase bandwidth
  efficiency would need a ``HardwareSpec`` schema change; out of scope
  here.

Evidence is kept **per distinct cost model**: on a heterogeneous cluster
one throttling worker must not blend its residuals into its healthy
peers' constants (workers sharing one model — the homogeneous default —
share one evidence pool, which is the same thing said twice).

Predictions from every consumer — the ``AnalyticalPredictor`` admission
maths, ``ClusterPredictor`` per-worker pricing, toggle chunk gating —
sharpen automatically because they all read the same ``CostModel``
objects this monitor updates. Against a drift-free clock (the default
cost-model backend) every residual is zero, so an armed monitor is a
bit-exact no-op: recalibration swaps in the identical model.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.perf.hardware import InterferenceTable, gamma_at
from repro.perf.model import CostModel

_EFF_FLOOR = 1e-6                    # efficiency fractions stay in (0, 1]


def _pow2_bucket(x: float) -> int:
    """Power-of-two bucket lower bound: 1, 2, 4, 8… (sizes below 1 -> 1)."""
    return 1 << max(int(x).bit_length() - 1, 0)


class _Evidence:
    """Residual accumulators for ONE distinct cost model."""

    def __init__(self):
        # implied-γ EWMA per (decode-bucket, chunk-bucket) cell
        self.gamma_ewma: dict[tuple[int, int], float] = {}
        self.gamma_obs: dict[tuple[int, int], int] = {}
        # pure-phase observed/predicted ratio EWMAs (reset on each apply:
        # the fold into the spec consumes the accumulated drift)
        self.ratio = {"prefill": 1.0, "decode": 1.0}
        self.ratio_obs = {"prefill": 0, "decode": 0}

    def reset_ratio(self, phase: str) -> None:
        """Restart ONE phase's ratio EWMA after its drift was folded into
        the spec; a phase still below its evidence floor keeps
        accumulating across windows (low-rate phases would otherwise
        never reach the floor before being wiped)."""
        self.ratio[phase] = 1.0
        self.ratio_obs[phase] = 0


class DriftMonitor:
    """Re-fits per-bucket γ and the measured efficiency constants from
    observed iteration residuals on a configurable cadence.

    ``costs`` maps worker id -> the ``CostModel`` whose ``WorkerSpec`` the
    monitor keeps current (homogeneous clusters share one instance; its
    evidence pool and update are shared the same way). ``every`` is the
    recalibration cadence in observed iterations."""

    def __init__(self, costs: dict[int, CostModel], every: int = 256,
                 alpha: float = 0.2, floor: int = 8,
                 gamma_max: float = 1.0, adjust_efficiency: bool = True,
                 ratio_clip: tuple[float, float] = (0.125, 8.0)):
        if every < 1:
            raise ValueError(f"recalibration cadence must be >= 1 "
                             f"iteration, got {every}")
        self.costs = dict(costs)
        self.every = int(every)
        self.alpha = alpha
        self.floor = floor
        self.gamma_max = gamma_max
        self.adjust_efficiency = adjust_efficiency
        self.ratio_clip = ratio_clip
        # evidence per DISTINCT model object (id-keyed; workers sharing a
        # CostModel share a pool, per-worker models drift independently)
        self._models: dict[int, tuple[CostModel, _Evidence]] = {}
        for cost in self.costs.values():
            self._models.setdefault(id(cost), (cost, _Evidence()))
        self._since_apply = 0
        self.recalibrations = 0

    def register(self, wid: int, cost: CostModel) -> None:
        """Start monitoring a worker added after construction (elastic
        clusters): the scheduler calls this from its add-worker path so
        late workers observe and recalibrate like founding ones."""
        self.costs[wid] = cost
        self._models.setdefault(id(cost), (cost, _Evidence()))

    # --------------------------------------------------------------- feed
    def observe(self, wid: int, plan, predicted: float,
                observed: float) -> None:
        """One finished iteration: its composition, the worker model's
        current prediction for it, and the backend's observed duration."""
        cost = self.costs.get(wid)
        if cost is None or predicted <= 0.0 or observed <= 0.0:
            return
        ev = self._models[id(cost)][1]
        n, s = plan.n_decode, plan.sum_ctx
        p, c = plan.prefill_tokens, plan.prefill_ctx_offset
        if n > 0 and p > 0:
            unit = cost._interference(1.0, n, s, p, c)
            if unit > 0.0:
                base0 = predicted - cost.interference_penalty(n, s, p, c)
                # discount uniform efficiency drift before attributing the
                # excess to contention: the pure-phase ratio EWMAs track
                # how much slower than the model the hardware runs overall
                # (they accumulate even when adjust_efficiency is off —
                # e.g. paired with an OnlinePredictor that owns the
                # correction), and a uniformly-1.5x-slow backend must not
                # read as γ
                r = self._drift_ratio(ev, cost, n, s, p, c)
                # symmetric per-sample clamp: negative residuals (noise
                # below the additive prediction) must pull the EWMA down,
                # or a drift-free noisy clock would learn a phantom γ from
                # E[max(noise, 0)] > 0; the fold into the table clamps the
                # *converged* value into [0, gamma_max] instead
                implied = min(max((observed - r * base0) / (r * unit),
                                  -self.gamma_max), self.gamma_max)
                key = (_pow2_bucket(n), _pow2_bucket(p))
                prev = ev.gamma_ewma.get(key)
                ev.gamma_ewma[key] = implied if prev is None else \
                    (1.0 - self.alpha) * prev + self.alpha * implied
                ev.gamma_obs[key] = ev.gamma_obs.get(key, 0) + 1
        elif p > 0 or n > 0:
            phase = "prefill" if p > 0 else "decode"
            lo, hi = self.ratio_clip
            ratio = min(max(observed / predicted, lo), hi)
            ev.ratio[phase] = (1.0 - self.alpha) * ev.ratio[phase] \
                + self.alpha * ratio
            ev.ratio_obs[phase] += 1
        self._since_apply += 1
        if self._since_apply >= self.every:
            self.apply()

    def _drift_ratio(self, ev: _Evidence, cost: CostModel, n: int, s: float,
                     p: int, c: float) -> float:
        """Blended pure-phase observed/predicted ratio for one mixed
        iteration, weighted by the model's own phase shares. Phases below
        the evidence floor contribute ratio 1.0; after a fold (which
        resets the EWMAs) the drift lives in the model and this correctly
        returns toward 1.0."""
        r_p = ev.ratio["prefill"] if ev.ratio_obs["prefill"] >= self.floor \
            else 1.0
        r_d = ev.ratio["decode"] if ev.ratio_obs["decode"] >= self.floor \
            else 1.0
        if r_p == 1.0 and r_d == 1.0:
            return 1.0
        t_p = cost.prefill_time(p, int(c))
        t_d = cost.decode_iter_time(n, s)
        if t_p + t_d <= 0.0:
            return 1.0
        return (r_p * t_p + r_d * t_d) / (t_p + t_d)

    # -------------------------------------------------------------- re-fit
    def _table(self, current, ev: _Evidence) -> Optional[InterferenceTable]:
        """The re-fitted γ table from cells with >= ``floor`` evidence, or
        None when nothing is warm yet. The grid is the union of the warm
        cells and the current table's edges; cells without fresh evidence
        keep the model's *current* coefficient there, so a recalibration
        refines what it has evidence for and never forgets the startup
        calibration's cells outside the traffic's hull."""
        warm = {k for k, n in ev.gamma_obs.items() if n >= self.floor}
        if not warm:
            return None
        d_edges = {k[0] for k in warm}
        c_edges = {k[1] for k in warm}
        if isinstance(current, InterferenceTable):
            d_edges |= set(current.decode_edges)
            c_edges |= set(current.chunk_edges)
        else:
            # scalar start: anchor the lowest bucket on each axis so a
            # cell below the warm hull keeps the current scalar instead of
            # clamping into a big-batch cell it has no evidence for
            d_edges.add(1)
            c_edges.add(1)
        decode_edges = tuple(sorted(d_edges))
        chunk_edges = tuple(sorted(c_edges))
        gamma = tuple(
            tuple(min(max(ev.gamma_ewma[(db, cb)], 0.0), self.gamma_max)
                  if (db, cb) in warm
                  else gamma_at(current, db, cb)
                  for cb in chunk_edges)
            for db in decode_edges)
        return InterferenceTable(decode_edges=decode_edges,
                                 chunk_edges=chunk_edges, gamma=gamma)

    def apply(self) -> None:
        """Fold each model's accumulated evidence into that model."""
        self._since_apply = 0
        self.recalibrations += 1
        for cost, ev in self._models.values():
            hw = cost.worker.hw
            changes: dict = {}
            new_table = self._table(hw.interference, ev)
            if new_table is not None:
                changes["interference"] = new_table
            if self.adjust_efficiency:
                if ev.ratio_obs["prefill"] >= self.floor:
                    changes["mfu_prefill"] = self._clamp_eff(
                        hw.mfu_prefill / ev.ratio["prefill"])
                    ev.reset_ratio("prefill")
                if ev.ratio_obs["decode"] >= self.floor:
                    r = ev.ratio["decode"]
                    changes["mfu_decode"] = self._clamp_eff(hw.mfu_decode / r)
                    changes["bw_eff"] = self._clamp_eff(hw.bw_eff / r)
                    ev.reset_ratio("decode")
            if changes:
                cost.worker = dataclasses.replace(
                    cost.worker, hw=dataclasses.replace(hw, **changes))

    @staticmethod
    def _clamp_eff(x: float) -> float:
        return min(max(x, _EFF_FLOOR), 1.0)

    # ------------------------------------------------------------- reporting
    def gamma_range(self) -> tuple[float, float]:
        """(min, max) learned γ across every model's warm cells;
        (0, 0) before any cell warms up."""
        warm = [min(max(ev.gamma_ewma[k], 0.0), self.gamma_max)
                for _, ev in self._models.values()
                for k, n in ev.gamma_obs.items() if n >= self.floor]
        if not warm:
            return 0.0, 0.0
        return min(warm), max(warm)

    @property
    def gamma_obs(self) -> dict:
        """Union view of per-cell observation counts (single-model
        monitors expose their one pool directly)."""
        out: dict[tuple[int, int], int] = {}
        for _, ev in self._models.values():
            for k, n in ev.gamma_obs.items():
                out[k] = out.get(k, 0) + n
        return out

    @property
    def gamma_ewma(self) -> dict:
        """Union view of learned per-cell γ (when multiple models learned
        the same cell, the last model's value wins — use per-model
        evidence via ``_models`` for exact multi-model introspection)."""
        out: dict[tuple[int, int], float] = {}
        for _, ev in self._models.values():
            out.update(ev.gamma_ewma)
        return out
