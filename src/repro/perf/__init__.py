"""repro.perf — the unified interference-aware performance model.

One subsystem owns every step-time estimate in the stack:

* ``hardware``    — per-worker ``HardwareSpec``/``WorkerSpec`` (clusters
                    may mix fast and slow workers);
* ``model``       — the ``IterationCostModel`` interface and the roofline
                    ``CostModel`` with the §IV mixed-batch interference
                    term (``HardwareSpec.interference``, default off);
* ``predictor``   — §IV-C analytic/profiled predictors, per-worker aware
                    (``ClusterPredictor`` prices on the target worker);
* ``calibration`` — ``OnlinePredictor``: per-(worker, phase, size-bucket)
                    EWMA correction from observed durations;
* ``calibrate``   — measured-MFU roofline: run the real Pallas kernels
                    once, instantiate the model from measurements
                    (``CalibratedRooflineBackend``); v2 adds
                    ``calibrate_interference`` — the mixed-vs-pure kernel
                    grid sweep that fits a bucketed ``InterferenceTable``;
* ``recalibrate`` — ``DriftMonitor``: periodic online re-fit of per-bucket
                    γ and the measured efficiency constants from observed
                    iteration residuals (thermal drift, stale profiles).

``serving/costmodel.py`` and ``core/predictor.py`` remain as import shims
so every pre-existing call site keeps working unchanged.
"""
from repro.perf.calibrate import (CalibratedRooflineBackend,
                                  InterferenceCalibration,
                                  KernelCalibration, calibrate_hardware,
                                  calibrate_interference)
from repro.perf.calibration import OnlinePredictor
from repro.perf.hardware import (V5E, HardwareSpec, InterferenceTable,
                                 WorkerSpec, gamma_at, gamma_at_batch)
from repro.perf.model import (STATE_TOKEN_EQUIV, CostModel,
                              IterationCostModel, ModelCostSpec,
                              build_cost_spec, canonical_iteration_time,
                              relative_speeds)
from repro.perf.predictor import (AnalyticalPredictor, BiasedPredictor,
                                  ClusterPredictor, Predictor,
                                  ProfiledPredictor, profile_worker)
from repro.perf.recalibrate import DriftMonitor

__all__ = [
    "AnalyticalPredictor", "BiasedPredictor", "CalibratedRooflineBackend",
    "ClusterPredictor", "CostModel", "DriftMonitor", "HardwareSpec",
    "InterferenceCalibration", "InterferenceTable", "IterationCostModel",
    "KernelCalibration", "ModelCostSpec", "OnlinePredictor", "Predictor",
    "ProfiledPredictor", "STATE_TOKEN_EQUIV", "V5E", "WorkerSpec",
    "build_cost_spec", "calibrate_hardware", "calibrate_interference",
    "canonical_iteration_time", "gamma_at", "gamma_at_batch",
    "profile_worker", "relative_speeds",
]
