"""Executors: the clock sources behind the engine.

* ``SimExecutor`` — the analytical cost model (default for benchmarks).
* ``RealExecutor`` — actually runs the JAX model on this host: slot-based
  batched cache, chunked prefill into per-slot cache views, batched decode
  across slots. Iteration durations are measured wall-clock. This proves
  the scheduler drives a real model end-to-end (examples + integration
  tests use smoke-scale configs).
* ``RealJaxBackend`` — the ``ExecutionBackend`` adapter that plugs a
  ``ClusterRealExecutors`` registry into the unified ``ClusterScheduler``:
  real compute + wall-clock durations (``clock="wall"``), or real compute
  under the cost-model clock (``clock="model"``) so scheduling decisions
  are bit-identical to the pure simulator — the backend-parity guarantee.

Two execution regimes per executor:

* ``batched=False`` — the scalar reference: one unjitted prefill-chunk
  call per request (a compile per distinct chunk length), a full-cache
  gather/scatter copy per chunk, and a host sync per sampled token.
  Kept bit-for-bit as the seed path; the parity tests compare against it.
* ``batched=True`` (default) — the fast path: prefill chunks are padded
  to a pow2 **bucket grid** and all same-bucket parts of an iteration run
  as ONE jitted slot-indexed call that updates the slotted cache in place
  (``jax.lax.dynamic_slice`` row gather + ``jax.lax.dynamic_update_slice``
  row scatter under ``donate_argnums``), then the decode batch, with
  exactly one ``block_until_ready`` and one device->host token transfer
  per iteration. Bucket padding never writes past the cache: rows pad
  LEFT by re-feeding already-prefilled prefix tokens (recomputing the
  same KV) and only spill right while ``start + bucket <= max_len``;
  batch rows pad by duplicating row 0, so writes stay idempotent and the
  cache geometry is identical to the scalar path. Compiled entry points
  live in a per-cluster ``ExecutorKernels`` (identical shapes across
  replicas => one compile per (bucket, rows) for the whole cluster),
  warmed over the bucket grid at construction so first-iteration compile
  latency never poisons ``OnlinePredictor`` EWMAs.

The fast path requires the chunked-prefill contract and a uniform slotted
{"k","v"} cache (dense/moe/vlm transformers); ring-cache and stateful
families (gemma2 sliding window, rwkv, zamba2, whisper) transparently
fall back to the scalar reference even under ``batched=True``.
"""
from __future__ import annotations

import time
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models import api as model_api
from repro.perf import CostModel
from repro.sched.backend import SlotExhausted
from repro.serving.engine import IterationPlan, Worker

# CPU jax cannot honour buffer donation; the (once-per-compile) warning is
# expected off-accelerator and would otherwise pollute every test run
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")

_BUCKET_FLOOR = 32


def _bucket_grid(max_len: int) -> tuple[int, ...]:
    """Pow2 chunk buckets from the floor up, capped by the cache length
    (the last bucket is ``max_len`` itself so padded writes stay in
    bounds even for a non-pow2 cache)."""
    grid = []
    b = min(_BUCKET_FLOOR, max_len)
    while b < max_len:
        grid.append(b)
        b *= 2
    grid.append(max_len)
    return tuple(grid)


def _next_pow2(n: int) -> int:
    return 1 << max(0, n - 1).bit_length() if n > 1 else 1


def _uniform_cache(cache) -> bool:
    """True for the slotted dict-of-arrays cache the bucketed kernels can
    row-index: {"k","v"} with (L, B, S, H, D) leaves. Ring caches (tuple
    leaves) and stateful pytrees fall back to the scalar path."""
    return (isinstance(cache, dict) and set(cache.keys()) == {"k", "v"}
            and all(getattr(a, "ndim", 0) == 5 for a in cache.values()))


def _slice_row(tree, idx):
    """One slot row (axis 1) of every leaf, via ``lax.dynamic_slice`` so a
    traced index is allowed."""
    return jax.tree.map(
        lambda a: lax.dynamic_slice(
            a, (0, idx) + (0,) * (a.ndim - 2),
            (a.shape[0], 1) + a.shape[2:]), tree)


class ExecutorKernels:
    """Jitted, slot-indexed entry points over one cache geometry, shared
    by every executor in a cluster (replicas have identical shapes, so
    each (bucket, rows) signature compiles exactly once per process).

    ``prefill_traces`` / ``decode_traces`` increment only when jax
    actually traces (= compiles) an entry point — the compile-count
    regression tests pin them to the bucket grid, not to the number of
    distinct chunk lengths seen.
    """

    def __init__(self, api, max_slots: int, max_len: int):
        self.api = api
        self.max_slots = max_slots
        self.max_len = max_len
        self.buckets = _bucket_grid(max_len)
        self.prefill_traces = 0
        self.decode_traces = 0
        self._prefill_fns: dict[tuple[int, int], object] = {}
        self._decode = None
        self._copy = None

    def bucket_for(self, take: int) -> int:
        for b in self.buckets:
            if take <= b:
                return b
        raise ValueError(f"chunk of {take} tokens exceeds max_len "
                         f"{self.max_len}")

    # ---------------------------------------------------------- entry points
    def prefill_fn(self, bucket: int, rows: int):
        """Batched bucketed prefill: gathers ``rows`` slot views, runs one
        padded ``prefill_chunk``, scatters the rows back in place, and
        samples every row's next token on device."""
        key = (bucket, rows)
        fn = self._prefill_fns.get(key)
        if fn is None:
            api = self.api

            def step(params, cache, chunk, slots, starts, takes):
                self.prefill_traces += 1     # trace-time only: a jit miss
                view = jax.tree.map(
                    lambda *xs: jnp.concatenate(xs, axis=1),
                    *[_slice_row(cache, slots[i]) for i in range(rows)])
                logits, view = api.prefill_chunk(
                    params, view, chunk, starts, take=takes)
                for i in range(rows):
                    cache = jax.tree.map(
                        lambda a, r: lax.dynamic_update_slice(
                            a, r.astype(a.dtype),
                            (0, slots[i]) + (0,) * (a.ndim - 2)),
                        cache, _slice_row(view, i))
                toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return toks, cache

            fn = jax.jit(step, donate_argnums=1)
            self._prefill_fns[key] = fn
        return fn

    @property
    def decode_fn(self):
        if self._decode is None:
            api = self.api

            def step(params, cache, tokens, lengths):
                self.decode_traces += 1
                logits, cache = api.decode(params, cache, tokens, lengths)
                return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

            self._decode = jax.jit(step, donate_argnums=1)
        return self._decode

    @property
    def copy_fn(self):
        """Device-to-device KV slot copy (migration fast path)."""
        if self._copy is None:

            def step(dst, src, dslot, sslot):
                return jax.tree.map(
                    lambda a, r: lax.dynamic_update_slice(
                        a, r.astype(a.dtype),
                        (0, dslot) + (0,) * (a.ndim - 2)),
                    dst, _slice_row(src, sslot))

            self._copy = jax.jit(step, donate_argnums=0)
        return self._copy

    # --------------------------------------------------------------- warmup
    def warmup(self, params) -> None:
        """Compile the (bucket, 1-row) grid + the decode step up front on a
        throwaway cache, so the first scheduled iterations measure steady-
        state execution (not compilation) — the durations that feed the
        OnlinePredictor EWMAs."""
        cache = self.api.init_cache(self.max_slots, self.max_len)
        one = jnp.zeros((1,), jnp.int32)
        for b in self.buckets:
            _, cache = self.prefill_fn(b, 1)(
                params, cache, jnp.zeros((1, b), jnp.int32), one, one,
                jnp.ones((1,), jnp.int32))
        zeros = jnp.zeros((self.max_slots,), jnp.int32)
        _, cache = self.decode_fn(params, cache, zeros, zeros)
        jax.block_until_ready(cache)


class SimExecutor:
    def __init__(self, cost: CostModel):
        self.cost = cost

    def duration_fn(self):
        return lambda worker, plan: worker.plan_duration(plan)


class RealExecutor:
    """One executor per worker; owns params + a slotted cache."""

    def __init__(self, cfg, rng, max_slots: int = 8, max_len: int = 256,
                 params=None, batched: bool = True, wid: int = 0,
                 kernels: Optional[ExecutorKernels] = None, owner=None):
        self.cfg = cfg
        self.api = model_api.build(cfg)
        self.params = params if params is not None else self.api.init(rng)
        self.max_slots = max_slots
        self.max_len = max_len
        self.wid = wid
        self.cache = self.api.init_cache(max_slots, max_len)
        self.free_slots = list(range(max_slots))
        self.slot_of: dict[int, int] = {}
        self.owner = owner                   # cluster rid -> wid registry
        self.lengths = np.zeros(max_slots, np.int32)
        self.prompts: dict[int, np.ndarray] = {}     # rid -> prompt tokens
        self.generated: dict[int, list[int]] = {}
        self.pending_logits: dict[int, np.ndarray] = {}
        self._decode_fn = jax.jit(
            lambda p, c, t, l: self.api.decode(p, c, t, l))
        self.batched = batched
        self.fast = bool(batched and self.api.prefill_chunk is not None
                         and _uniform_cache(self.cache))
        if self.fast and kernels is None:
            kernels = ExecutorKernels(self.api, max_slots, max_len)
        self.kernels = kernels

    # ------------------------------------------------------------ requests
    def register(self, req) -> None:
        if req.rid not in self.prompts:
            rng = np.random.default_rng(req.rid)
            self.prompts[req.rid] = rng.integers(
                0, self.cfg.vocab_size, size=req.prompt_len).astype(np.int32)
            self.generated[req.rid] = []

    def _slot(self, rid: int) -> int:
        if rid not in self.slot_of:
            if not self.free_slots:
                raise SlotExhausted(self.wid, rid, self.max_slots)
            self.slot_of[rid] = self.free_slots.pop()
            self.lengths[self.slot_of[rid]] = 0
            if self.owner is not None:
                self.owner[rid] = self.wid
        return self.slot_of[rid]

    def release(self, rid: int) -> None:
        slot = self.slot_of.pop(rid, None)
        if slot is not None:
            self.lengths[slot] = 0
            self.free_slots.append(slot)
            if self.owner is not None and self.owner.get(rid) == self.wid:
                del self.owner[rid]

    # ----------------------------------------------------------- execution
    def _cache_view(self, slot: int):
        return jax.tree.map(lambda a: a[:, slot:slot + 1], self.cache)

    def _cache_write(self, slot: int, view) -> None:
        self.cache = jax.tree.map(
            lambda a, s: a.at[:, slot:slot + 1].set(s), self.cache, view)

    def run_prefill_chunk(self, req, tokens_this_chunk: int) -> None:
        self.register(req)
        slot = self._slot(req.rid)
        start = int(req.prefilled_tokens)
        take = tokens_this_chunk
        chunk = self.prompts[req.rid][start:start + take]
        chunk_j = jnp.asarray(chunk[None, :], jnp.int32)
        starts = jnp.asarray([start], jnp.int32)
        view = self._cache_view(slot)
        if self.api.prefill_chunk is not None:
            logits, view = self.api.prefill_chunk(
                self.params, view, chunk_j, starts)
        else:
            # stateful families: re-run full prefill up to this point
            full = self.prompts[req.rid][: start + take]
            view = self._fresh_view()
            logits, view = self.api.prefill(
                self.params, view, jnp.asarray(full[None, :], jnp.int32),
                jnp.asarray([start + take], jnp.int32))
        self._cache_write(slot, view)
        self.lengths[slot] = start + take
        if start + take >= req.prompt_len:
            tok = int(jnp.argmax(logits[0]))
            self.generated[req.rid].append(tok)

    def _fresh_view(self):
        one = self.api.init_cache(1, self.max_len)
        return one

    def run_decode_batch(self, reqs) -> None:  # lint: not-parity(the decode batch IS the unit of work; run_plan's scalar regime calls this directly)
        if not reqs:
            return
        slots = [self._slot(r.rid) for r in reqs]
        tokens = np.zeros(self.max_slots, np.int32)
        lengths = np.array(self.lengths)
        for r, s in zip(reqs, slots):
            tokens[s] = self.generated[r.rid][-1]
        logits, self.cache = self._decode_fn(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(lengths))
        logits = np.asarray(logits)
        for r, s in zip(reqs, slots):
            self.generated[r.rid].append(int(logits[s].argmax()))
            self.lengths[s] += 1

    # ------------------------------------------------------ fused fast path
    def assign_slots(self, plan: IterationPlan) -> None:
        """Reserve every slot the plan needs BEFORE any compute runs, so a
        ``SlotExhausted`` refusal is side-effect-free on the device (re-
        running a final prefill chunk would double-append its sampled
        token)."""
        for req, _ in plan.prefill_parts:
            self.register(req)
            self._slot(req.rid)
        for r in plan.decode_reqs:
            self._slot(r.rid)

    def run_plan(self, plan: IterationPlan) -> None:
        """Execute one composed iteration (either regime), returning after
        the device is idle."""
        self.assign_slots(plan)
        if self.fast:
            self._run_plan_fast(plan)
            return
        for req, take in plan.prefill_parts:
            self.run_prefill_chunk(req, take)
        self.run_decode_batch(plan.decode_reqs)
        jax.block_until_ready(self.cache)

    def _run_plan_fast(self, plan: IterationPlan) -> None:
        groups: dict[int, list] = {}
        for req, take in plan.prefill_parts:
            bucket = self.kernels.bucket_for(take)
            groups.setdefault(bucket, []).append((req, take))
        pending = []        # (rows-of-Optional[req] | decode pairs)
        tok_parts = []
        for bucket in sorted(groups):
            parts = groups[bucket]
            rows = _next_pow2(len(parts))
            chunk = np.zeros((rows, bucket), np.int32)
            slots = np.zeros(rows, np.int32)
            starts = np.zeros(rows, np.int32)
            takes = np.ones(rows, np.int32)
            finals: list = [None] * rows
            for i, (req, take) in enumerate(parts):
                start = int(req.prefilled_tokens)
                slot = self.slot_of[req.rid]
                # pad LEFT with the already-prefilled prefix (recomputing
                # identical KV) so the padded write window never crosses
                # max_len — dynamic_update_slice clamps, which would slide
                # real KV rows to wrong positions
                pad_l = min(bucket - take, start)
                row_start = start - pad_l
                toks = self.prompts[req.rid][row_start:start + take]
                chunk[i, :len(toks)] = toks
                slots[i] = slot
                starts[i] = row_start
                takes[i] = pad_l + take
                self.lengths[slot] = start + take
                if start + take >= req.prompt_len:
                    finals[i] = req
            for i in range(len(parts), rows):   # duplicate row 0: idempotent
                chunk[i] = chunk[0]
                slots[i] = slots[0]
                starts[i] = starts[0]
                takes[i] = takes[0]
            toks_dev, self.cache = self.kernels.prefill_fn(bucket, rows)(
                self.params, self.cache, jnp.asarray(chunk),
                jnp.asarray(slots), jnp.asarray(starts), jnp.asarray(takes))
            tok_parts.append(toks_dev)
            pending.append(("prefill", finals))
        if plan.decode_reqs:
            dpairs = [(r, self._slot(r.rid)) for r in plan.decode_reqs]
            tokens = np.zeros(self.max_slots, np.int32)
            lengths = np.array(self.lengths)
            for r, s in dpairs:
                tokens[s] = self.generated[r.rid][-1]
            toks_dev, self.cache = self.kernels.decode_fn(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(lengths))
            tok_parts.append(toks_dev)
            pending.append(("decode", dpairs))
        # exactly one device sync + one device->host transfer per iteration
        jax.block_until_ready(self.cache)
        if not tok_parts:
            return
        host = np.asarray(tok_parts[0]) if len(tok_parts) == 1 else \
            np.asarray(jnp.concatenate(tok_parts))
        off = 0
        for kind, data in pending:
            if kind == "prefill":
                for i, req in enumerate(data):
                    if req is not None:
                        self.generated[req.rid].append(int(host[off + i]))
                off += len(data)
            else:
                for r, s in data:
                    self.generated[r.rid].append(int(host[off + s]))
                    self.lengths[s] += 1
                off += self.max_slots

    def duration_fn(self):
        """Measured-wall-clock duration_fn for the Simulator."""

        def run(worker: Worker, plan: IterationPlan) -> float:
            t0 = time.perf_counter()  # lint: allow-wallclock(real executor measures device wall time)
            self.run_plan(plan)
            return time.perf_counter() - t0  # lint: allow-wallclock(real executor measures device wall time)

        return run


class ClusterRealExecutors:
    """Per-worker RealExecutor registry + shared duration_fn dispatch.

    All replicas share weights AND compiled entry points (identical cache
    geometry => one jit cache for the cluster), warmed over the bucket
    grid at construction.
    """

    def __init__(self, cfg, n_workers: int, rng=None, max_slots=8,
                 max_len=256, batched: bool = True, warmup: bool = True):
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        api = model_api.build(cfg)
        params = api.init(rng)   # replicas share weights
        self.batched = batched
        self._owner: dict[int, int] = {}     # rid -> owning wid
        kernels = None
        if batched and api.prefill_chunk is not None and \
                _uniform_cache(api.init_cache(1, max_len)):
            kernels = ExecutorKernels(api, max_slots, max_len)
        self.kernels = kernels
        self.execs = {
            i: RealExecutor(cfg, rng, max_slots, max_len, params=params,
                            batched=batched, wid=i, kernels=kernels,
                            owner=self._owner)
            for i in range(n_workers)
        }
        if kernels is not None and warmup:
            kernels.warmup(params)

    def duration_fn(self):
        def run(worker: Worker, plan: IterationPlan) -> float:
            return self.execs[worker.wid].duration_fn()(worker, plan)
        return run

    def on_finish(self, req) -> None:
        wid = self._owner.get(req.rid)
        if wid is not None:
            self.execs[wid].release(req.rid)

    def as_backend(self, clock: str = "wall") -> "RealJaxBackend":
        return RealJaxBackend(self, clock=clock)

    def migrate(self, req, src: int, dst: int) -> None:
        """Move the request across workers. Cache-true families copy the
        KV slot device-to-device (on TPU this is the ICI transfer);
        stateful/ring families re-derive it by replaying prefill."""
        se, de = self.execs[src], self.execs[dst]
        de.prompts[req.rid] = se.prompts[req.rid]
        de.generated[req.rid] = list(se.generated[req.rid])
        slot = de._slot(req.rid)      # SlotExhausted surfaces to scheduler
        sslot = se.slot_of.get(req.rid)
        if de.fast and sslot is not None and se.fast:
            de.cache = de.kernels.copy_fn(
                de.cache, se.cache, jnp.int32(slot), jnp.int32(sslot))
            de.lengths[slot] = se.lengths[sslot]
            se.release(req.rid)
            return
        # replay KV on the destination (simulating the transfer)
        full = np.concatenate([
            de.prompts[req.rid],
            np.asarray(de.generated[req.rid][:-1], np.int32)]) \
            if len(de.generated[req.rid]) > 1 else de.prompts[req.rid]
        view = de._fresh_view()
        _, view = de.api.prefill(
            de.params, view, jnp.asarray(full[None, :], jnp.int32),
            jnp.asarray([len(full)], jnp.int32))
        de._cache_write(slot, view)
        de.lengths[slot] = len(full)
        se.release(req.rid)


class RealJaxBackend:
    """ExecutionBackend over per-worker RealExecutors.

    ``clock="wall"``   — report measured wall-clock durations (the real
                         serving configuration; feeds OnlinePredictor with
                         genuine execution times).
    ``clock="model"``  — run the real compute but report the analytical
                         cost-model duration. Scheduling then sees exactly
                         the timings the pure simulator sees, which makes
                         decision logs comparable across backends.

    A worker out of KV slots raises ``SlotExhausted`` before any compute
    runs; the scheduler turns it into a dispatch refusal (the request
    re-queues) instead of a crash.
    """

    def __init__(self, execs: ClusterRealExecutors, clock: str = "wall"):
        if clock not in ("wall", "model"):
            raise ValueError(f"clock must be 'wall' or 'model', got {clock!r}")
        self.execs = execs
        self.clock = clock

    def run_iteration(self, worker: Worker, plan: IterationPlan) -> float:
        e = self.execs.execs[worker.wid]
        t0 = time.perf_counter()  # lint: allow-wallclock(real executor measures device wall time)
        e.run_plan(plan)
        measured = time.perf_counter() - t0  # lint: allow-wallclock(real executor measures device wall time)
        return measured if self.clock == "wall" else worker.plan_duration(plan)

    def on_finish(self, req) -> None:
        self.execs.on_finish(req)

    def on_migrate(self, req, src_wid: int, dst_wid: int) -> None:
        self.execs.migrate(req, src_wid, dst_wid)
