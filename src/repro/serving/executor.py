"""Executors: the clock sources behind the engine.

* ``SimExecutor`` — the analytical cost model (default for benchmarks).
* ``RealExecutor`` — actually runs the JAX model on this host: slot-based
  batched cache, chunked prefill into per-slot cache views, batched decode
  across slots. Iteration durations are measured wall-clock. This proves
  the scheduler drives a real model end-to-end (examples + integration
  tests use smoke-scale configs).
* ``RealJaxBackend`` — the ``ExecutionBackend`` adapter that plugs a
  ``ClusterRealExecutors`` registry into the unified ``ClusterScheduler``:
  real compute + wall-clock durations (``clock="wall"``), or real compute
  under the cost-model clock (``clock="model"``) so scheduling decisions
  are bit-identical to the pure simulator — the backend-parity guarantee.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api as model_api
from repro.perf import CostModel
from repro.serving.engine import IterationPlan, Worker


class SimExecutor:
    def __init__(self, cost: CostModel):
        self.cost = cost

    def duration_fn(self):
        return lambda worker, plan: worker.plan_duration(plan)


class RealExecutor:
    """One executor per worker; owns params + a slotted cache."""

    def __init__(self, cfg, rng, max_slots: int = 8, max_len: int = 256,
                 params=None):
        self.cfg = cfg
        self.api = model_api.build(cfg)
        self.params = params if params is not None else self.api.init(rng)
        self.max_slots = max_slots
        self.max_len = max_len
        self.cache = self.api.init_cache(max_slots, max_len)
        self.free_slots = list(range(max_slots))
        self.slot_of: dict[int, int] = {}
        self.lengths = np.zeros(max_slots, np.int32)
        self.prompts: dict[int, np.ndarray] = {}     # rid -> prompt tokens
        self.generated: dict[int, list[int]] = {}
        self.pending_logits: dict[int, np.ndarray] = {}
        self._decode_fn = jax.jit(
            lambda p, c, t, l: self.api.decode(p, c, t, l))

    # ------------------------------------------------------------ requests
    def register(self, req) -> None:
        if req.rid not in self.prompts:
            rng = np.random.default_rng(req.rid)
            self.prompts[req.rid] = rng.integers(
                0, self.cfg.vocab_size, size=req.prompt_len).astype(np.int32)
            self.generated[req.rid] = []

    def _slot(self, rid: int) -> int:
        if rid not in self.slot_of:
            if not self.free_slots:
                raise MemoryError("no free slots")
            self.slot_of[rid] = self.free_slots.pop()
            self.lengths[self.slot_of[rid]] = 0
        return self.slot_of[rid]

    def release(self, rid: int) -> None:
        slot = self.slot_of.pop(rid, None)
        if slot is not None:
            self.lengths[slot] = 0
            self.free_slots.append(slot)

    # ----------------------------------------------------------- execution
    def _cache_view(self, slot: int):
        return jax.tree.map(lambda a: a[:, slot:slot + 1], self.cache)

    def _cache_write(self, slot: int, view) -> None:
        self.cache = jax.tree.map(
            lambda a, s: a.at[:, slot:slot + 1].set(s), self.cache, view)

    def run_prefill_chunk(self, req, tokens_this_chunk: int) -> None:
        self.register(req)
        slot = self._slot(req.rid)
        start = int(req.prefilled_tokens)
        take = tokens_this_chunk
        chunk = self.prompts[req.rid][start:start + take]
        chunk_j = jnp.asarray(chunk[None, :], jnp.int32)
        starts = jnp.asarray([start], jnp.int32)
        view = self._cache_view(slot)
        if self.api.prefill_chunk is not None:
            logits, view = self.api.prefill_chunk(
                self.params, view, chunk_j, starts)
        else:
            # stateful families: re-run full prefill up to this point
            full = self.prompts[req.rid][: start + take]
            view = self._fresh_view()
            logits, view = self.api.prefill(
                self.params, view, jnp.asarray(full[None, :], jnp.int32),
                jnp.asarray([start + take], jnp.int32))
        self._cache_write(slot, view)
        self.lengths[slot] = start + take
        if start + take >= req.prompt_len:
            tok = int(jnp.argmax(logits[0]))
            self.generated[req.rid].append(tok)

    def _fresh_view(self):
        one = self.api.init_cache(1, self.max_len)
        return one

    def run_decode_batch(self, reqs) -> None:
        if not reqs:
            return
        slots = [self._slot(r.rid) for r in reqs]
        tokens = np.zeros(self.max_slots, np.int32)
        lengths = np.array(self.lengths)
        for r, s in zip(reqs, slots):
            tokens[s] = self.generated[r.rid][-1]
        logits, self.cache = self._decode_fn(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(lengths))
        logits = np.asarray(logits)
        for r, s in zip(reqs, slots):
            self.generated[r.rid].append(int(logits[s].argmax()))
            self.lengths[s] += 1

    def duration_fn(self):
        """Measured-wall-clock duration_fn for the Simulator."""

        def run(worker: Worker, plan: IterationPlan) -> float:
            t0 = time.perf_counter()
            for req, take in plan.prefill_parts:
                self.run_prefill_chunk(req, take)
            self.run_decode_batch(plan.decode_reqs)
            jax.block_until_ready(self.cache)
            return time.perf_counter() - t0

        return run


class ClusterRealExecutors:
    """Per-worker RealExecutor registry + shared duration_fn dispatch."""

    def __init__(self, cfg, n_workers: int, rng=None, max_slots=8,
                 max_len=256):
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        api = model_api.build(cfg)
        params = api.init(rng)   # replicas share weights
        self.execs = {
            i: RealExecutor(cfg, rng, max_slots, max_len, params=params)
            for i in range(n_workers)
        }

    def duration_fn(self):
        def run(worker: Worker, plan: IterationPlan) -> float:
            return self.execs[worker.wid].duration_fn()(worker, plan)
        return run

    def on_finish(self, req) -> None:
        for e in self.execs.values():
            e.release(req.rid)

    def as_backend(self, clock: str = "wall") -> "RealJaxBackend":
        return RealJaxBackend(self, clock=clock)

    def migrate(self, req, src: int, dst: int) -> None:
        """Copy the request's tokens; the KV re-registers on the target
        (cache content is re-derived — on TPU this is the ICI transfer)."""
        se, de = self.execs[src], self.execs[dst]
        de.prompts[req.rid] = se.prompts[req.rid]
        de.generated[req.rid] = list(se.generated[req.rid])
        # replay KV on the destination (simulating the transfer)
        slot = de._slot(req.rid)
        full = np.concatenate([
            de.prompts[req.rid],
            np.asarray(de.generated[req.rid][:-1], np.int32)]) \
            if len(de.generated[req.rid]) > 1 else de.prompts[req.rid]
        view = de._fresh_view()
        _, view = de.api.prefill(
            de.params, view, jnp.asarray(full[None, :], jnp.int32),
            jnp.asarray([len(full)], jnp.int32))
        de._cache_write(slot, view)
        de.lengths[slot] = len(full)
        se.release(req.rid)


class RealJaxBackend:
    """ExecutionBackend over per-worker RealExecutors.

    ``clock="wall"``   — report measured wall-clock durations (the real
                         serving configuration; feeds OnlinePredictor with
                         genuine execution times).
    ``clock="model"``  — run the real compute but report the analytical
                         cost-model duration. Scheduling then sees exactly
                         the timings the pure simulator sees, which makes
                         decision logs comparable across backends.
    """

    def __init__(self, execs: ClusterRealExecutors, clock: str = "wall"):
        if clock not in ("wall", "model"):
            raise ValueError(f"clock must be 'wall' or 'model', got {clock!r}")
        self.execs = execs
        self.clock = clock

    def run_iteration(self, worker: Worker, plan: IterationPlan) -> float:
        e = self.execs.execs[worker.wid]
        t0 = time.perf_counter()
        for req, take in plan.prefill_parts:
            e.run_prefill_chunk(req, take)
        e.run_decode_batch(plan.decode_reqs)
        jax.block_until_ready(e.cache)
        measured = time.perf_counter() - t0
        return measured if self.clock == "wall" else worker.plan_duration(plan)

    def on_finish(self, req) -> None:
        self.execs.on_finish(req)

    def on_migrate(self, req, src_wid: int, dst_wid: int) -> None:
        self.execs.migrate(req, src_wid, dst_wid)
