"""Workload traces.

The Mooncake trace (paper §V-A) is not packaged offline, so we synthesise a
trace whose marginals match the paper's characterisation (Fig. 3):

* prefill lengths: long-tail — lognormal body + heavy lognormal tail
  (the paper: "the distribution of prefill text lengths follows a long-tail
  pattern", inputs far more dynamic than outputs);
* outputs: short, low-variance lognormal;
* arrivals: Gamma-modulated Poisson (doubly stochastic) reproducing the
  short-term burstiness of Fig. 3(a).

``load_csv``/``save_csv`` use the Mooncake trace schema (timestamp_ms,
input_length, output_length) so the real trace drops in when available.
"""
from __future__ import annotations

import csv
import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from repro.core.metrics import derive_slos
from repro.core.request import Request, SLOSpec


@dataclasses.dataclass(frozen=True)
class TraceProfile:
    name: str = "mooncake-like"
    # input-length mixture (lognormal body + tail)
    body_median: float = 2048.0
    body_sigma: float = 1.1
    tail_median: float = 16384.0
    tail_sigma: float = 0.7
    tail_frac: float = 0.15
    min_input: int = 16
    max_input: int = 32768      # Mooncake-like long-context cap: the tail
                                # service time stays within ~1x of the TTFT
                                # SLO (as in the paper's A100 setup), so
                                # head-of-line effects degrade rather than
                                # structurally break attainment
    # output lengths
    out_median: float = 256.0
    out_sigma: float = 0.7
    min_output: int = 2
    max_output: int = 2048
    # burstiness: per-window Gamma(shape k) rate modulation; k->inf = Poisson
    burst_window: float = 10.0      # seconds
    burst_shape: float = 2.0


MOONCAKE = TraceProfile()
STEADY = TraceProfile(name="steady", tail_frac=0.05, burst_shape=50.0)


def sample_lengths(rng: np.random.Generator, n: int,
                   prof: TraceProfile) -> tuple[np.ndarray, np.ndarray]:
    tail = rng.random(n) < prof.tail_frac
    body = rng.lognormal(math.log(prof.body_median), prof.body_sigma, n)
    tl = rng.lognormal(math.log(prof.tail_median), prof.tail_sigma, n)
    inputs = np.where(tail, tl, body)
    inputs = np.clip(inputs, prof.min_input, prof.max_input).astype(int)
    outputs = rng.lognormal(math.log(prof.out_median), prof.out_sigma, n)
    outputs = np.clip(outputs, prof.min_output, prof.max_output).astype(int)
    return inputs, outputs


def sample_arrivals(rng: np.random.Generator, rate: float, duration: float,
                    prof: TraceProfile) -> np.ndarray:
    """Gamma-modulated Poisson arrivals over [0, duration)."""
    times: list[float] = []
    t = 0.0
    while t < duration:
        window_rate = rate * rng.gamma(prof.burst_shape, 1.0 / prof.burst_shape)
        end = min(t + prof.burst_window, duration)
        n = rng.poisson(window_rate * (end - t))
        times.extend(rng.uniform(t, end, n))
        t = end
    return np.sort(np.asarray(times))


def generate_trace(rate: float, duration: float, cost_model,
                   seed: int = 0, profile: TraceProfile = MOONCAKE,
                   slo_scale: tuple[float, float] = (5.0, 5.0),
                   fixed_slo: Optional[SLOSpec] = None) -> list[Request]:
    """Paper §V-A SLO setting: TTFT SLO = 5x the light-load prefill latency
    of the request's own prompt; TPOT SLO = 5x the light-load decode
    latency (per-request, as in DistServe)."""
    rng = np.random.default_rng(seed)
    times = sample_arrivals(rng, rate, duration, profile)
    inputs, outputs = sample_lengths(rng, len(times), profile)
    reqs = []
    for i, (t, pl, ol) in enumerate(zip(times, inputs, outputs)):
        if fixed_slo is not None:
            slo = fixed_slo
        else:
            slo = derive_slos(cost_model, int(pl), slo_scale[0], slo_scale[1])
        reqs.append(Request(rid=i, arrival_time=float(t), prompt_len=int(pl),
                            output_len=int(ol), slo=slo))
    return reqs


def save_csv(path: str, requests: Sequence[Request]) -> None:
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["timestamp_ms", "input_length", "output_length"])
        for r in requests:
            w.writerow([int(r.arrival_time * 1000), r.prompt_len, r.output_len])


def load_csv(path: str, cost_model, slo_scale=(5.0, 5.0)) -> list[Request]:
    reqs = []
    with open(path) as f:
        for i, row in enumerate(csv.DictReader(f)):
            pl = int(row["input_length"])
            slo = derive_slos(cost_model, pl, *slo_scale)
            reqs.append(Request(
                rid=i, arrival_time=int(row["timestamp_ms"]) / 1000.0,
                prompt_len=pl, output_len=int(row["output_length"]), slo=slo))
    return reqs
