"""Legacy import path — the workload subsystem lives in ``repro.workload``.

Everything that used to be defined here (the mooncake-like profile,
Gamma-modulated arrivals, ``generate_trace``, the Mooncake-schema CSV
round-trip) moved into the ``repro.workload`` package, which adds named
scenarios (bursty / diurnal / longctx / agentic / mixture), SLO classes
and replay iterators on top. This shim keeps every pre-package import
working; ``generate_trace`` remains RNG-stream identical, so seeded
benchmark numbers reproduce exactly.
"""
from repro.workload import (AGENTIC, LONGCTX, MOONCAKE,  # noqa: F401
                            SCENARIOS, STEADY, Scenario, ScenarioComponent,
                            TraceProfile, generate_trace, get_scenario,
                            load_csv, replay_csv, sample_arrivals,
                            sample_lengths, save_csv)

__all__ = [
    "AGENTIC", "LONGCTX", "MOONCAKE", "SCENARIOS", "STEADY", "Scenario",
    "ScenarioComponent", "TraceProfile", "generate_trace", "get_scenario",
    "load_csv", "replay_csv", "sample_arrivals", "sample_lengths",
    "save_csv",
]
