"""Discrete-event cluster simulator.

Drives Workers + a Policy over a request trace. The same Policy objects run
unchanged against the real-JAX executor (serving/executor.py) — only the
clock source differs, which is the point: the scheduler under test is the
artifact, the executor is exchangeable.

Events: request arrival, per-worker iteration completion, migration
completion, worker failure/recovery (fault-tolerance experiments), elastic
worker addition.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Optional, Sequence

from repro.core.metrics import ServeMetrics, compute_metrics
from repro.core.policies import Policy
from repro.core.request import Phase, Request
from repro.core.toggle import Role
from repro.serving.costmodel import CostModel
from repro.serving.engine import Worker
from repro.serving.transfer import LinkSpec, TransferEngine


@dataclasses.dataclass(order=True)
class _Event:
    time: float
    seq: int
    kind: str = dataclasses.field(compare=False)
    payload: object = dataclasses.field(compare=False, default=None)


class Simulator:
    def __init__(self, workers: Sequence[Worker], policy: Policy,
                 duration_fn: Optional[Callable] = None,
                 transfer: Optional[TransferEngine] = None):
        """duration_fn(worker, plan) -> seconds; default = cost model.

        ``transfer``: bandwidth-contended KV migration engine. None keeps
        the legacy fixed-delay ``CostModel.migration_time`` path."""
        self.workers = {w.wid: w for w in workers}
        self.policy = policy
        self.duration_fn = duration_fn or (lambda w, p: w.plan_duration(p))
        self.transfer = transfer
        if transfer is not None:
            for w in workers:
                transfer.add_worker(
                    w.wid, LinkSpec.from_hardware(w.cost.worker.hw))
        self.now = 0.0
        self._heap: list[_Event] = []
        self._seq = itertools.count()
        self.global_queue: list[Request] = []
        self.requests: list[Request] = []
        self._worker_busy: dict[int, bool] = {w.wid: False for w in workers}
        self._failures: list[tuple[float, int]] = []
        self.max_sim_time = float("inf")

    # ----------------------------------------------------------------- api
    def push(self, kind: str, time: float, payload=None) -> None:
        heapq.heappush(self._heap, _Event(time, next(self._seq), kind, payload))

    def add_trace(self, requests: Sequence[Request]) -> None:
        for r in requests:
            self.push("arrival", r.arrival_time, r)

    def inject_failure(self, time: float, wid: int,
                       recover_after: Optional[float] = None) -> None:
        self.push("fail", time, (wid, recover_after))

    def add_worker_at(self, time: float, worker: Worker) -> None:
        self.push("add_worker", time, worker)

    # ---------------------------------------------------------------- loop
    def run(self, until: Optional[float] = None) -> ServeMetrics:
        if until is not None:
            self.max_sim_time = until
        while self._heap:
            ev = heapq.heappop(self._heap)
            if ev.time > self.max_sim_time:
                break
            self.now = ev.time
            getattr(self, f"_on_{ev.kind}")(ev)
        return self.metrics()

    def metrics(self) -> ServeMetrics:
        qt, bt = {}, {}
        for w in self.workers.values():
            qt.update(w.queue_times)
            bt.update(w.blocked_time)
        return compute_metrics(self.requests, qt, bt)

    # -------------------------------------------------------------- events
    def _on_arrival(self, ev: _Event) -> None:
        req: Request = ev.payload
        self.requests.append(req)
        self._try_dispatch(req)

    def _try_dispatch(self, req: Request) -> None:
        wid = self.policy.dispatch_prefill(req, self.now)
        if wid is None or wid not in self.workers or \
                not self.workers[wid].view.alive:
            if req not in self.global_queue:
                self.global_queue.append(req)
            return
        if req in self.global_queue:
            self.global_queue.remove(req)
        self.workers[wid].admit_prefill(req, self.now)
        self._kick(wid)

    def _kick(self, wid: int) -> None:
        """Start an iteration on a now-idle worker if it has work."""
        w = self.workers[wid]
        if self._worker_busy[wid] or not w.view.alive:
            return
        head = w.prefill_queue[0] if w.prefill_queue else None
        rule = self.policy.batch_rule(w.view, self.now, head)
        plan = w.compose_iteration(rule, self.now)
        if plan.empty:
            return
        dur = self.duration_fn(w, plan)
        self._worker_busy[wid] = True
        self.push("iter_done", self.now + dur, (wid, plan, dur))

    def _on_iter_done(self, ev: _Event) -> None:
        wid, plan, dur = ev.payload
        w = self.workers[wid]
        self._worker_busy[wid] = False
        if not w.view.alive:
            return
        finished_prefills = w.complete_iteration(plan, self.now, dur)
        for req in finished_prefills:
            self._route_decode(w, req)
        # watermark evictions re-enter global dispatch (re-prefill cost)
        for req in w.drain_preempted():
            self._try_dispatch(req)
        # retry the global queue now that state changed
        for req in list(self.global_queue):
            self._try_dispatch(req)
        self._kick(wid)

    def _route_decode(self, src: Worker, req: Request) -> None:
        target = self.policy.dispatch_decode(req, self.now)
        if target is None or target == src.wid:
            src.admit_decode(req, self.now)
            self._kick(src.wid)
            return
        # KV migration: src frees; target admits when the bytes have crossed
        # the (possibly contended) ICI links
        req.migrations += 1
        req.phase = Phase.MIGRATING
        src.release(req)
        if self.transfer is None:
            delay = src.cost.migration_time(req.context_len)
            self.push("migration_done", self.now + delay,
                      (target, req, self.now))
            return
        nbytes = src.cost.kv_transfer_bytes(req.context_len)
        self.transfer.start(src.wid, target, nbytes, self.now,
                            payload=(target, req, self.now))
        self._schedule_transfer_tick()

    # -------------------------------------------------- contended transfers
    def _schedule_transfer_tick(self) -> None:
        t = self.transfer.next_completion()
        if t is not None:
            self.push("transfer_tick", max(t, self.now),
                      self.transfer.version)

    def _on_transfer_tick(self, ev: _Event) -> None:
        if ev.payload != self.transfer.version:
            return                           # rates changed since scheduling
        for flow in self.transfer.pop_completed(self.now):
            latency = self.transfer.delivery_latency(flow.src)
            self.push("migration_done", self.now + latency, flow.payload)
        self._schedule_transfer_tick()

    def _on_migration_done(self, ev: _Event) -> None:
        wid, req, started = ev.payload
        wait = self.now - started
        req.migration_wait += wait
        if req.generated_tokens > 0:
            # the user is mid-stream: time on the wire is inter-token
            # latency — it burns TPOT budget exactly like a stalled
            # iteration (this is the D->P/P->D asymmetry cost the paper's
            # toggle avoids by keeping decodes in place)
            req.decode_time += wait
            req.tpot_slack -= wait
        w = self.workers.get(wid)
        if w is None or not w.view.alive or \
                not w.admit_migrated(req, self.now):
            req.restarts += 1
            req.reset_for_reprefill(self.now)
            self._try_dispatch(req)
            return
        self._kick(wid)

    def _on_fail(self, ev: _Event) -> None:
        wid, recover_after = ev.payload
        w = self.workers.get(wid)
        if w is None:
            return
        lost = w.fail(self.now)
        self.policy.on_worker_failure(wid)
        if self.transfer is not None:
            # KV in flight to OR from the dead worker is lost: restart
            for flow in self.transfer.drop_flows_touching(wid, self.now):
                _, req, started = flow.payload
                req.migration_wait += self.now - started
                req.restarts += 1
                req.reset_for_reprefill(self.now)
                lost.append(req)
            self._schedule_transfer_tick()
        for r in lost:
            if r.phase != Phase.FINISHED:
                self._try_dispatch(r)
        if recover_after is not None:
            self.push("recover", self.now + recover_after, wid)

    def _on_recover(self, ev: _Event) -> None:
        wid = ev.payload
        w = self.workers.get(wid)
        if w is None:
            return
        w.view.alive = True
        for req in list(self.global_queue):
            self._try_dispatch(req)
        self._kick(wid)

    def _on_add_worker(self, ev: _Event) -> None:
        w: Worker = ev.payload
        self.workers[w.wid] = w
        self._worker_busy[w.wid] = False
        if self.transfer is not None:
            self.transfer.add_worker(
                w.wid, LinkSpec.from_hardware(w.cost.worker.hw))
        self.policy.workers[w.wid] = w.view
        if hasattr(self.policy, "toggle"):
            self.policy.toggle.workers[w.wid] = w.view
        for req in list(self.global_queue):
            self._try_dispatch(req)


def build_cluster(cfg, policy_name: str, n_workers: int = 4,
                  worker_spec=None, predictor=None,
                  use_transfer_engine: bool = True,
                  ici_bw: Optional[float] = None,
                  ici_links: Optional[int] = None,
                  page_size: int = 16, **policy_kw):
    """Convenience: workers + cost models + policy, wired together.

    ``ici_bw``/``ici_links`` override the per-worker migration link model
    (bytes/s per link, link count); ``use_transfer_engine=False`` reverts
    to the seed's fixed uncontended ``migration_time`` delay."""
    from repro.core.predictor import AnalyticalPredictor
    from repro.core.policies import make_policy
    from repro.serving.costmodel import WorkerSpec

    worker_spec = worker_spec or WorkerSpec()
    if ici_bw is not None or ici_links is not None:
        hw = dataclasses.replace(
            worker_spec.hw,
            ici_bw=ici_bw if ici_bw is not None else worker_spec.hw.ici_bw,
            ici_links=(ici_links if ici_links is not None
                       else worker_spec.hw.ici_links))
        worker_spec = dataclasses.replace(worker_spec, hw=hw)
    cost = CostModel(cfg, worker_spec, page_size=page_size)
    workers = [Worker(i, cost) for i in range(n_workers)]
    predictor = predictor or AnalyticalPredictor(cost)
    policy = make_policy(policy_name, [w.view for w in workers], predictor,
                         **policy_kw)
    transfer = TransferEngine() if use_transfer_engine else None
    policy.attach_transfer(transfer, cost.kv_transfer_bytes,
                           cost.state_tokens)
    for w in workers:
        w.queue_discipline = policy.queue_discipline
    sim = Simulator(workers, policy, transfer=transfer)
    return sim, cost
