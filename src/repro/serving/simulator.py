"""Discrete-event driver for the unified ClusterScheduler.

The Simulator owns exactly two things: the event heap and the clock. Every
scheduling decision — dispatch, batch composition, decode routing, role
lifecycle, predictor feedback — lives in ``repro.sched.ClusterScheduler``
and is byte-for-byte the code path the real-JAX executor drives (see
``repro.sched.backend.ExecutionBackend``); only the backend's notion of an
iteration duration differs. ``tests/test_sched_core.py`` pins that parity.

Events: request arrival, per-worker iteration completion, migration
completion, transfer ticks, worker failure/recovery, elastic worker
addition, role-rebalance reviews.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Optional, Sequence

from repro.core.metrics import ServeMetrics
from repro.core.policies import Policy
from repro.core.request import Request
from repro.sched.backend import (CallableBackend, ExecutionBackend,
                                 TraceReplayBackend)
from repro.sched.core import ClusterScheduler
from repro.sched.rebalance import RebalanceConfig, RoleRebalancer
from repro.serving.engine import Worker


# heap entries are plain tuples ``(time, seq, kind, payload)`` — the seq
# counter is unique, so comparisons never reach kind/payload and the heap
# skips dataclass dispatch entirely (this is the hottest allocation in a
# large simulation)
class Simulator:
    def __init__(self, workers: Sequence[Worker], policy: Policy,
                 duration_fn: Optional[Callable] = None,
                 transfer=None,
                 backend: Optional[ExecutionBackend] = None,
                 rebalancer: Optional[RoleRebalancer] = None,
                 drift_monitor=None,
                 record_decisions: bool = False):
        """``backend`` supplies iteration durations (and execution, for the
        real-JAX backend); default = the analytical cost model.
        ``duration_fn(worker, plan) -> seconds`` is the legacy hook and
        wraps into a ``CallableBackend`` over ``backend``.

        ``transfer``: bandwidth-contended KV migration engine. None keeps
        the legacy fixed-delay ``CostModel.migration_time`` path.

        ``drift_monitor``: optional ``repro.perf.recalibrate.DriftMonitor``
        fed every observed iteration for online γ/MFU recalibration."""
        if duration_fn is not None:
            backend = CallableBackend(duration_fn, base=backend)
        self.sched = ClusterScheduler(
            workers, policy, backend=backend, transfer=transfer,
            rebalancer=rebalancer, drift_monitor=drift_monitor,
            record_decisions=record_decisions)
        self.sched.bind(self.push)
        self.now = 0.0
        self._heap: list[tuple] = []
        self._seq = itertools.count()
        self.max_sim_time = float("inf")
        self._replay: Optional[TraceReplayBackend] = None

    # ------------------------------------------------- scheduler passthrough
    @property
    def workers(self) -> dict[int, Worker]:
        return self.sched.workers

    @property
    def policy(self) -> Policy:
        return self.sched.policy

    @property
    def transfer(self):
        return self.sched.transfer

    @property
    def requests(self) -> list[Request]:
        return self.sched.requests

    @property
    def global_queue(self) -> dict[int, Request]:
        return self.sched.global_queue

    @property
    def decisions(self):
        return self.sched.decisions

    @property
    def duration_fn(self) -> Callable:
        backend = self.sched.backend
        return lambda worker, plan: backend.run_iteration(worker, plan)

    @duration_fn.setter
    def duration_fn(self, fn: Callable) -> None:
        # layer the raw clock over the current backend so lifecycle hooks
        # (slot teardown, KV materialisation) keep firing
        self.sched.backend = CallableBackend(fn, base=self.sched.backend)

    # ----------------------------------------------------------------- api
    def push(self, kind: str, time: float, payload=None) -> None:
        heapq.heappush(self._heap, (time, next(self._seq), kind, payload))

    def add_trace(self, requests: Sequence[Request]) -> None:
        for r in requests:
            self.push("arrival", r.arrival_time, r)

    def add_replay(self, replay) -> None:
        """Stream arrivals lazily from a ``TraceReplayBackend`` (or any
        ``(arrival_time, Request)`` iterator, which is wrapped in one over
        the current backend). Exactly one pending arrival sits in the heap
        at a time; each processed arrival pulls the next — a recorded
        production trace replays in constant memory."""
        if not isinstance(replay, TraceReplayBackend) \
                and not hasattr(replay, "next_arrival"):
            replay = TraceReplayBackend(replay, inner=self.sched.backend)
        elif getattr(replay, "inner_defaulted", False):
            # a bare TraceReplayBackend(feed) adopts the simulator's
            # configured clock instead of discarding it for the default
            replay.inner = self.sched.backend
            replay.inner_defaulted = False
        self._replay = replay
        self.sched.backend = replay
        nxt = replay.next_arrival()
        if nxt is not None:
            self.push("replay_next", nxt[0], nxt[1])

    def inject_failure(self, time: float, wid: int,
                       recover_after: Optional[float] = None) -> None:
        self.push("fail", time, (wid, recover_after))

    def add_worker_at(self, time: float, worker: Worker) -> None:
        self.push("add_worker", time, worker)

    # ---------------------------------------------------------------- loop
    def run(self, until: Optional[float] = None) -> ServeMetrics:
        """Drain the heap. Events sharing a timestamp are popped as one
        batch and handed to ``ClusterScheduler.handle_batch`` (same-kind
        runs share one handler dispatch). The total processing order is
        identical to one-at-a-time pops: the batch is drained in seq
        order, and any event a handler pushes at the *same* timestamp gets
        a strictly higher seq than everything drained — the outer loop
        re-drains it as the next batch, exactly where the one-at-a-time
        loop would have popped it."""
        if until is not None:
            self.max_sim_time = until
        heap = self._heap
        pop = heapq.heappop
        handle_batch = self.sched.handle_batch
        max_t = self.max_sim_time
        batch: list[tuple] = []
        while heap:
            t = heap[0][0]
            if t > max_t:
                break
            self.now = t
            batch.clear()
            while heap and heap[0][0] == t:
                batch.append(pop(heap))
            i, m = 0, len(batch)
            while i < m:
                if batch[i][2] == "replay_next":
                    # driver-level streaming arrival: hand it to the
                    # scheduler, then pull the next from the replay
                    # iterator (a same-t successor re-drains next round)
                    self.sched.handle("arrival", t, batch[i][3])
                    nxt = self._replay.next_arrival()
                    if nxt is not None:
                        self.push("replay_next", nxt[0], nxt[1])
                    i += 1
                    continue
                j = i + 1
                while j < m and batch[j][2] != "replay_next":
                    j += 1
                handle_batch(t, batch[i:j])
                i = j
        return self.metrics()

    def metrics(self) -> ServeMetrics:
        return self.sched.metrics()


def build_cluster(cfg, policy_name: str, n_workers: int = 4,
                  worker_spec=None, predictor=None,
                  use_transfer_engine: bool = True,
                  ici_bw: Optional[float] = None,
                  ici_links: Optional[int] = None,
                  page_size: int = 16,
                  online_predictor: bool = False,
                  recalibrate_every: Optional[int] = None,
                  per_worker_calibration: str | bool = "auto",
                  worker_specs: Optional[Sequence] = None,
                  role_rebalance: str | bool = "auto",
                  rebalance_config: Optional[RebalanceConfig] = None,
                  record_decisions: bool = False,
                  backend: Optional[ExecutionBackend] = None,
                  host_kv_gb: float = 0.0,
                  prefix_cache: bool = False,
                  prefix_cache_frac: float = 0.2,
                  vectorized: bool = True,
                  **policy_kw):
    """Convenience: workers + cost models + policy + scheduler, wired.

    ``worker_specs``: one ``WorkerSpec`` per worker for heterogeneous
    clusters (mixed chip generations, degraded stragglers) — each worker
    gets its own ``CostModel``, the default predictor becomes a per-worker
    ``ClusterPredictor``, and every ``WorkerView.speed`` carries the
    worker's relative throughput so load comparisons price work on the
    target's hardware. Omitted (the homogeneous default) every speed is
    exactly 1.0 and all decisions are bit-identical to the global-spec
    scheduler.

    ``ici_bw``/``ici_links`` override the per-worker migration link model
    (bytes/s per link, link count); ``use_transfer_engine=False`` reverts
    to the seed's fixed uncontended ``migration_time`` delay.

    ``online_predictor=True`` wraps the predictor in an ``OnlinePredictor``
    so observed iteration durations EWMA-correct its estimates;
    ``per_worker_calibration``: "auto" (per-worker EWMA exactly when the
    cluster is heterogeneous), True/False to force.
    ``recalibrate_every=N`` arms a ``DriftMonitor`` that re-fits the
    per-bucket interference γ and nudges the measured MFU/bandwidth
    constants on the worker cost models every N observed iterations
    (None = legacy calibrate-once; a drift-free clock makes it a
    bit-exact no-op). Combined with an observing predictor
    (``online_predictor=True``) the monitor re-fits γ only — efficiency
    drift stays the predictor's job, so the two loops never correct the
    same error twice.
    ``role_rebalance``: "auto" (windowed-attainment rebalancing for
    policies that own a toggle, i.e. tropical), True (same, but a
    ValueError on policies without role lifecycle), or False (keep the
    legacy dispatch-count ``review_roles`` side effect).

    ``host_kv_gb``: per-worker host-DRAM KV tier (GB). 0 (default) keeps
    the seed's evict + full re-prefill watermark behaviour bit-exact;
    > 0 lets watermark victims offload over the host DMA link when the
    predictor prices restore below re-prefill.
    ``prefix_cache=True`` arms a per-worker cross-request prefix cache
    (LRU over at most ``prefix_cache_frac`` of HBM pages): requests
    sharing a workload-tagged system prompt skip the cached span of
    prefill.
    ``vectorized`` (default True) switches the scheduler hot path to the
    batched implementations: dispatch prices a candidate against every
    worker in one numpy evaluation (``Predictor.predict_*_batch``), the
    cost model memoizes per-signature iteration times, and workers run
    their fast bookkeeping paths. Decisions are bit-identical either way
    (tests/test_vectorized.py pins it); ``vectorized=False`` keeps the
    per-worker scalar loops — the reference the scale benchmark's
    sim-throughput speedup is measured against."""
    from repro.core.policies import make_policy
    from repro.perf import (AnalyticalPredictor, ClusterPredictor, CostModel,
                            OnlinePredictor, WorkerSpec, relative_speeds)
    from repro.serving.kvcache import PrefixIndex
    from repro.serving.transfer import TransferEngine

    worker_spec = worker_spec or WorkerSpec()
    specs = list(worker_specs) if worker_specs is not None \
        else [worker_spec] * n_workers
    if len(specs) != n_workers:
        raise ValueError(f"worker_specs has {len(specs)} entries for "
                         f"{n_workers} workers")
    if ici_bw is not None or ici_links is not None:
        specs = [dataclasses.replace(s, hw=dataclasses.replace(
            s.hw,
            ici_bw=ici_bw if ici_bw is not None else s.hw.ici_bw,
            ici_links=(ici_links if ici_links is not None
                       else s.hw.ici_links))) for s in specs]
    heterogeneous = len(set(specs)) > 1
    cost = CostModel(cfg, specs[0], page_size=page_size)
    if heterogeneous:
        costs = {i: CostModel(cfg, s, page_size=page_size)
                 for i, s in enumerate(specs)}
    else:
        costs = {i: cost for i in range(n_workers)}
    workers = [
        Worker(i, costs[i],
               host_pages=costs[i].host_capacity_pages(host_kv_gb * 1e9),
               prefix_cache=PrefixIndex(max_pages=int(
                   prefix_cache_frac * costs[i].kv_capacity_pages()))
               if prefix_cache else None)
        for i in range(n_workers)]
    for wid, speed in relative_speeds(costs).items():
        workers[wid].view.speed = speed
    if predictor is None:
        predictor = ClusterPredictor(costs) if heterogeneous \
            else AnalyticalPredictor(cost)
    if online_predictor and not hasattr(predictor, "observe_iteration"):
        per_worker = heterogeneous if per_worker_calibration == "auto" \
            else bool(per_worker_calibration)
        predictor = OnlinePredictor(predictor, per_worker=per_worker)
    if host_kv_gb > 0:
        # offload only when the predictor prices restore (wire + residue)
        # below a full re-prefill of the same context — the ISSUE's tier
        # decision rule, evaluated per victim at preemption time
        def _gate(req, _p=predictor, _w=None):
            return _p.predict_restore(req.context_len, wid=_w) \
                < _p.predict_prefill(req.context_len, wid=_w)
        for w in workers:
            w.offload_gate = \
                lambda req, _p=predictor, _w=w.wid: _gate(req, _p, _w)
    policy = make_policy(policy_name, [w.view for w in workers], predictor,
                         **policy_kw)
    if vectorized:
        policy.vectorized = True
        if getattr(policy, "toggle", None) is not None:
            policy.toggle.vectorized = True
        for w in workers:
            w.fast = True
        for c in costs.values():
            c.cached = True      # idempotent on the shared homogeneous model
    transfer = TransferEngine() if use_transfer_engine else None
    policy.attach_transfer(transfer, cost.kv_transfer_bytes,
                           cost.state_tokens)
    for w in workers:
        w.queue_discipline = policy.queue_discipline

    rebalancer = None
    has_toggle = getattr(policy, "toggle", None) is not None
    if role_rebalance is True and not has_toggle:
        raise ValueError(
            f"role_rebalance=True requires a policy with role lifecycle "
            f"(a MultiplexingToggle); {policy.name!r} has none")
    if has_toggle and (role_rebalance is True or role_rebalance == "auto"):
        rebalancer = RoleRebalancer(rebalance_config or RebalanceConfig(
            hbm_watermark=policy.toggle.cfg.hbm_watermark))
        # role lifecycle is now event-driven at the scheduler: turn off the
        # toggle's dispatch-count review side effect
        policy.toggle.cfg = dataclasses.replace(
            policy.toggle.cfg, role_transitions=False)

    drift_monitor = None
    if recalibrate_every is not None:
        from repro.perf.recalibrate import DriftMonitor
        # an observing predictor (OnlinePredictor) already EWMA-corrects
        # efficiency drift at the prediction layer; folding the same drift
        # into the model too would double-correct until the predictor's
        # scales decay back — so the monitor then re-fits γ only (the one
        # axis the predictor cannot learn)
        drift_monitor = DriftMonitor(
            costs, every=recalibrate_every,
            adjust_efficiency=not hasattr(predictor, "observe_iteration"))

    sim = Simulator(workers, policy, transfer=transfer, backend=backend,
                    rebalancer=rebalancer, drift_monitor=drift_monitor,
                    record_decisions=record_decisions)
    return sim, cost
