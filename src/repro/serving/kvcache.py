"""Paged KV-cache management.

``BlockAllocator`` is the accounting layer the engine/toggle use for the
HBM watermark (§IV-C: "the multiplexing toggle records the status of each
worker, including monitoring the HBM watermark"). ``PagedKVStore`` is the
physical page pool consumed by the Pallas paged_attention kernel — pages
are allocated per request, the block table provides the indirection.

Two beyond-paper production mechanisms live here as well:

* **Tiered KV (HBM → host DRAM)** — ``PageAccountant`` optionally grows a
  second, host-DRAM tier behind the HBM pool. Watermark-crossing decodes
  *offload* their pages (``offload``/``restore``) instead of discarding
  them for a full re-prefill; the engine moves the bytes over the
  contended ``TransferEngine`` host link and the toggle prices the
  restore cost into its slack math (``Predictor.predict_restore``). A
  zero-size host tier is bit-exact with the evict+re-prefill accountant.
* **Cross-request prefix reuse** — ``PrefixIndex`` is a per-worker LRU of
  cached prompt prefixes (shared system prompts): requests carrying a
  matching ``prefix_key`` skip the cached span of prefill and borrow the
  cached pages under a refcount, so an entry can never be evicted out
  from under a mid-decode borrower (LLMServe-style prefix awareness with
  a hit-rate estimator feeding dispatch scores).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def pages_for(tokens: int, page_size: int) -> int:
    """Pages covering ``tokens`` (ceil-div) — THE page-rounding rule.
    Every admission gate and allocator must share it, or the scheduler
    admits what the pool then rejects."""
    return -(-max(int(tokens), 0) // max(int(page_size), 1))


class PageAccountant:
    """Counts-only page-granular KV accounting for scheduler admission.

    ``BlockAllocator`` below hands out physical page *ids* for the Pallas
    kernel's block tables; the scheduler does not need ids, only truthful
    arithmetic: how many pages a request pins (ceil of its token footprint),
    how many remain allocatable, and how much of the pool is internal
    fragmentation (allocated-but-unwritten page tails). The engine keeps one
    accountant per worker so the toggle's §IV-B admission checks gate on
    real allocatable pages rather than a token counter that ignores block
    rounding."""

    def __init__(self, total_pages: int, page_size: int,
                 host_pages: int = 0):
        self.total_pages = int(total_pages)
        self.page_size = int(page_size)
        self._pages: dict[int, int] = {}    # rid -> pages held (HBM)
        self._tokens: dict[int, int] = {}   # rid -> tokens covered (HBM)
        # Host-DRAM tier: same page arithmetic, second pool. 0 == disabled
        # and every tier method below degenerates to a no-op/False, keeping
        # the single-tier accountant bit-exact.
        self.host_total_pages = int(host_pages)
        self._host_pages: dict[int, int] = {}
        self._host_tokens: dict[int, int] = {}
        # maintained totals: admission checks read used/free pages on every
        # dispatch, which must not re-sum the per-rid dicts each time
        self._used = 0
        self._host_used = 0

    # ---------------------------------------------------------------- query
    @property
    def used_pages(self) -> int:
        return self._used

    @property
    def free_pages(self) -> int:
        return self.total_pages - self.used_pages

    @property
    def utilization(self) -> float:
        return self.used_pages / max(self.total_pages, 1)

    @property
    def host_used_pages(self) -> int:
        return self._host_used

    @property
    def host_free_pages(self) -> int:
        return self.host_total_pages - self.host_used_pages

    @property
    def fragmentation(self) -> float:
        """Fraction of *used* pool bytes that are allocated page tails no
        token occupies (0 when every page is exactly full)."""
        used_tok = self.used_pages * self.page_size
        if used_tok == 0:
            return 0.0
        return 1.0 - sum(self._tokens.values()) / used_tok

    def pages_for(self, tokens: int) -> int:
        return pages_for(tokens, self.page_size)

    def can_fit(self, tokens: int, rid: Optional[int] = None) -> bool:
        held = self._pages.get(rid, 0) if rid is not None else 0
        return self.pages_for(tokens) - held <= self.free_pages

    # ------------------------------------------------------------- mutation
    def reserve(self, rid: int, tokens: int) -> bool:
        """Grow ``rid``'s allocation to cover ``tokens`` total. False (and
        no state change) when the pool cannot supply the growth."""
        tokens = max(int(tokens), 0)
        need = self.pages_for(tokens) - self._pages.get(rid, 0)
        if need > self.free_pages:
            return False
        grow = max(0, need)
        self._pages[rid] = self._pages.get(rid, 0) + grow
        self._used += grow
        self._tokens[rid] = max(self._tokens.get(rid, 0), tokens)
        return True

    def release(self, rid: int) -> int:
        """Free every page held by ``rid`` in BOTH tiers; returns the HBM
        page count (host pages, if any, are freed silently — a finished or
        restarted request must never leave residue in either pool)."""
        self._tokens.pop(rid, None)
        self._host_tokens.pop(rid, None)
        self._host_used -= self._host_pages.pop(rid, 0)
        pages = self._pages.pop(rid, 0)
        self._used -= pages
        return pages

    def held_pages(self, rid: int) -> int:
        return self._pages.get(rid, 0)

    def reset(self) -> None:
        self._pages.clear()
        self._tokens.clear()
        self._host_pages.clear()
        self._host_tokens.clear()
        self._used = 0
        self._host_used = 0

    # ------------------------------------------------------- host-DRAM tier
    def can_offload(self, rid: int) -> bool:
        """Would ``offload(rid)`` succeed right now?"""
        pages = self._pages.get(rid, 0)
        return (pages > 0 and self.host_total_pages > 0
                and pages + self._host_pages.get(rid, 0)
                <= self.host_free_pages + self._host_pages.get(rid, 0))

    def offload(self, rid: int) -> int:
        """Move ``rid``'s HBM pages into the host tier (accounting only —
        the engine moves the bytes over the host link). Returns the page
        count moved, 0 (no state change) if the host tier lacks room."""
        if not self.can_offload(rid):
            return 0
        pages = self._pages.pop(rid)
        tokens = self._tokens.pop(rid, 0)
        self._used -= pages
        self._host_used += pages
        self._host_pages[rid] = self._host_pages.get(rid, 0) + pages
        self._host_tokens[rid] = max(self._host_tokens.get(rid, 0), tokens)
        return pages

    def can_restore(self, rid: int) -> bool:
        return (self._host_pages.get(rid, 0) > 0
                and self._host_pages[rid] <= self.free_pages)

    def restore(self, rid: int) -> int:
        """Move ``rid``'s host-tier pages back into HBM. Returns the page
        count moved, 0 (no state change) if HBM cannot hold them."""
        if not self.can_restore(rid):
            return 0
        pages = self._host_pages.pop(rid)
        tokens = self._host_tokens.pop(rid, 0)
        self._host_used -= pages
        self._used += pages
        self._pages[rid] = self._pages.get(rid, 0) + pages
        self._tokens[rid] = max(self._tokens.get(rid, 0), tokens)
        return pages

    def host_held_pages(self, rid: int) -> int:
        return self._host_pages.get(rid, 0)


@dataclasses.dataclass
class CachedPrefix:
    """One shared-prompt span resident in a worker's HBM page pool.

    ``rid`` is a negative pseudo request-id the cache pins its pages under
    in the worker's ``PageAccountant`` (request rids are non-negative, so
    the namespaces never collide). ``refs`` counts borrowers currently
    decoding on top of this span — eviction is refused while refs > 0."""
    key: int
    tokens: int
    rid: int
    pages: int
    refs: int = 0
    last_use: int = 0


class PrefixIndex:
    """Per-worker LRU index of cached prompt prefixes.

    Counts-only, like ``PageAccountant``: entries pin pages under pseudo
    rids; the worker charges/releases the actual pool. Keeps both lifetime
    hit counters and an EWMA hit-rate estimator (the dispatch-score signal,
    in the spirit of LLMServe's prefix-awareness scorer)."""

    def __init__(self, max_pages: int, ewma_alpha: float = 0.05):
        self.max_pages = int(max_pages)
        self.ewma_alpha = float(ewma_alpha)
        self._entries: dict[int, CachedPrefix] = {}   # key -> entry
        self._seq = itertools.count(1)
        self._rids = itertools.count(1)
        self.lookups = 0
        self.hits = 0
        self.hit_ewma = 0.0
        # bumped on every change to the {key: tokens} content, so view
        # refreshes can skip rebuilding ``spans()`` when nothing moved
        self.version = 0

    # ---------------------------------------------------------------- query
    @property
    def used_pages(self) -> int:
        return sum(e.pages for e in self._entries.values())

    @property
    def hit_rate(self) -> float:
        """Lifetime hit rate (0 before any lookup)."""
        return self.hits / self.lookups if self.lookups else 0.0

    def peek(self, key: int) -> int:
        """Cached span (tokens) for ``key`` WITHOUT touching counters or
        LRU order — admission checks may probe repeatedly."""
        e = self._entries.get(key)
        return e.tokens if e is not None else 0

    def spans(self) -> dict[int, int]:
        """{key: tokens} snapshot for the WorkerView (dispatch scoring)."""
        return {k: e.tokens for k, e in self._entries.items()}

    # ------------------------------------------------------------- mutation
    def lookup(self, key: int) -> Optional[CachedPrefix]:
        """Counted lookup: bumps LRU recency and the hit-rate estimator."""
        self.lookups += 1
        e = self._entries.get(key)
        hit = 1.0 if e is not None else 0.0
        self.hit_ewma += self.ewma_alpha * (hit - self.hit_ewma)
        if e is not None:
            self.hits += 1
            e.last_use = next(self._seq)
        return e

    def insert(self, key: int, tokens: int, pages: int) -> CachedPrefix:
        """Register a new cached span; caller has already reserved
        ``pages`` in the pool under the returned entry's pseudo rid."""
        e = CachedPrefix(key=key, tokens=int(tokens), rid=-next(self._rids),
                         pages=int(pages), last_use=next(self._seq))
        self._entries[key] = e
        self.version += 1
        return e

    def unref(self, key: int) -> None:
        e = self._entries.get(key)
        if e is not None and e.refs > 0:
            e.refs -= 1

    def evict_lru(self) -> Optional[CachedPrefix]:
        """Pop the least-recently-used UNREFERENCED entry (caller frees its
        pages). Entries with live borrowers are never evicted — a borrower
        mid-decode must not have its prefix pages dangle."""
        victim = None
        for e in self._entries.values():
            if e.refs == 0 and (victim is None or e.last_use < victim.last_use):
                victim = e
        if victim is not None:
            del self._entries[victim.key]
            self.version += 1
        return victim

    def clear(self) -> list[CachedPrefix]:
        """Drop every entry (worker failure: HBM content is gone)."""
        dropped = list(self._entries.values())
        self._entries.clear()
        if dropped:
            self.version += 1
        return dropped


class BlockAllocator:
    """Free-list page allocator with watermark accounting."""

    def __init__(self, n_blocks: int, block_size: int):
        self.n_blocks = n_blocks
        self.block_size = block_size
        self._free = list(range(n_blocks - 1, -1, -1))
        self.allocated: dict[int, list[int]] = {}   # rid -> pages

    # ---------------------------------------------------------------- query
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.n_blocks - len(self._free)

    @property
    def utilization(self) -> float:
        return self.used_blocks / max(self.n_blocks, 1)

    def blocks_for(self, tokens: int) -> int:
        return -(-tokens // self.block_size)

    def can_fit(self, tokens: int) -> bool:
        return self.blocks_for(tokens) <= self.free_blocks

    # ------------------------------------------------------------- mutation
    def allocate(self, rid: int, tokens: int) -> Optional[list[int]]:
        need = self.blocks_for(tokens) - len(self.allocated.get(rid, []))
        if need > len(self._free):
            return None
        pages = self.allocated.setdefault(rid, [])
        for _ in range(max(0, need)):
            pages.append(self._free.pop())
        return pages

    def extend(self, rid: int, new_total_tokens: int) -> bool:
        """Grow a request's allocation to cover ``new_total_tokens``."""
        return self.allocate(rid, new_total_tokens) is not None

    def release(self, rid: int) -> None:
        for p in self.allocated.pop(rid, []):
            self._free.append(p)

    def table(self, rid: int, max_pages: int) -> np.ndarray:
        pages = self.allocated.get(rid, [])
        t = np.full((max_pages,), -1, np.int32)
        t[: len(pages)] = pages[:max_pages]
        return t


@dataclasses.dataclass
class PagedKVStore:
    """Physical page pool: (L, n_pages, page_size, Hkv, D) per K and V.

    Feeds kernels/paged_attention.py; append writes go through
    ``write_tokens`` (host-side for the CPU real-executor; on TPU the
    engine fuses the write into the decode step)."""

    k_pages: jax.Array
    v_pages: jax.Array
    allocator: BlockAllocator

    @classmethod
    def create(cls, num_layers: int, n_pages: int, page_size: int,
               num_kv_heads: int, head_dim: int, dtype=jnp.bfloat16):
        shape = (num_layers, n_pages, page_size, num_kv_heads, head_dim)
        return cls(
            k_pages=jnp.zeros(shape, dtype),
            v_pages=jnp.zeros(shape, dtype),
            allocator=BlockAllocator(n_pages, page_size),
        )

    def write_tokens(self, rid: int, pos: int, k: jax.Array, v: jax.Array):
        """k/v: (L, T, Hkv, D) new tokens for request ``rid`` starting at
        logical position ``pos``. Allocates pages as needed."""
        t = k.shape[1]
        ps = self.allocator.block_size
        if not self.allocator.extend(rid, pos + t):
            raise MemoryError(f"KV pool exhausted for rid={rid}")
        pages = self.allocator.allocated[rid]
        kp, vp = self.k_pages, self.v_pages
        for i in range(t):
            logical = pos + i
            page = pages[logical // ps]
            off = logical % ps
            kp = kp.at[:, page, off].set(k[:, i])
            vp = vp.at[:, page, off].set(v[:, i])
        self.k_pages, self.v_pages = kp, vp

    def gather_dense(self, rid: int, length: int):
        """(L, length, Hkv, D) dense view for testing."""
        ps = self.allocator.block_size
        pages = self.allocator.allocated[rid]
        k = jnp.concatenate([self.k_pages[:, p] for p in pages], axis=1)
        v = jnp.concatenate([self.v_pages[:, p] for p in pages], axis=1)
        return k[:, :length], v[:, :length]
