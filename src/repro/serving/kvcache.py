"""Paged KV-cache management.

``BlockAllocator`` is the accounting layer the engine/toggle use for the
HBM watermark (§IV-C: "the multiplexing toggle records the status of each
worker, including monitoring the HBM watermark"). ``PagedKVStore`` is the
physical page pool consumed by the Pallas paged_attention kernel — pages
are allocated per request, the block table provides the indirection.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def pages_for(tokens: int, page_size: int) -> int:
    """Pages covering ``tokens`` (ceil-div) — THE page-rounding rule.
    Every admission gate and allocator must share it, or the scheduler
    admits what the pool then rejects."""
    return -(-max(int(tokens), 0) // max(int(page_size), 1))


class PageAccountant:
    """Counts-only page-granular KV accounting for scheduler admission.

    ``BlockAllocator`` below hands out physical page *ids* for the Pallas
    kernel's block tables; the scheduler does not need ids, only truthful
    arithmetic: how many pages a request pins (ceil of its token footprint),
    how many remain allocatable, and how much of the pool is internal
    fragmentation (allocated-but-unwritten page tails). The engine keeps one
    accountant per worker so the toggle's §IV-B admission checks gate on
    real allocatable pages rather than a token counter that ignores block
    rounding."""

    def __init__(self, total_pages: int, page_size: int):
        self.total_pages = int(total_pages)
        self.page_size = int(page_size)
        self._pages: dict[int, int] = {}    # rid -> pages held
        self._tokens: dict[int, int] = {}   # rid -> tokens covered

    # ---------------------------------------------------------------- query
    @property
    def used_pages(self) -> int:
        return sum(self._pages.values())

    @property
    def free_pages(self) -> int:
        return self.total_pages - self.used_pages

    @property
    def utilization(self) -> float:
        return self.used_pages / max(self.total_pages, 1)

    @property
    def fragmentation(self) -> float:
        """Fraction of *used* pool bytes that are allocated page tails no
        token occupies (0 when every page is exactly full)."""
        used_tok = self.used_pages * self.page_size
        if used_tok == 0:
            return 0.0
        return 1.0 - sum(self._tokens.values()) / used_tok

    def pages_for(self, tokens: int) -> int:
        return pages_for(tokens, self.page_size)

    def can_fit(self, tokens: int, rid: Optional[int] = None) -> bool:
        held = self._pages.get(rid, 0) if rid is not None else 0
        return self.pages_for(tokens) - held <= self.free_pages

    # ------------------------------------------------------------- mutation
    def reserve(self, rid: int, tokens: int) -> bool:
        """Grow ``rid``'s allocation to cover ``tokens`` total. False (and
        no state change) when the pool cannot supply the growth."""
        tokens = max(int(tokens), 0)
        need = self.pages_for(tokens) - self._pages.get(rid, 0)
        if need > self.free_pages:
            return False
        self._pages[rid] = self._pages.get(rid, 0) + max(0, need)
        self._tokens[rid] = max(self._tokens.get(rid, 0), tokens)
        return True

    def release(self, rid: int) -> int:
        """Free every page held by ``rid``; returns the page count."""
        self._tokens.pop(rid, None)
        return self._pages.pop(rid, 0)

    def reset(self) -> None:
        self._pages.clear()
        self._tokens.clear()


class BlockAllocator:
    """Free-list page allocator with watermark accounting."""

    def __init__(self, n_blocks: int, block_size: int):
        self.n_blocks = n_blocks
        self.block_size = block_size
        self._free = list(range(n_blocks - 1, -1, -1))
        self.allocated: dict[int, list[int]] = {}   # rid -> pages

    # ---------------------------------------------------------------- query
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.n_blocks - len(self._free)

    @property
    def utilization(self) -> float:
        return self.used_blocks / max(self.n_blocks, 1)

    def blocks_for(self, tokens: int) -> int:
        return -(-tokens // self.block_size)

    def can_fit(self, tokens: int) -> bool:
        return self.blocks_for(tokens) <= self.free_blocks

    # ------------------------------------------------------------- mutation
    def allocate(self, rid: int, tokens: int) -> Optional[list[int]]:
        need = self.blocks_for(tokens) - len(self.allocated.get(rid, []))
        if need > len(self._free):
            return None
        pages = self.allocated.setdefault(rid, [])
        for _ in range(max(0, need)):
            pages.append(self._free.pop())
        return pages

    def extend(self, rid: int, new_total_tokens: int) -> bool:
        """Grow a request's allocation to cover ``new_total_tokens``."""
        return self.allocate(rid, new_total_tokens) is not None

    def release(self, rid: int) -> None:
        for p in self.allocated.pop(rid, []):
            self._free.append(p)

    def table(self, rid: int, max_pages: int) -> np.ndarray:
        pages = self.allocated.get(rid, [])
        t = np.full((max_pages,), -1, np.int32)
        t[: len(pages)] = pages[:max_pages]
        return t


@dataclasses.dataclass
class PagedKVStore:
    """Physical page pool: (L, n_pages, page_size, Hkv, D) per K and V.

    Feeds kernels/paged_attention.py; append writes go through
    ``write_tokens`` (host-side for the CPU real-executor; on TPU the
    engine fuses the write into the decode step)."""

    k_pages: jax.Array
    v_pages: jax.Array
    allocator: BlockAllocator

    @classmethod
    def create(cls, num_layers: int, n_pages: int, page_size: int,
               num_kv_heads: int, head_dim: int, dtype=jnp.bfloat16):
        shape = (num_layers, n_pages, page_size, num_kv_heads, head_dim)
        return cls(
            k_pages=jnp.zeros(shape, dtype),
            v_pages=jnp.zeros(shape, dtype),
            allocator=BlockAllocator(n_pages, page_size),
        )

    def write_tokens(self, rid: int, pos: int, k: jax.Array, v: jax.Array):
        """k/v: (L, T, Hkv, D) new tokens for request ``rid`` starting at
        logical position ``pos``. Allocates pages as needed."""
        t = k.shape[1]
        ps = self.allocator.block_size
        if not self.allocator.extend(rid, pos + t):
            raise MemoryError(f"KV pool exhausted for rid={rid}")
        pages = self.allocator.allocated[rid]
        kp, vp = self.k_pages, self.v_pages
        for i in range(t):
            logical = pos + i
            page = pages[logical // ps]
            off = logical % ps
            kp = kp.at[:, page, off].set(k[:, i])
            vp = vp.at[:, page, off].set(v[:, i])
        self.k_pages, self.v_pages = kp, vp

    def gather_dense(self, rid: int, length: int):
        """(L, length, Hkv, D) dense view for testing."""
        ps = self.allocator.block_size
        pages = self.allocator.allocated[rid]
        k = jnp.concatenate([self.k_pages[:, p] for p in pages], axis=1)
        v = jnp.concatenate([self.v_pages[:, p] for p in pages], axis=1)
        return k[:, :length], v[:, :length]
