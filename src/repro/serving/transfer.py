"""Bandwidth-contended KV transfer engine.

Replaces the fixed ``CostModel.migration_time`` delay with a per-worker ICI
link model: each worker exposes ``ici_links x ici_bw`` bytes/s of egress and
ingress capacity, and every in-flight migration is a *flow* holding a
max-min-fair share of the links it crosses. A burst of P->D handoffs out of
one prefill worker therefore queues on that worker's egress links instead
of teleporting — the disaggregation penalty DistServe-style splits pay and
that Tropical's Path-② multiplexing avoids (paper §IV's asymmetry
argument rests on this cost being real).

The engine is clock-agnostic: the simulator advances it to event times and
asks for the next flow completion; real deployments would swap it for a
NIXL/UCX-style transfer layer with the same interface.

Tiered-KV offload/restore traffic rides the same engine: each worker with
a host-DRAM tier registers a *host node* (``host_node(wid)``, a negative
id that can never collide with a worker id) whose ``LinkSpec`` models the
worker's DMA path to host memory. Offloads are worker→host flows, restores
host→worker — so KV spills contend with migrations for the worker's real
link capacity instead of teleporting.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Optional

import numpy as np


def host_node(wid: int) -> int:
    """Pseudo node id for worker ``wid``'s host-DRAM endpoint. Worker ids
    are non-negative, so the mapping is collision-free and invertible."""
    return -(int(wid) + 1)


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """Aggregate P2P capacity of one worker (bytes/s per direction)."""
    egress_bw: float
    ingress_bw: float
    latency: float = 0.001      # per-migration fixed cost (handshake/launch)

    @classmethod
    def from_hardware(cls, hw) -> "LinkSpec":
        bw = hw.ici_bw * hw.ici_links
        return cls(egress_bw=bw, ingress_bw=bw, latency=hw.migration_latency)

    @classmethod
    def from_host_hardware(cls, hw) -> "LinkSpec":
        """Host-DRAM DMA endpoint (PCIe/DMA, not ICI): symmetric, slower,
        with its own setup latency."""
        return cls(egress_bw=hw.host_bw, ingress_bw=hw.host_bw,
                   latency=hw.host_latency)


@dataclasses.dataclass
class Flow:
    fid: int
    src: int
    dst: int
    nbytes: float
    remaining: float
    payload: object
    start_time: float
    rate: float = 0.0           # current granted bytes/s

    @property
    def finished(self) -> bool:
        # absolute floor plus a relative guard: float residue from
        # rate*dt draining must not strand a flow (or spin the event loop
        # on zero-length completions)
        return self.remaining <= max(1e-6, 1e-9 * self.nbytes) or \
            (self.rate > 0 and self.remaining / self.rate < 1e-9)


class TransferEngine:
    """Max-min fair sharing of per-worker egress/ingress link capacity."""

    def __init__(self, links: Optional[dict[int, LinkSpec]] = None,
                 default_spec: Optional[LinkSpec] = None):
        self.links: dict[int, LinkSpec] = dict(links or {})
        self.default_spec = default_spec or LinkSpec(50e9 * 2, 50e9 * 2)
        self._flows: dict[int, Flow] = {}
        self._fid = itertools.count()
        self._clock = 0.0
        # bumped on every rate change; schedulers use it to drop stale events
        self.version = 0
        # lifetime stats (benchmarks / regression guards)
        self.completed_flows = 0
        self.bytes_moved = 0.0
        self.total_transfer_seconds = 0.0
        # wid-indexed (ingress_bw, latency) cache for the vectorized
        # predictor; the topology only grows, so len(links) is a token
        self._ibw_cache: Optional[np.ndarray] = None
        self._ibw_token = -1

    # ------------------------------------------------------------- topology
    def add_worker(self, wid: int, spec: Optional[LinkSpec] = None) -> None:
        self.links.setdefault(wid, spec or self.default_spec)

    def add_host(self, wid: int, spec: LinkSpec) -> int:
        """Register worker ``wid``'s host-DRAM endpoint; returns its node
        id. Offload flows are ``start(wid, host_node(wid), ...)``."""
        node = host_node(wid)
        self.links[node] = spec
        return node

    def _spec(self, wid: int) -> LinkSpec:
        return self.links.get(wid, self.default_spec)

    # -------------------------------------------------------------- queries
    @property
    def active_flows(self) -> int:
        return len(self._flows)

    def egress_queued_bytes(self, wid: int) -> float:
        return sum(f.remaining for f in self._flows.values() if f.src == wid)

    def ingress_queued_bytes(self, wid: int) -> float:
        return sum(f.remaining for f in self._flows.values() if f.dst == wid)

    def predict_transfer_time(self, src: int, dst: int, nbytes: float,
                              now: Optional[float] = None) -> float:
        """Predicted completion time of a new src->dst flow given current
        queue depths. Links drain their whole backlog at full capacity
        under fair sharing, so the new flow lands behind
        ``queued/capacity`` seconds on its most-contended link. Pass
        ``now`` so already-drained bytes don't count as backlog."""
        if now is not None:
            self.advance(now)
        s, d = self._spec(src), self._spec(dst)
        if s.egress_bw <= 0 or d.ingress_bw <= 0:
            return float("inf")          # dead link: the KV never arrives
        t_out = ((self.egress_queued_bytes(src) + nbytes) / s.egress_bw
                 if math.isfinite(s.egress_bw) else 0.0)
        t_in = ((self.ingress_queued_bytes(dst) + nbytes) / d.ingress_bw
                if math.isfinite(d.ingress_bw) else 0.0)
        return s.latency + max(t_out, t_in)

    def predict_transfer_time_batch(self, src: int, dsts, nbytes: float,
                                    now: Optional[float] = None) -> list:
        """``predict_transfer_time`` against many candidate destinations in
        one pass: the clock advances once, the source egress backlog is
        summed once, and a single sweep over in-flight flows accumulates
        each candidate's ingress backlog (per-destination accumulation in
        flow-table order — the same addition sequence as the scalar
        filtered sums, so every element is bit-identical)."""
        if now is not None:
            self.advance(now)
        s = self._spec(src)
        if s.egress_bw <= 0:
            return [float("inf")] * len(dsts)
        egress = self.egress_queued_bytes(src)
        t_out = ((egress + nbytes) / s.egress_bw
                 if math.isfinite(s.egress_bw) else 0.0)
        want = set(dsts)
        ingress = dict.fromkeys(want, 0.0)
        for f in self._flows.values():
            if f.dst in ingress:
                ingress[f.dst] += f.remaining
        out = []
        for dst in dsts:
            d = self._spec(dst)
            if d.ingress_bw <= 0:
                out.append(float("inf"))
                continue
            t_in = ((ingress[dst] + nbytes) / d.ingress_bw
                    if math.isfinite(d.ingress_bw) else 0.0)
            out.append(s.latency + max(t_out, t_in))
        return out

    def _ingress_bw_array(self, n: int) -> np.ndarray:
        """Ingress bandwidth indexed by worker id for ids ``0..n-1``."""
        c = self._ibw_cache
        if c is None or c.size < n or self._ibw_token != len(self.links):
            m = max(n, c.size if c is not None else 0)
            c = np.empty(m, dtype=np.float64)
            for w in range(m):
                c[w] = self._spec(w).ingress_bw
            self._ibw_cache = c
            self._ibw_token = len(self.links)
        return c

    def predict_transfer_times(self, src: int, dsts: np.ndarray,
                               nbytes: float,
                               now: Optional[float] = None) -> np.ndarray:
        """Array-native ``predict_transfer_time_batch``: ``dsts`` is an
        int array of non-negative worker ids; returns a float64 array.
        Each element is bit-identical to the scalar prediction — the
        per-destination backlogs accumulate in flow-table order and the
        divisions/max use the same operand values."""
        if now is not None:
            self.advance(now)
        n = dsts.size
        s = self._spec(src)
        if s.egress_bw <= 0:
            return np.full(n, float("inf"))
        t_out = ((self.egress_queued_bytes(src) + nbytes) / s.egress_bw
                 if math.isfinite(s.egress_bw) else 0.0)
        ing = np.zeros(n, dtype=np.float64)
        if self._flows:
            acc: dict[int, float] = {}
            for f in self._flows.values():
                acc[f.dst] = acc.get(f.dst, 0.0) + f.remaining
            for dst, v in acc.items():
                ing[dsts == dst] = v
        ibw = self._ingress_bw_array(int(dsts.max()) + 1 if n else 0)[dsts]
        dead = ibw <= 0
        safe = np.where(dead, 1.0, ibw)
        t_in = np.where(np.isfinite(ibw), (ing + nbytes) / safe, 0.0)
        out = s.latency + np.maximum(t_out, t_in)
        if dead.any():
            out[dead] = float("inf")
        return out

    # ------------------------------------------------------------ mechanics
    def advance(self, now: float) -> None:
        """Drain in-flight flows up to ``now`` at their granted rates."""
        dt = now - self._clock
        if dt > 0:
            for f in self._flows.values():
                if math.isinf(f.rate):
                    f.remaining = 0.0
                else:
                    f.remaining = max(0.0, f.remaining - f.rate * dt)
        self._clock = max(self._clock, now)

    def start(self, src: int, dst: int, nbytes: float, now: float,
              payload: object = None) -> Flow:
        self.advance(now)
        f = Flow(fid=next(self._fid), src=src, dst=dst,
                 nbytes=float(nbytes), remaining=max(float(nbytes), 0.0),
                 payload=payload, start_time=now)
        self._flows[f.fid] = f
        self._reallocate()
        return f

    def pop_completed(self, now: float) -> list[Flow]:
        """Flows fully drained by ``now`` (engine re-shares their links)."""
        self.advance(now)
        done = [f for f in self._flows.values() if f.finished]
        for f in done:
            del self._flows[f.fid]
            self.completed_flows += 1
            self.bytes_moved += f.nbytes
            self.total_transfer_seconds += now - f.start_time
        if done:
            self._reallocate()
        return done

    def next_completion(self) -> Optional[float]:
        """Absolute time of the earliest flow completion, or None."""
        best = None
        for f in self._flows.values():
            if math.isinf(f.rate):
                t = self._clock
            elif f.rate > 0:
                t = self._clock + f.remaining / f.rate
            else:               # zero capacity: stalls forever
                continue
            if best is None or t < best:
                best = t
        return best

    def drop_flows_touching(self, wid: int, now: float) -> list[Flow]:
        """Worker died mid-transfer: in-bound KV never lands, and the
        untransferred remainder of out-bound KV was lost with its HBM —
        both directions abandon. Advances to ``now`` first so survivors'
        new rates don't apply retroactively."""
        self.advance(now)
        dead = [f for f in self._flows.values()
                if f.src == wid or f.dst == wid]
        for f in dead:
            del self._flows[f.fid]
        if dead:
            self._reallocate()
        return dead

    # --------------------------------------------------- max-min fair rates
    def _reallocate(self) -> None:
        """Progressive-filling (waterfilling) max-min fair allocation over
        the bipartite egress/ingress resource graph. Two concurrent flows
        out of one worker each get half its egress; a flow bottlenecked on
        its destination releases source bandwidth to its siblings."""
        self.version += 1
        flows = list(self._flows.values())
        if not flows:
            return
        cap: dict[tuple[str, int], float] = {}
        members: dict[tuple[str, int], set[int]] = {}
        for f in flows:
            out_r, in_r = ("out", f.src), ("in", f.dst)
            cap.setdefault(out_r, self._spec(f.src).egress_bw)
            cap.setdefault(in_r, self._spec(f.dst).ingress_bw)
            members.setdefault(out_r, set()).add(f.fid)
            members.setdefault(in_r, set()).add(f.fid)
        unassigned = {f.fid for f in flows}
        by_id = {f.fid: f for f in flows}
        while unassigned:
            bottleneck = None
            for r, fids in members.items():
                live = fids & unassigned
                if not live:
                    continue
                share = cap[r] / len(live)
                if bottleneck is None or share < bottleneck[0]:
                    bottleneck = (share, r, live)
            if bottleneck is None:      # pragma: no cover - defensive
                break
            share, _, live = bottleneck
            for fid in live:
                f = by_id[fid]
                f.rate = share
                unassigned.discard(fid)
                if math.isfinite(share):
                    cap[("out", f.src)] -= share
                    cap[("in", f.dst)] -= share

    # ------------------------------------------------------------ delivery
    def delivery_latency(self, src: int) -> float:
        return self._spec(src).latency
