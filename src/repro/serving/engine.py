"""Per-worker continuous-batching engine.

A Worker owns: a local prefill queue, the running decode batch, KV/state
accounting, and iteration composition (driven by the policy's BatchRule).
It is executor-agnostic: ``compose_iteration`` returns the work description;
the ClusterScheduler's ``ExecutionBackend`` (cost model or real JAX —
``repro.sched.backend``) supplies the duration; ``complete_iteration``
applies state transitions + SLO bookkeeping.

Fast mode (``build_cluster(vectorized=True)``) makes the per-iteration
bookkeeping array-native and incremental:

* ``RequestColumns`` — a structure-of-arrays mirror of the decode batch
  (the ``ViewColumns`` discipline one layer down) so completion effects
  (decode recording, KV footprint growth, blocked-time charging, finish
  detection, page-growth filtering) run as numpy ops over the batch, with
  scalar fallbacks only for the rows the masks flag;
* incremental ``_refresh_view`` — queue tokens, per-class TPOT floors and
  the prefix-span map are maintained as running aggregates at the mutation
  sites instead of O(batch) rescans per event.

The scalar path remains the reference: ``view_reference()`` recomputes
every derived view field from scratch, and the fast path must match it —
and the full decision stream / ``ServeMetrics`` — bit for bit
(``tests/test_engine_fast.py``, ``tests/test_vectorized.py``).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import math

import numpy as np

from repro.core.policies import BatchRule, Policy
from repro.core.request import Phase, Request
from repro.core.toggle import Role, WorkerView
from repro.perf import CostModel
from repro.serving.kvcache import PageAccountant, PrefixIndex

# Decode batches below this run the scalar completion/refresh bodies even
# in fast mode: numpy's fixed per-op cost (~µs) only amortises once the
# loop it replaces has enough rows. The scalar body is the parity
# reference, so the shortcut cannot change results, only wall clock.
_VEC_MIN_BATCH = 8


def _slack_key(now: float):
    """Sort key for the class-aware 'slack' discipline: tightest relative
    TTFT slack first among requests that can still make their deadline;
    already-hopeless requests (deadline passed — TTFT is unattainable
    whatever happens next) go last, exactly like the 'edf' discipline's
    hopeless demotion — spending capacity on them ahead of salvageable
    work buys no attainment. Shared by worker queues and the scheduler's
    global overflow queue so both orders agree."""
    def key(r: Request):
        rel = r.rel_ttft_slack(now)
        return (rel <= 0.0, rel, r.arrival_time, r.rid)
    return key


@dataclasses.dataclass
class IterationPlan:
    decode_reqs: list          # requests getting one token this iteration
    prefill_parts: list        # (request, tokens) chunks executed
    n_decode: int
    sum_ctx: float
    prefill_tokens: int
    prefill_ctx_offset: float
    exclusive_prefill: bool    # decode stalled behind prefill (interference)
    # decode-batch membership version at compose time (fast mode): lets
    # ``complete_iteration`` prove the SoA rows still ARE the planned batch
    # and take the vector path; any admit/release/offload in between bumps
    # the worker counter and the completion falls back to the scalar body
    batch_version: int = -1

    @property
    def empty(self) -> bool:
        return self.n_decode == 0 and self.prefill_tokens == 0


class RequestColumns:
    """Structure-of-arrays mirror of one worker's decode batch: the
    per-request scalars the completion hot path touches, as numpy columns
    in ``decode_running``'s insertion order — which is exactly the plan
    order ``compose_iteration`` captured, so masked results map back to
    requests by row index.

    The ``Request`` objects stay authoritative: the fast completion path
    writes its array results straight back every iteration, so the mirror
    never holds state the scalar fallbacks (preempt / offload / metrics)
    can't see. Any membership change just sets ``dirty`` (batch-granular
    analogue of ``ViewColumns``' per-row dirty set — decode batches are
    small enough that a whole-batch rebuild beats row surgery); the next
    reader rebuilds from the dict."""

    __slots__ = ("reqs", "rids", "ctx", "gen", "rem_out", "decode_time",
                 "tpot_slack", "tpot_slo", "cached_prefix", "pages_held",
                 "n", "dirty")

    def __init__(self) -> None:
        self.reqs: list[Request] = []
        self.rids: list[int] = []
        self.ctx = np.empty(0, dtype=np.int64)
        self.gen = np.empty(0, dtype=np.int64)
        self.rem_out = np.empty(0, dtype=np.int64)
        self.decode_time = np.empty(0, dtype=np.float64)
        self.tpot_slack = np.empty(0, dtype=np.float64)
        self.tpot_slo = np.empty(0, dtype=np.float64)
        self.cached_prefix = np.empty(0, dtype=np.int64)
        self.pages_held = np.empty(0, dtype=np.int64)
        self.n = 0
        self.dirty = True

    def rebuild(self, decode_running: dict[int, Request],
                pages: PageAccountant) -> None:
        reqs = list(decode_running.values())
        n = len(reqs)
        self.reqs = reqs
        self.rids = [r.rid for r in reqs]
        self.ctx = np.fromiter((r.context_len for r in reqs), np.int64, n)
        self.gen = np.fromiter((r.generated_tokens for r in reqs),
                               np.int64, n)
        self.rem_out = np.fromiter((r.remaining_output for r in reqs),
                                   np.int64, n)
        self.decode_time = np.fromiter((r.decode_time for r in reqs),
                                       np.float64, n)
        self.tpot_slack = np.fromiter((r.tpot_slack for r in reqs),
                                      np.float64, n)
        self.tpot_slo = np.fromiter((r.slo.tpot for r in reqs),
                                    np.float64, n)
        self.cached_prefix = np.fromiter((r.cached_prefix for r in reqs),
                                         np.int64, n)
        self.pages_held = np.fromiter((pages.held_pages(rid)
                                       for rid in self.rids), np.int64, n)
        self.n = n
        self.dirty = False


class Worker:
    def __init__(self, wid: int, cost: CostModel, role: Role = Role.MULTIPLEX,
                 queue_discipline: str = "fcfs",
                 kv_preempt_watermark: float = 0.98,
                 host_pages: int = 0,
                 prefix_cache: Optional[PrefixIndex] = None,
                 offload_gate: Optional[Callable[[Request], bool]] = None):
        self.wid = wid
        self.cost = cost
        self.queue_discipline = queue_discipline   # fcfs | edf
        # page-granular HBM accounting: admission and growth gate on real
        # allocatable pages; crossing the watermark evicts decodes (which
        # pay a re-prefill on readmission) — unless a host-DRAM tier
        # (``host_pages`` > 0) can absorb the spill and the ``offload_gate``
        # (predictor-priced: restore beats re-prefill) approves
        self.pages = PageAccountant(cost.kv_capacity_pages(), cost.page_size,
                                    host_pages=host_pages)
        self.kv_preempt_watermark = kv_preempt_watermark
        # fast mode (build_cluster(vectorized=True)): incremental view
        # refresh + array-shaped completion effects over RequestColumns.
        # State transitions are identical — tests/test_vectorized.py and
        # tests/test_engine_fast.py pin decision/metrics/view parity.
        self.fast = False
        self.prefix_cache = prefix_cache
        self.offload_gate = offload_gate
        self.view = WorkerView(
            wid=wid, role=role,
            kv_capacity_tokens=float(max(cost.kv_capacity_tokens(), 1)),
            total_pages=self.pages.total_pages,
            free_pages=self.pages.total_pages,
            page_size=self.pages.page_size,
            host_total_pages=self.pages.host_total_pages,
            host_free_pages=self.pages.host_total_pages,
        )
        self.prefill_queue: deque[Request] = deque()
        # insertion-ordered, keyed by rid: O(1) membership/removal where
        # the old list paid O(batch) scans per event; iteration order is
        # insertion order, i.e. exactly the old list order (plan parity)
        self.decode_running: dict[int, Request] = {}
        self.preempted: list[Request] = []       # drained by the simulator
        # tiered-KV lifecycle (scheduler drains/advances these):
        # offload_started -> engine starts the worker->host flow;
        # offloading (wire) -> offloaded (parked) -> restoring (wire back)
        self.offload_started: list[Request] = []
        self.offloading: dict[int, Request] = {}
        self.offloaded: dict[int, Request] = {}
        self.restoring: dict[int, Request] = {}
        self.busy = False
        # incremental-view aggregates, maintained at the mutation sites in
        # both modes (the slow path ignores them; keeping them mode-blind
        # makes toggling ``fast`` mid-life safe in tests):
        # exact queued-prefill token count (ints — no float drift)
        self._q_tokens = 0
        # per-class {tpot: live count} so the class floor map rebuilds
        # from keys already in the batch instead of an O(batch) walk
        self._floor_counts: dict[str, dict[float, int]] = {}
        self._floors_cache: Optional[dict[str, float]] = {}
        # bumped on every decode-batch membership change; plans carry the
        # compose-time value so completion can prove row alignment
        self._batch_version = 0
        self._cols = RequestColumns()
        # prefix-cache content version last mirrored into the view
        self._prefix_seen = -1
        # metrics
        self.blocked_time: dict[int, float] = {}
        self.queue_times: dict[int, float] = {}
        self.busy_time = 0.0
        # wall seconds this worker's decode batches spent blocked behind
        # co-batched prefill work, charged ONCE per mixed iteration (the
        # per-request ``blocked_time`` dict intentionally charges the same
        # interval to every blocked request — see complete_iteration)
        self.interference_time = 0.0
        self.preemption_count = 0
        self.offload_count = 0
        self.restore_count = 0
        self.pages_offloaded = 0
        self.pages_restored = 0
        self.pages_reprefilled = 0

    # ---------------------------------------------------- batch bookkeeping
    def _decode_add(self, req: Request) -> None:
        self.decode_running[req.rid] = req
        self._batch_version += 1
        self._cols.dirty = True
        counts = self._floor_counts.get(req.slo.name)
        if counts is None:
            counts = self._floor_counts[req.slo.name] = {}
        tpot = req.slo.tpot
        counts[tpot] = counts.get(tpot, 0) + 1
        self._floors_cache = None

    def _decode_discard(self, req: Request) -> bool:
        if self.decode_running.pop(req.rid, None) is None:
            return False
        self._batch_version += 1
        self._cols.dirty = True
        # tolerant of direct decode_running inserts (test harnesses):
        # missing entries just skip the floor aggregate — fast-mode runs
        # always pair _decode_add/_decode_discard, and the view parity
        # tests would surface any imbalance as a floor-map divergence
        counts = self._floor_counts.get(req.slo.name)
        tpot = req.slo.tpot
        if counts is not None and tpot in counts:
            left = counts[tpot] - 1
            if left:
                counts[tpot] = left
            else:
                del counts[tpot]
                if not counts:
                    del self._floor_counts[req.slo.name]
        self._floors_cache = None
        return True

    # ------------------------------------------------------------- admission
    def admit_prefill(self, req: Request, now: float) -> None:
        req.worker = self.wid
        self.prefill_queue.append(req)
        self._q_tokens += req.remaining_prefill
        self._refresh_view()

    def admit_decode(self, req: Request, now: float) -> None:
        req.worker = self.wid
        req.phase = Phase.DECODING
        self._decode_add(req)
        self._refresh_view()

    def withdraw_prefill(self, req: Request, now: float = 0.0) -> None:
        """Back out a queued/just-started prefill whose execution the
        backend refused (e.g. ``SlotExhausted``): drop it from the queue,
        return its reserved pages / borrowed prefix ref / KV accounting.
        The caller re-queues the request elsewhere."""
        if req in self.prefill_queue:
            self.prefill_queue.remove(req)
            self._q_tokens -= req.remaining_prefill
        self.release(req, refresh=False)
        self._refresh_view()

    def admit_migrated(self, req: Request, now: float) -> bool:
        """Admit a request whose KV just arrived over the links. False when
        the page pool cannot hold the migrated context (caller restarts the
        request elsewhere — the re-prefill cost of a failed placement)."""
        if not self.pages.reserve(req.rid, self._page_need(req.context_len,
                                                           req.cached_prefix)):
            return False
        self.view.kv_used_tokens += self._own_state(req, req.context_len)
        self.admit_decode(req, now)
        return True

    # ------------------------------------------------------------- planning
    def compose_iteration(self, rule: BatchRule, now: float) -> IterationPlan:
        decode_reqs: list[Request] = []
        prefill_parts: list[tuple[Request, int]] = []
        budget = rule.prefill_budget

        run_prefill_exclusively = (
            rule.prefill_exclusive and self._has_admissible_prefill())
        if run_prefill_exclusively:
            # full-prompt (or budget-bounded) prefill-only iteration
            taken = set()
            while budget > 0 and self._has_admissible_prefill():
                req = self._next_admissible_prefill(now)
                if req is None or req.rid in taken:
                    break
                take = min(req.remaining_prefill, budget)
                if take < req.remaining_prefill and prefill_parts:
                    break       # don't split a second prompt mid-iteration
                if not self._start_prefill(req, now):
                    break       # page pool can't hold the prompt yet
                prefill_parts.append((req, take))
                taken.add(req.rid)
                budget -= take
        else:
            if rule.run_decode:
                decode_reqs = list(self.decode_running.values())
            if budget > 0 and self._has_admissible_prefill():
                req = self._peek_admissible_prefill(now)
                if req is not None and self._start_prefill(req, now):
                    take = min(req.remaining_prefill, budget)
                    prefill_parts.append((req, take))

        if self.fast and rule.run_decode and not run_prefill_exclusively:
            # decode_reqs is exactly decode_running, whose context sum the
            # view maintains (refreshed after every mutation) — same value,
            # no O(batch) rescan
            sum_ctx = self.view.decode_sum_ctx
        else:
            sum_ctx = float(sum(r.context_len for r in decode_reqs))
        p_tokens = sum(t for _, t in prefill_parts)
        ctx_off = float(prefill_parts[0][0].prefilled_tokens) if prefill_parts else 0.0
        return IterationPlan(
            decode_reqs=decode_reqs, prefill_parts=prefill_parts,
            n_decode=len(decode_reqs), sum_ctx=sum_ctx,
            prefill_tokens=p_tokens, prefill_ctx_offset=ctx_off,
            exclusive_prefill=run_prefill_exclusively and bool(prefill_parts),
            batch_version=self._batch_version if self.fast else -1,
        )

    def plan_duration(self, plan: IterationPlan) -> float:
        return self.cost.iteration_time(
            plan.n_decode, plan.sum_ctx, plan.prefill_tokens,
            plan.prefill_ctx_offset)

    # ------------------------------------------------------------ completion
    def complete_iteration(self, plan: IterationPlan, now: float,
                           duration: float) -> list[Request]:
        """Apply effects at iteration end. Returns requests whose prefill
        finished this iteration (for decode dispatch)."""
        self.busy_time += duration
        finished_prefills: list[Request] = []
        # decode side. ``interference`` is the wall time this iteration ran
        # beyond a pure decode pass (piggybacked prefill compute + the §IV
        # contention penalty when γ is active). It is one per-ITERATION
        # quantity: the worker-level ``interference_time`` accumulates it
        # exactly once, while the per-request ``blocked_time`` dict charges
        # the same interval to EVERY blocked decode — deliberately, because
        # each request's stream really did stall that long (wall blocking
        # is concurrent, so per-request entries must never be summed across
        # a batch as if they were machine time).
        pure_decode = self.cost.decode_iter_time(plan.n_decode, plan.sum_ctx) \
            if plan.n_decode else 0.0
        interference = max(0.0, duration - pure_decode)
        if plan.n_decode and plan.prefill_tokens > 0:
            self.interference_time += interference
        if self.fast and plan.n_decode >= _VEC_MIN_BATCH \
                and plan.batch_version == self._batch_version:
            # membership unchanged since compose: the SoA rows are exactly
            # plan.decode_reqs, in order — take the vector path. Below
            # the batch threshold the numpy fixed cost exceeds the loop
            # it replaces, so small batches run the scalar body (which IS
            # the reference — parity is free).
            self._decode_effects_fast(plan, now, duration, interference)
        else:
            if self.fast and plan.n_decode:
                # scalar fallback advanced the batch outside the SoA; a
                # refresh between compose and now may have rebuilt (and
                # clean-flagged) the mirror at the new version, so the
                # version bump alone does not guarantee a re-pull
                self._cols.dirty = True
            self._decode_effects(plan, now, duration, interference)
        while self.pages.utilization > self.kv_preempt_watermark:
            if self._evict_prefix_lru():
                continue
            if len(self.decode_running) <= 1 or not self._preempt_one(now):
                break
        # decode requests stalled behind an exclusive prefill count as blocked
        if plan.exclusive_prefill and self.decode_running:
            bt = self.blocked_time
            for r in self.decode_running.values():
                r.decode_time += duration
                r.tpot_slack -= duration       # the stall burns slack
                bt[r.rid] = bt.get(r.rid, 0.0) + duration
            # scalar mutation of batch members outside the SoA path: the
            # mirror must re-pull before the next vector step reads it
            self._cols.dirty = True
        # prefill side
        fast = self.fast
        for req, tokens in plan.prefill_parts:
            in_queue = req in self.prefill_queue
            before = req.remaining_prefill
            req.prefilled_tokens += tokens
            if in_queue:
                # exact aggregate delta (remaining_prefill clamps at 0, so
                # the delta is re-derived, not assumed equal to ``tokens``)
                self._q_tokens -= before - req.remaining_prefill
            if req.remaining_prefill == 0:
                req.record_first_token(now)
                # the prefill's forward pass emitted token #1: charge its
                # footprint (context grew past the prompt the admission
                # reservation covered), so release(st(final ctx)) balances
                # to zero over the request's life
                self.view.kv_used_tokens += \
                    self.cost.state_tokens(req.context_len) \
                    - self.cost.state_tokens(req.prompt_len)
                if (self.prefix_cache is not None
                        and req.prefix_key is not None
                        and req.cached_prefix == 0):
                    # first bearer of this shared prompt on this worker:
                    # retain a copy of the prefix span for later arrivals
                    self._cache_prefix(req)
                if req.remaining_output == 0:
                    req.phase = Phase.FINISHED
                    req.finish_time = now
                    self.release(req, refresh=not fast)
                else:
                    finished_prefills.append(req)
                if in_queue:
                    self.prefill_queue.remove(req)
        self._refresh_view()
        return finished_prefills

    def _decode_effects(self, plan: IterationPlan, now: float,
                        duration: float, interference: float) -> None:
        """Scalar reference for the decode-side completion effects: token
        recording, KV footprint growth, blocked-time charging, finish
        detection, then page growth for the tokens just written."""
        running = self.decode_running
        bt = self.blocked_time
        mixed = plan.prefill_tokens > 0
        for r in plan.decode_reqs:
            if r.phase != Phase.DECODING or r.rid not in running:
                continue        # evicted mid-compose (page preemption)
            r.record_decode_iteration(duration)
            # grow the token counter by the request's true footprint
            # delta so release() — which frees state_tokens(ctx) — always
            # balances: 1.0 for dense KV, 0.5 past a sliding window's
            # cap, 0 for constant-state (rwkv/mamba, whose fixed state
            # was pinned in full at admission). A flat += 1 leaked the
            # difference on every finished request.
            self.view.kv_used_tokens += \
                self.cost.state_tokens(r.context_len) \
                - self.cost.state_tokens(r.context_len - 1)
            if mixed:
                bt[r.rid] = bt.get(r.rid, 0.0) + interference
            if r.remaining_output == 0:
                r.phase = Phase.FINISHED
                r.finish_time = now
                self.release(r, refresh=not self.fast)
        # page growth for the tokens just written; evict newest decodes
        # when the pool can't supply it, then enforce the watermark
        for r in plan.decode_reqs:
            if r.phase != Phase.DECODING or r.rid not in running:
                continue
            need = self._page_need(r.context_len, r.cached_prefix)
            while not self.pages.reserve(r.rid, need):
                if self._evict_prefix_lru():
                    continue       # unreferenced cached prefixes go first
                if not self._preempt_one(now, keep=r):
                    self._preempt(r, now)      # nobody else to evict
                    break

    def _decode_effects_fast(self, plan: IterationPlan, now: float,
                             duration: float, interference: float) -> None:
        """Array-native decode-side completion: the same effects as
        ``_decode_effects``, as elementwise ops over ``RequestColumns``.
        Bit-for-bit identical because every column op mirrors the scalar
        recurrence's IEEE-754 association order, and the one cross-row
        accumulation (the KV footprint delta) sums exactly-representable
        dyadic values, where grouping cannot change the result. Rows the
        masks flag (finished, page growth) fall back to the exact scalar
        bodies in row (= plan) order.

        One knowing divergence: rows that need no new pages skip the
        no-op ``PageAccountant.reserve`` the scalar loop still issues, so
        the accountant's advisory per-rid token watermark (feeding only
        the ``fragmentation`` diagnostic) can read lower here. Decisions
        never consume it."""
        cols = self._cols
        if cols.dirty:
            cols.rebuild(self.decode_running, self.pages)
        reqs = cols.reqs
        # one decode token per request — the scalar recurrences of
        # Request.record_decode_iteration, elementwise
        cols.ctx += 1
        cols.gen += 1
        cols.rem_out -= 1
        cols.decode_time += duration
        cols.tpot_slack += cols.tpot_slo - duration
        delta = self.cost.state_token_delta_sum(cols.ctx)
        if delta:
            self.view.kv_used_tokens += delta
        if plan.prefill_tokens > 0:
            bt = self.blocked_time
            for rid in cols.rids:
                bt[rid] = bt.get(rid, 0.0) + interference
        # immediate writeback: Requests stay authoritative for every
        # scalar consumer (preempt/offload victims, metrics, routing)
        for r, d, t, g in zip(reqs, cols.decode_time.tolist(),
                              cols.tpot_slack.tolist(), cols.gen.tolist()):
            r.decode_time = d
            r.tpot_slack = t
            r.generated_tokens = g
        done = None
        if cols.rem_out.min() == 0:
            done = np.nonzero(cols.rem_out == 0)[0]
            for i in done.tolist():
                r = reqs[i]
                r.phase = Phase.FINISHED
                r.finish_time = now
                self.release(r, refresh=False)
        # page growth: vector-filter the rows whose own reservation no
        # longer covers their grown footprint, scalar-handle only those
        spec = self.cost.spec
        if spec.kv_bytes_per_token <= 0:
            return          # constant-state: footprint pinned at admission
        cap = spec.ctx_cap
        ps = self.pages.page_size
        if cap is None:
            need_tok = np.maximum(cols.ctx - cols.cached_prefix, 0)
        else:
            st_ctx = cols.ctx * 0.5 + np.minimum(cols.ctx, cap) * 0.5
            st_cached = cols.cached_prefix * 0.5 \
                + np.minimum(cols.cached_prefix, cap) * 0.5
            need_tok = np.ceil(
                np.maximum(st_ctx - st_cached, 0.0)).astype(np.int64)
        grow = -(-need_tok // ps) > cols.pages_held
        if done is not None:
            grow[done] = False
        if not grow.any():
            return
        running = self.decode_running
        for i in np.nonzero(grow)[0].tolist():
            r = reqs[i]
            if r.phase != Phase.DECODING or r.rid not in running:
                continue        # evicted by an earlier victim this pass
            need = self._page_need(r.context_len, r.cached_prefix)
            while not self.pages.reserve(r.rid, need):
                if self._evict_prefix_lru():
                    continue
                if not self._preempt_one(now, keep=r):
                    self._preempt(r, now)
                    break
            else:
                cols.pages_held[i] = self.pages.held_pages(r.rid)

    def release(self, req: Request, refresh: bool = True) -> None:
        """Free KV held by a finished/migrated request (both tiers), and
        return any borrowed prefix-cache reference. ``refresh=False`` lets
        ``complete_iteration`` coalesce many releases into its single
        trailing view rebuild (the rebuild is a full recompute, so the
        final state is identical)."""
        self.view.kv_used_tokens = max(
            0.0, self.view.kv_used_tokens - self._own_state(req, req.context_len))
        self.pages.release(req.rid)
        if req.cached_prefix > 0 and self.prefix_cache is not None:
            self.prefix_cache.unref(req.prefix_key)
            req.cached_prefix = 0
        self._decode_discard(req)
        if refresh:
            self._refresh_view()

    # ------------------------------------------------------------ preemption
    def _preempt(self, req: Request, now: float) -> None:
        """Evict a decode's KV pages; the request re-prefills its whole
        context (the §IV-B eviction cost) wherever dispatch next places it."""
        req.preemptions += 1
        self.preemption_count += 1
        self.pages_reprefilled += self.pages.held_pages(req.rid)
        # preemption only happens inside complete_iteration, whose trailing
        # _refresh_view covers fast mode's skipped intermediate rebuild
        self.release(req, refresh=not self.fast)
        req.reset_for_reprefill(now)
        self.preempted.append(req)

    def _preempt_one(self, now: float, keep: Optional[Request] = None) -> bool:
        """Displace the most recently admitted decode (least sunk prefill
        work, vLLM-style LIFO recomputation). Prefers *offloading* its
        pages to the host-DRAM tier (restore later, no re-prefill) when the
        tier has room and the offload gate prices restore below re-prefill;
        falls back to eviction. Returns False when there is no eligible
        victim."""
        for victim in reversed(self.decode_running.values()):
            if victim is not keep:
                if self._try_offload(victim, now):
                    return True
                self._preempt(victim, now)
                return True
        return False

    def drain_preempted(self) -> list[Request]:
        out, self.preempted = self.preempted, []
        return out

    # ------------------------------------------------------------- tiered KV
    def _try_offload(self, victim: Request, now: float) -> bool:
        """Move ``victim``'s KV accounting to the host tier instead of
        discarding it. The scheduler drains ``offload_started`` and puts
        the bytes on the host link."""
        if (self.offload_gate is None or self.pages.host_total_pages <= 0
                or not self.pages.can_offload(victim.rid)
                or not self.offload_gate(victim)):
            return False
        moved = self.pages.offload(victim.rid)
        if moved <= 0:
            return False
        victim.offloads += 1
        self.offload_count += 1
        self.pages_offloaded += moved
        victim.phase = Phase.OFFLOADED
        if victim.stall_start is None:
            victim.stall_start = now    # stream stalls until restore lands
        self.view.kv_used_tokens = max(
            0.0,
            self.view.kv_used_tokens - self._own_state(victim,
                                                       victim.context_len))
        # a borrowed prefix ref stays held across the park: the cached span
        # must still be resident when the restore lands
        self._decode_discard(victim)
        self.offloading[victim.rid] = victim
        self.offload_started.append(victim)
        return True

    def drain_offload_started(self) -> list[Request]:
        out, self.offload_started = self.offload_started, []
        return out

    def offload_landed(self, req: Request) -> None:
        """The worker->host flow completed; the request is restore-eligible."""
        if self.offloading.pop(req.rid, None) is not None:
            self.offloaded[req.rid] = req

    def next_restorable(self) -> Optional[Request]:
        """Oldest parked request whose pages fit back in HBM without
        pushing utilization past the preempt watermark (restoring must not
        immediately re-trigger the preemption it was meant to avoid)."""
        for rid, req in self.offloaded.items():
            pages = self.pages.host_held_pages(rid)
            would = (self.pages.used_pages + pages) \
                / max(self.pages.total_pages, 1)
            if pages <= self.pages.free_pages \
                    and would <= self.kv_preempt_watermark:
                return req
        return None

    def begin_restore(self, req: Request, now: float) -> bool:
        """Reserve the HBM destination and mark the restore in flight."""
        if req.rid not in self.offloaded or not self.pages.can_restore(req.rid):
            return False
        self.pages.restore(req.rid)
        del self.offloaded[req.rid]
        self.restoring[req.rid] = req
        self._refresh_view()
        return True

    def finish_restore(self, req: Request, now: float) -> bool:
        """Restore flow landed: rejoin the decode batch. The whole parked
        interval (offload wire + host dwell + restore wire) is inter-token
        latency the user saw — charged like migration wait."""
        if self.restoring.pop(req.rid, None) is None:
            return False               # stale completion (failure raced it)
        self.restore_count += 1
        self.pages_restored += self.pages.held_pages(req.rid)
        self.view.kv_used_tokens += self._own_state(req, req.context_len)
        req.restores += 1
        if req.stall_start is not None:
            gap = now - req.stall_start
            req.decode_time += gap
            req.tpot_slack -= gap
            req.stall_start = None
        self.admit_decode(req, now)
        return True

    # ------------------------------------------------------------- internals
    def _page_need(self, ctx_tokens: int, cached: int = 0) -> int:
        """Token-footprint the request's OWN reservation must cover: its
        full context minus any span borrowed from the prefix cache (whose
        pages are pinned under the cache entry's pseudo rid)."""
        st = self.cost.state_tokens(ctx_tokens)
        if cached > 0:
            st -= self.cost.state_tokens(cached)
        return int(math.ceil(max(st, 0.0)))

    def _own_state(self, req: Request, ctx_tokens: int) -> float:
        """``state_tokens`` charged to ``req`` itself (excludes the
        borrowed prefix span — the cache entry carries those tokens)."""
        st = self.cost.state_tokens(ctx_tokens)
        if req.cached_prefix > 0:
            st -= self.cost.state_tokens(req.cached_prefix)
        return max(st, 0.0)

    def _prefix_span(self, req: Request) -> int:
        """Tokens of ``req``'s prompt a prefill start here would borrow
        from the cache (0 without a hit). Capped at prompt_len - 1 so at
        least one prefill token always runs — the forward pass that emits
        the first token. Pure peek: no counters, no LRU touch."""
        if req.cached_prefix > 0:
            return req.cached_prefix
        if self.prefix_cache is None or req.prefix_key is None:
            return 0
        span = self.prefix_cache.peek(req.prefix_key)
        return max(0, min(span, req.prefix_len, req.prompt_len - 1))

    def _kv_room_for(self, req: Request) -> bool:
        span = self._prefix_span(req)
        if not self.pages.can_fit(self._page_need(req.prompt_len, span),
                                  rid=req.rid):
            return False
        st = self.cost.state_tokens(req.prompt_len)
        if span > 0:
            st -= self.cost.state_tokens(span)
        return self.view.kv_used_tokens + max(st, 0.0) \
            <= self.view.kv_capacity_tokens

    def _has_admissible_prefill(self) -> bool:
        return any(self._kv_room_for(r) or r.prefill_start is not None
                   for r in self.prefill_queue)

    def _prefill_order(self, now: float) -> list[Request]:
        """Queue order. 'fcfs' (the discipline of vLLM/Sarathi/DistServe and
        the paper's Tropical). 'slack' is the multi-tenant class-aware
        order: tightest-relative-TTFT-slack first — absolute seconds are
        not comparable across SLO classes, the consumed budget *fraction*
        is. A homogeneous queue (every request in one class) keeps the
        exact FCFS admission order, so single-class runs are
        decision-identical to the paper's discipline (an interactive-class
        arrival only ever overtakes *other-class* work). 'edf' is the
        beyond-paper SLO-aware order: earliest-deadline-first among
        requests that can still make TTFT; already-hopeless requests are
        served last (spending capacity on them in deadline order buys no
        attainment)."""
        if self.queue_discipline == "fcfs":
            return list(self.prefill_queue)

        if self.queue_discipline == "slack":
            if len({r.slo.name for r in self.prefill_queue}) <= 1:
                return list(self.prefill_queue)
            return sorted(self.prefill_queue, key=_slack_key(now))

        def key(r: Request):
            deadline = r.arrival_time + r.slo.ttft
            t_exec = self.cost.prefill_time(r.remaining_prefill,
                                            r.prefilled_tokens)
            hopeless = now + t_exec > deadline
            return (hopeless, deadline, r.rid)

        return sorted(self.prefill_queue, key=key)

    def peek_prefill(self, now: float) -> Optional[Request]:
        """Head-of-queue under the active discipline — what the policy's
        ``batch_rule`` sizes its chunk budget against. 'fcfs'/'edf' keep
        the legacy raw queue head; 'slack' surfaces the class-aware order's
        head (identical for a single-class queue). O(n) min, not a full
        sort — this runs on every _kick."""
        if not self.prefill_queue:
            return None
        if self.queue_discipline == "slack" and \
                len({r.slo.name for r in self.prefill_queue}) > 1:
            return min(self.prefill_queue, key=_slack_key(now))
        return self.prefill_queue[0]

    def _next_admissible_prefill(self, now: float) -> Optional[Request]:
        for r in self._prefill_order(now):
            if r.remaining_prefill > 0 and (
                    r.prefill_start is not None or self._kv_room_for(r)):
                return r
        return None

    def _peek_admissible_prefill(self, now: float) -> Optional[Request]:
        return self._next_admissible_prefill(now)

    def _start_prefill(self, req: Request, now: float) -> bool:
        """Reserve prompt KV and mark the prefill started. False (state
        untouched) when the page pool can't hold the prompt — unreachable
        behind the ``_kv_room_for`` admission gate, kept as the contract
        for callers. A prefix-cache hit borrows the cached span: only the
        uncached suffix reserves pages and runs prefill compute."""
        if req.prefill_start is None:
            span = self._prefix_span(req)
            if not self.pages.reserve(req.rid,
                                      self._page_need(req.prompt_len, span)):
                return False
            if self.prefix_cache is not None and req.prefix_key is not None:
                entry = self.prefix_cache.lookup(req.prefix_key)  # counted
                if entry is not None and span > 0:
                    entry.refs += 1
                    req.cached_prefix = span
                    # the borrowed span never runs prefill compute: the
                    # queued-token aggregate sheds it here (req is still
                    # in the queue — starts only come from queue walks)
                    before = req.remaining_prefill
                    req.prefilled_tokens = span
                    self._q_tokens -= before - req.remaining_prefill
                    req.prefix_hits += 1
            req.prefill_start = now
            req.phase = Phase.PREFILLING
            self.queue_times[req.rid] = now - req.arrival_time
            self.view.kv_used_tokens += self._own_state(req, req.prompt_len)
        return True

    def _cache_prefix(self, req: Request) -> None:
        """Retain a copy of ``req``'s just-prefilled shared-prompt span for
        later arrivals. Skipped when the key is already cached, the span
        exceeds the cache's page budget, or HBM lacks free pages (the
        cache must never squeeze live decodes to populate itself)."""
        if self.cost.spec.kv_bytes_per_token <= 0:
            return          # constant-state families have no prefix KV
        if self.prefix_cache.peek(req.prefix_key) > 0:
            return          # another bearer landed first
        tokens = min(req.prefix_len, req.prompt_len)
        if tokens <= 0:
            return
        need = self._page_need(tokens)
        pages = self.pages.pages_for(need)
        if pages <= 0 or pages > self.prefix_cache.max_pages:
            return
        while self.prefix_cache.used_pages + pages > self.prefix_cache.max_pages:
            if not self._evict_prefix_lru():
                return
        if self.pages.free_pages < pages:
            return
        entry = self.prefix_cache.insert(req.prefix_key, tokens, pages)
        self.pages.reserve(entry.rid, need)
        self.view.kv_used_tokens += self.cost.state_tokens(tokens)

    def _evict_prefix_lru(self) -> bool:
        """Drop the LRU *unreferenced* cache entry and free its pages.
        False when the cache is off, empty, or every entry has a live
        borrower (those pages must not dangle under a mid-decode)."""
        if self.prefix_cache is None:
            return False
        entry = self.prefix_cache.evict_lru()
        if entry is None:
            return False
        self.pages.release(entry.rid)
        self.view.kv_used_tokens = max(
            0.0, self.view.kv_used_tokens
            - self.cost.state_tokens(entry.tokens))
        return True

    # ------------------------------------------------------------------ view
    def view_reference(self) -> dict:
        """Every derived view field, recomputed from scratch — the scalar
        reference the fast incremental refresh must match bit for bit
        after every event (``tests/test_engine_fast.py`` walks event
        histories asserting exactly that)."""
        decode = list(self.decode_running.values())
        fields: dict = {
            "queued_prefill_tokens": sum(r.remaining_prefill
                                         for r in self.prefill_queue),
            "queued_requests": len(self.prefill_queue),
            "decode_batch": len(decode),
            "decode_sum_ctx": float(sum(r.context_len for r in decode)),
        }
        base_iter = self.cost.decode_iter_time(
            fields["decode_batch"], fields["decode_sum_ctx"]) \
            if decode else 0.0
        fields["min_tpot_slack"] = min(
            (r.effective_slack(base_iter) for r in decode),
            default=float("inf"))
        floors: dict[str, float] = {}
        for r in decode:
            name = r.slo.name
            floors[name] = min(floors.get(name, float("inf")), r.slo.tpot)
        fields["decode_tpot_floor"] = floors
        fields["total_pages"] = self.pages.total_pages
        fields["free_pages"] = self.pages.free_pages
        fields["page_size"] = self.pages.page_size
        fields["host_total_pages"] = self.pages.host_total_pages
        fields["host_free_pages"] = self.pages.host_free_pages
        if self.prefix_cache is not None:
            fields["cached_prefixes"] = self.prefix_cache.spans()
            fields["prefix_hit_ewma"] = self.prefix_cache.hit_ewma
        return fields

    def _refresh_view(self) -> None:
        if self.fast:
            self._refresh_view_fast()
            return
        self.view.assign(**self.view_reference())

    def _refresh_view_fast(self) -> None:
        """Incremental refresh: running aggregates + SoA reductions in
        place of ``view_reference``'s O(batch + queue) rescans. Values are
        bit-identical: the queue/floor aggregates are exact integer /
        min-structure maintenance, ``decode_sum_ctx`` is an exact int64
        sum, and the slack reduction mirrors ``Request.effective_slack``'s
        float ops elementwise before one order-free ``min``."""
        running = self.decode_running
        n = len(running)
        if not n:
            sum_ctx = 0.0
            min_slack = float("inf")
        elif n < _VEC_MIN_BATCH:
            # small batch: the reference's own scalar recurrences, hand
            # inlined (``Request.effective_slack``'s exact float ops, the
            # same int context sum), straight off the live requests — no
            # SoA rebuild, no numpy fixed cost. cols stays dirty; it
            # re-pulls when the batch grows past the threshold.
            ictx = 0
            for r in running.values():
                ictx += r.prompt_len + r.generated_tokens
            sum_ctx = float(ictx)
            base_iter = self.cost.decode_iter_time(n, sum_ctx)
            min_slack = float("inf")
            for r in running.values():
                rem = r.output_len - r.prior_tokens - r.generated_tokens
                if rem > 4:
                    rem = 4
                elif rem < 0:
                    rem = 0
                s = r.tpot_slack + max(0.0, r.slo.tpot - base_iter) * rem
                if s < min_slack:
                    min_slack = s
        else:
            cols = self._cols
            if cols.dirty:
                cols.rebuild(running, self.pages)
            sum_ctx = float(np.sum(cols.ctx))
            # memoized in fast mode — repeat signatures are dict hits
            base_iter = self.cost.decode_iter_time(n, sum_ctx)
            credit = np.maximum(0.0, cols.tpot_slo - base_iter) \
                * np.minimum(cols.rem_out, 4)
            min_slack = float(np.min(cols.tpot_slack + credit))
        floors = self._floors_cache
        if floors is None:
            floors = self._floors_cache = {
                name: min(counts)
                for name, counts in self._floor_counts.items()}
        pages = self.pages
        view = self.view
        set_ = object.__setattr__
        set_(view, "queued_prefill_tokens", self._q_tokens)
        set_(view, "queued_requests", len(self.prefill_queue))
        set_(view, "decode_batch", n)
        set_(view, "decode_sum_ctx", sum_ctx)
        set_(view, "min_tpot_slack", min_slack)
        set_(view, "decode_tpot_floor", floors)
        set_(view, "total_pages", pages.total_pages)
        set_(view, "free_pages", pages.free_pages)
        set_(view, "page_size", pages.page_size)
        set_(view, "host_total_pages", pages.host_total_pages)
        set_(view, "host_free_pages", pages.host_free_pages)
        pc = self.prefix_cache
        if pc is not None:
            if pc.version != self._prefix_seen:
                self._prefix_seen = pc.version
                set_(view, "cached_prefixes", pc.spans())
            set_(view, "prefix_hit_ewma", pc.hit_ewma)
        cols_mirror = view._cols
        if cols_mirror is not None:
            cols_mirror.dirty.add(view._row)

    # -------------------------------------------------------------- failure
    def fail(self, now: Optional[float] = None) -> list[Request]:
        """Worker dies: every held request must restart elsewhere — the
        host tier dies with its worker (it hangs off the same host), so
        parked/in-flight offloads are lost too, accounted exactly once
        (``offload_started`` entries are already in ``offloading``)."""
        self.view.alive = False
        lost = list(self.prefill_queue) + list(self.decode_running.values()) \
            + list(self.offloading.values()) + list(self.offloaded.values()) \
            + list(self.restoring.values())
        self.prefill_queue.clear()
        self.decode_running.clear()
        self.offload_started.clear()
        self.offloading.clear()
        self.offloaded.clear()
        self.restoring.clear()
        self._q_tokens = 0
        self._floor_counts.clear()
        self._floors_cache = {}
        self._batch_version += 1
        self._cols.dirty = True
        if self.prefix_cache is not None:
            self.prefix_cache.clear()   # entries died with the HBM
        self.view.kv_used_tokens = 0.0
        self.view.cached_prefixes = {}
        self.pages.reset()
        for r in lost:
            r.restarts += 1
            r.reset_for_reprefill(now)
        self._refresh_view()
        return lost
