"""Per-worker continuous-batching engine.

A Worker owns: a local prefill queue, the running decode batch, KV/state
accounting, and iteration composition (driven by the policy's BatchRule).
It is executor-agnostic: ``compose_iteration`` returns the work description;
the ClusterScheduler's ``ExecutionBackend`` (cost model or real JAX —
``repro.sched.backend``) supplies the duration; ``complete_iteration``
applies state transitions + SLO bookkeeping.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import math

from repro.core.policies import BatchRule, Policy
from repro.core.request import Phase, Request
from repro.core.toggle import Role, WorkerView
from repro.perf import CostModel
from repro.serving.kvcache import PageAccountant, PrefixIndex


def _slack_key(now: float):
    """Sort key for the class-aware 'slack' discipline: tightest relative
    TTFT slack first among requests that can still make their deadline;
    already-hopeless requests (deadline passed — TTFT is unattainable
    whatever happens next) go last, exactly like the 'edf' discipline's
    hopeless demotion — spending capacity on them ahead of salvageable
    work buys no attainment. Shared by worker queues and the scheduler's
    global overflow queue so both orders agree."""
    def key(r: Request):
        rel = r.rel_ttft_slack(now)
        return (rel <= 0.0, rel, r.arrival_time, r.rid)
    return key


@dataclasses.dataclass
class IterationPlan:
    decode_reqs: list          # requests getting one token this iteration
    prefill_parts: list        # (request, tokens) chunks executed
    n_decode: int
    sum_ctx: float
    prefill_tokens: int
    prefill_ctx_offset: float
    exclusive_prefill: bool    # decode stalled behind prefill (interference)

    @property
    def empty(self) -> bool:
        return self.n_decode == 0 and self.prefill_tokens == 0


class Worker:
    def __init__(self, wid: int, cost: CostModel, role: Role = Role.MULTIPLEX,
                 queue_discipline: str = "fcfs",
                 kv_preempt_watermark: float = 0.98,
                 host_pages: int = 0,
                 prefix_cache: Optional[PrefixIndex] = None,
                 offload_gate: Optional[Callable[[Request], bool]] = None):
        self.wid = wid
        self.cost = cost
        self.queue_discipline = queue_discipline   # fcfs | edf
        # page-granular HBM accounting: admission and growth gate on real
        # allocatable pages; crossing the watermark evicts decodes (which
        # pay a re-prefill on readmission) — unless a host-DRAM tier
        # (``host_pages`` > 0) can absorb the spill and the ``offload_gate``
        # (predictor-priced: restore beats re-prefill) approves
        self.pages = PageAccountant(cost.kv_capacity_pages(), cost.page_size,
                                    host_pages=host_pages)
        self.kv_preempt_watermark = kv_preempt_watermark
        # fast mode (build_cluster(vectorized=True)): coalesce the per-event
        # view rebuild into one refresh per completed iteration, use
        # phase-only membership checks and the view's maintained decode
        # context sum in place of O(batch) rescans. State transitions are
        # identical — tests/test_vectorized.py pins decision parity.
        self.fast = False
        self.prefix_cache = prefix_cache
        self.offload_gate = offload_gate
        self.view = WorkerView(
            wid=wid, role=role,
            kv_capacity_tokens=float(max(cost.kv_capacity_tokens(), 1)),
            total_pages=self.pages.total_pages,
            free_pages=self.pages.total_pages,
            page_size=self.pages.page_size,
            host_total_pages=self.pages.host_total_pages,
            host_free_pages=self.pages.host_total_pages,
        )
        self.prefill_queue: deque[Request] = deque()
        self.decode_running: list[Request] = []
        self.preempted: list[Request] = []       # drained by the simulator
        # tiered-KV lifecycle (scheduler drains/advances these):
        # offload_started -> engine starts the worker->host flow;
        # offloading (wire) -> offloaded (parked) -> restoring (wire back)
        self.offload_started: list[Request] = []
        self.offloading: dict[int, Request] = {}
        self.offloaded: dict[int, Request] = {}
        self.restoring: dict[int, Request] = {}
        self.busy = False
        # metrics
        self.blocked_time: dict[int, float] = {}
        self.queue_times: dict[int, float] = {}
        self.busy_time = 0.0
        # wall seconds this worker's decode batches spent blocked behind
        # co-batched prefill work, charged ONCE per mixed iteration (the
        # per-request ``blocked_time`` dict intentionally charges the same
        # interval to every blocked request — see complete_iteration)
        self.interference_time = 0.0
        self.preemption_count = 0
        self.offload_count = 0
        self.restore_count = 0
        self.pages_offloaded = 0
        self.pages_restored = 0
        self.pages_reprefilled = 0

    # ------------------------------------------------------------- admission
    def admit_prefill(self, req: Request, now: float) -> None:
        req.worker = self.wid
        self.prefill_queue.append(req)
        self._refresh_view()

    def admit_decode(self, req: Request, now: float) -> None:
        req.worker = self.wid
        req.phase = Phase.DECODING
        self.decode_running.append(req)
        self._refresh_view()

    def admit_migrated(self, req: Request, now: float) -> bool:
        """Admit a request whose KV just arrived over the links. False when
        the page pool cannot hold the migrated context (caller restarts the
        request elsewhere — the re-prefill cost of a failed placement)."""
        if not self.pages.reserve(req.rid, self._page_need(req.context_len,
                                                           req.cached_prefix)):
            return False
        self.view.kv_used_tokens += self._own_state(req, req.context_len)
        self.admit_decode(req, now)
        return True

    # ------------------------------------------------------------- planning
    def compose_iteration(self, rule: BatchRule, now: float) -> IterationPlan:
        decode_reqs: list[Request] = []
        prefill_parts: list[tuple[Request, int]] = []
        budget = rule.prefill_budget

        run_prefill_exclusively = (
            rule.prefill_exclusive and self._has_admissible_prefill())
        if run_prefill_exclusively:
            # full-prompt (or budget-bounded) prefill-only iteration
            taken = set()
            while budget > 0 and self._has_admissible_prefill():
                req = self._next_admissible_prefill(now)
                if req is None or req.rid in taken:
                    break
                take = min(req.remaining_prefill, budget)
                if take < req.remaining_prefill and prefill_parts:
                    break       # don't split a second prompt mid-iteration
                if not self._start_prefill(req, now):
                    break       # page pool can't hold the prompt yet
                prefill_parts.append((req, take))
                taken.add(req.rid)
                budget -= take
        else:
            if rule.run_decode:
                decode_reqs = list(self.decode_running)
            if budget > 0 and self._has_admissible_prefill():
                req = self._peek_admissible_prefill(now)
                if req is not None and self._start_prefill(req, now):
                    take = min(req.remaining_prefill, budget)
                    prefill_parts.append((req, take))

        if self.fast and rule.run_decode and not run_prefill_exclusively:
            # decode_reqs is exactly decode_running, whose context sum the
            # view maintains (refreshed after every mutation) — same value,
            # no O(batch) rescan
            sum_ctx = self.view.decode_sum_ctx
        else:
            sum_ctx = float(sum(r.context_len for r in decode_reqs))
        p_tokens = sum(t for _, t in prefill_parts)
        ctx_off = float(prefill_parts[0][0].prefilled_tokens) if prefill_parts else 0.0
        return IterationPlan(
            decode_reqs=decode_reqs, prefill_parts=prefill_parts,
            n_decode=len(decode_reqs), sum_ctx=sum_ctx,
            prefill_tokens=p_tokens, prefill_ctx_offset=ctx_off,
            exclusive_prefill=run_prefill_exclusively and bool(prefill_parts),
        )

    def plan_duration(self, plan: IterationPlan) -> float:
        return self.cost.iteration_time(
            plan.n_decode, plan.sum_ctx, plan.prefill_tokens,
            plan.prefill_ctx_offset)

    # ------------------------------------------------------------ completion
    def complete_iteration(self, plan: IterationPlan, now: float,
                           duration: float) -> list[Request]:
        """Apply effects at iteration end. Returns requests whose prefill
        finished this iteration (for decode dispatch)."""
        self.busy_time += duration
        finished_prefills: list[Request] = []
        # decode side. ``interference`` is the wall time this iteration ran
        # beyond a pure decode pass (piggybacked prefill compute + the §IV
        # contention penalty when γ is active). It is one per-ITERATION
        # quantity: the worker-level ``interference_time`` accumulates it
        # exactly once, while the per-request ``blocked_time`` dict charges
        # the same interval to EVERY blocked decode — deliberately, because
        # each request's stream really did stall that long (wall blocking
        # is concurrent, so per-request entries must never be summed across
        # a batch as if they were machine time).
        pure_decode = self.cost.decode_iter_time(plan.n_decode, plan.sum_ctx) \
            if plan.n_decode else 0.0
        interference = max(0.0, duration - pure_decode)
        if plan.n_decode and plan.prefill_tokens > 0:
            self.interference_time += interference
        fast = self.fast
        for r in plan.decode_reqs:
            # fast mode drops the list scan: every site that removes a
            # request from decode_running sets its phase away from DECODING
            # first, so the phase test alone is equivalent
            if r.phase != Phase.DECODING or \
                    (not fast and r not in self.decode_running):
                continue        # evicted mid-compose (page preemption)
            r.record_decode_iteration(duration)
            # grow the token counter by the request's true footprint
            # delta so release() — which frees state_tokens(ctx) — always
            # balances: 1.0 for dense KV, 0.5 past a sliding window's
            # cap, 0 for constant-state (rwkv/mamba, whose fixed state
            # was pinned in full at admission). A flat += 1 leaked the
            # difference on every finished request.
            self.view.kv_used_tokens += \
                self.cost.state_tokens(r.context_len) \
                - self.cost.state_tokens(r.context_len - 1)
            if plan.prefill_tokens > 0:
                self.blocked_time[r.rid] = \
                    self.blocked_time.get(r.rid, 0.0) + interference
            if r.remaining_output == 0:
                r.phase = Phase.FINISHED
                r.finish_time = now
                self.release(r, refresh=not fast)
        # page growth for the tokens just written; evict newest decodes
        # when the pool can't supply it, then enforce the watermark
        for r in plan.decode_reqs:
            if r.phase != Phase.DECODING or \
                    (not fast and r not in self.decode_running):
                continue
            need = self._page_need(r.context_len, r.cached_prefix)
            while not self.pages.reserve(r.rid, need):
                if self._evict_prefix_lru():
                    continue       # unreferenced cached prefixes go first
                if not self._preempt_one(now, keep=r):
                    self._preempt(r, now)      # nobody else to evict
                    break
        while self.pages.utilization > self.kv_preempt_watermark:
            if self._evict_prefix_lru():
                continue
            if len(self.decode_running) <= 1 or not self._preempt_one(now):
                break
        # decode requests stalled behind an exclusive prefill count as blocked
        if plan.exclusive_prefill:
            for r in self.decode_running:
                r.decode_time += duration
                r.tpot_slack -= duration       # the stall burns slack
                self.blocked_time[r.rid] = \
                    self.blocked_time.get(r.rid, 0.0) + duration
        # prefill side
        for req, tokens in plan.prefill_parts:
            req.prefilled_tokens += tokens
            if req.remaining_prefill == 0:
                req.record_first_token(now)
                # the prefill's forward pass emitted token #1: charge its
                # footprint (context grew past the prompt the admission
                # reservation covered), so release(st(final ctx)) balances
                # to zero over the request's life
                self.view.kv_used_tokens += \
                    self.cost.state_tokens(req.context_len) \
                    - self.cost.state_tokens(req.prompt_len)
                if (self.prefix_cache is not None
                        and req.prefix_key is not None
                        and req.cached_prefix == 0):
                    # first bearer of this shared prompt on this worker:
                    # retain a copy of the prefix span for later arrivals
                    self._cache_prefix(req)
                if req.remaining_output == 0:
                    req.phase = Phase.FINISHED
                    req.finish_time = now
                    self.release(req, refresh=not fast)
                else:
                    finished_prefills.append(req)
                if req in self.prefill_queue:
                    self.prefill_queue.remove(req)
        self._refresh_view()
        return finished_prefills

    def release(self, req: Request, refresh: bool = True) -> None:
        """Free KV held by a finished/migrated request (both tiers), and
        return any borrowed prefix-cache reference. ``refresh=False`` lets
        ``complete_iteration`` coalesce many releases into its single
        trailing view rebuild (the rebuild is a full recompute, so the
        final state is identical)."""
        self.view.kv_used_tokens = max(
            0.0, self.view.kv_used_tokens - self._own_state(req, req.context_len))
        self.pages.release(req.rid)
        if req.cached_prefix > 0 and self.prefix_cache is not None:
            self.prefix_cache.unref(req.prefix_key)
            req.cached_prefix = 0
        if req in self.decode_running:
            self.decode_running.remove(req)
        if refresh:
            self._refresh_view()

    # ------------------------------------------------------------ preemption
    def _preempt(self, req: Request, now: float) -> None:
        """Evict a decode's KV pages; the request re-prefills its whole
        context (the §IV-B eviction cost) wherever dispatch next places it."""
        req.preemptions += 1
        self.preemption_count += 1
        self.pages_reprefilled += self.pages.held_pages(req.rid)
        # preemption only happens inside complete_iteration, whose trailing
        # _refresh_view covers fast mode's skipped intermediate rebuild
        self.release(req, refresh=not self.fast)
        req.reset_for_reprefill(now)
        self.preempted.append(req)

    def _preempt_one(self, now: float, keep: Optional[Request] = None) -> bool:
        """Displace the most recently admitted decode (least sunk prefill
        work, vLLM-style LIFO recomputation). Prefers *offloading* its
        pages to the host-DRAM tier (restore later, no re-prefill) when the
        tier has room and the offload gate prices restore below re-prefill;
        falls back to eviction. Returns False when there is no eligible
        victim."""
        for victim in reversed(self.decode_running):
            if victim is not keep:
                if self._try_offload(victim, now):
                    return True
                self._preempt(victim, now)
                return True
        return False

    def drain_preempted(self) -> list[Request]:
        out, self.preempted = self.preempted, []
        return out

    # ------------------------------------------------------------- tiered KV
    def _try_offload(self, victim: Request, now: float) -> bool:
        """Move ``victim``'s KV accounting to the host tier instead of
        discarding it. The scheduler drains ``offload_started`` and puts
        the bytes on the host link."""
        if (self.offload_gate is None or self.pages.host_total_pages <= 0
                or not self.pages.can_offload(victim.rid)
                or not self.offload_gate(victim)):
            return False
        moved = self.pages.offload(victim.rid)
        if moved <= 0:
            return False
        victim.offloads += 1
        self.offload_count += 1
        self.pages_offloaded += moved
        victim.phase = Phase.OFFLOADED
        if victim.stall_start is None:
            victim.stall_start = now    # stream stalls until restore lands
        self.view.kv_used_tokens = max(
            0.0,
            self.view.kv_used_tokens - self._own_state(victim,
                                                       victim.context_len))
        # a borrowed prefix ref stays held across the park: the cached span
        # must still be resident when the restore lands
        self.decode_running.remove(victim)
        self.offloading[victim.rid] = victim
        self.offload_started.append(victim)
        return True

    def drain_offload_started(self) -> list[Request]:
        out, self.offload_started = self.offload_started, []
        return out

    def offload_landed(self, req: Request) -> None:
        """The worker->host flow completed; the request is restore-eligible."""
        if self.offloading.pop(req.rid, None) is not None:
            self.offloaded[req.rid] = req

    def next_restorable(self) -> Optional[Request]:
        """Oldest parked request whose pages fit back in HBM without
        pushing utilization past the preempt watermark (restoring must not
        immediately re-trigger the preemption it was meant to avoid)."""
        for rid, req in self.offloaded.items():
            pages = self.pages.host_held_pages(rid)
            would = (self.pages.used_pages + pages) \
                / max(self.pages.total_pages, 1)
            if pages <= self.pages.free_pages \
                    and would <= self.kv_preempt_watermark:
                return req
        return None

    def begin_restore(self, req: Request, now: float) -> bool:
        """Reserve the HBM destination and mark the restore in flight."""
        if req.rid not in self.offloaded or not self.pages.can_restore(req.rid):
            return False
        self.pages.restore(req.rid)
        del self.offloaded[req.rid]
        self.restoring[req.rid] = req
        self._refresh_view()
        return True

    def finish_restore(self, req: Request, now: float) -> bool:
        """Restore flow landed: rejoin the decode batch. The whole parked
        interval (offload wire + host dwell + restore wire) is inter-token
        latency the user saw — charged like migration wait."""
        if self.restoring.pop(req.rid, None) is None:
            return False               # stale completion (failure raced it)
        self.restore_count += 1
        self.pages_restored += self.pages.held_pages(req.rid)
        self.view.kv_used_tokens += self._own_state(req, req.context_len)
        req.restores += 1
        if req.stall_start is not None:
            gap = now - req.stall_start
            req.decode_time += gap
            req.tpot_slack -= gap
            req.stall_start = None
        self.admit_decode(req, now)
        return True

    # ------------------------------------------------------------- internals
    def _page_need(self, ctx_tokens: int, cached: int = 0) -> int:
        """Token-footprint the request's OWN reservation must cover: its
        full context minus any span borrowed from the prefix cache (whose
        pages are pinned under the cache entry's pseudo rid)."""
        st = self.cost.state_tokens(ctx_tokens)
        if cached > 0:
            st -= self.cost.state_tokens(cached)
        return int(math.ceil(max(st, 0.0)))

    def _own_state(self, req: Request, ctx_tokens: int) -> float:
        """``state_tokens`` charged to ``req`` itself (excludes the
        borrowed prefix span — the cache entry carries those tokens)."""
        st = self.cost.state_tokens(ctx_tokens)
        if req.cached_prefix > 0:
            st -= self.cost.state_tokens(req.cached_prefix)
        return max(st, 0.0)

    def _prefix_span(self, req: Request) -> int:
        """Tokens of ``req``'s prompt a prefill start here would borrow
        from the cache (0 without a hit). Capped at prompt_len - 1 so at
        least one prefill token always runs — the forward pass that emits
        the first token. Pure peek: no counters, no LRU touch."""
        if req.cached_prefix > 0:
            return req.cached_prefix
        if self.prefix_cache is None or req.prefix_key is None:
            return 0
        span = self.prefix_cache.peek(req.prefix_key)
        return max(0, min(span, req.prefix_len, req.prompt_len - 1))

    def _kv_room_for(self, req: Request) -> bool:
        span = self._prefix_span(req)
        if not self.pages.can_fit(self._page_need(req.prompt_len, span),
                                  rid=req.rid):
            return False
        st = self.cost.state_tokens(req.prompt_len)
        if span > 0:
            st -= self.cost.state_tokens(span)
        return self.view.kv_used_tokens + max(st, 0.0) \
            <= self.view.kv_capacity_tokens

    def _has_admissible_prefill(self) -> bool:
        return any(self._kv_room_for(r) or r.prefill_start is not None
                   for r in self.prefill_queue)

    def _prefill_order(self, now: float) -> list[Request]:
        """Queue order. 'fcfs' (the discipline of vLLM/Sarathi/DistServe and
        the paper's Tropical). 'slack' is the multi-tenant class-aware
        order: tightest-relative-TTFT-slack first — absolute seconds are
        not comparable across SLO classes, the consumed budget *fraction*
        is. A homogeneous queue (every request in one class) keeps the
        exact FCFS admission order, so single-class runs are
        decision-identical to the paper's discipline (an interactive-class
        arrival only ever overtakes *other-class* work). 'edf' is the
        beyond-paper SLO-aware order: earliest-deadline-first among
        requests that can still make TTFT; already-hopeless requests are
        served last (spending capacity on them in deadline order buys no
        attainment)."""
        if self.queue_discipline == "fcfs":
            return list(self.prefill_queue)

        if self.queue_discipline == "slack":
            if len({r.slo.name for r in self.prefill_queue}) <= 1:
                return list(self.prefill_queue)
            return sorted(self.prefill_queue, key=_slack_key(now))

        def key(r: Request):
            deadline = r.arrival_time + r.slo.ttft
            t_exec = self.cost.prefill_time(r.remaining_prefill,
                                            r.prefilled_tokens)
            hopeless = now + t_exec > deadline
            return (hopeless, deadline, r.rid)

        return sorted(self.prefill_queue, key=key)

    def peek_prefill(self, now: float) -> Optional[Request]:
        """Head-of-queue under the active discipline — what the policy's
        ``batch_rule`` sizes its chunk budget against. 'fcfs'/'edf' keep
        the legacy raw queue head; 'slack' surfaces the class-aware order's
        head (identical for a single-class queue). O(n) min, not a full
        sort — this runs on every _kick."""
        if not self.prefill_queue:
            return None
        if self.queue_discipline == "slack" and \
                len({r.slo.name for r in self.prefill_queue}) > 1:
            return min(self.prefill_queue, key=_slack_key(now))
        return self.prefill_queue[0]

    def _next_admissible_prefill(self, now: float) -> Optional[Request]:
        for r in self._prefill_order(now):
            if r.remaining_prefill > 0 and (
                    r.prefill_start is not None or self._kv_room_for(r)):
                return r
        return None

    def _peek_admissible_prefill(self, now: float) -> Optional[Request]:
        return self._next_admissible_prefill(now)

    def _start_prefill(self, req: Request, now: float) -> bool:
        """Reserve prompt KV and mark the prefill started. False (state
        untouched) when the page pool can't hold the prompt — unreachable
        behind the ``_kv_room_for`` admission gate, kept as the contract
        for callers. A prefix-cache hit borrows the cached span: only the
        uncached suffix reserves pages and runs prefill compute."""
        if req.prefill_start is None:
            span = self._prefix_span(req)
            if not self.pages.reserve(req.rid,
                                      self._page_need(req.prompt_len, span)):
                return False
            if self.prefix_cache is not None and req.prefix_key is not None:
                entry = self.prefix_cache.lookup(req.prefix_key)  # counted
                if entry is not None and span > 0:
                    entry.refs += 1
                    req.cached_prefix = span
                    req.prefilled_tokens = span
                    req.prefix_hits += 1
            req.prefill_start = now
            req.phase = Phase.PREFILLING
            self.queue_times[req.rid] = now - req.arrival_time
            self.view.kv_used_tokens += self._own_state(req, req.prompt_len)
        return True

    def _cache_prefix(self, req: Request) -> None:
        """Retain a copy of ``req``'s just-prefilled shared-prompt span for
        later arrivals. Skipped when the key is already cached, the span
        exceeds the cache's page budget, or HBM lacks free pages (the
        cache must never squeeze live decodes to populate itself)."""
        if self.cost.spec.kv_bytes_per_token <= 0:
            return          # constant-state families have no prefix KV
        if self.prefix_cache.peek(req.prefix_key) > 0:
            return          # another bearer landed first
        tokens = min(req.prefix_len, req.prompt_len)
        if tokens <= 0:
            return
        need = self._page_need(tokens)
        pages = self.pages.pages_for(need)
        if pages <= 0 or pages > self.prefix_cache.max_pages:
            return
        while self.prefix_cache.used_pages + pages > self.prefix_cache.max_pages:
            if not self._evict_prefix_lru():
                return
        if self.pages.free_pages < pages:
            return
        entry = self.prefix_cache.insert(req.prefix_key, tokens, pages)
        self.pages.reserve(entry.rid, need)
        self.view.kv_used_tokens += self.cost.state_tokens(tokens)

    def _evict_prefix_lru(self) -> bool:
        """Drop the LRU *unreferenced* cache entry and free its pages.
        False when the cache is off, empty, or every entry has a live
        borrower (those pages must not dangle under a mid-decode)."""
        if self.prefix_cache is None:
            return False
        entry = self.prefix_cache.evict_lru()
        if entry is None:
            return False
        self.pages.release(entry.rid)
        self.view.kv_used_tokens = max(
            0.0, self.view.kv_used_tokens
            - self.cost.state_tokens(entry.tokens))
        return True

    def _refresh_view(self) -> None:
        v = self.view
        v.queued_prefill_tokens = sum(r.remaining_prefill
                                      for r in self.prefill_queue)
        v.queued_requests = len(self.prefill_queue)
        v.decode_batch = len(self.decode_running)
        v.decode_sum_ctx = float(sum(r.context_len
                                     for r in self.decode_running))
        base_iter = self.cost.decode_iter_time(v.decode_batch,
                                               v.decode_sum_ctx) \
            if self.decode_running else 0.0
        v.min_tpot_slack = min(
            (r.effective_slack(base_iter) for r in self.decode_running),
            default=float("inf"))
        floors: dict[str, float] = {}
        for r in self.decode_running:
            name = r.slo.name
            floors[name] = min(floors.get(name, float("inf")), r.slo.tpot)
        v.decode_tpot_floor = floors
        v.total_pages = self.pages.total_pages
        v.free_pages = self.pages.free_pages
        v.page_size = self.pages.page_size
        v.host_total_pages = self.pages.host_total_pages
        v.host_free_pages = self.pages.host_free_pages
        if self.prefix_cache is not None:
            v.cached_prefixes = self.prefix_cache.spans()
            v.prefix_hit_ewma = self.prefix_cache.hit_ewma

    # -------------------------------------------------------------- failure
    def fail(self, now: Optional[float] = None) -> list[Request]:
        """Worker dies: every held request must restart elsewhere — the
        host tier dies with its worker (it hangs off the same host), so
        parked/in-flight offloads are lost too, accounted exactly once
        (``offload_started`` entries are already in ``offloading``)."""
        self.view.alive = False
        lost = list(self.prefill_queue) + list(self.decode_running) \
            + list(self.offloading.values()) + list(self.offloaded.values()) \
            + list(self.restoring.values())
        self.prefill_queue.clear()
        self.decode_running.clear()
        self.offload_started.clear()
        self.offloading.clear()
        self.offloaded.clear()
        self.restoring.clear()
        if self.prefix_cache is not None:
            self.prefix_cache.clear()   # entries died with the HBM
        self.view.kv_used_tokens = 0.0
        self.view.cached_prefixes = {}
        self.pages.reset()
        for r in lost:
            r.restarts += 1
            r.reset_for_reprefill(now)
        self._refresh_view()
        return lost
