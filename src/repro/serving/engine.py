"""Per-worker continuous-batching engine.

A Worker owns: a local prefill queue, the running decode batch, KV/state
accounting, and iteration composition (driven by the policy's BatchRule).
It is executor-agnostic: ``compose_iteration`` returns the work description;
the ClusterScheduler's ``ExecutionBackend`` (cost model or real JAX —
``repro.sched.backend``) supplies the duration; ``complete_iteration``
applies state transitions + SLO bookkeeping.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import math

from repro.core.policies import BatchRule, Policy
from repro.core.request import Phase, Request
from repro.core.toggle import Role, WorkerView
from repro.perf import CostModel
from repro.serving.kvcache import PageAccountant


def _slack_key(now: float):
    """Sort key for the class-aware 'slack' discipline: tightest relative
    TTFT slack first among requests that can still make their deadline;
    already-hopeless requests (deadline passed — TTFT is unattainable
    whatever happens next) go last, exactly like the 'edf' discipline's
    hopeless demotion — spending capacity on them ahead of salvageable
    work buys no attainment. Shared by worker queues and the scheduler's
    global overflow queue so both orders agree."""
    def key(r: Request):
        rel = r.rel_ttft_slack(now)
        return (rel <= 0.0, rel, r.arrival_time, r.rid)
    return key


@dataclasses.dataclass
class IterationPlan:
    decode_reqs: list          # requests getting one token this iteration
    prefill_parts: list        # (request, tokens) chunks executed
    n_decode: int
    sum_ctx: float
    prefill_tokens: int
    prefill_ctx_offset: float
    exclusive_prefill: bool    # decode stalled behind prefill (interference)

    @property
    def empty(self) -> bool:
        return self.n_decode == 0 and self.prefill_tokens == 0


class Worker:
    def __init__(self, wid: int, cost: CostModel, role: Role = Role.MULTIPLEX,
                 queue_discipline: str = "fcfs",
                 kv_preempt_watermark: float = 0.98):
        self.wid = wid
        self.cost = cost
        self.queue_discipline = queue_discipline   # fcfs | edf
        # page-granular HBM accounting: admission and growth gate on real
        # allocatable pages; crossing the watermark evicts decodes (which
        # pay a re-prefill on readmission)
        self.pages = PageAccountant(cost.kv_capacity_pages(), cost.page_size)
        self.kv_preempt_watermark = kv_preempt_watermark
        self.view = WorkerView(
            wid=wid, role=role,
            kv_capacity_tokens=float(max(cost.kv_capacity_tokens(), 1)),
            total_pages=self.pages.total_pages,
            free_pages=self.pages.total_pages,
            page_size=self.pages.page_size,
        )
        self.prefill_queue: deque[Request] = deque()
        self.decode_running: list[Request] = []
        self.preempted: list[Request] = []       # drained by the simulator
        self.busy = False
        # metrics
        self.blocked_time: dict[int, float] = {}
        self.queue_times: dict[int, float] = {}
        self.busy_time = 0.0
        # wall seconds this worker's decode batches spent blocked behind
        # co-batched prefill work, charged ONCE per mixed iteration (the
        # per-request ``blocked_time`` dict intentionally charges the same
        # interval to every blocked request — see complete_iteration)
        self.interference_time = 0.0
        self.preemption_count = 0

    # ------------------------------------------------------------- admission
    def admit_prefill(self, req: Request, now: float) -> None:
        req.worker = self.wid
        self.prefill_queue.append(req)
        self._refresh_view()

    def admit_decode(self, req: Request, now: float) -> None:
        req.worker = self.wid
        req.phase = Phase.DECODING
        self.decode_running.append(req)
        self._refresh_view()

    def admit_migrated(self, req: Request, now: float) -> bool:
        """Admit a request whose KV just arrived over the links. False when
        the page pool cannot hold the migrated context (caller restarts the
        request elsewhere — the re-prefill cost of a failed placement)."""
        if not self.pages.reserve(req.rid, self._page_need(req.context_len)):
            return False
        self.view.kv_used_tokens += self.cost.state_tokens(req.context_len)
        self.admit_decode(req, now)
        return True

    # ------------------------------------------------------------- planning
    def compose_iteration(self, rule: BatchRule, now: float) -> IterationPlan:
        decode_reqs: list[Request] = []
        prefill_parts: list[tuple[Request, int]] = []
        budget = rule.prefill_budget

        run_prefill_exclusively = (
            rule.prefill_exclusive and self._has_admissible_prefill())
        if run_prefill_exclusively:
            # full-prompt (or budget-bounded) prefill-only iteration
            taken = set()
            while budget > 0 and self._has_admissible_prefill():
                req = self._next_admissible_prefill(now)
                if req is None or req.rid in taken:
                    break
                take = min(req.remaining_prefill, budget)
                if take < req.remaining_prefill and prefill_parts:
                    break       # don't split a second prompt mid-iteration
                if not self._start_prefill(req, now):
                    break       # page pool can't hold the prompt yet
                prefill_parts.append((req, take))
                taken.add(req.rid)
                budget -= take
        else:
            if rule.run_decode:
                decode_reqs = list(self.decode_running)
            if budget > 0 and self._has_admissible_prefill():
                req = self._peek_admissible_prefill(now)
                if req is not None and self._start_prefill(req, now):
                    take = min(req.remaining_prefill, budget)
                    prefill_parts.append((req, take))

        sum_ctx = float(sum(r.context_len for r in decode_reqs))
        p_tokens = sum(t for _, t in prefill_parts)
        ctx_off = float(prefill_parts[0][0].prefilled_tokens) if prefill_parts else 0.0
        return IterationPlan(
            decode_reqs=decode_reqs, prefill_parts=prefill_parts,
            n_decode=len(decode_reqs), sum_ctx=sum_ctx,
            prefill_tokens=p_tokens, prefill_ctx_offset=ctx_off,
            exclusive_prefill=run_prefill_exclusively and bool(prefill_parts),
        )

    def plan_duration(self, plan: IterationPlan) -> float:
        return self.cost.iteration_time(
            plan.n_decode, plan.sum_ctx, plan.prefill_tokens,
            plan.prefill_ctx_offset)

    # ------------------------------------------------------------ completion
    def complete_iteration(self, plan: IterationPlan, now: float,
                           duration: float) -> list[Request]:
        """Apply effects at iteration end. Returns requests whose prefill
        finished this iteration (for decode dispatch)."""
        self.busy_time += duration
        finished_prefills: list[Request] = []
        # decode side. ``interference`` is the wall time this iteration ran
        # beyond a pure decode pass (piggybacked prefill compute + the §IV
        # contention penalty when γ is active). It is one per-ITERATION
        # quantity: the worker-level ``interference_time`` accumulates it
        # exactly once, while the per-request ``blocked_time`` dict charges
        # the same interval to EVERY blocked decode — deliberately, because
        # each request's stream really did stall that long (wall blocking
        # is concurrent, so per-request entries must never be summed across
        # a batch as if they were machine time).
        pure_decode = self.cost.decode_iter_time(plan.n_decode, plan.sum_ctx) \
            if plan.n_decode else 0.0
        interference = max(0.0, duration - pure_decode)
        if plan.n_decode and plan.prefill_tokens > 0:
            self.interference_time += interference
        for r in plan.decode_reqs:
            if r.phase != Phase.DECODING or r not in self.decode_running:
                continue        # evicted mid-compose (page preemption)
            r.record_decode_iteration(duration)
            # grow the token counter by the request's true footprint
            # delta so release() — which frees state_tokens(ctx) — always
            # balances: 1.0 for dense KV, 0.5 past a sliding window's
            # cap, 0 for constant-state (rwkv/mamba, whose fixed state
            # was pinned in full at admission). A flat += 1 leaked the
            # difference on every finished request.
            self.view.kv_used_tokens += \
                self.cost.state_tokens(r.context_len) \
                - self.cost.state_tokens(r.context_len - 1)
            if plan.prefill_tokens > 0:
                self.blocked_time[r.rid] = \
                    self.blocked_time.get(r.rid, 0.0) + interference
            if r.remaining_output == 0:
                r.phase = Phase.FINISHED
                r.finish_time = now
                self.release(r)
        # page growth for the tokens just written; evict newest decodes
        # when the pool can't supply it, then enforce the watermark
        for r in plan.decode_reqs:
            if r.phase != Phase.DECODING or r not in self.decode_running:
                continue
            need = self._page_need(r.context_len)
            while not self.pages.reserve(r.rid, need):
                if not self._preempt_one(now, keep=r):
                    self._preempt(r, now)      # nobody else to evict
                    break
        while (self.pages.utilization > self.kv_preempt_watermark
               and len(self.decode_running) > 1):
            if not self._preempt_one(now):
                break
        # decode requests stalled behind an exclusive prefill count as blocked
        if plan.exclusive_prefill:
            for r in self.decode_running:
                r.decode_time += duration
                r.tpot_slack -= duration       # the stall burns slack
                self.blocked_time[r.rid] = \
                    self.blocked_time.get(r.rid, 0.0) + duration
        # prefill side
        for req, tokens in plan.prefill_parts:
            req.prefilled_tokens += tokens
            if req.remaining_prefill == 0:
                req.record_first_token(now)
                # the prefill's forward pass emitted token #1: charge its
                # footprint (context grew past the prompt the admission
                # reservation covered), so release(st(final ctx)) balances
                # to zero over the request's life
                self.view.kv_used_tokens += \
                    self.cost.state_tokens(req.context_len) \
                    - self.cost.state_tokens(req.prompt_len)
                if req.remaining_output == 0:
                    req.phase = Phase.FINISHED
                    req.finish_time = now
                    self.release(req)
                else:
                    finished_prefills.append(req)
                if req in self.prefill_queue:
                    self.prefill_queue.remove(req)
        self._refresh_view()
        return finished_prefills

    def release(self, req: Request) -> None:
        """Free KV held by a finished/migrated request."""
        self.view.kv_used_tokens = max(
            0.0, self.view.kv_used_tokens - self.cost.state_tokens(req.context_len))
        self.pages.release(req.rid)
        if req in self.decode_running:
            self.decode_running.remove(req)
        self._refresh_view()

    # ------------------------------------------------------------ preemption
    def _preempt(self, req: Request, now: float) -> None:
        """Evict a decode's KV pages; the request re-prefills its whole
        context (the §IV-B eviction cost) wherever dispatch next places it."""
        req.preemptions += 1
        self.preemption_count += 1
        self.release(req)
        req.reset_for_reprefill(now)
        self.preempted.append(req)

    def _preempt_one(self, now: float, keep: Optional[Request] = None) -> bool:
        """Evict the most recently admitted decode (least sunk prefill work,
        vLLM-style LIFO recomputation). Returns False when there is no
        eligible victim."""
        for victim in reversed(self.decode_running):
            if victim is not keep:
                self._preempt(victim, now)
                return True
        return False

    def drain_preempted(self) -> list[Request]:
        out, self.preempted = self.preempted, []
        return out

    # ------------------------------------------------------------- internals
    def _page_need(self, ctx_tokens: int) -> int:
        return int(math.ceil(self.cost.state_tokens(ctx_tokens)))

    def _kv_room_for(self, req: Request) -> bool:
        if not self.pages.can_fit(self._page_need(req.prompt_len),
                                  rid=req.rid):
            return False
        return self.view.kv_used_tokens + self.cost.state_tokens(req.prompt_len) \
            <= self.view.kv_capacity_tokens

    def _has_admissible_prefill(self) -> bool:
        return any(self._kv_room_for(r) or r.prefill_start is not None
                   for r in self.prefill_queue)

    def _prefill_order(self, now: float) -> list[Request]:
        """Queue order. 'fcfs' (the discipline of vLLM/Sarathi/DistServe and
        the paper's Tropical). 'slack' is the multi-tenant class-aware
        order: tightest-relative-TTFT-slack first — absolute seconds are
        not comparable across SLO classes, the consumed budget *fraction*
        is. A homogeneous queue (every request in one class) keeps the
        exact FCFS admission order, so single-class runs are
        decision-identical to the paper's discipline (an interactive-class
        arrival only ever overtakes *other-class* work). 'edf' is the
        beyond-paper SLO-aware order: earliest-deadline-first among
        requests that can still make TTFT; already-hopeless requests are
        served last (spending capacity on them in deadline order buys no
        attainment)."""
        if self.queue_discipline == "fcfs":
            return list(self.prefill_queue)

        if self.queue_discipline == "slack":
            if len({r.slo.name for r in self.prefill_queue}) <= 1:
                return list(self.prefill_queue)
            return sorted(self.prefill_queue, key=_slack_key(now))

        def key(r: Request):
            deadline = r.arrival_time + r.slo.ttft
            t_exec = self.cost.prefill_time(r.remaining_prefill,
                                            r.prefilled_tokens)
            hopeless = now + t_exec > deadline
            return (hopeless, deadline, r.rid)

        return sorted(self.prefill_queue, key=key)

    def peek_prefill(self, now: float) -> Optional[Request]:
        """Head-of-queue under the active discipline — what the policy's
        ``batch_rule`` sizes its chunk budget against. 'fcfs'/'edf' keep
        the legacy raw queue head; 'slack' surfaces the class-aware order's
        head (identical for a single-class queue). O(n) min, not a full
        sort — this runs on every _kick."""
        if not self.prefill_queue:
            return None
        if self.queue_discipline == "slack" and \
                len({r.slo.name for r in self.prefill_queue}) > 1:
            return min(self.prefill_queue, key=_slack_key(now))
        return self.prefill_queue[0]

    def _next_admissible_prefill(self, now: float) -> Optional[Request]:
        for r in self._prefill_order(now):
            if r.remaining_prefill > 0 and (
                    r.prefill_start is not None or self._kv_room_for(r)):
                return r
        return None

    def _peek_admissible_prefill(self, now: float) -> Optional[Request]:
        return self._next_admissible_prefill(now)

    def _start_prefill(self, req: Request, now: float) -> bool:
        """Reserve prompt KV and mark the prefill started. False (state
        untouched) when the page pool can't hold the prompt — unreachable
        behind the ``_kv_room_for`` admission gate, kept as the contract
        for callers."""
        if req.prefill_start is None:
            if not self.pages.reserve(req.rid,
                                      self._page_need(req.prompt_len)):
                return False
            req.prefill_start = now
            req.phase = Phase.PREFILLING
            self.queue_times[req.rid] = now - req.arrival_time
            self.view.kv_used_tokens += self.cost.state_tokens(req.prompt_len)
        return True

    def _refresh_view(self) -> None:
        v = self.view
        v.queued_prefill_tokens = sum(r.remaining_prefill
                                      for r in self.prefill_queue)
        v.queued_requests = len(self.prefill_queue)
        v.decode_batch = len(self.decode_running)
        v.decode_sum_ctx = float(sum(r.context_len
                                     for r in self.decode_running))
        base_iter = self.cost.decode_iter_time(v.decode_batch,
                                               v.decode_sum_ctx) \
            if self.decode_running else 0.0
        v.min_tpot_slack = min(
            (r.effective_slack(base_iter) for r in self.decode_running),
            default=float("inf"))
        floors: dict[str, float] = {}
        for r in self.decode_running:
            name = r.slo.name
            floors[name] = min(floors.get(name, float("inf")), r.slo.tpot)
        v.decode_tpot_floor = floors
        v.total_pages = self.pages.total_pages
        v.free_pages = self.pages.free_pages
        v.page_size = self.pages.page_size

    # -------------------------------------------------------------- failure
    def fail(self, now: Optional[float] = None) -> list[Request]:
        """Worker dies: every held request must restart elsewhere."""
        self.view.alive = False
        lost = list(self.prefill_queue) + list(self.decode_running)
        self.prefill_queue.clear()
        self.decode_running.clear()
        self.view.kv_used_tokens = 0.0
        self.pages.reset()
        for r in lost:
            r.restarts += 1
            r.reset_for_reprefill(now)
        self._refresh_view()
        return lost
