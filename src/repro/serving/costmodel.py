"""DEPRECATED import shim — the cost model moved to ``repro.perf``.

Every name below is re-exported unchanged (same classes, same objects —
``isinstance`` checks and pickle-free configs keep working), so existing
import paths stay valid. New code should import from ``repro.perf``:

    from repro.perf import CostModel, HardwareSpec, WorkerSpec, V5E

The move gave the model a home of its own: per-worker ``HardwareSpec``
(heterogeneous clusters), the §IV mixed-batch interference term, the
per-worker online calibration layer and the measured-MFU calibrated
roofline all live in ``src/repro/perf/``.
"""
from repro.perf.hardware import V5E, HardwareSpec, WorkerSpec
from repro.perf.model import (CostModel, IterationCostModel, ModelCostSpec,
                              build_cost_spec, relative_speeds)

__all__ = [
    "CostModel", "HardwareSpec", "IterationCostModel", "ModelCostSpec",
    "V5E", "WorkerSpec", "build_cost_spec", "relative_speeds",
]
