"""Analytical TPU-v5e worker step-time model.

Used by (a) the SimExecutor as the simulation clock and (b) the scheduler's
execution-time predictor (§IV-C: "we leverage offline profiling tools to
estimate the execution time of a prefill request" — prefill time on
XLA/TPU static shapes is even more predictable than on GPU).

The model is a two-term roofline per iteration:

    t = max(FLOPs / (chips·peak·mfu),  bytes / (chips·bw·eff)) + t_fixed

with per-family FLOP/byte accounting (dense / MoE active params / rwkv &
mamba constant-state / enc-dec).  Hardware constants follow the assignment:
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI per chip.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.models.layers import ModelConfig


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16 per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    hbm_bytes: float = 16e9           # per chip
    ici_bw: float = 50e9              # bytes/s per link
    ici_links: int = 2                # usable links for P2P KV migration
    mfu_prefill: float = 0.55         # achievable fraction of peak, big GEMMs
    mfu_decode: float = 0.6           # decode GEMMs are memory bound anyway
    bw_eff: float = 0.8
    t_fixed: float = 0.003            # per-iteration dispatch overhead (s)
    migration_latency: float = 0.001  # per-migration fixed cost (s)


V5E = HardwareSpec()


@dataclasses.dataclass(frozen=True)
class ModelCostSpec:
    """Closed-form per-token cost coefficients for one architecture."""
    name: str
    n_params: float                 # total parameters
    n_active: float                 # matmul-active params per token
    kv_bytes_per_token: float       # bytes of KV/state written per token
    attn_flops_per_ctx_token: float  # 4·Hq·Dh summed over ctx-attending layers
    ctx_cap: Optional[int]          # sliding-window cap (gemma2 local layers)
    state_bytes: float              # constant per-request state (rwkv/mamba)
    bytes_per_weight: float = 2.0   # bf16


def _transformer_attn_params(cfg: ModelConfig) -> float:
    p = (cfg.d_model * cfg.num_heads * cfg.head_dim          # wq
         + 2 * cfg.d_model * cfg.num_kv_heads * cfg.head_dim  # wk, wv
         + cfg.num_heads * cfg.head_dim * cfg.d_model)        # wo
    if cfg.qkv_bias:
        p += (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.head_dim
    return p


def build_cost_spec(cfg: ModelConfig) -> ModelCostSpec:
    d, f, L, v = cfg.d_model, cfg.d_ff, cfg.num_layers, cfg.vocab_size
    embed = v * d * (1 if cfg.tie_embeddings else 2)
    mlp = (3 if cfg.mlp_gated else 2) * d * f

    if cfg.family in ("dense", "vlm"):
        per_layer = _transformer_attn_params(cfg) + mlp
        total = embed + L * per_layer
        active = L * per_layer + v * d      # unembed matmul counts as active
        kv = 2 * L * cfg.num_kv_heads * cfg.head_dim * 2.0
        attn_c = 4.0 * cfg.num_heads * cfg.head_dim * L
        ctx_cap = cfg.sliding_window if cfg.local_global_alternating else None
        state = 0.0
    elif cfg.family == "moe":
        experts = cfg.num_experts * 3 * d * f
        shared = cfg.num_shared_experts * 3 * d * f
        dense_res = (3 * d * cfg.moe_dense_residual_ff
                     if cfg.moe_dense_residual_ff else 0)
        router = d * cfg.num_experts
        per_layer = _transformer_attn_params(cfg) + experts + shared \
            + dense_res + router
        per_layer_active = _transformer_attn_params(cfg) \
            + cfg.top_k * 3 * d * f + shared + dense_res + router
        total = embed + L * per_layer
        active = L * per_layer_active + v * d
        kv = 2 * L * cfg.num_kv_heads * cfg.head_dim * 2.0
        attn_c = 4.0 * cfg.num_heads * cfg.head_dim * L
        ctx_cap, state = None, 0.0
    elif cfg.family == "rwkv":
        # tm: 5 square proj + lora; cm: 2 d·f + d·d
        per_layer = 5 * d * d + d * (5 * 32) + d * 64 + 64 * d \
            + 2 * d * f + d * d
        total = embed + L * per_layer
        active = L * per_layer + v * d
        kv = 0.0
        attn_c = 0.0
        ctx_cap = None
        state = L * (d / 64) * 64 * 64 * 4.0 + 2 * L * d * 2.0  # wkv f32
    elif cfg.family == "hybrid":
        d_inner = cfg.ssm_expand * d
        n_heads = d_inner // 64
        mamba = 2 * d * d_inner + 2 * d * cfg.ssm_state + d * n_heads \
            + d_inner * d
        shared = _transformer_attn_params(cfg) + mlp + 2 * d * d + d * d
        ninv = (L + cfg.attn_every - 1) // cfg.attn_every
        total = embed + L * mamba + shared
        active = L * mamba + ninv * shared + v * d
        kv = 2 * ninv * cfg.num_kv_heads * cfg.head_dim * 2.0
        attn_c = 4.0 * cfg.num_heads * cfg.head_dim * ninv
        ctx_cap = None
        state = L * (n_heads * 64 * cfg.ssm_state * 4.0
                     + (cfg.ssm_conv - 1) * (d_inner + 2 * cfg.ssm_state) * 2.0)
    elif cfg.family == "encdec":
        n_enc = cfg.encoder_layers or L
        enc_layer = _transformer_attn_params(cfg) + mlp
        dec_layer = 2 * _transformer_attn_params(cfg) + mlp
        total = embed + n_enc * enc_layer + L * dec_layer
        active = L * dec_layer + v * d          # decode-side active
        kv = 2 * L * cfg.num_kv_heads * cfg.head_dim * 2.0
        attn_c = 4.0 * cfg.num_heads * cfg.head_dim * L * 2  # self + cross
        ctx_cap = None
        state = 0.0
    else:
        raise ValueError(cfg.family)

    return ModelCostSpec(
        name=cfg.name, n_params=float(total), n_active=float(active),
        kv_bytes_per_token=float(kv), attn_flops_per_ctx_token=float(attn_c),
        ctx_cap=ctx_cap, state_bytes=float(state),
    )


@dataclasses.dataclass(frozen=True)
class WorkerSpec:
    """One serving worker = ``tp`` chips running one model replica."""
    tp: int = 4
    hw: HardwareSpec = V5E

    @property
    def peak_flops(self) -> float:
        return self.tp * self.hw.peak_flops

    @property
    def hbm_bw(self) -> float:
        return self.tp * self.hw.hbm_bw

    @property
    def hbm_bytes(self) -> float:
        return self.tp * self.hw.hbm_bytes


class CostModel:
    """Iteration-time + capacity model for one (model, worker) pair."""

    def __init__(self, cfg: ModelConfig, worker: WorkerSpec = WorkerSpec(),
                 page_size: int = 16):
        self.cfg = cfg
        self.spec = build_cost_spec(cfg)
        self.worker = worker
        self.page_size = page_size          # KV block granularity (tokens)
        self.params_bytes = self.spec.n_params * self.spec.bytes_per_weight

    # ------------------------------------------------------------ capacity
    def kv_capacity_pages(self, reserve_frac: float = 0.1) -> int:
        """Allocatable KV pages per worker (page = ``page_size`` tokens)."""
        return max(1, self.kv_capacity_tokens(reserve_frac) // self.page_size)

    def kv_capacity_tokens(self, reserve_frac: float = 0.1) -> int:
        free = self.worker.hbm_bytes * (1 - reserve_frac) - self.params_bytes
        if self.spec.kv_bytes_per_token <= 0:
            # constant-state family: capacity = #states that fit
            per = max(self.spec.state_bytes, 1.0)
            return int(free / per) * 10_000   # effectively request-bounded
        return max(0, int(free / self.spec.kv_bytes_per_token))

    def state_tokens(self, ctx: int) -> float:
        """HBM tokens-equivalent held by a request with context ctx."""
        if self.spec.kv_bytes_per_token <= 0:
            return self.spec.state_bytes / max(self.spec.kv_bytes_per_token, 1.0) \
                if self.spec.kv_bytes_per_token else 0.0
        cap = self.spec.ctx_cap
        if cap is not None:
            # gemma2: half the layers hold only window-sized KV
            return ctx * 0.5 + min(ctx, cap) * 0.5
        return float(ctx)

    # --------------------------------------------------------------- steps
    def _roofline(self, flops: float, bytes_: float, mfu: float) -> float:
        hw = self.worker.hw
        t_c = flops / (self.worker.peak_flops * mfu)
        t_m = bytes_ / (self.worker.hbm_bw * hw.bw_eff)
        return max(t_c, t_m) + hw.t_fixed

    def _attn_ctx(self, ctx: float) -> float:
        cap = self.spec.ctx_cap
        if cap is None:
            return ctx
        return 0.5 * ctx + 0.5 * min(ctx, cap)

    def iteration_time(self, n_decode: int, sum_ctx: float,
                       prefill_tokens: int = 0,
                       prefill_ctx_offset: float = 0.0) -> float:
        """One engine iteration: a decode batch (n_decode requests whose
        contexts sum to sum_ctx) plus an optional piggybacked prefill chunk
        of ``prefill_tokens`` starting at context ``prefill_ctx_offset``."""
        s = self.spec
        flops = 0.0
        bytes_ = 0.0
        if n_decode > 0:
            flops += 2.0 * s.n_active * n_decode
            flops += s.attn_flops_per_ctx_token * self._attn_ctx(sum_ctx)
            bytes_ += s.kv_bytes_per_token * self._attn_ctx(sum_ctx)
            bytes_ += s.state_bytes * n_decode * 2  # rwkv/mamba state rw
        if prefill_tokens > 0:
            p, c = float(prefill_tokens), float(prefill_ctx_offset)
            flops += 2.0 * s.n_active * p
            flops += s.attn_flops_per_ctx_token * self._attn_ctx(c + p / 2) * p
            bytes_ += s.kv_bytes_per_token * (self._attn_ctx(c + p) + p)
        if flops == 0.0 and bytes_ == 0.0:
            return 0.0
        bytes_ += self.params_bytes  # weights stream once per iteration
        mfu = (self.worker.hw.mfu_prefill if prefill_tokens > 0
               else self.worker.hw.mfu_decode)
        return self._roofline(flops, bytes_, mfu)

    def prefill_time(self, prompt_tokens: int, ctx_offset: int = 0) -> float:
        return self.iteration_time(0, 0.0, prompt_tokens, ctx_offset)

    def decode_iter_time(self, n_decode: int, sum_ctx: float) -> float:
        return self.iteration_time(n_decode, sum_ctx)

    # ----------------------------------------------------------- migration
    def kv_transfer_bytes(self, ctx_tokens: int) -> float:
        """Bytes of KV/state that must cross the ICI links to migrate a
        request with context ``ctx_tokens``."""
        return self.spec.kv_bytes_per_token * self.state_tokens(ctx_tokens) \
            + self.spec.state_bytes

    def migration_time(self, ctx_tokens: int) -> float:
        """Uncontended lower bound (the seed's fixed-delay model); the
        contended path lives in serving/transfer.py."""
        hw = self.worker.hw
        bw = hw.ici_bw * hw.ici_links
        return hw.migration_latency + self.kv_transfer_bytes(ctx_tokens) / bw
