"""The Multiplexing Toggle (paper §IV) — Tropical's cluster scheduler.

Responsibilities (Fig. 6):
  * assignment: classify workers as PREFILL or MULTIPLEX;
  * dispatching: route requests
      Path ① -> prefill workers (queue-dominated regime),
      Path ② -> multiplexing workers directly (interference within budget);
  * track per-worker status: HBM watermark, local queue, decode batch,
    accumulated TPOT slack (§IV-B);
  * role transitions: P->M when every multiplexing worker is above the HBM
    watermark; M->P when prefill queuing persistently violates TTFT slack.
    Transitions only change *admission* — running decodes drain in place,
    so there is no migration/recompute overhead (the paper's asymmetry
    argument: D->P switching is the expensive direction and is avoided).
    Under the unified ClusterScheduler the event-driven, windowed
    ``repro.sched.rebalance.RoleRebalancer`` owns this lifecycle and the
    dispatch-count ``review_roles`` here is disabled
    (``ToggleConfig.role_transitions=False``).

The toggle is executor-agnostic: it sees ``WorkerView`` state snapshots and
returns dispatch decisions; the engine (serving/engine.py) owns execution.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional, Sequence

import numpy as np

from repro.core.predictor import Predictor
from repro.core.request import Phase, Request


class Role(enum.Enum):
    PREFILL = "prefill"
    MULTIPLEX = "multiplex"


@dataclasses.dataclass
class WorkerView:
    """Scheduler-visible state of one worker (kept current by the engine).

    When a ``ViewColumns`` mirror is attached (vectorized dispatch), every
    field assignment marks this view's row dirty so the column arrays
    re-pull it before the next batched decision — writers (engine refresh,
    role transitions, failure paths) never need to know about the mirror.
    """
    wid: int
    role: Role
    # prefill side
    queued_prefill_tokens: int = 0          # tokens waiting in local queue
    queued_requests: int = 0
    # decode side
    decode_batch: int = 0                   # running decode requests
    decode_sum_ctx: float = 0.0
    min_tpot_slack: float = float("inf")    # min over running decodes
    decode_tpot_floor: dict = dataclasses.field(default_factory=dict)
                                            # class name -> tightest TPOT
                                            # SLO among running decodes of
                                            # that class: multi-tenant
                                            # admission must protect the
                                            # tightest *resident* class,
                                            # not just the arriving
                                            # request's
    # memory — token-level (legacy) and page-level (paged KV accounting)
    kv_used_tokens: float = 0.0
    kv_capacity_tokens: float = 1.0
    total_pages: int = 0                    # 0 = worker has no page pool
    free_pages: int = 0
    page_size: int = 16
    # host-DRAM tier (tiered KV): 0 pages = tier disabled, every tier-aware
    # branch below degenerates to the legacy evict-only decision
    host_total_pages: int = 0
    host_free_pages: int = 0
    # prefix cache: {prefix_key: cached tokens} resident on this worker +
    # the cache's EWMA hit-rate estimate (dispatch-score signal)
    cached_prefixes: dict = dataclasses.field(default_factory=dict)
    prefix_hit_ewma: float = 0.0
    alive: bool = True
    # hardware — relative throughput of this worker's HardwareSpec
    # (fastest worker in the cluster = 1.0; see repro.perf.relative_speeds).
    # Load comparisons divide by it so "least loaded" means "finishes
    # soonest": a 2x-slow straggler with half the queue is NOT less loaded.
    # Homogeneous clusters have speed 1.0 everywhere, keeping every
    # ordering (and thus every decision) bit-identical to the pre-perf
    # scheduler.
    speed: float = 1.0

    # ViewColumns back-reference; CLASS attributes (not dataclass fields)
    # so unattached views — and the dataclass __init__'s own assignments,
    # which run before attach — resolve them without per-instance state.
    _cols = None
    _row = -1

    def __setattr__(self, name, value):
        object.__setattr__(self, name, value)
        c = self._cols
        if c is not None:
            c.dirty.add(self._row)

    def assign(self, **fields) -> None:
        """Bulk field update with ONE dirty-mark: the engine's per-event
        view refresh writes ~12 fields back to back, and marking the row
        once instead of per assignment keeps the mirror contract while
        dropping the redundant set-adds from the hottest write path."""
        setattr_ = object.__setattr__
        for name, value in fields.items():
            setattr_(self, name, value)
        c = self._cols
        if c is not None:
            c.dirty.add(self._row)

    @property
    def hbm_util(self) -> float:
        if self.total_pages > 0:
            return 1.0 - self.free_pages / self.total_pages
        return self.kv_used_tokens / max(self.kv_capacity_tokens, 1.0)

    def pages_for(self, tokens: float) -> int:
        from repro.serving.kvcache import pages_for
        return pages_for(int(tokens), self.page_size)

    def page_headroom_for(self, tokens: float, watermark: float = 1.0) -> bool:
        """Would admitting ``tokens`` keep page usage under ``watermark``?
        True when the worker reports no page pool (token check governs)."""
        if self.total_pages <= 0:
            return True
        used_after = (self.total_pages - self.free_pages
                      + self.pages_for(tokens))
        return used_after <= watermark * self.total_pages

    @property
    def unfinished_tokens(self) -> float:
        """InFaaS-style load metric: fewest unfinished token count."""
        return self.queued_prefill_tokens + self.decode_sum_ctx


class ViewColumns:
    """Dirty-flagged structure-of-arrays mirror of the worker views.

    Batched dispatch reads whole per-worker columns (pages, KV usage,
    batch sizes, slack, load) as numpy arrays instead of re-gathering
    them from Python objects on every decision. ``WorkerView.__setattr__``
    marks a row dirty on ANY field write, and ``sync`` re-pulls exactly
    the dirty rows — one event touches one worker, so the per-dispatch
    sync cost is O(touched workers), not O(cluster). The dict-valued
    fields (``decode_tpot_floor``, ``cached_prefixes``) stay on the view
    objects; the few code paths that need them walk only the rows that
    survived the array gates."""

    def __init__(self, views: Sequence[WorkerView]):
        self.views = list(views)
        n = len(self.views)
        self.dirty: set = set()
        self.wid = np.empty(n, dtype=np.int64)
        self.total_pages = np.empty(n, dtype=np.int64)
        self.free_pages = np.empty(n, dtype=np.int64)
        self.page_size = np.empty(n, dtype=np.int64)
        self.decode_batch = np.empty(n, dtype=np.int64)
        self.queued_prefill_tokens = np.empty(n, dtype=np.int64)
        self.kv_used_tokens = np.empty(n, dtype=np.float64)
        self.kv_capacity_tokens = np.empty(n, dtype=np.float64)
        self.decode_sum_ctx = np.empty(n, dtype=np.float64)
        self.min_tpot_slack = np.empty(n, dtype=np.float64)
        self.speed = np.empty(n, dtype=np.float64)
        self.alive = np.empty(n, dtype=bool)
        self.is_prefill = np.empty(n, dtype=bool)
        for i, v in enumerate(self.views):
            self._pull(i, v)
            object.__setattr__(v, "_row", i)
            object.__setattr__(v, "_cols", self)

    def _pull(self, i: int, v: WorkerView) -> None:
        self.wid[i] = v.wid
        self.total_pages[i] = v.total_pages
        self.free_pages[i] = v.free_pages
        self.page_size[i] = v.page_size
        self.decode_batch[i] = v.decode_batch
        self.queued_prefill_tokens[i] = v.queued_prefill_tokens
        self.kv_used_tokens[i] = v.kv_used_tokens
        self.kv_capacity_tokens[i] = v.kv_capacity_tokens
        self.decode_sum_ctx[i] = v.decode_sum_ctx
        self.min_tpot_slack[i] = v.min_tpot_slack
        self.speed[i] = v.speed
        self.alive[i] = v.alive
        self.is_prefill[i] = v.role is Role.PREFILL

    def sync(self) -> None:
        if self.dirty:
            views = self.views
            for i in self.dirty:
                self._pull(i, views[i])
            self.dirty.clear()


@dataclasses.dataclass(frozen=True)
class ToggleConfig:
    hbm_watermark: float = 0.90         # stop Path-② above this
    hbm_admission: float = 0.85         # don't admit prefill into M above
    slack_safety: float = 1.2           # chunk must fit slack*1/safety
    decode_iter_guard: float = 0.8      # don't multiplex when decode iter
                                        # time > guard * TPOT_SLO (§IV-C)
    chunk_tokens: int = 2048            # chunked prefill on M workers
    migrate_stall_budget: float = 4.0   # TPOT budgets a migration stall may
                                        # burn (beyond banked slack) before
                                        # decode-in-place wins
    slack_chunking: bool = False        # beyond-paper: size chunk by slack
    min_chunk: int = 256
    queue_violation_window: int = 16    # dispatches between role reviews
    role_transitions: bool = True       # dispatch-count review_roles. The
                                        # ClusterScheduler turns this off
                                        # when its event-driven windowed
                                        # RoleRebalancer owns role lifecycle
                                        # (repro.sched.rebalance)


class MultiplexingToggle:
    def __init__(self, workers: Sequence[WorkerView], predictor: Predictor,
                 config: ToggleConfig = ToggleConfig(),
                 transfer=None, kv_bytes_fn=None):
        self.workers = {w.wid: w for w in workers}
        self.predictor = predictor
        self.cfg = config
        # optional contended-transfer awareness (serving/transfer.py):
        # dispatch_decode penalises destinations whose migration would sit
        # behind deep link queues. kv_bytes_fn(ctx_tokens) -> bytes to move.
        self.transfer = transfer
        self.kv_bytes_fn = kv_bytes_fn
        # ctx tokens -> HBM-token footprint (sliding-window archs hold less
        # than their raw context); engines reserve pages in these units, so
        # the admission gates must too. None = identity (dense).
        self.state_tokens_fn = None
        self._ttft_pressure = 0           # recent Path-① slack violations
        self._dispatches = 0
        # batched dispatch: price a candidate against every worker in one
        # numpy evaluation (Predictor.predict_*_batch) instead of a
        # per-worker Python loop. Decisions are bit-identical either way
        # (tests/test_vectorized.py pins it); build_cluster(vectorized=...)
        # sets this, default off so the toggle alone stays scalar-shaped.
        self.vectorized = False
        self._columns: Optional[ViewColumns] = None   # lazy SoA mirror

    # ------------------------------------------------------------- helpers
    def _alive(self, role: Optional[Role] = None):
        return [w for w in self.workers.values()
                if w.alive and (role is None or w.role == role)]

    def chunk_for(self, w: WorkerView, tpot_slo: float) -> int:
        """Prefill chunk size admissible on multiplexing worker ``w``.

        beyond-paper: size the chunk to the current slack budget (the
        paper uses a fixed 2048 chunk). The cost of a candidate chunk
        includes the §IV contention penalty (0.0 under γ=0): sizing by
        the additive estimate alone would pick chunks the penalty then
        pushes over budget — rejected outright by the admission gates
        instead of shrunk to fit. Analytic predictors invert the budget
        in closed form (``Predictor.chunk_candidates`` + one batched
        verification); others bisect (``_chunk_for_bisect``)."""
        if not self.cfg.slack_chunking:
            return self.cfg.chunk_tokens
        cfg = self.cfg
        lo, hi = cfg.min_chunk, cfg.chunk_tokens
        budget = w.min_tpot_slack / cfg.slack_safety
        ictx = int(w.decode_sum_ctx)
        cand = self.predictor.chunk_candidates(
            [w.wid], lo, hi, np.array([budget]),
            np.array([float(w.decode_batch)]),
            np.array([w.decode_sum_ctx]), np.array([float(ictx)]))
        if cand is None:
            return self._chunk_for_bisect(w, tpot_slo)
        row = np.unique(cand[0])            # sorted; row[0] == lo
        wids = [w.wid] * row.size
        offs = np.full(row.size, ictx, dtype=np.int64)
        t = self.predictor.predict_prefill_batch(wids, row, offs)
        if w.decode_batch > 0:
            t = t + self.predictor.predict_interference_batch(
                wids, w.decode_batch, w.decode_sum_ctx, row, offs)
        feas = t <= budget
        if not feas[0]:     # the minimum chunk already busts the budget
            return lo
        return int(row[feas].max())

    def _chunk_for_bisect(self, w: WorkerView, tpot_slo: float) -> int:
        """Reference bisection for ``chunk_for``: the fallback for
        predictors with no closed form, and the test-time cross-check the
        closed-form path is pinned against (tests/test_vectorized.py)."""
        def chunk_cost(tokens: int) -> float:
            t = self.predictor.predict_prefill(tokens, int(w.decode_sum_ctx),
                                               wid=w.wid)
            if w.decode_batch > 0:
                t += self.predictor.predict_interference(
                    w.decode_batch, w.decode_sum_ctx, tokens,
                    int(w.decode_sum_ctx), wid=w.wid)
            return t

        lo, hi = self.cfg.min_chunk, self.cfg.chunk_tokens
        budget = w.min_tpot_slack / self.cfg.slack_safety
        if chunk_cost(lo) > budget:
            return lo
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if chunk_cost(mid) <= budget:
                lo = mid
            else:
                hi = mid - 1
        return lo

    # ----------------------------------------------------------- helpers
    def _cached_span(self, w: WorkerView, req: Request) -> int:
        """Tokens of ``req``'s prompt already resident in ``w``'s prefix
        cache — prefill there runs (and is priced on) only the uncached
        suffix. Capped at prompt_len - 1: one token always prefills (the
        first-token forward pass)."""
        if req.prefix_key is None or not w.cached_prefixes:
            return 0
        span = w.cached_prefixes.get(req.prefix_key, 0)
        return max(0, min(span, req.prefix_len, req.prompt_len - 1))

    def _tier_relief(self, w: WorkerView, req: Request,
                     need_tokens: float) -> bool:
        """HBM memory checks failed — admit anyway iff the host-DRAM tier
        can absorb a displaced resident decode AND pulling it back is
        predicted to cost less than the slack the batch has banked
        (``Predictor.predict_restore``: wire time + re-prefill residue).
        Without a tier (or an empty batch) this is False and the legacy
        evict-only admission decision stands."""
        if w.host_total_pages <= 0 or w.decode_batch <= 0:
            return False
        need_pages = w.pages_for(self._kv_need_tokens(need_tokens))
        if need_pages > w.host_free_pages:
            return False
        typical_ctx = int(w.decode_sum_ctx / w.decode_batch)
        stall = self.predictor.predict_restore(typical_ctx, wid=w.wid)
        return stall * self.cfg.slack_safety <= max(w.min_tpot_slack, 0.0)

    # ----------------------------------------------------------- Path ②
    def _multiplex_ok(self, w: WorkerView, req: Request) -> bool:
        """§IV-B / §IV-C admission: slack, decode-iter guard, HBM (with
        host-tier relief when offload+restore beats rejection)."""
        cfg = self.cfg
        if w.role != Role.MULTIPLEX or not w.alive:
            return False
        footprint = (req.prompt_len - self._cached_span(w, req)
                     + req.remaining_output)
        # page-granular headroom: block rounding + fragmentation can exhaust
        # allocatable pages well before the token counter says so
        mem_ok = (w.hbm_util <= cfg.hbm_admission
                  and w.kv_used_tokens + footprint
                  <= cfg.hbm_watermark * w.kv_capacity_tokens
                  and w.page_headroom_for(self._kv_need_tokens(footprint),
                                          cfg.hbm_watermark))
        if not mem_ok and not self._tier_relief(w, req, footprint):
            return False
        chunk = min(self.chunk_for(w, req.slo.tpot), req.remaining_prefill
                    or req.prompt_len)
        t_chunk = self.predictor.predict_prefill(chunk, int(w.decode_sum_ctx),
                                                 wid=w.wid)
        if w.decode_batch > 0:
            # §IV contention: the chunk's true cost to the batch includes
            # the super-additive mixed-batch penalty (exactly 0.0 under the
            # legacy γ=0 model, preserving decision parity)
            t_chunk += self.predictor.predict_interference(
                w.decode_batch, w.decode_sum_ctx, chunk,
                int(w.decode_sum_ctx), wid=w.wid)
            # per-iteration slack must absorb the inserted chunk
            if t_chunk * self.cfg.slack_safety > max(w.min_tpot_slack, 0.0):
                return False
            # decode batch already near the TPOT SLO -> no multiplexing.
            # Class-aware: the binding budget is the arriving request's own
            # TPOT SLO or the tightest resident of a *different* class
            # (its iterations absorb the inserted chunk too). Keyed on
            # class identity, so single-class traffic — whatever its
            # per-request SLO spread — stays the paper's per-request
            # check exactly.
            other = min((t for n, t in w.decode_tpot_floor.items()
                         if n != req.slo.name), default=float("inf"))
            t_iter = self.predictor.predict_decode_iter(
                w.decode_batch, w.decode_sum_ctx, wid=w.wid)
            if t_iter > cfg.decode_iter_guard * min(req.slo.tpot, other):
                return False
        return True

    # ----------------------------------------------------------- Path ①
    def _prefill_queue_time(self, w: WorkerView) -> float:
        return self.predictor.predict_prefill(max(w.queued_prefill_tokens, 0),
                                              wid=w.wid)

    def _prefill_ok(self, w: WorkerView, req: Request, now: float) -> bool:
        suffix = req.prompt_len - self._cached_span(w, req)
        t_exec = self.predictor.predict_prefill(suffix, wid=w.wid)
        t_queue = self._prefill_queue_time(w)
        return t_queue + t_exec <= req.ttft_deadline_slack(now)

    # ---------------------------------------------------------- dispatch
    def _predict_ttft_on_prefill(self, w: WorkerView, req: Request) -> float:
        # prefill is priced on the UNCACHED suffix: workers already holding
        # the request's prefix predict a shorter TTFT and win dispatch
        suffix = req.prompt_len - self._cached_span(w, req)
        return self._prefill_queue_time(w) \
            + self.predictor.predict_prefill(suffix, wid=w.wid)

    def _predict_ttft_on_multiplex(self, w: WorkerView, req: Request) -> float:
        """Chunked-prefill completion on an M worker: each chunk is admitted
        once the batch has re-banked ~chunk_time of slack, i.e. the prefill
        advances at chunk/(t_chunk + catchup) tokens/s."""
        chunk = self.cfg.chunk_tokens
        t_chunk = self.predictor.predict_prefill(chunk, int(w.decode_sum_ctx),
                                                 wid=w.wid)
        if w.decode_batch > 0:
            # interference slows the chunk's effective advance rate too
            t_chunk += self.predictor.predict_interference(
                w.decode_batch, w.decode_sum_ctx, chunk,
                int(w.decode_sum_ctx), wid=w.wid)
        base = self.predictor.predict_decode_iter(
            max(w.decode_batch, 1), w.decode_sum_ctx, wid=w.wid)
        margin = max(req.slo.tpot - base, 1e-3)
        catchup = t_chunk / margin * base        # iterations to re-bank
        rate = chunk / (t_chunk + catchup)
        queue = w.queued_prefill_tokens / max(rate, 1.0)
        suffix = req.prompt_len - self._cached_span(w, req)
        return queue + suffix / max(rate, 1.0)

    # ------------------------------------------------- vectorized dispatch
    # The batched twins of chunk_for / _multiplex_ok / the TTFT predictors:
    # per-worker state comes from the dirty-synced ``ViewColumns`` mirror
    # (no Python re-gathering), and the candidate is priced against ALL
    # workers in one Predictor.*_batch evaluation. Every arithmetic
    # expression mirrors its scalar twin operation-for-operation (same
    # association order, same masked terms), so selections are
    # bit-identical — tests/test_vectorized.py pins decision parity.

    def _cols_sync(self) -> ViewColumns:
        c = self._columns
        if c is None:
            c = self._columns = ViewColumns(list(self.workers.values()))
        elif c.dirty:
            c.sync()
        return c

    def _chunk_for_vec(self, c: ViewColumns, gidx: np.ndarray,
                       tpot_slo: float) -> np.ndarray:
        """``chunk_for`` for many workers. Analytic predictors invert the
        slack budget in closed form: ``Predictor.chunk_candidates`` emits
        every chunk size where feasibility can flip (quadratic roots of
        the piecewise roofline+penalty cost, plus structural breakpoints)
        and ONE batched cost evaluation over rows × candidates verifies
        them — where the lockstep bisection issued ~12. Predictors with
        no closed form fall back to ``_chunk_for_vec_bisect``."""
        cfg = self.cfg
        n = gidx.size
        if not cfg.slack_chunking:
            return np.full(n, cfg.chunk_tokens, dtype=np.int64)
        sumctx = c.decode_sum_ctx[gidx]
        ictx = sumctx.astype(np.int64)
        batch = c.decode_batch[gidx]
        lo, hi = cfg.min_chunk, cfg.chunk_tokens
        budget = c.min_tpot_slack[gidx] / cfg.slack_safety
        cand = self.predictor.chunk_candidates(
            c.wid[gidx].tolist(), lo, hi, budget, batch.astype(np.float64),
            sumctx, ictx.astype(np.float64))
        if cand is None:
            return self._chunk_for_vec_bisect(c, gidx, tpot_slo)
        k = cand.shape[1]
        toks = cand.ravel()
        wrep = np.repeat(c.wid[gidx], k).tolist()
        offs = np.repeat(ictx, k)
        t = self.predictor.predict_prefill_batch(wrep, toks, offs)
        has_b = batch > 0
        if bool(has_b.any()):
            t_int = self.predictor.predict_interference_batch(
                wrep, np.repeat(batch, k), np.repeat(sumctx, k), toks, offs)
            t = t + np.where(np.repeat(has_b, k), t_int, 0.0)
        feas = (t <= np.repeat(budget, k)).reshape(n, k)
        best = np.where(feas, cand, lo).max(axis=1)
        # a row whose minimum chunk busts the budget returns min_chunk
        # outright (bisection semantics); lo is always a candidate
        lo_ok = np.where(cand == lo, feas, False).any(axis=1)
        return np.where(lo_ok, best, lo).astype(np.int64)

    def _chunk_for_vec_bisect(self, c: ViewColumns, gidx: np.ndarray,
                              tpot_slo: float) -> np.ndarray:
        """Reference lockstep masked binary search for ``_chunk_for_vec``
        (fallback + test-time cross-check). Rows converge at different
        interval lengths, so finished rows (lo == hi) freeze under an
        active mask while the rest keep bisecting; frozen rows re-price
        at ``lo`` (pure, discarded)."""
        cfg = self.cfg
        n = gidx.size
        if not cfg.slack_chunking:
            return np.full(n, cfg.chunk_tokens, dtype=np.int64)
        wids = c.wid[gidx].tolist()
        sumctx = c.decode_sum_ctx[gidx]
        ictx = sumctx.astype(np.int64)
        batch = c.decode_batch[gidx]
        has_b = batch > 0
        any_b = bool(has_b.any())

        def chunk_cost(tokens: np.ndarray) -> np.ndarray:
            t = self.predictor.predict_prefill_batch(wids, tokens, ictx)
            if any_b:
                t_int = self.predictor.predict_interference_batch(
                    wids, batch, sumctx, tokens, ictx)
                t = t + np.where(has_b, t_int, 0.0)
            return t

        lo = np.full(n, cfg.min_chunk, dtype=np.int64)
        hi = np.full(n, cfg.chunk_tokens, dtype=np.int64)
        budget = c.min_tpot_slack[gidx] / cfg.slack_safety
        # rows whose minimum chunk already busts the budget return min_chunk
        hi = np.where(chunk_cost(lo) > budget, lo, hi)
        active = lo < hi
        while np.any(active):
            mid = (lo + hi + 1) // 2
            fits = chunk_cost(np.where(active, mid, lo)) <= budget
            lo = np.where(active & fits, mid, lo)
            hi = np.where(active & ~fits, mid - 1, hi)
            active = lo < hi
        return lo

    def _other_floor_vec(self, c: ViewColumns, gidx: np.ndarray,  # lint: parity-ref(_multiplex_ok)
                         name: str) -> np.ndarray:
        """Tightest resident TPOT SLO of a *different* class, per row.
        The floor dicts stay Python-side; single-class rows (empty dict or
        only the arriving class — the overwhelmingly common shape) resolve
        without building a generator."""
        inf = float("inf")
        out = np.empty(gidx.size, dtype=np.float64)
        views = c.views
        for j, i in enumerate(gidx.tolist()):
            fl = views[i].decode_tpot_floor
            if not fl or (name in fl and len(fl) == 1):
                out[j] = inf
            elif name not in fl:
                out[j] = min(fl.values())
            else:
                out[j] = min(t for nm, t in fl.items() if nm != name)
        return out

    def _multiplex_ok_vec(self, c: ViewColumns, midx: np.ndarray,
                          req: Request) -> np.ndarray:
        """``_multiplex_ok`` over all M workers at once — returns the
        admissible rows of ``midx``. The memory gates run as column
        arithmetic (the footprint is one scalar when the request carries
        no prefix key — the common case); the predictor-priced chunk and
        iteration gates run as one batched evaluation over the rows that
        survive. Only the rare tier-relief fallback (mem-failing rows that
        actually have a host tier and a resident batch) drops to the
        scalar helper with its restore prediction."""
        cfg = self.cfg
        total = c.total_pages[midx]
        free = c.free_pages[midx]
        used = c.kv_used_tokens[midx]
        cap = c.kv_capacity_tokens[midx]
        ps = c.page_size[midx]
        if req.prefix_key is None:
            # no prefix -> every cached span is 0 -> uniform footprint
            fparr = req.prompt_len + req.remaining_output
            kvi = max(int(self._kv_need_tokens(fparr)), 0)
        else:
            fparr = np.array(
                [req.prompt_len - self._cached_span(c.views[i], req)
                 + req.remaining_output for i in midx.tolist()],
                dtype=np.int64)
            if self.state_tokens_fn is None:
                kvi = np.maximum(fparr, 0)
            else:
                kvi = np.maximum(np.fromiter(
                    (int(self.state_tokens_fn(int(f)))
                     for f in fparr.tolist()), np.int64, midx.size), 0)
        # pages_for, vectorised: ceil-div by the (clamped) page size
        pages = -(-kvi // np.maximum(ps, 1))
        util = np.where(total > 0, 1.0 - free / np.maximum(total, 1),
                        used / np.maximum(cap, 1.0))
        ok = ((util <= cfg.hbm_admission)
              & (used + fparr <= cfg.hbm_watermark * cap)
              & ((total <= 0)
                 | (total - free + pages <= cfg.hbm_watermark * total)))
        if not ok.all():
            for j in np.nonzero(~ok)[0].tolist():
                w = c.views[midx[j]]
                # replicate _tier_relief's own cheap pre-checks so tierless
                # rows never pay the predictor call
                if w.host_total_pages > 0 and w.decode_batch > 0:
                    f = fparr if req.prefix_key is None else int(fparr[j])
                    ok[j] = self._tier_relief(w, req, f)
        gidx = midx[ok] if not ok.all() else midx
        if gidx.size == 0:
            return gidx
        batch = c.decode_batch[gidx]
        has_b = batch > 0
        if not has_b.any():
            return gidx        # no decode batches: the chunk gates all pass
        # only rows with a resident decode batch can fail the chunk gates,
        # so the predictor-priced tail runs on that subset alone — the
        # evaluations are elementwise, so each surviving row's values are
        # bit-identical to a full-width evaluation
        bidx = np.nonzero(has_b)[0]
        sub = gidx[bidx]
        wids = c.wid[sub].tolist()
        batch_b = batch[bidx]
        sumctx = c.decode_sum_ctx[sub]
        ictx = sumctx.astype(np.int64)
        rp = req.remaining_prefill or req.prompt_len
        if cfg.slack_chunking:
            chunks = np.minimum(
                self._chunk_for_vec(c, sub, req.slo.tpot), rp)
        else:
            chunks = min(cfg.chunk_tokens, rp)   # uniform: scalar broadcast
        t_chunk = self.predictor.predict_prefill_batch(wids, chunks, ictx)
        t_int = self.predictor.predict_interference_batch(
            wids, batch_b, sumctx, chunks, ictx)
        t_chunk = t_chunk + t_int
        slack_arr = np.maximum(c.min_tpot_slack[sub], 0.0)
        other = self._other_floor_vec(c, sub, req.slo.name)
        t_iter = self.predictor.predict_decode_iter_batch(
            wids, batch_b, sumctx)
        fail = ((t_chunk * cfg.slack_safety > slack_arr)
                | (t_iter > cfg.decode_iter_guard
                   * np.minimum(req.slo.tpot, other)))
        if not fail.any():
            return gidx
        keep = np.ones(gidx.size, dtype=bool)
        keep[bidx[fail]] = False
        return gidx[keep]

    def _ttft_prefill_vec(self, c: ViewColumns, pidx: np.ndarray,  # lint: parity-ref(_predict_ttft_on_prefill)
                          req: Request) -> np.ndarray:
        # queue + exec priced in ONE stacked batch call (rows 0..n-1 the
        # queue drains, rows n..2n-1 the uncached suffixes), then the
        # halves are summed — elementwise, so bit-identical to two calls
        n = pidx.size
        wids = c.wid[pidx].tolist()
        qtok = np.maximum(c.queued_prefill_tokens[pidx], 0)
        if req.prefix_key is None:
            stok = np.full(n, req.prompt_len, dtype=np.int64)
        else:
            stok = np.array(
                [req.prompt_len - self._cached_span(c.views[i], req)
                 for i in pidx.tolist()], dtype=np.int64)
        t = self.predictor.predict_prefill_batch(
            wids + wids, np.concatenate([qtok, stok]))
        return t[:n] + t[n:]

    def _ttft_multiplex_vec(self, c: ViewColumns, gidx: np.ndarray,  # lint: parity-ref(_predict_ttft_on_multiplex)
                            req: Request) -> np.ndarray:
        cfg = self.cfg
        wids = c.wid[gidx].tolist()
        sumctx = c.decode_sum_ctx[gidx]
        ictx = sumctx.astype(np.int64)
        batch = c.decode_batch[gidx]
        chunk = cfg.chunk_tokens
        t_chunk = self.predictor.predict_prefill_batch(wids, chunk, ictx)
        has_b = batch > 0
        if has_b.any():
            # price interference only where a decode batch exists; the
            # other rows add an exact 0.0 either way
            bidx = np.nonzero(has_b)[0]
            t_int = np.zeros(gidx.size)
            t_int[bidx] = self.predictor.predict_interference_batch(
                c.wid[gidx[bidx]].tolist(), batch[bidx], sumctx[bidx],
                chunk, ictx[bidx])
            t_chunk = t_chunk + t_int
        base = self.predictor.predict_decode_iter_batch(
            wids, np.maximum(batch, 1), sumctx)
        margin = np.maximum(req.slo.tpot - base, 1e-3)
        catchup = t_chunk / margin * base
        rate = chunk / (t_chunk + catchup)
        queued = c.queued_prefill_tokens[gidx]
        if req.prefix_key is None:
            suffix = float(req.prompt_len)     # uniform: scalar broadcast
        else:
            suffix = np.array(
                [req.prompt_len - self._cached_span(c.views[i], req)
                 for i in gidx.tolist()], dtype=np.float64)
        floor = np.maximum(rate, 1.0)
        return queued / floor + suffix / floor

    def _dispatch_prefill_vec(self, req: Request,
                              now: float) -> Optional[int]:
        slack = req.ttft_deadline_slack(now)
        c = self._cols_sync()
        live = c.alive
        pidx = np.nonzero(live & c.is_prefill)[0]
        midx = np.nonzero(live & ~c.is_prefill)[0]
        parts: list[np.ndarray] = []
        wids: list[int] = []
        if pidx.size:
            parts.append(self._ttft_prefill_vec(c, pidx, req))
            wids.extend(c.wid[pidx].tolist())
        if midx.size:
            gidx = self._multiplex_ok_vec(c, midx, req)
            if gidx.size:
                parts.append(self._ttft_multiplex_vec(c, gidx, req))
                wids.extend(c.wid[gidx].tolist())
        if not wids:
            m_any = [c.views[i] for i in midx.tolist()] or self._alive()
            if not m_any:
                return None
            self._ttft_pressure += 1
            return min(m_any, key=lambda w: w.unfinished_tokens / w.speed).wid
        t = parts[0] if len(parts) == 1 else np.concatenate(parts)
        in_slo = np.nonzero(t <= slack)[0]
        if in_slo.size:
            return wids[int(in_slo[int(np.argmin(t[in_slo]))])]
        self._ttft_pressure += 1
        return wids[int(np.argmin(t))]

    def _dispatch_decode_vec(self, req: Request,
                             now: float) -> Optional[int]:
        cfg = self.cfg
        c = self._cols_sync()
        midx = np.nonzero(c.alive & ~c.is_prefill)[0]
        cidx = midx
        if midx.size:
            need = req.context_len + req.remaining_output
            kvi = max(int(self._kv_need_tokens(need)), 0)
            total = c.total_pages[midx]
            free = c.free_pages[midx]
            # pages_for, vectorised: ceil-div by the (clamped) page size
            pages = -(-kvi // np.maximum(c.page_size[midx], 1))
            fits = ((c.kv_used_tokens[midx] + need
                     <= cfg.hbm_watermark * c.kv_capacity_tokens[midx])
                    & ((total <= 0)
                       | (total - free + pages
                          <= cfg.hbm_watermark * total)))
            if not fits.all():
                cidx = midx[fits]
        if cidx.size == 0:
            src = self.workers.get(req.worker) \
                if req.worker is not None else None
            if src is not None and src.alive:
                return None
            cidx = midx            # src dead: least-bad
        if cidx.size == 0:
            return None
        tpot = max(req.slo.tpot, 1e-6)
        cw = c.wid[cidx]
        if self.transfer is None or self.kv_bytes_fn is None \
                or req.worker is None:
            remote = None
        else:
            remote = cw != req.worker
        if remote is None or not remote.any():
            # matches the scalar short-circuit exactly: src == dst (or no
            # transfer awareness) never touches the engine, so its drain
            # arithmetic stays untouched too
            stalls = np.zeros(cidx.size)
        else:
            nbytes = self.kv_bytes_fn(req.context_len)
            stalls = np.zeros(cidx.size)
            stalls[remote] = self.transfer \
                .predict_transfer_times(req.worker, cw[remote], nbytes,
                                        now=now)
        q = stalls / tpot
        # int(q) truncates; q >= 0 so trunc == floor == int()
        bucket = np.where(np.isinf(stalls), q, np.trunc(q))
        load = (c.queued_prefill_tokens[cidx] + c.decode_sum_ctx[cidx]) \
            / c.speed[cidx]
        # lexsort: last key is primary -> (bucket, load, wid) tuple order;
        # wid is unique, so ties resolve identically to the scalar min
        best = int(np.lexsort((cw, load, bucket))[0])
        if req.worker is not None and float(stalls[best]) > \
                req.tpot_slack + cfg.migrate_stall_budget * tpot:
            return None
        return int(cw[best])

    def dispatch_prefill(self, req: Request, now: float) -> Optional[int]:
        """Choose the worker minimising predicted TTFT among SLO-admissible
        paths (Path ① prefill workers / Path ② multiplexing workers); the
        per-path admission checks of §IV-B/C gate candidacy."""
        self._dispatches += 1
        if self.cfg.role_transitions and \
                self._dispatches % self.cfg.queue_violation_window == 0:
            self.review_roles(now)
        if self.vectorized:
            return self._dispatch_prefill_vec(req, now)

        slack = req.ttft_deadline_slack(now)
        cands: list[tuple[float, int, bool]] = []   # (t_pred, wid, in_slo)
        for w in self._alive(Role.PREFILL):
            t = self._predict_ttft_on_prefill(w, req)
            cands.append((t, w.wid, t <= slack))
        for w in self._alive(Role.MULTIPLEX):
            if self._multiplex_ok(w, req):
                t = self._predict_ttft_on_multiplex(w, req)
                cands.append((t, w.wid, t <= slack))
        if not cands:
            m_any = self._alive(Role.MULTIPLEX) or self._alive()
            if not m_any:
                return None
            self._ttft_pressure += 1
            return min(m_any, key=lambda w: w.unfinished_tokens / w.speed).wid
        ok = [c for c in cands if c[2]]
        if not ok:
            self._ttft_pressure += 1
        pick = min(ok or cands, key=lambda c: c[0])
        return pick[1]

    def _kv_need_tokens(self, ctx_tokens: float) -> float:
        """Raw context tokens -> HBM-token footprint, matching the units
        the engine's PageAccountant reserves in."""
        if self.state_tokens_fn is None:
            return ctx_tokens
        return self.state_tokens_fn(int(ctx_tokens))

    def _transfer_stall(self, src_wid: Optional[int], dst: WorkerView,
                        req: Request, now: float) -> float:
        """Predicted seconds the migrated KV sits on the wire behind the
        source's egress queue and ``dst``'s ingress queue."""
        if self.transfer is None or self.kv_bytes_fn is None \
                or src_wid is None or src_wid == dst.wid:
            return 0.0
        nbytes = self.kv_bytes_fn(req.context_len)
        return self.transfer.predict_transfer_time(src_wid, dst.wid, nbytes,
                                                   now=now)

    def dispatch_decode(self, req: Request, now: float) -> Optional[int]:
        """After Path-① prefill completes: pick a multiplexing worker for the
        decode phase (KV migrates). InFaaS least-unfinished-tokens, tempered
        by predicted transfer time: a destination whose links are backed up
        stalls the first decode tokens however idle its batch is, so stall
        (quantised to TPOT budgets — the granularity at which it burns
        slack) ranks ahead of queue depth."""
        if self.vectorized:
            return self._dispatch_decode_vec(req, now)
        need = req.context_len + req.remaining_output
        cands = [w for w in self._alive(Role.MULTIPLEX)
                 if w.kv_used_tokens + need
                 <= self.cfg.hbm_watermark * w.kv_capacity_tokens
                 and w.page_headroom_for(self._kv_need_tokens(need),
                                         self.cfg.hbm_watermark)]
        if not cands:
            # every M worker is page/watermark-full: migrating would pay the
            # wire transfer only for admit_migrated to reject it (restart +
            # full re-prefill). Decode in place while the source lives — it
            # still holds the request's pages at dispatch time.
            src = self.workers.get(req.worker) \
                if req.worker is not None else None
            if src is not None and src.alive:
                return None
            cands = self._alive(Role.MULTIPLEX)   # src dead: least-bad
        if not cands:
            return None
        tpot = max(req.slo.tpot, 1e-6)
        best_key, best_w, best_stall = None, None, 0.0
        for w in cands:
            stall = self._transfer_stall(req.worker, w, req, now)
            bucket = stall / tpot if math.isinf(stall) else int(stall / tpot)
            # load normalised by the destination's speed: tokens on a slow
            # worker take proportionally longer to clear the runway
            key = (bucket, w.unfinished_tokens / w.speed, w.wid)
            if best_key is None or key < best_key:
                best_key, best_w, best_stall = key, w, stall
        # §IV asymmetry: when even the best link queue would burn more TPOT
        # budget than the request has banked (plus a bounded forward
        # credit), keep decoding in place — the source worker multiplexes
        # the decode rather than drowning it on the wire
        if req.worker is not None and best_stall > \
                req.tpot_slack + self.cfg.migrate_stall_budget * tpot:
            return None
        return best_w.wid

    # ------------------------------------------------------ role management
    def review_roles(self, now: float) -> None:
        """§IV-C: all M workers above watermark -> P becomes M; persistent
        prefill TTFT pressure -> one M (least decode load) becomes P."""
        cfg = self.cfg
        m = self._alive(Role.MULTIPLEX)
        p = self._alive(Role.PREFILL)
        if m and all(w.hbm_util > cfg.hbm_watermark for w in m) and p:
            conv = min(p, key=lambda w: w.queued_prefill_tokens)
            conv.role = Role.MULTIPLEX
            self._ttft_pressure = 0
            return
        if self._ttft_pressure >= cfg.queue_violation_window and len(m) > 1:
            conv = min(m, key=lambda w: w.decode_batch)
            if conv.hbm_util < 0.5:
                conv.role = Role.PREFILL
        self._ttft_pressure = 0

    # --------------------------------------------------------------- faults
    def on_worker_failure(self, wid: int) -> None:
        if wid in self.workers:
            self.workers[wid].alive = False

    def on_worker_recovered(self, wid: int, role: Role) -> None:
        w = self.workers.get(wid)
        if w is not None:
            w.alive = True
            w.role = role
