"""DEPRECATED import shim — the §IV-C predictors moved to ``repro.perf``.

Every name is re-exported unchanged so existing import paths keep
working. New code should import from ``repro.perf``:

    from repro.perf import AnalyticalPredictor, OnlinePredictor, ...

The predictors live with the cost model they wrap now: per-worker pricing
(``ClusterPredictor``), the per-(worker, phase, size-bucket) online
calibration hierarchy (``OnlinePredictor``) and the measured-MFU
calibrated roofline are one subsystem in ``src/repro/perf/``.

SIGNATURE CHANGE: every ``predict_*`` method now takes an optional
``wid=None`` keyword (per-worker pricing on heterogeneous clusters) and
the toggle/policies pass it unconditionally; likewise the scheduler
passes ``wid=`` to ``observe_iteration`` (and ``OnlinePredictor``
forwards it to ``observe_prefill``/``observe_decode``). A ``Predictor``
subclass overriding any ``predict_*`` or ``observe_*`` method with the
old signature must add the ``wid=None`` parameter (ignore it to keep
worker-agnostic behaviour).
"""
from repro.perf.calibration import OnlinePredictor
from repro.perf.predictor import (AnalyticalPredictor, BiasedPredictor,
                                  ClusterPredictor, Predictor,
                                  ProfiledPredictor, profile_worker)

__all__ = [
    "AnalyticalPredictor", "BiasedPredictor", "ClusterPredictor",
    "OnlinePredictor", "Predictor", "ProfiledPredictor", "profile_worker",
]
