"""Execution-time predictor (paper §IV-C).

The toggle "leverages offline profiling tools to estimate both the execution
time of a prefill request and the queuing time when scheduling to the local
worker". Two implementations share the interface:

* ``AnalyticalPredictor`` — wraps the roofline CostModel (what the simulator
  itself uses, optionally with a safety margin; predictor error can be
  injected for robustness experiments).
* ``ProfiledPredictor`` — piecewise-linear interpolation over an offline
  profile table {(tokens, ctx) -> seconds}, the way a real deployment
  profiles its worker; built by ``profile_worker`` from any executor.

``OnlinePredictor`` wraps either of them and closes the §IV-C loop: the
scheduler feeds every observed iteration duration back in, and per-phase
EWMA correction factors pull a biased/stale offline profile toward what
the executor actually delivers (wall-clock on the real backend, injected
noise in robustness sims) while preserving the base safety margin.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Callable, Optional, Sequence

from repro.serving.costmodel import CostModel


class Predictor:
    def predict_prefill(self, tokens: int, ctx_offset: int = 0) -> float:
        raise NotImplementedError

    def predict_decode_iter(self, n_decode: int, sum_ctx: float) -> float:
        raise NotImplementedError

    def predict_migration(self, ctx_tokens: int) -> float:
        raise NotImplementedError


@dataclasses.dataclass
class AnalyticalPredictor(Predictor):
    cost: CostModel
    safety: float = 1.1          # conservative over-estimate (paper: the
                                 # toggle "conservatively sends requests")
    def predict_prefill(self, tokens: int, ctx_offset: int = 0) -> float:
        return self.cost.prefill_time(tokens, ctx_offset) * self.safety

    def predict_decode_iter(self, n_decode: int, sum_ctx: float) -> float:
        return self.cost.decode_iter_time(n_decode, sum_ctx) * self.safety

    def predict_migration(self, ctx_tokens: int) -> float:
        return self.cost.migration_time(ctx_tokens) * self.safety


class BiasedPredictor(AnalyticalPredictor):
    """Systematically ``bias``×-miscalibrated analytical predictor — a
    stale or wrong-hardware offline profile. Robustness benchmarks and the
    OnlinePredictor convergence tests inject known error through this."""

    def __init__(self, cost: CostModel, bias: float, safety: float = 1.1):
        super().__init__(cost, safety=safety)
        self.bias = bias

    def predict_prefill(self, tokens: int, ctx_offset: int = 0) -> float:
        return super().predict_prefill(tokens, ctx_offset) * self.bias

    def predict_decode_iter(self, n_decode: int, sum_ctx: float) -> float:
        return super().predict_decode_iter(n_decode, sum_ctx) * self.bias


class ProfiledPredictor(Predictor):
    """Interpolates a profiled (tokens -> seconds) table; ctx contributions
    enter linearly with a profiled per-ctx-token coefficient."""

    def __init__(self, prefill_points: Sequence[tuple[int, float]],
                 decode_points: Sequence[tuple[int, float, float]],
                 ctx_coeff: float, migration_coeff: float,
                 safety: float = 1.1):
        self.prefill_points = sorted(prefill_points)
        self.decode_points = sorted(decode_points)
        self.ctx_coeff = ctx_coeff
        self.migration_coeff = migration_coeff
        self.safety = safety

    @staticmethod
    def _interp(points, x):
        xs = [p[0] for p in points]
        i = bisect.bisect_left(xs, x)
        if i == 0:
            lo, hi = points[0], points[min(1, len(points) - 1)]
        elif i >= len(points):
            lo, hi = points[-2] if len(points) > 1 else points[-1], points[-1]
        else:
            lo, hi = points[i - 1], points[i]
        if hi[0] == lo[0]:
            return lo[1]
        t = (x - lo[0]) / (hi[0] - lo[0])
        return lo[1] + t * (hi[1] - lo[1])

    def predict_prefill(self, tokens: int, ctx_offset: int = 0) -> float:
        base = self._interp(self.prefill_points, tokens)
        return (base + self.ctx_coeff * ctx_offset * tokens) * self.safety

    def predict_decode_iter(self, n_decode: int, sum_ctx: float) -> float:
        base = self._interp([(b, t) for b, t, _ in self.decode_points], n_decode)
        return (base + self.ctx_coeff * sum_ctx) * self.safety

    def predict_migration(self, ctx_tokens: int) -> float:
        return self.migration_coeff * ctx_tokens * self.safety


class OnlinePredictor(Predictor):
    """Online feedback wrapper: per-phase multiplicative EWMA correction.

    Let ``raw`` be the base predictor's estimate (which already includes
    its conservative ``safety`` margin). After each observed iteration the
    matching phase's scale moves toward ``observed * margin / raw`` — so an
    unbiased base converges to scale 1.0 (the safety margin is *kept*, not
    regressed away), and a k×-biased base converges to scale 1/k, restoring
    calibrated-but-conservative predictions. Mixed decode+prefill
    iterations split the observed time proportionally to the current
    corrected per-phase estimates.

    Heterogeneity: a single global scale per phase assumes the base's bias
    is size-independent, but real profiles miss differently at batch 1
    than at batch 128 (kernel occupancy, attention-vs-MLP balance). Each
    observation therefore also feeds a per-(phase, size-bucket) EWMA —
    buckets are powers of two over prefill tokens / decode batch size —
    and predictions use the bucket's scale once it has ``bucket_floor``
    observations, falling back to the global per-phase scale below the
    floor (cold buckets borrow strength instead of guessing from one
    sample). ``bucketed=False`` restores pure global correction.
    """

    def __init__(self, base: Predictor, alpha: float = 0.2,
                 clip: tuple[float, float] = (0.125, 8.0),
                 bucketed: bool = True, bucket_floor: int = 8):
        self.base = base
        self.alpha = alpha
        self.clip = clip
        self.bucketed = bucketed
        self.bucket_floor = bucket_floor
        # preserve the base's deliberate conservatism as the convergence
        # target; a margin-free base converges to exact calibration
        self.margin = float(getattr(base, "safety", 1.0))
        self.prefill_scale = 1.0
        self.decode_scale = 1.0
        self.prefill_observations = 0
        self.decode_observations = 0
        self.bucket_scales: dict[tuple[str, int], float] = {}
        self.bucket_observations: dict[tuple[str, int], int] = {}

    # ------------------------------------------------------------- buckets
    @staticmethod
    def _bucket(size: float) -> int:
        """Power-of-two size bucket: 1, 2, 3… for sizes 1, 2-3, 4-7, …"""
        return max(int(size), 1).bit_length()

    def _bucket_scale(self, phase: str, size: float,
                      global_scale: float) -> float:
        if not self.bucketed:
            return global_scale
        key = (phase, self._bucket(size))
        if self.bucket_observations.get(key, 0) < self.bucket_floor:
            return global_scale
        return self.bucket_scales[key]

    def _observe_bucket(self, phase: str, size: float, ratio: float,
                        global_scale: float) -> None:
        if not self.bucketed:
            return
        key = (phase, self._bucket(size))
        # seed a cold bucket from the converged global scale, not 1.0:
        # crossing bucket_floor must refine the prediction, never snap it
        # back toward the uncorrected base
        self.bucket_scales[key] = self._ewma(
            self.bucket_scales.get(key, global_scale), ratio)
        self.bucket_observations[key] = \
            self.bucket_observations.get(key, 0) + 1

    # ----------------------------------------------------------- predictions
    def predict_prefill(self, tokens: int, ctx_offset: int = 0) -> float:
        return self.base.predict_prefill(tokens, ctx_offset) \
            * self._bucket_scale("prefill", tokens, self.prefill_scale)

    def predict_decode_iter(self, n_decode: int, sum_ctx: float) -> float:
        return self.base.predict_decode_iter(n_decode, sum_ctx) \
            * self._bucket_scale("decode", n_decode, self.decode_scale)

    def predict_migration(self, ctx_tokens: int) -> float:
        return self.base.predict_migration(ctx_tokens)

    # ------------------------------------------------------------- feedback
    def _ewma(self, scale: float, ratio: float) -> float:
        lo, hi = self.clip
        ratio = min(max(ratio, lo), hi)
        return (1.0 - self.alpha) * scale + self.alpha * ratio

    def observe_prefill(self, tokens: int, ctx_offset: int,
                        observed: float) -> None:
        if tokens <= 0:
            return
        raw = self.base.predict_prefill(tokens, ctx_offset)
        if raw > 0.0 and observed > 0.0:
            ratio = observed * self.margin / raw
            self._observe_bucket("prefill", tokens, ratio,
                                 self.prefill_scale)
            self.prefill_scale = self._ewma(self.prefill_scale, ratio)
            self.prefill_observations += 1

    def observe_decode(self, n_decode: int, sum_ctx: float,
                       observed: float) -> None:
        if n_decode <= 0:
            return
        raw = self.base.predict_decode_iter(n_decode, sum_ctx)
        if raw > 0.0 and observed > 0.0:
            ratio = observed * self.margin / raw
            self._observe_bucket("decode", n_decode, ratio,
                                 self.decode_scale)
            self.decode_scale = self._ewma(self.decode_scale, ratio)
            self.decode_observations += 1

    def observe_iteration(self, n_decode: int, sum_ctx: float,
                          prefill_tokens: int, ctx_offset: float,
                          observed: float) -> None:
        """ClusterScheduler hook: one finished iteration's composition and
        its observed duration (simulated or wall-clock)."""
        has_p = prefill_tokens > 0
        has_d = n_decode > 0
        if has_p and has_d:
            cp = self.predict_prefill(prefill_tokens, int(ctx_offset))
            cd = self.predict_decode_iter(n_decode, sum_ctx)
            if cp + cd <= 0.0:
                return
            share = cp / (cp + cd)
            self.observe_prefill(prefill_tokens, int(ctx_offset),
                                 observed * share)
            self.observe_decode(n_decode, sum_ctx, observed * (1.0 - share))
        elif has_p:
            self.observe_prefill(prefill_tokens, int(ctx_offset), observed)
        elif has_d:
            self.observe_decode(n_decode, sum_ctx, observed)


def profile_worker(step_fn: Callable[[int, float, int], float],
                   token_grid: Sequence[int] = (128, 512, 2048, 8192),
                   batch_grid: Sequence[int] = (1, 8, 32, 128),
                   ctx_probe: int = 8192) -> ProfiledPredictor:
    """Build a ProfiledPredictor by measuring ``step_fn(n_decode, sum_ctx,
    prefill_tokens) -> seconds`` — works against the real executor or the
    simulator alike (offline profiling per §IV-C)."""
    prefill_points = [(t, step_fn(0, 0.0, t)) for t in token_grid]
    decode_points = [(b, step_fn(b, float(b * 512), 0), 512.0)
                     for b in batch_grid]
    t0 = step_fn(1, 0.0, 0)
    t1 = step_fn(1, float(ctx_probe), 0)
    ctx_coeff = max(0.0, (t1 - t0) / ctx_probe)
    return ProfiledPredictor(prefill_points, decode_points, ctx_coeff,
                             migration_coeff=1e-9)
