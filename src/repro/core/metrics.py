"""SLO attainment + latency metrics (paper Eq. 1-3, Figs. 8-11)."""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence

import numpy as np

from repro.core.request import Phase, Request


def percentile(xs: Sequence[float], p: float) -> float:
    if not len(xs):
        return float("nan")
    return float(np.percentile(np.asarray(xs, dtype=np.float64), p))


@dataclasses.dataclass
class ClassMetrics:
    """Attainment + latency tails for one SLO class (multi-tenant view)."""
    name: str
    weight: float
    n_total: int
    n_finished: int
    slo_attainment: float
    ttft_attainment: float
    tpot_attainment: float
    ttft_avg: float
    ttft_p90: float
    tpot_avg: float
    tpot_p90: float

    def row(self) -> dict:
        return {k: getattr(self, k) for k in (
            "weight", "n_total", "n_finished", "slo_attainment",
            "ttft_attainment", "tpot_attainment", "ttft_avg", "ttft_p90",
            "tpot_avg", "tpot_p90")}


@dataclasses.dataclass
class ServeMetrics:
    n_total: int
    n_finished: int
    slo_attainment: float          # Eq. 3
    ttft_attainment: float
    tpot_attainment: float
    ttft_avg: float
    ttft_p90: float
    tpot_avg: float
    tpot_p90: float
    queue_avg: float
    queue_p90: float
    ttfts: list
    tpots: list
    queues: list
    blocked_time_avg: float        # decode blocked by prefill (interference)
    migrations: int
    restarts: int
    preemptions: int               # KV watermark/pool evictions
    migration_wait_avg: float      # seconds a migrated request sat on links
    # multi-tenant view: one ClassMetrics per SLO class seen in the run and
    # the class-weight-normalised attainment Σ w_c·A_c / Σ w_c (equals
    # slo_attainment when every request shares one class)
    per_class: dict = dataclasses.field(default_factory=dict)
    weighted_attainment: float = float("nan")
    # tiered KV + prefix reuse (all zero when neither feature is on)
    kv_offloads: int = 0           # decode KV spills to the host-DRAM tier
    kv_restores: int = 0           # spills pulled back into HBM
    pages_offloaded: int = 0
    pages_restored: int = 0
    pages_reprefilled: int = 0     # pages lost to evict + full re-prefill
    prefix_lookups: int = 0
    prefix_hits: int = 0
    prefix_hit_rate: float = 0.0   # hits / lookups (0 when no lookups)

    def row(self) -> dict:
        return {k: getattr(self, k) for k in (
            "n_total", "n_finished", "slo_attainment", "ttft_attainment",
            "tpot_attainment", "ttft_avg", "ttft_p90", "tpot_avg",
            "tpot_p90", "queue_avg", "queue_p90", "blocked_time_avg",
            "migrations", "restarts", "preemptions", "migration_wait_avg",
            "weighted_attainment", "kv_offloads", "kv_restores",
            "pages_offloaded", "pages_restored", "pages_reprefilled",
            "prefix_lookups", "prefix_hits", "prefix_hit_rate")}

    def per_class_rows(self) -> dict:
        """{class_name: flat metric dict} — the JSON-facing projection."""
        return {name: cm.row() for name, cm in sorted(self.per_class.items())}


def _class_metrics(name: str, weight: float,
                   reqs: Sequence[Request]) -> ClassMetrics:
    fin = [r for r in reqs if r.phase == Phase.FINISHED]
    ttfts = [r.ttft() for r in fin]
    tpots = [r.tpot() for r in fin]
    n = max(len(reqs), 1)
    return ClassMetrics(
        name=name, weight=weight,
        n_total=len(reqs), n_finished=len(fin),
        slo_attainment=sum(1 for r in fin if r.slo_ok()) / n,
        ttft_attainment=sum(1 for r in fin if r.ttft_ok()) / n,
        tpot_attainment=sum(1 for r in fin if r.tpot_ok()) / n,
        ttft_avg=float(np.mean(ttfts)) if ttfts else float("nan"),
        ttft_p90=percentile(ttfts, 90),
        tpot_avg=float(np.mean(tpots)) if tpots else float("nan"),
        tpot_p90=percentile(tpots, 90),
    )


def compute_metrics(requests: Iterable[Request],
                    queue_times: Optional[dict] = None,
                    blocked_times: Optional[dict] = None,
                    counters: Optional[dict] = None) -> ServeMetrics:
    reqs = list(requests)
    fin = [r for r in reqs if r.phase == Phase.FINISHED]
    by_class: dict[str, list[Request]] = {}
    weights: dict[str, float] = {}
    for r in reqs:
        by_class.setdefault(r.slo.name, []).append(r)
        weights[r.slo.name] = getattr(r.slo, "weight", 1.0)
    per_class = {name: _class_metrics(name, weights[name], rs)
                 for name, rs in by_class.items()}
    w_sum = sum(cm.weight for cm in per_class.values())
    weighted = sum(cm.weight * cm.slo_attainment
                   for cm in per_class.values()) / w_sum \
        if w_sum > 0 else float("nan")
    ttfts = [r.ttft() for r in fin]
    tpots = [r.tpot() for r in fin]
    ok_ttft = [r for r in fin if r.ttft_ok()]
    ok_tpot = [r for r in fin if r.tpot_ok()]
    ok_both = [r for r in fin if r.slo_ok()]
    n = max(len(reqs), 1)
    queues = list((queue_times or {}).values())
    blocked = list((blocked_times or {}).values())
    waits = [r.migration_wait for r in reqs if r.migrations > 0]
    return ServeMetrics(
        n_total=len(reqs),
        n_finished=len(fin),
        slo_attainment=len(ok_both) / n,
        ttft_attainment=len(ok_ttft) / n,
        tpot_attainment=len(ok_tpot) / n,
        ttft_avg=float(np.mean(ttfts)) if ttfts else float("nan"),
        ttft_p90=percentile(ttfts, 90),
        tpot_avg=float(np.mean(tpots)) if tpots else float("nan"),
        tpot_p90=percentile(tpots, 90),
        queue_avg=float(np.mean(queues)) if queues else float("nan"),
        queue_p90=percentile(queues, 90),
        ttfts=ttfts,
        tpots=tpots,
        queues=queues,
        blocked_time_avg=float(np.mean(blocked)) if blocked else 0.0,
        migrations=sum(r.migrations for r in reqs),
        restarts=sum(r.restarts for r in reqs),
        preemptions=sum(r.preemptions for r in reqs),
        migration_wait_avg=float(np.mean(waits)) if waits else 0.0,
        per_class=per_class,
        weighted_attainment=weighted,
        **_tier_counters(counters or {}),
    )


def _tier_counters(counters: dict) -> dict:
    """Aggregate worker-level tiered-KV/prefix counters (scheduler-supplied;
    the prefix hit *rate* is derived here so callers pass raw counts only)."""
    keys = ("kv_offloads", "kv_restores", "pages_offloaded",
            "pages_restored", "pages_reprefilled", "prefix_lookups",
            "prefix_hits")
    out = {k: int(counters.get(k, 0)) for k in keys}
    lookups = out["prefix_lookups"]
    out["prefix_hit_rate"] = out["prefix_hits"] / lookups if lookups else 0.0
    return out


def cdf(xs: Sequence[float], n_points: int = 50):
    """(value, fraction<=value) pairs for Fig.11-style CDFs."""
    xs = sorted(x for x in xs if x is not None)
    if not xs:
        return []
    out = []
    for i in range(n_points + 1):
        q = i / n_points
        idx = min(int(q * (len(xs) - 1)), len(xs) - 1)
        out.append((xs[idx], q))
    return out


def derive_slos(cost_model, prompt_len: int, ttft_scale: float = 5.0,
                tpot_scale: float = 5.0):
    """Paper §V-A: SLO = scale x the light-workload latency of the phase."""
    from repro.core.request import SLOSpec
    t_prefill = cost_model.prefill_time(prompt_len)
    t_decode = cost_model.decode_iter_time(1, float(prompt_len))
    return SLOSpec(ttft=ttft_scale * t_prefill, tpot=tpot_scale * t_decode)
