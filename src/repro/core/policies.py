"""Scheduling policies: Tropical + the paper's three baselines.

A policy owns (a) worker role assignment, (b) global dispatch, and (c) the
per-iteration batch-composition rule its workers follow. The unified
``repro.sched.ClusterScheduler`` consults the policy at every dispatch and
iteration boundary; execution backends (sim cost model or real JAX) are
orthogonal — see ``repro.sched.backend.ExecutionBackend``.

  vllm       — non-disaggregated, prefill-prioritised full-prompt iterations
               (decode stalls behind prefill: the interference regime).
  sarathi    — non-disaggregated + chunked prefill (hybrid batches,
               chunk=2048 as profiled in the paper §V-A).
  distserve  — disaggregated: static P/D worker split, full-prompt prefill
               on P, pure decode batches on D, KV migration P->D.
  tropical   — SLO-aware multiplexing via the MultiplexingToggle.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.predictor import Predictor
from repro.core.request import Request
from repro.core.toggle import MultiplexingToggle, Role, ToggleConfig, WorkerView


@dataclasses.dataclass(frozen=True)
class BatchRule:
    """What a worker may put in one iteration."""
    run_decode: bool
    prefill_budget: int            # max new prefill tokens this iteration
    prefill_exclusive: bool        # if True and prefill work exists, decode
                                   # is stalled (vLLM-style interference)


class Policy:
    name = "base"
    queue_discipline = "fcfs"     # what the real systems do; see engine
    toggle = None                 # policies owning a MultiplexingToggle set
                                  # this; the ClusterScheduler keys role
                                  # rebalancing and worker registration on it
    vectorized = False            # build_cluster(vectorized=True) flips this
                                  # (and the toggle's) to the batched paths

    def __init__(self, workers: Sequence[WorkerView], predictor: Predictor):
        self.workers = {w.wid: w for w in workers}
        self.predictor = predictor
        self.transfer = None          # set via attach_transfer

    def attach_transfer(self, transfer, kv_bytes_fn,
                        state_tokens_fn=None) -> None:
        """Give the policy visibility into the contended KV transfer engine
        (queue depths on worker links) and the cost model's HBM-footprint
        conversion. Baselines ignore it — DistServe's blind migration is
        exactly the cost the paper charges it."""
        self.transfer = transfer

    # --- dispatch ----------------------------------------------------------
    def dispatch_prefill(self, req: Request, now: float) -> Optional[int]:
        raise NotImplementedError

    def dispatch_decode(self, req: Request, now: float) -> Optional[int]:
        """Where decode continues after prefill. None = same worker."""
        return None

    # --- iteration composition ---------------------------------------------
    def batch_rule(self, w: WorkerView, now: float,
                   head: Optional[Request]) -> BatchRule:
        raise NotImplementedError

    def on_worker_failure(self, wid: int) -> None:
        self.workers[wid].alive = False

    def _alive(self, role: Optional[Role] = None):
        return [w for w in self.workers.values()
                if w.alive and (role is None or w.role == role)]

    def _least_loaded(self, ws):
        # InFaaS least-unfinished-tokens, normalised by the worker's
        # relative hardware speed: on a heterogeneous cluster the same
        # token backlog clears later on a straggler. Homogeneous speeds
        # are exactly 1.0, so orderings (and decisions) are unchanged.
        if not ws:
            return None
        if self.vectorized:
            # same keys, same first-wins tie-break: np.argmin returns the
            # first minimum exactly as min() keeps the first smallest
            loads = np.fromiter((w.unfinished_tokens / w.speed for w in ws),
                                dtype=np.float64, count=len(ws))
            return ws[int(np.argmin(loads))].wid
        return min(ws, key=lambda w: w.unfinished_tokens / w.speed).wid


# ---------------------------------------------------------------------------


class VLLMPolicy(Policy):
    """Colocated; InFaaS least-unfinished-token dispatch; prefill-priority."""
    name = "vllm"
    prefill_token_budget = 16384

    def dispatch_prefill(self, req, now):
        return self._least_loaded(self._alive())

    def batch_rule(self, w, now, head):
        return BatchRule(run_decode=True,
                         prefill_budget=self.prefill_token_budget,
                         prefill_exclusive=True)


class SarathiPolicy(Policy):
    """Colocated + chunked prefill: hybrid decode+chunk iterations."""
    name = "sarathi"

    def __init__(self, workers, predictor, chunk: int = 2048):
        super().__init__(workers, predictor)
        self.chunk = chunk

    def dispatch_prefill(self, req, now):
        return self._least_loaded(self._alive())

    def batch_rule(self, w, now, head):
        return BatchRule(run_decode=True, prefill_budget=self.chunk,
                         prefill_exclusive=False)


class DistServePolicy(Policy):
    """Static P/D split; decode always migrates to a D worker."""
    name = "distserve"
    prefill_token_budget = 16384

    def __init__(self, workers, predictor, n_prefill: Optional[int] = None):
        super().__init__(workers, predictor)
        ws = list(self.workers.values())
        n_p = n_prefill if n_prefill is not None else len(ws) // 2
        for i, w in enumerate(ws):
            w.role = Role.PREFILL if i < n_p else Role.MULTIPLEX

    def dispatch_prefill(self, req, now):
        wid = self._least_loaded(self._alive(Role.PREFILL))
        if wid is None:                     # note: wid 0 is a valid worker
            wid = self._least_loaded(self._alive())
        return wid

    def dispatch_decode(self, req, now):
        return self._least_loaded(self._alive(Role.MULTIPLEX))

    def batch_rule(self, w, now, head):
        if w.role == Role.PREFILL:
            return BatchRule(run_decode=False,
                             prefill_budget=self.prefill_token_budget,
                             prefill_exclusive=True)
        return BatchRule(run_decode=True, prefill_budget=0,
                         prefill_exclusive=False)


class TropicalPolicy(Policy):
    """SLO-aware multiplexing (the paper's contribution). The 'slack'
    queue discipline adds multi-tenant class-awareness: heterogeneous
    prefill queues serve tightest-relative-TTFT-slack first, while a
    single-class queue keeps the paper's exact FCFS order (decision
    parity with the pre-SLO-class scheduler)."""
    name = "tropical"
    queue_discipline = "slack"
    prefill_token_budget = 16384

    def __init__(self, workers, predictor, n_prefill: Optional[int] = None,
                 toggle_config: ToggleConfig = ToggleConfig()):
        super().__init__(workers, predictor)
        ws = list(self.workers.values())
        n_p = n_prefill if n_prefill is not None else len(ws) // 2
        for i, w in enumerate(ws):
            w.role = Role.PREFILL if i < n_p else Role.MULTIPLEX
        self.toggle = MultiplexingToggle(ws, predictor, toggle_config)
        # per-class typical TTFT SLO (EWMA over dispatched requests): live
        # multi-tenant traffic makes long loose-class prefills run chunked
        # so a tight-class arrival mid-iteration waits one chunk, not one
        # long-context prompt. Keyed by class NAME — per-request SLO
        # variation inside one class never triggers it, so single-class
        # runs keep the paper's full-prompt budget bit-exactly. An EWMA,
        # not a lifetime min: one short-prompt outlier with a derived
        # per-request SLO must not permanently ratchet the class's
        # tightness. Entries expire after class_ttl dispatches without
        # traffic: a departed tenant stops taxing the survivors.
        self._class_ttft: dict[str, float] = {}
        self._class_last_seen: dict[str, int] = {}
        self._dispatch_no = 0
        self.class_ttl = 1024
        self.class_ttft_alpha = 0.1

    def attach_transfer(self, transfer, kv_bytes_fn,
                        state_tokens_fn=None) -> None:
        super().attach_transfer(transfer, kv_bytes_fn, state_tokens_fn)
        self.toggle.transfer = transfer
        self.toggle.kv_bytes_fn = kv_bytes_fn
        self.toggle.state_tokens_fn = state_tokens_fn

    def dispatch_prefill(self, req, now):
        self._dispatch_no += 1
        name = req.slo.name
        prev = self._class_ttft.get(name)
        a = self.class_ttft_alpha
        self._class_ttft[name] = req.slo.ttft if prev is None \
            else (1.0 - a) * prev + a * req.slo.ttft
        self._class_last_seen[name] = self._dispatch_no
        for stale in [n for n, last in self._class_last_seen.items()
                      if self._dispatch_no - last > self.class_ttl]:
            del self._class_last_seen[stale]
            del self._class_ttft[stale]
        return self.toggle.dispatch_prefill(req, now)

    def _tightest_other_class_ttft(self, name: str) -> float:
        """Tightest typical TTFT among live classes OTHER than ``name``."""
        return min((t for n, t in self._class_ttft.items()
                    if n != name), default=float("inf"))

    def dispatch_decode(self, req, now):
        # decode stays in place on a multiplexing worker (Path ②); only
        # Path-① prefills migrate
        w = self.workers[req.worker]
        if w.role == Role.MULTIPLEX and w.alive:
            return None
        return self.toggle.dispatch_decode(req, now)

    def batch_rule(self, w, now, head):
        if w.role == Role.PREFILL:
            budget = self.prefill_token_budget
            # multi-tenant head-of-line guard: a looser-CLASS head must not
            # hold the worker for a whole long-context prompt when a
            # tighter class is queued behind it — or could arrive
            # mid-iteration (recently dispatched classes proxy for live
            # tenants). Chunking bounds the tight tenant's wait to one
            # chunk. Compared at class level (typical vs typical), so
            # intra-class SLO spread never flips it; single-class traffic
            # (no OTHER class live) keeps the paper's full-prompt budget.
            if head is not None:
                own = self._class_ttft.get(head.slo.name, head.slo.ttft)
                if own > self._tightest_other_class_ttft(head.slo.name) \
                        * (1.0 + 1e-9):
                    budget = self.toggle.cfg.chunk_tokens
            return BatchRule(run_decode=True, prefill_budget=budget,
                             prefill_exclusive=True)
        # multiplexing worker: piggyback a chunk only when slack allows
        if head is None:
            return BatchRule(run_decode=True, prefill_budget=0,
                             prefill_exclusive=False)
        if w.decode_batch == 0:
            return BatchRule(run_decode=True,
                             prefill_budget=self.prefill_token_budget,
                             prefill_exclusive=False)
        chunk = self.toggle.chunk_for(w, head.slo.tpot)
        take = min(chunk, head.remaining_prefill)
        # the chunk's true cost to the batch includes the §IV mixed-batch
        # contention penalty (exactly 0.0 under the legacy γ=0 model) —
        # the per-iteration insertion gate must price what dispatch
        # admission prices, or slack-blowing chunks slip in here
        t_chunk = self.predictor.predict_prefill(
            take, int(w.decode_sum_ctx), wid=w.wid) \
            + self.predictor.predict_interference(
                w.decode_batch, w.decode_sum_ctx, take,
                int(w.decode_sum_ctx), wid=w.wid)
        budget = max(w.min_tpot_slack, 0.0) / self.toggle.cfg.slack_safety
        if t_chunk <= budget:
            return BatchRule(run_decode=True, prefill_budget=chunk,
                             prefill_exclusive=False)
        return BatchRule(run_decode=True, prefill_budget=0,
                         prefill_exclusive=False)


class TropicalPPPolicy(TropicalPolicy):
    """Beyond-paper extensions on top of the faithful Tropical:
    * EDF + hopeless-last prefill queue order (SLO-aware queueing);
    * slack-sized prefill chunks instead of the fixed 2048 (§IV-B note:
      the paper uses a fixed chunk; sizing it to the currently banked
      slack extracts more multiplexing throughput at equal TPOT safety).
    Reported separately in EXPERIMENTS.md §Repro vs §Beyond."""
    name = "tropical++"
    queue_discipline = "edf"

    def __init__(self, workers, predictor, n_prefill: Optional[int] = None,
                 toggle_config: Optional[ToggleConfig] = None):
        super().__init__(
            workers, predictor, n_prefill,
            toggle_config or ToggleConfig(slack_chunking=True))


POLICIES = {
    "vllm": VLLMPolicy,
    "sarathi": SarathiPolicy,
    "distserve": DistServePolicy,
    "tropical": TropicalPolicy,
    "tropical++": TropicalPPPolicy,
}


def make_policy(name: str, workers, predictor, **kw) -> Policy:
    return POLICIES[name](workers, predictor, **kw)
