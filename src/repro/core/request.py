"""Request lifecycle + SLO bookkeeping (paper §II-B).

Timestamps are simulation-clock (or wall-clock for the real executor)
seconds. TTFT/TPOT follow the paper's Eq. (1)-(3):

    TTFT  = first_token_time - arrival
    TPOT  = (sum of decode-phase time) / (#generated tokens beyond first)
    A     = |R_TTFT ∩ R_TPOT| / |R|
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class Phase(enum.Enum):
    QUEUED_PREFILL = "queued_prefill"
    PREFILLING = "prefilling"
    MIGRATING = "migrating"
    QUEUED_DECODE = "queued_decode"
    DECODING = "decoding"
    FINISHED = "finished"
    FAILED = "failed"


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    ttft: float     # seconds
    tpot: float     # seconds / output token


@dataclasses.dataclass
class Request:
    rid: int
    arrival_time: float
    prompt_len: int
    output_len: int            # tokens to generate (incl. first token)
    slo: SLOSpec

    # --- runtime state -----------------------------------------------------
    phase: Phase = Phase.QUEUED_PREFILL
    worker: Optional[int] = None          # current worker id
    prefilled_tokens: int = 0             # chunked-prefill progress
    generated_tokens: int = 0
    prefill_start: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    decode_time: float = 0.0              # accumulated decode-phase seconds
    tpot_slack: float = 0.0               # paper §IV-B accumulated slack
    migrations: int = 0
    restarts: int = 0                     # fault-tolerance: re-prefills

    # ------------------------------------------------------------------ SLO
    @property
    def context_len(self) -> int:
        return self.prompt_len + self.generated_tokens

    @property
    def remaining_prefill(self) -> int:
        return max(0, self.prompt_len - self.prefilled_tokens)

    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    def tpot(self) -> Optional[float]:
        if self.finish_time is None or self.generated_tokens <= 1:
            return 0.0 if self.finish_time is not None else None
        return self.decode_time / (self.generated_tokens - 1)

    def ttft_ok(self) -> bool:
        t = self.ttft()
        return t is not None and t <= self.slo.ttft

    def tpot_ok(self) -> bool:
        t = self.tpot()
        return t is not None and t <= self.slo.tpot

    def slo_ok(self) -> bool:
        return self.ttft_ok() and self.tpot_ok()

    # ------------------------------------------------------- event recording
    def record_decode_iteration(self, duration: float) -> None:
        """One decode iteration this request took part in (paper §IV-B:
        slack accumulates by TPOT_SLO - iteration_time)."""
        self.decode_time += duration
        self.generated_tokens += 1
        self.tpot_slack += self.slo.tpot - duration

    def record_first_token(self, now: float) -> None:
        self.first_token_time = now
        self.generated_tokens = 1
        # one iteration of initial credit: TPOT is measured per *generated*
        # token, so the budget of the first decode iteration is available
        # the moment the request enters decode (paper Fig. 7 banks slack
        # from the first tokens before admitting a prefill).
        self.tpot_slack = self.slo.tpot

    def effective_slack(self, base_iter: float, horizon: int = 4) -> float:
        """Delay this request can absorb NOW without its final TPOT
        average exceeding the SLO (paper §II-B: users read at an average
        rate, so early/remaining tokens bank budget). banked slack plus a
        bounded forward credit over the next ``horizon`` iterations at the
        current base decode rate."""
        remaining = max(0, self.output_len - self.generated_tokens)
        credit = max(0.0, (self.slo.tpot - base_iter)) * min(remaining,
                                                             horizon)
        return self.tpot_slack + credit

    def ttft_deadline_slack(self, now: float) -> float:
        """Remaining TTFT budget at ``now`` (before any predicted costs)."""
        return self.slo.ttft - (now - self.arrival_time)
