"""Request lifecycle + SLO bookkeeping (paper §II-B).

Timestamps are simulation-clock (or wall-clock for the real executor)
seconds. TTFT/TPOT follow the paper's Eq. (1)-(3):

    TTFT  = first_token_time - arrival
    TPOT  = (sum of decode-phase time) / (#generated tokens beyond first)
    A     = |R_TTFT ∩ R_TPOT| / |R|
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class Phase(enum.Enum):
    QUEUED_PREFILL = "queued_prefill"
    PREFILLING = "prefilling"
    MIGRATING = "migrating"
    QUEUED_DECODE = "queued_decode"
    DECODING = "decoding"
    OFFLOADED = "offloaded"     # KV parked in the host-DRAM tier
    FINISHED = "finished"
    FAILED = "failed"


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """A named SLO tier in a multi-tenant workload (paper §II-B + the
    per-application TTFT/TPOT requirements of DistServe §5).

    ``name`` identifies the tenant class in per-class metrics and the
    rebalancer's windowed attainment; ``weight`` is its share in the
    weighted cluster attainment (Σ w_c·A_c / Σ w_c). The single-tenant
    legacy entry points construct the anonymous ``default`` class via the
    ``SLOSpec`` alias, which keeps every pre-multi-tenant call site and
    pickle/CSV schema working unchanged."""
    ttft: float             # seconds
    tpot: float             # seconds / output token
    name: str = "default"
    weight: float = 1.0


# Legacy alias: an SLOSpec *is* the anonymous default-class SLOClass.
SLOSpec = SLOClass


# eq=False: requests are identities, not values — every membership /
# equality check in the stack compares the same live object, and identity
# comparison keeps hot ``in``-list checks O(1) per element instead of a
# 25-field structural compare (it also restores hashability).
# ``slots=True``: the engine reads/writes these fields millions of times
# per simulated minute; slot access skips the per-instance __dict__ and
# shrinks each request by ~100 bytes at 100k-request trace scale.
@dataclasses.dataclass(eq=False, slots=True)
class Request:
    rid: int
    arrival_time: float
    prompt_len: int
    output_len: int            # tokens to generate (incl. first token)
    slo: SLOSpec

    # --- runtime state -----------------------------------------------------
    phase: Phase = Phase.QUEUED_PREFILL
    worker: Optional[int] = None          # current worker id
    prefilled_tokens: int = 0             # chunked-prefill progress
    generated_tokens: int = 0
    prefill_start: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    decode_time: float = 0.0              # accumulated decode-phase seconds
    tpot_slack: float = 0.0               # paper §IV-B accumulated slack
    migrations: int = 0
    migration_wait: float = 0.0           # seconds spent MIGRATING on links
    restarts: int = 0                     # fault-tolerance: re-prefills
    preemptions: int = 0                  # KV evictions (watermark/pool)
    prior_tokens: int = 0                 # tokens streamed before KV loss
    stall_start: Optional[float] = None   # stream stalled (KV lost) at
    # --- tiered KV + prefix reuse ------------------------------------------
    offloads: int = 0                     # KV spills to the host-DRAM tier
    restores: int = 0                     # KV pulls back from the host tier
    prefix_key: Optional[int] = None      # shared-prompt identity (workload)
    prefix_len: int = 0                   # leading tokens covered by the key
    cached_prefix: int = 0                # tokens borrowed from a worker's
                                          # prefix cache at current placement
    prefix_hits: int = 0                  # lifetime prefix-cache hits

    # ------------------------------------------------------------------ SLO
    @property
    def context_len(self) -> int:
        return self.prompt_len + self.generated_tokens

    @property
    def remaining_prefill(self) -> int:
        return max(0, self.prompt_len - self.prefilled_tokens)

    @property
    def streamed_tokens(self) -> int:
        """Tokens delivered to the user across KV losses (restarts fold
        generated tokens into ``prior_tokens``; the stream itself never
        rewinds — the user keeps what was sent)."""
        return self.prior_tokens + self.generated_tokens

    @property
    def remaining_output(self) -> int:
        return max(0, self.output_len - self.streamed_tokens)

    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    def tpot(self) -> Optional[float]:
        if self.finish_time is None or self.streamed_tokens <= 1:
            return 0.0 if self.finish_time is not None else None
        return self.decode_time / (self.streamed_tokens - 1)

    def ttft_ok(self) -> bool:
        t = self.ttft()
        return t is not None and t <= self.slo.ttft

    def tpot_ok(self) -> bool:
        t = self.tpot()
        return t is not None and t <= self.slo.tpot

    def slo_ok(self) -> bool:
        return self.ttft_ok() and self.tpot_ok()

    # ------------------------------------------------------- event recording
    def record_decode_iteration(self, duration: float) -> None:
        """One decode iteration this request took part in (paper §IV-B:
        slack accumulates by TPOT_SLO - iteration_time)."""
        self.decode_time += duration
        self.generated_tokens += 1
        self.tpot_slack += self.slo.tpot - duration

    def record_first_token(self, now: float) -> None:
        self.generated_tokens = 1    # the prefill's forward pass emits it
        if self.first_token_time is None:
            self.first_token_time = now
            # one iteration of initial credit: TPOT is measured per
            # *generated* token, so the budget of the first decode iteration
            # is available the moment the request enters decode (paper
            # Fig. 7 banks slack from the first tokens before admitting a
            # prefill).
            self.tpot_slack = self.slo.tpot
        else:
            # resumed stream after KV loss: TTFT was already achieved; the
            # stall since eviction is inter-token latency the user saw
            if self.stall_start is not None:
                gap = now - self.stall_start
                self.decode_time += gap
                self.tpot_slack = self.slo.tpot - gap
                self.stall_start = None

    def effective_slack(self, base_iter: float, horizon: int = 4) -> float:
        """Delay this request can absorb NOW without its final TPOT
        average exceeding the SLO (paper §II-B: users read at an average
        rate, so early/remaining tokens bank budget). banked slack plus a
        bounded forward credit over the next ``horizon`` iterations at the
        current base decode rate."""
        credit = max(0.0, (self.slo.tpot - base_iter)) \
            * min(self.remaining_output, horizon)
        return self.tpot_slack + credit

    def ttft_deadline_slack(self, now: float) -> float:
        """Remaining TTFT budget at ``now`` (before any predicted costs)."""
        return self.slo.ttft - (now - self.arrival_time)

    def rel_ttft_slack(self, now: float) -> float:
        """TTFT budget remaining as a fraction of the class's whole budget.
        The class-aware dispatch order serves tightest-relative-slack
        first: absolute seconds are not comparable across SLO classes (2 s
        of slack is plenty for an interactive class and nothing for a
        batch class), the consumed *fraction* is."""
        return self.ttft_deadline_slack(now) / max(self.slo.ttft, 1e-9)

    def reset_for_reprefill(self, now: Optional[float] = None) -> None:
        """KV/state was lost (worker failure, page eviction, failed
        migration placement): the full context re-prefills wherever
        dispatch next places the request, then the stream resumes — only
        ``remaining_output`` tokens are still owed (what was streamed
        stays streamed). Callers bump the counter that names the cause
        (``restarts``/``preemptions``)."""
        self.prompt_len = self.context_len   # generated tokens fold in
        self.prior_tokens += self.generated_tokens
        self.generated_tokens = 0
        self.prefilled_tokens = 0
        self.prefill_start = None
        self.phase = Phase.QUEUED_PREFILL
        self.worker = None
        self.cached_prefix = 0   # any borrowed prefix ref was released by
                                 # the worker before this reset
        if now is not None and self.prior_tokens > 0 \
                and self.stall_start is None:
            self.stall_start = now           # mid-stream: stall clock runs
