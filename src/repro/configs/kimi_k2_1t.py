"""kimi-k2-1t-a32b [moe] — arXiv:2501.kimi2 (paper-table); unverified.

61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840; MoE 384 experts
top-8 + 1 shared expert. head_dim 128 (q_dim 8192 decoupled from d_model).
"""
import dataclasses
import jax.numpy as jnp
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=2048, vocab_size=163840,
    num_experts=384, top_k=8, num_shared_experts=1,
)

SMOKE = dataclasses.replace(
    CONFIG, name="kimi-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=512,
    num_experts=8, top_k=2, num_shared_experts=1, dtype=jnp.float32,
)
