"""qwen2-1.5b [dense] — arXiv:2407.10671; hf.

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936; QKV bias."""
import dataclasses
import jax.numpy as jnp
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936, qkv_bias=True, rope_theta=1_000_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen2-smoke", num_layers=4, d_model=96, num_heads=6,
    num_kv_heads=2, head_dim=16, d_ff=192, vocab_size=512, dtype=jnp.float32,
)
