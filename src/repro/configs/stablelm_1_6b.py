"""stablelm-1.6b [dense] — hf:stabilityai/stablelm-2-1_6b; unverified.

24L d_model=2048 32H (kv=32) d_ff=5632 vocab=100352; LayerNorm.
Simplification vs HF (noted in DESIGN.md): full rotary instead of partial
(25%) rotary dims.
"""
import dataclasses
import jax.numpy as jnp
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=5632, vocab_size=100352, use_layernorm=True, norm_eps=1e-5,
    rope_theta=10_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="stablelm-smoke", num_layers=4, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512, dtype=jnp.float32,
)
