"""whisper-medium [audio enc-dec] — arXiv:2212.04356; unverified.

24L enc + 24L dec, d_model=1024 16H d_ff=4096 vocab=51865; plain GELU MLP,
LayerNorm, sinusoidal positions, conv frontend STUBBED (input_specs
provides frame embeddings)."""
import dataclasses
import jax.numpy as jnp
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    num_layers=24, encoder_layers=24, d_model=1024, num_heads=16,
    num_kv_heads=16, head_dim=64, d_ff=4096, vocab_size=51865,
    use_layernorm=True, mlp_gated=False, mlp_activation="gelu",
    use_rope=False, qkv_bias=True, norm_eps=1e-5,
)

SMOKE = dataclasses.replace(
    CONFIG, name="whisper-smoke", num_layers=3, encoder_layers=3, d_model=64,
    num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
    dtype=jnp.float32,
)
