"""paligemma-3b [vlm] — arXiv:2407.07726; hf.

Gemma-2b backbone: 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216,
head_dim 256, GeGLU. SigLIP frontend is a STUB: input_specs() provides
precomputed patch embeddings (dim 1152) projected into the backbone."""
import dataclasses
import jax.numpy as jnp
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=257216, mlp_activation="gelu",
    vision_feature_dim=1152, num_patches=1024,
)

SMOKE = dataclasses.replace(
    CONFIG, name="paligemma-smoke", num_layers=3, d_model=64, num_heads=4,
    num_kv_heads=1, head_dim=16, d_ff=128, vocab_size=512,
    vision_feature_dim=48, num_patches=8, dtype=jnp.float32,
)
