"""arctic-480b [moe] — hf:Snowflake/snowflake-arctic-base.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000; MoE 128 experts
top-2 PLUS a parallel dense residual MLP (dense-MoE hybrid)."""
import dataclasses
import jax.numpy as jnp
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8, head_dim=128,
    d_ff=4864, vocab_size=32000,
    num_experts=128, top_k=2, moe_dense_residual_ff=4864,
)

SMOKE = dataclasses.replace(
    CONFIG, name="arctic-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=96, vocab_size=512,
    num_experts=8, top_k=2, moe_dense_residual_ff=96, dtype=jnp.float32,
)
