"""rwkv6-7b "Finch" [ssm] — arXiv:2404.05892; hf.

32L d_model=4096 (attn-free, 64 heads x 64 dims) d_ff=14336 vocab=65536."""
import dataclasses
import jax.numpy as jnp
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="rwkv",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64, head_dim=64,
    d_ff=14336, vocab_size=65536,
)

SMOKE = dataclasses.replace(
    CONFIG, name="rwkv6-smoke", num_layers=3, d_model=128, num_heads=2,
    num_kv_heads=2, head_dim=64, d_ff=256, vocab_size=512, dtype=jnp.float32,
)
