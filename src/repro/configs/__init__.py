"""Architecture registry: ``get_config(name)`` / ``get_smoke(name)``.

Each module defines CONFIG (the exact assigned full-size config) and SMOKE
(a reduced same-family config for CPU tests).
"""
from __future__ import annotations

import importlib

ARCHS = [
    "gemma2_2b",
    "qwen2_1_5b",
    "deepseek_7b",
    "stablelm_1_6b",
    "arctic_480b",
    "kimi_k2_1t",
    "rwkv6_7b",
    "paligemma_3b",
    "zamba2_2_7b",
    "whisper_medium",
    "internlm20b",
]

_ALIASES = {
    "gemma2-2b": "gemma2_2b",
    "qwen2-1.5b": "qwen2_1_5b",
    "deepseek-7b": "deepseek_7b",
    "stablelm-1.6b": "stablelm_1_6b",
    "arctic-480b": "arctic_480b",
    "kimi-k2-1t-a32b": "kimi_k2_1t",
    "kimi-k2-1t": "kimi_k2_1t",
    "rwkv6-7b": "rwkv6_7b",
    "paligemma-3b": "paligemma_3b",
    "zamba2-2.7b": "zamba2_2_7b",
    "whisper-medium": "whisper_medium",
    "internlm-20b": "internlm20b",
}


def _module(name: str):
    key = _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    return importlib.import_module(f"repro.configs.{key}")


def get_config(name: str):
    return _module(name).CONFIG


def get_smoke(name: str):
    return _module(name).SMOKE


def list_archs():
    return list(ARCHS)
