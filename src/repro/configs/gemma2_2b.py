"""gemma2-2b [dense] — arXiv:2408.00118; hf.

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000; local+global
alternating sliding window (4096), attention softcap 50, final softcap 30,
GeGLU, head_dim 256, sandwich norms.
"""
import dataclasses
import jax.numpy as jnp
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    num_layers=26, d_model=2304, num_heads=8, num_kv_heads=4, head_dim=256,
    d_ff=9216, vocab_size=256000,
    attn_softcap=50.0, final_softcap=30.0,
    sliding_window=4096, local_global_alternating=True,
    mlp_activation="gelu", use_post_norms=True, rope_theta=10_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="gemma2-smoke", num_layers=4, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512, sliding_window=8,
    dtype=jnp.float32,
)
