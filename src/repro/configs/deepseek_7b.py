"""deepseek-7b [dense] — arXiv:2401.02954; hf. llama-arch.

30L d_model=4096 32H (kv=32, MHA) d_ff=11008 vocab=102400."""
import dataclasses
import jax.numpy as jnp
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense",
    num_layers=30, d_model=4096, num_heads=32, num_kv_heads=32, head_dim=128,
    d_ff=11008, vocab_size=102400, rope_theta=10_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="deepseek-smoke", num_layers=4, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512, dtype=jnp.float32,
)
