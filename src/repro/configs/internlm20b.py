"""internlm2-20b — the paper's evaluation model (arXiv InternLM2 tech report).

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544; llama-arch; 200K
max context. Used by the serving cost model + paper-figure benchmarks."""
import dataclasses
import jax.numpy as jnp
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="internlm-20b", family="dense",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=92544, rope_theta=1_000_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="internlm-smoke", num_layers=4, d_model=64, num_heads=8,
    num_kv_heads=2, head_dim=8, d_ff=128, vocab_size=512, dtype=jnp.float32,
)
