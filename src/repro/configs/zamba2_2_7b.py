"""zamba2-2.7b [hybrid] — arXiv:2411.15242; hf.

54 Mamba2 blocks d_model=2560 (d_inner 5120, ssm_state 64) + shared
attention block (32H, head_dim 80, d_ff 10240) every 6 blocks."""
import dataclasses
import jax.numpy as jnp
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32000, ssm_state=64, attn_every=6,
)

SMOKE = dataclasses.replace(
    CONFIG, name="zamba2-smoke", num_layers=6, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512, ssm_state=16,
    attn_every=3, dtype=jnp.float32,
)
