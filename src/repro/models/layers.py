"""Core transformer building blocks, pure JAX.

Conventions
-----------
* Params are nested dicts of jnp arrays; every module provides
  ``init_<module>(rng, cfg) -> params`` and a pure ``apply`` function.
* Weights are stored in ``cfg.dtype`` (default bf16); numerically sensitive
  reductions (norms, softmax, logsumexp) run in f32.
* Head axes carry logical sharding names via ``logical_specs`` companions
  (see models/sharding.py).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | rwkv | hybrid | vlm | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    sliding_window: Optional[int] = None      # window for local layers
    local_global_alternating: bool = False    # gemma2: even layers local
    rope_theta: float = 10_000.0
    # mlp
    mlp_activation: str = "silu"              # silu->SwiGLU, gelu->GeGLU
    mlp_gated: bool = True                    # False: plain 2-matrix MLP
    use_layernorm: bool = False               # LN instead of RMSNorm
    use_post_norms: bool = False              # gemma2 sandwich norms
    use_rope: bool = True                     # False: absolute positions
    # moe
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    moe_dense_residual_ff: int = 0            # arctic: parallel dense FFN
    moe_capacity_factor: float = 2.0
    expert_pad_to: int = 0                    # physical expert-table pad so
                                              # E divides the EP group (the
                                              # padded experts get -inf
                                              # router logits, never routed)
    # ssm / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    attn_every: int = 0                       # zamba2: shared attn cadence
    # encdec
    encoder_layers: int = 0
    # vlm
    vision_feature_dim: int = 0
    num_patches: int = 0
    # misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    dtype: Any = jnp.bfloat16
    # checkpointing / perf knobs (hillclimb surface)
    remat_policy: str = "dots"                # none | dots | full
    scan_layers: bool = True
    attn_q_chunk: int = 512                   # flash-style Q blocking
    attn_unroll_chunks: bool = False          # dry-run: unroll so XLA's
                                              # static cost model sees all
                                              # chunks (while bodies are
                                              # counted once otherwise)
    kv_cache_quant: bool = False              # fp8(e4m3) KV cache storage
                                              # (decode memory-term lever)
    window_sized_cache: bool = False          # gemma2: local layers keep a
                                              # window-sized ring cache
                                              # instead of full seq

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim


# ---------------------------------------------------------------------------
# initialisers
# ---------------------------------------------------------------------------


def _dense_init(rng, shape, dtype, in_axis_size=None):
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    scale = 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


def _embed_init(rng, shape, dtype):
    return (jax.random.normal(rng, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def init_norm(cfg: ModelConfig):
    if cfg.use_layernorm:
        return {
            "scale": jnp.ones((cfg.d_model,), cfg.dtype),
            "bias": jnp.zeros((cfg.d_model,), cfg.dtype),
        }
    return {"scale": jnp.zeros((cfg.d_model,), cfg.dtype)}


def apply_norm(params, x, cfg: ModelConfig):
    if cfg.use_layernorm:
        return layer_norm(x, params["scale"], params["bias"], cfg.norm_eps)
    return rms_norm(x, params["scale"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def init_attention(rng, cfg: ModelConfig):
    k = jax.random.split(rng, 4)
    p = {
        "wq": _dense_init(k[0], (cfg.d_model, cfg.num_heads, cfg.head_dim), cfg.dtype),
        "wk": _dense_init(k[1], (cfg.d_model, cfg.num_kv_heads, cfg.head_dim), cfg.dtype),
        "wv": _dense_init(k[2], (cfg.d_model, cfg.num_kv_heads, cfg.head_dim), cfg.dtype),
        "wo": _dense_init(
            k[3], (cfg.num_heads, cfg.head_dim, cfg.d_model), cfg.dtype,
            in_axis_size=cfg.q_dim,
        ),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads, cfg.head_dim), cfg.dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads, cfg.head_dim), cfg.dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads, cfg.head_dim), cfg.dtype)
    return p


def _soft_cap(logits: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


@dataclasses.dataclass(frozen=True)
class MaskSpec:
    """Lazy attention-mask description. Masks are generated per Q-chunk so
    an (Sq, Sk) boolean is never materialised at long context.

    kind:
      * "causal"   — query i attends to kpos <= q_offset + i
      * "full"     — bidirectional (encoder)
      * "lengths"  — decode: kpos <= lengths[b] (lengths is (B,))
    ``window``: additionally restrict to kpos > qpos - window.
    """
    kind: str = "causal"
    window: Optional[int] = None
    q_offset: int = 0

    def block(self, sq: int, sk: int, q_start, lengths=None) -> jax.Array:
        kpos = jnp.arange(sk)[None, :]
        if self.kind == "full":
            ok = jnp.ones((sq, sk), bool)[None, None]
        elif self.kind == "causal":
            qpos = jnp.arange(sq)[:, None] + q_start + self.q_offset
            ok = kpos <= qpos
            if self.window is not None:
                ok = ok & (kpos > qpos - self.window)
            ok = ok[None, None]
        elif self.kind == "lengths":
            ok = kpos <= lengths[:, None]
            if self.window is not None:
                ok = ok & (kpos > lengths[:, None] - self.window)
            ok = jnp.broadcast_to(ok[:, None, None, :], (lengths.shape[0], 1, sq, sk))
        elif self.kind == "ring":
            # window-ring decode cache: slots [0, min(lengths+1, sk)) hold
            # the last tokens; once wrapped, every slot is valid.
            ok = (kpos <= lengths[:, None]) | (lengths[:, None] + 1 >= sk)
            ok = jnp.broadcast_to(ok[:, None, None, :],
                                  (lengths.shape[0], 1, sq, sk))
        elif self.kind == "chunk":
            # chunked prefill: query i of this chunk sits at absolute
            # position lengths[b] + q_start + i (lengths = per-request
            # already-prefilled token count).
            qpos = lengths[:, None, None] + q_start + jnp.arange(sq)[None, :, None]
            ok = kpos[None] <= qpos
            if self.window is not None:
                ok = ok & (kpos[None] > qpos - self.window)
            ok = ok[:, None]
        else:
            raise ValueError(self.kind)
        return ok


def attention_scores(
    q: jax.Array,              # (B, Sq, Hq, D) — rope already applied
    k: jax.Array,              # (B, Sk, Hkv, D)
    v: jax.Array,              # (B, Sk, Hkv, D)
    mask: MaskSpec,
    *,
    attn_softcap: Optional[float] = None,
    lengths: Optional[jax.Array] = None,
    q_chunk: int = 512,
    unroll: bool = False,
) -> jax.Array:
    """XLA reference attention with flash-style Q chunking at long context;
    the Pallas kernels replace this on the serving hot path.
    Returns (B, Sq, Hq, D)."""
    b, sq, hq, d = q.shape
    if sq <= q_chunk:
        return _attn_block(q, k, v, mask, 0, attn_softcap, lengths)

    n = sq // q_chunk
    assert n * q_chunk == sq, f"Sq={sq} not a multiple of {q_chunk}"
    qc = q.reshape(b, n, q_chunk, hq, d).transpose(1, 0, 2, 3, 4)

    def body(_, args):
        i, qi = args
        out = _attn_block(qi, k, v, mask, i * q_chunk, attn_softcap, lengths)
        return None, out

    _, outs = lax.scan(body, None, (jnp.arange(n), qc),
                       unroll=n if unroll else 1)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, hq, d)


def _attn_block(q, k, v, mask: MaskSpec, q_start, attn_softcap, lengths):
    """bf16 x bf16 -> f32 dots via preferred_element_type: no materialised
    f32 copy of the KV cache (MXU-native mixed precision); softmax in f32;
    probabilities cast back to the KV dtype for the AV matmul (flash-attn
    convention)."""
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, sq, hkv, group, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    logits = logits * (1.0 / math.sqrt(d))
    logits = _soft_cap(logits, attn_softcap)
    m = mask.block(sq, sk, q_start, lengths)       # (B|1, 1, sq, sk)
    logits = jnp.where(m[:, :, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, hq, d).astype(q.dtype)


def apply_attention(
    params,
    x: jax.Array,              # (B, S, d_model)
    cfg: ModelConfig,
    *,
    positions: jax.Array,      # (B, S) absolute positions
    mask: MaskSpec,
    kv_cache: Optional[tuple[jax.Array, jax.Array]] = None,
    cache_positions: Optional[jax.Array] = None,  # (B,) write offsets
    lengths: Optional[jax.Array] = None,          # (B,) for "lengths" masks
    rope: bool = True,
    cross_kv: Optional[tuple[jax.Array, jax.Array]] = None,
):
    """Returns (out, new_cache).

    * no cache: self-attention over x (prefill / train).
    * kv_cache (B, Smax, Hkv, D): write new K/V at ``cache_positions``,
      attend over the cache (decode).
    * cross_kv: attend over fixed K/V (encoder-decoder cross attention);
      no Q/K rope, no cache update.
    """
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if cfg.qkv_bias:
        q = q + params["bq"]

    if cross_kv is not None:
        k_all, v_all = cross_kv
        out = attention_scores(q, k_all, v_all, mask,
                               attn_softcap=cfg.attn_softcap, lengths=lengths,
                               q_chunk=cfg.attn_q_chunk,
                               unroll=cfg.attn_unroll_chunks)
        out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
        return out, None

    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        k = k + params["bk"]
        v = v + params["bv"]
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if kv_cache is not None:
        k_cache, v_cache = kv_cache
        k_cache = _cache_write(k_cache, k, cache_positions)
        v_cache = _cache_write(v_cache, v, cache_positions)
        k_all, v_all = k_cache, v_cache
        new_cache = (k_cache, v_cache)
        if k_all.dtype != q.dtype:      # quantised (fp8) cache storage
            k_all = k_all.astype(q.dtype)
            v_all = v_all.astype(q.dtype)
    else:
        k_all, v_all = k, v
        new_cache = (k, v)

    out = attention_scores(q, k_all, v_all, mask,
                           attn_softcap=cfg.attn_softcap, lengths=lengths,
                           q_chunk=cfg.attn_q_chunk,
                           unroll=cfg.attn_unroll_chunks)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return out, new_cache


def compute_kv(params, x, cfg: ModelConfig, positions=None, rope=False):
    """K/V projection only (whisper cross-attn precompute)."""
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        k = k + params["bk"]
        v = v + params["bv"]
    if rope:
        k = apply_rope(k, positions, cfg.rope_theta)
    return k, v


def _cache_write(cache: jax.Array, new: jax.Array, write_pos: jax.Array) -> jax.Array:
    """cache: (B, Smax, H, D); new: (B, Snew, H, D); write_pos: (B,)."""

    def upd(c, n, p):
        return lax.dynamic_update_slice_in_dim(c, n.astype(c.dtype), p, axis=0)

    return jax.vmap(upd)(cache, new, write_pos)


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------


def init_mlp(rng, cfg: ModelConfig, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    k = jax.random.split(rng, 3)
    p = {
        "w_up": _dense_init(k[1], (cfg.d_model, d_ff), cfg.dtype),
        "w_down": _dense_init(k[2], (d_ff, cfg.d_model), cfg.dtype),
    }
    if cfg.mlp_gated:
        p["w_gate"] = _dense_init(k[0], (cfg.d_model, d_ff), cfg.dtype)
    return p


def _activate(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(kind)


def apply_mlp(params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    if "w_gate" in params:
        g = _activate(jnp.einsum("bsd,df->bsf", x, params["w_gate"]),
                      cfg.mlp_activation)
        u = g * u
    else:
        u = _activate(u, cfg.mlp_activation)
    return jnp.einsum("bsf,fd->bsd", u, params["w_down"])


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------


def init_embedding(rng, cfg: ModelConfig):
    p = {"table": _embed_init(rng, (cfg.vocab_size, cfg.d_model), cfg.dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = _embed_init(
            jax.random.fold_in(rng, 1), (cfg.vocab_size, cfg.d_model), cfg.dtype
        )
    return p


def embed(params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = params["table"][tokens]
    return x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)


def unembed(params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    table = params.get("unembed", params["table"])
    logits = jnp.einsum("bsd,vd->bsv", x, table).astype(jnp.float32)
    return _soft_cap(logits, cfg.final_softcap)


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Sharding-friendly CE: never materialises probabilities.

    logits (B, S, V) f32, labels (B, S) int32 -> scalar mean loss.
    Reductions over V lower to small per-token all-reduces when V is
    sharded (GSPMD handles the sharded-axis reduction)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    true_logit = jnp.sum(logits * onehot, axis=-1)
    return jnp.mean(lse - true_logit)
