"""Mamba-2 (SSD, arXiv:2405.21060) block — used by zamba2.

Selective state space with scalar-per-head decay:

    S_t = exp(dt_t A_h) S_{t-1} + dt_t x_t ⊗ B_t        (d_head × d_state)
    y_t = C_t · S_t + D_h x_t

Chunked-parallel form: because the decay is a *scalar* per head/step, every
exponent in the chunked decomposition is <= 0, so it is f32-safe with no
clipping (contrast rwkv6.wkv_chunked).

State per layer: conv state (B, conv_k-1, d_conv_in) + SSD state
(B, H, d_head, d_state) — O(1) decode.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.layers import ModelConfig

D_HEAD = 64


def dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // D_HEAD
    d_conv_in = d_inner + 2 * cfg.ssm_state   # x + B + C (n_groups = 1)
    return d_inner, n_heads, d_conv_in


def init_block(rng, cfg: ModelConfig):
    """Projections are split per stream (z, x, B, C, dt) rather than packed,
    so z/x/dt can be head-aligned TP-sharded while B/C stay replicated."""
    d_inner, n_heads, _ = dims(cfg)
    k = jax.random.split(rng, 8)
    n = cfg.ssm_state
    return {
        "norm": L.init_norm(cfg),
        "in_z": L._dense_init(k[0], (cfg.d_model, d_inner), cfg.dtype),
        "in_x": L._dense_init(k[1], (cfg.d_model, d_inner), cfg.dtype),
        "in_b": L._dense_init(k[2], (cfg.d_model, n), cfg.dtype),
        "in_c": L._dense_init(k[3], (cfg.d_model, n), cfg.dtype),
        "in_dt": L._dense_init(k[4], (cfg.d_model, n_heads), cfg.dtype),
        "conv_wx": L._dense_init(k[5], (cfg.ssm_conv, d_inner), cfg.dtype),
        "conv_bx": jnp.zeros((d_inner,), cfg.dtype),
        "conv_wbc": L._dense_init(k[6], (cfg.ssm_conv, 2 * n), cfg.dtype),
        "conv_bbc": jnp.zeros((2 * n,), cfg.dtype),
        "A_log": jnp.zeros((n_heads,), jnp.float32),          # A = -exp(A_log)
        "dt_bias": jnp.full((n_heads,), -2.0, jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "gate_norm": {"scale": jnp.zeros((d_inner,), cfg.dtype)},
        "out_proj": L._dense_init(k[7], (d_inner, cfg.d_model), cfg.dtype),
    }


def init_state(cfg: ModelConfig, batch: int):
    d_inner, n_heads, _ = dims(cfg)
    return {
        "conv_x": jnp.zeros((batch, cfg.ssm_conv - 1, d_inner), cfg.dtype),
        "conv_bc": jnp.zeros((batch, cfg.ssm_conv - 1, 2 * cfg.ssm_state),
                             cfg.dtype),
        "ssd": jnp.zeros((batch, n_heads, D_HEAD, cfg.ssm_state), jnp.float32),
    }


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def ssd_scan(x, dt, A, Bm, Cm, D, s0):
    """Sequential oracle.
    x: (B,T,H,P); dt: (B,T,H); A: (H,); Bm/Cm: (B,T,N); s0: (B,H,P,N)."""
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = Bm.astype(jnp.float32), Cm.astype(jnp.float32)

    def step(s, inp):
        xt, dtt, bt, ct = inp                       # (B,H,P),(B,H),(B,N),(B,N)
        decay = jnp.exp(dtt * A[None])              # (B,H)
        upd = jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], bt)
        s = decay[..., None, None] * s + upd
        y = jnp.einsum("bhpn,bn->bhp", s, ct)
        return s, y

    xs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
          Bf.transpose(1, 0, 2), Cf.transpose(1, 0, 2))
    sT, ys = lax.scan(step, s0.astype(jnp.float32), xs)
    y = ys.transpose(1, 0, 2, 3) + D[None, None, :, None] * xf
    return y, sT


def ssd_chunked(x, dt, A, Bm, Cm, D, s0, chunk: int = 64):
    """Chunked-parallel SSD (all exponents <= 0)."""
    b, t, h, p = x.shape
    n_state = Bm.shape[-1]
    assert t % chunk == 0
    n = t // chunk
    xf = x.astype(jnp.float32).reshape(b, n, chunk, h, p).transpose(1, 0, 3, 2, 4)
    dtf = dt.astype(jnp.float32).reshape(b, n, chunk, h).transpose(1, 0, 3, 2)
    Bf = Bm.astype(jnp.float32).reshape(b, n, chunk, n_state).transpose(1, 0, 2, 3)
    Cf = Cm.astype(jnp.float32).reshape(b, n, chunk, n_state).transpose(1, 0, 2, 3)

    a = dtf * A[None, None, :, None]               # (n,B,H,C) log-decay <= 0
    cum = jnp.cumsum(a, axis=-1)                    # inclusive
    total = cum[..., -1:]

    def step(s, inp):
        xc, dtc, bc, cc, cumc, totc = inp           # xc: (B,H,C,P)
        # intra-chunk: Att[i,j] = (C_i.B_j) exp(cum[i]-cum[j]) dt_j, j<=i
        cb = jnp.einsum("bin,bjn->bij", cc, bc)     # (B,C,C)
        dec = jnp.exp(cumc[..., :, None] - cumc[..., None, :])  # (B,H,C,C)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        att = jnp.where(mask, cb[:, None] * dec, 0.0) * dtc[:, :, None, :]
        y_intra = jnp.einsum("bhij,bhjp->bhip", att, xc)
        # state contribution: y_i += C_i . (exp(cum[i]) S)
        c_dec = cc[:, None, :, :] * jnp.exp(cumc)[..., None]   # (B,H,C,N)
        y_state = jnp.einsum("bhcn,bhpn->bhcp", c_dec, s)
        # state update: S' = exp(tot) S + sum_j exp(tot-cum[j]) dt_j x_j B_j
        k_dec = (dtc * jnp.exp(totc - cumc))[..., None] * xc   # (B,H,C,P)
        s = jnp.exp(totc)[..., None] * s + jnp.einsum(
            "bhcp,bcn->bhpn", k_dec, bc)
        return s, y_intra + y_state

    xs = (xf, dtf, Bf, Cf, cum, total)
    sT, ys = lax.scan(step, s0.astype(jnp.float32), xs)
    y = ys.swapaxes(2, 3).transpose(1, 0, 2, 3, 4).reshape(b, t, h, p)
    return y + D[None, None, :, None] * x.astype(jnp.float32), sT


def ssd_decode(x, dt, A, Bm, Cm, D, s):
    """One step. x: (B,H,P); dt: (B,H); Bm/Cm: (B,N); s: (B,H,P,N)."""
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A[None])
    upd = jnp.einsum("bhp,bn->bhpn", xf * dtf[..., None], Bm.astype(jnp.float32))
    s = decay[..., None, None] * s + upd
    y = jnp.einsum("bhpn,bn->bhp", s, Cm.astype(jnp.float32))
    return y + D[None, :, None] * xf, s


# ---------------------------------------------------------------------------
# block
# ---------------------------------------------------------------------------


def _causal_conv(seq, conv_state, w, bias):
    """seq: (B,T,Dc); conv_state: (B,K-1,Dc) = trailing inputs of the past.
    Returns (out (B,T,Dc), new_state)."""
    k = w.shape[0]
    ext = jnp.concatenate([conv_state.astype(seq.dtype), seq], axis=1)
    out = sum(ext[:, i : i + seq.shape[1]] * w[i][None, None] for i in range(k))
    new_state = ext[:, -(k - 1):] if k > 1 else conv_state
    return jax.nn.silu(out + bias[None, None]), new_state


def apply_block(bp, x, state, cfg: ModelConfig, seq_mode: str):
    """x: (B,T,d). Returns (out, new_state)."""
    d_inner, n_heads, _ = dims(cfg)
    b, t, _ = x.shape
    h = L.apply_norm(bp["norm"], x, cfg)
    z = jnp.einsum("btd,de->bte", h, bp["in_z"])
    xr = jnp.einsum("btd,de->bte", h, bp["in_x"])
    bc = jnp.einsum("btd,de->bte", h,
                    jnp.concatenate([bp["in_b"], bp["in_c"]], axis=-1))
    dt_raw = jnp.einsum("btd,de->bte", h, bp["in_dt"])
    xs, new_conv_x = _causal_conv(xr, state["conv_x"], bp["conv_wx"],
                                  bp["conv_bx"])
    bc, new_conv_bc = _causal_conv(bc, state["conv_bc"], bp["conv_wbc"],
                                   bp["conv_bbc"])
    Bm, Cm = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + bp["dt_bias"][None, None])
    A = -jnp.exp(bp["A_log"])
    xh = xs.reshape(b, t, n_heads, D_HEAD)

    if seq_mode == "decode":
        y, new_ssd = ssd_decode(xh[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0],
                                bp["D"], state["ssd"])
        y = y[:, None]
    elif seq_mode == "chunked" and t % 64 == 0 and t >= 64:
        y, new_ssd = ssd_chunked(xh, dt, A, Bm, Cm, bp["D"], state["ssd"])
    else:
        y, new_ssd = ssd_scan(xh, dt, A, Bm, Cm, bp["D"], state["ssd"])

    y = y.reshape(b, t, d_inner).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), bp["gate_norm"]["scale"], cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, bp["out_proj"])
    return out, {"conv_x": new_conv_x, "conv_bc": new_conv_bc, "ssd": new_ssd}
