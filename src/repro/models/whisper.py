"""Whisper-style encoder-decoder (arXiv:2212.04356) — transformer backbone.

The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, S_enc, d_model); positions are sinusoidal.

Serving mapping (DESIGN.md §5): the encoder pass + cross-KV precompute is
the *prefill* (cost ~ encoder FLOPs over S_enc), the decoder step is the
*decode* with a self-KV cache plus fixed cross-KV — so the P/D
disaggregation and SLO-aware multiplexing apply unchanged.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.layers import MaskSpec, ModelConfig


def sinusoid_positions(s: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _init_enc_block(rng, cfg: ModelConfig):
    k = jax.random.split(rng, 2)
    return {
        "norm_attn": L.init_norm(cfg),
        "attn": L.init_attention(k[0], cfg),
        "norm_mlp": L.init_norm(cfg),
        "mlp": L.init_mlp(k[1], cfg),
    }


def _init_dec_block(rng, cfg: ModelConfig):
    k = jax.random.split(rng, 3)
    return {
        "norm_self": L.init_norm(cfg),
        "self_attn": L.init_attention(k[0], cfg),
        "norm_cross": L.init_norm(cfg),
        "cross_attn": L.init_attention(k[1], cfg),
        "norm_mlp": L.init_norm(cfg),
        "mlp": L.init_mlp(k[2], cfg),
    }


def init_lm(rng, cfg: ModelConfig):
    k = jax.random.split(rng, 4)
    n_enc = cfg.encoder_layers or cfg.num_layers
    return {
        "embed": L.init_embedding(k[0], cfg),
        "enc_blocks": jax.vmap(lambda r: _init_enc_block(r, cfg))(
            jax.random.split(k[1], n_enc)),
        "dec_blocks": jax.vmap(lambda r: _init_dec_block(r, cfg))(
            jax.random.split(k[2], cfg.num_layers)),
        "enc_norm": L.init_norm(cfg),
        "final_norm": L.init_norm(cfg),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int):
    kvshape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    cross = (cfg.num_layers, batch, enc_len, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(kvshape, cfg.dtype),
        "v": jnp.zeros(kvshape, cfg.dtype),
        "cross_k": jnp.zeros(cross, cfg.dtype),
        "cross_v": jnp.zeros(cross, cfg.dtype),
    }


def cache_spec(cfg: ModelConfig, batch: int, max_len: int, enc_len: int):
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
        jax.eval_shape(lambda: init_cache(cfg, batch, max_len, enc_len)),
    )


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------


def encode(params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames: (B, S_enc, d_model) stub conv-frontend output."""
    b, s, d = frames.shape
    x = frames + sinusoid_positions(s, d, frames.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(carry, bp):
        h = L.apply_norm(bp["norm_attn"], carry, cfg)
        attn, _ = L.apply_attention(bp["attn"], h, cfg, positions=positions,
                                    mask=MaskSpec("full"), rope=False)
        x = carry + attn
        h = L.apply_norm(bp["norm_mlp"], x, cfg)
        return x + L.apply_mlp(bp["mlp"], h, cfg), None

    if cfg.scan_layers:
        x, _ = lax.scan(body, x, params["enc_blocks"])
    else:
        n = jax.tree.leaves(params["enc_blocks"])[0].shape[0]
        for i in range(n):
            bp = jax.tree.map(lambda a: a[i], params["enc_blocks"])
            x, _ = body(x, bp)
    return L.apply_norm(params["enc_norm"], x, cfg)


def compute_cross_kv(params, enc_out, cfg: ModelConfig):
    """Per-decoder-layer K/V over encoder output: (L, B, S_enc, Hkv, D)."""

    def body(_, bp):
        k, v = L.compute_kv(bp["cross_attn"], enc_out, cfg)
        return None, (k, v)

    if cfg.scan_layers:
        _, (ks, vs) = lax.scan(body, None, params["dec_blocks"])
    else:
        ks, vs = [], []
        for i in range(cfg.num_layers):
            bp = jax.tree.map(lambda a: a[i], params["dec_blocks"])
            _, (k, v) = body(None, bp)
            ks.append(k)
            vs.append(v)
        ks, vs = jnp.stack(ks), jnp.stack(vs)
    return ks, vs


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------


def _dec_block(bp, x, cfg: ModelConfig, *, positions, mask, kv, cross_kv,
               cache_positions, lengths):
    h = L.apply_norm(bp["norm_self"], x, cfg)
    attn, new_kv = L.apply_attention(
        bp["self_attn"], h, cfg, positions=positions, mask=mask,
        kv_cache=kv, cache_positions=cache_positions, lengths=lengths,
        rope=False)
    x = x + attn
    h = L.apply_norm(bp["norm_cross"], x, cfg)
    cross, _ = L.apply_attention(
        bp["cross_attn"], h, cfg, positions=positions, mask=MaskSpec("full"),
        cross_kv=cross_kv, rope=False)
    x = x + cross
    h = L.apply_norm(bp["norm_mlp"], x, cfg)
    return x + L.apply_mlp(bp["mlp"], h, cfg), new_kv


def _run_decoder(params, x, cfg: ModelConfig, *, positions, mask, cache,
                 cache_positions, lengths, remat=False):
    def body(carry, scanned):
        bp, kv, ckv = scanned
        fn = functools.partial(
            _dec_block, cfg=cfg, positions=positions, mask=mask,
            cross_kv=ckv, cache_positions=cache_positions, lengths=lengths)
        if remat:
            fn = jax.checkpoint(fn, prevent_cse=False)
        h, new_kv = fn(bp, carry, kv=kv)
        return h, new_kv

    xs = (params["dec_blocks"], (cache["k"], cache["v"]),
          (cache["cross_k"], cache["cross_v"]))
    if cfg.scan_layers:
        x, new_kv = lax.scan(body, x, xs)
        return x, {"k": new_kv[0], "v": new_kv[1],
                   "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
    ck, cv = cache["k"], cache["v"]
    for i in range(cfg.num_layers):
        bp = jax.tree.map(lambda a: a[i], params["dec_blocks"])
        x, nkv = body(x, (bp, (ck[i], cv[i]),
                          (cache["cross_k"][i], cache["cross_v"][i])))
        ck, cv = ck.at[i].set(nkv[0]), cv.at[i].set(nkv[1])
    return x, {"k": ck, "v": cv,
               "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}


def _dec_embed(params, tokens, start, cfg: ModelConfig):
    x = L.embed(params["embed"], tokens, cfg)
    pos = sinusoid_positions(8192 + tokens.shape[1], cfg.d_model, x.dtype)
    # gather per-batch positional rows at start..start+S
    idx = start[:, None] + jnp.arange(tokens.shape[1])[None]
    return x + pos[idx]


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def forward_train(params, frames, tokens, cfg: ModelConfig, ep=None):
    """Teacher forcing: frames (B,S_enc,d), tokens (B,S_dec)."""
    enc = encode(params, frames, cfg)
    ck, cv = compute_cross_kv(params, enc, cfg)
    b, s = tokens.shape
    zero = jnp.zeros((b,), jnp.int32)
    x = _dec_embed(params, tokens, zero, cfg)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    cache = {"k": jnp.zeros((cfg.num_layers, b, s, cfg.num_kv_heads,
                             cfg.head_dim), cfg.dtype),
             "v": jnp.zeros((cfg.num_layers, b, s, cfg.num_kv_heads,
                             cfg.head_dim), cfg.dtype),
             "cross_k": ck, "cross_v": cv}
    x, _ = _run_decoder(params, x, cfg, positions=positions,
                        mask=MaskSpec("causal"), cache=cache,
                        cache_positions=zero, lengths=None, remat=True)
    x = L.apply_norm(params["final_norm"], x, cfg)
    return L.unembed(params["embed"], x, cfg)


def lm_loss(params, batch, cfg: ModelConfig, ep=None):
    logits = forward_train(params, batch["frames"], batch["tokens"], cfg)
    return L.softmax_xent(logits, batch["labels"])


def prefill(params, cache, frames, tokens, lengths, cfg: ModelConfig, ep=None):
    """Encode + cross-KV precompute + decoder prompt prefill."""
    enc = encode(params, frames, cfg)
    ck, cv = compute_cross_kv(params, enc, cfg)
    cache = dict(cache, cross_k=ck, cross_v=cv)
    b, s = tokens.shape
    zero = jnp.zeros((b,), jnp.int32)
    x = _dec_embed(params, tokens, zero, cfg)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x, cache = _run_decoder(params, x, cfg, positions=positions,
                            mask=MaskSpec("causal"), cache=cache,
                            cache_positions=zero, lengths=None)
    x = L.apply_norm(params["final_norm"], x, cfg)
    idx = jnp.clip(lengths - 1, 0, s - 1)
    last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    return L.unembed(params["embed"], last[:, None], cfg)[:, 0], cache


def decode(params, cache, tokens, lengths, cfg: ModelConfig, ep=None):
    b = tokens.shape[0]
    x = _dec_embed(params, tokens[:, None], lengths, cfg)
    positions = lengths[:, None]
    x, cache = _run_decoder(params, x, cfg, positions=positions,
                            mask=MaskSpec("lengths"), cache=cache,
                            cache_positions=lengths, lengths=lengths)
    x = L.apply_norm(params["final_norm"], x, cfg)
    return L.unembed(params["embed"], x, cfg)[:, 0], cache
