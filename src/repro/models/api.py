"""Uniform model API over all architecture families.

Every family exposes:
  init(rng)                          -> params
  init_cache(batch, max_len)         -> cache/state pytree
  prefill(params, cache, inputs, lengths) -> (last_logits, cache)
  decode(params, cache, tokens, lengths)  -> (logits, cache)
  loss(params, batch)                -> scalar
plus shape-only variants (``*_spec``) for the dry-run.

"inputs" is tokens (B, S) for LMs; whisper/vlm carry extra modality inputs
in a dict (stub frontends per the assignment).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import rwkv6, transformer, whisper, zamba2
from repro.models.layers import ModelConfig
from repro.models.moe import EPInfo


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable
    init_cache: Callable          # (batch, max_len) -> pytree
    cache_spec: Callable          # (batch, max_len) -> ShapeDtypeStruct tree
    prefill: Callable             # (params, cache, inputs, lengths, ep=None)
    decode: Callable              # (params, cache, tokens, lengths, ep=None)
    loss: Callable                # (params, batch, ep=None)
    prefill_chunk: Optional[Callable] = None
    # shape helpers for the dry-run / serving engine
    enc_len_for: Callable = lambda seq: 0


def _sds(tree):
    return jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)


def build(cfg: ModelConfig) -> ModelAPI:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        def prefill_fn(params, cache, inputs, lengths, ep=None):
            if isinstance(inputs, dict):
                return transformer.prefill(
                    params, cache, inputs["tokens"], lengths, cfg, ep=ep,
                    prefix_embeds=inputs.get("prefix_embeds"))
            return transformer.prefill(params, cache, inputs, lengths, cfg, ep=ep)

        return ModelAPI(
            cfg=cfg,
            init=lambda rng: transformer.init_lm(rng, cfg),
            init_cache=lambda b, s: transformer.init_cache(cfg, b, s),
            cache_spec=lambda b, s: transformer.cache_spec(cfg, b, s),
            prefill=prefill_fn,
            decode=lambda p, c, t, l, ep=None: transformer.decode(p, c, t, l, cfg, ep=ep),
            loss=lambda p, batch, ep=None: transformer.lm_loss(p, batch, cfg, ep=ep),
            prefill_chunk=lambda p, c, ch, st, ep=None, take=None:
                transformer.prefill_chunk(p, c, ch, st, cfg, ep=ep,
                                          take=take),
        )
    if fam == "rwkv":
        return ModelAPI(
            cfg=cfg,
            init=lambda rng: rwkv6.init_lm(rng, cfg),
            init_cache=lambda b, s: rwkv6.init_state(cfg, b),
            cache_spec=lambda b, s: rwkv6.state_spec(cfg, b),
            prefill=lambda p, c, t, l, ep=None: rwkv6.prefill(p, c, t, l, cfg),
            decode=lambda p, c, t, l, ep=None: rwkv6.decode(p, c, t, l, cfg),
            loss=lambda p, batch, ep=None: rwkv6.lm_loss(p, batch, cfg),
        )
    if fam == "hybrid":
        return ModelAPI(
            cfg=cfg,
            init=lambda rng: zamba2.init_lm(rng, cfg),
            init_cache=lambda b, s: zamba2.init_state(cfg, b, s),
            cache_spec=lambda b, s: zamba2.state_spec(cfg, b, s),
            prefill=lambda p, c, t, l, ep=None: zamba2.prefill(p, c, t, l, cfg),
            decode=lambda p, c, t, l, ep=None: zamba2.decode(p, c, t, l, cfg),
            loss=lambda p, batch, ep=None: zamba2.lm_loss(p, batch, cfg),
        )
    if fam == "encdec":
        def enc_len_for(seq):
            return seq

        def prefill_fn(params, cache, inputs, lengths, ep=None):
            return whisper.prefill(params, cache, inputs["frames"],
                                   inputs["tokens"], lengths, cfg)

        return ModelAPI(
            cfg=cfg,
            init=lambda rng: whisper.init_lm(rng, cfg),
            init_cache=lambda b, s, enc_len=0: whisper.init_cache(
                cfg, b, s, enc_len or max(8, s // 4)),
            cache_spec=lambda b, s, enc_len=0: whisper.cache_spec(
                cfg, b, s, enc_len or max(8, s // 4)),
            prefill=prefill_fn,
            decode=lambda p, c, t, l, ep=None: whisper.decode(p, c, t, l, cfg),
            loss=lambda p, batch, ep=None: whisper.lm_loss(p, batch, cfg),
            enc_len_for=enc_len_for,
        )
    raise ValueError(f"unknown family {fam}")
