"""PartitionSpec rules: map (arch config, mesh) -> pytree of PartitionSpecs.

Baseline policy (hillclimb surface — see EXPERIMENTS.md §Perf):
  * vocab / d_ff dims        -> 'model' (Megatron TP)
  * attention heads          -> 'model' iff divisible, else replicated
  * MoE expert dim           -> the whole mesh (EP; 1T-class models cannot
                                fit any replicated expert layout)
  * batch                    -> ('pod','data') iff divisible, else the KV
                                cache sequence dim goes to 'data'
                                (long-context split-K decode)
  * mamba d_inner / heads    -> 'model' (head-aligned after proj split)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.layers import ModelConfig


@dataclasses.dataclass(frozen=True)
class MeshRules:
    mesh: Mesh
    batch_axes: tuple[str, ...]          # e.g. ('pod','data') or ('data',)
    model_axis: str = "model"
    ep_axes: tuple[str, ...] = ()        # expert-parallel axes (full mesh)

    @property
    def tp(self) -> int:
        return int(self.mesh.shape[self.model_axis])

    @property
    def n_batch(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.batch_axes]))


def make_rules(mesh: Mesh) -> MeshRules:
    names = mesh.axis_names
    batch = tuple(a for a in names if a != "model")
    return MeshRules(mesh=mesh, batch_axes=batch, ep_axes=tuple(names))


def _head_axis(rules: MeshRules, n_heads: int) -> Optional[str]:
    return rules.model_axis if n_heads % rules.tp == 0 else None


def _ff_axis(rules: MeshRules, d_ff: int) -> Optional[str]:
    return rules.model_axis if d_ff % rules.tp == 0 else None


def param_specs(cfg: ModelConfig, params_tree: Any, rules: MeshRules):
    """Walk the params pytree and assign PartitionSpecs by path."""
    m = rules.model_axis
    hq = _head_axis(rules, cfg.num_heads)
    hkv = _head_axis(rules, cfg.num_kv_heads)
    ff = _ff_axis(rules, cfg.d_ff)
    dm = rules.model_axis if cfg.d_model % rules.tp == 0 else None
    ep = rules.ep_axes

    def spec_for(path: tuple[str, ...], leaf) -> P:
        keys = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        name = keys[-1]
        joined = "/".join(str(k) for k in keys)
        nd = leaf.ndim if hasattr(leaf, "ndim") else len(leaf.shape)

        # --- embeddings -------------------------------------------------
        if "embed" in keys and name in ("table", "unembed"):
            v = leaf.shape[0]
            return P(m if v % rules.tp == 0 else None, None)
        if name == "vision_proj":
            return P(None, None)
        # --- rwkv (before attention: tm/cm reuse the wk/wv names) -------
        if "tm" in keys:
            if name in ("wr", "wg", "wk", "wv"):
                return P(None, dm)
            if name == "wo":
                return P(dm, None)
            if name == "decay_w2":
                return P(None, dm)
            if name in ("ln_out_scale", "ln_out_bias", "decay_base"):
                return P(dm)
            if name == "bonus_u":
                return P(_head_axis(rules, leaf.shape[0]), None)
            return P(*([None] * nd))
        if "cm" in keys:
            if name == "wk":
                return P(None, _ff_axis(rules, leaf.shape[1]))
            if name == "wv":
                return P(_ff_axis(rules, leaf.shape[0]), None)
            return P(*([None] * nd))
        # --- MoE (before generic mlp rules; expert weights are 3D) ------
        if "moe" in keys:
            if name == "router":
                return P(None, None)
            if name in ("w_gate", "w_up", "w_down") and nd == 3 and "shared" not in keys:
                return P(ep, None, None)
            if name in ("w_gate", "w_up"):
                return P(None, ff)
            if name == "w_down":
                return P(ff, None)
        # --- attention ---------------------------------------------------
        if name == "wq":
            return P(None, hq, None)
        if name in ("wk", "wv"):
            return P(None, hkv, None)
        if name == "wo":
            return P(hq, None, None)
        if name == "bq":
            return P(hq, None)
        if name in ("bk", "bv"):
            return P(hkv, None)
        # --- dense MLP -----------------------------------------------------
        if name in ("w_gate", "w_up") and nd == 2:
            fdim = leaf.shape[1]
            return P(None, m if fdim % rules.tp == 0 else None)
        if name == "w_down" and nd == 2:
            fdim = leaf.shape[0]
            return P(m if fdim % rules.tp == 0 else None, None)
        # --- mamba2 --------------------------------------------------------
        if name in ("in_z", "in_x"):
            return P(None, m if leaf.shape[1] % rules.tp == 0 else None)
        if name == "in_dt":
            return P(None, m if leaf.shape[1] % rules.tp == 0 else None)
        if name in ("in_b", "in_c"):
            return P(None, None)
        if name == "conv_wx":
            return P(None, m if leaf.shape[1] % rules.tp == 0 else None)
        if name == "conv_bx":
            return P(m if leaf.shape[0] % rules.tp == 0 else None)
        if name in ("A_log", "dt_bias", "D"):
            return P(m if leaf.shape[0] % rules.tp == 0 else None)
        if name == "out_proj" and nd == 2:
            return P(m if leaf.shape[0] % rules.tp == 0 else None, None)
        if "gate_norm" in keys:
            return P(m if leaf.shape[0] % rules.tp == 0 else None)
        # --- everything else (norms, small projections) -------------------
        return P(*([None] * nd))

    # blocks are stacked with a leading layer dim — prepend None
    def with_layer_dim(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "name", p))) for p in path]
        s = spec_for(path, leaf)
        stacked = any(k in ("blocks", "enc_blocks", "dec_blocks") for k in keys)
        if stacked:
            inner = spec_for(path, _DropLead(leaf))
            return P(None, *inner)
        return s

    return jax.tree_util.tree_map_with_path(with_layer_dim, params_tree)


class _DropLead:
    """Shape proxy with the leading (layer) dim removed."""

    def __init__(self, leaf):
        self.shape = tuple(leaf.shape[1:])
        self.ndim = len(self.shape)


def batch_spec(rules: MeshRules, batch: int) -> tuple:
    """Returns the batch-dim sharding (or None when batch is too small)."""
    if batch % rules.n_batch == 0:
        return rules.batch_axes
    # try data axis only
    d = int(np.prod([rules.mesh.shape[a] for a in rules.batch_axes
                     if a == "data"]))
    if batch % d == 0:
        return ("data",)
    return None


def io_specs(cfg: ModelConfig, rules: MeshRules, batch: int):
    b = batch_spec(rules, batch)
    return {
        "tokens": P(b, None),
        "labels": P(b, None),
        "lengths": P(b),
        "frames": P(b, None, None),
        "prefix_embeds": P(b, None, None),
        "logits": P(b, rules.model_axis if cfg.vocab_size % rules.tp == 0 else None),
    }


def cache_specs(cfg: ModelConfig, rules: MeshRules, batch: int,
                cache_tree: Any):
    """Sharding for cache/state pytrees (transformer / rwkv / zamba /
    whisper). When batch can't be sharded, the KV sequence dim takes 'data'
    (split-K long-context decode)."""
    b = batch_spec(rules, batch)
    seq = None if b is not None else "data"
    hkv = _head_axis(rules, cfg.num_kv_heads)
    m = rules.model_axis

    def spec(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "name", p))) for p in path]
        name = keys[-1]
        nd = len(leaf.shape)
        if any(k in ("k", "v", "cross_k", "cross_v") for k in keys):
            # (L|ninv, B, S, Hkv, D) stacked — or (B, S, Hkv, D) for
            # per-layer ring caches. When Hkv doesn't divide the model
            # axis, shard the *sequence* over 'model' instead (split-K
            # attention: softmax/AV reductions over the sharded S become
            # small per-token all-reduces under GSPMD) — never replicate a
            # multi-GB cache.
            if hkv is not None:
                inner = P(b, seq, hkv, None)
            else:
                s_axes = ("model",) if b is not None else ("data", "model")
                inner = P(b, s_axes, None, None)
            if nd == 5:
                return P(None, *inner)
            if inner[1] is not None and leaf.shape[1] % rules.tp != 0:
                return P(inner[0], None, *inner[2:])   # small ring: no shard
            return inner
        if name in ("ts_tm", "ts_cm"):               # (L, B, d)
            return P(None, b, m if cfg.d_model % rules.tp == 0 else None)
        if name == "wkv":                             # (L, B, H, hd, hd)
            h = leaf.shape[2]
            return P(None, b, m if h % rules.tp == 0 else None, None, None)
        if name in ("conv_x",):                       # (L, B, K-1, d_inner)
            return P(None, b, None, m if leaf.shape[-1] % rules.tp == 0 else None)
        if name in ("conv_bc",):
            return P(None, b, None, None)
        if name == "ssd":                              # (L, B, H, hd, N)
            h = leaf.shape[2]
            return P(None, b, m if h % rules.tp == 0 else None, None, None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(spec, cache_tree)
