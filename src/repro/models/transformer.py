"""Decoder-only transformer LM (dense + MoE + VLM-backbone variants).

One homogeneous block is scanned over depth. Per-layer heterogeneity
(gemma2 local/global alternation) is expressed with *scanned arrays*
(per-layer attention window), so a single scan covers every family and the
HLO stays O(1) in depth.

Public entry points (all pure):
  init_lm(rng, cfg)                                  -> params
  init_cache(cfg, batch, max_len, dtype)             -> cache pytree
  forward_train(params, tokens, cfg, ep)             -> logits (B, S, V)
  prefill(params, cache, tokens, lengths, cfg, ep)   -> (last_logits, cache)
  prefill_chunk(params, cache, chunk, starts, cfg)   -> (last_logits, cache)
  decode(params, cache, tokens, lengths, cfg, ep)    -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models import moe as M
from repro.models.layers import MaskSpec, ModelConfig

NO_WINDOW = jnp.iinfo(jnp.int32).max // 2  # "window" that never masks


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(rng, cfg: ModelConfig):
    k = jax.random.split(rng, 6)
    p = {
        "norm_attn": L.init_norm(cfg),
        "attn": L.init_attention(k[0], cfg),
        "norm_mlp": L.init_norm(cfg),
    }
    if cfg.use_post_norms:
        p["norm_attn_post"] = L.init_norm(cfg)
        p["norm_mlp_post"] = L.init_norm(cfg)
    if cfg.is_moe:
        p["moe"] = M.init_moe(k[1], cfg)
        if cfg.moe_dense_residual_ff:
            p["mlp"] = L.init_mlp(k[2], cfg, d_ff=cfg.moe_dense_residual_ff)
    else:
        p["mlp"] = L.init_mlp(k[2], cfg)
    return p


def init_lm(rng, cfg: ModelConfig):
    k = jax.random.split(rng, 3)
    blocks = jax.vmap(lambda r: _init_block(r, cfg))(
        jax.random.split(k[0], cfg.num_layers)
    )
    p = {
        "embed": L.init_embedding(k[1], cfg),
        "blocks": blocks,
        "final_norm": L.init_norm(cfg),
    }
    if cfg.vision_feature_dim:
        p["vision_proj"] = L._dense_init(
            k[2], (cfg.vision_feature_dim, cfg.d_model), cfg.dtype
        )
    return p


def layer_windows_py(cfg: ModelConfig) -> list:
    if cfg.local_global_alternating and cfg.sliding_window:
        return [cfg.sliding_window if i % 2 == 0 else NO_WINDOW
                for i in range(cfg.num_layers)]
    if cfg.sliding_window:
        return [cfg.sliding_window] * cfg.num_layers
    return [NO_WINDOW] * cfg.num_layers


def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer sliding windows as a scanned i32 array; NO_WINDOW = global.
    gemma2 convention: even layers local, odd layers global."""
    return jnp.asarray(layer_windows_py(cfg), jnp.int32)


def _cache_dtype(cfg: ModelConfig, dtype=None):
    if dtype is not None:
        return dtype
    if cfg.kv_cache_quant:
        return jnp.float8_e4m3fn
    return cfg.dtype


def _use_ring(cfg: ModelConfig) -> bool:
    return cfg.window_sized_cache and cfg.local_global_alternating \
        and not cfg.scan_layers


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = _cache_dtype(cfg, dtype)
    if _use_ring(cfg):
        # per-layer cache: local layers keep only a window-sized ring
        ks, vs = [], []
        for w in layer_windows_py(cfg):
            s = min(max_len, w)
            shape = (batch, s, cfg.num_kv_heads, cfg.head_dim)
            ks.append(jnp.zeros(shape, dtype))
            vs.append(jnp.zeros(shape, dtype))
        return {"k": tuple(ks), "v": tuple(vs)}
    shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_spec(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
        jax.eval_shape(lambda: init_cache(cfg, batch, max_len, dtype)),
    )


# ---------------------------------------------------------------------------
# block
# ---------------------------------------------------------------------------


def _block_fn(
    bp,
    x,
    cfg: ModelConfig,
    *,
    positions,
    mask: MaskSpec,
    window,
    kv=None,
    cache_positions=None,
    lengths=None,
    ep: Optional[M.EPInfo] = None,
    ring: bool = False,
):
    """One transformer block. ``window`` is a traced per-layer scalar.
    ``ring``: this layer's cache is a window-sized ring buffer (decode-only;
    the ring holds exactly the last ``ring_size`` tokens so the window mask
    reduces to slot-validity)."""
    if ring:
        ring_size = kv[0].shape[1]
        mask = MaskSpec(kind="ring")
        cache_positions = jnp.mod(cache_positions, ring_size)
    else:
        mask = MaskSpec(kind=mask.kind, window=window, q_offset=mask.q_offset)
    h = L.apply_norm(bp["norm_attn"], x, cfg)
    attn_out, new_kv = L.apply_attention(
        bp["attn"], h, cfg, positions=positions, mask=mask,
        kv_cache=kv, cache_positions=cache_positions, lengths=lengths,
    )
    if "norm_attn_post" in bp:
        attn_out = L.apply_norm(bp["norm_attn_post"], attn_out, cfg)
    x = x + attn_out

    h = L.apply_norm(bp["norm_mlp"], x, cfg)
    if cfg.is_moe:
        mlp_out = M.apply_moe(bp["moe"], h, cfg, ep)
        if cfg.moe_dense_residual_ff:
            mlp_out = mlp_out + L.apply_mlp(bp["mlp"], h, cfg)
    else:
        mlp_out = L.apply_mlp(bp["mlp"], h, cfg)
    if "norm_mlp_post" in bp:
        mlp_out = L.apply_norm(bp["norm_mlp_post"], mlp_out, cfg)
    return x + mlp_out, new_kv


def _remat(fn, cfg: ModelConfig):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "full":
        return jax.checkpoint(fn, prevent_cse=False)
    return jax.checkpoint(
        fn,
        policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        prevent_cse=False,
    )


def _run_blocks(
    params, x, cfg: ModelConfig, *,
    positions, mask, cache=None, cache_positions=None, lengths=None,
    ep=None, remat=False,
):
    windows = layer_windows(cfg)

    def body(carry, scanned, ring=False):
        bp, window, kv = scanned
        fn = functools.partial(
            _block_fn, cfg=cfg, positions=positions, mask=mask,
            cache_positions=cache_positions, lengths=lengths, ep=ep,
            ring=ring,
        )
        if remat:
            fn = _remat(fn, cfg)
        h, new_kv = fn(bp, carry, window=window, kv=kv)
        return h, new_kv

    if not cfg.scan_layers:
        # Unrolled (dry-run mode: exact cost_analysis; scan bodies are only
        # counted once by XLA's static cost model).
        per_layer = cache is not None and isinstance(cache["k"], tuple)
        ck = None if cache is None else (list(cache["k"]) if per_layer
                                         else cache["k"])
        cv = None if cache is None else (list(cache["v"]) if per_layer
                                         else cache["v"])
        max_s = max((a.shape[1] for a in ck), default=0) if per_layer else 0
        for i in range(cfg.num_layers):
            bp = jax.tree.map(lambda a: a[i], params["blocks"])
            if cache is None:
                kv = None
            elif per_layer:
                kv = (ck[i], cv[i])
            else:
                kv = (ck[i], cv[i])
            ring = per_layer and kv[0].shape[1] < max_s
            x, new_kv = body(x, (bp, windows[i], kv), ring=ring)
            if cache is not None:
                if per_layer:
                    ck[i], cv[i] = new_kv
                else:
                    ck = ck.at[i].set(new_kv[0])
                    cv = cv.at[i].set(new_kv[1])
        if cache is None:
            return x, None
        if per_layer:
            return x, {"k": tuple(ck), "v": tuple(cv)}
        return x, {"k": ck, "v": cv}

    if cache is None:
        def body2(carry, scanned):
            bp, window = scanned
            h, _ = body(carry, (bp, window, None))
            return h, None
        x, _ = lax.scan(body2, x, (params["blocks"], windows))
        return x, None
    kvs = (params["blocks"], windows, (cache["k"], cache["v"]))
    x, new_kv = lax.scan(body, x, kvs)
    return x, {"k": new_kv[0], "v": new_kv[1]}


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def _embed_inputs(params, tokens, cfg: ModelConfig, prefix_embeds=None):
    x = L.embed(params["embed"], tokens, cfg)
    if prefix_embeds is not None:
        vis = (prefix_embeds @ params["vision_proj"]).astype(x.dtype)
        x = jnp.concatenate([vis, x], axis=1)
    return x


def forward_train(params, tokens, cfg: ModelConfig, ep=None, prefix_embeds=None):
    """tokens (B, S) -> logits (B, S_total, V)."""
    x = _embed_inputs(params, tokens, cfg, prefix_embeds)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x, _ = _run_blocks(
        params, x, cfg, positions=positions, mask=MaskSpec("causal"),
        ep=ep, remat=True,
    )
    x = L.apply_norm(params["final_norm"], x, cfg)
    return L.unembed(params["embed"], x, cfg)


def lm_loss(params, batch, cfg: ModelConfig, ep=None):
    """batch: {tokens (B,S), labels (B,S)} -> scalar CE."""
    logits = forward_train(params, batch["tokens"], cfg, ep=ep,
                           prefix_embeds=batch.get("prefix_embeds"))
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:      # vlm prefix: loss on text only
        logits = logits[:, -labels.shape[1]:]
    return L.softmax_xent(logits, labels)


def prefill(params, cache, tokens, lengths, cfg: ModelConfig, ep=None,
            prefix_embeds=None):
    """Full-prompt prefill. tokens (B, S) padded; KV written at [0, S).
    Returns (last_token_logits (B, V), cache)."""
    x = _embed_inputs(params, tokens, cfg, prefix_embeds)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    zero = jnp.zeros((b,), jnp.int32)
    x, cache = _run_blocks(
        params, x, cfg, positions=positions, mask=MaskSpec("causal"),
        cache=cache, cache_positions=zero, ep=ep,
    )
    x = L.apply_norm(params["final_norm"], x, cfg)
    idx = jnp.clip(lengths - 1, 0, s - 1)
    last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    return L.unembed(params["embed"], last[:, None], cfg)[:, 0], cache


def prefill_chunk(params, cache, chunk, starts, cfg: ModelConfig, ep=None,
                  take=None):
    """Chunked prefill: chunk (B, Sc) continues requests whose first
    ``starts[b]`` tokens are already in the cache. ``take`` (B,) selects the
    per-request last real token (chunks may be bucket-padded); default Sc."""
    x = _embed_inputs(params, chunk, cfg)
    b, sc, _ = x.shape
    positions = starts[:, None] + jnp.arange(sc)[None]
    x, cache = _run_blocks(
        params, x, cfg, positions=positions, mask=MaskSpec("chunk"),
        cache=cache, cache_positions=starts, lengths=starts, ep=ep,
    )
    x = L.apply_norm(params["final_norm"], x, cfg)
    idx = jnp.clip((take if take is not None else sc) - 1, 0, sc - 1)
    if not hasattr(idx, "shape") or idx.ndim == 0:
        last = x[:, idx][:, None]
    else:
        last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
    return L.unembed(params["embed"], last, cfg)[:, 0], cache


def decode(params, cache, tokens, lengths, cfg: ModelConfig, ep=None):
    """One decode step. tokens (B,) int32 — the freshly sampled token, to be
    written at position lengths[b]. Returns (logits (B, V), cache)."""
    x = L.embed(params["embed"], tokens[:, None], cfg)
    b = x.shape[0]
    positions = lengths[:, None]
    x, cache = _run_blocks(
        params, x, cfg, positions=positions, mask=MaskSpec("lengths"),
        cache=cache, cache_positions=lengths, lengths=lengths, ep=ep,
    )
    x = L.apply_norm(params["final_norm"], x, cfg)
    return L.unembed(params["embed"], x, cfg)[:, 0], cache
