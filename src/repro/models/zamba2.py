"""Zamba2 (arXiv:2411.15242) — Mamba2 backbone + *shared* attention block.

``num_layers`` Mamba2 blocks; every ``attn_every`` blocks, one shared
(single weight set) attention+MLP block is invoked, taking
``proj(concat(hidden, original_embedding))`` as input — each invocation has
its own KV cache slot (the weights are shared, the caches are not).

State: per-layer mamba states + per-invocation KV caches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models.layers import MaskSpec, ModelConfig


def n_shared_invocations(cfg: ModelConfig) -> int:
    return (cfg.num_layers + cfg.attn_every - 1) // cfg.attn_every


def init_lm(rng, cfg: ModelConfig):
    k = jax.random.split(rng, 6)
    blocks = jax.vmap(lambda r: M2.init_block(r, cfg))(
        jax.random.split(k[0], cfg.num_layers)
    )
    shared = {
        "norm_attn": L.init_norm(cfg),
        "attn": L.init_attention(k[1], cfg),
        "norm_mlp": L.init_norm(cfg),
        "mlp": L.init_mlp(k[2], cfg),
        "in_proj": L._dense_init(k[3], (2 * cfg.d_model, cfg.d_model), cfg.dtype),
        "out_proj": L._dense_init(k[4], (cfg.d_model, cfg.d_model), cfg.dtype),
    }
    return {
        "embed": L.init_embedding(k[5], cfg),
        "blocks": blocks,
        "shared": shared,
        "final_norm": L.init_norm(cfg),
    }


def init_state(cfg: ModelConfig, batch: int, max_len: int):
    ninv = n_shared_invocations(cfg)
    m = M2.init_state(cfg, batch)
    mamba = jax.tree.map(
        lambda a: jnp.zeros((cfg.num_layers,) + a.shape, a.dtype), m)
    return {
        "mamba": mamba,
        "k": jnp.zeros((ninv, batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                       cfg.dtype),
        "v": jnp.zeros((ninv, batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                       cfg.dtype),
    }


def state_spec(cfg: ModelConfig, batch: int, max_len: int):
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
        jax.eval_shape(lambda: init_state(cfg, batch, max_len)),
    )


def _shared_block(sp, h, x0, cfg: ModelConfig, *, positions, mask, kv,
                  cache_positions, lengths):
    inp = jnp.concatenate([h, x0], axis=-1)
    a_in = jnp.einsum("btd,de->bte", inp, sp["in_proj"])
    z = L.apply_norm(sp["norm_attn"], a_in, cfg)
    attn_out, new_kv = L.apply_attention(
        sp["attn"], z, cfg, positions=positions, mask=mask,
        kv_cache=kv, cache_positions=cache_positions, lengths=lengths)
    a = a_in + attn_out
    z = L.apply_norm(sp["norm_mlp"], a, cfg)
    a = a + L.apply_mlp(sp["mlp"], z, cfg)
    return h + jnp.einsum("btd,de->bte", a, sp["out_proj"]), new_kv


def _run(params, x, state, cfg: ModelConfig, seq_mode: str, *,
         positions, mask, cache_positions, lengths, remat=False):
    """Mixed cadence breaks a single homogeneous scan; we unroll the shared
    invocations and scan each mamba segment between them."""
    x0 = x
    ninv = n_shared_invocations(cfg)
    new_mamba = state["mamba"]
    new_k, new_v = state["k"], state["v"]

    def mamba_seg(x, lo, hi):
        def body(carry, scanned):
            bp, st = scanned
            fn = functools.partial(M2.apply_block, cfg=cfg, seq_mode=seq_mode)
            if remat:
                fn = jax.checkpoint(fn, prevent_cse=False)
            out, nst = fn(bp, carry, st)
            return carry + out, nst

        seg_params = jax.tree.map(lambda a: a[lo:hi], params["blocks"])
        seg_state = jax.tree.map(lambda a: a[lo:hi], state["mamba"])
        if cfg.scan_layers:
            x, nst = lax.scan(body, x, (seg_params, seg_state))
            return x, nst
        outs = []
        for i in range(hi - lo):
            bp = jax.tree.map(lambda a: a[i], seg_params)
            st = jax.tree.map(lambda a: a[i], seg_state)
            x, nst_i = body(x, (bp, st))
            outs.append(nst_i)
        return x, jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    for inv in range(ninv):
        lo = inv * cfg.attn_every
        hi = min(cfg.num_layers, (inv + 1) * cfg.attn_every)
        x, nst = mamba_seg(x, lo, hi)
        new_mamba = jax.tree.map(
            lambda full, seg: lax.dynamic_update_slice_in_dim(full, seg, lo, 0),
            new_mamba, nst)
        x, kv = _shared_block(
            params["shared"], x, x0, cfg, positions=positions, mask=mask,
            kv=(new_k[inv], new_v[inv]), cache_positions=cache_positions,
            lengths=lengths)
        new_k = new_k.at[inv].set(kv[0])
        new_v = new_v.at[inv].set(kv[1])

    return x, {"mamba": new_mamba, "k": new_k, "v": new_v}


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def forward_train(params, tokens, cfg: ModelConfig, ep=None):
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens, cfg)
    state = init_state(cfg, b, s)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    zero = jnp.zeros((b,), jnp.int32)
    x, _ = _run(params, x, state, cfg, "chunked", positions=positions,
                mask=MaskSpec("causal"), cache_positions=zero, lengths=None,
                remat=True)
    x = L.apply_norm(params["final_norm"], x, cfg)
    return L.unembed(params["embed"], x, cfg)


def lm_loss(params, batch, cfg: ModelConfig, ep=None):
    logits = forward_train(params, batch["tokens"], cfg)
    return L.softmax_xent(logits, batch["labels"])


def prefill(params, state, tokens, lengths, cfg: ModelConfig, ep=None):
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    zero = jnp.zeros((b,), jnp.int32)
    x, state = _run(params, x, state, cfg, "chunked", positions=positions,
                    mask=MaskSpec("causal"), cache_positions=zero, lengths=None)
    x = L.apply_norm(params["final_norm"], x, cfg)
    idx = jnp.clip(lengths - 1, 0, s - 1)
    last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    return L.unembed(params["embed"], last[:, None], cfg)[:, 0], state


def decode(params, state, tokens, lengths, cfg: ModelConfig, ep=None):
    b = tokens.shape[0]
    x = L.embed(params["embed"], tokens[:, None], cfg)
    positions = lengths[:, None]
    x, state = _run(params, x, state, cfg, "decode", positions=positions,
                    mask=MaskSpec("lengths"), cache_positions=lengths,
                    lengths=lengths)
    x = L.apply_norm(params["final_norm"], x, cfg)
    return L.unembed(params["embed"], x, cfg)[:, 0], state
