"""RWKV-6 "Finch" (arXiv:2404.05892) — attention-free LM.

Time-mixing: token-shift with data-dependent (LoRA-produced) interpolation,
data-dependent per-channel decay w_t, and the WKV linear recurrence

    out_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T

per head (head size 64). Channel-mixing: squared-ReLU MLP with token shift.

State per layer (decode is O(1) in context length):
  * ts_tm, ts_cm: (B, d) last-token hidden for the two token shifts
  * wkv:          (B, H, Dk, Dv) f32 recurrent state

Prefill/train run the recurrence with a time-dim lax.scan over chunks; the
Pallas kernel (kernels/wkv6.py) implements the chunked form for the TPU hot
path and is validated against `wkv_scan` here.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.layers import ModelConfig

LORA_DECAY = 64
LORA_MIX = 32
N_MIX = 5  # w, k, v, r, g


def _dinit(rng, shape, dtype, scale=None):
    fan_in = shape[0]
    s = scale if scale is not None else 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.normal(rng, shape, jnp.float32) * s).astype(dtype)


def head_dims(cfg: ModelConfig) -> tuple[int, int]:
    hd = 64
    return cfg.d_model // hd, hd


def init_block(rng, cfg: ModelConfig):
    d, dt = cfg.d_model, cfg.dtype
    k = jax.random.split(rng, 16)
    nh, hd = head_dims(cfg)
    return {
        "norm_tm": L.init_norm(cfg),
        "norm_cm": L.init_norm(cfg),
        "tm": {
            "mix_base": jnp.zeros((N_MIX, d), dt),
            "mix_x": jnp.zeros((d,), dt),
            "mix_w1": _dinit(k[0], (d, N_MIX * LORA_MIX), dt),
            "mix_w2": _dinit(k[1], (N_MIX, LORA_MIX, d), dt, scale=0.01),
            "wr": _dinit(k[2], (d, d), dt),
            "wk": _dinit(k[3], (d, d), dt),
            "wv": _dinit(k[4], (d, d), dt),
            "wg": _dinit(k[5], (d, d), dt),
            "wo": _dinit(k[6], (d, d), dt),
            "decay_base": jnp.full((d,), -6.0, jnp.float32),
            "decay_w1": _dinit(k[7], (d, LORA_DECAY), dt),
            "decay_w2": _dinit(k[8], (LORA_DECAY, d), dt, scale=0.01),
            "bonus_u": _dinit(k[9], (nh, hd), jnp.float32, scale=0.5),
            "ln_out_scale": jnp.ones((d,), dt),
            "ln_out_bias": jnp.zeros((d,), dt),
        },
        "cm": {
            "mix_k": jnp.zeros((d,), dt),
            "mix_r": jnp.zeros((d,), dt),
            "wk": _dinit(k[10], (d, cfg.d_ff), dt),
            "wv": _dinit(k[11], (cfg.d_ff, d), dt),
            "wr": _dinit(k[12], (d, d), dt),
        },
    }


def init_lm(rng, cfg: ModelConfig):
    k = jax.random.split(rng, 2)
    blocks = jax.vmap(lambda r: init_block(r, cfg))(
        jax.random.split(k[0], cfg.num_layers)
    )
    return {
        "embed": L.init_embedding(k[1], cfg),
        "blocks": blocks,
        "final_norm": L.init_norm(cfg),
    }


def init_state(cfg: ModelConfig, batch: int):
    nh, hd = head_dims(cfg)
    return {
        "ts_tm": jnp.zeros((cfg.num_layers, batch, cfg.d_model), cfg.dtype),
        "ts_cm": jnp.zeros((cfg.num_layers, batch, cfg.d_model), cfg.dtype),
        "wkv": jnp.zeros((cfg.num_layers, batch, nh, hd, hd), jnp.float32),
    }


def state_spec(cfg: ModelConfig, batch: int):
    nh, hd = head_dims(cfg)
    return {
        "ts_tm": jax.ShapeDtypeStruct((cfg.num_layers, batch, cfg.d_model), cfg.dtype),
        "ts_cm": jax.ShapeDtypeStruct((cfg.num_layers, batch, cfg.d_model), cfg.dtype),
        "wkv": jax.ShapeDtypeStruct((cfg.num_layers, batch, nh, hd, hd), jnp.float32),
    }


# ---------------------------------------------------------------------------
# WKV recurrence
# ---------------------------------------------------------------------------


def wkv_scan(r, k, v, w, u, s0):
    """Sequential WKV (the oracle).

    r,k,v: (B, T, H, D); w: (B, T, H, D) decay in (0,1); u: (H, D);
    s0: (B, H, D, D) [key, value]. Returns (out (B,T,H,D), sT).
    """
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))

    def step(s, inputs):
        rt, kt, vt, wt = inputs  # (B, H, D)
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        out = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, out

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (rf, kf, vf, wf))
    sT, outs = lax.scan(step, s0.astype(jnp.float32), xs)
    return outs.transpose(1, 0, 2, 3), sT


def wkv_chunked(r, k, v, w, u, s0, chunk: int = 64):
    """Chunked-parallel WKV: intra-chunk via masked matmuls (MXU friendly),
    inter-chunk state via a scan over T/chunk steps. Matches wkv_scan."""
    b, t, h, d = r.shape
    assert t % chunk == 0, (t, chunk)
    n = t // chunk
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    # (n, B, H, C, D)
    def to_chunks(a):
        return a.reshape(b, n, chunk, h, d).transpose(1, 0, 3, 2, 4)
    rc, kc, vc, wc = map(to_chunks, (rf, kf, vf, wf))

    logw = jnp.log(jnp.maximum(wc, 1e-38))            # (n,B,H,C,D)
    cum = jnp.cumsum(logw, axis=-2)                    # inclusive cumsum
    total = cum[..., -1:, :]                           # (n,B,H,1,D)
    ref = cum[..., chunk // 2 : chunk // 2 + 1, :]     # midpoint reference
    # exponents below are taken relative to ``ref`` so their magnitude is
    # bounded by half-chunk * max|log w| (f32-safe given the decay clip).

    def step(s, xs):
        rt, kt, vt, logw_c, cum_c, total_c, ref_c = xs
        # r_i scaled by prod_{j<=i-1} w (relative to ref)
        r_dec = rt * jnp.exp(cum_c - logw_c - ref_c)   # (B,H,C,D)
        # state contribution: r_i ⊙ prod_{j<i} w · s  (re-apply ref)
        r_state = rt * jnp.exp(cum_c - logw_c)
        out_state = jnp.einsum("bhcd,bhde->bhce", r_state, s)
        # intra-chunk A[i,j] = sum_d r_i[d] k_j[d] exp(cum[i-1,d]-cum[j,d])
        kj = kt * jnp.exp(ref_c - cum_c)               # (B,H,C,D)
        att = jnp.einsum("bhid,bhjd->bhij", r_dec, kj)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        att = jnp.where(mask, att, 0.0)
        diag = jnp.einsum("bhid,bhid->bhi", rt * u[None, :, None, :], kt)
        out_intra = jnp.einsum("bhij,bhjd->bhid", att, vt) + diag[..., None] * vt
        # state update: decay k_j to chunk end
        k_dec = kt * jnp.exp(total_c - cum_c)
        s = jnp.exp(total_c.squeeze(-2))[..., None] * s + jnp.einsum(
            "bhcd,bhce->bhde", k_dec, vt)
        return s, out_state + out_intra

    sT, outs = lax.scan(step, s0.astype(jnp.float32),
                        (rc, kc, vc, logw, cum, total, ref))
    return outs.transpose(1, 0, 3, 2, 4).reshape(b, t, h, d), sT


def wkv_decode(r, k, v, w, u, s):
    """Single-token WKV. r,k,v,w: (B, H, D); s: (B, H, D, D)."""
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    kv = jnp.einsum("bhi,bhj->bhij", kf, vf)
    out = jnp.einsum("bhi,bhij->bhj", rf, s + u[None, :, :, None] * kv)
    s = wf[..., None] * s + kv
    return out, s


# ---------------------------------------------------------------------------
# block
# ---------------------------------------------------------------------------


def _token_shift(x, last):
    """shifted[t] = x[t-1], with ``last`` filling t=0. x: (B,T,d)."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def _time_mix(p, x, last, wkv_state, cfg: ModelConfig, *, seq_mode: str):
    b, t, d = x.shape
    nh, hd = head_dims(cfg)
    xx = _token_shift(x, last) - x
    xbase = x + xx * p["mix_x"]
    lora = jnp.einsum("btd,dm->btm", xbase, p["mix_w1"])
    lora = jnp.tanh(lora).reshape(b, t, N_MIX, LORA_MIX)
    mixes = p["mix_base"][None, None] + jnp.einsum(
        "btnm,nmd->btnd", lora, p["mix_w2"])
    xw, xk, xv, xr, xg = [x + xx * mixes[:, :, i] for i in range(N_MIX)]

    r = jnp.einsum("btd,de->bte", xr, p["wr"]).reshape(b, t, nh, hd)
    k = jnp.einsum("btd,de->bte", xk, p["wk"]).reshape(b, t, nh, hd)
    v = jnp.einsum("btd,de->bte", xv, p["wv"]).reshape(b, t, nh, hd)
    g = jax.nn.silu(jnp.einsum("btd,de->bte", xg, p["wg"]))

    decay_lora = jnp.einsum("btd,dm->btm", xw, p["decay_w1"])
    decay = p["decay_base"][None, None] + jnp.einsum(
        "btm,md->btd", jnp.tanh(decay_lora), p["decay_w2"]).astype(jnp.float32)
    # clip keeps |log w| <= e^0.5 so the chunked form's factored exponents
    # stay f32-safe (see wkv_chunked); scan/decode see the same w.
    decay = jnp.clip(decay, -20.0, 0.5)
    w = jnp.exp(-jnp.exp(decay)).reshape(b, t, nh, hd)   # (0,1)

    if seq_mode == "decode":
        out, new_s = wkv_decode(r[:, 0], k[:, 0], v[:, 0], w[:, 0],
                                p["bonus_u"], wkv_state)
        out = out[:, None]
    elif seq_mode == "chunked" and t % 64 == 0 and t >= 64:
        out, new_s = wkv_chunked(r, k, v, w, p["bonus_u"], wkv_state)
    else:
        out, new_s = wkv_scan(r, k, v, w, p["bonus_u"], wkv_state)

    out = out.reshape(b, t, d)
    # per-head group norm
    og = out.reshape(b, t, nh, hd)
    mu = og.mean(-1, keepdims=True)
    var = og.var(-1, keepdims=True)
    og = (og - mu) * lax.rsqrt(var + 64e-5)
    out = og.reshape(b, t, d) * p["ln_out_scale"].astype(jnp.float32) \
        + p["ln_out_bias"].astype(jnp.float32)
    out = (out.astype(x.dtype) * g)
    return jnp.einsum("btd,de->bte", out, p["wo"]), x[:, -1, :], new_s


def _channel_mix(p, x, last):
    xx = _token_shift(x, last) - x
    xk = x + xx * p["mix_k"]
    xr = x + xx * p["mix_r"]
    k = jnp.square(jax.nn.relu(jnp.einsum("btd,df->btf", xk, p["wk"])))
    kv = jnp.einsum("btf,fd->btd", k, p["wv"])
    return jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["wr"])) * kv, x[:, -1, :]


def _block(bp, x, state, cfg: ModelConfig, seq_mode: str):
    ts_tm, ts_cm, wkv_s = state
    h = L.apply_norm(bp["norm_tm"], x, cfg)
    tm_out, new_ts_tm, new_wkv = _time_mix(
        bp["tm"], h, ts_tm, wkv_s, cfg, seq_mode=seq_mode)
    x = x + tm_out
    h = L.apply_norm(bp["norm_cm"], x, cfg)
    cm_out, new_ts_cm = _channel_mix(bp["cm"], h, ts_cm)
    x = x + cm_out
    return x, (new_ts_tm, new_ts_cm, new_wkv)


def _run(params, x, state, cfg: ModelConfig, seq_mode: str, remat=False):
    def body(carry, scanned):
        bp, st = scanned
        fn = functools.partial(_block, cfg=cfg, seq_mode=seq_mode)
        if remat:
            fn = jax.checkpoint(fn, prevent_cse=False)
        h, new_st = fn(bp, carry, st)
        return h, new_st

    sts = (state["ts_tm"], state["ts_cm"], state["wkv"])
    if not cfg.scan_layers:
        a, b_, c = sts
        for i in range(cfg.num_layers):
            bp = jax.tree.map(lambda t: t[i], params["blocks"])
            x, ns = body(x, (bp, (a[i], b_[i], c[i])))
            a, b_, c = a.at[i].set(ns[0]), b_.at[i].set(ns[1]), c.at[i].set(ns[2])
        new = (a, b_, c)
    else:
        x, new = lax.scan(body, x, (params["blocks"], sts))
    return x, {"ts_tm": new[0], "ts_cm": new[1], "wkv": new[2]}


# ---------------------------------------------------------------------------
# entry points (same interface as transformer.py)
# ---------------------------------------------------------------------------


def forward_train(params, tokens, cfg: ModelConfig, ep=None):
    b = tokens.shape[0]
    x = L.embed(params["embed"], tokens, cfg)
    state = init_state(cfg, b)
    x, _ = _run(params, x, state, cfg, "chunked", remat=True)
    x = L.apply_norm(params["final_norm"], x, cfg)
    return L.unembed(params["embed"], x, cfg)


def lm_loss(params, batch, cfg: ModelConfig, ep=None):
    logits = forward_train(params, batch["tokens"], cfg)
    return L.softmax_xent(logits, batch["labels"])


def prefill(params, state, tokens, lengths, cfg: ModelConfig, ep=None):
    """NOTE: linear-state models have no per-position cache; requests padded
    to a common length are handled by the engine one-at-a-time (B matches)."""
    x = L.embed(params["embed"], tokens, cfg)
    x, state = _run(params, x, state, cfg, "chunked")
    x = L.apply_norm(params["final_norm"], x, cfg)
    idx = jnp.clip(lengths - 1, 0, tokens.shape[1] - 1)
    last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    return L.unembed(params["embed"], last[:, None], cfg)[:, 0], state


def decode(params, state, tokens, lengths, cfg: ModelConfig, ep=None):
    x = L.embed(params["embed"], tokens[:, None], cfg)
    x, state = _run(params, x, state, cfg, "decode")
    x = L.apply_norm(params["final_norm"], x, cfg)
    return L.unembed(params["embed"], x, cfg)[:, 0], state
